//! Bench: embedded streaming engine — per-step latency by precision and
//! time-batch, and the per-component split (rec vs nonrec vs gates).

#[path = "harness.rs"]
mod harness;
use harness::{bench, fmt, header};

use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::model::ParamSet;
use tracenorm::prng::Pcg64;
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::tensor::Tensor;

/// wsj_mini dimensions (keep in sync with python configs).
fn dims() -> ModelDims {
    ModelDims {
        feat_dim: 40,
        conv: vec![ConvDims { context: 2, dim: 64 }, ConvDims { context: 2, dim: 96 }],
        gru_dims: vec![96, 128, 160],
        fc_dim: 192,
        vocab: 29,
        total_stride: 4,
    }
}

fn params(dims: &ModelDims, rank_frac: f64, seed: u64) -> ParamSet {
    let mut rng = Pcg64::seeded(seed);
    let mut p = ParamSet::new();
    let mut prev = dims.feat_dim;
    for (i, c) in dims.conv.iter().enumerate() {
        p.set(format!("conv{i}_w"), Tensor::glorot(c.dim, c.context * prev, &mut rng));
        p.set(format!("conv{i}_b"), Tensor::zeros(&[c.dim]));
        prev = c.dim;
    }
    for (i, &h) in dims.gru_dims.iter().enumerate() {
        let din = if i == 0 { dims.conv.last().unwrap().dim } else { dims.gru_dims[i - 1] };
        let r = ((h.min(din) as f64 * rank_frac) as usize).max(4);
        p.set(format!("rec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
        p.set(format!("rec{i}_v"), Tensor::glorot(r, h, &mut rng));
        p.set(format!("nonrec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
        p.set(format!("nonrec{i}_v"), Tensor::glorot(r, din, &mut rng));
        p.set(format!("gru{i}_b"), Tensor::zeros(&[3 * h]));
    }
    let last = *dims.gru_dims.last().unwrap();
    let r = ((dims.fc_dim.min(last) as f64 * rank_frac) as usize).max(4);
    p.set("fc_u", Tensor::glorot(dims.fc_dim, r, &mut rng));
    p.set("fc_v", Tensor::glorot(r, last, &mut rng));
    p.set("fc_b", Tensor::zeros(&[dims.fc_dim]));
    p.set("out_w", Tensor::glorot(dims.vocab, dims.fc_dim, &mut rng));
    p.set("out_b", Tensor::zeros(&[dims.vocab]));
    p
}

fn main() {
    let d = dims();
    let mut rng = Pcg64::seeded(3);
    let utter = Tensor::randn(&[96, d.feat_dim], 0.7, &mut rng);

    header("streaming engine: utterance latency by precision / rank");
    for (label, frac) in [("rank 1.00", 1.0), ("rank 0.25", 0.25)] {
        let p = params(&d, frac, 1);
        for prec in [Precision::F32, Precision::Int8] {
            let engine = Engine::from_params(&d, "partial", &p, prec, 4).unwrap();
            bench(&format!("{label} {prec:?} transcribe 96 frames"), 400, || {
                let mut bd = Breakdown::default();
                std::hint::black_box(engine.transcribe(&utter, &mut bd).unwrap());
            });
        }
    }

    header("time-batch sweep (int8, rank 0.25)");
    let p = params(&d, 0.25, 1);
    for tb in [1usize, 2, 4, 8] {
        let engine = Engine::from_params(&d, "partial", &p, Precision::Int8, tb).unwrap();
        bench(&format!("time_batch={tb} transcribe"), 400, || {
            let mut bd = Breakdown::default();
            std::hint::black_box(engine.transcribe(&utter, &mut bd).unwrap());
        });
    }

    header("per-component split (int8, rank 0.25, time_batch 4)");
    let engine = Engine::from_params(&d, "partial", &p, Precision::Int8, 4).unwrap();
    let mut bd = Breakdown::default();
    for _ in 0..50 {
        let _ = engine.transcribe(&utter, &mut bd).unwrap();
    }
    let total = bd.acoustic_total();
    println!(
        "frontend {:>9} ({:4.1}%)  nonrec {:>9} ({:4.1}%)  rec {:>9} ({:4.1}%)  gates {:>9} ({:4.1}%)  fc/out {:>9} ({:4.1}%)",
        fmt(bd.frontend / 50.0), bd.frontend / total * 100.0,
        fmt(bd.nonrec / 50.0), bd.nonrec / total * 100.0,
        fmt(bd.rec / 50.0), bd.rec / total * 100.0,
        fmt(bd.gates / 50.0), bd.gates / total * 100.0,
        fmt(bd.fc_out / 50.0), bd.fc_out / total * 100.0,
    );
}

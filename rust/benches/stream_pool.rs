//! Bench: the multi-stream pool (DESIGN.md §6) — the repo's first
//! trajectory bench for the concurrency architecture.
//!
//! Two views:
//! 1. **kernel**: the pooled batch-m recurrent GEMM
//!    (`qgemm_farm_rows`) against m sequential batch-1 `qgemm_farm`
//!    calls on a paper-scale recurrent layer.  The acceptance target is
//!    pooled m=4 ≥ 2× the 4-sequential baseline — the weight matrix
//!    streams through cache once instead of four times.
//! 2. **end-to-end**: throughput and per-stream latency of a
//!    `StreamPool` at pool sizes 1/2/4/8 vs decoding the same streams
//!    one after another.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use std::sync::Arc;

use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::kernels::{qgemm_farm, qgemm_farm_rows};
use tracenorm::prng::Pcg64;
use tracenorm::stream::{demo_dims, synthetic_params, StreamPool};
use tracenorm::tensor::{Tensor, TensorI8};

fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
    let n: usize = shape.iter().product();
    TensorI8::new(shape, (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()).unwrap()
}

fn main() {
    // paper-scale GRU recurrent weight: 3·768 × 768 int8 (~1.7 MB, well
    // past L2, so the weight stream dominates)
    const N: usize = 3 * 768;
    const K: usize = 768;
    header(&format!("pooled recurrent GEMM: batch-m vs m sequential batch-1 ({N}x{K} int8)"));
    let mut rng = Pcg64::seeded(0);
    let w = rand_i8(&[N, K], &mut rng);
    for m in [1usize, 2, 4, 8] {
        let x = rand_i8(&[m, K], &mut rng);
        let rows: Vec<TensorI8> =
            (0..m).map(|i| TensorI8::new(&[1, K], x.row(i).to_vec()).unwrap()).collect();
        let scales: Vec<f32> = (0..m).map(|i| 0.008 + 0.001 * i as f32).collect();
        let tp = bench(&format!("pooled     m={m}"), 300, || {
            std::hint::black_box(qgemm_farm_rows(&x, &w, &scales, 0.02));
        });
        let ts = bench(&format!("sequential {m} x m=1"), 300, || {
            for (r, s) in rows.iter().zip(&scales) {
                std::hint::black_box(qgemm_farm(r, &w, *s, 0.02));
            }
        });
        println!("  -> pooled speedup {:.2}x (acceptance: >= 2x at m=4)", ts / tp);
    }

    header("stream pool end-to-end (int8 wsj_mini, 96-frame utterances)");
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 1);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap());
    let utter = Tensor::randn(&[96, dims.feat_dim], 0.7, &mut rng);
    let audio_secs = 96.0 * 0.01;

    for m in [1usize, 2, 4, 8] {
        let tseq = bench(&format!("sequential {m} streams"), 400, || {
            for _ in 0..m {
                let mut bd = Breakdown::default();
                std::hint::black_box(engine.transcribe(&utter, &mut bd).unwrap());
            }
        });
        let mut pool = StreamPool::new(engine.clone(), m);
        let tpool = bench(&format!("pooled     {m} streams"), 400, || {
            let mut bd = Breakdown::default();
            let ids: Vec<_> = (0..m).map(|_| pool.open().unwrap()).collect();
            for &id in &ids {
                pool.push_frames(id, utter.data()).unwrap();
            }
            pool.pump(&mut bd).unwrap();
            for &id in &ids {
                std::hint::black_box(pool.close(id, &mut bd).unwrap());
            }
        });
        println!(
            "  per-stream {:.3} ms (vs {:.3} ms sequential)  |  {:.1}x realtime aggregate",
            tpool * 1e3 / m as f64,
            tseq * 1e3 / m as f64,
            m as f64 * audio_secs / tpool
        );
    }
}

//! Bench: the sharded serving runtime (DESIGN.md §9) — shards × pool
//! size sweep under a saturating burst, emitting machine-readable
//! `BENCH_shard.json` for the perf trajectory (uploaded by CI like
//! `BENCH_gemm.json`).
//!
//! The acceptance shape: steady-state *simulated-span throughput*
//! (sessions per second of clock, where the clock advances by the
//! measured wall-clock of each parallel round) increases with shard
//! count on a multi-core host — N shards decode N pools concurrently,
//! so a round costs ~one pool's tick instead of N of them.  On a
//! single-core or loaded host the curve flattens; the JSON records
//! `available_parallelism` so a flat curve is never misread.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use std::sync::Arc;

use tracenorm::data::{CorpusSpec, Dataset};
use tracenorm::infer::{Engine, Precision};
use tracenorm::jsonx::Json;
use tracenorm::serve::{stream_serve, StreamServeConfig};
use tracenorm::stream::{demo_dims, synthetic_params};

fn main() {
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 1);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap());
    let utts = 24usize;
    let data = Dataset::generate(CorpusSpec::standard(41), 0, 0, utts);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    header(&format!(
        "sharded serve: shards x pool sweep ({utts} burst sessions, int8 wsj_mini, {cores} cores)"
    ));
    let mut results = Vec::new();
    for &shards in &[1usize, 2, 4] {
        for &pool in &[2usize, 4] {
            let cfg = StreamServeConfig {
                arrival_rate: 1e6, // burst: every slot fills immediately
                pool_size: pool,
                chunk_frames: 16,
                shards,
                seed: 9,
                ..Default::default()
            };
            // wall-clock of the whole serve (spawn + rounds + drain)
            let wall = bench(&format!("serve shards={shards} pool={pool}"), 600, || {
                let r = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
                std::hint::black_box(&r);
            });
            // one representative run for the simulated-clock report
            let r = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
            println!(
                "  -> {:.1} sessions/s simulated  (p50 {:.1} ms, p99 {:.1} ms, busy {:.3} s over {:.3} s span, rec batch {:.2})",
                r.throughput,
                r.session_latency.p50 * 1e3,
                r.session_latency.p99 * 1e3,
                r.busy_secs,
                r.span_secs,
                r.mean_rec_batch
            );
            results.push(Json::obj(vec![
                ("shards", Json::num(shards as f64)),
                ("pool", Json::num(pool as f64)),
                ("sessions", Json::num(r.sessions as f64)),
                ("throughput", Json::num(r.throughput)),
                ("p50", Json::num(r.session_latency.p50)),
                ("p99", Json::num(r.session_latency.p99)),
                ("busy_secs", Json::num(r.busy_secs)),
                ("span_secs", Json::num(r.span_secs)),
                ("mean_rec_batch", Json::num(r.mean_rec_batch)),
                ("serve_wall_secs", Json::num(wall)),
            ]));
        }
    }

    let report = Json::obj(vec![
        ("bench", Json::str("shard")),
        ("sessions", Json::num(utts as f64)),
        ("backend", Json::str(engine.backend_name())),
        ("available_parallelism", Json::num(cores as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = std::env::var("BENCH_SHARD_JSON").unwrap_or_else(|_| "BENCH_shard.json".into());
    std::fs::write(&path, report.to_string_pretty()).expect("write BENCH_shard.json");
    println!("wrote machine-readable sweep to {path}");
}

//! Bench: confidence-gated cascade decoding (DESIGN.md §11) — the
//! CER-vs-effective-FLOPs curve per rung pair, persisted to
//! `BENCH_cascade.json` (path overridable via `BENCH_CASCADE_JSON`).
//!
//! Each rung pair shares one synthetic seed, so the unfactored conv
//! frontend is byte-identical across the pair and escalated blocks
//! reuse it (the `shared_frontend` fast path).  Per threshold the sweep
//! decodes the synthetic corpus through the cascade pool and records
//! the escalation rate, the analytic effective GFLOP/frame
//! (`low + rate * (high - shared frontend)` — the same accounting the
//! serve reports print), corpus CER against the reference texts, and
//! the fidelity gap (CER of the cascade transcript against the pure
//! high-rung transcript).  `matched_cer_flops_reduction` is the best
//! `high / effective` ratio over sweep points whose CER matches the
//! pure high rung — the ISSUE-10 acceptance number (>= 1.5 expected).

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use std::sync::Arc;

use tracenorm::data::{CorpusSpec, Dataset, Utterance};
use tracenorm::decoder::cer;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::jsonx::Json;
use tracenorm::stream::{demo_dims, synthetic_params, CascadeCfg, PoolStats, StreamPool};

/// A rung engine at `frac` from the seed shared by every rung.
fn engine_at(frac: f64) -> Arc<Engine> {
    let dims = demo_dims();
    let p = synthetic_params(&dims, frac, 5);
    Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap())
}

/// Pooled decode of the whole corpus (4 concurrent sessions, ragged
/// chunk pushes); returns per-utterance transcripts and the gate stats.
fn decode_corpus(
    low: &Arc<Engine>,
    cascade: Option<&CascadeCfg>,
    utts: &[Utterance],
) -> (Vec<String>, PoolStats) {
    let feat = low.feat_dim();
    let mut pool = StreamPool::new(low.clone(), 4);
    if let Some(cc) = cascade {
        pool.set_cascade(cc.clone()).unwrap();
    }
    let mut out = vec![String::new(); utts.len()];
    let mut bd = Breakdown::default();
    for group in (0..utts.len()).collect::<Vec<usize>>().chunks(4) {
        let ids: Vec<(tracenorm::stream::StreamId, usize)> =
            group.iter().map(|&i| (pool.open().unwrap(), i)).collect();
        let mut off = vec![0usize; ids.len()];
        let mut open = ids.len();
        while open > 0 {
            for (k, &(id, i)) in ids.iter().enumerate() {
                if off[k] == usize::MAX {
                    continue;
                }
                let data = utts[i].feats.data();
                let end = (off[k] + 32 * feat).min(data.len());
                if off[k] < end {
                    pool.push_frames(id, &data[off[k]..end]).unwrap();
                    off[k] = end;
                }
                if off[k] >= data.len() {
                    out[i] = pool.close(id, &mut bd).unwrap().transcript;
                    off[k] = usize::MAX;
                    open -= 1;
                }
            }
            pool.pump(&mut bd).unwrap();
        }
    }
    (out, pool.stats)
}

fn mean_cer(hyps: &[String], refs: &[&str]) -> f64 {
    let sum: f64 = hyps.iter().zip(refs).map(|(h, r)| cer(h, r)).sum();
    sum / hyps.len() as f64
}

fn main() {
    let n = 8;
    let data = Dataset::generate(CorpusSpec::standard(5), 0, 0, n);
    let texts: Vec<&str> = data.test.iter().map(|u| u.text.as_str()).collect();
    let pairs = [(0.125, 0.5), (0.125, 0.75)];
    let thresholds = [0.0, 1e-3, 0.01, 0.1, 0.3, 1.0, f64::INFINITY];

    let mut results: Vec<Json> = Vec::new();
    let mut best_reduction = 0.0f64;
    for (lf, hf) in pairs {
        let low = engine_at(lf);
        let high = engine_at(hf);
        let stride = low.total_stride() as f64;
        let gflops = |macs: u64| 2.0 * macs as f64 / stride / 1e9;
        let gl = gflops(low.macs_per_step());
        let gh = gflops(high.macs_per_step());
        // escalated blocks reuse the low rung's frontend activations
        let g_esc = gflops(high.macs_per_step() - high.frontend_macs_per_step());

        header(&format!(
            "cascade {lf}:{hf} — low {gl:.4} / high {gh:.4} GFLOP/frame, {n} utts"
        ));
        let (high_hyps, _) = decode_corpus(&high, None, &data.test);
        let cer_high = mean_cer(&high_hyps, &texts);
        let high_refs: Vec<&str> = high_hyps.iter().map(String::as_str).collect();

        for t in thresholds {
            let cc = CascadeCfg { high: high.clone(), threshold: t, shared_frontend: true };
            let mut last: Option<(Vec<String>, PoolStats)> = None;
            let secs = bench(&format!("decode corpus @ threshold {t}"), 250, || {
                last = Some(decode_corpus(&low, Some(&cc), &data.test));
            });
            let (hyps, stats) = last.unwrap();
            let rate = stats.escalation_rate();
            let g_eff = gl + rate * g_esc;
            let c = mean_cer(&hyps, &texts);
            let gap = mean_cer(&hyps, &high_refs);
            // "matched CER": no worse than the pure high rung on the
            // corpus (small slack for ties), or transcript-identical
            if c <= cer_high + 0.005 || gap == 0.0 {
                best_reduction = best_reduction.max(gh / g_eff);
            }
            println!(
                "    esc {:5.1}%  eff {g_eff:.4} GF/frame ({:.2}x below high)  \
                 cer {c:.3} (high {cer_high:.3})  gap-vs-high {gap:.3}",
                rate * 100.0,
                gh / g_eff
            );
            results.push(Json::obj(vec![
                ("pair", Json::str(format!("{lf}:{hf}"))),
                // inf is not representable in strict JSON
                ("threshold", Json::str(t.to_string())),
                ("escalation_rate", Json::num(rate)),
                ("stream_blocks", Json::num(stats.stream_blocks as f64)),
                ("escalated_blocks", Json::num(stats.escalated_blocks as f64)),
                ("gflops_low", Json::num(gl)),
                ("gflops_high", Json::num(gh)),
                ("gflops_effective", Json::num(g_eff)),
                ("flops_reduction_vs_high", Json::num(gh / g_eff)),
                ("cer", Json::num(c)),
                ("cer_high_rung", Json::num(cer_high)),
                ("cer_gap_vs_high", Json::num(gap)),
                ("corpus_secs", Json::num(secs)),
            ]));
        }
    }

    println!(
        "\nbest effective-FLOPs reduction at matched CER: {best_reduction:.2}x \
         (acceptance floor 1.5x)"
    );
    let report = Json::obj(vec![
        ("bench", Json::str("cascade")),
        ("utts", Json::num(n as f64)),
        ("matched_cer_flops_reduction", Json::num(best_reduction)),
        ("results", Json::Arr(results)),
    ]);
    let path =
        std::env::var("BENCH_CASCADE_JSON").unwrap_or_else(|_| "BENCH_cascade.json".into());
    std::fs::write(&path, report.to_string_pretty()).expect("write BENCH_cascade.json");
    println!("wrote {path}");
}

//! Bench: the SVD / warmstart path (the stage-1→2 transition cost) and
//! the ν diagnostic.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::linalg::{nu_coefficient, svd};
use tracenorm::prng::Pcg64;
use tracenorm::tensor::Tensor;

fn main() {
    header("Jacobi SVD by matrix size (wsj_mini group shapes)");
    let mut rng = Pcg64::seeded(0);
    for &(m, n) in &[(288usize, 96usize), (384, 128), (480, 160), (192, 160), (480, 480)] {
        let w = Tensor::randn(&[m, n], 1.0, &mut rng);
        bench(&format!("svd {m}x{n}"), 600, || {
            std::hint::black_box(svd(&w).unwrap());
        });
    }

    header("nu coefficient");
    let w = Tensor::randn(&[480, 160], 1.0, &mut rng);
    bench("nu 480x160", 400, || {
        std::hint::black_box(nu_coefficient(&w).unwrap());
    });

    header("truncated reconstruction (rank 40 of 480x160)");
    let w = Tensor::randn(&[480, 160], 1.0, &mut rng);
    let s = svd(&w).unwrap();
    bench("balanced_factors r=40", 300, || {
        std::hint::black_box(s.balanced_factors(40));
    });
    bench("reconstruct r=40", 300, || {
        std::hint::black_box(s.reconstruct(40));
    });
}

//! Bench: the Figure-6 GEMM comparison (farm vs gemmlowp-style vs f32)
//! across batch sizes, plus GOP/s and the farm/lowp speedup factor.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::kernels::{farm_counts, gemm_f32, qgemm_farm, qgemm_lowp};
use tracenorm::prng::Pcg64;
use tracenorm::tensor::{Tensor, TensorI8};

const N: usize = 6144;
const K: usize = 320;

fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
    let n: usize = shape.iter().product();
    TensorI8::new(shape, (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()).unwrap()
}

fn main() {
    header(&format!("Fig 6 benchmark: A = {N}x{K} int8, batch sweep"));
    let mut rng = Pcg64::seeded(0);
    let w = rand_i8(&[N, K], &mut rng);
    let wf = Tensor::randn(&[N, K], 0.05, &mut rng);

    for m in [1usize, 2, 4, 8, 16] {
        let x = rand_i8(&[m, K], &mut rng);
        let xf = Tensor::randn(&[m, K], 1.0, &mut rng);
        let ops = farm_counts(m, N, K).ops() as f64;

        let tf = bench(&format!("qgemm_farm   m={m}"), 300, || {
            std::hint::black_box(qgemm_farm(&x, &w, 0.01, 0.01));
        });
        let tl = bench(&format!("qgemm_lowp   m={m}"), 300, || {
            std::hint::black_box(qgemm_lowp(&x, &w, 0.01, 0.01));
        });
        bench(&format!("gemm_f32     m={m}"), 300, || {
            std::hint::black_box(gemm_f32(&xf, &wf, None));
        });
        println!(
            "  -> farm {:.2} GOP/s, lowp {:.2} GOP/s, farm/lowp speedup {:.2}x\n",
            ops / tf / 1e9,
            ops / tl / 1e9,
            tl / tf
        );
    }
}

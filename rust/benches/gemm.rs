//! Bench: the Figure-6 GEMM comparison (farm vs gemmlowp-style vs f32)
//! across batch sizes, plus the **backend sweep**: every registered
//! [`GemmBackend`](tracenorm::kernels::GemmBackend) × m ∈ {1,2,4,8} ×
//! bits ∈ {8,4} on steady-state `*_into` calls — weights pre-packed
//! once, output tensor reused — so the numbers measure exactly what the
//! engine's hot loop pays.  Every quantized row carries its `bits` and
//! `bytes_per_weight` (1.0 int8, 0.625 int4 at the 32-column scale
//! group).  Packing cost is excluded from the steady-state rows and
//! reported separately.
//!
//! Emits machine-readable `BENCH_gemm.json` (override the path with
//! `BENCH_GEMM_JSON`) so future PRs have a perf trajectory.  The
//! acceptance floor for this module tree is `blocked >= scalar` at every
//! m in the sweep.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::jsonx::Json;
use tracenorm::kernels::{
    all_backends, farm4_counts, farm_counts, gemm_f32, qgemm_farm, qgemm_lowp,
    simd_runtime_available, GemmBackend, PackedGatePanels, PackedQ4Matrix, PackedQMatrix,
    PreparedQ4Matrix, PreparedQMatrix,
};
use tracenorm::prng::Pcg64;
use tracenorm::quant::{quantize4, QMatrix};
use tracenorm::tensor::{Tensor, TensorI8};

const N: usize = 6144;
const K: usize = 320;

fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
    let n: usize = shape.iter().product();
    TensorI8::new(shape, (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()).unwrap()
}

fn main() {
    header(&format!("Fig 6 benchmark: A = {N}x{K} int8, batch sweep"));
    let mut rng = Pcg64::seeded(0);
    let w = rand_i8(&[N, K], &mut rng);
    let wf = Tensor::randn(&[N, K], 0.05, &mut rng);

    for m in [1usize, 2, 4, 8, 16] {
        let x = rand_i8(&[m, K], &mut rng);
        let xf = Tensor::randn(&[m, K], 1.0, &mut rng);
        let ops = farm_counts(m, N, K).ops() as f64;

        let tf = bench(&format!("qgemm_farm   m={m}"), 300, || {
            std::hint::black_box(qgemm_farm(&x, &w, 0.01, 0.01));
        });
        let tl = bench(&format!("qgemm_lowp   m={m}"), 300, || {
            std::hint::black_box(qgemm_lowp(&x, &w, 0.01, 0.01));
        });
        bench(&format!("gemm_f32     m={m}"), 300, || {
            std::hint::black_box(gemm_f32(&xf, &wf, None));
        });
        println!(
            "  -> farm {:.2} GOP/s, lowp {:.2} GOP/s, farm/lowp speedup {:.2}x\n",
            ops / tf / 1e9,
            ops / tl / 1e9,
            tl / tf
        );
    }

    // -- backend sweep: steady-state *_into calls, pre-packed weights ------

    header(&format!("backend sweep: {N}x{K}, *_into steady state (packing excluded)"));
    let tpack = bench("PackedQMatrix::pack (one-time plan cost)", 200, || {
        std::hint::black_box(PackedQMatrix::pack(&w));
    });
    let prepped = PreparedQMatrix::new(QMatrix { q: w.clone(), scale: 0.01 });
    // the same weight read as a stacked [z|r|h̃] gate matrix (N = 3H), so
    // the fused sweep is directly comparable to the plain rows sweep
    assert_eq!(N % 3, 0, "fused sweep needs a stacked gate shape");
    let tgpack = bench("PackedGatePanels::pack (one-time plan cost)", 200, || {
        std::hint::black_box(PackedGatePanels::pack(&w));
    });
    let prepped_gates = PreparedQMatrix::new_with_gates(QMatrix { q: w.clone(), scale: 0.01 });

    let mut results: Vec<Json> = Vec::new();
    for (_, be) in all_backends() {
        for m in [1usize, 2, 4, 8] {
            let x = rand_i8(&[m, K], &mut rng);
            let xf = Tensor::randn(&[m, K], 1.0, &mut rng);
            let scales: Vec<f32> = (0..m).map(|i| 0.008 + 0.001 * i as f32).collect();
            let ops = farm_counts(m, N, K).ops() as f64;
            let mut out = Tensor::zeros(&[m, N]);

            let tq = bench(&format!("{:<8} qgemm_farm_into      m={m}", be.name()), 300, || {
                be.qgemm_farm_into(x.data(), m, &prepped, 0.01, &mut out);
                std::hint::black_box(&out);
            });
            let tr = bench(&format!("{:<8} qgemm_farm_rows_into m={m}", be.name()), 300, || {
                be.qgemm_farm_rows_into(x.data(), m, &prepped, &scales, &mut out);
                std::hint::black_box(&out);
            });
            let tg = bench(&format!("{:<8} qgemm_gates_rows     m={m}", be.name()), 300, || {
                be.qgemm_gates_rows_into(x.data(), m, &prepped_gates, &scales, &mut out);
                std::hint::black_box(&out);
            });
            let tf32 = bench(&format!("{:<8} gemm_f32_into        m={m}", be.name()), 300, || {
                be.gemm_f32_into(&xf, &wf, None, &mut out);
                std::hint::black_box(&out);
            });
            let mut kinds = vec![
                ("qgemm_farm", tq),
                ("qgemm_farm_rows", tr),
                ("qgemm_gates", tg),
                ("gemm_f32", tf32),
            ];
            if m == 1 {
                // the steady-state decode shape: the dedicated GEMV path
                let tv = bench(&format!("{:<8} qgemv_into           m=1", be.name()), 300, || {
                    be.qgemv_into(x.data(), &prepped, 0.01, &mut out);
                    std::hint::black_box(&out);
                });
                kinds.push(("qgemv", tv));
            }
            for (kind, secs) in kinds {
                let bits = if kind == "gemm_f32" { 32 } else { 8 };
                let bpw = if kind == "gemm_f32" { 4.0 } else { 1.0 };
                results.push(Json::obj(vec![
                    ("backend", Json::str(be.name())),
                    ("kind", Json::str(kind)),
                    ("m", Json::num(m as f64)),
                    ("bits", Json::num(bits as f64)),
                    ("bytes_per_weight", Json::num(bpw)),
                    ("secs", Json::num(secs)),
                    ("gops", Json::num(ops / secs / 1e9)),
                ]));
            }
        }
        println!();
    }

    // -- int4 sweep: the packed sub-byte path on the same shapes ------------

    header(&format!("int4 sweep: {N}x{K} nibble-packed, *_into steady state"));
    let wq4 = quantize4(&wf);
    // weight-stream bytes per weight scalar: packed nibbles + per-group
    // scales (0.625 at the 32-column group), vs 1.0 for int8
    let bpw4 = wq4.payload_bytes() as f64 / (N * K) as f64;
    let tq4pack = bench("PackedQ4Matrix::pack (one-time plan cost)", 200, || {
        std::hint::black_box(PackedQ4Matrix::pack(&wq4));
    });
    let prepped4 = PreparedQ4Matrix::new(wq4.clone());
    let prepped4_gates = PreparedQ4Matrix::new_with_gates(wq4.clone());
    assert!(prepped4_gates.gates.is_some(), "int4 fused sweep needs gate panels");
    for (_, be) in all_backends() {
        for m in [1usize, 2, 4, 8] {
            let x = rand_i8(&[m, K], &mut rng);
            let scales: Vec<f32> = (0..m).map(|i| 0.008 + 0.001 * i as f32).collect();
            let ops = farm4_counts(m, N, K).ops() as f64;
            let mut out = Tensor::zeros(&[m, N]);

            let tq = bench(&format!("{:<8} qgemm4_farm_into     m={m}", be.name()), 300, || {
                be.qgemm4_farm_into(x.data(), m, &prepped4, 0.01, &mut out);
                std::hint::black_box(&out);
            });
            let tr = bench(&format!("{:<8} qgemm4_farm_rows     m={m}", be.name()), 300, || {
                be.qgemm4_farm_rows_into(x.data(), m, &prepped4, &scales, &mut out);
                std::hint::black_box(&out);
            });
            let tg = bench(&format!("{:<8} qgemm4_gates_rows    m={m}", be.name()), 300, || {
                be.qgemm4_gates_rows_into(x.data(), m, &prepped4_gates, &scales, &mut out);
                std::hint::black_box(&out);
            });
            let mut kinds = vec![
                ("qgemm4_farm", tq),
                ("qgemm4_farm_rows", tr),
                ("qgemm4_gates", tg),
            ];
            if m == 1 {
                let tv = bench(&format!("{:<8} qgemv4_into          m=1", be.name()), 300, || {
                    be.qgemv4_into(x.data(), &prepped4, 0.01, &mut out);
                    std::hint::black_box(&out);
                });
                kinds.push(("qgemv4", tv));
            }
            for (kind, secs) in kinds {
                results.push(Json::obj(vec![
                    ("backend", Json::str(be.name())),
                    ("kind", Json::str(kind)),
                    ("m", Json::num(m as f64)),
                    ("bits", Json::num(4.0)),
                    ("bytes_per_weight", Json::num(bpw4)),
                    ("secs", Json::num(secs)),
                    ("gops", Json::num(ops / secs / 1e9)),
                ]));
            }
        }
        println!();
    }

    let report = Json::obj(vec![
        ("bench", Json::str("gemm")),
        ("n", Json::num(N as f64)),
        ("k", Json::num(K as f64)),
        ("pack_secs", Json::num(tpack)),
        ("gate_pack_secs", Json::num(tgpack)),
        ("q4_pack_secs", Json::num(tq4pack)),
        ("pack_excluded_from_steady_state", Json::Bool(true)),
        // when false, any backend="simd" rows below are scalar-fallback
        // timings — do not read them as vector-path numbers
        ("simd_vector_path_available", Json::Bool(simd_runtime_available())),
        ("results", Json::Arr(results)),
    ]);
    let path = std::env::var("BENCH_GEMM_JSON").unwrap_or_else(|_| "BENCH_gemm.json".into());
    std::fs::write(&path, report.to_string_pretty()).expect("write BENCH_gemm.json");
    println!("wrote machine-readable sweep to {path}");
}

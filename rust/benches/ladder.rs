//! Bench: the rank-ladder registry (DESIGN.md §8) — offline build cost,
//! registry load (checksum + engine construction from stored int8
//! factors, no SVD), the per-rung decode latency that makes the ladder a
//! serving knob (the paper's Figure-1 tradeoff at runtime), and the
//! controller's per-tick overhead (which must be noise next to a GEMM).

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::controller::{ControllerConfig, FidelityController};
use tracenorm::infer::Breakdown;
use tracenorm::prng::Pcg64;
use tracenorm::registry::{ladder_build, Registry};
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::stream::{demo_dims, synthetic_params};
use tracenorm::tensor::Tensor;

/// Mid-size dims for the build bench: big enough that the SVDs are real
/// work, small enough that BENCH_SMOKE stays quick.
fn build_dims() -> ModelDims {
    ModelDims {
        feat_dim: 40,
        conv: vec![ConvDims { context: 2, dim: 48 }],
        gru_dims: vec![48, 64],
        fc_dim: 64,
        vocab: 29,
        total_stride: 2,
    }
}

fn main() {
    let tmp = std::env::temp_dir().join(format!("tnladder-bench-{}", std::process::id()));

    header("ladder-build: per-group truncated SVD + int8 quantize (mid dims)");
    let bdims = build_dims();
    let bparams = synthetic_params(&bdims, 1.0, 0);
    let build_dir = tmp.join("mid");
    bench("ladder_build 2 rungs (0.5, 0.25)", 2000, || {
        ladder_build(&bparams, &bdims, &[0.5, 0.25], &build_dir).unwrap();
    });

    // serve-side benches run on the full demo dims; build once outside
    // the timed region (the offline pass is not the serving hot path)
    let dims = demo_dims();
    let params = synthetic_params(&dims, 1.0, 1);
    let serve_dir = tmp.join("demo");
    ladder_build(&params, &dims, &[0.5, 0.125], &serve_dir).unwrap();

    header("registry load: checksum-verify + engines from stored int8 factors");
    bench("Registry::load 2 rungs", 1000, || {
        std::hint::black_box(Registry::load(&serve_dir, 4).unwrap());
    });

    header("per-rung decode latency (96-frame utterance, int8)");
    let reg = Registry::load(&serve_dir, 4).unwrap();
    let mut rng = Pcg64::seeded(2);
    let utter = Tensor::randn(&[96, dims.feat_dim], 0.7, &mut rng);
    for tier in 0..reg.num_tiers() {
        let v = reg.tier(tier);
        let name = format!(
            "tier {tier} {} (rank {:.3}, {} KB)",
            v.info.tag,
            v.info.rank_frac,
            v.info.bytes / 1024
        );
        bench(&name, 400, || {
            let mut bd = Breakdown::default();
            std::hint::black_box(v.engine.transcribe(&utter, &mut bd).unwrap());
        });
    }

    header("controller overhead (1e4 observe+record ticks)");
    let mut ctl = FidelityController::new(3, ControllerConfig::default()).unwrap();
    let mut i = 0u64;
    bench("10k control ticks", 300, || {
        for _ in 0..10_000 {
            i = i.wrapping_add(1);
            ctl.record_latency((i % 3) as usize, 0.01 + (i % 7) as f64 * 1e-3);
            std::hint::black_box(ctl.observe(i as f64, ((i % 10) as f64) / 10.0));
        }
    });

    let _ = std::fs::remove_dir_all(&tmp);
}

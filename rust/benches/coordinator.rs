//! Bench: the PJRT coordinator hot path — train step, eval step, stream
//! chunk step, and the serving batcher — against real AOT artifacts.
//! Skips (successfully) when `make artifacts` hasn't run.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::data::{make_batch, CorpusSpec, Dataset, Utterance};
use tracenorm::model::ParamSet;
use tracenorm::runtime::{Runtime, Value};
use tracenorm::tensor::Tensor;
use tracenorm::train::{TrainOpts, Trainer};

fn main() {
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP coordinator bench (run `make artifacts`): {e}");
            return;
        }
    };
    let data = Dataset::generate(CorpusSpec::standard(5), 16, 8, 8);

    header("PJRT train step (batch 8 x 128 frames)");
    for artifact in ["train_mini_unfact", "train_mini_partial_full", "train_mini_partial_r250"] {
        let spec = rt.manifest().artifact(artifact).unwrap().clone();
        let geom = spec.batch.unwrap();
        let refs: Vec<&Utterance> = data.train.iter().take(geom.batch).collect();
        let batch = make_batch(&refs, &geom, data.spec.feat_dim);
        let opts = TrainOpts { epochs: 1, quiet: true, ..Default::default() };
        let mut t = Trainer::new(&rt, artifact, opts).unwrap();
        t.step(&batch).unwrap(); // compile + warm
        bench(&format!("step {artifact}"), 2500, || {
            std::hint::black_box(t.step(&batch).unwrap());
        });
    }

    header("PJRT eval step (batch 8)");
    for artifact in ["eval_mini_unfact", "eval_mini_partial_r250"] {
        let spec = rt.manifest().artifact(artifact).unwrap().clone();
        let loaded = rt.load(artifact).unwrap();
        let params = ParamSet::init(&spec, 0).unwrap();
        let geom = spec.batch.unwrap();
        let refs: Vec<&Utterance> = data.dev.iter().take(geom.batch).collect();
        let batch = make_batch(&refs, &geom, data.spec.feat_dim);
        let mut inputs = params.values_in_order(&spec.param_names).unwrap();
        inputs.push(batch.feats.clone());
        inputs.push(batch.frame_lens.clone());
        loaded.run(&inputs).unwrap();
        bench(&format!("eval {artifact}"), 2000, || {
            std::hint::black_box(loaded.run(&inputs).unwrap());
        });
    }

    header("PJRT stream chunk step (batch 1) by chunk size");
    for artifact in [
        "stream_mini_partial_r250_c4",
        "stream_mini_partial_r250_c8",
        "stream_mini_partial_r250_c16",
    ] {
        let spec = rt.manifest().artifact(artifact).unwrap().clone();
        let loaded = rt.load(artifact).unwrap();
        let params = ParamSet::init(&spec, 0).unwrap();
        let dims = rt.manifest().dims(&spec.config).unwrap().clone();
        let chunk = spec.chunk.unwrap();
        let mut inputs = params.values_in_order(&spec.param_names).unwrap();
        for &h in &dims.gru_dims {
            inputs.push(Value::F32(Tensor::zeros(&[1, h])));
        }
        inputs.push(Value::F32(Tensor::zeros(&[1, chunk, dims.feat_dim])));
        loaded.run(&inputs).unwrap();
        let per_frame = 1.0 / chunk as f64;
        let t = bench(&format!("stream chunk={chunk}"), 1500, || {
            std::hint::black_box(loaded.run(&inputs).unwrap());
        });
        println!("  -> {:.3} ms per raw frame", t * 1e3 * per_frame);
    }
}

//! Shared hand-rolled bench harness (criterion is unavailable offline).
//!
//! Each `[[bench]]` target is a plain binary (`harness = false`) that
//! includes this file via `#[path]`/`include!` and reports
//! min/mean/p50 over adaptive iteration counts.

// not every bench uses every helper
#![allow(dead_code)]

use std::time::Instant;

/// Run `f` repeatedly for ~`budget_ms`, reporting per-call stats.
///
/// With `BENCH_SMOKE` set in the environment, runs exactly one timed
/// iteration per case — CI uses this to keep every bench compiling and
/// executing without paying the measurement budget.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> f64 {
    // warmup
    f();
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = if std::env::var_os("BENCH_SMOKE").is_some() {
        1
    } else {
        ((budget_ms as f64 / 1e3 / once).ceil() as usize).clamp(3, 10_000)
    };
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    println!(
        "{name:<44} min {:>10} p50 {:>10} mean {:>10} ({iters} iters)",
        fmt(min),
        fmt(p50),
        fmt(mean)
    );
    min
}

pub fn fmt(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

pub fn header(title: &str) {
    println!("\n==== {title} ====");
}

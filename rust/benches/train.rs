//! Bench: native-training hot paths — full optimizer-step time by GRU
//! layer width (tape build + forward + backward + penalty + SGD), and
//! CTC forward-backward cost over the T×U lattice grid.
//!
//! Emits machine-readable `BENCH_train.json` (override the path with
//! `BENCH_TRAIN_JSON`) so future PRs have a perf trajectory for the
//! training subsystem alongside the GEMM sweep.

#[path = "harness.rs"]
mod harness;
use harness::{bench, header};

use tracenorm::autograd::{ctc_loss_grad, log_softmax_rows, NativeOpts};
use tracenorm::data::{make_batch, CorpusSpec, Dataset, Utterance};
use tracenorm::jsonx::Json;
use tracenorm::prng::Pcg64;
use tracenorm::runtime::{BatchGeom, ConvDims, ModelDims};
use tracenorm::tensor::Tensor;
use tracenorm::train::{NativeTrainer, TrainOpts};

fn dims_for(hidden: usize) -> ModelDims {
    ModelDims {
        feat_dim: 40,
        conv: vec![ConvDims { context: 2, dim: hidden }],
        gru_dims: vec![hidden, hidden],
        fc_dim: hidden + 16,
        vocab: 29,
        total_stride: 2,
    }
}

fn normalized_logp(t: usize, v: usize, rng: &mut Pcg64) -> Tensor {
    let mut logits = Tensor::randn(&[t, v], 1.0, rng);
    log_softmax_rows(&mut logits);
    logits
}

fn main() {
    let mut results: Vec<Json> = Vec::new();

    // -- optimizer step time by layer size --------------------------------
    header("native train step by GRU width (batch 2, synthetic utterances)");
    let data = Dataset::generate(CorpusSpec::standard(3), 4, 0, 0);
    let geom = BatchGeom { batch: 2, max_frames: 128, max_label: 12 };
    for hidden in [16usize, 32, 64] {
        let dims = dims_for(hidden);
        let opts = TrainOpts {
            lr: 1e-4,
            lam_rec: 1e-3,
            lam_nonrec: 1e-3,
            ..TrainOpts::default()
        };
        let mut t = NativeTrainer::new_factored(&dims, opts, NativeOpts::default());
        let refs: Vec<&Utterance> = data.train.iter().take(2).collect();
        let batch = make_batch(&refs, &geom, 40);
        let secs = bench(&format!("native step   h={hidden:<3} params={}", t.params.num_scalars()), 300, || {
            std::hint::black_box(t.step(&batch).unwrap());
        });
        results.push(Json::obj(vec![
            ("kind", Json::str("step")),
            ("hidden", Json::num(hidden as f64)),
            ("params", Json::num(t.params.num_scalars() as f64)),
            ("secs", Json::num(secs)),
        ]));
    }

    // -- CTC forward-backward cost over the T×U lattice -------------------
    header("ctc_loss_grad by T (frames) x U (labels), vocab 29");
    let mut rng = Pcg64::seeded(7);
    for (t_len, u) in [(16usize, 4usize), (32, 8), (64, 12), (128, 12)] {
        let logp = normalized_logp(t_len, 29, &mut rng);
        let labels: Vec<i32> = (0..u).map(|i| (i as i32 % 27) + 1).collect();
        let secs = bench(&format!("ctc T={t_len:<4} U={u:<3}"), 200, || {
            std::hint::black_box(ctc_loss_grad(&logp, &labels).unwrap());
        });
        results.push(Json::obj(vec![
            ("kind", Json::str("ctc")),
            ("t", Json::num(t_len as f64)),
            ("u", Json::num(u as f64)),
            ("secs", Json::num(secs)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::str("train")),
        ("results", Json::Arr(results)),
    ]);
    let path = std::env::var("BENCH_TRAIN_JSON").unwrap_or_else(|_| "BENCH_train.json".into());
    std::fs::write(&path, report.to_string_pretty()).expect("write BENCH_train.json");
    println!("wrote machine-readable sweep to {path}");
}

//! Integration tests over the full stack: PJRT runtime + AOT artifacts +
//! coordinator + embedded engine.
//!
//! These need `make artifacts` to have run; if the manifest is missing the
//! tests succeed vacuously with a loud message (CI convention for
//! build-step dependencies).

use std::sync::OnceLock;

use tracenorm::data::{make_batch, CorpusSpec, Dataset, Utterance};
use tracenorm::decoder;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::model::{magnitude_masks, warmstart, ParamSet};
use tracenorm::runtime::{Runtime, Value};
use tracenorm::serve::{simulate, ServeConfig};
use tracenorm::tensor::Tensor;
use tracenorm::train::{eval_name, Evaluator, TrainOpts, Trainer};

/// The xla crate's PJRT handles are `Rc`-based (not `Send`/`Sync`).  The
/// test binary pins `RUST_TEST_THREADS=1` via `.cargo/config.toml`, so
/// tests execute strictly sequentially and each test thread's accesses are
/// ordered by libtest's thread joins (happens-before) — sharing the cached
/// runtime across those threads is sound even though `Rc` refcounts are
/// non-atomic.
struct SharedRt(Option<Runtime>);
unsafe impl Send for SharedRt {}
unsafe impl Sync for SharedRt {}

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<SharedRt> = OnceLock::new();
    RT.get_or_init(|| {
        assert_eq!(
            std::env::var("RUST_TEST_THREADS").as_deref(),
            Ok("1"),
            "integration tests must run with RUST_TEST_THREADS=1 (set in .cargo/config.toml)"
        );
        match Runtime::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
            Ok(rt) => SharedRt(Some(rt)),
            Err(e) => {
                eprintln!("SKIPPING integration tests (run `make artifacts`): {e}");
                SharedRt(None)
            }
        }
    })
    .0
    .as_ref()
}

fn dataset() -> &'static Dataset {
    static DS: OnceLock<Dataset> = OnceLock::new();
    DS.get_or_init(|| Dataset::generate(CorpusSpec::standard(11), 48, 16, 16))
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    for name in [
        "train_mini_unfact",
        "train_mini_unfact_masked",
        "train_mini_partial_full",
        "train_mini_partial_r250",
        "train_mini_split_full",
        "train_mini_joint_full",
        "eval_mini_unfact",
        "eval_mini_partial_r250",
        "stream_mini_partial_r250_c8",
        "stream_mini_partial_r250_c8_int8",
        "train_s50_unfact",
    ] {
        assert!(m.artifacts.contains_key(name), "missing artifact {name}");
    }
    assert_eq!(m.alphabet.len(), 29);
    assert!(m.rank_ladder.len() >= 4);
}

#[test]
fn eval_artifact_produces_normalized_logprobs() {
    let Some(rt) = runtime() else { return };
    let eval = Evaluator::new(rt, "eval_mini_unfact").unwrap();
    let spec = rt.manifest().artifact("eval_mini_unfact").unwrap().clone();
    let params = ParamSet::init(&spec, 3).unwrap();
    let utts = &dataset().dev[..4];
    let rows = eval.logprobs(&params, utts).unwrap();
    assert_eq!(rows.len(), 4);
    for (logp, len, _) in rows {
        assert!(len > 0 && len <= logp.rows());
        for t in 0..len {
            let total: f32 = logp.row(t).iter().map(|v| v.exp()).sum();
            assert!((total - 1.0).abs() < 1e-3, "row {t} sums to {total}");
        }
    }
}

#[test]
fn pjrt_training_reduces_loss_and_learns() {
    let Some(rt) = runtime() else { return };
    let ds = dataset();
    let spec = rt.manifest().artifact("train_mini_partial_full").unwrap().clone();
    let mut batcher = tracenorm::data::Batcher::new(
        &ds.train,
        spec.batch.unwrap(),
        ds.spec.feat_dim,
        0,
    );
    let opts = TrainOpts {
        seed: 5,
        lr: 2e-3,
        lr_decay: 1.0,
        epochs: 1,
        lam_rec: 1e-4,
        lam_nonrec: 1e-4,
        quiet: true,
    };
    let mut t = Trainer::new(rt, "train_mini_partial_full", opts).unwrap();
    let batches = batcher.epoch();
    let first = t.step(&batches[0]).unwrap();
    assert!(first.loss.is_finite() && first.penalty > 0.0);
    let mut last = first;
    for _ in 0..4 {
        for b in &batches {
            last = t.step(b).unwrap();
        }
    }
    assert!(
        last.loss < first.loss,
        "loss did not decrease: {} -> {}",
        first.loss,
        last.loss
    );
}

#[test]
fn embedded_engine_matches_pjrt_eval() {
    let Some(rt) = runtime() else { return };
    let ds = dataset();
    let spec = rt.manifest().artifact("eval_mini_partial_r250").unwrap().clone();
    let params = ParamSet::init(&spec, 7).unwrap();
    let eval = Evaluator::new(rt, "eval_mini_partial_r250").unwrap();
    let utt = &ds.dev[0];
    let pjrt = &eval.logprobs(&params, std::slice::from_ref(utt)).unwrap()[0];

    let dims = rt.manifest().dims("wsj_mini").unwrap().clone();
    let engine = Engine::from_params(&dims, "partial", &params, Precision::F32, 4).unwrap();
    let mut bd = Breakdown::default();
    let (_, rows) = engine.transcribe(&utt.feats, &mut bd).unwrap();

    let out_len = pjrt.1;
    assert!(rows.len() >= out_len, "{} vs {}", rows.len(), out_len);
    for t in 0..out_len {
        for (a, b) in pjrt.0.row(t).iter().zip(&rows[t]) {
            assert!(
                (a - b).abs() < 2e-2,
                "t={t}: PJRT {a} vs engine {b} (diff {})",
                (a - b).abs()
            );
        }
    }
}

#[test]
fn stream_artifact_matches_eval_artifact() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().artifact("stream_mini_partial_r250_c8").unwrap().clone();
    let params = ParamSet::init(&spec, 9).unwrap();
    let loaded = rt.load("stream_mini_partial_r250_c8").unwrap();
    let dims = rt.manifest().dims("wsj_mini").unwrap().clone();

    // stream 16 raw frames as two chunks of 8 through the HLO stream step
    let mut rng = tracenorm::prng::Pcg64::seeded(4);
    let feats = Tensor::randn(&[16, dims.feat_dim], 0.5, &mut rng);
    let mut hs: Vec<Value> = dims
        .gru_dims
        .iter()
        .map(|&h| Value::F32(Tensor::zeros(&[1, h])))
        .collect();
    let mut streamed: Vec<f32> = Vec::new();
    for c in 0..2 {
        let chunk = Tensor::new(
            &[1, 8, dims.feat_dim],
            feats.data()[c * 8 * dims.feat_dim..(c + 1) * 8 * dims.feat_dim].to_vec(),
        )
        .unwrap();
        let mut inputs = params.values_in_order(&loaded.spec.param_names).unwrap();
        inputs.extend(hs.iter().cloned());
        inputs.push(Value::F32(chunk));
        let out = loaded.run(&inputs).unwrap();
        let ngru = dims.gru_dims.len();
        hs = out[..ngru].to_vec();
        streamed.extend(out[ngru].as_f32().unwrap().data());
    }

    // same params through the eval artifact (pad to max_frames)
    let eval_spec = rt.manifest().artifact("eval_mini_partial_r250").unwrap().clone();
    let eval = rt.load("eval_mini_partial_r250").unwrap();
    let geom = eval_spec.batch.unwrap();
    let mut padded = Tensor::zeros(&[geom.batch, geom.max_frames, dims.feat_dim]);
    padded.data_mut()[..16 * dims.feat_dim].copy_from_slice(feats.data());
    let mut inputs = params.values_in_order(&eval_spec.param_names).unwrap();
    inputs.push(Value::F32(padded));
    inputs.push(Value::I32(vec![16, 0, 0, 0, 0, 0, 0, 0], vec![geom.batch]));
    let out = eval.run(&inputs).unwrap();
    let logp = out[0].as_f32().unwrap();
    let t_out = 16 / dims.total_stride;
    let v = dims.vocab;
    for t in 0..t_out {
        for j in 0..v {
            let a = logp.data()[t * v + j];
            let b = streamed[t * v + j];
            assert!((a - b).abs() < 1e-3, "t={t} j={j}: {a} vs {b}");
        }
    }
}

#[test]
fn int8_stream_artifact_runs_and_tracks_f32() {
    let Some(rt) = runtime() else { return };
    let loaded = rt.load("stream_mini_partial_r250_c8_int8").unwrap();
    let dims = rt.manifest().dims("wsj_mini").unwrap().clone();
    // f32 params for the f32 stream artifact, quantized wire for int8
    let f32_spec = rt.manifest().artifact("stream_mini_partial_r250_c8").unwrap().clone();
    let params = ParamSet::init(&f32_spec, 13).unwrap();

    let mut inputs = Vec::new();
    for name in &loaded.spec.param_names {
        if let Some(base) = name.strip_suffix("_q") {
            let w = params.get(base).unwrap();
            let q = tracenorm::quant::quantize(w);
            inputs.push(Value::I8(q.q.clone()));
        } else if let Some(base) = name.strip_suffix("_scale") {
            let w = params.get(base).unwrap();
            let q = tracenorm::quant::quantize(w);
            inputs.push(Value::scalar(q.scale));
        } else {
            inputs.push(Value::F32(params.get(name).unwrap().clone()));
        }
    }
    for &h in &dims.gru_dims {
        inputs.push(Value::F32(Tensor::zeros(&[1, h])));
    }
    let mut rng = tracenorm::prng::Pcg64::seeded(6);
    let chunk = Tensor::randn(&[1, 8, dims.feat_dim], 0.5, &mut rng);
    inputs.push(Value::F32(chunk.clone()));
    let out_q = loaded.run(&inputs).unwrap();
    let logp_q = out_q[dims.gru_dims.len()].as_f32().unwrap().clone();

    // f32 reference
    let f32_loaded = rt.load("stream_mini_partial_r250_c8").unwrap();
    let mut inputs_f = params.values_in_order(&f32_spec.param_names).unwrap();
    for &h in &dims.gru_dims {
        inputs_f.push(Value::F32(Tensor::zeros(&[1, h])));
    }
    inputs_f.push(Value::F32(chunk));
    let out_f = f32_loaded.run(&inputs_f).unwrap();
    let logp_f = out_f[dims.gru_dims.len()].as_f32().unwrap();

    let mean_diff: f32 = logp_q
        .data()
        .iter()
        .zip(logp_f.data())
        .map(|(a, b)| (a - b).abs())
        .sum::<f32>()
        / logp_q.len() as f32;
    assert!(mean_diff < 0.3, "int8 HLO diverges from f32: mean diff {mean_diff}");
}

#[test]
fn warmstart_roundtrip_through_artifacts() {
    let Some(rt) = runtime() else { return };
    let s1_spec = rt.manifest().artifact("train_mini_partial_full").unwrap().clone();
    let stage1 = ParamSet::init(&s1_spec, 21).unwrap();
    let s2_spec = rt.manifest().artifact("train_mini_partial_r500").unwrap().clone();
    let p2 = warmstart(&stage1, &s2_spec, 22).unwrap();
    // every param has the target shape; runs through the stage-2 trainer
    for n in &s2_spec.param_names {
        assert_eq!(p2.get(n).unwrap().shape(), s2_spec.input_shape(n).unwrap());
    }
    assert!(p2.num_scalars() < stage1.num_scalars());
    let ds = dataset();
    let geom = s2_spec.batch.unwrap();
    let refs: Vec<&Utterance> = ds.train.iter().take(geom.batch).collect();
    let batch = make_batch(&refs, &geom, ds.spec.feat_dim);
    let opts = TrainOpts { epochs: 1, quiet: true, ..Default::default() };
    let mut t = Trainer::with_params(rt, "train_mini_partial_r500", p2, opts).unwrap();
    let m = t.step(&batch).unwrap();
    assert!(m.loss.is_finite());
}

#[test]
fn masked_training_keeps_pruned_weights_zero() {
    let Some(rt) = runtime() else { return };
    let ds = dataset();
    let spec = rt.manifest().artifact("train_mini_unfact_masked").unwrap().clone();
    let opts = TrainOpts { epochs: 1, lr: 2e-3, quiet: true, ..Default::default() };
    let mut t = Trainer::new(rt, "train_mini_unfact_masked", opts).unwrap();
    let masks = magnitude_masks(&t.params, 0.5).unwrap();
    t.set_masks(masks.clone()).unwrap();
    let geom = spec.batch.unwrap();
    let refs: Vec<&Utterance> = ds.train.iter().take(geom.batch).collect();
    let batch = make_batch(&refs, &geom, ds.spec.feat_dim);
    for _ in 0..3 {
        t.step(&batch).unwrap();
    }
    for (mname, m) in masks.iter() {
        let wname = format!("{}_w", mname.strip_suffix("_mask").unwrap());
        let w = t.params.get(&wname).unwrap();
        for (wv, mv) in w.data().iter().zip(m.data()) {
            if *mv == 0.0 {
                assert_eq!(*wv, 0.0, "pruned weight drifted in {wname}");
            }
        }
    }
}

#[test]
fn serve_simulation_reports_sane_numbers() {
    let Some(rt) = runtime() else { return };
    let ds = dataset();
    let spec = rt.manifest().artifact("eval_mini_unfact").unwrap().clone();
    let params = ParamSet::init(&spec, 31).unwrap();
    let report = simulate(
        rt,
        "eval_mini_unfact",
        &params,
        &ds.dev,
        &ServeConfig { arrival_rate: 50.0, max_batch: 8, window: 0.02, seed: 1 },
    )
    .unwrap();
    assert_eq!(report.requests, ds.dev.len());
    assert!(report.throughput > 0.0);
    assert!(report.p50_latency <= report.p95_latency);
    assert!(report.p95_latency <= report.p99_latency);
    assert!(report.mean_batch >= 1.0 && report.mean_batch <= 8.0);
    // batching should actually happen at this arrival rate
    assert!(report.mean_batch > 1.5, "mean batch {}", report.mean_batch);
}

#[test]
fn greedy_decode_of_trained_model_beats_chance() {
    // quick end-to-end learn check through the PJRT path
    let Some(rt) = runtime() else { return };
    let ds = dataset();
    let spec = rt.manifest().artifact("train_mini_unfact").unwrap().clone();
    let mut batcher = tracenorm::data::Batcher::new(
        &ds.train,
        spec.batch.unwrap(),
        ds.spec.feat_dim,
        3,
    );
    let opts = TrainOpts {
        seed: 1,
        lr: 2e-3,
        lr_decay: 1.0,
        epochs: 8,
        quiet: true,
        ..Default::default()
    };
    let mut t = Trainer::new(rt, "train_mini_unfact", opts).unwrap();
    let eval = Evaluator::new(rt, &eval_name("train_mini_unfact")).unwrap();
    t.run(&mut batcher, None, None).unwrap();
    let stats = eval.greedy_cer(&t.params, &ds.dev).unwrap();
    assert!(
        stats.cer() < 0.9,
        "model failed to learn anything: CER {}",
        stats.cer()
    );
    let _ = decoder::BLANK;
}

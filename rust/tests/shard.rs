//! Cross-shard determinism suite for the sharded serving runtime
//! (DESIGN.md §9).
//!
//! The load-bearing guarantee: sharding changes *placement and timing*,
//! never *decoding*.  With a fixed seed, every shard count must produce
//! identical per-stream transcripts (and therefore identical CER),
//! because pooled decoding is bit-identical to sequential decoding and
//! each session's stream is untouched by its neighbours.  The `--shards
//! 1` path additionally replays the historical arrival schedule bit for
//! bit ([`tracenorm::shard::sharded_arrivals`] is pinned to the old
//! root-seeded process in its unit tests).

use std::path::PathBuf;
use std::sync::Arc;

use tracenorm::controller::ControllerConfig;
use tracenorm::data::{CorpusSpec, Dataset};
use tracenorm::decoder;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::registry::{ladder_build, Registry};
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::serve::{
    ladder_serve, stream_serve, LadderServeConfig, StreamServeConfig, StreamServeReport,
};
use tracenorm::stream::{demo_dims, synthetic_params};

fn demo_engine(seed: u64) -> Arc<Engine> {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, seed);
    Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap())
}

fn serve_at(shards: usize, engine: Arc<Engine>, utts: &Dataset) -> StreamServeReport {
    let cfg = StreamServeConfig {
        arrival_rate: 1e5, // burst: shards and pools saturate
        pool_size: 2,
        chunk_frames: 16,
        shards,
        seed: 11,
        ..Default::default()
    };
    stream_serve(engine, &utts.test, &cfg).unwrap()
}

fn corpus_cer(transcripts: &[(String, String)]) -> f64 {
    let mut stats = decoder::ErrorStats::default();
    for (reference, hyp) in transcripts {
        stats.push(hyp, reference);
    }
    stats.cer()
}

/// The acceptance criterion of ISSUE 5: same seed at shards ∈ {1, 2, 4}
/// produces identical per-stream transcripts and final CER.
#[test]
fn shard_counts_1_2_4_produce_identical_transcripts_and_cer() {
    let engine = demo_engine(7);
    let data = Dataset::generate(CorpusSpec::standard(31), 0, 0, 10);
    let base = serve_at(1, engine.clone(), &data);
    assert_eq!(base.transcripts.len(), 10);
    let base_cer = corpus_cer(&base.transcripts);

    for shards in [2usize, 4] {
        let r = serve_at(shards, engine.clone(), &data);
        assert_eq!(r.shards, shards);
        assert_eq!(
            r.transcripts, base.transcripts,
            "shards={shards} must not change any transcript"
        );
        let cer = corpus_cer(&r.transcripts);
        assert_eq!(cer, base_cer, "shards={shards} must not change CER");
        // placement actually used the fleet under a burst
        let used: std::collections::BTreeSet<usize> =
            r.shard_of_session.iter().copied().collect();
        assert!(used.len() > 1, "burst load must touch more than one shard: {used:?}");
        assert!(used.iter().all(|&s| s < shards));
        // every session is accounted to exactly one shard
        assert_eq!(r.per_shard.iter().map(|s| s.sessions).sum::<usize>(), 10);
        assert_eq!(r.session_latency.count, 10);
    }
}

/// Sharded transcripts also match the plain per-utterance engine decode
/// — concurrency at any shard count is invisible to decoding.
#[test]
fn sharded_transcripts_match_sequential_engine_decode() {
    let engine = demo_engine(9);
    let data = Dataset::generate(CorpusSpec::standard(32), 0, 0, 6);
    let r = serve_at(3, engine.clone(), &data);
    for (utt, (reference, hyp)) in r.transcripts.iter().enumerate() {
        let mut bd = Breakdown::default();
        let (solo, _) = engine.transcribe(&data.test[utt].feats, &mut bd).unwrap();
        assert_eq!(hyp, &solo, "session {utt} (ref '{reference}') drifted under sharding");
    }
}

/// The aggregate frame count (and so the realtime-factor accounting) is
/// shard-invariant: every raw frame is counted exactly once.
#[test]
fn breakdown_frames_are_shard_invariant() {
    let engine = demo_engine(13);
    let data = Dataset::generate(CorpusSpec::standard(33), 0, 0, 8);
    let f1 = serve_at(1, engine.clone(), &data).breakdown.frames;
    let f4 = serve_at(4, engine, &data).breakdown.frames;
    assert!(f1 > 0);
    assert_eq!(f1, f4);
}

// ---------------------------------------------------------------------------
// Sharded ladder serving.
// ---------------------------------------------------------------------------

fn tiny_dims() -> ModelDims {
    ModelDims {
        feat_dim: 8,
        conv: vec![ConvDims { context: 2, dim: 12 }],
        gru_dims: vec![10, 12],
        fc_dim: 14,
        vocab: 29,
        total_stride: 2,
    }
}

fn temp_ladder_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tn-shard-{tag}-{}", std::process::id()))
}

#[test]
fn sharded_ladder_serves_every_session_with_per_shard_controllers() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 8);
    let dir = temp_ladder_dir("ladder");
    ladder_build(&params, &dims, &[0.5, 0.125], &dir).unwrap();
    let reg = Registry::load(&dir, 2).unwrap();

    let data = Dataset::generate(CorpusSpec::standard(34), 0, 0, 12);
    let cfg = LadderServeConfig {
        base_rate: 1e5, // burst into 2 shards x 2 tiers x 2 slots
        ramp_rate: 1e5,
        ramp_range: (0, 0),
        pool_size: 2,
        chunk_frames: 4,
        shards: 2,
        seed: 5,
        controller: ControllerConfig {
            target_p99: 1e9, // occupancy-driven only, like the 1-shard ramp test
            high_water: 0.95,
            low_water: 0.5,
            breach_ticks: 2,
            clear_ticks: 2,
            window: 32,
        },
        ..Default::default()
    };
    let r = ladder_serve(&reg, &data.test, &cfg).unwrap();
    assert_eq!(r.sessions, 12);
    assert_eq!(r.shards, 2);
    assert_eq!(r.tiers.iter().map(|t| t.sessions).sum::<usize>(), 12);
    assert_eq!(r.per_shard.iter().map(|s| s.sessions).sum::<usize>(), 12);
    assert!(
        r.per_shard.iter().all(|s| s.sessions > 0),
        "a burst must land sessions on both shards: {:?}",
        r.per_shard.iter().map(|s| s.sessions).collect::<Vec<_>>()
    );
    // per-tier latency counts line up with admissions
    assert!(r.tiers.iter().all(|t| t.sessions == t.latency.count));
    // shift events, if any, are tagged with a real shard and stay
    // clock-ordered after the merge
    assert!(r.shifts.iter().all(|s| s.shard < 2));
    assert!(r.shifts.windows(2).all(|w| w[0].clock <= w[1].clock));
    assert_eq!(r.tier_of_session.len(), 12);
    assert_eq!(r.shard_of_session.len(), 12);
    // the JSON form carries the per-shard and per-tier slices
    let j = tracenorm::jsonx::Json::parse(&r.to_json().to_string_pretty()).unwrap();
    assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
    assert_eq!(j.get("tiers").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(j.get("per_shard").unwrap().as_arr().unwrap().len(), 2);
    std::fs::remove_dir_all(&dir).unwrap();
}

//! Steady-state allocation discipline (DESIGN.md §4): a counting global
//! allocator proves the engine's per-block decode loop performs **zero**
//! heap allocations once the scratch arena is warm, and the pool-level
//! arena growth counters prove the lock-stepped executor reuses its
//! buffers across pump rounds.
//!
//! The counting allocator is the "debug-mode allocation counter" of the
//! refactor: it wraps the system allocator and counts alloc/realloc hits
//! only while armed, so warmup (which legitimately sizes the arena) is
//! exempt.  Tests run single-threaded (`RUST_TEST_THREADS=1` via
//! `rust/.cargo/config.toml`), so arming is race-free.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::prng::Pcg64;
use tracenorm::stream::{demo_dims, synthetic_params, StreamPool};
use tracenorm::tensor::Tensor;

struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static HITS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` with the allocation counter armed; returns the hit count.
fn count_allocs(f: impl FnOnce()) -> u64 {
    HITS.store(0, Ordering::Relaxed);
    ARMED.store(true, Ordering::Relaxed);
    f();
    ARMED.store(false, Ordering::Relaxed);
    HITS.load(Ordering::Relaxed)
}

#[test]
fn engine_steady_state_block_loop_is_alloc_free() {
    for precision in [Precision::F32, Precision::Int8] {
        let dims = demo_dims();
        let params = synthetic_params(&dims, 0.5, 3);
        let eng = Engine::from_params(&dims, "partial", &params, precision, 4).unwrap();
        let block = eng.block_raw_len();
        let mut rng = Pcg64::seeded(4);
        let frames = Tensor::randn(&[2 * block / dims.feat_dim, dims.feat_dim], 0.7, &mut rng);
        let mut state = eng.new_state();
        let mut bd = Breakdown::default();

        // warmup: two blocks size every scratch buffer and reserve the
        // stream buffer's capacity
        let rows = eng.stream(&mut state, frames.data(), &mut bd).unwrap();
        assert_eq!(rows.len(), 2 * eng.time_batch);
        assert_eq!(state.buffered_len(), 0);

        // steady state: buffer + pump N more blocks under the counter
        let mut steps = 0;
        let hits = count_allocs(|| {
            for _ in 0..5 {
                eng.buffer_frames(&mut state, &frames.data()[..block], &mut bd);
                assert!(eng.pump_block(&mut state, &mut bd).unwrap());
                steps += state.block_logp().rows();
            }
        });
        assert_eq!(steps, 5 * eng.time_batch);
        assert_eq!(
            hits, 0,
            "steady-state decode loop allocated {hits} times ({precision:?})"
        );
        assert_eq!(state.scratch_grow_events(), 0);
    }
}

#[test]
fn gemv_and_fused_paths_are_alloc_free_and_probe_free() {
    // time_batch = 1 keeps every activation batch at m = 1, so the block
    // loop exercises exactly the new small-batch paths: the m = 1 GEMV
    // dispatch on the non-recurrent / head GEMMs and the fused GRU-gate
    // kernel on the recurrent path (fused is the default).  Both must be
    // silent under the counting allocator once warm, and autotune probes
    // are construction-only: the probe counter must not move during
    // decode.
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 9);
    let eng = Engine::from_params(&dims, "partial", &params, Precision::Int8, 1).unwrap();
    assert!(eng.fused_gates(), "fused gates must default on");
    let block = eng.block_raw_len();
    let mut rng = Pcg64::seeded(10);
    let frames = Tensor::randn(&[2 * block / dims.feat_dim, dims.feat_dim], 0.7, &mut rng);
    let mut state = eng.new_state();
    let mut bd = Breakdown::default();

    // warmup sizes the arena
    eng.stream(&mut state, frames.data(), &mut bd).unwrap();
    assert_eq!(state.buffered_len(), 0);

    let probes_before = tracenorm::kernels::autotune::probe_count();
    let hits = count_allocs(|| {
        for _ in 0..5 {
            eng.buffer_frames(&mut state, &frames.data()[..block], &mut bd);
            assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        }
    });
    assert_eq!(hits, 0, "gemv/fused steady-state loop allocated {hits} times");
    assert_eq!(state.scratch_grow_events(), 0);
    assert_eq!(
        tracenorm::kernels::autotune::probe_count(),
        probes_before,
        "autotune probed during steady-state decode (must be construction-only)"
    );
}

#[test]
fn obs_enabled_block_loop_stays_alloc_free() {
    // The flight recorder must preserve the steady-state invariant:
    // spans land in a Copy struct on the Breakdown, kernel counters are
    // static atomics, and the pending-quantize cell is a thread-local
    // Cell<f64> — none of which may touch the heap once warm.
    tracenorm::obs::reset_process_metrics();
    tracenorm::obs::set_enabled(true);
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 3);
    let eng = Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap();
    let block = eng.block_raw_len();
    let mut rng = Pcg64::seeded(4);
    let frames = Tensor::randn(&[2 * block / dims.feat_dim, dims.feat_dim], 0.7, &mut rng);
    let mut state = eng.new_state();
    let mut bd = Breakdown::default();

    eng.stream(&mut state, frames.data(), &mut bd).unwrap();
    assert_eq!(state.buffered_len(), 0);

    let hits = count_allocs(|| {
        for _ in 0..5 {
            eng.buffer_frames(&mut state, &frames.data()[..block], &mut bd);
            assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        }
    });
    tracenorm::obs::set_enabled(false);
    assert_eq!(hits, 0, "obs-on steady-state decode loop allocated {hits} times");
    assert_eq!(state.scratch_grow_events(), 0);
    // and the recorder actually recorded: spans cover the decode stages
    // and the int8 kernels hit the counters
    assert!(!bd.spans.is_empty(), "obs on but no spans recorded");
    assert!(bd.spans.total_secs() > 0.0);
    assert!(
        tracenorm::obs::counters::total_calls() > 0,
        "obs on but kernel counters never moved"
    );
}

#[test]
fn obs_disabled_costs_nothing_and_freezes_counters() {
    // With the recorder off (the default), decode must not touch the
    // kernel counters or the span accumulators — the only cost is the
    // relaxed flag load at each instrumentation site.
    tracenorm::obs::reset_process_metrics();
    tracenorm::obs::set_enabled(false);
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 3);
    let eng = Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap();
    let block = eng.block_raw_len();
    let mut rng = Pcg64::seeded(4);
    let frames = Tensor::randn(&[2 * block / dims.feat_dim, dims.feat_dim], 0.7, &mut rng);
    let mut state = eng.new_state();
    let mut bd = Breakdown::default();

    let calls_before = tracenorm::obs::counters::total_calls();
    eng.stream(&mut state, frames.data(), &mut bd).unwrap();
    for _ in 0..3 {
        eng.buffer_frames(&mut state, &frames.data()[..block], &mut bd);
        assert!(eng.pump_block(&mut state, &mut bd).unwrap());
    }
    assert_eq!(
        tracenorm::obs::counters::total_calls(),
        calls_before,
        "kernel counters moved while obs was disabled"
    );
    assert!(bd.spans.is_empty(), "spans recorded while obs was disabled");
    // the plain timing breakdown still works with the recorder off
    assert!(bd.frames > 0 && bd.acoustic_total() > 0.0);

    // same contract at the pool level: pump + close leave the counters
    // frozen, and even the traced pump path records no span data — the
    // per-block records carry empty deltas because no instrumentation
    // site fired
    let eng = Arc::new(eng);
    let mut pool = StreamPool::new(eng.clone(), 2);
    let id = pool.open().unwrap();
    let mut bdp = Breakdown::default();
    pool.push_frames(id, frames.data()).unwrap();
    let mut traces = Vec::new();
    pool.pump_traced(&mut bdp, &mut traces).unwrap();
    let closed = pool.close(id, &mut bdp).unwrap();
    assert_eq!(
        tracenorm::obs::counters::total_calls(),
        calls_before,
        "kernel counters moved during pooled decode with obs disabled"
    );
    assert!(bdp.spans.is_empty(), "pool spans recorded while obs was disabled");
    assert!(!traces.is_empty());
    assert!(
        traces.iter().all(|t| t.spans.is_empty()),
        "traced pump recorded span deltas while obs was disabled"
    );
    // ... and the traced path decodes bit-identically to the plain one
    let mut plain = StreamPool::new(eng, 2);
    let pid = plain.open().unwrap();
    let mut bdq = Breakdown::default();
    plain.push_frames(pid, frames.data()).unwrap();
    plain.pump(&mut bdq).unwrap();
    let ref_closed = plain.close(pid, &mut bdq).unwrap();
    assert_eq!(closed.transcript, ref_closed.transcript);
    assert_eq!(closed.logprob_rows, ref_closed.logprob_rows);
}

#[test]
fn pool_per_timestep_loop_reuses_the_arena() {
    // The pool's poll API hands out owned rows, so a pump round is not
    // literally zero-alloc at the API boundary — but the per-timestep
    // executor must reuse the pool arena: its footprint and growth
    // counters freeze after one full-occupancy warmup round.
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 5);
    let eng = Arc::new(
        Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap(),
    );
    let block = eng.block_raw_len();
    let mut pool = StreamPool::new(eng, 4);
    let ids: Vec<_> = (0..4).map(|_| pool.open().unwrap()).collect();
    let mut rng = Pcg64::seeded(6);
    let frames = Tensor::randn(&[block / dims.feat_dim, dims.feat_dim], 0.5, &mut rng);
    let mut bd = Breakdown::default();

    // two warmup rounds: the per-layer ping-pong tensors alternate roles
    // between blocks, so both parities must see their steady-state shapes
    for _ in 0..2 {
        for &id in &ids {
            pool.push_frames(id, frames.data()).unwrap();
        }
        pool.pump(&mut bd).unwrap();
    }
    let fp = pool.scratch_footprint();
    assert!(fp > 0);

    for _ in 0..5 {
        for &id in &ids {
            pool.push_frames(id, frames.data()).unwrap();
            pool.poll(id).unwrap();
        }
        pool.pump(&mut bd).unwrap();
    }
    assert_eq!(pool.scratch_footprint(), fp, "pool arena grew after warmup");
    assert_eq!(pool.scratch_grow_events(), 0);
}

#[test]
fn pool_block_allocations_bounded_by_row_handoff() {
    // Cross-check the pool with the counter: after warmup, the only
    // allocations a pump round may make are the owned log-prob rows it
    // materializes for the poll API (one Vec per output step per stream,
    // plus amortized growth of the per-session ready queues).  The GEMM /
    // gather / gate machinery itself must be silent.
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 7);
    let eng = Arc::new(
        Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap(),
    );
    let (m, t) = (2usize, 4usize); // streams × time_batch output steps
    let block = eng.block_raw_len();
    let mut pool = StreamPool::new(eng, m);
    let ids: Vec<_> = (0..m).map(|_| pool.open().unwrap()).collect();
    let mut rng = Pcg64::seeded(8);
    let frames = Tensor::randn(&[block / dims.feat_dim, dims.feat_dim], 0.5, &mut rng);
    let mut bd = Breakdown::default();
    // warm two full-occupancy rounds (both ping-pong parities)
    for _ in 0..2 {
        for &id in &ids {
            pool.push_frames(id, frames.data()).unwrap();
        }
        pool.pump(&mut bd).unwrap();
    }
    for &id in &ids {
        pool.push_frames(id, frames.data()).unwrap();
    }
    let hits = count_allocs(|| {
        pool.pump(&mut bd).unwrap();
    });
    let budget = (m * t) as u64 * 2 + 8; // rows + amortized queue growth
    assert!(
        hits <= budget,
        "pooled pump allocated {hits} times for {m}x{t} rows (budget {budget})"
    );
}

//! Gradient correctness for the native training subsystem (ISSUE 4):
//! central finite differences vs reverse-mode autograd for **every tape
//! op**, the GRU cell chain (the whole tiny network), the CTC loss
//! (also cross-checked against brute-force path enumeration), and the
//! trace-norm surrogate penalty — plus the end-to-end two-stage run
//! whose checkpoint round-trips into the serving stack bit-identically.
//!
//! Tolerances are scaled per op: f32 forward arithmetic puts a noise
//! floor of ~`loss·1e-7 / (2ε)` under every finite difference, so each
//! comparison allows a small absolute term plus a relative term.

use std::path::PathBuf;

use tracenorm::autograd::tape::{Tape, Var};
use tracenorm::autograd::{self, ctc_loss_grad, log_softmax_rows, NativeOpts};
use tracenorm::checkpoint::{self, TrainMeta, TrainState};
use tracenorm::data::{Batcher, CorpusSpec, Dataset};
use tracenorm::infer::{Breakdown, Engine};
use tracenorm::model;
use tracenorm::prng::Pcg64;
use tracenorm::proplite;
use tracenorm::registry::{ladder_build, Registry};
use tracenorm::runtime::{BatchGeom, ConvDims, ModelDims};
use tracenorm::tensor::Tensor;
use tracenorm::train::{two_stage_native, Stage2Lr, TrainOpts, NATIVE_RANK_LADDER};

// ---------------------------------------------------------------------------
// Finite-difference harness.
// ---------------------------------------------------------------------------

/// Build a scalar loss from leaf tensors, returning (loss, grad per input).
fn scalar_loss(
    build: &dyn Fn(&mut Tape, &[Var]) -> Var,
    inputs: &[Tensor],
) -> (f32, Vec<Tensor>) {
    let mut tape = Tape::new();
    let vars: Vec<Var> = inputs.iter().map(|t| tape.leaf(t.clone(), true)).collect();
    let loss = build(&mut tape, &vars);
    let val = tape.value(loss).data()[0];
    let grads = tape.backward(loss);
    let gs = vars
        .iter()
        .zip(inputs)
        .map(|(v, t)| {
            grads[v.index()].clone().unwrap_or_else(|| Tensor::zeros(t.shape()))
        })
        .collect();
    (val, gs)
}

/// Central-difference check of every element of every input.
fn fd_matches(
    build: &dyn Fn(&mut Tape, &[Var]) -> Var,
    inputs: &[Tensor],
    eps: f32,
    tol_abs: f32,
    tol_rel: f32,
) -> bool {
    let (_, gs) = scalar_loss(build, inputs);
    for (i, t) in inputs.iter().enumerate() {
        for j in 0..t.len() {
            let mut plus = inputs.to_vec();
            plus[i].data_mut()[j] += eps;
            let mut minus = inputs.to_vec();
            minus[i].data_mut()[j] -= eps;
            let fd = (scalar_loss(build, &plus).0 - scalar_loss(build, &minus).0) / (2.0 * eps);
            let ad = gs[i].data()[j];
            let tol = tol_abs + tol_rel * ad.abs().max(fd.abs());
            if (ad - fd).abs() > tol {
                eprintln!("input {i} elem {j}: autograd {ad} vs fd {fd} (tol {tol})");
                return false;
            }
        }
    }
    true
}

/// Reduce an op's output to a scalar via a fixed pseudo-random weighted
/// sum, so the FD probes a dense linear functional of every output.
fn wsum(tape: &mut Tape, y: Var, seed: u64) -> Var {
    let shape = tape.value(y).shape().to_vec();
    let mut rng = Pcg64::seeded(seed ^ 0x57e1_6875);
    let w = tape.leaf(Tensor::randn(&shape, 1.0, &mut rng), false);
    let p = tape.mul(y, w);
    tape.sum(p)
}

fn rt(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    Tensor::randn(shape, 0.8, rng)
}

// ---------------------------------------------------------------------------
// Per-op gradient checks (proplite-randomized shapes/values).
// ---------------------------------------------------------------------------

#[test]
fn grad_matmul_nt() {
    proplite::check(
        "grad-matmul-nt",
        12,
        |rng, size| {
            let (m, k, n) = (1 + size % 3, 2 + size % 4, 1 + rng.below(5));
            vec![rt(rng, &[m, k]), rt(rng, &[n, k])]
        },
        |ts| {
            fd_matches(
                &|tape, v| {
                    let y = tape.matmul_nt(v[0], v[1]);
                    wsum(tape, y, 1)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_elementwise_add_sub_mul() {
    proplite::check(
        "grad-add-sub-mul",
        10,
        |rng, size| {
            let (m, n) = (1 + size % 3, 2 + rng.below(4));
            vec![rt(rng, &[m, n]), rt(rng, &[m, n]), rt(rng, &[m, n])]
        },
        |ts| {
            fd_matches(
                &|tape, v| {
                    let a = tape.add(v[0], v[1]);
                    let s = tape.sub(a, v[2]);
                    let p = tape.mul(s, v[1]); // reuse an input: fan-out grads
                    wsum(tape, p, 2)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_add_bias() {
    proplite::check(
        "grad-add-bias",
        10,
        |rng, size| {
            let (m, n) = (1 + size % 4, 2 + rng.below(4));
            vec![rt(rng, &[m, n]), rt(rng, &[n])]
        },
        |ts| {
            fd_matches(
                &|tape, v| {
                    let y = tape.add_bias(v[0], v[1]);
                    wsum(tape, y, 3)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_sigmoid_tanh() {
    proplite::check(
        "grad-sigmoid-tanh",
        10,
        |rng, size| vec![rt(rng, &[1 + size % 3, 3])],
        |ts| {
            fd_matches(
                &|tape, v| {
                    let s = tape.sigmoid(v[0]);
                    let t = tape.tanh(s);
                    wsum(tape, t, 4)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_relu_away_from_kink() {
    proplite::check(
        "grad-relu",
        10,
        |rng, size| {
            let mut t = rt(rng, &[1 + size % 3, 4]);
            // keep every element away from the non-differentiable point
            for v in t.data_mut() {
                if v.abs() < 0.1 {
                    *v = 0.1 * if *v < 0.0 { -1.0 } else { 1.0 };
                }
            }
            vec![t]
        },
        |ts| {
            fd_matches(
                &|tape, v| {
                    let y = tape.relu(v[0]);
                    wsum(tape, y, 5)
                },
                ts,
                2e-2, // eps must stay below the 0.1 kink margin
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_slicing_and_concat() {
    proplite::check(
        "grad-slice-row-concat-stack",
        10,
        |rng, size| {
            let m = 2 + size % 3;
            vec![rt(rng, &[2 * m, 6]), rt(rng, &[m, 6])]
        },
        |ts| {
            fd_matches(
                &|tape, v| {
                    let a = tape.slice_cols(v[0], 1, 4);
                    let b = tape.row(a, 0);
                    let c = tape.concat_rows(&[b, b]);
                    let d = tape.stack_rows(c, 2);
                    let e = tape.slice_cols(v[1], 0, 3);
                    let f = tape.concat_rows(&[e, a]);
                    let l1 = wsum(tape, d, 6);
                    let l2 = wsum(tape, f, 7);
                    tape.add(l1, l2)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_log_softmax() {
    proplite::check(
        "grad-log-softmax",
        10,
        |rng, size| vec![rt(rng, &[1 + size % 4, 5])],
        |ts| {
            fd_matches(
                &|tape, v| {
                    let y = tape.log_softmax(v[0]);
                    wsum(tape, y, 8)
                },
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

#[test]
fn grad_ctc_loss() {
    proplite::check(
        "grad-ctc",
        10,
        |rng, size| {
            let t = 4 + size % 4;
            let mut logits = rt(rng, &[t, 5]);
            // operate on normalized rows (the real input regime)
            log_softmax_rows(&mut logits);
            vec![logits]
        },
        |ts| {
            fd_matches(
                &|tape, v| tape.ctc(v[0], &[1, 2]).unwrap(),
                ts,
                1e-2,
                5e-3,
                5e-2,
            )
        },
    );
}

// ---------------------------------------------------------------------------
// CTC vs brute-force path enumeration.
// ---------------------------------------------------------------------------

/// Sum, in probability space, over all V^T emission paths that collapse
/// (dedupe consecutive, drop blanks) to `labels`.
fn brute_force_log_p(logp: &Tensor, labels: &[i32]) -> f64 {
    let (t_len, v) = (logp.rows(), logp.cols());
    let mut total = 0.0f64;
    let n_paths = (v as u64).pow(t_len as u32);
    for code in 0..n_paths {
        let mut c = code;
        let mut path = Vec::with_capacity(t_len);
        for _ in 0..t_len {
            path.push((c % v as u64) as i32);
            c /= v as u64;
        }
        let mut collapsed = Vec::new();
        let mut prev = -1;
        for &s in &path {
            if s != prev && s != 0 {
                collapsed.push(s);
            }
            prev = s;
        }
        if collapsed == labels {
            let lp: f64 =
                path.iter().enumerate().map(|(t, &s)| logp.row(t)[s as usize] as f64).sum();
            total += lp.exp();
        }
    }
    total.ln()
}

#[test]
fn ctc_matches_brute_force_enumeration() {
    let mut rng = Pcg64::seeded(42);
    for labels in [vec![1], vec![1, 2], vec![1, 1], vec![2, 1, 2]] {
        let mut logits = Tensor::randn(&[4, 4], 1.0, &mut rng);
        log_softmax_rows(&mut logits);
        let want = -brute_force_log_p(&logits, &labels);
        let (loss, grad) = ctc_loss_grad(&logits, &labels).unwrap();
        assert!(
            ((loss as f64) - want).abs() < 1e-4,
            "labels {labels:?}: ctc {loss} vs brute force {want}"
        );
        // each frame's gradient row sums to −1 (total occupancy)
        for t in 0..4 {
            let s: f32 = grad.row(t).iter().sum();
            assert!((s + 1.0).abs() < 1e-3, "labels {labels:?} row {t}: {s}");
        }
    }
}

// ---------------------------------------------------------------------------
// Whole-network gradient check (the GRU cell chain, factored and dense).
// ---------------------------------------------------------------------------

fn micro_dims() -> ModelDims {
    ModelDims {
        feat_dim: 4,
        conv: vec![ConvDims { context: 2, dim: 6 }],
        gru_dims: vec![5],
        fc_dim: 6,
        vocab: 7,
        total_stride: 2,
    }
}

fn net_loss(params: &model::ParamSet, dims: &ModelDims, feats: &Tensor, labels: &[i32]) -> f32 {
    let mut fwd = autograd::build_forward(params, dims, feats).unwrap();
    let loss = fwd.tape.ctc(fwd.logp, labels).unwrap();
    fwd.tape.value(loss).data()[0]
}

fn check_net_grads(params: &model::ParamSet, dims: &ModelDims) {
    let mut rng = Pcg64::seeded(31);
    let feats = Tensor::randn(&[8, 4], 0.8, &mut rng);
    let labels = [1i32, 2];
    let (loss, grads) = autograd::utterance_grads(params, dims, &feats, &labels).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let eps = 1e-2f32;
    for (name, g) in grads.iter() {
        let base = params.get(name).unwrap();
        for j in 0..base.len() {
            let mut plus = params.clone();
            plus.get_mut(name).unwrap().data_mut()[j] += eps;
            let mut minus = params.clone();
            minus.get_mut(name).unwrap().data_mut()[j] -= eps;
            let fd = (net_loss(&plus, dims, &feats, &labels)
                - net_loss(&minus, dims, &feats, &labels))
                / (2.0 * eps);
            let ad = g.data()[j];
            let tol = 5e-3 + 5e-2 * ad.abs().max(fd.abs());
            assert!(
                (ad - fd).abs() <= tol,
                "{name}[{j}]: autograd {ad} vs fd {fd} (tol {tol})"
            );
        }
    }
}

#[test]
fn grad_full_network_factored() {
    let dims = micro_dims();
    check_net_grads(&model::init_factored_full(&dims, 7), &dims);
}

#[test]
fn grad_full_network_dense() {
    let dims = micro_dims();
    check_net_grads(&model::init_dense(&dims, 8), &dims);
}

// ---------------------------------------------------------------------------
// Trace-norm surrogate penalty gradient.
// ---------------------------------------------------------------------------

#[test]
fn grad_surrogate_penalty() {
    proplite::check(
        "grad-surrogate",
        10,
        |rng, size| {
            let r = 2 + size % 3;
            vec![rt(rng, &[5, r]), rt(rng, &[r, 4]), rt(rng, &[4, 4])]
        },
        |ts| {
            let (lam_rec, lam_nonrec) = (0.7f32, 0.3f32);
            let mut p = model::ParamSet::new();
            p.set("rec0_u", ts[0].clone());
            p.set("rec0_v", ts[1].clone());
            p.set("fc_w", ts[2].clone());
            let (_, grads) = autograd::surrogate_penalty(&p, lam_rec, lam_nonrec).unwrap();
            let eps = 1e-2f32;
            for name in ["rec0_u", "rec0_v", "fc_w"] {
                let base = p.get(name).unwrap().clone();
                for j in 0..base.len() {
                    let pen_at = |delta: f32| {
                        let mut q = p.clone();
                        q.get_mut(name).unwrap().data_mut()[j] += delta;
                        autograd::surrogate_penalty(&q, lam_rec, lam_nonrec).unwrap().0
                    };
                    let fd = (pen_at(eps) - pen_at(-eps)) / (2.0 * eps);
                    let ad = grads.get(name).unwrap().data()[j];
                    if (ad - fd).abs() > 1e-3 + 2e-2 * ad.abs().max(fd.abs()) {
                        eprintln!("{name}[{j}]: {ad} vs {fd}");
                        return false;
                    }
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------------------
// End-to-end: native two-stage → checkpoint → ladder → bit-identical serve.
// ---------------------------------------------------------------------------

fn e2e_dims() -> ModelDims {
    ModelDims {
        feat_dim: 8,
        conv: vec![ConvDims { context: 2, dim: 10 }],
        gru_dims: vec![8, 8],
        fc_dim: 12,
        vocab: 29,
        total_stride: 2,
    }
}

fn e2e_corpus(seed: u64, n_train: usize) -> Dataset {
    let spec = CorpusSpec {
        seed,
        feat_dim: 8,
        max_frames: 64,
        max_label: 6,
        dur_min: 3,
        dur_max: 6,
        noise: 0.3,
        bands: 2,
        feasibility_stride: 2,
    };
    Dataset::generate(spec, n_train, 4, 4)
}

#[test]
fn native_two_stage_trains_and_roundtrips_into_serving_stack() {
    let dims = e2e_dims();
    let data = e2e_corpus(23, 18);
    let geom = BatchGeom { batch: 3, max_frames: 64, max_label: 6 };
    let mut batcher = Batcher::new(&data.train, geom, 8, 5);
    let opts = TrainOpts {
        seed: 23,
        lr: 3e-3,
        lr_decay: 0.92,
        epochs: 0, // set per stage by two_stage_native
        lam_rec: 1e-3,
        lam_nonrec: 1e-3,
        quiet: true,
    };
    let r = two_stage_native(
        &dims,
        &mut batcher,
        None,
        0.9,
        NATIVE_RANK_LADDER,
        3,
        5,
        opts,
        NativeOpts::default(),
        Stage2Lr::Continuation,
    )
    .unwrap();

    // acceptance: stage-1 loss strictly decreases over the smoke epochs
    assert_eq!(r.stage1_history.len(), 3);
    for w in r.stage1_history.windows(2) {
        assert!(
            w[1].mean_loss < w[0].mean_loss,
            "stage-1 loss must decrease monotonically: {:?}",
            r.stage1_history.iter().map(|l| l.mean_loss).collect::<Vec<_>>()
        );
    }
    assert!(r.stage2.history.iter().all(|l| l.mean_loss.is_finite()));

    // save as a TNCK-v2 train-state; params must round-trip bit-exactly
    let dir: PathBuf =
        std::env::temp_dir().join(format!("tn-native-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("stage2.tnck");
    let meta = TrainMeta {
        dims: dims.clone(),
        stage: 2,
        epoch: r.stage2.history.len(),
        lr: r.stage2.lr,
        lr_decay: r.stage2.opts.lr_decay,
        momentum: r.stage2.nopts.momentum,
        clip: r.stage2.nopts.clip,
        lam_rec: 0.0,
        lam_nonrec: 0.0,
        seed: 23,
    };
    let state = TrainState {
        params: r.stage2.params.clone(),
        momentum: r.stage2.velocity.clone(),
        meta,
    };
    checkpoint::save_train_state(&state, &ckpt).unwrap();
    let loaded = checkpoint::load_params_any(&ckpt).unwrap();
    assert_eq!(loaded.len(), r.stage2.params.len());
    for (name, t) in r.stage2.params.iter() {
        assert_eq!(loaded.get(name).unwrap(), t, "{name} must round-trip bit-exactly");
    }
    // the schedule metadata survives too (the satellite fix)
    let st = checkpoint::load_train_state(&ckpt).unwrap();
    assert_eq!(st.meta.stage, 2);
    assert!((st.meta.lr - r.stage2.lr).abs() < 1e-9);
    assert_eq!(st.momentum.len(), r.stage2.velocity.len());

    // ladder-build from the trained checkpoint → Registry::load → decode
    // bit-identical to an engine built directly from the artifact entries
    let ladder_dir = dir.join("ladder");
    let rungs = ladder_build(&loaded, &dims, &[0.5], &ladder_dir).unwrap();
    let reg = Registry::load(&ladder_dir, 4).unwrap();
    assert_eq!(reg.num_tiers(), 1);
    let art = checkpoint::load_artifact(ladder_dir.join(&rungs[0].file)).unwrap();
    let direct = Engine::from_entries(&dims, &art.entries, 4).unwrap();

    let feats = &data.test[0].feats;
    let mut b1 = Breakdown::default();
    let mut b2 = Breakdown::default();
    let (t_reg, rows_reg) = reg.tier(0).engine.transcribe(feats, &mut b1).unwrap();
    let (t_dir, rows_dir) = direct.transcribe(feats, &mut b2).unwrap();
    assert_eq!(t_reg, t_dir);
    assert_eq!(rows_reg, rows_dir, "registry decode must be bit-identical to from_entries");

    std::fs::remove_dir_all(&dir).unwrap();
}

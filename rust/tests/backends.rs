//! Backend parity suite (DESIGN.md §4): every registered GEMM backend
//! must be **bit-identical** to the scalar reference — and therefore to
//! `qgemm_ref` / `qgemm4_ref` — on the int8 and packed-int4 entry points
//! (i32 accumulation is exact within a scale group; the f32 group fold
//! follows one fixed association order), and within 1e-5 (relative) of
//! scalar on f32.  Runs under both the default build and
//! `--features simd` (scripts/ci.sh exercises both).

use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::kernels::{
    all_backends, qgemm4_farm_rows, qgemm4_ref, qgemm_ref, BackendSel, GemmBackend,
    PreparedQ4Matrix, PreparedQMatrix,
};
use tracenorm::prng::Pcg64;
use tracenorm::quant::{quantize4, QMatrix};
use tracenorm::stream::{demo_dims, synthetic_params, StreamPool};
use tracenorm::tensor::{Tensor, TensorI8};

fn rand_i8(r: usize, c: usize, rng: &mut Pcg64) -> TensorI8 {
    TensorI8::new(&[r, c], (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect())
        .unwrap()
}

/// The shape grid of the parity contract: every m ∈ 1..=8, with odd and
/// ragged n/k — n over all mod-4 residues, k below the 8-wide unroll
/// tail, straddling the 256-col pack strip, and paper-scale.
fn parity_shapes() -> Vec<(usize, usize, usize)> {
    let mut shapes = Vec::new();
    for m in 1..=8usize {
        for &(n, k) in &[
            (1usize, 1usize),
            (3, 3),
            (5, 7), // k < 8: the dot_i8 unroll tail
            (7, 5),
            (33, 31),
            (34, 100),
            (64, 255),
            (65, 257), // k straddles the KC=256 strip boundary
            (96, 320),
        ] {
            shapes.push((m, n, k));
        }
    }
    shapes
}

#[test]
fn int8_backends_bit_identical_to_reference() {
    let mut rng = Pcg64::seeded(1);
    for (m, n, k) in parity_shapes() {
        let x = rand_i8(m, k, &mut rng);
        let wq = rand_i8(n, k, &mut rng);
        let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.021 });
        let want = qgemm_ref(&x, &wq, 0.013, 0.021);
        for (_, be) in all_backends() {
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), m, &w, 0.013, &mut out);
            assert_eq!(out, want, "{} qgemm_farm_into ({m},{n},{k})", be.name());
        }
    }
}

#[test]
fn int8_farm_rows_bit_identical_to_batch1_calls() {
    // the pooled contract, per backend: one batch-m call with per-row
    // scales == m batch-1 calls of the same backend, bit for bit
    let mut rng = Pcg64::seeded(2);
    for (m, n, k) in parity_shapes() {
        let x = rand_i8(m, k, &mut rng);
        let wq = rand_i8(n, k, &mut rng);
        let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.017 });
        let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
        for (_, be) in all_backends() {
            let mut pooled = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_rows_into(x.data(), m, &w, &sx, &mut pooled);
            for i in 0..m {
                let mut solo = Tensor::zeros(&[0, 0]);
                be.qgemm_farm_into(x.row(i), 1, &w, sx[i], &mut solo);
                assert_eq!(
                    pooled.row(i),
                    solo.row(0),
                    "{} row {i} of ({m},{n},{k})",
                    be.name()
                );
            }
        }
    }
}

#[test]
fn int8_gemv_bit_identical_to_batch1_farm() {
    // the dedicated m = 1 GEMV entry point, per backend: same bits as
    // the batch-1 farm call and the reference, across ragged n/k
    // (including k < 8 and every n mod 4 residue in the grid)
    let mut rng = Pcg64::seeded(4);
    for (m, n, k) in parity_shapes() {
        if m != 1 {
            continue;
        }
        let x = rand_i8(1, k, &mut rng);
        let wq = rand_i8(n, k, &mut rng);
        let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.021 });
        let want = qgemm_ref(&x, &wq, 0.013, 0.021);
        for (_, be) in all_backends() {
            let mut gemv = Tensor::zeros(&[0, 0]);
            be.qgemv_into(x.data(), &w, 0.013, &mut gemv);
            assert_eq!(gemv, want, "{} qgemv_into ({n},{k})", be.name());

            let mut farm = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), 1, &w, 0.013, &mut farm);
            assert_eq!(gemv, farm, "{} gemv vs batch-1 farm ({n},{k})", be.name());
        }
    }
}

#[test]
fn fused_gates_bit_identical_to_three_separate_gemms() {
    // the fused kernel's contract, stated the way the GRU uses it: the
    // (m, 3H) fused result equals three independent per-gate GEMMs
    // against the z / r / h̃ row slices of the stacked weight
    let mut rng = Pcg64::seeded(5);
    for &(m, h, k) in &[
        (1usize, 1usize, 1usize),
        (1, 5, 7), // k < 8 tail
        (2, 7, 5),
        (3, 33, 31),
        (4, 64, 257), // k straddles the KC=256 strip boundary
        (8, 32, 100),
    ] {
        let x = rand_i8(m, k, &mut rng);
        let wq = rand_i8(3 * h, k, &mut rng);
        let w = PreparedQMatrix::new_with_gates(QMatrix { q: wq.clone(), scale: 0.021 });
        assert!(w.gates.is_some(), "(3·{h}, {k}) weight must carry gate panels");
        let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();

        // three separate per-gate reference GEMMs over the row slices
        let gate_slice = |g: usize| {
            let rows: Vec<i8> =
                (g * h..(g + 1) * h).flat_map(|j| wq.row(j).iter().copied()).collect();
            TensorI8::new(&[h, k], rows).unwrap()
        };
        let per_gate: Vec<Tensor> = (0..3)
            .map(|g| {
                let wg = gate_slice(g);
                let mut want = Tensor::zeros(&[m, h]);
                for i in 0..m {
                    let xi = TensorI8::new(&[1, k], x.row(i).to_vec()).unwrap();
                    let row = qgemm_ref(&xi, &wg, sx[i], 0.021);
                    want.row_mut(i).copy_from_slice(row.row(0));
                }
                want
            })
            .collect();

        for (_, be) in all_backends() {
            let mut fused = Tensor::zeros(&[0, 0]);
            be.qgemm_gates_rows_into(x.data(), m, &w, &sx, &mut fused);
            assert_eq!(fused.shape(), &[m, 3 * h], "{} fused shape", be.name());
            for i in 0..m {
                for g in 0..3 {
                    assert_eq!(
                        &fused.row(i)[g * h..(g + 1) * h],
                        per_gate[g].row(i),
                        "{} gate {g} row {i} of ({m},{h},{k})",
                        be.name()
                    );
                }
            }
        }
    }
}

fn rand_q4(n: usize, k: usize, rng: &mut Pcg64) -> tracenorm::quant::Q4Matrix {
    quantize4(&Tensor::randn(&[n, k], 0.4, rng))
}

#[test]
fn int4_backends_bit_identical_to_reference() {
    // the int4 bit-identity contract on the same ragged grid as int8:
    // exact i32 sub-accumulation per scale group, one fixed f32 fold
    // order over groups — so every backend reproduces qgemm4_ref exactly
    let mut rng = Pcg64::seeded(31);
    for (m, n, k) in parity_shapes() {
        let x = rand_i8(m, k, &mut rng);
        let q4 = rand_q4(n, k, &mut rng);
        let w = PreparedQ4Matrix::new(q4.clone());
        let want = qgemm4_ref(&x, &q4, 0.013);
        for (_, be) in all_backends() {
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm4_farm_into(x.data(), m, &w, 0.013, &mut out);
            assert_eq!(out, want, "{} qgemm4_farm_into ({m},{n},{k})", be.name());
        }
    }
}

#[test]
fn int4_farm_rows_bit_identical_to_batch1_calls() {
    // pooled contract, int4: one batch-m call with per-row scales == m
    // batch-1 calls of the same backend, bit for bit
    let mut rng = Pcg64::seeded(32);
    for (m, n, k) in parity_shapes() {
        let x = rand_i8(m, k, &mut rng);
        let w = PreparedQ4Matrix::new(rand_q4(n, k, &mut rng));
        let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
        for (_, be) in all_backends() {
            let mut pooled = Tensor::zeros(&[0, 0]);
            be.qgemm4_farm_rows_into(x.data(), m, &w, &sx, &mut pooled);
            for i in 0..m {
                let mut solo = Tensor::zeros(&[0, 0]);
                be.qgemm4_farm_into(x.row(i), 1, &w, sx[i], &mut solo);
                assert_eq!(
                    pooled.row(i),
                    solo.row(0),
                    "{} int4 row {i} of ({m},{n},{k})",
                    be.name()
                );
            }
        }
    }
}

#[test]
fn int4_gemv_bit_identical_to_batch1_farm() {
    // the dedicated m = 1 int4 GEMV entry point, per backend: same bits
    // as the batch-1 farm call and the scalar reference
    let mut rng = Pcg64::seeded(33);
    for (m, n, k) in parity_shapes() {
        if m != 1 {
            continue;
        }
        let x = rand_i8(1, k, &mut rng);
        let q4 = rand_q4(n, k, &mut rng);
        let w = PreparedQ4Matrix::new(q4.clone());
        let want = qgemm4_ref(&x, &q4, 0.013);
        for (_, be) in all_backends() {
            let mut gemv = Tensor::zeros(&[0, 0]);
            be.qgemv4_into(x.data(), &w, 0.013, &mut gemv);
            assert_eq!(gemv, want, "{} qgemv4_into ({n},{k})", be.name());

            let mut farm = Tensor::zeros(&[0, 0]);
            be.qgemm4_farm_into(x.data(), 1, &w, 0.013, &mut farm);
            assert_eq!(gemv, farm, "{} int4 gemv vs batch-1 farm ({n},{k})", be.name());
        }
    }
}

#[test]
fn int4_fused_gates_bit_identical_to_plain_rows_sweep() {
    // the fused [z|r|h̃] int4 kernel is a layout optimization, not a new
    // numeric path: its (m, 3H) result must match the plain stacked
    // per-row sweep (the scalar reference) bit for bit, per backend
    let mut rng = Pcg64::seeded(34);
    for &(m, h, k) in &[
        (1usize, 1usize, 1usize),
        (1, 5, 7), // k < 8, odd half-byte tail
        (2, 7, 5),
        (3, 33, 31),  // k straddles the 32-col scale group
        (4, 64, 257), // k straddles the KC strip boundary
        (8, 32, 100),
    ] {
        let x = rand_i8(m, k, &mut rng);
        let q4 = rand_q4(3 * h, k, &mut rng);
        let w = PreparedQ4Matrix::new_with_gates(q4.clone());
        assert!(w.gates.is_some(), "(3·{h}, {k}) int4 weight must carry gate panels");
        let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
        let want = qgemm4_farm_rows(&x, &q4, &sx);
        for (_, be) in all_backends() {
            let mut fused = Tensor::zeros(&[0, 0]);
            be.qgemm4_gates_rows_into(x.data(), m, &w, &sx, &mut fused);
            assert_eq!(fused, want, "{} int4 fused gates ({m},{h},{k})", be.name());
        }
    }
}

#[test]
fn int4_engines_bit_identical_across_backends() {
    // end to end at --bits 4: same weights, every backend, identical
    // transcripts and log-prob rows
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 41);
    let mut rng = Pcg64::seeded(42);
    let feats = Tensor::randn(&[48, dims.feat_dim], 0.7, &mut rng);

    let reference = Engine::from_params(&dims, "partial", &params, Precision::Int4, 4)
        .unwrap()
        .with_backend(BackendSel::Scalar)
        .unwrap();
    let mut bd = Breakdown::default();
    let (t0, r0) = reference.transcribe(&feats, &mut bd).unwrap();

    for (sel, _) in all_backends() {
        for fused in [true, false] {
            let eng = Engine::from_params(&dims, "partial", &params, Precision::Int4, 4)
                .unwrap()
                .with_backend(sel)
                .unwrap()
                .with_fused_gates(fused);
            let mut bd = Breakdown::default();
            let (t, r) = eng.transcribe(&feats, &mut bd).unwrap();
            assert_eq!(t, t0, "{sel} fused={fused} int4 transcript");
            assert_eq!(r, r0, "{sel} fused={fused} int4 log-prob rows");
        }
    }
}

#[test]
fn int4_pooled_decoding_bit_identical_under_every_backend() {
    // the pooled bit-identity guarantee holds on the sub-byte path too
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 43);
    let mut rng = Pcg64::seeded(44);
    let utts: Vec<Tensor> =
        (0..3).map(|_| Tensor::randn(&[32, dims.feat_dim], 0.6, &mut rng)).collect();

    for (sel, _) in all_backends() {
        let eng = std::sync::Arc::new(
            Engine::from_params(&dims, "partial", &params, Precision::Int4, 4)
                .unwrap()
                .with_backend(sel)
                .unwrap(),
        );
        let solos: Vec<(String, Vec<Vec<f32>>)> = utts
            .iter()
            .map(|u| {
                let mut bd = Breakdown::default();
                eng.transcribe(u, &mut bd).unwrap()
            })
            .collect();

        let mut pool = StreamPool::new(eng, 3);
        let ids: Vec<_> = (0..3).map(|_| pool.open().unwrap()).collect();
        let mut bd = Breakdown::default();
        for (id, u) in ids.iter().zip(&utts) {
            pool.push_frames(*id, u.data()).unwrap();
        }
        pool.pump(&mut bd).unwrap();
        for (i, id) in ids.iter().enumerate() {
            let closed = pool.close(*id, &mut bd).unwrap();
            assert_eq!(closed.transcript, solos[i].0, "{sel} int4 pooled transcript {i}");
            assert_eq!(closed.logprob_rows, solos[i].1, "{sel} int4 pooled rows {i}");
        }
    }
}

#[test]
fn f32_backends_within_1e5_of_scalar() {
    let mut rng = Pcg64::seeded(3);
    for &(m, n, k) in &[(1usize, 7usize, 5usize), (2, 33, 64), (4, 65, 257), (8, 96, 320)] {
        let x = Tensor::randn(&[m, k], 1.0, &mut rng);
        let w = Tensor::randn(&[n, k], 0.1, &mut rng);
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.01).collect();
        let mut want = Tensor::zeros(&[0, 0]);
        tracenorm::kernels::ScalarBackend.gemm_f32_into(&x, &w, Some(&bias), &mut want);
        let scale = want.abs_max().max(1.0);
        for (_, be) in all_backends() {
            let mut out = Tensor::zeros(&[0, 0]);
            be.gemm_f32_into(&x, &w, Some(&bias), &mut out);
            let rel = out.max_abs_diff(&want) / scale;
            assert!(rel < 1e-5, "{} f32 rel err {rel} at ({m},{n},{k})", be.name());
        }
    }
}

#[test]
fn int8_engines_bit_identical_across_backends() {
    // end to end: same weights, every backend, identical transcripts and
    // log-prob rows — backend choice can never change what a user hears
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 11);
    let mut rng = Pcg64::seeded(12);
    let feats = Tensor::randn(&[48, dims.feat_dim], 0.7, &mut rng);

    let reference = Engine::from_params(&dims, "partial", &params, Precision::Int8, 4)
        .unwrap()
        .with_backend(BackendSel::Scalar)
        .unwrap();
    let mut bd = Breakdown::default();
    let (t0, r0) = reference.transcribe(&feats, &mut bd).unwrap();

    for (sel, _) in all_backends() {
        let eng = Engine::from_params(&dims, "partial", &params, Precision::Int8, 4)
            .unwrap()
            .with_backend(sel)
            .unwrap();
        let mut bd = Breakdown::default();
        let (t, r) = eng.transcribe(&feats, &mut bd).unwrap();
        assert_eq!(t, t0, "{sel} transcript");
        assert_eq!(r, r0, "{sel} log-prob rows must be bit-identical");
    }
}

#[test]
fn pooled_decoding_bit_identical_under_every_backend() {
    // the PR-1 pooled bit-identity guarantee must survive backend choice
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.25, 13);
    let mut rng = Pcg64::seeded(14);
    let utts: Vec<Tensor> =
        (0..3).map(|_| Tensor::randn(&[32, dims.feat_dim], 0.6, &mut rng)).collect();

    for (sel, _) in all_backends() {
        let eng = std::sync::Arc::new(
            Engine::from_params(&dims, "partial", &params, Precision::Int8, 4)
                .unwrap()
                .with_backend(sel)
                .unwrap(),
        );
        let solos: Vec<(String, Vec<Vec<f32>>)> = utts
            .iter()
            .map(|u| {
                let mut bd = Breakdown::default();
                eng.transcribe(u, &mut bd).unwrap()
            })
            .collect();

        let mut pool = StreamPool::new(eng, 3);
        let ids: Vec<_> = (0..3).map(|_| pool.open().unwrap()).collect();
        let mut bd = Breakdown::default();
        for (id, u) in ids.iter().zip(&utts) {
            pool.push_frames(*id, u.data()).unwrap();
        }
        pool.pump(&mut bd).unwrap();
        for (i, id) in ids.iter().enumerate() {
            let closed = pool.close(*id, &mut bd).unwrap();
            assert_eq!(closed.transcript, solos[i].0, "{sel} pooled transcript {i}");
            assert_eq!(closed.logprob_rows, solos[i].1, "{sel} pooled rows {i}");
        }
    }
}

#[test]
fn fused_gates_switch_is_bit_identical_end_to_end() {
    // --fused-gates on/off is a performance switch, not an accuracy
    // knob: identical transcripts and log-prob rows under every backend,
    // for both single-stream and pooled decoding
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 21);
    let mut rng = Pcg64::seeded(22);
    let feats = Tensor::randn(&[48, dims.feat_dim], 0.7, &mut rng);
    let utts: Vec<Tensor> =
        (0..3).map(|_| Tensor::randn(&[32, dims.feat_dim], 0.6, &mut rng)).collect();

    for (sel, _) in all_backends() {
        let mk = |fused: bool| {
            Engine::from_params(&dims, "partial", &params, Precision::Int8, 4)
                .unwrap()
                .with_backend(sel)
                .unwrap()
                .with_fused_gates(fused)
        };
        let on = mk(true);
        let off = mk(false);
        assert!(on.fused_gates() && !off.fused_gates());

        let mut bd = Breakdown::default();
        let (t_on, r_on) = on.transcribe(&feats, &mut bd).unwrap();
        let (t_off, r_off) = off.transcribe(&feats, &mut bd).unwrap();
        assert_eq!(t_on, t_off, "{sel} fused on/off transcript");
        assert_eq!(r_on, r_off, "{sel} fused on/off log-prob rows");

        // pooled decoding with the fused engine vs solo with the plain one
        let eng = std::sync::Arc::new(mk(true));
        let solos: Vec<(String, Vec<Vec<f32>>)> = utts
            .iter()
            .map(|u| {
                let mut bd = Breakdown::default();
                off.transcribe(u, &mut bd).unwrap()
            })
            .collect();
        let mut pool = StreamPool::new(eng, 3);
        let ids: Vec<_> = (0..3).map(|_| pool.open().unwrap()).collect();
        let mut bd = Breakdown::default();
        for (id, u) in ids.iter().zip(&utts) {
            pool.push_frames(*id, u.data()).unwrap();
        }
        pool.pump(&mut bd).unwrap();
        for (i, id) in ids.iter().enumerate() {
            let closed = pool.close(*id, &mut bd).unwrap();
            assert_eq!(closed.transcript, solos[i].0, "{sel} fused pooled transcript {i}");
            assert_eq!(closed.logprob_rows, solos[i].1, "{sel} fused pooled rows {i}");
        }
    }
}

#[test]
fn simd_selector_requires_feature() {
    let r = tracenorm::kernels::resolve(BackendSel::Simd);
    #[cfg(feature = "simd")]
    assert_eq!(r.unwrap().name(), "simd");
    #[cfg(not(feature = "simd"))]
    assert!(r.is_err(), "simd selector must fail without the feature");
}

//! Confidence-gated cascade contracts (DESIGN.md §11): the threshold
//! endpoints must be **bit-identical** to single-rung decoding — 0 to
//! the pure low rung, ∞ to the pure high rung — on every backend and at
//! any shard count; checkpoint-rewind must be deterministic at any
//! threshold; escalation events must land in the merged journal in
//! `journal::canonical_cmp` order; and `Registry::cascade_pair` must
//! parse tags and tier indices while rejecting malformed pairs.
//!
//! Both rungs come from `synthetic_params` at the *same seed*, so the
//! unfactored conv frontend is byte-identical across the pair — the
//! configuration the shared-frontend fast path assumes.

use std::cmp::Ordering;
use std::path::PathBuf;
use std::sync::Arc;

use tracenorm::controller::ControllerConfig;
use tracenorm::data::Utterance;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::kernels::BackendSel;
use tracenorm::obs;
use tracenorm::obs::journal::canonical_cmp;
use tracenorm::obs::EventKind;
use tracenorm::prng::Pcg64;
use tracenorm::registry::{ladder_build, Registry};
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::serve::{
    ladder_serve, stream_serve_cascade, CascadePlan, LadderServeConfig, StreamServeConfig,
};
use tracenorm::stream::{synthetic_params, CascadeCfg, StreamId, StreamPool};
use tracenorm::tensor::Tensor;

/// Small dims so cascade cases stay fast in debug builds; conv + two
/// GRU layers + factored fc still exercise every checkpointed stage.
fn tiny_dims() -> ModelDims {
    ModelDims {
        feat_dim: 8,
        conv: vec![ConvDims { context: 2, dim: 12 }],
        gru_dims: vec![10, 12],
        fc_dim: 14,
        vocab: 29,
        total_stride: 2,
    }
}

/// A rung engine at `frac`, from the shared seed every rung of the pair
/// uses (identical conv frontends).
fn engine_at(frac: f64, backend: BackendSel, precision: Precision) -> Arc<Engine> {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, frac, 5);
    Arc::new(
        Engine::from_params(&dims, "partial", &params, precision, 4)
            .unwrap()
            .with_backend(backend)
            .unwrap(),
    )
}

fn cc(high: &Arc<Engine>, threshold: f64) -> CascadeCfg {
    CascadeCfg { high: high.clone(), threshold, shared_frontend: true }
}

fn backends() -> Vec<BackendSel> {
    #[allow(unused_mut)]
    let mut v = vec![BackendSel::Scalar, BackendSel::Blocked];
    #[cfg(feature = "simd")]
    v.push(BackendSel::Simd);
    v
}

fn ragged_utts(n: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg64::seeded(seed);
    (0..n).map(|i| Tensor::randn(&[10 + 5 * i + rng.below(8), 8], 0.7, &mut rng)).collect()
}

/// Round-robin ragged-chunk decode of every utterance through one pool;
/// returns per-utterance (transcript, logprob rows) plus the pool stats.
fn pool_decode(
    mut pool: StreamPool,
    utts: &[Tensor],
) -> (Vec<(String, Vec<Vec<f32>>)>, tracenorm::stream::PoolStats) {
    let ids: Vec<StreamId> = utts.iter().map(|_| pool.open().unwrap()).collect();
    let mut off = vec![0usize; utts.len()];
    let mut got: Vec<Option<(String, Vec<Vec<f32>>)>> = vec![None; utts.len()];
    let mut bd = Breakdown::default();
    let mut done = 0;
    let mut round = 0usize;
    while done < utts.len() {
        for i in 0..utts.len() {
            if got[i].is_some() {
                continue;
            }
            // per-stream chunk sizes drift round to round so block
            // boundaries land mid-chunk as often as on the edge
            let chunk = (2 + (i + round) % 5) * 8;
            let data = utts[i].data();
            let end = (off[i] + chunk).min(data.len());
            if off[i] < end {
                pool.push_frames(ids[i], &data[off[i]..end]).unwrap();
                off[i] = end;
            }
            if off[i] >= data.len() {
                let closed = pool.close(ids[i], &mut bd).unwrap();
                got[i] = Some((closed.transcript, closed.logprob_rows));
                done += 1;
            }
        }
        pool.pump(&mut bd).unwrap();
        round += 1;
    }
    let stats = pool.stats;
    (got.into_iter().map(Option::unwrap).collect(), stats)
}

/// Threshold 0 never escalates and is bit-identical to the pure low
/// rung; threshold ∞ always escalates and is bit-identical to the pure
/// high rung — per backend, transcripts *and* log-prob rows.
#[test]
fn threshold_endpoints_bit_identical_to_single_rung_pools() {
    let utts = ragged_utts(4, 3);
    for backend in backends() {
        for precision in [Precision::Int8, Precision::F32] {
            let low = engine_at(0.25, backend, precision);
            let high = engine_at(0.75, backend, precision);
            let (ref_low, _) = pool_decode(StreamPool::new(low.clone(), 4), &utts);
            let (ref_high, _) = pool_decode(StreamPool::new(high.clone(), 4), &utts);

            let pool0 =
                StreamPool::new(low.clone(), 4).with_cascade(cc(&high, 0.0)).unwrap();
            let (got0, st0) = pool_decode(pool0, &utts);
            assert_eq!(got0, ref_low, "threshold 0 diverged from pure low ({backend:?})");
            assert!(st0.stream_blocks > 0, "no blocks crossed the gate");
            assert_eq!(st0.escalated_blocks, 0, "threshold 0 must never escalate");

            let pool_inf = StreamPool::new(low.clone(), 4)
                .with_cascade(cc(&high, f64::INFINITY))
                .unwrap();
            let (got_inf, st_inf) = pool_decode(pool_inf, &utts);
            assert_eq!(
                got_inf, ref_high,
                "threshold inf diverged from pure high ({backend:?})"
            );
            assert_eq!(
                st_inf.escalated_blocks, st_inf.stream_blocks,
                "threshold inf must escalate every block"
            );
            assert!(st_inf.stream_blocks > 0);
        }
    }
}

/// Checkpoint/rewind is deterministic: the same workload through the
/// same cascade yields bit-identical output and identical gate counters
/// at every threshold, escalate-none through escalate-all.
#[test]
fn cascade_decode_is_deterministic_at_any_threshold() {
    let utts = ragged_utts(4, 11);
    let low = engine_at(0.25, BackendSel::Scalar, Precision::Int8);
    let high = engine_at(0.75, BackendSel::Scalar, Precision::Int8);
    for threshold in [0.0, 1e-3, 0.05, 1.0, f64::INFINITY] {
        let run = || {
            let pool = StreamPool::new(low.clone(), 4)
                .with_cascade(cc(&high, threshold))
                .unwrap();
            pool_decode(pool, &utts)
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b, "threshold {threshold}: reruns diverged");
        assert_eq!(sa.stream_blocks, sb.stream_blocks);
        assert_eq!(sa.escalated_blocks, sb.escalated_blocks);
    }
}

/// Cascade rejects incompatible rung pairs and malformed thresholds.
#[test]
fn cascade_rejects_incompatible_rungs_and_bad_thresholds() {
    let low = engine_at(0.25, BackendSel::Scalar, Precision::Int8);
    let high = engine_at(0.75, BackendSel::Scalar, Precision::Int8);
    assert!(StreamPool::new(low.clone(), 2).with_cascade(cc(&high, f64::NAN)).is_err());
    assert!(StreamPool::new(low.clone(), 2).with_cascade(cc(&high, -0.5)).is_err());

    let mut other = tiny_dims();
    other.gru_dims = vec![10, 16];
    let p = synthetic_params(&other, 0.75, 5);
    let alien =
        Arc::new(Engine::from_params(&other, "partial", &p, Precision::Int8, 4).unwrap());
    assert!(
        StreamPool::new(low.clone(), 2).with_cascade(cc(&alien, 1.0)).is_err(),
        "mismatched hidden widths must be rejected"
    );

    let mut pool = StreamPool::new(low, 2).with_cascade(cc(&high, 1.0)).unwrap();
    assert!(pool.set_escalation_threshold(f64::NAN).is_err());
    assert!(pool.set_escalation_threshold(-1.0).is_err());
    assert!(pool.set_escalation_threshold(0.25).is_ok());
    assert_eq!(pool.cascade().unwrap().threshold, 0.25);
}

fn fixed_utterances(n: usize, frames: usize, feat: usize, seed: u64) -> Vec<Utterance> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| Utterance {
            text: String::new(),
            labels: Vec::new(),
            feats: Tensor::randn(&[frames, feat], 0.6, &mut rng),
        })
        .collect()
}

/// The serve-level endpoints, at 1, 2 and 4 shards: a cascade serve at
/// threshold 0 reproduces the plain low-rung serve transcript for
/// transcript, threshold ∞ the plain high-rung serve — and the summary
/// accounting matches the gate counters.
#[test]
fn serve_endpoints_bit_identical_across_shard_counts() {
    let low = engine_at(0.25, BackendSel::Auto, Precision::Int8);
    let high = engine_at(0.75, BackendSel::Auto, Precision::Int8);
    let utts = fixed_utterances(8, 24, 8, 19);
    for shards in [1usize, 2, 4] {
        let cfg = StreamServeConfig {
            arrival_rate: 40.0,
            pool_size: 2,
            chunk_frames: 8,
            shards,
            seed: 7,
            ..Default::default()
        };
        let base_low = stream_serve_cascade(low.clone(), None, &utts, &cfg).unwrap();
        assert!(base_low.cascade.is_none(), "no cascade requested, none reported");
        let base_high = stream_serve_cascade(high.clone(), None, &utts, &cfg).unwrap();

        let c0 =
            stream_serve_cascade(low.clone(), Some(cc(&high, 0.0)), &utts, &cfg).unwrap();
        assert_eq!(
            c0.transcripts, base_low.transcripts,
            "{shards} shard(s): threshold 0 diverged from pure low serve"
        );
        let s0 = c0.cascade.expect("cascade summary missing");
        assert_eq!(s0.escalated_blocks, 0);
        assert_eq!(s0.escalation_rate, 0.0);
        assert!(s0.stream_blocks > 0);
        assert_eq!(s0.gflops_effective, s0.gflops_low, "rate 0 serves at low-rung cost");

        let cinf =
            stream_serve_cascade(low.clone(), Some(cc(&high, f64::INFINITY)), &utts, &cfg)
                .unwrap();
        assert_eq!(
            cinf.transcripts, base_high.transcripts,
            "{shards} shard(s): threshold inf diverged from pure high serve"
        );
        let sinf = cinf.cascade.expect("cascade summary missing");
        assert_eq!(sinf.escalated_blocks, sinf.stream_blocks);
        assert_eq!(sinf.escalation_rate, 1.0);
        assert!(sinf.gflops_high > sinf.gflops_low, "rung pair must differ in cost");
        assert!(sinf.gflops_effective > sinf.gflops_low);
    }
}

/// Escalation events land in the merged journal in canonical order,
/// one per escalated block, shard-tagged — and under a fixed tick the
/// whole journal is identical run to run.
#[test]
fn escalation_events_journal_in_canonical_order() {
    let low = engine_at(0.25, BackendSel::Auto, Precision::Int8);
    let high = engine_at(0.75, BackendSel::Auto, Precision::Int8);
    let utts = fixed_utterances(6, 24, 8, 23);
    let run = || {
        obs::reset_process_metrics();
        obs::set_enabled(true);
        let cfg = StreamServeConfig {
            arrival_rate: 40.0,
            pool_size: 2,
            chunk_frames: 8,
            shards: 2,
            seed: 9,
            tick_secs: Some(0.002),
            ..Default::default()
        };
        let r = stream_serve_cascade(low.clone(), Some(cc(&high, f64::INFINITY)), &utts, &cfg)
            .unwrap();
        obs::set_enabled(false);
        r
    };
    let r = run();
    let journal = r.obs.expect("obs report missing").journal;
    assert!(
        journal.windows(2).all(|w| canonical_cmp(&w[0], &w[1]) != Ordering::Greater),
        "merged journal violates canonical_cmp order"
    );
    let esc: Vec<_> =
        journal.iter().filter(|e| e.kind == EventKind::CascadeEscalate).collect();
    let summary = r.cascade.expect("cascade summary missing");
    assert_eq!(
        esc.len() as u64,
        summary.escalated_blocks,
        "one journal event per escalated block"
    );
    assert!(!esc.is_empty(), "threshold inf with traffic must escalate");
    for e in &esc {
        assert_eq!(e.kind.name(), "cascade_escalate");
        assert!(e.shard < 2, "escalation events are shard-tagged");
        assert_eq!(e.tier, 0, "single-rung serve decodes on tier 0");
        assert!(e.session < utts.len());
    }

    let j2 = run().obs.expect("obs report missing").journal;
    let j3 = run().obs.expect("obs report missing").journal;
    assert_eq!(j2, j3, "fixed-tick cascade journal must be identical run to run");
}

fn temp_ladder_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tncascade-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `cascade_pair` accepts rung tags and tier indices (whitespace
/// tolerated), and rejects same-rung, swapped, unknown and out-of-range
/// specs; rung metadata carries a positive, fidelity-ordered
/// GFLOP/frame figure and same-bits rungs share a frontend.
#[test]
fn registry_cascade_pair_parses_tags_and_indices() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 5);
    let dir = temp_ladder_dir("pair");
    ladder_build(&params, &dims, &[0.5, 0.25], &dir).unwrap();
    let reg = Registry::load(&dir, 4).unwrap();

    assert_eq!(reg.cascade_pair("r0250:r0500").unwrap(), (1, 0));
    assert_eq!(reg.cascade_pair("1:0").unwrap(), (1, 0));
    assert_eq!(reg.cascade_pair(" 1 : r0500 ").unwrap(), (1, 0));

    for bad in ["r0500:r0250", "0:0", "1:1", "zzz:0", "5:0", "1:9", "r0500", ""] {
        assert!(reg.cascade_pair(bad).is_err(), "spec '{bad}' must be rejected");
    }

    let v = reg.variants();
    assert!(v.iter().all(|v| v.info.gflops_per_frame > 0.0));
    assert!(
        v[0].info.gflops_per_frame > v[1].info.gflops_per_frame,
        "tier 0 is the costlier rung"
    );
    assert!(reg.shared_frontend(0, 1), "same-bits rungs share the conv frontend");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Ladder serving with a cascade plan: low-tier sessions run the gate
/// (threshold ∞ escalates every block), escalations are journaled on
/// the low tier, and the ∞-threshold knob never blocks the ramp's
/// fidelity downshift.
#[test]
fn ladder_cascade_escalates_and_journals_on_the_low_tier() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 8);
    let dir = temp_ladder_dir("serve");
    ladder_build(&params, &dims, &[0.5, 0.125], &dir).unwrap();
    let reg = Registry::load(&dir, 2).unwrap();

    // the occupancy-driven burst/trickle workload from the controller
    // ramp test: the burst spills sessions onto tier 1 — the cascade's
    // low rung — and the trickle drains back to tier 0
    let utts = fixed_utterances(12, 16, 8, 9);
    obs::reset_process_metrics();
    obs::set_enabled(true);
    let cfg = LadderServeConfig {
        base_rate: 1e-3,
        ramp_rate: 1e9,
        ramp_range: (0, 8),
        pool_size: 2,
        chunk_frames: 2,
        shards: 1,
        seed: 3,
        controller: ControllerConfig {
            target_p99: 1e9,
            high_water: 0.95,
            low_water: 0.5,
            breach_ticks: 2,
            clear_ticks: 2,
            window: 32,
        },
        cascade: Some(CascadePlan { low_tier: 1, high_tier: 0, threshold: f64::INFINITY }),
        ..Default::default()
    };
    let r = ladder_serve(&reg, &utts, &cfg).unwrap();
    obs::set_enabled(false);

    assert!(r.downshifts >= 1, "an infinite knob must not absorb the ramp");
    let c = r.cascade.expect("cascade summary missing from ladder report");
    assert!(c.stream_blocks > 0, "tier-1 sessions must cross the gate");
    assert_eq!(c.escalated_blocks, c.stream_blocks);
    assert_eq!(c.escalation_rate, 1.0);
    assert!(c.gflops_high > c.gflops_low);

    let journal = r.obs.expect("obs report missing").journal;
    assert!(journal.windows(2).all(|w| canonical_cmp(&w[0], &w[1]) != Ordering::Greater));
    let esc: Vec<_> =
        journal.iter().filter(|e| e.kind == EventKind::CascadeEscalate).collect();
    assert_eq!(esc.len() as u64, c.escalated_blocks);
    assert!(esc.iter().all(|e| e.tier == 1), "escalations journal on the low rung's tier");
    std::fs::remove_dir_all(&dir).unwrap();
}

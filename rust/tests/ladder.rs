//! Integration tests for the rank-ladder subsystem (no artifacts
//! needed): a built ladder must round-trip build → load → serve with
//! pooled decoding **bit-identical** to a direct engine constructed from
//! the same factored weights, and the fidelity controller must
//! demonstrably downshift under a synthetic load ramp and upshift once
//! it drains (ISSUE acceptance criteria; DESIGN.md §8).

use std::path::PathBuf;

use tracenorm::controller::ControllerConfig;
use tracenorm::data::Utterance;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::model::truncate_groups;
use tracenorm::prng::Pcg64;
use tracenorm::registry::{ladder_build, Registry, LADDER_MANIFEST};
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::serve::{ladder_serve, LadderServeConfig};
use tracenorm::stream::{synthetic_params, StreamPool};
use tracenorm::tensor::Tensor;

/// Small dims so SVDs stay fast in debug builds; the structure still
/// exercises conv, two GRU layers, factored fc and the int8 path.
fn tiny_dims() -> ModelDims {
    ModelDims {
        feat_dim: 8,
        conv: vec![ConvDims { context: 2, dim: 12 }],
        gru_dims: vec![10, 12],
        fc_dim: 14,
        vocab: 29,
        total_stride: 2,
    }
}

fn temp_ladder_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tnladder-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn ladder_round_trips_and_pooled_decode_is_bit_identical() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 5);
    let dir = temp_ladder_dir("roundtrip");
    let rungs = ladder_build(&params, &dims, &[0.25, 0.5], &dir).unwrap();
    assert_eq!(rungs.len(), 2);
    assert!(dir.join(LADDER_MANIFEST).exists());
    // rung order is tier order: fidelity-descending
    assert!(rungs[0].rank_frac > rungs[1].rank_frac);
    assert!(rungs[0].params > rungs[1].params, "lower rank must mean fewer params");
    assert!(rungs[0].bytes > rungs[1].bytes);
    for r in &rungs {
        assert!(!r.nu.is_empty(), "each rung carries per-group nu diagnostics");
        assert!(r.nu.iter().all(|(_, nu)| (0.0..=1.0).contains(nu)));
    }

    let reg = Registry::load(&dir, 4).unwrap();
    assert_eq!(reg.num_tiers(), 2);
    let mut rng = Pcg64::seeded(7);
    let feats = Tensor::randn(&[26, 8], 0.7, &mut rng);

    for tier in 0..reg.num_tiers() {
        let v = reg.tier(tier);
        assert_eq!(v.info.tag, rungs[tier].tag);
        assert_eq!(v.engine.precision, Precision::Int8);
        assert_eq!(v.info.params, rungs[tier].params);

        // the reference: a direct engine built from the same factored
        // f32 weights (same SVD truncation, same quantize() call)
        let factored = truncate_groups(&params, v.info.rank_frac).unwrap();
        let direct =
            Engine::from_params(&dims, "partial", &factored, Precision::Int8, 4).unwrap();
        let mut bd = Breakdown::default();
        let (ref_text, ref_rows) = direct.transcribe(&feats, &mut bd).unwrap();
        assert_eq!(v.engine.model_bytes(), direct.model_bytes());

        // pooled decode through the registry engine, ragged chunks
        let mut pool = StreamPool::new(v.engine.clone(), 3);
        let id = pool.open().unwrap();
        let data = feats.data();
        let mut bd2 = Breakdown::default();
        for chunk in [&data[..48], &data[48..120], &data[120..]] {
            pool.push_frames(id, chunk).unwrap();
            pool.pump(&mut bd2).unwrap();
        }
        let closed = pool.close(id, &mut bd2).unwrap();
        assert_eq!(closed.transcript, ref_text);
        assert_eq!(closed.logprob_rows.len(), ref_rows.len());
        for (a, b) in closed.logprob_rows.iter().zip(&ref_rows) {
            assert_eq!(a, b, "tier {tier}: pooled decode must be bit-identical");
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn registry_load_detects_artifact_corruption() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 6);
    let dir = temp_ladder_dir("corrupt");
    let rungs = ladder_build(&params, &dims, &[0.5], &dir).unwrap();
    let path = dir.join(&rungs[0].file);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    assert!(Registry::load(&dir, 4).is_err(), "flipped bit must fail the checksum");
    std::fs::remove_dir_all(&dir).unwrap();
}

fn fixed_utterances(n: usize, frames: usize, feat: usize, seed: u64) -> Vec<Utterance> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|_| Utterance {
            text: String::new(),
            labels: Vec::new(),
            feats: Tensor::randn(&[frames, feat], 0.6, &mut rng),
        })
        .collect()
}

#[test]
fn controller_downshifts_under_ramp_and_upshifts_after() {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 1.0, 8);
    let dir = temp_ladder_dir("ramp");
    ladder_build(&params, &dims, &[0.5, 0.125], &dir).unwrap();
    let reg = Registry::load(&dir, 2).unwrap();

    // 8-session burst at near-instant arrivals into 2x2 slots, then 4
    // trickle sessions far apart.  The burst saturates tier 0 (occupancy
    // 1.0 >= high_water) -> downshift; the drain and the idle gaps clear
    // the counters -> upshift before the trickle, which lands on tier 0.
    // Occupancy is integer-driven, so this sequencing does not depend on
    // wall-clock speed.  target_p99 is huge so only occupancy triggers.
    let utts = fixed_utterances(12, 16, 8, 9);
    let cfg = LadderServeConfig {
        base_rate: 1e-3,
        ramp_rate: 1e9,
        ramp_range: (0, 8),
        pool_size: 2,
        chunk_frames: 2,
        shards: 1,
        seed: 3,
        controller: ControllerConfig {
            target_p99: 1e9,
            high_water: 0.95,
            low_water: 0.5,
            breach_ticks: 2,
            clear_ticks: 2,
            window: 32,
        },
        ..Default::default()
    };
    let r = ladder_serve(&reg, &utts, &cfg).unwrap();

    assert_eq!(r.sessions, 12);
    assert!(r.downshifts >= 1, "ramp must force a downshift ({:?} shifts)", r.shifts);
    assert!(r.upshifts >= 1, "drain must allow an upshift ({:?} shifts)", r.shifts);
    // the per-tier report shows traffic on both rungs
    assert!(r.tiers[0].sessions >= 1, "tier 0 served sessions");
    assert!(r.tiers[1].sessions >= 1, "tier 1 absorbed the ramp spill");
    assert_eq!(r.tiers.iter().map(|t| t.sessions).sum::<usize>(), 12);
    assert!(r.tiers.iter().all(|t| t.sessions == t.latency.count));
    // at least one burst session was admitted below top fidelity...
    assert!(r.tier_of_session[..8].iter().any(|&t| t > 0));
    // ...and after the ramp drained, the trickle rides tier 0 again
    assert_eq!(*r.tier_of_session.last().unwrap(), 0, "tiers: {:?}", r.tier_of_session);
    // shift log alternates down then up at least once, in clock order
    assert!(r.shifts[0].down);
    assert!(r.shifts.windows(2).all(|w| w[0].clock <= w[1].clock));
    std::fs::remove_dir_all(&dir).unwrap();
}

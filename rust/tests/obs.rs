//! Flight-recorder contracts (DESIGN.md §10): the stage-span breakdown
//! accounts for the wall time of the instrumented decode path, recording
//! never changes decoding output, and the shard event journal is
//! deterministic — the same workload yields the same event multiset in
//! clock order at any shard count.
//!
//! Tests run single-threaded (`RUST_TEST_THREADS=1` via
//! `rust/.cargo/config.toml`), so toggling the process-global obs flag
//! is race-free.

use std::sync::Arc;
use std::time::Instant;

use tracenorm::data::{CorpusSpec, Dataset};
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::obs;
use tracenorm::obs::trace::Replay;
use tracenorm::obs::{EventKind, SloConfig, NO_SHARD};
use tracenorm::prng::Pcg64;
use tracenorm::serve::{stream_serve, StreamServeConfig};
use tracenorm::stream::{demo_dims, synthetic_params};
use tracenorm::tensor::Tensor;

/// Spans must sum to the wall time of the staged block loop: every
/// stage's self-time is measured with quantize time subtracted from its
/// enclosing stage, so the sum neither double-counts nor leaks.
#[test]
fn span_sum_accounts_for_pump_wall_time() {
    obs::reset_process_metrics();
    obs::set_enabled(true);
    let dims = demo_dims();
    let params = synthetic_params(&dims, 0.5, 11);
    let eng = Engine::from_params(&dims, "partial", &params, Precision::Int8, 4).unwrap();
    let block = eng.block_raw_len();
    let mut rng = Pcg64::seeded(12);
    let frames = Tensor::randn(&[2 * block / dims.feat_dim, dims.feat_dim], 0.7, &mut rng);
    let mut state = eng.new_state();
    let mut bd = Breakdown::default();

    // warmup block (arena sizing happens outside the measured window)
    eng.stream(&mut state, frames.data(), &mut bd).unwrap();
    bd = Breakdown::default();

    // measure wall strictly around the pump calls — buffering is a
    // memcpy outside the staged primitives and carries no span
    let mut wall = 0.0;
    for _ in 0..16 {
        eng.buffer_frames(&mut state, &frames.data()[..block], &mut bd);
        let t0 = Instant::now();
        assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        wall += t0.elapsed().as_secs_f64();
    }
    obs::set_enabled(false);

    let span_sum = bd.spans.total_secs();
    assert!(span_sum > 0.0, "obs on but spans empty");
    // 1) spans reproduce the coarse breakdown exactly (same timers, the
    //    quantize share just moved between buckets)
    let acoustic = bd.acoustic_total();
    assert!(
        (span_sum - acoustic).abs() <= 0.02 * acoustic + 1e-6,
        "span sum {span_sum} vs breakdown total {acoustic}"
    );
    // 2) and they account for the pump wall time within tolerance —
    //    the gap is per-call timer + dispatch overhead only
    assert!(
        (wall - span_sum).abs() <= 0.05 * wall + 5e-4,
        "span sum {span_sum} vs pump wall {wall}"
    );
    // quantize self-time was carved out of the int8 stages, so it must
    // show up as its own stage
    assert!(
        bd.spans.get(obs::Stage::Quantize) > 0.0,
        "int8 decode recorded no quantize self-time"
    );
}

/// The recorder is passive: transcripts are bit-identical with obs on
/// and off (same engine, same seed, same arrivals).
#[test]
fn transcripts_bit_identical_with_obs_on_and_off() {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, 3);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
    let data = Dataset::generate(CorpusSpec::standard(21), 0, 0, 5);
    let cfg = StreamServeConfig {
        arrival_rate: 50.0,
        pool_size: 3,
        chunk_frames: 16,
        shards: 2,
        seed: 7,
        ..Default::default()
    };

    obs::set_enabled(false);
    let off = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
    assert!(off.obs.is_none(), "obs report present with recorder off");

    obs::reset_process_metrics();
    obs::set_enabled(true);
    let on = stream_serve(engine, &data.test, &cfg).unwrap();
    obs::set_enabled(false);

    assert_eq!(off.transcripts, on.transcripts, "recording changed decoding");
    let rep = on.obs.expect("obs report missing with recorder on");
    assert!(!rep.spans.is_empty());
    assert!(!rep.journal.is_empty());
}

/// Journal determinism: every event is produced on the router thread, so
/// the merged journal is clock-ordered, shard-tagged, and carries the
/// same per-session lifecycle multiset at any shard count.
#[test]
fn journal_merge_deterministic_across_shard_counts() {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, 3);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
    let data = Dataset::generate(CorpusSpec::standard(23), 0, 0, 6);

    let mut lifecycles: Vec<Vec<(&'static str, usize)>> = Vec::new();
    for shards in [1usize, 2, 4] {
        obs::reset_process_metrics();
        obs::set_enabled(true);
        let cfg = StreamServeConfig {
            arrival_rate: 40.0,
            pool_size: 2,
            chunk_frames: 16,
            shards,
            seed: 9,
            ..Default::default()
        };
        let r = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
        obs::set_enabled(false);
        let journal = r.obs.expect("obs report missing").journal;

        // merged journal is clock-ordered
        for w in journal.windows(2) {
            assert!(w[0].clock <= w[1].clock, "journal out of clock order");
        }
        // placement / drain events are shard-tagged with a real shard
        for e in &journal {
            match e.kind {
                EventKind::Placement | EventKind::Drain => {
                    assert!(e.shard < shards, "event shard {} of {shards}", e.shard)
                }
                EventKind::Admission | EventKind::Backpressure => {
                    assert_eq!(e.shard, NO_SHARD)
                }
                _ => {}
            }
        }
        // every session is admitted, placed and drained exactly once
        let mut lc: Vec<(&'static str, usize)> = journal
            .iter()
            .filter(|e| {
                matches!(
                    e.kind,
                    EventKind::Admission | EventKind::Placement | EventKind::Drain
                )
            })
            .map(|e| (e.kind.name(), e.session))
            .collect();
        lc.sort();
        assert_eq!(lc.len(), 3 * data.test.len());
        lifecycles.push(lc);
    }
    // ... and that lifecycle multiset is identical at 1, 2 and 4 shards
    assert_eq!(lifecycles[0], lifecycles[1], "1-shard vs 2-shard journals differ");
    assert_eq!(lifecycles[0], lifecycles[2], "1-shard vs 4-shard journals differ");
}

fn temp_path(tag: &str, ext: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("tracenorm_obs_{tag}_{}.{ext}", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Under `--fixed-tick-ms` the simulated clock — and with it every
/// journal clock and block stamp — is a pure function of the seed, so
/// the exported Chrome trace is byte-identical run to run.
#[test]
fn fixed_tick_trace_is_byte_identical_run_to_run() {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, 3);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
    let data = Dataset::generate(CorpusSpec::standard(25), 0, 0, 5);
    let run = |out: &str| {
        obs::reset_process_metrics();
        obs::set_enabled(true);
        let cfg = StreamServeConfig {
            arrival_rate: 50.0,
            pool_size: 2,
            chunk_frames: 16,
            shards: 1,
            seed: 5,
            trace_out: Some(out.to_string()),
            tick_secs: Some(0.002),
            ..Default::default()
        };
        let r = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
        obs::set_enabled(false);
        r
    };
    let (a, b) = (temp_path("trace_a", "json"), temp_path("trace_b", "json"));
    run(&a);
    run(&b);
    let ta = std::fs::read_to_string(&a).unwrap();
    let tb = std::fs::read_to_string(&b).unwrap();
    assert_eq!(ta, tb, "fixed-tick trace must be byte-identical across runs");
    // and it is a well-formed Chrome-trace document with block slices
    // and journal instants on session tracks
    let doc = tracenorm::jsonx::Json::parse(ta.trim()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")),
        "trace carries no pump-block slices"
    );
    assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("i")));
    assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("M")));
    std::fs::remove_file(&a).ok();
    std::fs::remove_file(&b).ok();
}

/// The offline replay reconstructs the exact in-process journal from the
/// JSONL deltas (canonical order makes this partition-independent), and
/// per-session event sequences agree — shard tag aside — at 1, 2 and 4
/// shards.
#[test]
fn obs_report_replay_matches_in_process_journal_at_any_shard_count() {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, 3);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
    let data = Dataset::generate(CorpusSpec::standard(27), 0, 0, 6);
    let mut per_session: Vec<Vec<(usize, Vec<&'static str>)>> = Vec::new();
    for shards in [1usize, 2, 4] {
        let mpath = temp_path(&format!("replay_{shards}"), "jsonl");
        obs::reset_process_metrics();
        obs::set_enabled(true);
        let cfg = StreamServeConfig {
            arrival_rate: 40.0,
            pool_size: 2,
            chunk_frames: 16,
            shards,
            seed: 9,
            metrics_out: Some(mpath.clone()),
            ..Default::default()
        };
        let r = stream_serve(engine.clone(), &data.test, &cfg).unwrap();
        obs::set_enabled(false);
        let live = r.obs.expect("obs report missing").journal;
        let replay = Replay::from_jsonl(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
        assert_eq!(replay.gap_missed, 0, "journal ring must not lap at this size");
        assert_eq!(replay.config.as_ref().unwrap().shards, shards);
        assert_eq!(replay.journal, live, "replayed journal diverges at {shards} shard(s)");
        assert!(!replay.blocks.is_empty(), "block-trace records must ship in the JSONL");
        let tl = replay.timelines();
        assert_eq!(tl.len(), data.test.len());
        // Every session drains; only sessions long enough to fill at
        // least one raw block appear in a BlockSpan (the close-path
        // flush of a final partial block is deliberately untraced).
        assert!(tl.iter().all(|t| t.latency().is_some()));
        assert!(
            tl.iter().any(|t| t.blocks > 0),
            "no session participated in a traced block"
        );
        per_session.push(
            tl.iter()
                .map(|t| (t.session, t.kinds.iter().map(|k| k.name()).collect()))
                .collect(),
        );
        std::fs::remove_file(&mpath).ok();
    }
    assert_eq!(per_session[0], per_session[1], "1 vs 2 shards: per-session sequences differ");
    assert_eq!(per_session[0], per_session[2], "1 vs 4 shards: per-session sequences differ");
}

/// Full round trip: a fixed-tick serve writes both a JSONL and a trace;
/// `obs-report`'s replay re-emits the trace from the JSONL alone,
/// byte-identical.  The run also exercises the SLO engine (impossible
/// deadline -> every session misses, alert journaled on the rising edge)
/// without letting it steer (`slo_actions: false`).
#[test]
fn obs_report_replay_round_trips_the_live_trace_bytes() {
    let dims = demo_dims();
    let p = synthetic_params(&dims, 0.25, 3);
    let engine =
        Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
    let data = Dataset::generate(CorpusSpec::standard(29), 0, 0, 6);
    let mpath = temp_path("roundtrip", "jsonl");
    let tpath = temp_path("roundtrip", "json");
    obs::reset_process_metrics();
    obs::set_enabled(true);
    let cfg = StreamServeConfig {
        arrival_rate: 40.0,
        pool_size: 2,
        chunk_frames: 16,
        shards: 2,
        seed: 13,
        metrics_out: Some(mpath.clone()),
        trace_out: Some(tpath.clone()),
        slo: Some(SloConfig {
            fast_window: 2,
            slow_window: 4,
            ..SloConfig::for_target(1e-9, 0.01)
        }),
        slo_actions: false,
        tick_secs: Some(0.002),
    };
    let r = stream_serve(engine, &data.test, &cfg).unwrap();
    obs::set_enabled(false);

    let slo = r.slo.expect("slo summary missing with --slo-target");
    assert_eq!(slo.total, 6);
    assert_eq!(slo.misses, 6, "1 ns deadline: every session misses");
    assert!(slo.alerts >= 1, "sustained misses must fire a burn-rate alert");
    let journal = &r.obs.as_ref().unwrap().journal;
    assert!(
        journal.iter().any(|e| e.kind == EventKind::SloAlert && e.shard == NO_SHARD),
        "rising edge must be journaled as slo_alert"
    );

    let replay = Replay::from_jsonl(&std::fs::read_to_string(&mpath).unwrap()).unwrap();
    assert_eq!(replay.gap_missed, 0);
    let live = std::fs::read_to_string(&tpath).unwrap();
    let re = format!("{}\n", replay.chrome_trace().to_string_compact());
    assert_eq!(live, re, "offline re-emission must match the live --trace-out bytes");
    std::fs::remove_file(&mpath).ok();
    std::fs::remove_file(&tpath).ok();
}

//! Property tests for the multi-stream pool (no artifacts needed):
//! pooled decoding must be **bit-identical** to sequential single-stream
//! decoding in both precisions, for arbitrary utterance lengths and
//! client chunkings, and the pool must survive retire-and-replace churn.

use std::sync::Arc;

use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::prng::Pcg64;
use tracenorm::proplite::check;
use tracenorm::runtime::{ConvDims, ModelDims};
use tracenorm::stream::{synthetic_params, StreamId, StreamPool};
use tracenorm::tensor::Tensor;

/// Small dims so property cases stay fast in debug builds; two GRU
/// layers + two conv layers still exercise every pooled stage.
fn tiny_dims() -> ModelDims {
    ModelDims {
        feat_dim: 8,
        conv: vec![ConvDims { context: 2, dim: 12 }],
        gru_dims: vec![10, 12],
        fc_dim: 14,
        vocab: 29,
        total_stride: 2,
    }
}

fn engine(precision: Precision, seed: u64) -> Arc<Engine> {
    let dims = tiny_dims();
    let params = synthetic_params(&dims, 0.5, seed);
    Arc::new(Engine::from_params(&dims, "partial", &params, precision, 4).unwrap())
}

/// Reference: each utterance decoded alone through the plain engine.
fn solo(eng: &Engine, u: &Tensor) -> (String, Vec<Vec<f32>>) {
    let mut bd = Breakdown::default();
    eng.transcribe(u, &mut bd).unwrap()
}

#[test]
fn prop_pool_of_4_bit_identical_to_sequential() {
    for precision in [Precision::F32, Precision::Int8] {
        check(
            &format!("pool4-bit-identical-{precision:?}"),
            6,
            |rng, size| {
                // four utterances of ragged lengths, each with its own
                // client chunk size (in frames)
                let utts: Vec<Tensor> = (0..4)
                    .map(|_| Tensor::randn(&[2 + rng.below(10 + size), 8], 0.7, rng))
                    .collect();
                let chunks: Vec<usize> = (0..4).map(|_| 1 + rng.below(5)).collect();
                (utts, chunks)
            },
            |(utts, chunks)| {
                let eng = engine(precision, 9);
                let refs: Vec<(String, Vec<Vec<f32>>)> =
                    utts.iter().map(|u| solo(&eng, u)).collect();

                let mut pool = StreamPool::new(eng.clone(), 4);
                let ids: Vec<StreamId> = (0..4).map(|_| pool.open().unwrap()).collect();
                let mut off = [0usize; 4];
                let mut got: Vec<Option<(String, Vec<Vec<f32>>)>> = vec![None, None, None, None];
                let mut bd = Breakdown::default();
                let mut done = 0;
                while done < 4 {
                    // round-robin interleaved pushes with per-stream
                    // chunking, pumping between rounds so streams advance
                    // at genuinely mixed batch sizes
                    for i in 0..4 {
                        if got[i].is_some() {
                            continue;
                        }
                        let data = utts[i].data();
                        let end = (off[i] + chunks[i] * 8).min(data.len());
                        if off[i] < end {
                            pool.push_frames(ids[i], &data[off[i]..end]).unwrap();
                            off[i] = end;
                        }
                        if off[i] >= data.len() {
                            let closed = pool.close(ids[i], &mut bd).unwrap();
                            got[i] = Some((closed.transcript, closed.logprob_rows));
                            done += 1;
                        }
                    }
                    pool.pump(&mut bd).unwrap();
                }

                refs.iter().zip(&got).all(|(r, g)| {
                    let g = g.as_ref().unwrap();
                    r.0 == g.0
                        && r.1.len() == g.1.len()
                        && r.1.iter().zip(&g.1).all(|(a, b)| a == b) // bit-exact f32
                })
            },
        );
    }
}

#[test]
fn churn_retire_and_replace_keeps_streams_independent() {
    for precision in [Precision::F32, Precision::Int8] {
        let eng = engine(precision, 11);
        let mut rng = Pcg64::seeded(5);
        let utts: Vec<Tensor> =
            (0..10).map(|_| Tensor::randn(&[4 + rng.below(14), 8], 0.6, &mut rng)).collect();
        let refs: Vec<String> = utts.iter().map(|u| solo(&eng, u).0).collect();

        let mut pool = StreamPool::new(eng.clone(), 4);
        let mut active: Vec<(StreamId, usize, usize)> = Vec::new(); // (id, utt, offset)
        let mut next = 0usize;
        let mut bd = Breakdown::default();
        let mut finished = 0usize;
        while finished < utts.len() {
            // replace retired streams immediately — the churn under test
            while next < utts.len() && !pool.is_full() {
                active.push((pool.open().unwrap(), next, 0));
                next += 1;
            }
            for (id, utt, off) in &mut active {
                let data = utts[*utt].data();
                let end = (*off + 3 * 8).min(data.len());
                if *off < end {
                    pool.push_frames(*id, &data[*off..end]).unwrap();
                    *off = end;
                }
            }
            pool.pump(&mut bd).unwrap();
            let mut i = 0;
            while i < active.len() {
                let (id, utt, off) = active[i];
                if off >= utts[utt].data().len() {
                    // partial transcript is always a prefix of the final
                    let partial = pool.transcript(id).unwrap();
                    let closed = pool.close(id, &mut bd).unwrap();
                    assert!(
                        closed.transcript.starts_with(&partial),
                        "partial {partial:?} not a prefix of {:?}",
                        closed.transcript
                    );
                    assert_eq!(
                        closed.transcript, refs[utt],
                        "utterance {utt} transcript diverged under churn ({precision:?})"
                    );
                    active.swap_remove(i);
                    finished += 1;
                } else {
                    i += 1;
                }
            }
        }
        assert_eq!(pool.stats.opened, 10);
        assert_eq!(pool.stats.closed, 10);
        assert!(pool.stats.mean_rec_batch() > 1.0, "churn should still pool streams");
        assert_eq!(pool.active(), 0);
    }
}

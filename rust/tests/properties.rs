//! Cross-module property tests (proplite harness; no artifacts needed).

use tracenorm::data::{labels_to_text, text_to_labels, CorpusSpec, Dataset};
use tracenorm::jsonx::Json;
use tracenorm::kernels::{
    all_backends, gemm_f32, qgemm4_farm, qgemm_farm, qgemm_farm_rows, qgemm_lowp, qgemm_ref,
    GemmBackend, PackedGatePanels, PackedQ4Matrix, PackedQMatrix, PreparedQMatrix, KC, NR,
};
use tracenorm::linalg::{nu_from_singular_values, svd};
use tracenorm::model::{magnitude_masks, mask_density, ParamSet};
use tracenorm::prng::Pcg64;
use tracenorm::proplite::check;
use tracenorm::quant::{
    dequantize, qgemm4_abs_error_bound, qgemm_abs_error_bound, quantize, quantize4, quantize_into,
    QMatrix, Q4_GROUP,
};
use tracenorm::tensor::{Tensor, TensorI8};

fn rand_tensor(rng: &mut Pcg64, m: usize, n: usize, scale: f32) -> Tensor {
    Tensor::randn(&[m.max(1), n.max(1)], scale, rng)
}

#[test]
fn prop_svd_reconstructs_any_matrix() {
    check(
        "svd-reconstruct",
        40,
        |rng, size| {
            let m = 1 + rng.below(size + 2);
            let n = 1 + rng.below(size + 2);
            let scale = 1.0 + rng.uniform() as f32 * 10.0;
            rand_tensor(rng, m, n, scale)
        },
        |w| {
            let s = svd(w).unwrap();
            let rec = s.reconstruct(s.s.len());
            w.max_abs_diff(&rec) < 1e-2 * (1.0 + w.abs_max())
        },
    );
}

#[test]
fn prop_svd_values_sorted_nonnegative() {
    check(
        "svd-sorted",
        30,
        |rng, size| {
            let (m, n) = (1 + rng.below(size + 3), 1 + rng.below(size + 3));
            rand_tensor(rng, m, n, 1.0)
        },
        |w| {
            let s = svd(w).unwrap();
            s.s.windows(2).all(|p| p[0] >= p[1] - 1e-5) && s.s.iter().all(|&x| x >= 0.0)
        },
    );
}

#[test]
fn prop_nu_in_unit_interval() {
    check(
        "nu-bounds",
        50,
        |rng, size| {
            let d = 2 + rng.below(size + 2);
            let mut s: Vec<f32> = (0..d).map(|_| rng.uniform() as f32 + 1e-4).collect();
            s.sort_by(|a, b| b.partial_cmp(a).unwrap());
            s
        },
        |s| {
            let nu = nu_from_singular_values(s).unwrap();
            (-1e-5..=1.0 + 1e-5).contains(&nu)
        },
    );
}

#[test]
fn prop_quantize_roundtrip_within_half_step() {
    check(
        "quant-halfstep",
        50,
        |rng, size| {
            let (m, n) = (1 + rng.below(size + 4), 1 + rng.below(size + 4));
            rand_tensor(rng, m, n, 0.5)
        },
        |w| {
            let q = quantize(w);
            let deq = dequantize(&q);
            w.max_abs_diff(&deq) <= q.scale * 0.5 + 1e-6
        },
    );
}

#[test]
fn prop_farm_lowp_ref_identical() {
    check(
        "qgemm-agreement",
        25,
        |rng, size| {
            let m = 1 + rng.below(8);
            let n = 1 + rng.below(size * 8 + 8);
            let k = 1 + rng.below(size * 16 + 8);
            let mk =
                |rng: &mut Pcg64, r: usize, c: usize| {
                    TensorI8::new(
                        &[r, c],
                        (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect(),
                    )
                    .unwrap()
                };
            let x = mk(rng, m, k);
            let w = mk(rng, n, k);
            (x, w)
        },
        |(x, w)| {
            let a = qgemm_farm(x, w, 0.013, 0.027);
            let b = qgemm_lowp(x, w, 0.013, 0.027);
            let c = qgemm_ref(x, w, 0.013, 0.027);
            a == b && b == c
        },
    );
}

#[test]
fn prop_packed_qmatrix_roundtrip_lossless() {
    // pack/unpack must be exact for every ragged shape: all n mod NR
    // residues, all interesting k tails — k < 8 (dot_i8's unroll tail),
    // the KC strip boundary ±, multi-strip, and plain odd sizes
    check(
        "packed-qmatrix-roundtrip",
        80,
        |rng, size| {
            let n = 1 + rng.below(4 * NR + size * 4); // sweeps every n % NR
            let k = match rng.below(4) {
                0 => 1 + rng.below(7),                    // k < 8
                1 => KC - 3 + rng.below(7),               // straddles KC
                2 => 2 * KC - 2 + rng.below(5),           // multi-strip tail
                _ => 1 + rng.below(size * 16 + 16),       // generic ragged
            };
            let data: Vec<i8> =
                (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            TensorI8::new(&[n, k], data).unwrap()
        },
        |w| PackedQMatrix::pack(w).unpack() == *w,
    );
}

#[test]
fn prop_gate_panels_roundtrip_lossless() {
    // the gate-interleaved [z|r|h̃] layout must be exact for every
    // stacked (3H, k) gate shape: H = 1, k < 8 tails, the KC strip
    // boundary ±, multi-strip, and generic ragged sizes
    check(
        "gate-panels-roundtrip",
        80,
        |rng, size| {
            let h = 1 + rng.below(size * 4 + 4);
            let k = match rng.below(4) {
                0 => 1 + rng.below(7),              // k < 8
                1 => KC - 3 + rng.below(7),         // straddles KC
                2 => 2 * KC - 2 + rng.below(5),     // multi-strip tail
                _ => 1 + rng.below(size * 16 + 16), // generic ragged
            };
            let data: Vec<i8> =
                (0..3 * h * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            TensorI8::new(&[3 * h, k], data).unwrap()
        },
        |w| PackedGatePanels::pack(w).unpack() == *w,
    );
}

#[test]
fn prop_fused_gates_bit_identical_across_backends() {
    // fused-gate parity as a property: for random stacked gate shapes
    // and per-row scales, every backend's fused entry point reproduces
    // the plain stacked per-row sweep bit for bit
    check(
        "fused-gates-parity",
        20,
        |rng, size| {
            let m = 1 + rng.below(8);
            let h = 1 + rng.below(size * 4 + 4);
            let k = 1 + rng.below(size * 16 + 8);
            let mk = |rng: &mut Pcg64, r: usize, c: usize| {
                TensorI8::new(
                    &[r, c],
                    (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect(),
                )
                .unwrap()
            };
            let x = mk(rng, m, k);
            let w = mk(rng, 3 * h, k);
            let sx: Vec<f32> = (0..m).map(|_| 0.002 + rng.uniform() as f32 * 0.02).collect();
            (x, w, sx)
        },
        |(x, w, sx)| {
            let m = x.rows();
            let prepped = PreparedQMatrix::new_with_gates(QMatrix { q: w.clone(), scale: 0.019 });
            let want = qgemm_farm_rows(x, w, sx, 0.019);
            prepped.gates.is_some()
                && all_backends().iter().all(|(_, be)| {
                    let mut out = Tensor::zeros(&[0, 0]);
                    be.qgemm_gates_rows_into(x.data(), m, &prepped, sx, &mut out);
                    out == want
                })
        },
    );
}

#[test]
fn prop_all_backends_bit_identical_on_int8() {
    // the parity contract as a property: for random ragged shapes and
    // scales, every registered backend reproduces qgemm_ref bit for bit
    // through both the uniform-scale and per-row-scale entry points
    check(
        "backend-parity",
        20,
        |rng, size| {
            let m = 1 + rng.below(8);
            let n = 1 + rng.below(size * 8 + 8);
            let k = 1 + rng.below(size * 16 + 8);
            let mk = |rng: &mut Pcg64, r: usize, c: usize| {
                TensorI8::new(
                    &[r, c],
                    (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect(),
                )
                .unwrap()
            };
            let x = mk(rng, m, k);
            let w = mk(rng, n, k);
            let sx: Vec<f32> = (0..m).map(|_| 0.002 + rng.uniform() as f32 * 0.02).collect();
            (x, w, sx)
        },
        |(x, w, sx)| {
            let m = x.rows();
            let prepped = PreparedQMatrix::new(QMatrix { q: w.clone(), scale: 0.019 });
            let want = qgemm_ref(x, w, 0.007, 0.019);
            let want_rows = qgemm_farm_rows(x, w, sx, 0.019);
            all_backends().iter().all(|(_, be)| {
                let mut out = Tensor::zeros(&[0, 0]);
                be.qgemm_farm_into(x.data(), m, &prepped, 0.007, &mut out);
                let mut rows = Tensor::zeros(&[0, 0]);
                be.qgemm_farm_rows_into(x.data(), m, &prepped, sx, &mut rows);
                out == want && rows == want_rows
            })
        },
    );
}

#[test]
fn prop_qgemm_within_analytic_bound_of_f32_gemm() {
    // quantize real f32 operands the way the embedded engine does
    // (per-tensor weights, per-call activations), run the int8 farm
    // kernel, and assert every output element stays within the analytic
    // worst-case error bound of the f32 reference GEMM
    // (quant::qgemm_abs_error_bound) across random shapes and scales.
    check(
        "qgemm-analytic-bound",
        30,
        |rng, size| {
            let m = 1 + rng.below(6);
            let n = 1 + rng.below(size * 6 + 6);
            let k = 1 + rng.below(size * 12 + 8);
            let sx = 0.2 + rng.uniform() as f32 * 2.0;
            let sw = 0.1 + rng.uniform() as f32;
            (Tensor::randn(&[m, k], sx, rng), Tensor::randn(&[n, k], sw, rng))
        },
        |(x, w)| {
            let (m, k) = (x.rows(), x.cols());
            let qw = quantize(w);
            let mut xq = vec![0i8; m * k];
            let sx = quantize_into(x.data(), &mut xq);
            let xq = TensorI8::new(&[m, k], xq).unwrap();
            let y = qgemm_farm(&xq, &qw.q, sx, qw.scale);
            let yref = gemm_f32(x, w, None);
            let bound = qgemm_abs_error_bound(k, sx, qw.scale);
            y.data()
                .iter()
                .zip(yref.data())
                .all(|(a, b)| (a - b).abs() <= bound)
        },
    );
}

#[test]
fn prop_packed_q4_roundtrip_lossless() {
    // the nibble-panel pack/unpack must be exact for every ragged int4
    // shape: odd k (the half-byte tail), k below one scale group, the
    // group boundary ±, multi-group strips, and every n mod NR residue
    check(
        "packed-q4-roundtrip",
        80,
        |rng, size| {
            let n = 1 + rng.below(4 * NR + size * 4); // sweeps every n % NR
            let k = match rng.below(4) {
                0 => 1 + rng.below(7),                // k < 8, incl. odd half-byte tails
                1 => Q4_GROUP - 3 + rng.below(7),     // straddles the scale group
                2 => 2 * Q4_GROUP - 2 + rng.below(5), // multi-group tail
                _ => 1 + rng.below(size * 16 + 16),   // generic ragged
            };
            quantize4(&rand_tensor(rng, n, k, 0.5))
        },
        |q| PackedQ4Matrix::pack(q).unpack() == *q,
    );
}

#[test]
fn prop_qgemm4_within_analytic_bound_of_f32_gemm() {
    // per-group int4 quantization the way the engine does it (group
    // scales on weights, per-call activation scale), run the scalar int4
    // farm kernel, and assert every output element stays within the
    // analytic worst-case bound of the f32 reference GEMM
    // (quant::qgemm4_abs_error_bound, evaluated at the largest group
    // scale) across random shapes and scales.
    check(
        "qgemm4-analytic-bound",
        30,
        |rng, size| {
            let m = 1 + rng.below(6);
            let n = 1 + rng.below(size * 6 + 6);
            let k = 1 + rng.below(size * 12 + 8);
            let sx = 0.2 + rng.uniform() as f32 * 2.0;
            let sw = 0.1 + rng.uniform() as f32;
            (Tensor::randn(&[m, k], sx, rng), Tensor::randn(&[n, k], sw, rng))
        },
        |(x, w)| {
            let (m, k) = (x.rows(), x.cols());
            let qw = quantize4(w);
            let mut xq = vec![0i8; m * k];
            let sx = quantize_into(x.data(), &mut xq);
            let xq = TensorI8::new(&[m, k], xq).unwrap();
            let y = qgemm4_farm(&xq, &qw, sx);
            let yref = gemm_f32(x, w, None);
            let sw_max = qw.scales().iter().fold(0.0f32, |a, &s| a.max(s));
            let bound = qgemm4_abs_error_bound(k, sx, sw_max);
            y.data()
                .iter()
                .zip(yref.data())
                .all(|(a, b)| (a - b).abs() <= bound)
        },
    );
}

#[test]
fn prop_text_labels_roundtrip() {
    check(
        "labels-roundtrip",
        60,
        |rng, size| {
            let n = rng.below(size + 3);
            let chars: Vec<char> = (0..n)
                .map(|_| match rng.below(28) {
                    0 => ' ',
                    1 => '\'',
                    k => (b'a' + (k - 2) as u8) as char,
                })
                .collect();
            chars.into_iter().collect::<String>()
        },
        |text| labels_to_text(&text_to_labels(text)) == *text,
    );
}

#[test]
fn prop_mask_density_matches_requested_sparsity() {
    check(
        "mask-density",
        20,
        |rng, size| {
            let mut p = ParamSet::new();
            p.set(
                "fc_w",
                rand_tensor(rng, 8 + size * 4, 8 + size * 2, 1.0),
            );
            let sparsity = 0.1 + 0.8 * rng.uniform();
            (p, sparsity)
        },
        |(p, sparsity)| {
            let masks = magnitude_masks(p, *sparsity).unwrap();
            (mask_density(&masks) - (1.0 - sparsity)).abs() < 0.05
        },
    );
}

#[test]
fn prop_json_roundtrip_numbers_strings() {
    check(
        "json-roundtrip",
        60,
        |rng, size| {
            let n = rng.below(size + 2) + 1;
            let vals: Vec<Json> = (0..n)
                .map(|i| match i % 3 {
                    0 => Json::Num((rng.normal() * 1e3).round()),
                    1 => Json::Str(format!("s{}", rng.below(1000))),
                    _ => Json::Bool(rng.below(2) == 0),
                })
                .collect();
            Json::Arr(vals)
        },
        |v| Json::parse(&v.to_string_pretty()).unwrap() == *v,
    );
}

#[test]
fn prop_corpus_ctc_feasible() {
    // every generated utterance must satisfy the CTC feasibility bound
    // after the frontend stride: T' >= L + repeats
    check(
        "corpus-ctc-feasible",
        6,
        |rng, _| Dataset::generate(CorpusSpec::standard(rng.next_u64()), 12, 0, 0),
        |ds| {
            ds.train.iter().all(|u| {
                let t_out = u.feats.shape()[0] / 4; // wsj_mini stride
                let repeats = u
                    .labels
                    .windows(2)
                    .filter(|w| w[0] == w[1])
                    .count();
                t_out >= u.labels.len() + repeats
            })
        },
    );
}

//! Dense row-major tensors — the crate's numeric substrate.
//!
//! Deliberately small: shapes are `Vec<usize>`, storage is a flat
//! `Vec<f32>` (or `Vec<i8>` for [`TensorI8`]). Heavy GEMMs live in
//! [`crate::kernels`]; this module provides construction, views, reshapes
//! and the light element-wise operations used by the trainer, decoder and
//! linalg.

use crate::error::{Error, Result};
use crate::prng::Pcg64;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// An empty (0, 0) matrix — the initial state of scratch-arena buffers,
/// which take their real shape on first [`Tensor::reset`].
impl Default for Tensor {
    fn default() -> Tensor {
        Tensor::zeros(&[0, 0])
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], data }
    }

    /// Glorot-uniform init for a (fan_out, fan_in) weight matrix.
    pub fn glorot(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let mut t = Tensor::zeros(&[rows, cols]);
        rng.fill_glorot(&mut t.data, cols, rows);
        t
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg64) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place to `shape`, reusing the backing allocation: the
    /// data vector is resized (new elements zeroed, surviving prefix
    /// kept) and the shape is overwritten without reallocating.  This is
    /// the scratch-arena primitive ([`crate::infer`]): once a buffer has
    /// seen its steady-state shape, later `reset` calls perform **no**
    /// heap allocation.  Callers are expected to overwrite the contents.
    pub fn reset(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        self.data.resize(n, 0.0);
        self.shape.clear();
        self.shape.extend_from_slice(shape);
    }

    /// Capacity of the backing allocation in elements (allocation
    /// accounting for the scratch-arena footprint counters).
    pub fn capacity(&self) -> usize {
        self.data.capacity()
    }

    /// 2-D accessors (most weights are matrices).
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2, "rows() on rank-{} tensor", self.rank());
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2, "cols() on rank-{} tensor", self.rank());
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row slice of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} ({} elems) to {:?}",
                self.shape,
                self.data.len(),
                shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Matrix transpose (rank 2).
    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    /// Plain triple-loop matmul: `self (m,k) @ other (k,n)`. Reference
    /// implementation — the optimized path is `kernels::gemm_f32`.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        if k != k2 {
            return Err(Error::Shape(format!(
                "matmul {:?} x {:?}",
                self.shape, other.shape
            )));
        }
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Ok(out)
    }

    /// Concatenate rank-2 tensors along axis 0 (rows).
    pub fn concat_rows(parts: &[&Tensor]) -> Result<Tensor> {
        let cols = parts[0].cols();
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            if p.cols() != cols {
                return Err(Error::Shape("concat_rows: col mismatch".into()));
            }
            rows += p.rows();
            data.extend_from_slice(&p.data);
        }
        Tensor::new(&[rows, cols], data)
    }

    /// Split a rank-2 tensor into equal row blocks.
    pub fn split_rows(&self, parts: usize) -> Result<Vec<Tensor>> {
        let m = self.rows();
        if m % parts != 0 {
            return Err(Error::Shape(format!("split_rows: {m} rows into {parts}")));
        }
        let rows = m / parts;
        let c = self.cols();
        Ok((0..parts)
            .map(|p| {
                Tensor::new(
                    &[rows, c],
                    self.data[p * rows * c..(p + 1) * rows * c].to_vec(),
                )
                .unwrap()
            })
            .collect())
    }

    pub fn scale(&mut self, s: f32) -> &mut Self {
        for v in &mut self.data {
            *v *= s;
        }
        self
    }

    pub fn add_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape("add_assign shape mismatch".into()));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(())
    }

    pub fn mul_assign(&mut self, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape("mul_assign shape mismatch".into()));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
        Ok(())
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// Dense row-major int8 tensor (quantized weights/activations).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI8 {
    shape: Vec<usize>,
    data: Vec<i8>,
}

impl TensorI8 {
    pub fn new(shape: &[usize], data: Vec<i8>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "i8 shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(TensorI8 { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        TensorI8 { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i8] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[i8] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::seeded(0);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn concat_split_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        let a = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let c = Tensor::concat_rows(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[8, 3]);
        let parts = c.split_rows(2).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn reshape_checks_elements() {
        let t = Tensor::zeros(&[2, 6]);
        assert!(t.clone().reshape(&[3, 4]).is_ok());
        assert!(t.reshape(&[5, 2]).is_err());
    }

    #[test]
    fn frob_norm() {
        let t = Tensor::new(&[2, 2], vec![3., 0., 0., 4.]).unwrap();
        assert!((t.frob_norm() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn reset_reshapes_without_growing_within_capacity() {
        let mut t = Tensor::zeros(&[4, 8]);
        let cap = t.capacity();
        t.reset(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        t.reset(&[4, 8]);
        assert_eq!(t.shape(), &[4, 8]);
        assert_eq!(t.capacity(), cap, "shrink-then-grow must reuse the allocation");
    }
}

//! PCG64-based pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this is a first-party
//! implementation of the PCG XSL-RR 128/64 generator (O'Neill 2014) plus
//! the sampling helpers the corpus generator and initializers need.
//! Deterministic: every experiment seeds its own stream, so runs are
//! exactly reproducible.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotated output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Per-shard child generator for the sharded serving runtime
    /// (DESIGN.md §9): both the seed and the PCG stream are perturbed by
    /// the shard id, so the N shards' arrival processes are mutually
    /// uncorrelated while staying exactly reproducible from the single
    /// root seed.  Shard 0 reproduces [`Pcg64::seeded`] bit-for-bit,
    /// which is what keeps `--shards 1` serving on the historical
    /// arrival schedule.
    pub fn shard_seeded(root: u64, shard: u64) -> Self {
        Self::new(
            root ^ shard.wrapping_mul(0x9e37_79b9_7f4a_7c15),
            0xda3e_39cb_94b9_5bdb ^ shard.wrapping_mul(0xbf58_476d_1ce4_e5b9),
        )
    }

    /// Derive an independent child stream (for per-utterance determinism).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiasedness.
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached second draw dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill with i.i.d. N(0, std²).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Glorot-uniform [-lim, lim] with lim = sqrt(6 / (fan_in + fan_out)).
    /// Matches the Python-side initializer family (values differ — stage-1
    /// training always starts from Rust-initialized params).
    pub fn fill_glorot(&mut self, out: &mut [f32], fan_in: usize, fan_out: usize) {
        let lim = (6.0 / (fan_in + fan_out) as f64).sqrt();
        for v in out.iter_mut() {
            *v = self.uniform_in(-lim, lim) as f32;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn shard_zero_matches_root_stream() {
        let mut root = Pcg64::seeded(17);
        let mut s0 = Pcg64::shard_seeded(17, 0);
        for _ in 0..64 {
            assert_eq!(root.next_u64(), s0.next_u64());
        }
    }

    #[test]
    fn shard_streams_are_distinct() {
        let mut a = Pcg64::shard_seeded(17, 1);
        let mut b = Pcg64::shard_seeded(17, 2);
        let mut root = Pcg64::seeded(17);
        let (xa, xb, xr) = (a.next_u64(), b.next_u64(), root.next_u64());
        assert_ne!(xa, xb);
        assert_ne!(xa, xr);
        assert_ne!(xb, xr);
        // and reproducible: the same (root, shard) pair replays exactly
        let mut a2 = Pcg64::shard_seeded(17, 1);
        assert_eq!(a2.next_u64(), xa);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seeded(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::seeded(7);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5)] += 1;
        }
        for c in counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "frac {frac}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_sampling_respects_weights() {
        let mut rng = Pcg64::seeded(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}

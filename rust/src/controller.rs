//! Admission/fidelity controller for adaptive-fidelity serving
//! (DESIGN.md §8).
//!
//! The rank ladder turns the paper's Figure-1 accuracy-vs-parameters
//! curve into a runtime knob: tier 0 is the highest-rank (highest
//! fidelity) variant, higher tiers are progressively cheaper SVD
//! truncations.  The [`FidelityController`] maps live serving telemetry
//! to the tier **new** streams are admitted at — already-open sessions
//! are never migrated (a mid-utterance hidden state is meaningless under
//! different weights).
//!
//! Control rule (hysteresis; see the DESIGN.md §8 table):
//!
//! * **downshift pressure** — the currently-routed tier's windowed p99
//!   session latency breaches `target_p99`, *or* its pool occupancy is at
//!   or above `high_water`.  After `breach_ticks` consecutive pressured
//!   observations the controller routes new streams one tier down the
//!   ladder.
//! * **upshift clearance** — occupancy at or below `low_water` (the load
//!   has drained) and no latency breach.  After `clear_ticks` consecutive
//!   clear observations the controller moves one tier back up.
//! * anything in between is the dead band: both dwell counters reset, the
//!   tier holds.  `low_water < high_water` plus the two dwell counts is
//!   what prevents flapping when load sits near a threshold.
//!
//! The controller is deliberately pure state-machine — latencies and
//! occupancy are *injected* ([`FidelityController::record_latency`] /
//! [`FidelityController::observe`]), so unit tests drive it without a
//! clock and [`crate::serve::ladder_serve`] drives it from measured
//! wall-clock serving.

use std::collections::VecDeque;

use crate::error::{Error, Result};

/// Tuning for the [`FidelityController`].
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// windowed-p99 session latency (seconds) above which the routed
    /// tier counts as pressured
    pub target_p99: f64,
    /// pool occupancy fraction at/above which the routed tier counts as
    /// pressured (a leading indicator: a full pool queues admissions)
    pub high_water: f64,
    /// occupancy fraction at/below which the load counts as drained
    pub low_water: f64,
    /// consecutive pressured observations before a downshift
    pub breach_ticks: usize,
    /// consecutive clear observations before an upshift
    pub clear_ticks: usize,
    /// rolling latency samples kept per tier for the p99 estimate
    pub window: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            target_p99: 0.25,
            high_water: 0.95,
            low_water: 0.5,
            breach_ticks: 3,
            clear_ticks: 6,
            window: 64,
        }
    }
}

/// One fidelity shift, for the serving report.
#[derive(Clone, Copy, Debug)]
pub struct ShiftEvent {
    /// simulated clock at the shift
    pub clock: f64,
    /// tier new streams are routed to from now on
    pub tier: usize,
    /// true = downshift (lower fidelity), false = upshift
    pub down: bool,
    /// which worker shard's controller shifted (0 for an unsharded
    /// serve) — the sharded runtime merges every shard's shifts into one
    /// clock-ordered log (DESIGN.md §9)
    pub shard: usize,
}

/// Routes new streams to a fidelity tier based on injected telemetry.
///
/// When a cascade is active ([`FidelityController::set_cascade_knob`])
/// the controller gains a second, cheaper actuator: before spending a
/// pressure dwell on an admission-tier downshift it halves the cascade
/// escalation threshold (fewer blocks re-run on the high rung — an
/// immediate FLOPs cut that degrades only low-confidence frames), and on
/// drain it restores the threshold toward its base before upshifting
/// tiers.  Tier shifts only happen once the threshold governor is
/// exhausted, so cascade serving sheds load in finer steps than the
/// ladder alone.
#[derive(Debug)]
pub struct FidelityController {
    cfg: ControllerConfig,
    tiers: usize,
    /// shard label stamped on this controller's shift events
    shard: usize,
    current: usize,
    /// rolling latency window per tier
    windows: Vec<VecDeque<f64>>,
    pressure: usize,
    clear: usize,
    pub downshifts: u64,
    pub upshifts: u64,
    shifts: Vec<ShiftEvent>,
    /// configured cascade escalation threshold (None = no cascade knob)
    cascade_base: Option<f64>,
    /// governor floor: the threshold is never cut below base/8
    cascade_floor: f64,
    /// live threshold value the serve loop propagates to its pools
    cascade_current: f64,
    /// threshold halvings taken under pressure (report counter)
    pub threshold_cuts: u64,
    /// threshold doublings taken on drain (report counter)
    pub threshold_restores: u64,
}

impl FidelityController {
    /// `tiers` is the ladder depth (tier 0 = highest fidelity).
    pub fn new(tiers: usize, cfg: ControllerConfig) -> Result<FidelityController> {
        FidelityController::for_shard(tiers, cfg, 0)
    }

    /// A controller owned by worker shard `shard` of a sharded ladder
    /// serve: hysteresis state is fully per-shard (each shard reacts to
    /// its own pools' latency/occupancy), and shift events carry the
    /// shard id so the merged shift log stays attributable.
    pub fn for_shard(
        tiers: usize,
        cfg: ControllerConfig,
        shard: usize,
    ) -> Result<FidelityController> {
        if tiers == 0 {
            return Err(Error::Config("controller needs at least one tier".into()));
        }
        if !(cfg.low_water < cfg.high_water && cfg.high_water <= 1.0 && cfg.low_water >= 0.0) {
            return Err(Error::Config(format!(
                "controller water marks must satisfy 0 <= low {} < high {} <= 1",
                cfg.low_water, cfg.high_water
            )));
        }
        if cfg.target_p99 <= 0.0 || cfg.breach_ticks == 0 || cfg.clear_ticks == 0 || cfg.window == 0
        {
            return Err(Error::Config(
                "controller target_p99, dwell ticks and window must be positive".into(),
            ));
        }
        Ok(FidelityController {
            windows: (0..tiers).map(|_| VecDeque::with_capacity(cfg.window)).collect(),
            cfg,
            tiers,
            shard,
            current: 0,
            pressure: 0,
            clear: 0,
            downshifts: 0,
            upshifts: 0,
            shifts: Vec::new(),
            cascade_base: None,
            cascade_floor: 0.0,
            cascade_current: 0.0,
            threshold_cuts: 0,
            threshold_restores: 0,
        })
    }

    /// Arm the escalation-threshold governor with the serve's configured
    /// `--escalate-threshold` as its base.  Until this is called the
    /// controller behaves exactly as before the cascade existed.
    pub fn set_cascade_knob(&mut self, base: f64) {
        self.cascade_base = Some(base);
        self.cascade_floor = base / 8.0;
        self.cascade_current = base;
    }

    /// Live escalation threshold the serve loop should hand its pools
    /// this tick (None when no cascade knob is armed).
    pub fn escalation_threshold(&self) -> Option<f64> {
        self.cascade_base.map(|_| self.cascade_current)
    }

    /// Tier new streams should be admitted at right now.
    pub fn tier(&self) -> usize {
        self.current
    }

    pub fn num_tiers(&self) -> usize {
        self.tiers
    }

    /// Record one completed session's latency at the tier that served it.
    pub fn record_latency(&mut self, tier: usize, secs: f64) {
        let w = &mut self.windows[tier];
        if w.len() == self.cfg.window {
            w.pop_front();
        }
        w.push_back(secs);
    }

    /// Nearest-rank p99 over the tier's rolling window (None if empty).
    pub fn windowed_p99(&self, tier: usize) -> Option<f64> {
        let w = &self.windows[tier];
        if w.is_empty() {
            return None;
        }
        let mut v: Vec<f64> = w.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let idx = ((v.len() as f64 - 1.0) * 0.99).round() as usize;
        Some(v[idx.min(v.len() - 1)])
    }

    /// One control tick: evaluate the routed tier against the latency
    /// target and the water marks, advance the hysteresis counters, and
    /// shift at most one rung.  `occupancy_frac` is the routed tier's
    /// pool occupancy (0 when the server is idle).  Returns the shift if
    /// one happened.
    pub fn observe(&mut self, clock: f64, occupancy_frac: f64) -> Option<ShiftEvent> {
        self.observe_with_pressure(clock, occupancy_frac, false)
    }

    /// [`FidelityController::observe`] with an external pressure input:
    /// when `extra_pressure` is true (the SLO burn-rate engine breaching
    /// under `--slo-actions on`), the tick counts as pressured even if
    /// latency and occupancy look fine, and the drain path is blocked —
    /// an active SLO burn must never upshift.  With `extra_pressure`
    /// false this is exactly `observe`, so the default-off SLO wiring
    /// changes nothing.
    pub fn observe_with_pressure(
        &mut self,
        clock: f64,
        occupancy_frac: f64,
        extra_pressure: bool,
    ) -> Option<ShiftEvent> {
        let p99 = self.windowed_p99(self.current);
        let breached = p99.is_some_and(|p| p > self.cfg.target_p99);
        let pressured = breached || occupancy_frac >= self.cfg.high_water || extra_pressure;
        let drained = occupancy_frac <= self.cfg.low_water && !extra_pressure;
        if pressured {
            self.clear = 0;
            self.pressure = self.pressure.saturating_add(1);
            if self.pressure >= self.cfg.breach_ticks {
                // the threshold governor absorbs pressure first: halving
                // the escalation threshold cuts high-rung re-runs now,
                // without moving any session's admission tier
                if self.cascade_base.is_some() && self.cascade_current > self.cascade_floor {
                    self.pressure = 0;
                    self.cascade_current = (self.cascade_current / 2.0).max(self.cascade_floor);
                    self.threshold_cuts += 1;
                    return None;
                }
                if self.current + 1 < self.tiers {
                    self.pressure = 0;
                    self.current += 1;
                    self.downshifts += 1;
                    // the lower tier's history predates this overload; let
                    // it earn fresh samples instead of inheriting stale ones
                    self.windows[self.current].clear();
                    let ev =
                        ShiftEvent { clock, tier: self.current, down: true, shard: self.shard };
                    self.shifts.push(ev);
                    return Some(ev);
                }
            }
        } else if drained {
            self.pressure = 0;
            self.clear = self.clear.saturating_add(1);
            if self.clear >= self.cfg.clear_ticks {
                // undo threshold cuts before upshifting tiers: restoring
                // escalation fidelity is the cheaper recovery step
                if let Some(base) = self.cascade_base {
                    if self.cascade_current < base {
                        self.clear = 0;
                        self.cascade_current = (self.cascade_current * 2.0).min(base);
                        self.threshold_restores += 1;
                        return None;
                    }
                }
                if self.current > 0 {
                    self.clear = 0;
                    self.current -= 1;
                    self.upshifts += 1;
                    // stale breached samples from the overload era must not
                    // immediately re-trigger a downshift
                    self.windows[self.current].clear();
                    let ev =
                        ShiftEvent { clock, tier: self.current, down: false, shard: self.shard };
                    self.shifts.push(ev);
                    return Some(ev);
                }
            }
        } else {
            // dead band: hold, reset both dwell counters
            self.pressure = 0;
            self.clear = 0;
        }
        None
    }

    /// All shifts so far, in order.
    pub fn shifts(&self) -> &[ShiftEvent] {
        &self.shifts
    }
}

/// Merge per-shard shift logs into one clock-ordered log — the "shared
/// shift log" of the sharded ladder serve.  The sort is stable, so
/// same-clock shifts keep shard order.
///
/// With `--obs on` the flight-recorder journal
/// ([`crate::obs::journal`]) records the same shifts (as
/// `downshift`/`upshift` events, interleaved with the full
/// admission/placement/drain record) under the same stable clock-order
/// discipline; this narrower log remains the always-on report field.
pub fn merge_shift_logs(per_shard: &[&[ShiftEvent]]) -> Vec<ShiftEvent> {
    let mut all: Vec<ShiftEvent> = per_shard.iter().flat_map(|s| s.iter().copied()).collect();
    all.sort_by(|a, b| a.clock.total_cmp(&b.clock));
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControllerConfig {
        ControllerConfig {
            target_p99: 0.1,
            high_water: 0.9,
            low_water: 0.4,
            breach_ticks: 3,
            clear_ticks: 4,
            window: 16,
        }
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(FidelityController::new(0, cfg()).is_err());
        let mut c = cfg();
        c.low_water = 0.95; // >= high_water
        assert!(FidelityController::new(2, c).is_err());
        let mut c = cfg();
        c.breach_ticks = 0;
        assert!(FidelityController::new(2, c).is_err());
    }

    #[test]
    fn occupancy_breach_downshifts_after_dwell() {
        let mut ctl = FidelityController::new(3, cfg()).unwrap();
        assert_eq!(ctl.tier(), 0);
        assert!(ctl.observe(0.0, 1.0).is_none());
        assert!(ctl.observe(0.1, 1.0).is_none());
        let ev = ctl.observe(0.2, 1.0).expect("third pressured tick shifts");
        assert!(ev.down);
        assert_eq!(ctl.tier(), 1);
        // sustained pressure cascades one rung at a time
        for _ in 0..3 {
            ctl.observe(0.3, 1.0);
        }
        assert_eq!(ctl.tier(), 2);
        // bottom of the ladder: pressure can't shift further
        for _ in 0..10 {
            ctl.observe(0.4, 1.0);
        }
        assert_eq!(ctl.tier(), 2);
        assert_eq!(ctl.downshifts, 2);
    }

    #[test]
    fn slo_pressure_downshifts_and_blocks_the_upshift_drain() {
        // healthy latency, mid-band occupancy: without the external input
        // nothing shifts, with it the dwell counter runs to a downshift
        let mut ctl = FidelityController::new(2, cfg()).unwrap();
        for _ in 0..4 {
            assert!(ctl.observe(0.0, 0.6).is_none());
        }
        assert_eq!(ctl.tier(), 0);
        assert!(ctl.observe_with_pressure(0.1, 0.6, true).is_none());
        assert!(ctl.observe_with_pressure(0.2, 0.6, true).is_none());
        let ev = ctl.observe_with_pressure(0.3, 0.6, true).expect("SLO pressure shifts");
        assert!(ev.down);
        assert_eq!(ctl.tier(), 1);
        // drained occupancy would normally upshift after clear_ticks, but
        // an active SLO burn pins the tier down
        for _ in 0..8 {
            assert!(ctl.observe_with_pressure(0.4, 0.1, true).is_none(), "already at bottom");
        }
        assert_eq!(ctl.tier(), 1, "burning SLO must not upshift");
        // once the burn clears, the ordinary drain path resumes
        for _ in 0..4 {
            ctl.observe(0.5, 0.1);
        }
        assert_eq!(ctl.tier(), 0);
        assert_eq!(ctl.upshifts, 1);
    }

    #[test]
    fn extra_pressure_false_is_exactly_observe() {
        let mut a = FidelityController::new(3, cfg()).unwrap();
        let mut b = FidelityController::new(3, cfg()).unwrap();
        let occs = [1.0, 1.0, 1.0, 0.6, 0.1, 0.1, 0.1, 0.1, 0.1, 1.0];
        for (i, &occ) in occs.iter().enumerate() {
            let x = a.observe(i as f64, occ);
            let y = b.observe_with_pressure(i as f64, occ, false);
            assert_eq!(x.map(|e| (e.tier, e.down)), y.map(|e| (e.tier, e.down)));
        }
        assert_eq!(a.tier(), b.tier());
        assert_eq!(a.downshifts, b.downshifts);
    }

    #[test]
    fn latency_breach_downshifts_even_at_low_occupancy() {
        let mut ctl = FidelityController::new(2, cfg()).unwrap();
        for _ in 0..8 {
            ctl.record_latency(0, 0.5); // 5x over target
        }
        // mid-band occupancy so only the p99 breach applies
        for _ in 0..3 {
            ctl.observe(0.0, 0.6);
        }
        assert_eq!(ctl.tier(), 1);
        assert_eq!(ctl.downshifts, 1);
    }

    #[test]
    fn upshifts_when_load_drains_and_clears_stale_window() {
        let mut ctl = FidelityController::new(2, cfg()).unwrap();
        // overload: breached latencies on tier 0, full pool -> downshift
        for _ in 0..8 {
            ctl.record_latency(0, 1.0);
        }
        for _ in 0..3 {
            ctl.observe(0.0, 1.0);
        }
        assert_eq!(ctl.tier(), 1);
        // drain: clear ticks accumulate, then upshift
        for i in 0..3 {
            assert!(ctl.observe(1.0 + i as f64, 0.2).is_none());
        }
        let ev = ctl.observe(5.0, 0.2).expect("fourth clear tick upshifts");
        assert!(!ev.down);
        assert_eq!(ctl.tier(), 0);
        assert_eq!(ctl.upshifts, 1);
        // tier 0's stale breached window was cleared on the way up, so
        // calm traffic does not immediately re-downshift
        for _ in 0..10 {
            assert!(ctl.observe(6.0, 0.2).is_none());
        }
        assert_eq!(ctl.tier(), 0);
        assert_eq!(ctl.shifts().len(), 2);
    }

    #[test]
    fn dead_band_and_alternation_never_shift() {
        let mut ctl = FidelityController::new(2, cfg()).unwrap();
        // mid-band occupancy: neither pressured nor drained
        for _ in 0..50 {
            assert!(ctl.observe(0.0, 0.6).is_none());
        }
        // alternating pressure/drain: dwell counters reset each flip
        for i in 0..50 {
            let occ = if i % 2 == 0 { 1.0 } else { 0.0 };
            assert!(ctl.observe(0.0, occ).is_none(), "alternation must not flap");
        }
        assert_eq!(ctl.tier(), 0);
        assert_eq!(ctl.downshifts + ctl.upshifts, 0);
    }

    #[test]
    fn shard_label_rides_shift_events_and_logs_merge_in_clock_order() {
        let mut a = FidelityController::for_shard(2, cfg(), 0).unwrap();
        let mut b = FidelityController::for_shard(2, cfg(), 1).unwrap();
        for t in 0..3 {
            a.observe(10.0 + t as f64, 1.0);
            b.observe(t as f64, 1.0);
        }
        assert_eq!(a.shifts()[0].shard, 0);
        assert_eq!(b.shifts()[0].shard, 1);
        let merged = merge_shift_logs(&[a.shifts(), b.shifts()]);
        assert_eq!(merged.len(), 2);
        assert_eq!(merged[0].shard, 1, "shard 1 shifted earlier on the clock");
        assert!(merged.windows(2).all(|w| w[0].clock <= w[1].clock));
        // the plain constructor labels shard 0
        let mut c = FidelityController::new(2, cfg()).unwrap();
        for _ in 0..3 {
            c.observe(0.0, 1.0);
        }
        assert_eq!(c.shifts()[0].shard, 0);
    }

    #[test]
    fn threshold_governor_absorbs_pressure_before_tier_shifts() {
        let mut ctl = FidelityController::new(2, cfg()).unwrap();
        ctl.set_cascade_knob(4.0);
        assert_eq!(ctl.escalation_threshold(), Some(4.0));
        // each pressure dwell halves the threshold instead of downshifting
        for _ in 0..3 {
            assert!(ctl.observe(0.0, 1.0).is_none());
        }
        assert_eq!(ctl.escalation_threshold(), Some(2.0));
        assert_eq!(ctl.tier(), 0, "threshold cut absorbed the dwell");
        for _ in 0..6 {
            ctl.observe(0.1, 1.0);
        }
        // base/2 -> base/4 -> base/8 floor reached
        assert_eq!(ctl.escalation_threshold(), Some(0.5));
        assert_eq!(ctl.threshold_cuts, 3);
        assert_eq!(ctl.tier(), 0);
        // governor exhausted: the next dwell moves the admission tier
        for _ in 0..3 {
            ctl.observe(0.2, 1.0);
        }
        assert_eq!(ctl.tier(), 1);
        assert_eq!(ctl.downshifts, 1);
        // drain: threshold restores toward base before any upshift
        for _ in 0..4 {
            assert!(ctl.observe(1.0, 0.1).is_none());
        }
        assert_eq!(ctl.escalation_threshold(), Some(1.0));
        assert_eq!(ctl.tier(), 1, "restore happens before the tier moves");
        for _ in 0..8 {
            ctl.observe(2.0, 0.1);
        }
        assert_eq!(ctl.escalation_threshold(), Some(4.0), "restored to base, never past it");
        assert_eq!(ctl.threshold_restores, 3);
        // threshold back at base: the following drain dwell upshifts
        for _ in 0..4 {
            ctl.observe(3.0, 0.1);
        }
        assert_eq!(ctl.tier(), 0);
        assert_eq!(ctl.upshifts, 1);
    }

    #[test]
    fn unarmed_knob_leaves_the_state_machine_untouched() {
        let mut a = FidelityController::new(3, cfg()).unwrap();
        let mut b = FidelityController::new(3, cfg()).unwrap();
        b.set_cascade_knob(0.0); // threshold 0: floor == base, governor is a no-op
        assert_eq!(a.escalation_threshold(), None);
        let occs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1];
        for (i, &occ) in occs.iter().enumerate() {
            let x = a.observe(i as f64, occ);
            let y = b.observe(i as f64, occ);
            assert_eq!(x.map(|e| (e.tier, e.down)), y.map(|e| (e.tier, e.down)));
        }
        assert_eq!(a.tier(), b.tier());
        assert_eq!(b.threshold_cuts + b.threshold_restores, 0);
        assert_eq!(b.escalation_threshold(), Some(0.0));
    }

    #[test]
    fn rolling_window_evicts_old_samples() {
        let mut ctl = FidelityController::new(1, cfg()).unwrap();
        for _ in 0..16 {
            ctl.record_latency(0, 1.0);
        }
        assert!(ctl.windowed_p99(0).unwrap() > 0.9);
        // refill with fast samples; old breached ones age out
        for _ in 0..16 {
            ctl.record_latency(0, 0.01);
        }
        assert!(ctl.windowed_p99(0).unwrap() < 0.1);
    }
}

//! GEMM kernels — the Rust reproduction of the paper's §4 contribution.
//!
//! The paper ships hand-written AArch64 kernels ("farm") that beat
//! gemmlowp by 3–7× at batch sizes 1–4, the regime that dominates
//! on-device streaming ASR (the recurrent GEMM is strictly batch-1; the
//! non-recurrent one batches across ≤ 4 timesteps before latency suffers).
//!
//! Two competing int8 implementations reproduce the *algorithmic* contrast
//! on the host ISA (the 3–7× shape is ISA-independent; see DESIGN.md §3):
//!
//! * [`qgemm_farm`] — the farm strategy: **no packing**. The big weight
//!   matrix streams through cache exactly once per call in its storage
//!   layout; the tiny activation panel (m ≤ 8 rows) stays register/L1
//!   resident. 4-row × m-col register tiles of i32 accumulators.
//! * [`qgemm_lowp`] — the gemmlowp strategy: **pack-compute-unpack**.
//!   Both operands are copied into cache-friendly panel layouts before the
//!   compute pass (amortizes beautifully at large batch, but at batch 1–4
//!   the O(n·k) packing traffic rivals the GEMM itself).
//!
//! Both produce bit-identical i32 accumulations (tested), so Figure 6 is a
//! pure scheduling comparison.  [`gemm_f32`] is the f32 path of the
//! embedded engine.
//!
//! [`qgemm_farm_rows`] is the batch-m **pooled** entry point: the
//! [`crate::stream`] pool lock-steps the recurrent GEMMs of m concurrent
//! utterance streams into one call, with per-row activation scales so the
//! result stays bit-identical to m independent batch-1 calls.
//! [`pooled_rec_counts`]/[`sequential_rec_counts`] expose the op/byte
//! contrast for the roofline projection.

use crate::tensor::{Tensor, TensorI8};

/// Operation/byte accounting for roofline projection (devicesim).
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmCounts {
    /// multiply-accumulate ops
    pub macs: u64,
    /// bytes read from "DRAM" (counting each operand stream once, plus
    /// packing copies where the algorithm makes them)
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl GemmCounts {
    pub fn ops(&self) -> u64 {
        2 * self.macs
    }
}

/// Counts for `y(m,n) = x(m,k) · w(n,k)ᵀ` under the farm schedule.
pub fn farm_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    GemmCounts {
        macs: (m * n * k) as u64,
        // weights streamed once (n·k), activations reused from L1 (m·k),
        // output written once (4·m·n f32)
        bytes_read: (n * k + m * k) as u64,
        bytes_written: (4 * m * n) as u64,
    }
}

/// Counts for the gemmlowp schedule: the pack copies (read + write of
/// both operands) plus the fixed MR-tile padding of the MAC count.
pub fn lowp_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    let mp = m.div_ceil(8) * 8; // LOWP_MR register-tile padding
    GemmCounts {
        macs: (mp * n * k) as u64,
        bytes_read: (2 * (n * k + mp * k)) as u64, // stream + packed re-read
        bytes_written: (n * k + mp * k + 4 * m * n) as u64, // packed copies + output
    }
}

/// Counts for one **pooled** recurrent step: `m` concurrent streams'
/// hidden vectors lock-stepped into a single batch-m farm call
/// ([`qgemm_farm_rows`]).  The weight matrix streams from memory once
/// for all `m` streams — this is the whole point of cross-stream
/// batching (DESIGN.md §6).
pub fn pooled_rec_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    farm_counts(m, n, k)
}

/// Counts for the same work done the pre-pool way: `m` independent
/// batch-1 recurrent GEMMs, each streaming the weight matrix separately.
/// MACs match [`pooled_rec_counts`]; weight traffic is `m×`.
pub fn sequential_rec_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    let one = farm_counts(1, n, k);
    GemmCounts {
        macs: one.macs * m as u64,
        bytes_read: one.bytes_read * m as u64,
        bytes_written: one.bytes_written * m as u64,
    }
}

// ---------------------------------------------------------------------------
// f32 reference/production GEMM: y = x @ wᵀ  (x: (m,k), w: (n,k)).
// Row-dot-row formulation: both operands are walked contiguously.
// ---------------------------------------------------------------------------

#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled to give LLVM independent accumulation chains.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// `y = x @ wᵀ + bias?`, f32. x: (m, k), w: (n, k) -> (m, n).
pub fn gemm_f32(x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "gemm_f32 contraction mismatch");
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let xi = x.row(i);
        let orow = out.row_mut(i);
        for j in 0..n {
            orow[j] = dot_f32(xi, w.row(j));
        }
        if let Some(b) = bias {
            for j in 0..n {
                orow[j] += b[j];
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// farm: small-batch int8 GEMM, no packing.
// ---------------------------------------------------------------------------

#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0, 0, 0);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] as i32 * b[i] as i32 + a[i + 4] as i32 * b[i + 4] as i32;
        s1 += a[i + 1] as i32 * b[i + 1] as i32 + a[i + 5] as i32 * b[i + 5] as i32;
        s2 += a[i + 2] as i32 * b[i + 2] as i32 + a[i + 6] as i32 * b[i + 6] as i32;
        s3 += a[i + 3] as i32 * b[i + 3] as i32 + a[i + 7] as i32 * b[i + 7] as i32;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

/// farm-style quantized GEMM: `y = (sx·xq) (sw·wq)ᵀ`.
///
/// xq: (m, k) — the small activation panel (batch ≤ ~8 in practice);
/// wq: (n, k) — the big weight matrix, streamed once, in storage order.
/// Output tile: 4 weight rows × m activation rows of i32 accumulators
/// live in registers across the whole k extent.
pub fn qgemm_farm(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let (n, k2) = (wq.rows(), wq.cols());
    assert_eq!(k, k2, "qgemm_farm contraction mismatch");
    let scale = sx * sw;
    let mut out = Tensor::zeros(&[m, n]);

    let mut j = 0;
    // 4-row weight tiles: stream w rows j..j+4 against all m x-rows.
    while j + 4 <= n {
        let w0 = wq.row(j);
        let w1 = wq.row(j + 1);
        let w2 = wq.row(j + 2);
        let w3 = wq.row(j + 3);
        for i in 0..m {
            let xi = xq.row(i);
            let (a0, a1, a2, a3) =
                (dot_i8(xi, w0), dot_i8(xi, w1), dot_i8(xi, w2), dot_i8(xi, w3));
            let orow = out.row_mut(i);
            orow[j] = a0 as f32 * scale;
            orow[j + 1] = a1 as f32 * scale;
            orow[j + 2] = a2 as f32 * scale;
            orow[j + 3] = a3 as f32 * scale;
        }
        j += 4;
    }
    while j < n {
        let wj = wq.row(j);
        for i in 0..m {
            out.row_mut(i)[j] = dot_i8(xq.row(i), wj) as f32 * scale;
        }
        j += 1;
    }
    out
}

/// Batch-m farm GEMM with **per-row activation scales** — the pooled
/// recurrent step of the multi-stream engine ([`crate::stream`]).
///
/// Each activation row belongs to a different utterance stream and was
/// quantized independently (`sx[i]` is stream *i*'s dynamic scale), so
/// row *i* dequantizes as `acc · sx[i] · sw`.  The i32 accumulation and
/// the per-row scale product are exactly what `m` separate
/// [`qgemm_farm`] calls at batch 1 would compute, which is what makes
/// pooled decoding bit-identical to sequential decoding while the big
/// weight matrix streams through cache only **once** for all `m`
/// streams (the §4 small-batch sweet spot).
pub fn qgemm_farm_rows(xq: &TensorI8, wq: &TensorI8, sx: &[f32], sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let (n, k2) = (wq.rows(), wq.cols());
    assert_eq!(k, k2, "qgemm_farm_rows contraction mismatch");
    assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
    let scales: Vec<f32> = sx.iter().map(|&s| s * sw).collect();
    let mut out = Tensor::zeros(&[m, n]);

    let mut j = 0;
    while j + 4 <= n {
        let w0 = wq.row(j);
        let w1 = wq.row(j + 1);
        let w2 = wq.row(j + 2);
        let w3 = wq.row(j + 3);
        for i in 0..m {
            let xi = xq.row(i);
            let scale = scales[i];
            let (a0, a1, a2, a3) =
                (dot_i8(xi, w0), dot_i8(xi, w1), dot_i8(xi, w2), dot_i8(xi, w3));
            let orow = out.row_mut(i);
            orow[j] = a0 as f32 * scale;
            orow[j + 1] = a1 as f32 * scale;
            orow[j + 2] = a2 as f32 * scale;
            orow[j + 3] = a3 as f32 * scale;
        }
        j += 4;
    }
    while j < n {
        let wj = wq.row(j);
        for i in 0..m {
            out.row_mut(i)[j] = dot_i8(xq.row(i), wj) as f32 * scales[i];
        }
        j += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// gemmlowp-style: pack both operands, panel compute, unpack.
// ---------------------------------------------------------------------------

const LOWP_KC: usize = 256; // k-strip
const LOWP_NR: usize = 4; // weight panel rows
const LOWP_MR: usize = 8; // activation panel rows (gemmlowp NEON kernels are 8x8/12x4)

/// gemmlowp-style quantized GEMM (pack → compute → unpack).
///
/// Faithful to the library's structure, including the two properties that
/// make it lose at small batch (the paper's §4 point):
///
/// 1. **per-call packing** of both operands into `[strip][panel]`
///    interleaved layouts — O(n·k) copy traffic that only amortizes when
///    many activation columns reuse the packed weights;
/// 2. **a fixed MR×NR register tile** (gemmlowp's NEON kernels are
///    12×4/8×8 etc.): the activation panel is zero-padded up to
///    `LOWP_MR` rows, so a batch-1 GEMM performs `LOWP_MR×` the useful
///    multiply-accumulates.  farm instead specializes per batch size.
///
/// Exactness is unaffected (padded rows are zero and dropped on unpack);
/// the cost structure is what changes — which is exactly the Figure-6
/// story.
pub fn qgemm_lowp(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let (n, k2) = (wq.rows(), wq.cols());
    assert_eq!(k, k2, "qgemm_lowp contraction mismatch");
    let scale = sx * sw;
    let mp = m.div_ceil(LOWP_MR) * LOWP_MR; // fixed-tile row padding
    let mut acc = vec![0i32; mp * n];

    let nstrips = k.div_ceil(LOWP_KC);
    // Reusable packing buffers (gemmlowp allocates these per context).
    let npanels = n.div_ceil(LOWP_NR);
    let mut wpack = vec![0i8; npanels * LOWP_NR * LOWP_KC];
    let mut xpack = vec![0i8; mp * LOWP_KC];

    for strip in 0..nstrips {
        let k0 = strip * LOWP_KC;
        let kc = LOWP_KC.min(k - k0);

        // pack weights: panel-major, row-interleaved by 4 (zero-padded)
        for p in 0..npanels {
            for r in 0..LOWP_NR {
                let row = p * LOWP_NR + r;
                let dst = &mut wpack[(p * LOWP_NR + r) * LOWP_KC..][..kc];
                if row < n {
                    dst.copy_from_slice(&wq.row(row)[k0..k0 + kc]);
                } else {
                    dst.fill(0);
                }
            }
        }
        // pack activations: strip-contiguous rows, zero-padded to MR
        xpack.fill(0);
        for i in 0..m {
            xpack[i * LOWP_KC..i * LOWP_KC + kc]
                .copy_from_slice(&xq.row(i)[k0..k0 + kc]);
        }

        // compute pass over packed memory: full MR×NR tiles always
        for p in 0..npanels {
            let base = p * LOWP_NR;
            let w0 = &wpack[(base) * LOWP_KC..][..kc];
            let w1 = &wpack[(base + 1) * LOWP_KC..][..kc];
            let w2 = &wpack[(base + 2) * LOWP_KC..][..kc];
            let w3 = &wpack[(base + 3) * LOWP_KC..][..kc];
            for i in 0..mp {
                let xi = &xpack[i * LOWP_KC..][..kc];
                let arow = &mut acc[i * n..];
                let (a0, a1, a2, a3) =
                    (dot_i8(xi, w0), dot_i8(xi, w1), dot_i8(xi, w2), dot_i8(xi, w3));
                arow[base] += a0;
                if base + 1 < n {
                    arow[base + 1] += a1;
                }
                if base + 2 < n {
                    arow[base + 2] += a2;
                }
                if base + 3 < n {
                    arow[base + 3] += a3;
                }
            }
        }
    }

    // unpack / dequantize (drops the padded rows)
    let data: Vec<f32> = acc[..m * n].iter().map(|&a| a as f32 * scale).collect();
    Tensor::new(&[m, n], data).unwrap()
}

/// Naive i32 reference for exactness tests.
pub fn qgemm_ref(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let n = wq.rows();
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut a = 0i32;
            for kk in 0..k {
                a += xq.row(i)[kk] as i32 * wq.row(j)[kk] as i32;
            }
            out.set2(i, j, a as f32 * (sx * sw));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::{quantize, quantize_into};

    fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
        let n: usize = shape.iter().product();
        let data: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(shape, data).unwrap()
    }

    #[test]
    fn farm_matches_reference_exactly() {
        let mut rng = Pcg64::seeded(0);
        for &(m, n, k) in &[(1, 7, 5), (2, 64, 32), (4, 33, 100), (8, 128, 320), (3, 6144 / 64, 320)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let got = qgemm_farm(&x, &w, 0.01, 0.02);
            let want = qgemm_ref(&x, &w, 0.01, 0.02);
            assert_eq!(got, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn lowp_matches_reference_exactly() {
        let mut rng = Pcg64::seeded(1);
        for &(m, n, k) in &[(1, 7, 5), (2, 64, 300), (4, 33, 257), (16, 65, 512), (5, 9, 1000)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let got = qgemm_lowp(&x, &w, 0.5, 2.0);
            let want = qgemm_ref(&x, &w, 0.5, 2.0);
            assert_eq!(got, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn farm_and_lowp_agree() {
        let mut rng = Pcg64::seeded(2);
        let x = rand_i8(&[4, 320], &mut rng);
        let w = rand_i8(&[256, 320], &mut rng);
        let a = qgemm_farm(&x, &w, 0.1, 0.1);
        let b = qgemm_lowp(&x, &w, 0.1, 0.1);
        assert_eq!(a, b);
    }

    #[test]
    fn gemm_f32_matches_tensor_matmul() {
        let mut rng = Pcg64::seeded(3);
        let x = Tensor::randn(&[5, 37], 1.0, &mut rng);
        let w = Tensor::randn(&[11, 37], 1.0, &mut rng);
        let got = gemm_f32(&x, &w, None);
        let want = x.matmul(&w.transpose()).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gemm_f32_bias() {
        let x = Tensor::new(&[1, 2], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let got = gemm_f32(&x, &w, Some(&[10.0, 20.0]));
        assert_eq!(got.data(), &[11.0, 21.0]);
    }

    #[test]
    fn quantized_gemm_tracks_f32() {
        // end-to-end: quantize f32 operands, run farm, compare to f32 GEMM
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::randn(&[4, 320], 1.0, &mut rng);
        let w = Tensor::randn(&[64, 320], 0.1, &mut rng);
        let qw = quantize(&w);
        let mut xq_data = vec![0i8; 4 * 320];
        let sx = quantize_into(x.data(), &mut xq_data);
        let xq = TensorI8::new(&[4, 320], xq_data).unwrap();
        let got = qgemm_farm(&xq, &qw.q, sx, qw.scale);
        let want = gemm_f32(&x, &w, None);
        // relative error bounded by accumulated quantization noise
        let scale = want.abs_max().max(1e-6);
        assert!(got.max_abs_diff(&want) / scale < 0.02);
    }

    #[test]
    fn farm_rows_matches_independent_batch1_calls() {
        // the pooled-step contract: one batch-m call with per-row scales
        // is bit-identical to m separate batch-1 farm calls
        let mut rng = Pcg64::seeded(5);
        for &(m, n, k) in &[(2usize, 48usize, 32usize), (4, 96, 128), (3, 33, 100), (8, 64, 320)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let pooled = qgemm_farm_rows(&x, &w, &sx, 0.02);
            for i in 0..m {
                let xi = TensorI8::new(&[1, k], x.row(i).to_vec()).unwrap();
                let solo = qgemm_farm(&xi, &w, sx[i], 0.02);
                assert_eq!(pooled.row(i), solo.row(0), "row {i} of ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn farm_rows_with_uniform_scale_equals_farm() {
        let mut rng = Pcg64::seeded(6);
        let x = rand_i8(&[4, 160], &mut rng);
        let w = rand_i8(&[96, 160], &mut rng);
        let a = qgemm_farm(&x, &w, 0.011, 0.017);
        let b = qgemm_farm_rows(&x, &w, &[0.011; 4], 0.017);
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_counts_save_weight_traffic() {
        let (m, n, k) = (4usize, 384usize, 128usize);
        let pooled = pooled_rec_counts(m, n, k);
        let seq = sequential_rec_counts(m, n, k);
        assert_eq!(pooled.macs, seq.macs); // same useful work
        assert!(pooled.bytes_read < seq.bytes_read);
        // weight stream dominates: pooled reads ~1/m of the sequential bytes
        let ratio = seq.bytes_read as f64 / pooled.bytes_read as f64;
        assert!(ratio > m as f64 * 0.8, "ratio {ratio}");
        assert_eq!(pooled_rec_counts(1, n, k).bytes_read, sequential_rec_counts(1, n, k).bytes_read);
    }

    #[test]
    fn counts_reflect_packing_and_tile_overhead() {
        let f = farm_counts(1, 6144, 320);
        let l = lowp_counts(1, 6144, 320);
        assert_eq!(l.macs, 8 * f.macs); // MR=8 register-tile padding
        assert!(l.bytes_read > f.bytes_read);
        assert!(l.bytes_written > f.bytes_written);
        // at large batch the tile padding vanishes
        assert_eq!(lowp_counts(16, 64, 64).macs, farm_counts(16, 64, 64).macs);
    }
}

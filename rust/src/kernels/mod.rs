//! GEMM kernels — the Rust reproduction of the paper's §4 contribution,
//! organized as pluggable backends behind the [`GemmBackend`] trait.
//!
//! The paper ships hand-written AArch64 kernels ("farm") that beat
//! gemmlowp by 3–7× at batch sizes 1–4, the regime that dominates
//! on-device streaming ASR (the recurrent GEMM is strictly batch-1; the
//! non-recurrent one batches across ≤ 4 timesteps before latency suffers).
//! Deployment wins in that regime come from memory layout and allocation
//! discipline as much as arithmetic (Prabhavalkar et al., 1603.08042), so
//! this module separates the two concerns:
//!
//! * **What** is computed — `y = x·wᵀ` with exact i32 accumulation on the
//!   int8 path — is fixed by the reference functions [`qgemm_farm`],
//!   [`qgemm_farm_rows`], [`gemm_f32`] and [`qgemm_ref`], and every
//!   backend must reproduce the int8 results **bit-identically**
//!   (`rust/tests/backends.rs`).
//! * **How** it is computed — weight layout, tiling, ISA — is a backend:
//!
//! | backend | module | weight layout | notes |
//! |---|---|---|---|
//! | `scalar` | [`scalar`] | row-major | the original farm schedule; the reference |
//! | `blocked` | [`blocked`] | [`PackedQMatrix`] NR-panels | pre-packed once at plan time, k-stripped |
//! | `simd` | `simd` | row-major | `std::arch` AVX2/NEON, runtime-detected, feature-gated |
//!
//! Backends expose allocation-free `*_into` entry points
//! ([`GemmBackend::gemm_f32_into`], [`GemmBackend::qgemm_farm_into`],
//! [`GemmBackend::qgemm_farm_rows_into`]) that write into caller-owned
//! output tensors — the engine's scratch arena ([`crate::infer`]) — so
//! the steady-state decode loop performs zero heap allocations.
//!
//! Two **small-batch specializations** ride the same trait (DESIGN.md
//! §4; both default to the plain batch path, so every backend stays
//! correct without overriding them):
//!
//! * [`GemmBackend::qgemv_into`] — the dedicated m = 1 GEMV, the
//!   steady-state decode shape.  Single activation row, no batch loop,
//!   no panel staging.
//! * [`GemmBackend::qgemm_gates_rows_into`] — the fused GRU-gate
//!   product: when the prepared weight carries gate-interleaved
//!   [`PackedGatePanels`] (`[z|r|h̃]` adjacent per hidden unit), all
//!   three gate products are computed in one sweep over the weights
//!   instead of three.
//!
//! [`autotune`] adds runtime NR/KC tile selection for the blocked packed
//! layout — micro-probed once per `(n, k)` at engine construction, never
//! per call; `--autotune off` pins the defaults.
//!
//! **Dispatch rules** (see DESIGN.md §4): [`BackendSel`] names a backend;
//! [`resolve`] maps it to an implementation.  `auto` picks `simd` when
//! the crate was built with the `simd` feature *and* the CPU supports it
//! at runtime, else `blocked`.  `simd` without the feature is a
//! configuration error; `simd` with the feature but without CPU support
//! silently computes on the scalar path (same results — the backends are
//! bit-identical on int8).
//!
//! [`qgemm_lowp`] remains the gemmlowp contrast case of Figure 6
//! (pack-compute-unpack **per call**) and is deliberately not a backend:
//! its per-call packing is the cost the [`PackedQMatrix`] plan-time
//! packing exists to avoid.
//!
//! [`qgemm_farm_rows`] is the batch-m **pooled** entry point: the
//! [`crate::stream`] pool lock-steps the recurrent GEMMs of m concurrent
//! utterance streams into one call, with per-row activation scales so the
//! result stays bit-identical to m independent batch-1 calls.
//! [`pooled_rec_counts`]/[`sequential_rec_counts`] expose the op/byte
//! contrast for the roofline projection.

pub mod autotune;
pub mod blocked;
pub mod pack;
pub mod scalar;
#[cfg(feature = "simd")]
pub mod simd;

pub use blocked::BlockedBackend;
pub use pack::{PackedGatePanels, PackedQ4GatePanels, PackedQ4Matrix, PackedQMatrix, KC, MAX_NR, NR};
pub use scalar::{
    gemm_f32, qgemm4_farm, qgemm4_farm_rows, qgemm4_ref, qgemm_farm, qgemm_farm_rows, qgemm_lowp,
    qgemm_ref, ScalarBackend,
};
#[cfg(feature = "simd")]
pub use simd::SimdBackend;

use std::str::FromStr;

use crate::error::{Error, Result};
use crate::quant::{Q4Matrix, QMatrix};
use crate::tensor::{Tensor, TensorI8};

/// Operation/byte accounting for roofline projection (devicesim).
#[derive(Clone, Copy, Debug, Default)]
pub struct GemmCounts {
    /// multiply-accumulate ops
    pub macs: u64,
    /// bytes read from "DRAM" (counting each operand stream once, plus
    /// packing copies where the algorithm makes them)
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl GemmCounts {
    pub fn ops(&self) -> u64 {
        2 * self.macs
    }
}

/// Counts for `y(m,n) = x(m,k) · w(n,k)ᵀ` under the farm schedule.
pub fn farm_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    GemmCounts {
        macs: (m * n * k) as u64,
        // weights streamed once (n·k), activations reused from L1 (m·k),
        // output written once (4·m·n f32)
        bytes_read: (n * k + m * k) as u64,
        bytes_written: (4 * m * n) as u64,
    }
}

/// Counts for the gemmlowp schedule: the pack copies (read + write of
/// both operands) plus the fixed MR-tile padding of the MAC count.
pub fn lowp_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    let mp = m.div_ceil(8) * 8; // LOWP_MR register-tile padding
    GemmCounts {
        macs: (mp * n * k) as u64,
        bytes_read: (2 * (n * k + mp * k)) as u64, // stream + packed re-read
        bytes_written: (n * k + mp * k + 4 * m * n) as u64, // packed copies + output
    }
}

/// Counts for `y(m,n) = x(m,k) · w(n,k)ᵀ` under the int4 farm schedule:
/// the weight stream halves to one nibble per weight plus the per-group
/// f32 scales (`4·⌈k/group⌉` bytes per output row) — the bytes-per-weight
/// lever the sub-byte path exists for.
pub fn farm4_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    let group = crate::quant::Q4_GROUP;
    GemmCounts {
        macs: (m * n * k) as u64,
        bytes_read: (n * k.div_ceil(2) + 4 * n * k.div_ceil(group) + m * k) as u64,
        bytes_written: (4 * m * n) as u64,
    }
}

/// Counts for one **pooled** recurrent step: `m` concurrent streams'
/// hidden vectors lock-stepped into a single batch-m farm call
/// ([`qgemm_farm_rows`]).  The weight matrix streams from memory once
/// for all `m` streams — this is the whole point of cross-stream
/// batching (DESIGN.md §6).
pub fn pooled_rec_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    farm_counts(m, n, k)
}

/// Counts for the same work done the pre-pool way: `m` independent
/// batch-1 recurrent GEMMs, each streaming the weight matrix separately.
/// MACs match [`pooled_rec_counts`]; weight traffic is `m×`.
pub fn sequential_rec_counts(m: usize, n: usize, k: usize) -> GemmCounts {
    let one = farm_counts(1, n, k);
    GemmCounts {
        macs: one.macs * m as u64,
        bytes_read: one.bytes_read * m as u64,
        bytes_written: one.bytes_written * m as u64,
    }
}

// ---------------------------------------------------------------------------
// Prepared weights: every layout a backend may want, built once at plan
// time (engine construction / registry load) — never per call.
// ---------------------------------------------------------------------------

/// An int8 weight matrix prepared for all registered backends: the
/// row-major reference layout (scalar, simd) **plus** the nr-panel
/// pre-packed layout (blocked; tile shape per weight from
/// [`autotune::choose`]), and — for stacked GRU gate weights prepared
/// via [`PreparedQMatrix::new_with_gates`] — the gate-interleaved
/// [`PackedGatePanels`] the fused gate kernels consume.  All layouts are
/// built exactly once when the engine is constructed or a registry
/// artifact is loaded.
#[derive(Clone, Debug)]
pub struct PreparedQMatrix {
    /// row-major `(n, k)` int8 weights — the reference layout
    pub q: TensorI8,
    /// per-tensor dequantization scale (`w ≈ scale · q`)
    pub scale: f32,
    /// panel-interleaved pre-packed copy (see [`PackedQMatrix`])
    pub packed: PackedQMatrix,
    /// gate-interleaved `[z|r|h̃]` panels — present only on `(3H, k)`
    /// GRU gate weights prepared via [`PreparedQMatrix::new_with_gates`]
    pub gates: Option<PackedGatePanels>,
}

impl PreparedQMatrix {
    /// Prepare a quantized matrix for every backend (packs once; the
    /// blocked tile shape comes from the autotune cache).  Pack time is
    /// plan time by construction, so with obs on it lands in the global
    /// `Stage::Pack` span, never a per-stream decode span.
    pub fn new(q: QMatrix) -> PreparedQMatrix {
        let (nr, kc) = autotune::choose(q.q.rows(), q.q.cols());
        let t0 = std::time::Instant::now();
        let packed = PackedQMatrix::pack_with(&q.q, nr, kc);
        if crate::obs::enabled() {
            crate::obs::spans::record_global(crate::obs::Stage::Pack, t0.elapsed().as_secs_f64());
        }
        PreparedQMatrix { q: q.q, scale: q.scale, packed, gates: None }
    }

    /// Prepare a stacked `(3H, k)` GRU gate weight: everything
    /// [`PreparedQMatrix::new`] builds **plus** the gate-interleaved
    /// panel layout for the fused gate kernels.  Weights whose row count
    /// is not a multiple of 3 get no gate panels (the fused entry point
    /// then falls back to the stacked sweep — same bits).
    pub fn new_with_gates(q: QMatrix) -> PreparedQMatrix {
        let mut p = PreparedQMatrix::new(q);
        if p.q.rows() > 0 && p.q.rows() % 3 == 0 {
            let t0 = std::time::Instant::now();
            p.gates = Some(PackedGatePanels::pack(&p.q));
            if crate::obs::enabled() {
                crate::obs::spans::record_global(
                    crate::obs::Stage::Pack,
                    t0.elapsed().as_secs_f64(),
                );
            }
        }
        p
    }

    /// Output dimension `n` of `y = x·wᵀ`.
    pub fn n(&self) -> usize {
        self.q.rows()
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.q.cols()
    }
}

/// An int4 weight matrix prepared for all registered backends — the
/// sub-byte sibling of [`PreparedQMatrix`].  Carries the nibble-packed
/// row-major [`Q4Matrix`] (the reference layout scalar and simd consume)
/// **plus** the nr-panel pre-packed [`PackedQ4Matrix`] (blocked) and,
/// for `(3H, k)` GRU gate weights prepared via
/// [`PreparedQ4Matrix::new_with_gates`], the gate-interleaved
/// [`PackedQ4GatePanels`].  Scales are per-group (no per-tensor weight
/// scale), so dequantization happens inside the kernels.
#[derive(Clone, Debug)]
pub struct PreparedQ4Matrix {
    /// nibble-packed row-major weights + per-group scales — the
    /// reference layout
    pub q4: Q4Matrix,
    /// panel-interleaved pre-packed copy (see [`PackedQ4Matrix`])
    pub packed: PackedQ4Matrix,
    /// gate-interleaved `[z|r|h̃]` nibble panels — present only on
    /// `(3H, k)` gate weights prepared via
    /// [`PreparedQ4Matrix::new_with_gates`]
    pub gates: Option<PackedQ4GatePanels>,
}

impl PreparedQ4Matrix {
    /// Prepare an int4 matrix for every backend (packs once, at plan
    /// time).  The blocked tile shape comes from the same autotune cache
    /// as int8 — every candidate KC is a multiple of the scale group, and
    /// the round-up below keeps the strip/group alignment invariant even
    /// for non-default groups.
    pub fn new(q4: Q4Matrix) -> PreparedQ4Matrix {
        let (nr, mut kc) = autotune::choose(q4.rows(), q4.cols());
        let group = q4.group();
        if kc % group != 0 {
            kc = group * kc.div_ceil(group);
        }
        let t0 = std::time::Instant::now();
        let packed = PackedQ4Matrix::pack_with(&q4, nr, kc);
        if crate::obs::enabled() {
            crate::obs::spans::record_global(crate::obs::Stage::Pack, t0.elapsed().as_secs_f64());
        }
        PreparedQ4Matrix { q4, packed, gates: None }
    }

    /// Prepare a stacked `(3H, k)` int4 GRU gate weight: everything
    /// [`PreparedQ4Matrix::new`] builds plus the gate-interleaved panel
    /// layout.  Row counts that are not a multiple of 3 get no gate
    /// panels (the fused entry point then falls back to the stacked
    /// sweep — same bits).
    pub fn new_with_gates(q4: Q4Matrix) -> PreparedQ4Matrix {
        let mut p = PreparedQ4Matrix::new(q4);
        if p.q4.rows() > 0 && p.q4.rows() % 3 == 0 {
            let t0 = std::time::Instant::now();
            p.gates = Some(PackedQ4GatePanels::pack(&p.q4));
            if crate::obs::enabled() {
                crate::obs::spans::record_global(
                    crate::obs::Stage::Pack,
                    t0.elapsed().as_secs_f64(),
                );
            }
        }
        p
    }

    /// Output dimension `n` of `y = x·wᵀ`.
    pub fn n(&self) -> usize {
        self.q4.rows()
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.q4.cols()
    }

    /// Serving bytes of the reference layout (nibbles + group scales) —
    /// what actually streams through cache per GEMM call.
    pub fn bytes(&self) -> usize {
        self.q4.payload_bytes()
    }
}

// Compile-time Send+Sync audit (DESIGN.md §9): prepared weights are the
// shared read-only half of the serving plan — every shard thread reads
// the same `PreparedQMatrix` through its `Arc<Engine>`, so both layouts
// must stay shareable by construction.
const _: () = crate::assert_send_sync::<PreparedQMatrix>();
const _: () = crate::assert_send_sync::<PackedQMatrix>();
const _: () = crate::assert_send_sync::<PackedGatePanels>();
const _: () = crate::assert_send_sync::<PreparedQ4Matrix>();
const _: () = crate::assert_send_sync::<PackedQ4Matrix>();
const _: () = crate::assert_send_sync::<PackedQ4GatePanels>();

/// Per-output-row dequantization scales, shared by the backend kernels.
/// `Uniform` carries the pre-multiplied `sx·sw` product (one activation
/// scale per call); `PerRow` carries the per-stream activation scales and
/// the weight scale, multiplied per row exactly as `m` batch-1 calls
/// would — which is what keeps pooled decoding bit-identical.
#[derive(Clone, Copy)]
pub(crate) enum RowScales<'a> {
    Uniform(f32),
    PerRow(&'a [f32], f32),
}

impl RowScales<'_> {
    #[inline]
    pub(crate) fn get(&self, i: usize) -> f32 {
        match self {
            RowScales::Uniform(s) => *s,
            RowScales::PerRow(sx, sw) => sx[i] * sw,
        }
    }
}

// ---------------------------------------------------------------------------
// The backend trait + selection.
// ---------------------------------------------------------------------------

/// A GEMM execution strategy.  All entry points are `*_into`: they write
/// into a caller-owned output tensor (reshaped in place via
/// [`Tensor::reset`], which does not allocate in steady state), so the
/// engine's hot loop stays allocation-free.
///
/// Correctness contract: the int8 entry points accumulate in i32
/// (exact), so **every** backend must be bit-identical to
/// [`ScalarBackend`] — and therefore to [`qgemm_ref`] — on the same
/// inputs.  f32 entry points may differ from scalar only by summation
/// order (≤ 1e-5 relative).  `rust/tests/backends.rs` enforces both.
pub trait GemmBackend: Send + Sync {
    /// Stable backend name (CLI value, bench/report label).
    fn name(&self) -> &'static str;

    /// `out = x·wᵀ (+ bias)`, f32.  `x: (m, k)`, `w: (n, k)` → `(m, n)`.
    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor);

    /// `out = (sx·xq)·(w.scale·w)ᵀ`: int8 GEMM with one dynamic
    /// activation scale per call.  `xq` is row-major `(m, k)` with
    /// `k = w.k()`.
    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor);

    /// Batch-m int8 GEMM with **per-row** activation scales (the pooled
    /// recurrent path): row `i` dequantizes by `sx[i]·w.scale`,
    /// bit-identical to `m` separate batch-1
    /// [`GemmBackend::qgemm_farm_into`] calls.
    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    );

    /// Dedicated m = 1 GEMV — the steady-state decode shape.  `xq` is a
    /// single activation row of `w.k()` elements.  Default delegates to
    /// the batch path at m = 1; backends override with a path that skips
    /// the batch loop (and, for `blocked`, panel staging) entirely.
    /// Must stay bit-identical to [`GemmBackend::qgemm_farm_into`] at
    /// m = 1 (exact i32 accumulation — the parity suite pins it).
    fn qgemv_into(&self, xq: &[i8], w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        self.qgemm_farm_into(xq, 1, w, sx, out);
    }

    /// Fused GRU-gate product with per-row activation scales: computes
    /// the stacked `(m, 3H)` gate pre-activations of a `(3H, k)` gate
    /// weight.  Backends with a fused kernel read the gate-interleaved
    /// [`PackedGatePanels`] (one sweep over the weights instead of
    /// three); the default — and any weight prepared without gate
    /// panels — is the plain stacked sweep.  Output layout and bits are
    /// identical either way ([`GemmBackend::qgemm_farm_rows_into`] is
    /// the reference).
    fn qgemm_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        self.qgemm_farm_rows_into(xq, m, w, sx, out);
    }

    /// `out = (sx·xq) · dequant(w)ᵀ`: int4 GEMM with per-group weight
    /// scales and one dynamic activation scale per call.  Accumulation
    /// contract (every backend bit-identical to [`ScalarBackend`]):
    /// exact i32 per scale group → f32 multiply by the group scale → f32
    /// sum in ascending group order → final multiply by `sx`.
    fn qgemm4_farm_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: f32,
        out: &mut Tensor,
    );

    /// Batch-m int4 GEMM with **per-row** activation scales — the pooled
    /// recurrent path at `--bits 4`, bit-identical to `m` separate
    /// batch-1 [`GemmBackend::qgemm4_farm_into`] calls.
    fn qgemm4_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    );

    /// Dedicated m = 1 int4 GEMV.  Default delegates to the batch path
    /// at m = 1; overrides must stay bit-identical to it.
    fn qgemv4_into(&self, xq: &[i8], w: &PreparedQ4Matrix, sx: f32, out: &mut Tensor) {
        self.qgemm4_farm_into(xq, 1, w, sx, out);
    }

    /// Fused GRU-gate product on int4 weights: the 4-bit sibling of
    /// [`GemmBackend::qgemm_gates_rows_into`], reading the
    /// gate-interleaved [`PackedQ4GatePanels`] when present.  Default —
    /// and any weight prepared without gate panels — is the plain
    /// stacked sweep; output layout and bits are identical either way.
    fn qgemm4_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        self.qgemm4_farm_rows_into(xq, m, w, sx, out);
    }
}

/// Backend selector: the value of the `--backend` CLI flag and the knob
/// threaded through [`crate::registry`] and [`crate::serve`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendSel {
    /// best available: `simd` if compiled in and CPU-supported, else `blocked`
    Auto,
    Scalar,
    Blocked,
    Simd,
}

impl FromStr for BackendSel {
    type Err = Error;

    fn from_str(s: &str) -> Result<BackendSel> {
        match s {
            "auto" => Ok(BackendSel::Auto),
            "scalar" => Ok(BackendSel::Scalar),
            "blocked" => Ok(BackendSel::Blocked),
            "simd" => Ok(BackendSel::Simd),
            other => Err(Error::Config(format!(
                "unknown backend '{other}' (want scalar|blocked|simd|auto)"
            ))),
        }
    }
}

impl std::fmt::Display for BackendSel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendSel::Auto => "auto",
            BackendSel::Scalar => "scalar",
            BackendSel::Blocked => "blocked",
            BackendSel::Simd => "simd",
        })
    }
}

/// Resolve a selector to a backend implementation (the dispatch rules of
/// the module docs).  `Simd` errors when the crate was built without the
/// `simd` feature; `Auto` never errors.
pub fn resolve(sel: BackendSel) -> Result<&'static dyn GemmBackend> {
    match sel {
        BackendSel::Scalar => Ok(&ScalarBackend),
        BackendSel::Blocked => Ok(&BlockedBackend),
        BackendSel::Simd => simd_backend(),
        BackendSel::Auto => Ok(auto_backend()),
    }
}

#[cfg(feature = "simd")]
fn simd_backend() -> Result<&'static dyn GemmBackend> {
    Ok(&SimdBackend)
}

#[cfg(not(feature = "simd"))]
fn simd_backend() -> Result<&'static dyn GemmBackend> {
    Err(Error::Config(
        "backend 'simd' requires building with `--features simd`".into(),
    ))
}

/// The `auto` choice: `simd` when compiled in and usable on this CPU,
/// else `blocked` (whose f32 path is bit-identical to scalar).
pub fn auto_backend() -> &'static dyn GemmBackend {
    #[cfg(feature = "simd")]
    if simd::runtime_available() {
        return &SimdBackend;
    }
    &BlockedBackend
}

/// Whether the `simd` backend would actually take a vector path on this
/// CPU.  False when the crate was built without the `simd` feature or
/// the CPU lacks AVX2/NEON — in that case the backend still *works*
/// (scalar fallback, same bits) but runs at scalar speed, and benches /
/// reports should say so (`benches/gemm.rs` records this flag in
/// `BENCH_gemm.json` so fallback timings are never mistaken for vector
/// timings).
#[cfg(feature = "simd")]
pub fn simd_runtime_available() -> bool {
    simd::runtime_available()
}

/// Whether the `simd` backend would actually take a vector path on this
/// CPU (always false: built without the `simd` feature).
#[cfg(not(feature = "simd"))]
pub fn simd_runtime_available() -> bool {
    false
}

/// Every backend registered in this build, for the parity suite and the
/// bench sweep.  The `simd` entry appears only under the `simd` feature
/// (it still runs — on its scalar fallback — when the CPU lacks support).
pub fn all_backends() -> Vec<(BackendSel, &'static dyn GemmBackend)> {
    #[allow(unused_mut)] // mutated only under the simd feature
    let mut v: Vec<(BackendSel, &'static dyn GemmBackend)> =
        vec![(BackendSel::Scalar, &ScalarBackend), (BackendSel::Blocked, &BlockedBackend)];
    #[cfg(feature = "simd")]
    v.push((BackendSel::Simd, &SimdBackend));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::{quantize, quantize_into};

    fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
        let n: usize = shape.iter().product();
        let data: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(shape, data).unwrap()
    }

    #[test]
    fn farm_matches_reference_exactly() {
        let mut rng = Pcg64::seeded(0);
        for &(m, n, k) in &[(1, 7, 5), (2, 64, 32), (4, 33, 100), (8, 128, 320), (3, 6144 / 64, 320)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let got = qgemm_farm(&x, &w, 0.01, 0.02);
            let want = qgemm_ref(&x, &w, 0.01, 0.02);
            assert_eq!(got, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn lowp_matches_reference_exactly() {
        let mut rng = Pcg64::seeded(1);
        for &(m, n, k) in &[(1, 7, 5), (2, 64, 300), (4, 33, 257), (16, 65, 512), (5, 9, 1000)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let got = qgemm_lowp(&x, &w, 0.5, 2.0);
            let want = qgemm_ref(&x, &w, 0.5, 2.0);
            assert_eq!(got, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn farm_and_lowp_agree() {
        let mut rng = Pcg64::seeded(2);
        let x = rand_i8(&[4, 320], &mut rng);
        let w = rand_i8(&[256, 320], &mut rng);
        let a = qgemm_farm(&x, &w, 0.1, 0.1);
        let b = qgemm_lowp(&x, &w, 0.1, 0.1);
        assert_eq!(a, b);
    }

    #[test]
    fn gemm_f32_matches_tensor_matmul() {
        let mut rng = Pcg64::seeded(3);
        let x = Tensor::randn(&[5, 37], 1.0, &mut rng);
        let w = Tensor::randn(&[11, 37], 1.0, &mut rng);
        let got = gemm_f32(&x, &w, None);
        let want = x.matmul(&w.transpose()).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn gemm_f32_bias() {
        let x = Tensor::new(&[1, 2], vec![1.0, 1.0]).unwrap();
        let w = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let got = gemm_f32(&x, &w, Some(&[10.0, 20.0]));
        assert_eq!(got.data(), &[11.0, 21.0]);
    }

    #[test]
    fn quantized_gemm_tracks_f32() {
        // end-to-end: quantize f32 operands, run farm, compare to f32 GEMM
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::randn(&[4, 320], 1.0, &mut rng);
        let w = Tensor::randn(&[64, 320], 0.1, &mut rng);
        let qw = quantize(&w);
        let mut xq_data = vec![0i8; 4 * 320];
        let sx = quantize_into(x.data(), &mut xq_data);
        let xq = TensorI8::new(&[4, 320], xq_data).unwrap();
        let got = qgemm_farm(&xq, &qw.q, sx, qw.scale);
        let want = gemm_f32(&x, &w, None);
        // relative error bounded by accumulated quantization noise
        let scale = want.abs_max().max(1e-6);
        assert!(got.max_abs_diff(&want) / scale < 0.02);
    }

    #[test]
    fn farm_rows_matches_independent_batch1_calls() {
        // the pooled-step contract: one batch-m call with per-row scales
        // is bit-identical to m separate batch-1 farm calls
        let mut rng = Pcg64::seeded(5);
        for &(m, n, k) in &[(2usize, 48usize, 32usize), (4, 96, 128), (3, 33, 100), (8, 64, 320)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_i8(&[n, k], &mut rng);
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let pooled = qgemm_farm_rows(&x, &w, &sx, 0.02);
            for i in 0..m {
                let xi = TensorI8::new(&[1, k], x.row(i).to_vec()).unwrap();
                let solo = qgemm_farm(&xi, &w, sx[i], 0.02);
                assert_eq!(pooled.row(i), solo.row(0), "row {i} of ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn farm_rows_with_uniform_scale_equals_farm() {
        let mut rng = Pcg64::seeded(6);
        let x = rand_i8(&[4, 160], &mut rng);
        let w = rand_i8(&[96, 160], &mut rng);
        let a = qgemm_farm(&x, &w, 0.011, 0.017);
        let b = qgemm_farm_rows(&x, &w, &[0.011; 4], 0.017);
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_counts_save_weight_traffic() {
        let (m, n, k) = (4usize, 384usize, 128usize);
        let pooled = pooled_rec_counts(m, n, k);
        let seq = sequential_rec_counts(m, n, k);
        assert_eq!(pooled.macs, seq.macs); // same useful work
        assert!(pooled.bytes_read < seq.bytes_read);
        // weight stream dominates: pooled reads ~1/m of the sequential bytes
        let ratio = seq.bytes_read as f64 / pooled.bytes_read as f64;
        assert!(ratio > m as f64 * 0.8, "ratio {ratio}");
        assert_eq!(pooled_rec_counts(1, n, k).bytes_read, sequential_rec_counts(1, n, k).bytes_read);
    }

    #[test]
    fn counts_reflect_packing_and_tile_overhead() {
        let f = farm_counts(1, 6144, 320);
        let l = lowp_counts(1, 6144, 320);
        assert_eq!(l.macs, 8 * f.macs); // MR=8 register-tile padding
        assert!(l.bytes_read > f.bytes_read);
        assert!(l.bytes_written > f.bytes_written);
        // at large batch the tile padding vanishes
        assert_eq!(lowp_counts(16, 64, 64).macs, farm_counts(16, 64, 64).macs);
    }

    #[test]
    fn backend_sel_parses_and_resolves() {
        assert_eq!("scalar".parse::<BackendSel>().unwrap(), BackendSel::Scalar);
        assert_eq!("blocked".parse::<BackendSel>().unwrap(), BackendSel::Blocked);
        assert_eq!("simd".parse::<BackendSel>().unwrap(), BackendSel::Simd);
        assert_eq!("auto".parse::<BackendSel>().unwrap(), BackendSel::Auto);
        assert!("fast".parse::<BackendSel>().is_err());
        assert_eq!(resolve(BackendSel::Scalar).unwrap().name(), "scalar");
        assert_eq!(resolve(BackendSel::Blocked).unwrap().name(), "blocked");
        // auto always resolves; without the simd feature it is `blocked`
        let auto = resolve(BackendSel::Auto).unwrap();
        #[cfg(not(feature = "simd"))]
        assert_eq!(auto.name(), "blocked");
        #[cfg(feature = "simd")]
        assert!(auto.name() == "simd" || auto.name() == "blocked");
        #[cfg(not(feature = "simd"))]
        assert!(resolve(BackendSel::Simd).is_err(), "simd needs the feature");
    }

    #[test]
    fn all_backends_lists_scalar_and_blocked() {
        let names: Vec<&str> = all_backends().iter().map(|(_, b)| b.name()).collect();
        assert!(names.contains(&"scalar"));
        assert!(names.contains(&"blocked"));
    }

    #[test]
    fn prepared_matrix_exposes_dims_and_round_trips() {
        let mut rng = Pcg64::seeded(7);
        let w = Tensor::randn(&[37, 53], 0.3, &mut rng);
        let p = PreparedQMatrix::new(quantize(&w));
        assert_eq!(p.n(), 37);
        assert_eq!(p.k(), 53);
        assert_eq!(p.packed.unpack(), p.q, "plan-time packing must be lossless");
        assert!(p.gates.is_none(), "plain preparation must not build gate panels");
    }

    #[test]
    fn prepared_gates_round_trip_and_gate_rule() {
        let mut rng = Pcg64::seeded(8);
        let w = Tensor::randn(&[3 * 11, 17], 0.3, &mut rng);
        let p = PreparedQMatrix::new_with_gates(quantize(&w));
        let gp = p.gates.as_ref().expect("(3H, k) weight must get gate panels");
        assert_eq!((gp.h(), gp.k()), (11, 17));
        assert_eq!(gp.unpack(), p.q, "gate packing must be lossless");
        // non-multiple-of-3 row counts fall back to no panels
        let odd = Tensor::randn(&[10, 17], 0.3, &mut rng);
        assert!(PreparedQMatrix::new_with_gates(quantize(&odd)).gates.is_none());
    }

    #[test]
    fn gemv_entry_point_bit_identical_to_batch1() {
        // the trait default *and* every override must match qgemm_ref at
        // m = 1 (deeper shape grid lives in rust/tests/backends.rs)
        let mut rng = Pcg64::seeded(9);
        for &(n, k) in &[(5usize, 3usize), (7, 8), (33, 100), (96, 320)] {
            let x = rand_i8(&[1, k], &mut rng);
            let wq = rand_i8(&[n, k], &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.021 });
            let want = qgemm_ref(&x, &wq, 0.013, 0.021);
            for (_, be) in all_backends() {
                let mut out = Tensor::zeros(&[0, 0]);
                be.qgemv_into(x.data(), &w, 0.013, &mut out);
                assert_eq!(out, want, "{} qgemv ({n},{k})", be.name());
            }
        }
    }

    #[test]
    fn fused_gates_entry_point_bit_identical_to_stacked() {
        let mut rng = Pcg64::seeded(10);
        for &(m, h, k) in &[(1usize, 5usize, 7usize), (3, 8, 16), (4, 33, 100)] {
            let x = rand_i8(&[m, k], &mut rng);
            let wq = rand_i8(&[3 * h, k], &mut rng);
            let w = PreparedQMatrix::new_with_gates(QMatrix { q: wq.clone(), scale: 0.017 });
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let want = qgemm_farm_rows(&x, &wq, &sx, 0.017);
            for (_, be) in all_backends() {
                let mut out = Tensor::zeros(&[0, 0]);
                be.qgemm_gates_rows_into(x.data(), m, &w, &sx, &mut out);
                assert_eq!(out, want, "{} fused gates ({m},{h},{k})", be.name());
            }
        }
    }

    fn rand_q4(n: usize, k: usize, rng: &mut Pcg64) -> Q4Matrix {
        crate::quant::quantize4(&Tensor::randn(&[n, k], 0.5, rng))
    }

    #[test]
    fn farm4_matches_nibble_reference_exactly() {
        let mut rng = Pcg64::seeded(11);
        for &(m, n, k) in &[(1, 7, 5), (2, 64, 31), (4, 33, 100), (8, 128, 320), (3, 96, 513)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w = rand_q4(n, k, &mut rng);
            let got = qgemm4_farm(&x, &w, 0.013);
            let want = qgemm4_ref(&x, &w, 0.013);
            assert_eq!(got, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn prepared_q4_round_trips_and_exposes_dims() {
        let mut rng = Pcg64::seeded(12);
        let w = rand_q4(37, 53, &mut rng);
        let p = PreparedQ4Matrix::new(w.clone());
        assert_eq!((p.n(), p.k()), (37, 53));
        assert_eq!(p.packed.unpack(), w, "plan-time int4 packing must be lossless");
        assert!(p.gates.is_none());
        assert_eq!(p.bytes(), w.payload_bytes());
        // gate preparation follows the same multiple-of-3 rule as int8
        let g = PreparedQ4Matrix::new_with_gates(rand_q4(3 * 11, 17, &mut rng));
        let gp = g.gates.as_ref().expect("(3H, k) int4 weight must get gate panels");
        assert_eq!((gp.h(), gp.k()), (11, 17));
        assert_eq!(gp.unpack(), g.q4, "int4 gate packing must be lossless");
        assert!(PreparedQ4Matrix::new_with_gates(rand_q4(10, 17, &mut rng)).gates.is_none());
    }

    #[test]
    fn gemv4_and_gates4_entry_points_bit_identical_to_reference() {
        let mut rng = Pcg64::seeded(13);
        for &(n, k) in &[(5usize, 3usize), (7, 8), (33, 100), (96, 320)] {
            let x = rand_i8(&[1, k], &mut rng);
            let w4 = rand_q4(n, k, &mut rng);
            let w = PreparedQ4Matrix::new(w4.clone());
            let want = qgemm4_ref(&x, &w4, 0.013);
            for (_, be) in all_backends() {
                let mut out = Tensor::zeros(&[0, 0]);
                be.qgemv4_into(x.data(), &w, 0.013, &mut out);
                assert_eq!(out, want, "{} qgemv4 ({n},{k})", be.name());
            }
        }
        for &(m, h, k) in &[(1usize, 5usize, 7usize), (3, 8, 16), (4, 33, 100)] {
            let x = rand_i8(&[m, k], &mut rng);
            let w4 = rand_q4(3 * h, k, &mut rng);
            let w = PreparedQ4Matrix::new_with_gates(w4.clone());
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let want = qgemm4_farm_rows(&x, &w4, &sx);
            for (_, be) in all_backends() {
                let mut out = Tensor::zeros(&[0, 0]);
                be.qgemm4_gates_rows_into(x.data(), m, &w, &sx, &mut out);
                assert_eq!(out, want, "{} fused gates4 ({m},{h},{k})", be.name());
            }
        }
    }

    #[test]
    fn farm4_counts_halve_the_weight_stream() {
        let (m, n, k) = (1usize, 6144usize, 320usize);
        let i8c = farm_counts(m, n, k);
        let i4c = farm4_counts(m, n, k);
        assert_eq!(i4c.macs, i8c.macs); // same useful work
        assert!(i4c.bytes_read < i8c.bytes_read);
        // nibble stream + group scales ≈ 0.625 bytes/weight at group 32
        let per_weight = (i4c.bytes_read - (m * k) as u64) as f64 / (n * k) as f64;
        assert!(per_weight < 0.65, "int4 bytes/weight {per_weight}");
    }
}

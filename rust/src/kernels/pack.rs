//! Plan-time weight packing: the [`PackedQMatrix`] layout consumed by the
//! `blocked` backend, and the gate-interleaved [`PackedGatePanels`] layout
//! consumed by the fused GRU-gate kernels.
//!
//! gemmlowp's pack-compute-unpack loses at small batch because the O(n·k)
//! packing traffic recurs **every call** (paper §4, [`super::qgemm_lowp`]).
//! The layout itself is not the problem — paying for it repeatedly is.
//! Both layouts here keep the favorable interleaving but are built exactly
//! once, when the engine is constructed or a registry artifact is loaded;
//! steady-state GEMMs then only ever read them.
//!
//! Layout (`nr` panel rows, `kc` k-strip; defaults [`NR`]=4, [`KC`]=256,
//! overridable per matrix by the [`super::autotune`] probe):
//!
//! ```text
//! source  w (n, k), row-major             packed, strip-major
//! ┌──────────── k ────────────┐
//! │ row 0                     │   strip 0 (cols 0..kc):
//! │ row 1                     │     panel 0: k-interleaved rows 0..nr
//! │ ...                       │       [w00 w10 w20 w30 | w01 w11 w21 w31 | ...]
//! │ row n-1                   │     panel 1: rows nr..2nr, same interleave
//! └───────────────────────────┘     ... panel ⌈n/nr⌉-1 (tail rows zero-padded)
//!                                 strip 1 (cols kc..2kc): panels again
//!                                 ... last strip ragged (k mod kc)
//! ```
//!
//! Within a panel, element `(row p·nr + r, col k0 + kk)` lives at
//! `kk·nr + r`: the `nr` weights a register tile needs for one activation
//! element are adjacent, so the kernel loads the activation once and
//! reads weights strictly sequentially.  Rows past `n` in the last panel
//! are stored as zeros and contribute nothing to the i32 accumulation, so
//! ragged `n` stays bit-exact; ragged `k` is handled by the final short
//! strip.  [`PackedQMatrix::unpack`] inverts the layout exactly —
//! `rust/tests/properties.rs` property-tests the round trip over all
//! `n mod nr` / `k mod kc` tails, including `k < 8`.
//!
//! [`PackedGatePanels`] is the GRU-specific variant (DESIGN.md §4): a
//! stacked `(3H, k)` recurrent weight holds the z-gate rows `0..H`, the
//! r-gate rows `H..2H` and the candidate rows `2H..3H`, so a stacked
//! sweep touches three weight rows that are `H·k` bytes apart to produce
//! one hidden unit's gates.  The gate-interleaved layout stores, per
//! k-strip, per hidden unit `j`, the three gate rows **adjacent**:
//!
//! ```text
//! strip s: [ z_0 | r_0 | h̃_0 ][ z_1 | r_1 | h̃_1 ] ... [ z_{H-1} | r_{H-1} | h̃_{H-1} ]
//!            kc     kc    kc     (each gate row segment is kc contiguous i8)
//! ```
//!
//! so the fused kernel computes all three gate products for unit `j` in
//! one strictly-sequential pass over `3·kc` weight bytes and scatters to
//! `out[j]`, `out[H+j]`, `out[2H+j]` — one sweep over the weights instead
//! of three.  Gate segments stay contiguous (no element interleave), so
//! the same vector dot products the plain kernels use apply unchanged.

use crate::tensor::TensorI8;

/// Default weight rows per packed panel (the register-tile height of the
/// farm schedule — 4 weight rows of i32 accumulators).
pub const NR: usize = 4;

/// Default columns per k-strip; strips keep the working set of one panel
/// pass inside L1 for paper-scale `k`.
pub const KC: usize = 256;

/// Largest panel height any autotune candidate may request (the generic
/// packed core carries this many accumulators).
pub const MAX_NR: usize = 8;

/// An int8 weight matrix in nr-panel, kc-strip interleaved layout,
/// packed once at plan time (see module docs for the layout diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQMatrix {
    n: usize,
    k: usize,
    nr: usize,
    kc: usize,
    data: Vec<i8>,
}

impl PackedQMatrix {
    /// Pack a row-major `(n, k)` matrix with the default [`NR`]/[`KC`]
    /// tile.  O(n·k), runs once per weight at engine construction /
    /// registry load.
    pub fn pack(wq: &TensorI8) -> PackedQMatrix {
        PackedQMatrix::pack_with(wq, NR, KC)
    }

    /// Pack with an explicit `(nr, kc)` tile shape — the autotune probe
    /// ([`super::autotune`]) picks these per weight; `pack` is the pinned
    /// default.  Any `1 ≤ nr ≤ MAX_NR` stays bit-exact (padding rows are
    /// zero and i32 accumulation is exact).
    pub fn pack_with(wq: &TensorI8, nr: usize, kc: usize) -> PackedQMatrix {
        assert!(nr >= 1 && nr <= MAX_NR, "panel height {nr} out of range");
        assert!(kc >= 1, "k-strip width must be >= 1");
        let (n, k) = (wq.rows(), wq.cols());
        let npanels = n.div_ceil(nr);
        let nstrips = k.div_ceil(kc);
        let mut data = vec![0i8; npanels * nr * k];
        for s in 0..nstrips {
            let k0 = s * kc;
            let kcs = kc.min(k - k0);
            let strip_base = npanels * nr * k0;
            for p in 0..npanels {
                let pbase = strip_base + p * nr * kcs;
                for r in 0..nr {
                    let row = p * nr + r;
                    if row >= n {
                        continue; // padding rows stay zero
                    }
                    for (kk, &v) in wq.row(row)[k0..k0 + kcs].iter().enumerate() {
                        data[pbase + kk * nr + r] = v;
                    }
                }
            }
        }
        PackedQMatrix { n, k, nr, kc, data }
    }

    /// Output dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Panel height this matrix was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// k-strip width this matrix was packed with.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Bytes held by the packed copy (footprint accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Columns in strip `s` (`kc`, or the ragged tail for the last strip).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        self.kc.min(self.k - s * self.kc)
    }

    /// The interleaved `(kcs × nr)` block of (strip `s`, panel `p`).
    #[inline]
    pub(crate) fn panel(&self, s: usize, p: usize) -> &[i8] {
        let k0 = s * self.kc;
        let kcs = self.kc.min(self.k - k0);
        let npanels = self.n.div_ceil(self.nr);
        let base = npanels * self.nr * k0 + p * self.nr * kcs;
        &self.data[base..base + self.nr * kcs]
    }

    /// Exact inverse of [`PackedQMatrix::pack_with`] (drops the padding).
    pub fn unpack(&self) -> TensorI8 {
        let mut out = TensorI8::zeros(&[self.n, self.k]);
        let npanels = self.n.div_ceil(self.nr);
        let nstrips = self.k.div_ceil(self.kc);
        for s in 0..nstrips {
            let k0 = s * self.kc;
            let kcs = self.strip_cols(s);
            for p in 0..npanels {
                let panel = self.panel(s, p);
                for r in 0..self.nr {
                    let row = p * self.nr + r;
                    if row >= self.n {
                        continue;
                    }
                    for kk in 0..kcs {
                        out.data_mut()[row * self.k + k0 + kk] = panel[kk * self.nr + r];
                    }
                }
            }
        }
        out
    }
}

/// A stacked `(3H, k)` GRU gate weight in the gate-interleaved `[z|r|h̃]`
/// layout of the module docs: per k-strip, per hidden unit `j`, the three
/// gate rows adjacent as contiguous `kc`-byte segments.  Built once at
/// engine construction / registry load by
/// [`super::PreparedQMatrix::new_with_gates`]; consumed by the fused
/// gate kernels of the blocked and simd backends.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedGatePanels {
    h: usize,
    k: usize,
    data: Vec<i8>,
}

impl PackedGatePanels {
    /// Pack a stacked `(3H, k)` gate matrix (rows `[z; r; h̃]`, the GRU
    /// layout [`crate::infer`] uses throughout).  Panics unless the row
    /// count is a positive multiple of 3.
    pub fn pack(wq: &TensorI8) -> PackedGatePanels {
        let (n, k) = (wq.rows(), wq.cols());
        assert!(n > 0 && n % 3 == 0, "gate panels need a (3H, k) matrix, got {n} rows");
        let h = n / 3;
        let nstrips = k.div_ceil(KC);
        let mut data = vec![0i8; 3 * h * k];
        for s in 0..nstrips {
            let k0 = s * KC;
            let kcs = KC.min(k - k0);
            let strip_base = 3 * h * k0;
            for j in 0..h {
                let block = strip_base + j * 3 * kcs;
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    data[block + g * kcs..block + (g + 1) * kcs]
                        .copy_from_slice(&wq.row(row)[k0..k0 + kcs]);
                }
            }
        }
        PackedGatePanels { h, k, data }
    }

    /// Hidden width `H` (output dimension is `3H`).
    pub fn h(&self) -> usize {
        self.h
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bytes held by the packed copy (footprint accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Columns in strip `s` ([`KC`], or the ragged tail).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        KC.min(self.k - s * KC)
    }

    /// Number of k-strips.
    #[inline]
    pub(crate) fn nstrips(&self) -> usize {
        self.k.div_ceil(KC)
    }

    /// The `[z_j | r_j | h̃_j]` block of (strip `s`, hidden unit `j`):
    /// three contiguous gate segments of `strip_cols(s)` bytes each.
    #[inline]
    pub(crate) fn block(&self, s: usize, j: usize) -> &[i8] {
        let k0 = s * KC;
        let kcs = KC.min(self.k - k0);
        let base = 3 * self.h * k0 + j * 3 * kcs;
        &self.data[base..base + 3 * kcs]
    }

    /// Exact inverse of [`PackedGatePanels::pack`]: the `(3H, k)` stacked
    /// gate matrix (round-trip property-tested in
    /// `rust/tests/properties.rs`).
    pub fn unpack(&self) -> TensorI8 {
        let (h, k) = (self.h, self.k);
        let mut out = TensorI8::zeros(&[3 * h, k]);
        for s in 0..self.nstrips() {
            let k0 = s * KC;
            let kcs = self.strip_cols(s);
            for j in 0..h {
                let block = self.block(s, j);
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    out.data_mut()[row * k + k0..row * k + k0 + kcs]
                        .copy_from_slice(&block[g * kcs..(g + 1) * kcs]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn rand_i8(n: usize, k: usize, rng: &mut Pcg64) -> TensorI8 {
        let data: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(&[n, k], data).unwrap()
    }

    #[test]
    fn round_trip_exhaustive_small_tails() {
        // every n mod NR residue × every interesting k tail, incl. k < 8
        // (the dot_i8 unroll tail) and the KC strip boundary
        let mut rng = Pcg64::seeded(0);
        for n in 1..=9usize {
            for &k in &[1usize, 2, 3, 5, 7, 8, 9, 255, 256, 257, 511, 512, 513] {
                let w = rand_i8(n, k, &mut rng);
                let p = PackedQMatrix::pack(&w);
                assert_eq!(p.unpack(), w, "({n},{k})");
            }
        }
    }

    #[test]
    fn round_trip_with_explicit_tiles() {
        // every autotune candidate tile shape must round-trip on ragged
        // shapes too — tile choice may never change stored weights
        let mut rng = Pcg64::seeded(3);
        for &(nr, kc) in &[(4usize, 128usize), (4, 512), (8, 128), (8, 256), (8, 512), (1, 1)] {
            for &(n, k) in &[(1usize, 1usize), (7, 9), (9, 130), (17, 513)] {
                let w = rand_i8(n, k, &mut rng);
                let p = PackedQMatrix::pack_with(&w, nr, kc);
                assert_eq!((p.nr(), p.kc()), (nr, kc));
                assert_eq!(p.unpack(), w, "nr {nr} kc {kc} ({n},{k})");
            }
        }
    }

    #[test]
    fn packed_size_is_padded_rows_times_k() {
        let mut rng = Pcg64::seeded(1);
        let w = rand_i8(6, 300, &mut rng);
        let p = PackedQMatrix::pack(&w);
        assert_eq!(p.bytes(), 8 * 300, "6 rows pad to 2 panels of 4");
        assert_eq!((p.n(), p.k()), (6, 300));
    }

    #[test]
    fn strip_accounting_covers_k() {
        let mut rng = Pcg64::seeded(2);
        let w = rand_i8(4, 2 * KC + 17, &mut rng);
        let p = PackedQMatrix::pack(&w);
        let total: usize = (0..3).map(|s| p.strip_cols(s)).sum();
        assert_eq!(total, 2 * KC + 17);
        assert_eq!(p.strip_cols(2), 17);
    }

    #[test]
    fn gate_panels_round_trip_and_blocks() {
        let mut rng = Pcg64::seeded(4);
        for &(h, k) in &[(1usize, 1usize), (3, 7), (5, 256), (4, 257), (7, 513), (32, 100)] {
            let w = rand_i8(3 * h, k, &mut rng);
            let gp = PackedGatePanels::pack(&w);
            assert_eq!((gp.h(), gp.k()), (h, k));
            assert_eq!(gp.bytes(), 3 * h * k, "no padding in the gate layout");
            assert_eq!(gp.unpack(), w, "({h},{k})");
            // block (s=0, j) holds the three gate rows' strip-0 prefixes
            let kcs = gp.strip_cols(0);
            for j in 0..h {
                let b = gp.block(0, j);
                assert_eq!(&b[..kcs], &w.row(j)[..kcs], "z_{j}");
                assert_eq!(&b[kcs..2 * kcs], &w.row(h + j)[..kcs], "r_{j}");
                assert_eq!(&b[2 * kcs..], &w.row(2 * h + j)[..kcs], "h̃_{j}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "gate panels")]
    fn gate_panels_reject_non_gate_row_counts() {
        let mut rng = Pcg64::seeded(5);
        let w = rand_i8(7, 5, &mut rng);
        let _ = PackedGatePanels::pack(&w);
    }
}

//! Plan-time weight packing: the [`PackedQMatrix`] layout consumed by the
//! `blocked` backend.
//!
//! gemmlowp's pack-compute-unpack loses at small batch because the O(n·k)
//! packing traffic recurs **every call** (paper §4, [`super::qgemm_lowp`]).
//! The layout itself is not the problem — paying for it repeatedly is.
//! `PackedQMatrix` keeps the favorable layout but builds it exactly once,
//! when the engine is constructed or a registry artifact is loaded;
//! steady-state GEMMs then only ever read it.
//!
//! Layout (`NR = 4` panel rows, `KC = 256` k-strip):
//!
//! ```text
//! source  w (n, k), row-major             packed, strip-major
//! ┌──────────── k ────────────┐
//! │ row 0                     │   strip 0 (cols 0..KC):
//! │ row 1                     │     panel 0: k-interleaved rows 0..4
//! │ ...                       │       [w00 w10 w20 w30 | w01 w11 w21 w31 | ...]
//! │ row n-1                   │     panel 1: rows 4..8, same interleave
//! └───────────────────────────┘     ... panel ⌈n/NR⌉-1 (tail rows zero-padded)
//!                                 strip 1 (cols KC..2KC): panels again
//!                                 ... last strip ragged (kc = k mod KC)
//! ```
//!
//! Within a panel, element `(row p·NR + r, col k0 + kk)` lives at
//! `kk·NR + r`: the four weights a register tile needs for one activation
//! element are adjacent, so the kernel loads the activation once and
//! reads weights strictly sequentially.  Rows past `n` in the last panel
//! are stored as zeros and contribute nothing to the i32 accumulation, so
//! ragged `n` stays bit-exact; ragged `k` is handled by the final short
//! strip.  [`PackedQMatrix::unpack`] inverts the layout exactly —
//! `rust/tests/properties.rs` property-tests the round trip over all
//! `n mod NR` / `k mod KC` tails, including `k < 8`.

use crate::tensor::TensorI8;

/// Weight rows per packed panel (the register-tile height of the farm
/// schedule — 4 weight rows of i32 accumulators).
pub const NR: usize = 4;

/// Columns per k-strip; strips keep the working set of one panel pass
/// inside L1 for paper-scale `k`.
pub const KC: usize = 256;

/// An int8 weight matrix in NR-panel, KC-strip interleaved layout,
/// packed once at plan time (see module docs for the layout diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQMatrix {
    n: usize,
    k: usize,
    data: Vec<i8>,
}

impl PackedQMatrix {
    /// Pack a row-major `(n, k)` matrix.  O(n·k), runs once per weight
    /// at engine construction / registry load.
    pub fn pack(wq: &TensorI8) -> PackedQMatrix {
        let (n, k) = (wq.rows(), wq.cols());
        let npanels = n.div_ceil(NR);
        let nstrips = k.div_ceil(KC);
        let mut data = vec![0i8; npanels * NR * k];
        for s in 0..nstrips {
            let k0 = s * KC;
            let kc = KC.min(k - k0);
            let strip_base = npanels * NR * k0;
            for p in 0..npanels {
                let pbase = strip_base + p * NR * kc;
                for r in 0..NR {
                    let row = p * NR + r;
                    if row >= n {
                        continue; // padding rows stay zero
                    }
                    for (kk, &v) in wq.row(row)[k0..k0 + kc].iter().enumerate() {
                        data[pbase + kk * NR + r] = v;
                    }
                }
            }
        }
        PackedQMatrix { n, k, data }
    }

    /// Output dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bytes held by the packed copy (footprint accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Columns in strip `s` (`KC`, or the ragged tail for the last strip).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        KC.min(self.k - s * KC)
    }

    /// The interleaved `(kc × NR)` block of (strip `s`, panel `p`).
    #[inline]
    pub(crate) fn panel(&self, s: usize, p: usize) -> &[i8] {
        let k0 = s * KC;
        let kc = KC.min(self.k - k0);
        let npanels = self.n.div_ceil(NR);
        let base = npanels * NR * k0 + p * NR * kc;
        &self.data[base..base + NR * kc]
    }

    /// Exact inverse of [`PackedQMatrix::pack`] (drops the zero padding).
    pub fn unpack(&self) -> TensorI8 {
        let mut out = TensorI8::zeros(&[self.n, self.k]);
        let npanels = self.n.div_ceil(NR);
        let nstrips = self.k.div_ceil(KC);
        for s in 0..nstrips {
            let k0 = s * KC;
            let kc = self.strip_cols(s);
            for p in 0..npanels {
                let panel = self.panel(s, p);
                for r in 0..NR {
                    let row = p * NR + r;
                    if row >= self.n {
                        continue;
                    }
                    for kk in 0..kc {
                        out.data_mut()[row * self.k + k0 + kk] = panel[kk * NR + r];
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn rand_i8(n: usize, k: usize, rng: &mut Pcg64) -> TensorI8 {
        let data: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(&[n, k], data).unwrap()
    }

    #[test]
    fn round_trip_exhaustive_small_tails() {
        // every n mod NR residue × every interesting k tail, incl. k < 8
        // (the dot_i8 unroll tail) and the KC strip boundary
        let mut rng = Pcg64::seeded(0);
        for n in 1..=9usize {
            for &k in &[1usize, 2, 3, 5, 7, 8, 9, 255, 256, 257, 511, 512, 513] {
                let w = rand_i8(n, k, &mut rng);
                let p = PackedQMatrix::pack(&w);
                assert_eq!(p.unpack(), w, "({n},{k})");
            }
        }
    }

    #[test]
    fn packed_size_is_padded_rows_times_k() {
        let mut rng = Pcg64::seeded(1);
        let w = rand_i8(6, 300, &mut rng);
        let p = PackedQMatrix::pack(&w);
        assert_eq!(p.bytes(), 8 * 300, "6 rows pad to 2 panels of 4");
        assert_eq!((p.n(), p.k()), (6, 300));
    }

    #[test]
    fn strip_accounting_covers_k() {
        let mut rng = Pcg64::seeded(2);
        let w = rand_i8(4, 2 * KC + 17, &mut rng);
        let p = PackedQMatrix::pack(&w);
        let total: usize = (0..3).map(|s| p.strip_cols(s)).sum();
        assert_eq!(total, 2 * KC + 17);
        assert_eq!(p.strip_cols(2), 17);
    }
}

//! Plan-time weight packing: the [`PackedQMatrix`] layout consumed by the
//! `blocked` backend, and the gate-interleaved [`PackedGatePanels`] layout
//! consumed by the fused GRU-gate kernels.
//!
//! gemmlowp's pack-compute-unpack loses at small batch because the O(n·k)
//! packing traffic recurs **every call** (paper §4, [`super::qgemm_lowp`]).
//! The layout itself is not the problem — paying for it repeatedly is.
//! Both layouts here keep the favorable interleaving but are built exactly
//! once, when the engine is constructed or a registry artifact is loaded;
//! steady-state GEMMs then only ever read them.
//!
//! Layout (`nr` panel rows, `kc` k-strip; defaults [`NR`]=4, [`KC`]=256,
//! overridable per matrix by the [`super::autotune`] probe):
//!
//! ```text
//! source  w (n, k), row-major             packed, strip-major
//! ┌──────────── k ────────────┐
//! │ row 0                     │   strip 0 (cols 0..kc):
//! │ row 1                     │     panel 0: k-interleaved rows 0..nr
//! │ ...                       │       [w00 w10 w20 w30 | w01 w11 w21 w31 | ...]
//! │ row n-1                   │     panel 1: rows nr..2nr, same interleave
//! └───────────────────────────┘     ... panel ⌈n/nr⌉-1 (tail rows zero-padded)
//!                                 strip 1 (cols kc..2kc): panels again
//!                                 ... last strip ragged (k mod kc)
//! ```
//!
//! Within a panel, element `(row p·nr + r, col k0 + kk)` lives at
//! `kk·nr + r`: the `nr` weights a register tile needs for one activation
//! element are adjacent, so the kernel loads the activation once and
//! reads weights strictly sequentially.  Rows past `n` in the last panel
//! are stored as zeros and contribute nothing to the i32 accumulation, so
//! ragged `n` stays bit-exact; ragged `k` is handled by the final short
//! strip.  [`PackedQMatrix::unpack`] inverts the layout exactly —
//! `rust/tests/properties.rs` property-tests the round trip over all
//! `n mod nr` / `k mod kc` tails, including `k < 8`.
//!
//! [`PackedGatePanels`] is the GRU-specific variant (DESIGN.md §4): a
//! stacked `(3H, k)` recurrent weight holds the z-gate rows `0..H`, the
//! r-gate rows `H..2H` and the candidate rows `2H..3H`, so a stacked
//! sweep touches three weight rows that are `H·k` bytes apart to produce
//! one hidden unit's gates.  The gate-interleaved layout stores, per
//! k-strip, per hidden unit `j`, the three gate rows **adjacent**:
//!
//! ```text
//! strip s: [ z_0 | r_0 | h̃_0 ][ z_1 | r_1 | h̃_1 ] ... [ z_{H-1} | r_{H-1} | h̃_{H-1} ]
//!            kc     kc    kc     (each gate row segment is kc contiguous i8)
//! ```
//!
//! so the fused kernel computes all three gate products for unit `j` in
//! one strictly-sequential pass over `3·kc` weight bytes and scatters to
//! `out[j]`, `out[H+j]`, `out[2H+j]` — one sweep over the weights instead
//! of three.  Gate segments stay contiguous (no element interleave), so
//! the same vector dot products the plain kernels use apply unchanged.
//!
//! [`PackedQ4Matrix`] / [`PackedQ4GatePanels`] are the sub-byte variants
//! (DESIGN.md §4): the same panel/strip/block structure with two
//! twos-complement nibbles per byte — byte `t·nr + r` of a panel holds
//! columns `k0+2t` (low nibble) and `k0+2t+1` (high nibble) of panel row
//! `r` — and the per-group f32 scales of [`crate::quant::Q4Matrix`]
//! stored alongside each strip in matching `(group, r)` interleave, so a
//! kernel walking a strip reads nibble bytes and the scales it needs to
//! close each group strictly sequentially.  Strip widths must be a
//! multiple of the (even) scale-group width, so a strip always covers
//! whole groups and the per-group i32 sub-accumulation never straddles a
//! strip boundary — the invariant the int4 bit-identity contract rests
//! on.  Every autotune candidate satisfies it ([`super::autotune`]).

use crate::quant::Q4Matrix;
use crate::tensor::TensorI8;

/// Default weight rows per packed panel (the register-tile height of the
/// farm schedule — 4 weight rows of i32 accumulators).
pub const NR: usize = 4;

/// Default columns per k-strip; strips keep the working set of one panel
/// pass inside L1 for paper-scale `k`.
pub const KC: usize = 256;

/// Largest panel height any autotune candidate may request (the generic
/// packed core carries this many accumulators).
pub const MAX_NR: usize = 8;

/// An int8 weight matrix in nr-panel, kc-strip interleaved layout,
/// packed once at plan time (see module docs for the layout diagram).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQMatrix {
    n: usize,
    k: usize,
    nr: usize,
    kc: usize,
    data: Vec<i8>,
}

impl PackedQMatrix {
    /// Pack a row-major `(n, k)` matrix with the default [`NR`]/[`KC`]
    /// tile.  O(n·k), runs once per weight at engine construction /
    /// registry load.
    pub fn pack(wq: &TensorI8) -> PackedQMatrix {
        PackedQMatrix::pack_with(wq, NR, KC)
    }

    /// Pack with an explicit `(nr, kc)` tile shape — the autotune probe
    /// ([`super::autotune`]) picks these per weight; `pack` is the pinned
    /// default.  Any `1 ≤ nr ≤ MAX_NR` stays bit-exact (padding rows are
    /// zero and i32 accumulation is exact).
    pub fn pack_with(wq: &TensorI8, nr: usize, kc: usize) -> PackedQMatrix {
        assert!(nr >= 1 && nr <= MAX_NR, "panel height {nr} out of range");
        assert!(kc >= 1, "k-strip width must be >= 1");
        let (n, k) = (wq.rows(), wq.cols());
        let npanels = n.div_ceil(nr);
        let nstrips = k.div_ceil(kc);
        let mut data = vec![0i8; npanels * nr * k];
        for s in 0..nstrips {
            let k0 = s * kc;
            let kcs = kc.min(k - k0);
            let strip_base = npanels * nr * k0;
            for p in 0..npanels {
                let pbase = strip_base + p * nr * kcs;
                for r in 0..nr {
                    let row = p * nr + r;
                    if row >= n {
                        continue; // padding rows stay zero
                    }
                    for (kk, &v) in wq.row(row)[k0..k0 + kcs].iter().enumerate() {
                        data[pbase + kk * nr + r] = v;
                    }
                }
            }
        }
        PackedQMatrix { n, k, nr, kc, data }
    }

    /// Output dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Panel height this matrix was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// k-strip width this matrix was packed with.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Bytes held by the packed copy (footprint accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Columns in strip `s` (`kc`, or the ragged tail for the last strip).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        self.kc.min(self.k - s * self.kc)
    }

    /// The interleaved `(kcs × nr)` block of (strip `s`, panel `p`).
    #[inline]
    pub(crate) fn panel(&self, s: usize, p: usize) -> &[i8] {
        let k0 = s * self.kc;
        let kcs = self.kc.min(self.k - k0);
        let npanels = self.n.div_ceil(self.nr);
        let base = npanels * self.nr * k0 + p * self.nr * kcs;
        &self.data[base..base + self.nr * kcs]
    }

    /// Exact inverse of [`PackedQMatrix::pack_with`] (drops the padding).
    pub fn unpack(&self) -> TensorI8 {
        let mut out = TensorI8::zeros(&[self.n, self.k]);
        let npanels = self.n.div_ceil(self.nr);
        let nstrips = self.k.div_ceil(self.kc);
        for s in 0..nstrips {
            let k0 = s * self.kc;
            let kcs = self.strip_cols(s);
            for p in 0..npanels {
                let panel = self.panel(s, p);
                for r in 0..self.nr {
                    let row = p * self.nr + r;
                    if row >= self.n {
                        continue;
                    }
                    for kk in 0..kcs {
                        out.data_mut()[row * self.k + k0 + kk] = panel[kk * self.nr + r];
                    }
                }
            }
        }
        out
    }
}

/// A stacked `(3H, k)` GRU gate weight in the gate-interleaved `[z|r|h̃]`
/// layout of the module docs: per k-strip, per hidden unit `j`, the three
/// gate rows adjacent as contiguous `kc`-byte segments.  Built once at
/// engine construction / registry load by
/// [`super::PreparedQMatrix::new_with_gates`]; consumed by the fused
/// gate kernels of the blocked and simd backends.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedGatePanels {
    h: usize,
    k: usize,
    data: Vec<i8>,
}

impl PackedGatePanels {
    /// Pack a stacked `(3H, k)` gate matrix (rows `[z; r; h̃]`, the GRU
    /// layout [`crate::infer`] uses throughout).  Panics unless the row
    /// count is a positive multiple of 3.
    pub fn pack(wq: &TensorI8) -> PackedGatePanels {
        let (n, k) = (wq.rows(), wq.cols());
        assert!(n > 0 && n % 3 == 0, "gate panels need a (3H, k) matrix, got {n} rows");
        let h = n / 3;
        let nstrips = k.div_ceil(KC);
        let mut data = vec![0i8; 3 * h * k];
        for s in 0..nstrips {
            let k0 = s * KC;
            let kcs = KC.min(k - k0);
            let strip_base = 3 * h * k0;
            for j in 0..h {
                let block = strip_base + j * 3 * kcs;
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    data[block + g * kcs..block + (g + 1) * kcs]
                        .copy_from_slice(&wq.row(row)[k0..k0 + kcs]);
                }
            }
        }
        PackedGatePanels { h, k, data }
    }

    /// Hidden width `H` (output dimension is `3H`).
    pub fn h(&self) -> usize {
        self.h
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bytes held by the packed copy (footprint accounting).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Columns in strip `s` ([`KC`], or the ragged tail).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        KC.min(self.k - s * KC)
    }

    /// Number of k-strips.
    #[inline]
    pub(crate) fn nstrips(&self) -> usize {
        self.k.div_ceil(KC)
    }

    /// The `[z_j | r_j | h̃_j]` block of (strip `s`, hidden unit `j`):
    /// three contiguous gate segments of `strip_cols(s)` bytes each.
    #[inline]
    pub(crate) fn block(&self, s: usize, j: usize) -> &[i8] {
        let k0 = s * KC;
        let kcs = KC.min(self.k - k0);
        let base = 3 * self.h * k0 + j * 3 * kcs;
        &self.data[base..base + 3 * kcs]
    }

    /// Exact inverse of [`PackedGatePanels::pack`]: the `(3H, k)` stacked
    /// gate matrix (round-trip property-tested in
    /// `rust/tests/properties.rs`).
    pub fn unpack(&self) -> TensorI8 {
        let (h, k) = (self.h, self.k);
        let mut out = TensorI8::zeros(&[3 * h, k]);
        for s in 0..self.nstrips() {
            let k0 = s * KC;
            let kcs = self.strip_cols(s);
            for j in 0..h {
                let block = self.block(s, j);
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    out.data_mut()[row * k + k0..row * k + k0 + kcs]
                        .copy_from_slice(&block[g * kcs..(g + 1) * kcs]);
                }
            }
        }
        out
    }
}

/// An int4 weight matrix in nr-panel, kc-strip nibble layout with
/// per-group scales stored strip-major alongside the data (module docs).
/// Packed once at plan time from a row-major [`Q4Matrix`]; consumed by
/// the blocked backend's int4 packed core.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQ4Matrix {
    n: usize,
    k: usize,
    nr: usize,
    kc: usize,
    group: usize,
    data: Vec<u8>,
    scales: Vec<f32>,
}

impl PackedQ4Matrix {
    /// Pack with the default [`NR`]/[`KC`] tile.
    pub fn pack(q4: &Q4Matrix) -> PackedQ4Matrix {
        PackedQ4Matrix::pack_with(q4, NR, KC)
    }

    /// Pack with an explicit `(nr, kc)` tile.  `kc` must be a positive
    /// multiple of the matrix's (even) scale-group width so strips cover
    /// whole groups — every [`super::autotune`] candidate does.
    pub fn pack_with(q4: &Q4Matrix, nr: usize, kc: usize) -> PackedQ4Matrix {
        assert!(nr >= 1 && nr <= MAX_NR, "panel height {nr} out of range");
        let group = q4.group();
        assert!(group % 2 == 0, "int4 packing needs an even scale group, got {group}");
        assert!(
            kc >= group && kc % group == 0,
            "k-strip width {kc} must be a positive multiple of the scale group {group}"
        );
        let (n, k) = (q4.rows(), q4.cols());
        let ngroups = q4.ngroups();
        let npanels = n.div_ceil(nr);
        let nstrips = k.div_ceil(kc);
        let mut data = vec![0u8; npanels * nr * k.div_ceil(2)];
        let mut scales = vec![0.0f32; npanels * nr * ngroups];
        for s in 0..nstrips {
            let k0 = s * kc;
            let kcs = kc.min(k - k0);
            let pairs = kcs.div_ceil(2);
            let gs = kcs.div_ceil(group);
            for p in 0..npanels {
                // k0 is even (kc is) and a group multiple, so the strip's
                // byte/scale offsets into a source row are exact
                let dbase = npanels * nr * (k0 / 2) + p * nr * pairs;
                let sbase = npanels * nr * (k0 / group) + p * nr * gs;
                for r in 0..nr {
                    let row = p * nr + r;
                    if row >= n {
                        continue; // padding rows stay zero nibbles / zero scales
                    }
                    let rowb = q4.row_data(row);
                    for t in 0..pairs {
                        data[dbase + t * nr + r] = rowb[k0 / 2 + t];
                    }
                    let rows = q4.row_scales(row);
                    for g in 0..gs {
                        scales[sbase + g * nr + r] = rows[k0 / group + g];
                    }
                }
            }
        }
        PackedQ4Matrix { n, k, nr, kc, group, data, scales }
    }

    /// Output dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Panel height this matrix was packed with.
    pub fn nr(&self) -> usize {
        self.nr
    }

    /// k-strip width this matrix was packed with.
    pub fn kc(&self) -> usize {
        self.kc
    }

    /// Scale-group width (columns per f32 scale).
    pub fn group(&self) -> usize {
        self.group
    }

    /// Bytes held by the packed copy (nibble bytes + scale bytes).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Columns in strip `s` (`kc`, or the ragged tail for the last strip).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        self.kc.min(self.k - s * self.kc)
    }

    /// The nibble-interleaved `(⌈kcs/2⌉ × nr)` byte block of
    /// (strip `s`, panel `p`).
    #[inline]
    pub(crate) fn panel(&self, s: usize, p: usize) -> &[u8] {
        let k0 = s * self.kc;
        let pairs = self.strip_cols(s).div_ceil(2);
        let npanels = self.n.div_ceil(self.nr);
        let base = npanels * self.nr * (k0 / 2) + p * self.nr * pairs;
        &self.data[base..base + self.nr * pairs]
    }

    /// The `(groups-in-strip × nr)` scale block of (strip `s`, panel `p`),
    /// indexed `g·nr + r`.
    #[inline]
    pub(crate) fn panel_scales(&self, s: usize, p: usize) -> &[f32] {
        let k0 = s * self.kc;
        let gs = self.strip_cols(s).div_ceil(self.group);
        let npanels = self.n.div_ceil(self.nr);
        let base = npanels * self.nr * (k0 / self.group) + p * self.nr * gs;
        &self.scales[base..base + self.nr * gs]
    }

    /// Exact inverse of [`PackedQ4Matrix::pack_with`] (drops the padding).
    pub fn unpack(&self) -> Q4Matrix {
        let rb = self.k.div_ceil(2);
        let ngroups = self.k.div_ceil(self.group);
        let mut data = vec![0u8; self.n * rb];
        let mut scales = vec![0.0f32; self.n * ngroups];
        let npanels = self.n.div_ceil(self.nr);
        for s in 0..self.k.div_ceil(self.kc) {
            let k0 = s * self.kc;
            let kcs = self.strip_cols(s);
            let pairs = kcs.div_ceil(2);
            let gs = kcs.div_ceil(self.group);
            for p in 0..npanels {
                let panel = self.panel(s, p);
                let ps = self.panel_scales(s, p);
                for r in 0..self.nr {
                    let row = p * self.nr + r;
                    if row >= self.n {
                        continue;
                    }
                    for t in 0..pairs {
                        data[row * rb + k0 / 2 + t] = panel[t * self.nr + r];
                    }
                    for g in 0..gs {
                        scales[row * ngroups + k0 / self.group + g] = ps[g * self.nr + r];
                    }
                }
            }
        }
        Q4Matrix::from_parts(self.n, self.k, self.group, data, scales)
            .expect("packed q4 shape bookkeeping")
    }
}

/// The int4 gate-interleaved variant of [`PackedGatePanels`]: per
/// [`KC`]-strip, per hidden unit `j`, the three `[z_j | r_j | h̃_j]` gate
/// rows adjacent as contiguous nibble segments of `⌈kcs/2⌉` bytes each,
/// with the matching per-group scales blocked the same way
/// (`[z scales | r scales | h̃ scales]` per unit per strip).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedQ4GatePanels {
    h: usize,
    k: usize,
    group: usize,
    data: Vec<u8>,
    scales: Vec<f32>,
}

impl PackedQ4GatePanels {
    /// Pack a stacked `(3H, k)` int4 gate matrix.  Panics unless the row
    /// count is a positive multiple of 3 and the scale group is even and
    /// divides [`KC`].
    pub fn pack(q4: &Q4Matrix) -> PackedQ4GatePanels {
        let (n, k) = (q4.rows(), q4.cols());
        assert!(n > 0 && n % 3 == 0, "gate panels need a (3H, k) matrix, got {n} rows");
        let group = q4.group();
        assert!(
            group % 2 == 0 && KC % group == 0,
            "int4 gate panels need an even scale group dividing KC, got {group}"
        );
        let h = n / 3;
        let nstrips = k.div_ceil(KC);
        let ngroups = q4.ngroups();
        let mut data = vec![0u8; 3 * h * k.div_ceil(2)];
        let mut scales = vec![0.0f32; 3 * h * ngroups];
        for s in 0..nstrips {
            let k0 = s * KC;
            let kcs = KC.min(k - k0);
            let pairs = kcs.div_ceil(2);
            let gs = kcs.div_ceil(group);
            for j in 0..h {
                let dblock = 3 * h * (k0 / 2) + j * 3 * pairs;
                let sblock = 3 * h * (k0 / group) + j * 3 * gs;
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    data[dblock + g * pairs..dblock + (g + 1) * pairs]
                        .copy_from_slice(&q4.row_data(row)[k0 / 2..k0 / 2 + pairs]);
                    scales[sblock + g * gs..sblock + (g + 1) * gs]
                        .copy_from_slice(&q4.row_scales(row)[k0 / group..k0 / group + gs]);
                }
            }
        }
        PackedQ4GatePanels { h, k, group, data, scales }
    }

    /// Hidden width `H` (output dimension is `3H`).
    pub fn h(&self) -> usize {
        self.h
    }

    /// Contraction dimension `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Scale-group width (columns per f32 scale).
    pub fn group(&self) -> usize {
        self.group
    }

    /// Bytes held by the packed copy (nibble bytes + scale bytes).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Columns in strip `s` ([`KC`], or the ragged tail).
    #[inline]
    pub(crate) fn strip_cols(&self, s: usize) -> usize {
        KC.min(self.k - s * KC)
    }

    /// Number of k-strips.
    #[inline]
    pub(crate) fn nstrips(&self) -> usize {
        self.k.div_ceil(KC)
    }

    /// The `[z_j | r_j | h̃_j]` nibble block of (strip `s`, unit `j`):
    /// three contiguous gate segments of `⌈strip_cols(s)/2⌉` bytes each.
    #[inline]
    pub(crate) fn block(&self, s: usize, j: usize) -> &[u8] {
        let k0 = s * KC;
        let pairs = self.strip_cols(s).div_ceil(2);
        let base = 3 * self.h * (k0 / 2) + j * 3 * pairs;
        &self.data[base..base + 3 * pairs]
    }

    /// The matching scale block of (strip `s`, unit `j`): three contiguous
    /// gate segments of `⌈strip_cols(s)/group⌉` f32 scales each.
    #[inline]
    pub(crate) fn block_scales(&self, s: usize, j: usize) -> &[f32] {
        let k0 = s * KC;
        let gs = self.strip_cols(s).div_ceil(self.group);
        let base = 3 * self.h * (k0 / self.group) + j * 3 * gs;
        &self.scales[base..base + 3 * gs]
    }

    /// Exact inverse of [`PackedQ4GatePanels::pack`].
    pub fn unpack(&self) -> Q4Matrix {
        let (h, k) = (self.h, self.k);
        let rb = k.div_ceil(2);
        let ngroups = k.div_ceil(self.group);
        let mut data = vec![0u8; 3 * h * rb];
        let mut scales = vec![0.0f32; 3 * h * ngroups];
        for s in 0..self.nstrips() {
            let k0 = s * KC;
            let kcs = self.strip_cols(s);
            let pairs = kcs.div_ceil(2);
            let gs = kcs.div_ceil(self.group);
            for j in 0..h {
                let block = self.block(s, j);
                let bs = self.block_scales(s, j);
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    data[row * rb + k0 / 2..row * rb + k0 / 2 + pairs]
                        .copy_from_slice(&block[g * pairs..(g + 1) * pairs]);
                    scales[row * ngroups + k0 / self.group
                        ..row * ngroups + k0 / self.group + gs]
                        .copy_from_slice(&bs[g * gs..(g + 1) * gs]);
                }
            }
        }
        Q4Matrix::from_parts(3 * h, k, self.group, data, scales)
            .expect("packed q4 gate shape bookkeeping")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn rand_i8(n: usize, k: usize, rng: &mut Pcg64) -> TensorI8 {
        let data: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(&[n, k], data).unwrap()
    }

    #[test]
    fn round_trip_exhaustive_small_tails() {
        // every n mod NR residue × every interesting k tail, incl. k < 8
        // (the dot_i8 unroll tail) and the KC strip boundary
        let mut rng = Pcg64::seeded(0);
        for n in 1..=9usize {
            for &k in &[1usize, 2, 3, 5, 7, 8, 9, 255, 256, 257, 511, 512, 513] {
                let w = rand_i8(n, k, &mut rng);
                let p = PackedQMatrix::pack(&w);
                assert_eq!(p.unpack(), w, "({n},{k})");
            }
        }
    }

    #[test]
    fn round_trip_with_explicit_tiles() {
        // every autotune candidate tile shape must round-trip on ragged
        // shapes too — tile choice may never change stored weights
        let mut rng = Pcg64::seeded(3);
        for &(nr, kc) in &[(4usize, 128usize), (4, 512), (8, 128), (8, 256), (8, 512), (1, 1)] {
            for &(n, k) in &[(1usize, 1usize), (7, 9), (9, 130), (17, 513)] {
                let w = rand_i8(n, k, &mut rng);
                let p = PackedQMatrix::pack_with(&w, nr, kc);
                assert_eq!((p.nr(), p.kc()), (nr, kc));
                assert_eq!(p.unpack(), w, "nr {nr} kc {kc} ({n},{k})");
            }
        }
    }

    #[test]
    fn packed_size_is_padded_rows_times_k() {
        let mut rng = Pcg64::seeded(1);
        let w = rand_i8(6, 300, &mut rng);
        let p = PackedQMatrix::pack(&w);
        assert_eq!(p.bytes(), 8 * 300, "6 rows pad to 2 panels of 4");
        assert_eq!((p.n(), p.k()), (6, 300));
    }

    #[test]
    fn strip_accounting_covers_k() {
        let mut rng = Pcg64::seeded(2);
        let w = rand_i8(4, 2 * KC + 17, &mut rng);
        let p = PackedQMatrix::pack(&w);
        let total: usize = (0..3).map(|s| p.strip_cols(s)).sum();
        assert_eq!(total, 2 * KC + 17);
        assert_eq!(p.strip_cols(2), 17);
    }

    #[test]
    fn gate_panels_round_trip_and_blocks() {
        let mut rng = Pcg64::seeded(4);
        for &(h, k) in &[(1usize, 1usize), (3, 7), (5, 256), (4, 257), (7, 513), (32, 100)] {
            let w = rand_i8(3 * h, k, &mut rng);
            let gp = PackedGatePanels::pack(&w);
            assert_eq!((gp.h(), gp.k()), (h, k));
            assert_eq!(gp.bytes(), 3 * h * k, "no padding in the gate layout");
            assert_eq!(gp.unpack(), w, "({h},{k})");
            // block (s=0, j) holds the three gate rows' strip-0 prefixes
            let kcs = gp.strip_cols(0);
            for j in 0..h {
                let b = gp.block(0, j);
                assert_eq!(&b[..kcs], &w.row(j)[..kcs], "z_{j}");
                assert_eq!(&b[kcs..2 * kcs], &w.row(h + j)[..kcs], "r_{j}");
                assert_eq!(&b[2 * kcs..], &w.row(2 * h + j)[..kcs], "h̃_{j}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "gate panels")]
    fn gate_panels_reject_non_gate_row_counts() {
        let mut rng = Pcg64::seeded(5);
        let w = rand_i8(7, 5, &mut rng);
        let _ = PackedGatePanels::pack(&w);
    }

    fn rand_q4(n: usize, k: usize, rng: &mut Pcg64) -> Q4Matrix {
        crate::quant::quantize4(&crate::tensor::Tensor::randn(&[n, k], 0.5, rng))
    }

    #[test]
    fn q4_round_trip_exhaustive_small_tails() {
        // ragged n × ragged k incl. odd k (nibble tail), group tails
        // (k mod 32) and the KC strip boundary
        let mut rng = Pcg64::seeded(6);
        for n in 1..=9usize {
            for &k in &[1usize, 2, 3, 5, 7, 31, 32, 33, 63, 64, 65, 255, 256, 257, 513] {
                let q4 = rand_q4(n, k, &mut rng);
                let p = PackedQ4Matrix::pack(&q4);
                assert_eq!(p.unpack(), q4, "({n},{k})");
            }
        }
    }

    #[test]
    fn q4_round_trip_with_explicit_tiles() {
        let mut rng = Pcg64::seeded(7);
        for &(nr, kc) in &[(4usize, 128usize), (4, 512), (8, 128), (8, 256), (8, 512), (1, 32)] {
            for &(n, k) in &[(1usize, 1usize), (7, 9), (9, 130), (17, 513)] {
                let q4 = rand_q4(n, k, &mut rng);
                let p = PackedQ4Matrix::pack_with(&q4, nr, kc);
                assert_eq!((p.nr(), p.kc(), p.group()), (nr, kc, q4.group()));
                assert_eq!(p.unpack(), q4, "nr {nr} kc {kc} ({n},{k})");
            }
        }
    }

    #[test]
    fn q4_packed_bytes_are_half_the_int8_panel_bytes_plus_scales() {
        let mut rng = Pcg64::seeded(8);
        let q4 = rand_q4(6, 300, &mut rng);
        let p = PackedQ4Matrix::pack(&q4);
        // 6 rows pad to 2 panels of 4; 300 cols → 150 nibble bytes per
        // padded row + 10 group scales per padded row
        assert_eq!(p.bytes(), 8 * 150 + 8 * 10 * 4);
        assert_eq!((p.n(), p.k()), (6, 300));
    }

    #[test]
    #[should_panic(expected = "multiple of the scale group")]
    fn q4_pack_rejects_strip_not_covering_whole_groups() {
        let mut rng = Pcg64::seeded(9);
        let q4 = rand_q4(4, 64, &mut rng);
        let _ = PackedQ4Matrix::pack_with(&q4, 4, 48); // 48 % 32 != 0
    }

    #[test]
    fn q4_gate_panels_round_trip_and_blocks() {
        let mut rng = Pcg64::seeded(10);
        for &(h, k) in &[(1usize, 1usize), (3, 7), (5, 256), (4, 257), (7, 513), (32, 100)] {
            let q4 = rand_q4(3 * h, k, &mut rng);
            let gp = PackedQ4GatePanels::pack(&q4);
            assert_eq!((gp.h(), gp.k(), gp.group()), (h, k, q4.group()));
            assert_eq!(gp.unpack(), q4, "({h},{k})");
            // block (s=0, j) holds the three gate rows' strip-0 nibble
            // prefixes and their group scales
            let kcs = gp.strip_cols(0);
            let pairs = kcs.div_ceil(2);
            let gs = kcs.div_ceil(gp.group());
            for j in 0..h {
                let b = gp.block(0, j);
                let bs = gp.block_scales(0, j);
                for (g, row) in [j, h + j, 2 * h + j].into_iter().enumerate() {
                    assert_eq!(
                        &b[g * pairs..(g + 1) * pairs],
                        &q4.row_data(row)[..pairs],
                        "gate {g} unit {j} data"
                    );
                    assert_eq!(
                        &bs[g * gs..(g + 1) * gs],
                        &q4.row_scales(row)[..gs],
                        "gate {g} unit {j} scales"
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "gate panels")]
    fn q4_gate_panels_reject_non_gate_row_counts() {
        let mut rng = Pcg64::seeded(11);
        let q4 = rand_q4(7, 5, &mut rng);
        let _ = PackedQ4GatePanels::pack(&q4);
    }
}

//! The `blocked` backend: the farm schedule over
//! [`PackedQMatrix`](super::pack::PackedQMatrix) pre-packed weights.
//!
//! Same arithmetic as [`super::scalar`] (exact i32 accumulation →
//! bit-identical int8 results), different data movement: weights are read
//! from the NR-panel, KC-strip interleaved layout built once at plan
//! time.  Inside a panel the four weights a register tile needs for one
//! activation element are adjacent (`kk·NR + r`), so the inner loop loads
//! each activation once, feeds four independent i32 accumulator chains,
//! and walks the weight stream strictly sequentially — the prefetcher's
//! best case.  There is **no** per-call packing (the gemmlowp mistake at
//! small batch) and no allocation: `out` is reshaped in place.
//!
//! f32 weights are not packed (the embedded deployment path is int8);
//! the f32 entry point shares [`super::scalar`]'s core, so `blocked` and
//! `scalar` are bit-identical on f32 too.

use crate::tensor::Tensor;

use super::pack::{KC, NR};
use super::{scalar, GemmBackend, PreparedQMatrix, RowScales};

/// Core of the packed-panel schedule: for each panel, each activation
/// row carries 4 i32 accumulators across every k-strip, then writes the
/// 4 dequantized outputs (ragged last panel writes only the real rows).
fn qgemm_packed_core(
    xq: &[i8],
    m: usize,
    w: &PreparedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k) = (w.packed.n(), w.packed.k());
    assert_eq!(xq.len(), m * k, "blocked activation panel mismatch");
    out.reset(&[m, n]);
    let nstrips = k.div_ceil(KC);
    let npanels = n.div_ceil(NR);
    for p in 0..npanels {
        let j0 = p * NR;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
            for s in 0..nstrips {
                let k0 = s * KC;
                let kc = w.packed.strip_cols(s);
                let panel = w.packed.panel(s, p);
                for (kk, &xv) in xi[k0..k0 + kc].iter().enumerate() {
                    let xv = xv as i32;
                    let wb = kk * NR;
                    a0 += xv * panel[wb] as i32;
                    a1 += xv * panel[wb + 1] as i32;
                    a2 += xv * panel[wb + 2] as i32;
                    a3 += xv * panel[wb + 3] as i32;
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j0] = a0 as f32 * scale;
            if j0 + 1 < n {
                orow[j0 + 1] = a1 as f32 * scale;
            }
            if j0 + 2 < n {
                orow[j0 + 2] = a2 as f32 * scale;
            }
            if j0 + 3 < n {
                orow[j0 + 3] = a3 as f32 * scale;
            }
        }
    }
}

/// The packed-weight backend (see module docs).
pub struct BlockedBackend;

impl GemmBackend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
        // f32 weights are not packed; identical to scalar by construction
        scalar::gemm_f32_core(x, w, bias, out);
    }

    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        qgemm_packed_core(xq, m, w, RowScales::Uniform(sx * w.scale), out);
    }

    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
        qgemm_packed_core(xq, m, w, RowScales::PerRow(sx, w.scale), out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::QMatrix;
    use crate::tensor::TensorI8;

    #[test]
    fn blocked_matches_reference_on_ragged_shapes() {
        let mut rng = Pcg64::seeded(0);
        let be = BlockedBackend;
        let shapes = [(1usize, 1usize, 1usize), (1, 5, 3), (3, 7, 7), (2, 9, 257), (5, 66, 300)];
        for &(m, n, k) in &shapes {
            let mk = |r: usize, c: usize, rng: &mut Pcg64| {
                let data = (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                TensorI8::new(&[r, c], data).unwrap()
            };
            let x = mk(m, k, &mut rng);
            let wq = mk(n, k, &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.03 });
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), m, &w, 0.011, &mut out);
            let want = super::super::qgemm_ref(&x, &wq, 0.011, 0.03);
            assert_eq!(out, want, "({m},{n},{k})");
        }
    }
}

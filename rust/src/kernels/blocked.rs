//! The `blocked` backend: the farm schedule over
//! [`PackedQMatrix`](super::pack::PackedQMatrix) pre-packed weights.
//!
//! Same arithmetic as [`super::scalar`] (exact i32 accumulation →
//! bit-identical int8 results), different data movement: weights are read
//! from the nr-panel, kc-strip interleaved layout built once at plan
//! time (tile shape per weight chosen by [`super::autotune`]).  Inside a
//! panel the `nr` weights a register tile needs for one activation
//! element are adjacent (`kk·nr + r`), so the inner loop loads each
//! activation once, feeds `nr` independent i32 accumulator chains, and
//! walks the weight stream strictly sequentially — the prefetcher's best
//! case.  There is **no** per-call packing (the gemmlowp mistake at small
//! batch) and no allocation: `out` is reshaped in place.
//!
//! Small-batch specializations (DESIGN.md §4):
//!
//! * **m = 1 GEMV** ([`GemmBackend::qgemv_into`]): the steady-state
//!   decode shape.  With a single activation row there is no register
//!   tile to amortize the panel interleave over, so the GEMV path skips
//!   panel staging entirely and streams the row-major reference copy —
//!   one pass, no layout indirection.
//! * **Fused GRU gates** ([`GemmBackend::qgemm_gates_rows_into`]): when
//!   the prepared weight carries gate-interleaved
//!   [`PackedGatePanels`](super::pack::PackedGatePanels), all three gate
//!   products of each hidden unit are computed in one sweep over
//!   adjacent weight bytes instead of three sweeps `H·k` bytes apart.
//!
//! f32 weights are not packed (the embedded deployment path is int8);
//! the f32 entry point shares [`super::scalar`]'s core, so `blocked` and
//! `scalar` are bit-identical on f32 too.

use crate::tensor::Tensor;

use super::pack::{PackedGatePanels, PackedQMatrix, MAX_NR};
use super::{scalar, GemmBackend, PreparedQMatrix, RowScales};

/// Core of the packed-panel schedule: for each panel, each activation
/// row carries `nr` i32 accumulators across every k-strip, then writes
/// the `nr` dequantized outputs (ragged last panel writes only the real
/// rows).  Dispatches on the packed tile's panel height: the default
/// nr = 4 keeps the fully unrolled register tile, other heights run the
/// generic accumulator-array core (both exact, so bit-identical).
pub(crate) fn qgemm_packed_core(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    assert_eq!(xq.len(), m * pw.k(), "blocked activation panel mismatch");
    out.reset(&[m, pw.n()]);
    if pw.nr() == 4 {
        packed_core_nr4(xq, m, pw, scales, out);
    } else {
        packed_core_generic(xq, m, pw, scales, out);
    }
}

fn packed_core_nr4(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k) = (pw.n(), pw.k());
    let nstrips = k.div_ceil(pw.kc());
    let npanels = n.div_ceil(4);
    for p in 0..npanels {
        let j0 = p * 4;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
            for s in 0..nstrips {
                let k0 = s * pw.kc();
                let kc = pw.strip_cols(s);
                let panel = pw.panel(s, p);
                for (kk, &xv) in xi[k0..k0 + kc].iter().enumerate() {
                    let xv = xv as i32;
                    let wb = kk * 4;
                    a0 += xv * panel[wb] as i32;
                    a1 += xv * panel[wb + 1] as i32;
                    a2 += xv * panel[wb + 2] as i32;
                    a3 += xv * panel[wb + 3] as i32;
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j0] = a0 as f32 * scale;
            if j0 + 1 < n {
                orow[j0 + 1] = a1 as f32 * scale;
            }
            if j0 + 2 < n {
                orow[j0 + 2] = a2 as f32 * scale;
            }
            if j0 + 3 < n {
                orow[j0 + 3] = a3 as f32 * scale;
            }
        }
    }
}

fn packed_core_generic(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k, nr) = (pw.n(), pw.k(), pw.nr());
    let nstrips = k.div_ceil(pw.kc());
    let npanels = n.div_ceil(nr);
    for p in 0..npanels {
        let j0 = p * nr;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let mut acc = [0i32; MAX_NR];
            for s in 0..nstrips {
                let k0 = s * pw.kc();
                let kc = pw.strip_cols(s);
                let panel = pw.panel(s, p);
                for (kk, &xv) in xi[k0..k0 + kc].iter().enumerate() {
                    let xv = xv as i32;
                    let wb = kk * nr;
                    for (r, a) in acc[..nr].iter_mut().enumerate() {
                        *a += xv * panel[wb + r] as i32;
                    }
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            for (r, &a) in acc[..nr.min(n - j0)].iter().enumerate() {
                orow[j0 + r] = a as f32 * scale;
            }
        }
    }
}

/// Core of the fused GRU-gate schedule over gate-interleaved panels: for
/// each hidden unit `j`, one strictly-sequential pass over the adjacent
/// `[z_j | r_j | h̃_j]` weight segments produces all three gate products,
/// scattered to the stacked `[z | r | h̃]` output layout the gate math
/// ([`crate::infer`]) expects.  Exact i32 accumulation → bit-identical
/// to three separate sweeps and to [`super::qgemm_ref`].  Shared by the
/// blocked backend and the simd backend's portable fallback.
pub(crate) fn qgemm_gates_core(
    xq: &[i8],
    m: usize,
    gp: &PackedGatePanels,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (h, k) = (gp.h(), gp.k());
    assert_eq!(xq.len(), m * k, "fused-gate activation panel mismatch");
    out.reset(&[m, 3 * h]);
    let nstrips = gp.nstrips();
    for j in 0..h {
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut az, mut ar, mut ac) = (0i32, 0, 0);
            for s in 0..nstrips {
                let k0 = s * super::pack::KC;
                let kc = gp.strip_cols(s);
                let block = gp.block(s, j);
                let xs = &xi[k0..k0 + kc];
                az += scalar::dot_i8(xs, &block[..kc]);
                ar += scalar::dot_i8(xs, &block[kc..2 * kc]);
                ac += scalar::dot_i8(xs, &block[2 * kc..]);
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j] = az as f32 * scale;
            orow[h + j] = ar as f32 * scale;
            orow[2 * h + j] = ac as f32 * scale;
        }
    }
}

/// The packed-weight backend (see module docs).
pub struct BlockedBackend;

impl GemmBackend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
        // f32 weights are not packed; identical to scalar by construction
        scalar::gemm_f32_core(x, w, bias, out);
    }

    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        qgemm_packed_core(xq, m, &w.packed, RowScales::Uniform(sx * w.scale), out);
    }

    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
        qgemm_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, w.scale), out);
    }

    fn qgemv_into(&self, xq: &[i8], w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        // m = 1: no register tile to amortize the panel interleave over —
        // stream the row-major reference copy, no panel staging
        scalar::gemv_core(xq, &w.q, sx * w.scale, out);
    }

    fn qgemm_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_gates_rows needs one scale per row");
        match &w.gates {
            Some(gp) => qgemm_gates_core(xq, m, gp, RowScales::PerRow(sx, w.scale), out),
            None => qgemm_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, w.scale), out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::QMatrix;
    use crate::tensor::TensorI8;

    fn mk(r: usize, c: usize, rng: &mut Pcg64) -> TensorI8 {
        let data = (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(&[r, c], data).unwrap()
    }

    #[test]
    fn blocked_matches_reference_on_ragged_shapes() {
        let mut rng = Pcg64::seeded(0);
        let be = BlockedBackend;
        let shapes = [(1usize, 1usize, 1usize), (1, 5, 3), (3, 7, 7), (2, 9, 257), (5, 66, 300)];
        for &(m, n, k) in &shapes {
            let x = mk(m, k, &mut rng);
            let wq = mk(n, k, &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.03 });
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), m, &w, 0.011, &mut out);
            let want = super::super::qgemm_ref(&x, &wq, 0.011, 0.03);
            assert_eq!(out, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn packed_core_bit_identical_across_every_candidate_tile() {
        // tile autotuning may pick any (nr, kc) candidate: results must
        // be bit-identical to the reference for all of them, on ragged
        // n/k tails including k < 8 and n % nr != 0
        let mut rng = Pcg64::seeded(1);
        for &(m, n, k) in &[(1usize, 5usize, 3usize), (2, 9, 7), (3, 13, 257), (4, 66, 513)] {
            let x = mk(m, k, &mut rng);
            let wq = mk(n, k, &mut rng);
            let want = super::super::qgemm_ref(&x, &wq, 0.011, 0.03);
            for &(nr, kc) in crate::kernels::autotune::CANDIDATES {
                let pw = crate::kernels::PackedQMatrix::pack_with(&wq, nr, kc);
                let mut out = Tensor::zeros(&[0, 0]);
                qgemm_packed_core(
                    x.data(),
                    m,
                    &pw,
                    RowScales::Uniform(0.011 * 0.03),
                    &mut out,
                );
                assert_eq!(out, want, "tile ({nr},{kc}) at ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn fused_gates_core_matches_stacked_reference() {
        let mut rng = Pcg64::seeded(2);
        for &(m, h, k) in &[(1usize, 1usize, 1usize), (2, 5, 7), (3, 32, 257), (4, 7, 100)] {
            let x = mk(m, k, &mut rng);
            let wq = mk(3 * h, k, &mut rng);
            let gp = PackedGatePanels::pack(&wq);
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let mut out = Tensor::zeros(&[0, 0]);
            qgemm_gates_core(x.data(), m, &gp, RowScales::PerRow(&sx, 0.021), &mut out);
            let want = crate::kernels::qgemm_farm_rows(&x, &wq, &sx, 0.021);
            assert_eq!(out, want, "({m},{h},{k})");
        }
    }
}

//! The `blocked` backend: the farm schedule over
//! [`PackedQMatrix`](super::pack::PackedQMatrix) pre-packed weights.
//!
//! Same arithmetic as [`super::scalar`] (exact i32 accumulation →
//! bit-identical int8 results), different data movement: weights are read
//! from the nr-panel, kc-strip interleaved layout built once at plan
//! time (tile shape per weight chosen by [`super::autotune`]).  Inside a
//! panel the `nr` weights a register tile needs for one activation
//! element are adjacent (`kk·nr + r`), so the inner loop loads each
//! activation once, feeds `nr` independent i32 accumulator chains, and
//! walks the weight stream strictly sequentially — the prefetcher's best
//! case.  There is **no** per-call packing (the gemmlowp mistake at small
//! batch) and no allocation: `out` is reshaped in place.
//!
//! Small-batch specializations (DESIGN.md §4):
//!
//! * **m = 1 GEMV** ([`GemmBackend::qgemv_into`]): the steady-state
//!   decode shape.  With a single activation row there is no register
//!   tile to amortize the panel interleave over, so the GEMV path skips
//!   panel staging entirely and streams the row-major reference copy —
//!   one pass, no layout indirection.
//! * **Fused GRU gates** ([`GemmBackend::qgemm_gates_rows_into`]): when
//!   the prepared weight carries gate-interleaved
//!   [`PackedGatePanels`](super::pack::PackedGatePanels), all three gate
//!   products of each hidden unit are computed in one sweep over
//!   adjacent weight bytes instead of three sweeps `H·k` bytes apart.
//!
//! f32 weights are not packed (the embedded deployment path is int8);
//! the f32 entry point shares [`super::scalar`]'s core, so `blocked` and
//! `scalar` are bit-identical on f32 too.

use crate::quant::{nibble_hi, nibble_lo};
use crate::tensor::Tensor;

use super::pack::{PackedGatePanels, PackedQ4GatePanels, PackedQ4Matrix, PackedQMatrix, MAX_NR};
use super::{scalar, GemmBackend, PreparedQ4Matrix, PreparedQMatrix, RowScales};

/// Core of the packed-panel schedule: for each panel, each activation
/// row carries `nr` i32 accumulators across every k-strip, then writes
/// the `nr` dequantized outputs (ragged last panel writes only the real
/// rows).  Dispatches on the packed tile's panel height: the default
/// nr = 4 keeps the fully unrolled register tile, other heights run the
/// generic accumulator-array core (both exact, so bit-identical).
pub(crate) fn qgemm_packed_core(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    assert_eq!(xq.len(), m * pw.k(), "blocked activation panel mismatch");
    out.reset(&[m, pw.n()]);
    if pw.nr() == 4 {
        packed_core_nr4(xq, m, pw, scales, out);
    } else {
        packed_core_generic(xq, m, pw, scales, out);
    }
}

fn packed_core_nr4(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k) = (pw.n(), pw.k());
    let nstrips = k.div_ceil(pw.kc());
    let npanels = n.div_ceil(4);
    for p in 0..npanels {
        let j0 = p * 4;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0, 0, 0);
            for s in 0..nstrips {
                let k0 = s * pw.kc();
                let kc = pw.strip_cols(s);
                let panel = pw.panel(s, p);
                for (kk, &xv) in xi[k0..k0 + kc].iter().enumerate() {
                    let xv = xv as i32;
                    let wb = kk * 4;
                    a0 += xv * panel[wb] as i32;
                    a1 += xv * panel[wb + 1] as i32;
                    a2 += xv * panel[wb + 2] as i32;
                    a3 += xv * panel[wb + 3] as i32;
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j0] = a0 as f32 * scale;
            if j0 + 1 < n {
                orow[j0 + 1] = a1 as f32 * scale;
            }
            if j0 + 2 < n {
                orow[j0 + 2] = a2 as f32 * scale;
            }
            if j0 + 3 < n {
                orow[j0 + 3] = a3 as f32 * scale;
            }
        }
    }
}

fn packed_core_generic(
    xq: &[i8],
    m: usize,
    pw: &PackedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k, nr) = (pw.n(), pw.k(), pw.nr());
    let nstrips = k.div_ceil(pw.kc());
    let npanels = n.div_ceil(nr);
    for p in 0..npanels {
        let j0 = p * nr;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let mut acc = [0i32; MAX_NR];
            for s in 0..nstrips {
                let k0 = s * pw.kc();
                let kc = pw.strip_cols(s);
                let panel = pw.panel(s, p);
                for (kk, &xv) in xi[k0..k0 + kc].iter().enumerate() {
                    let xv = xv as i32;
                    let wb = kk * nr;
                    for (r, a) in acc[..nr].iter_mut().enumerate() {
                        *a += xv * panel[wb + r] as i32;
                    }
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            for (r, &a) in acc[..nr.min(n - j0)].iter().enumerate() {
                orow[j0 + r] = a as f32 * scale;
            }
        }
    }
}

/// Core of the fused GRU-gate schedule over gate-interleaved panels: for
/// each hidden unit `j`, one strictly-sequential pass over the adjacent
/// `[z_j | r_j | h̃_j]` weight segments produces all three gate products,
/// scattered to the stacked `[z | r | h̃]` output layout the gate math
/// ([`crate::infer`]) expects.  Exact i32 accumulation → bit-identical
/// to three separate sweeps and to [`super::qgemm_ref`].  Shared by the
/// blocked backend and the simd backend's portable fallback.
pub(crate) fn qgemm_gates_core(
    xq: &[i8],
    m: usize,
    gp: &PackedGatePanels,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (h, k) = (gp.h(), gp.k());
    assert_eq!(xq.len(), m * k, "fused-gate activation panel mismatch");
    out.reset(&[m, 3 * h]);
    let nstrips = gp.nstrips();
    for j in 0..h {
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut az, mut ar, mut ac) = (0i32, 0, 0);
            for s in 0..nstrips {
                let k0 = s * super::pack::KC;
                let kc = gp.strip_cols(s);
                let block = gp.block(s, j);
                let xs = &xi[k0..k0 + kc];
                az += scalar::dot_i8(xs, &block[..kc]);
                ar += scalar::dot_i8(xs, &block[kc..2 * kc]);
                ac += scalar::dot_i8(xs, &block[2 * kc..]);
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j] = az as f32 * scale;
            orow[h + j] = ar as f32 * scale;
            orow[2 * h + j] = ac as f32 * scale;
        }
    }
}

/// Core of the int4 packed-panel schedule: same strip/panel walk as
/// [`qgemm_packed_core`], but weights arrive two-per-byte with per-group
/// scales.  Each scale group keeps `nr` exact i32 sub-accumulators; at
/// the group boundary they fold into the f32 accumulators (one multiply
/// by the group scale each).  Strips cover whole groups (pack-time
/// invariant), so the f32 folds happen in ascending global group order —
/// exactly the accumulation contract of [`scalar::dot_q4_row`], which
/// makes this bit-identical to the scalar int4 reference.
pub(crate) fn qgemm4_packed_core(
    xq: &[i8],
    m: usize,
    pw: &PackedQ4Matrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k, nr, group) = (pw.n(), pw.k(), pw.nr(), pw.group());
    assert_eq!(xq.len(), m * k, "blocked int4 activation panel mismatch");
    out.reset(&[m, n]);
    let nstrips = k.div_ceil(pw.kc());
    let npanels = n.div_ceil(nr);
    for p in 0..npanels {
        let j0 = p * nr;
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let mut facc = [0f32; MAX_NR];
            for s in 0..nstrips {
                let k0 = s * pw.kc();
                let kcs = pw.strip_cols(s);
                let panel = pw.panel(s, p);
                let pscales = pw.panel_scales(s, p);
                let gs = kcs.div_ceil(group);
                for g in 0..gs {
                    let c0 = g * group; // strip-relative columns
                    let cend = (c0 + group).min(kcs);
                    let mut sub = [0i32; MAX_NR];
                    let mut c = c0;
                    while c + 1 < cend {
                        let x0 = xi[k0 + c] as i32;
                        let x1 = xi[k0 + c + 1] as i32;
                        let wb = (c / 2) * nr;
                        for (r, a) in sub[..nr].iter_mut().enumerate() {
                            let b = panel[wb + r];
                            *a += x0 * nibble_lo(b) as i32 + x1 * nibble_hi(b) as i32;
                        }
                        c += 2;
                    }
                    if c < cend {
                        // odd k tail: only the low nibble is real
                        let x0 = xi[k0 + c] as i32;
                        let wb = (c / 2) * nr;
                        for (r, a) in sub[..nr].iter_mut().enumerate() {
                            *a += x0 * nibble_lo(panel[wb + r]) as i32;
                        }
                    }
                    for (r, f) in facc[..nr].iter_mut().enumerate() {
                        *f += sub[r] as f32 * pscales[g * nr + r];
                    }
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            for (r, &f) in facc[..nr.min(n - j0)].iter().enumerate() {
                orow[j0 + r] = f * scale;
            }
        }
    }
}

/// Core of the fused int4 GRU-gate schedule: one pass over each hidden
/// unit's adjacent `[z_j | r_j | h̃_j]` nibble segments and their scale
/// segments.  The three f32 gate accumulators fold group terms in
/// ascending global order (strips ascending × groups-within-strip
/// ascending), so every gate row is bit-identical to the stacked scalar
/// sweep.  Shared by the blocked backend and the simd backend's portable
/// fallback.
pub(crate) fn qgemm4_gates_core(
    xq: &[i8],
    m: usize,
    gp: &PackedQ4GatePanels,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (h, k, group) = (gp.h(), gp.k(), gp.group());
    assert_eq!(xq.len(), m * k, "fused-gate int4 activation panel mismatch");
    out.reset(&[m, 3 * h]);
    let nstrips = gp.nstrips();
    for j in 0..h {
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let (mut az, mut ar, mut ac) = (0f32, 0f32, 0f32);
            for s in 0..nstrips {
                let k0 = s * super::pack::KC;
                let kcs = gp.strip_cols(s);
                let pairs = kcs.div_ceil(2);
                let gs = kcs.div_ceil(group);
                let block = gp.block(s, j);
                let bscales = gp.block_scales(s, j);
                let xs = &xi[k0..k0 + kcs];
                let (zb, rb, cb) = (&block[..pairs], &block[pairs..2 * pairs], &block[2 * pairs..]);
                for g in 0..gs {
                    let c0 = g * group;
                    let cend = (c0 + group).min(kcs);
                    az += scalar::dot_q4_group(xs, zb, c0, cend) as f32 * bscales[g];
                    ar += scalar::dot_q4_group(xs, rb, c0, cend) as f32 * bscales[gs + g];
                    ac += scalar::dot_q4_group(xs, cb, c0, cend) as f32 * bscales[2 * gs + g];
                }
            }
            let scale = scales.get(i);
            let orow = out.row_mut(i);
            orow[j] = az * scale;
            orow[h + j] = ar * scale;
            orow[2 * h + j] = ac * scale;
        }
    }
}

/// The packed-weight backend (see module docs).
pub struct BlockedBackend;

impl GemmBackend for BlockedBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
        // f32 weights are not packed; identical to scalar by construction
        scalar::gemm_f32_core(x, w, bias, out);
    }

    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        qgemm_packed_core(xq, m, &w.packed, RowScales::Uniform(sx * w.scale), out);
    }

    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
        qgemm_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, w.scale), out);
    }

    fn qgemv_into(&self, xq: &[i8], w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        // m = 1: no register tile to amortize the panel interleave over —
        // stream the row-major reference copy, no panel staging
        scalar::gemv_core(xq, &w.q, sx * w.scale, out);
    }

    fn qgemm_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_gates_rows needs one scale per row");
        match &w.gates {
            Some(gp) => qgemm_gates_core(xq, m, gp, RowScales::PerRow(sx, w.scale), out),
            None => qgemm_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, w.scale), out),
        }
    }

    fn qgemm4_farm_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: f32,
        out: &mut Tensor,
    ) {
        qgemm4_packed_core(xq, m, &w.packed, RowScales::Uniform(sx), out);
    }

    fn qgemm4_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm4_farm_rows needs one scale per row");
        qgemm4_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, 1.0), out);
    }

    fn qgemv4_into(&self, xq: &[i8], w: &PreparedQ4Matrix, sx: f32, out: &mut Tensor) {
        // m = 1: skip panel staging, stream the row-major nibble copy
        scalar::gemv4_core(xq, &w.q4, sx, out);
    }

    fn qgemm4_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm4_gates_rows needs one scale per row");
        match &w.gates {
            Some(gp) => qgemm4_gates_core(xq, m, gp, RowScales::PerRow(sx, 1.0), out),
            None => qgemm4_packed_core(xq, m, &w.packed, RowScales::PerRow(sx, 1.0), out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::QMatrix;
    use crate::tensor::TensorI8;

    fn mk(r: usize, c: usize, rng: &mut Pcg64) -> TensorI8 {
        let data = (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        TensorI8::new(&[r, c], data).unwrap()
    }

    #[test]
    fn blocked_matches_reference_on_ragged_shapes() {
        let mut rng = Pcg64::seeded(0);
        let be = BlockedBackend;
        let shapes = [(1usize, 1usize, 1usize), (1, 5, 3), (3, 7, 7), (2, 9, 257), (5, 66, 300)];
        for &(m, n, k) in &shapes {
            let x = mk(m, k, &mut rng);
            let wq = mk(n, k, &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.03 });
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), m, &w, 0.011, &mut out);
            let want = super::super::qgemm_ref(&x, &wq, 0.011, 0.03);
            assert_eq!(out, want, "({m},{n},{k})");
        }
    }

    #[test]
    fn packed_core_bit_identical_across_every_candidate_tile() {
        // tile autotuning may pick any (nr, kc) candidate: results must
        // be bit-identical to the reference for all of them, on ragged
        // n/k tails including k < 8 and n % nr != 0
        let mut rng = Pcg64::seeded(1);
        for &(m, n, k) in &[(1usize, 5usize, 3usize), (2, 9, 7), (3, 13, 257), (4, 66, 513)] {
            let x = mk(m, k, &mut rng);
            let wq = mk(n, k, &mut rng);
            let want = super::super::qgemm_ref(&x, &wq, 0.011, 0.03);
            for &(nr, kc) in crate::kernels::autotune::CANDIDATES {
                let pw = crate::kernels::PackedQMatrix::pack_with(&wq, nr, kc);
                let mut out = Tensor::zeros(&[0, 0]);
                qgemm_packed_core(
                    x.data(),
                    m,
                    &pw,
                    RowScales::Uniform(0.011 * 0.03),
                    &mut out,
                );
                assert_eq!(out, want, "tile ({nr},{kc}) at ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn fused_gates_core_matches_stacked_reference() {
        let mut rng = Pcg64::seeded(2);
        for &(m, h, k) in &[(1usize, 1usize, 1usize), (2, 5, 7), (3, 32, 257), (4, 7, 100)] {
            let x = mk(m, k, &mut rng);
            let wq = mk(3 * h, k, &mut rng);
            let gp = PackedGatePanels::pack(&wq);
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let mut out = Tensor::zeros(&[0, 0]);
            qgemm_gates_core(x.data(), m, &gp, RowScales::PerRow(&sx, 0.021), &mut out);
            let want = crate::kernels::qgemm_farm_rows(&x, &wq, &sx, 0.021);
            assert_eq!(out, want, "({m},{h},{k})");
        }
    }

    fn mk4(n: usize, k: usize, rng: &mut Pcg64) -> crate::quant::Q4Matrix {
        crate::quant::quantize4(&Tensor::randn(&[n, k], 0.5, rng))
    }

    #[test]
    fn int4_packed_core_bit_identical_across_every_candidate_tile() {
        let mut rng = Pcg64::seeded(3);
        for &(m, n, k) in &[(1usize, 5usize, 3usize), (2, 9, 31), (3, 13, 257), (4, 66, 513)] {
            let x = mk(m, k, &mut rng);
            let w4 = mk4(n, k, &mut rng);
            let want = crate::kernels::qgemm4_ref(&x, &w4, 0.011);
            for &(nr, kc) in crate::kernels::autotune::CANDIDATES {
                let pw = PackedQ4Matrix::pack_with(&w4, nr, kc);
                let mut out = Tensor::zeros(&[0, 0]);
                qgemm4_packed_core(x.data(), m, &pw, RowScales::Uniform(0.011), &mut out);
                assert_eq!(out, want, "tile ({nr},{kc}) at ({m},{n},{k})");
            }
        }
    }

    #[test]
    fn int4_fused_gates_core_matches_stacked_scalar_reference() {
        let mut rng = Pcg64::seeded(4);
        for &(m, h, k) in &[(1usize, 1usize, 1usize), (2, 5, 7), (3, 32, 257), (4, 7, 100)] {
            let x = mk(m, k, &mut rng);
            let w4 = mk4(3 * h, k, &mut rng);
            let gp = PackedQ4GatePanels::pack(&w4);
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.003 * i as f32).collect();
            let mut out = Tensor::zeros(&[0, 0]);
            qgemm4_gates_core(x.data(), m, &gp, RowScales::PerRow(&sx, 1.0), &mut out);
            let want = crate::kernels::qgemm4_farm_rows(&x, &w4, &sx);
            assert_eq!(out, want, "({m},{h},{k})");
        }
    }
}

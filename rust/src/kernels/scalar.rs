//! The `scalar` backend — the original portable-Rust farm schedule, now
//! the reference implementation every other backend must match
//! bit-identically on int8.
//!
//! Two competing int8 implementations reproduce the paper's *algorithmic*
//! contrast on the host ISA (the 3–7× shape is ISA-independent; see
//! DESIGN.md §3):
//!
//! * [`qgemm_farm`] — the farm strategy: **no packing**. The big weight
//!   matrix streams through cache exactly once per call in its storage
//!   layout; the tiny activation panel (m ≤ 8 rows) stays register/L1
//!   resident. 4-row × m-col register tiles of i32 accumulators.
//! * [`qgemm_lowp`] — the gemmlowp strategy: **pack-compute-unpack**.
//!   Both operands are copied into cache-friendly panel layouts before the
//!   compute pass (amortizes beautifully at large batch, but at batch 1–4
//!   the O(n·k) packing traffic rivals the GEMM itself).
//!
//! Both produce bit-identical i32 accumulations (tested), so Figure 6 is a
//! pure scheduling comparison.  [`gemm_f32`] is the f32 path of the
//! embedded engine.

use crate::quant::{nibble_hi, nibble_lo, Q4Matrix};
use crate::tensor::{Tensor, TensorI8};

use super::{GemmBackend, PreparedQ4Matrix, PreparedQMatrix, RowScales};

#[inline]
pub(crate) fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    // 4-way unrolled to give LLVM independent accumulation chains.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

#[inline]
pub(crate) fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0, 0, 0);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] as i32 * b[i] as i32 + a[i + 4] as i32 * b[i + 4] as i32;
        s1 += a[i + 1] as i32 * b[i + 1] as i32 + a[i + 5] as i32 * b[i + 5] as i32;
        s2 += a[i + 2] as i32 * b[i + 2] as i32 + a[i + 6] as i32 * b[i + 6] as i32;
        s3 += a[i + 3] as i32 * b[i + 3] as i32 + a[i + 7] as i32 * b[i + 7] as i32;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] as i32 * b[i] as i32;
    }
    s
}

/// Allocation-free core of [`gemm_f32`]: writes into `out`, reshaped in
/// place.  Shared by the scalar and blocked backends (f32 weights are not
/// packed), so both are bit-identical on f32.
pub(crate) fn gemm_f32_core(x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
    let (m, k) = (x.rows(), x.cols());
    let (n, k2) = (w.rows(), w.cols());
    assert_eq!(k, k2, "gemm_f32 contraction mismatch");
    out.reset(&[m, n]);
    for i in 0..m {
        let xi = x.row(i);
        let orow = out.row_mut(i);
        for j in 0..n {
            orow[j] = dot_f32(xi, w.row(j));
        }
        if let Some(b) = bias {
            for j in 0..n {
                orow[j] += b[j];
            }
        }
    }
}

/// Allocation-free core of the farm schedule over raw activation rows:
/// 4-row weight tiles streamed in storage order against all `m` x-rows,
/// per-row dequantization scales (see [`RowScales`]).
pub(crate) fn farm_core(
    xq: &[i8],
    m: usize,
    wq: &TensorI8,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k) = (wq.rows(), wq.cols());
    assert_eq!(xq.len(), m * k, "farm activation panel mismatch");
    out.reset(&[m, n]);
    let mut j = 0;
    // 4-row weight tiles: stream w rows j..j+4 against all m x-rows.
    while j + 4 <= n {
        let w0 = wq.row(j);
        let w1 = wq.row(j + 1);
        let w2 = wq.row(j + 2);
        let w3 = wq.row(j + 3);
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            let scale = scales.get(i);
            let (a0, a1, a2, a3) =
                (dot_i8(xi, w0), dot_i8(xi, w1), dot_i8(xi, w2), dot_i8(xi, w3));
            let orow = out.row_mut(i);
            orow[j] = a0 as f32 * scale;
            orow[j + 1] = a1 as f32 * scale;
            orow[j + 2] = a2 as f32 * scale;
            orow[j + 3] = a3 as f32 * scale;
        }
        j += 4;
    }
    while j < n {
        let wj = wq.row(j);
        for i in 0..m {
            out.row_mut(i)[j] = dot_i8(&xq[i * k..(i + 1) * k], wj) as f32 * scales.get(i);
        }
        j += 1;
    }
}

/// Allocation-free core of the dedicated m = 1 GEMV path (DESIGN.md §4):
/// the steady-state decode shape.  One activation row, streamed against
/// 4-row weight tiles in storage order — no per-row batch loop, no panel
/// staging, one pass over the weights.  Same exact i32 accumulation as
/// [`farm_core`] at m = 1, so bit-identical by construction.  `scale` is
/// the pre-multiplied `sx·sw` product.
pub(crate) fn gemv_core(xq: &[i8], wq: &TensorI8, scale: f32, out: &mut Tensor) {
    let (n, k) = (wq.rows(), wq.cols());
    assert_eq!(xq.len(), k, "gemv takes exactly one activation row");
    out.reset(&[1, n]);
    let orow = out.row_mut(0);
    let mut j = 0;
    while j + 4 <= n {
        let (a0, a1, a2, a3) = (
            dot_i8(xq, wq.row(j)),
            dot_i8(xq, wq.row(j + 1)),
            dot_i8(xq, wq.row(j + 2)),
            dot_i8(xq, wq.row(j + 3)),
        );
        orow[j] = a0 as f32 * scale;
        orow[j + 1] = a1 as f32 * scale;
        orow[j + 2] = a2 as f32 * scale;
        orow[j + 3] = a3 as f32 * scale;
        j += 4;
    }
    while j < n {
        orow[j] = dot_i8(xq, wq.row(j)) as f32 * scale;
        j += 1;
    }
}

// ---------------------------------------------------------------------------
// int4 reference cores: per-group scales, fixed accumulation contract
// (see the module docs of [`crate::kernels::pack`]).
// ---------------------------------------------------------------------------

/// Exact i32 sub-dot of one scale group: absolute weight columns
/// `[c0, cend)` of a nibble-packed row against the activation row.  `c0`
/// is always even (scale groups are even-sized), so every column pair
/// shares one byte; an odd `cend` — the ragged k tail — reads only the
/// low nibble of the final byte.
#[inline]
pub(crate) fn dot_q4_group(xq: &[i8], wbytes: &[u8], c0: usize, cend: usize) -> i32 {
    let mut acc = 0i32;
    let mut c = c0;
    while c + 1 < cend {
        let b = wbytes[c / 2];
        acc += xq[c] as i32 * nibble_lo(b) as i32 + xq[c + 1] as i32 * nibble_hi(b) as i32;
        c += 2;
    }
    if c < cend {
        acc += xq[c] as i32 * nibble_lo(wbytes[c / 2]) as i32;
    }
    acc
}

/// One int4 row dot under the fixed contract: exact i32 accumulation per
/// scale group, one f32 multiply by that group's scale, f32 sum in
/// ascending group order.  Every backend's int4 kernel must reproduce
/// this value bit-identically (the caller applies the activation scale
/// as one final f32 multiply).
#[inline]
pub(crate) fn dot_q4_row(xq: &[i8], wbytes: &[u8], scales: &[f32], k: usize, group: usize) -> f32 {
    let mut acc = 0.0f32;
    for (g, &s) in scales.iter().enumerate() {
        let c0 = g * group;
        let cend = (c0 + group).min(k);
        acc += dot_q4_group(xq, wbytes, c0, cend) as f32 * s;
    }
    acc
}

/// Allocation-free int4 farm core over raw activation rows — the
/// reference the blocked/simd int4 kernels are pinned to.  Weight rows
/// stream once in storage order; per-row activation scales come in via
/// [`RowScales`] with a unit weight scale (int4 weight scales are
/// per-group, folded into [`dot_q4_row`]).
pub(crate) fn farm4_core(
    xq: &[i8],
    m: usize,
    w: &Q4Matrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    let (n, k) = (w.rows(), w.cols());
    assert_eq!(xq.len(), m * k, "farm4 activation panel mismatch");
    out.reset(&[m, n]);
    let group = w.group();
    for j in 0..n {
        let wb = w.row_data(j);
        let ws = w.row_scales(j);
        for i in 0..m {
            let xi = &xq[i * k..(i + 1) * k];
            out.row_mut(i)[j] = dot_q4_row(xi, wb, ws, k, group) * scales.get(i);
        }
    }
}

/// Dedicated m = 1 int4 GEMV — same accumulation as [`farm4_core`] at
/// m = 1, so bit-identical by construction.
pub(crate) fn gemv4_core(xq: &[i8], w: &Q4Matrix, sx: f32, out: &mut Tensor) {
    let (n, k) = (w.rows(), w.cols());
    assert_eq!(xq.len(), k, "gemv4 takes exactly one activation row");
    out.reset(&[1, n]);
    let group = w.group();
    let orow = out.row_mut(0);
    for (j, o) in orow.iter_mut().enumerate() {
        *o = dot_q4_row(xq, w.row_data(j), w.row_scales(j), k, group) * sx;
    }
}

/// farm-style int4 GEMM: `y = (sx·xq) · dequant(w)ᵀ` with per-group
/// weight scales.  Allocating convenience wrapper over [`farm4_core`].
pub fn qgemm4_farm(xq: &TensorI8, w: &Q4Matrix, sx: f32) -> Tensor {
    assert_eq!(xq.cols(), w.cols(), "qgemm4_farm contraction mismatch");
    let mut out = Tensor::zeros(&[0, 0]);
    farm4_core(xq.data(), xq.rows(), w, RowScales::Uniform(sx), &mut out);
    out
}

/// Batch-m int4 farm GEMM with per-row activation scales (the pooled
/// recurrent path) — bit-identical to `m` batch-1 [`qgemm4_farm`] calls.
pub fn qgemm4_farm_rows(xq: &TensorI8, w: &Q4Matrix, sx: &[f32]) -> Tensor {
    assert_eq!(xq.cols(), w.cols(), "qgemm4_farm_rows contraction mismatch");
    assert_eq!(xq.rows(), sx.len(), "qgemm4_farm_rows needs one scale per row");
    let mut out = Tensor::zeros(&[0, 0]);
    farm4_core(xq.data(), xq.rows(), w, RowScales::PerRow(sx, 1.0), &mut out);
    out
}

/// Naive int4 reference for exactness tests: decodes one nibble at a
/// time via [`Q4Matrix::get`], accumulating under the same per-group
/// contract — deliberately independent of the packed-byte walk of
/// [`dot_q4_group`].
pub fn qgemm4_ref(xq: &TensorI8, w: &Q4Matrix, sx: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    assert_eq!(k, w.cols(), "qgemm4_ref contraction mismatch");
    let (n, group) = (w.rows(), w.group());
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let ws = w.row_scales(j);
            let mut acc = 0.0f32;
            for (g, &s) in ws.iter().enumerate() {
                let mut sub = 0i32;
                for c in g * group..(g * group + group).min(k) {
                    sub += xq.row(i)[c] as i32 * w.get(j, c) as i32;
                }
                acc += sub as f32 * s;
            }
            out.set2(i, j, acc * sx);
        }
    }
    out
}

/// `y = x @ wᵀ + bias?`, f32. x: (m, k), w: (n, k) -> (m, n).
pub fn gemm_f32(x: &Tensor, w: &Tensor, bias: Option<&[f32]>) -> Tensor {
    let mut out = Tensor::zeros(&[0, 0]);
    gemm_f32_core(x, w, bias, &mut out);
    out
}

/// farm-style quantized GEMM: `y = (sx·xq) (sw·wq)ᵀ`.
///
/// xq: (m, k) — the small activation panel (batch ≤ ~8 in practice);
/// wq: (n, k) — the big weight matrix, streamed once, in storage order.
/// Output tile: 4 weight rows × m activation rows of i32 accumulators
/// live in registers across the whole k extent.
pub fn qgemm_farm(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    assert_eq!(xq.cols(), wq.cols(), "qgemm_farm contraction mismatch");
    let mut out = Tensor::zeros(&[0, 0]);
    farm_core(xq.data(), xq.rows(), wq, RowScales::Uniform(sx * sw), &mut out);
    out
}

/// Batch-m farm GEMM with **per-row activation scales** — the pooled
/// recurrent step of the multi-stream engine ([`crate::stream`]).
///
/// Each activation row belongs to a different utterance stream and was
/// quantized independently (`sx[i]` is stream *i*'s dynamic scale), so
/// row *i* dequantizes as `acc · sx[i] · sw`.  The i32 accumulation and
/// the per-row scale product are exactly what `m` separate
/// [`qgemm_farm`] calls at batch 1 would compute, which is what makes
/// pooled decoding bit-identical to sequential decoding while the big
/// weight matrix streams through cache only **once** for all `m`
/// streams (the §4 small-batch sweet spot).
pub fn qgemm_farm_rows(xq: &TensorI8, wq: &TensorI8, sx: &[f32], sw: f32) -> Tensor {
    assert_eq!(xq.cols(), wq.cols(), "qgemm_farm_rows contraction mismatch");
    assert_eq!(xq.rows(), sx.len(), "qgemm_farm_rows needs one scale per row");
    let mut out = Tensor::zeros(&[0, 0]);
    farm_core(xq.data(), xq.rows(), wq, RowScales::PerRow(sx, sw), &mut out);
    out
}

// ---------------------------------------------------------------------------
// gemmlowp-style: pack both operands, panel compute, unpack.
// ---------------------------------------------------------------------------

const LOWP_KC: usize = 256; // k-strip
const LOWP_NR: usize = 4; // weight panel rows
const LOWP_MR: usize = 8; // activation panel rows (gemmlowp NEON kernels are 8x8/12x4)

/// gemmlowp-style quantized GEMM (pack → compute → unpack).
///
/// Faithful to the library's structure, including the two properties that
/// make it lose at small batch (the paper's §4 point):
///
/// 1. **per-call packing** of both operands into `[strip][panel]`
///    interleaved layouts — O(n·k) copy traffic that only amortizes when
///    many activation columns reuse the packed weights;
/// 2. **a fixed MR×NR register tile** (gemmlowp's NEON kernels are
///    12×4/8×8 etc.): the activation panel is zero-padded up to
///    `LOWP_MR` rows, so a batch-1 GEMM performs `LOWP_MR×` the useful
///    multiply-accumulates.  farm instead specializes per batch size.
///
/// Exactness is unaffected (padded rows are zero and dropped on unpack);
/// the cost structure is what changes — which is exactly the Figure-6
/// story.  This is deliberately **not** a [`GemmBackend`]: its per-call
/// packing is the cost [`super::PackedQMatrix`] plan-time packing avoids.
pub fn qgemm_lowp(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let (n, k2) = (wq.rows(), wq.cols());
    assert_eq!(k, k2, "qgemm_lowp contraction mismatch");
    let scale = sx * sw;
    let mp = m.div_ceil(LOWP_MR) * LOWP_MR; // fixed-tile row padding
    let mut acc = vec![0i32; mp * n];

    let nstrips = k.div_ceil(LOWP_KC);
    // Reusable packing buffers (gemmlowp allocates these per context).
    let npanels = n.div_ceil(LOWP_NR);
    let mut wpack = vec![0i8; npanels * LOWP_NR * LOWP_KC];
    let mut xpack = vec![0i8; mp * LOWP_KC];

    for strip in 0..nstrips {
        let k0 = strip * LOWP_KC;
        let kc = LOWP_KC.min(k - k0);

        // pack weights: panel-major, row-interleaved by 4 (zero-padded)
        for p in 0..npanels {
            for r in 0..LOWP_NR {
                let row = p * LOWP_NR + r;
                let dst = &mut wpack[(p * LOWP_NR + r) * LOWP_KC..][..kc];
                if row < n {
                    dst.copy_from_slice(&wq.row(row)[k0..k0 + kc]);
                } else {
                    dst.fill(0);
                }
            }
        }
        // pack activations: strip-contiguous rows, zero-padded to MR
        xpack.fill(0);
        for i in 0..m {
            xpack[i * LOWP_KC..i * LOWP_KC + kc]
                .copy_from_slice(&xq.row(i)[k0..k0 + kc]);
        }

        // compute pass over packed memory: full MR×NR tiles always
        for p in 0..npanels {
            let base = p * LOWP_NR;
            let w0 = &wpack[(base) * LOWP_KC..][..kc];
            let w1 = &wpack[(base + 1) * LOWP_KC..][..kc];
            let w2 = &wpack[(base + 2) * LOWP_KC..][..kc];
            let w3 = &wpack[(base + 3) * LOWP_KC..][..kc];
            for i in 0..mp {
                let xi = &xpack[i * LOWP_KC..][..kc];
                let arow = &mut acc[i * n..];
                let (a0, a1, a2, a3) =
                    (dot_i8(xi, w0), dot_i8(xi, w1), dot_i8(xi, w2), dot_i8(xi, w3));
                arow[base] += a0;
                if base + 1 < n {
                    arow[base + 1] += a1;
                }
                if base + 2 < n {
                    arow[base + 2] += a2;
                }
                if base + 3 < n {
                    arow[base + 3] += a3;
                }
            }
        }
    }

    // unpack / dequantize (drops the padded rows)
    let data: Vec<f32> = acc[..m * n].iter().map(|&a| a as f32 * scale).collect();
    Tensor::new(&[m, n], data).unwrap()
}

/// Naive i32 reference for exactness tests.
pub fn qgemm_ref(xq: &TensorI8, wq: &TensorI8, sx: f32, sw: f32) -> Tensor {
    let (m, k) = (xq.rows(), xq.cols());
    let n = wq.rows();
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut a = 0i32;
            for kk in 0..k {
                a += xq.row(i)[kk] as i32 * wq.row(j)[kk] as i32;
            }
            out.set2(i, j, a as f32 * (sx * sw));
        }
    }
    out
}

/// The reference backend: the farm schedule over row-major weights, no
/// packing, exactly the code the bit-identity contract is defined by.
pub struct ScalarBackend;

impl GemmBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
        gemm_f32_core(x, w, bias, out);
    }

    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        farm_core(xq, m, &w.q, RowScales::Uniform(sx * w.scale), out);
    }

    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
        farm_core(xq, m, &w.q, RowScales::PerRow(sx, w.scale), out);
    }

    fn qgemv_into(&self, xq: &[i8], w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        gemv_core(xq, &w.q, sx * w.scale, out);
    }

    fn qgemm4_farm_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: f32,
        out: &mut Tensor,
    ) {
        farm4_core(xq, m, &w.q4, RowScales::Uniform(sx), out);
    }

    fn qgemm4_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm4_farm_rows needs one scale per row");
        farm4_core(xq, m, &w.q4, RowScales::PerRow(sx, 1.0), out);
    }

    fn qgemv4_into(&self, xq: &[i8], w: &PreparedQ4Matrix, sx: f32, out: &mut Tensor) {
        gemv4_core(xq, &w.q4, sx, out);
    }

    // qgemm_gates_rows_into / qgemm4_gates_rows_into keep the trait
    // defaults (the stacked three-gate sweep): scalar *is* the reference
    // the fused kernels of the other backends are tested against.
}

//! Runtime NR/KC tile autotuning for the blocked backend's packed-weight
//! layout (DESIGN.md §4).
//!
//! The default [`NR`]=4 / [`KC`]=256 tile is a sane portable choice, but
//! the best panel height and strip width depend on the host's cache
//! hierarchy and the actual weight dims.  At **engine construction** (and
//! only then — never per call), [`choose`] micro-probes a small candidate
//! grid on the real `(n, k)` shape: each candidate is packed, the blocked
//! packed core is timed at the steady-state decode batch (m = 1), and the
//! fastest tile wins.  The winner is cached per `(n, k)` so repeated
//! constructions (registry rungs, shard fleets, tests) probe once per
//! shape per process.
//!
//! Correctness is never at stake: every tile shape produces exact i32
//! accumulation over the same products, so any choice is bit-identical to
//! [`super::qgemm_ref`] (the parity suite pins this across candidates).
//! The probe's only nondeterminism is *which* tile wins — `--autotune
//! off` (or `TRACENORM_AUTOTUNE=off`) pins the defaults for byte-stable
//! layout reproducibility.
//!
//! Probes are confined to plan time by construction: the steady-state
//! alloc/probe discipline is enforced in `rust/tests/alloc_free.rs` via
//! [`probe_count`], which must not move once decoding starts.  Weights
//! smaller than [`MIN_PROBE_ELEMS`] skip probing entirely (tile choice is
//! noise at that size, and tiny unit-test weights should not pay for
//! timing runs).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::tensor::{Tensor, TensorI8};

use super::blocked::qgemm_packed_core;
use super::pack::{PackedQMatrix, KC, NR};
use super::RowScales;

/// The probed `(nr, kc)` grid: both panel heights the packed core
/// specializes for × L1-scale strip widths around the default.
pub const CANDIDATES: &[(usize, usize)] =
    &[(4, 128), (4, 256), (4, 512), (8, 128), (8, 256), (8, 512)];

/// Weights with fewer than this many elements keep the default tile
/// (probing noise would exceed the win, and construction stays instant
/// for tiny test models).
pub const MIN_PROBE_ELEMS: usize = 32 * 1024;

/// Timed repetitions per candidate (minimum taken, after one warmup).
const PROBE_REPS: usize = 3;

static ENABLED: OnceLock<AtomicBool> = OnceLock::new();
static PROBES: AtomicU64 = AtomicU64::new(0);
#[allow(clippy::type_complexity)]
static CACHE: OnceLock<Mutex<HashMap<(usize, usize), (usize, usize)>>> = OnceLock::new();

fn enabled_flag() -> &'static AtomicBool {
    ENABLED.get_or_init(|| {
        let off = matches!(
            std::env::var("TRACENORM_AUTOTUNE").as_deref(),
            Ok("off") | Ok("0") | Ok("false")
        );
        AtomicBool::new(!off)
    })
}

/// Enable or disable probing process-wide (`--autotune on|off`; the
/// `TRACENORM_AUTOTUNE` env var sets the initial state).  Disabling pins
/// the [`NR`]/[`KC`] defaults for every later weight preparation; already
/// cached winners are left as-is.
pub fn set_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

/// Whether construction-time probing is currently enabled.
pub fn is_enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Number of micro-probes run so far in this process.  Steady-state
/// decode must never move this counter (`rust/tests/alloc_free.rs`).
pub fn probe_count() -> u64 {
    PROBES.load(Ordering::Relaxed)
}

/// The `(nr, kc)` tile to pack an `(n, k)` int8 weight with: the cached
/// probe winner when autotuning is on and the weight is probe-worthy,
/// else the pinned defaults.
pub fn choose(n: usize, k: usize) -> (usize, usize) {
    if !is_enabled() || n * k < MIN_PROBE_ELEMS {
        return (NR, KC);
    }
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(&hit) = cache.lock().unwrap().get(&(n, k)) {
        return hit;
    }
    let best = probe(n, k);
    cache.lock().unwrap().insert((n, k), best);
    best
}

/// Time every candidate tile on a synthetic `(n, k)` weight at m = 1 (the
/// steady-state decode batch) and return the fastest.  Operand *values*
/// cannot affect timing (dense integer kernels), so a fixed pattern is
/// used — the probe allocates and times, which is exactly why it only
/// ever runs at plan time.
fn probe(n: usize, k: usize) -> (usize, usize) {
    PROBES.fetch_add(1, Ordering::Relaxed);
    let obs_t0 = Instant::now();
    let best = probe_timed(n, k);
    if crate::obs::enabled() {
        crate::obs::spans::record_global(
            crate::obs::Stage::Autotune,
            obs_t0.elapsed().as_secs_f64(),
        );
    }
    best
}

fn probe_timed(n: usize, k: usize) -> (usize, usize) {
    let wq = TensorI8::new(
        &[n, k],
        (0..n * k).map(|i| ((i * 37 + 11) % 251) as i32 - 125).map(|v| v as i8).collect(),
    )
    .expect("probe weight shape");
    let xq: Vec<i8> = (0..k).map(|i| (((i * 7 + 3) % 251) as i32 - 125) as i8).collect();
    let mut out = Tensor::zeros(&[0, 0]);
    let mut best = (NR, KC);
    let mut best_t = f64::INFINITY;
    for &(nr, kc) in CANDIDATES {
        let packed = PackedQMatrix::pack_with(&wq, nr, kc);
        // warmup pass (page in the packed copy), then min over reps
        qgemm_packed_core(&xq, 1, &packed, RowScales::Uniform(1.0), &mut out);
        let mut t_min = f64::INFINITY;
        for _ in 0..PROBE_REPS {
            let t0 = Instant::now();
            qgemm_packed_core(&xq, 1, &packed, RowScales::Uniform(1.0), &mut out);
            t_min = t_min.min(t0.elapsed().as_secs_f64());
        }
        if t_min < best_t {
            best_t = t_min;
            best = (nr, kc);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_weights_skip_probing() {
        let before = probe_count();
        assert_eq!(choose(8, 8), (NR, KC));
        assert_eq!(probe_count(), before, "sub-threshold shapes must not probe");
    }

    #[test]
    fn disabled_pins_defaults() {
        let was = is_enabled();
        set_enabled(false);
        let before = probe_count();
        assert_eq!(choose(512, 512), (NR, KC));
        assert_eq!(probe_count(), before, "disabled autotune must not probe");
        set_enabled(was);
    }

    #[test]
    fn probe_winner_is_a_candidate_and_cached() {
        let was = is_enabled();
        set_enabled(true);
        let (n, k) = (192, 384); // probe-worthy, not a demo-dims shape
        let first = choose(n, k);
        assert!(CANDIDATES.contains(&first), "winner {first:?} not in the grid");
        let probes = probe_count();
        let second = choose(n, k);
        assert_eq!(first, second, "cached winner must be stable");
        assert_eq!(probe_count(), probes, "second lookup must hit the cache");
        set_enabled(was);
    }
}

//! The `simd` backend (cargo feature `simd`): the farm schedule with
//! `std::arch` vector dot products — AVX2 on x86_64, NEON on aarch64 —
//! selected by **runtime** CPU detection with a transparent scalar
//! fallback, so a `--features simd` binary is safe on any host.
//!
//! Exactness: the int8 path widens i8 → i16 and multiply-accumulates into
//! i32 lanes (`_mm256_madd_epi16` / `vmull_s8` + `vpadalq_s16`), which is
//! exact — integer addition is associative, so lane-order differences
//! cannot change the result and the backend stays **bit-identical** to
//! [`super::scalar`] on int8.  The f32 path reorders the summation into
//! vector lanes, so it may differ from scalar at rounding level (the
//! parity suite allows ≤ 1e-5 relative).
//!
//! Weights are read in the row-major reference layout: with the dot
//! vectorized along k, row-major already gives sequential weight loads,
//! and keeping one layout per ISA family avoids a second packed variant.

use crate::tensor::Tensor;

use super::{blocked, scalar, GemmBackend, PreparedQ4Matrix, PreparedQMatrix, RowScales};

/// Is an accelerated path actually usable on this CPU at runtime?
/// (`auto` consults this; without support the backend still works via
/// the scalar fallback.)
#[cfg(target_arch = "x86_64")]
pub fn runtime_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Is an accelerated path actually usable on this CPU at runtime?
#[cfg(target_arch = "aarch64")]
pub fn runtime_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// Is an accelerated path actually usable on this CPU at runtime?
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn runtime_available() -> bool {
    false
}

/// The runtime-detected vector backend (see module docs).
pub struct SimdBackend;

impl GemmBackend for SimdBackend {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn gemm_f32_into(&self, x: &Tensor, w: &Tensor, bias: Option<&[f32]>, out: &mut Tensor) {
        #[cfg(target_arch = "x86_64")]
        if runtime_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { x86::gemm_f32_avx2(x, w, bias, out) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if runtime_available() {
            // SAFETY: NEON support was just verified at runtime.
            unsafe { arm::gemm_f32_neon(x, w, bias, out) };
            return;
        }
        scalar::gemm_f32_core(x, w, bias, out);
    }

    fn qgemm_farm_into(&self, xq: &[i8], m: usize, w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        farm_dispatch(xq, m, w, RowScales::Uniform(sx * w.scale), out);
    }

    fn qgemm_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_farm_rows needs one scale per row");
        farm_dispatch(xq, m, w, RowScales::PerRow(sx, w.scale), out);
    }

    fn qgemv_into(&self, xq: &[i8], w: &PreparedQMatrix, sx: f32, out: &mut Tensor) {
        let scale = sx * w.scale;
        #[cfg(target_arch = "x86_64")]
        if runtime_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { x86::gemv_avx2(xq, &w.q, scale, out) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if runtime_available() {
            // SAFETY: NEON support was just verified at runtime.
            unsafe { arm::gemv_neon(xq, &w.q, scale, out) };
            return;
        }
        scalar::gemv_core(xq, &w.q, scale, out);
    }

    fn qgemm_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQMatrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm_gates_rows needs one scale per row");
        let Some(gp) = &w.gates else {
            // no gate panels on this weight: plain stacked sweep
            farm_dispatch(xq, m, w, RowScales::PerRow(sx, w.scale), out);
            return;
        };
        let scales = RowScales::PerRow(sx, w.scale);
        #[cfg(target_arch = "x86_64")]
        if runtime_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { x86::gates_avx2(xq, m, gp, scales, out) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if runtime_available() {
            // SAFETY: NEON support was just verified at runtime.
            unsafe { arm::gates_neon(xq, m, gp, scales, out) };
            return;
        }
        blocked::qgemm_gates_core(xq, m, gp, scales, out);
    }

    fn qgemm4_farm_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: f32,
        out: &mut Tensor,
    ) {
        farm4_dispatch(xq, m, w, RowScales::Uniform(sx), out);
    }

    fn qgemm4_farm_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm4_farm_rows needs one scale per row");
        farm4_dispatch(xq, m, w, RowScales::PerRow(sx, 1.0), out);
    }

    fn qgemv4_into(&self, xq: &[i8], w: &PreparedQ4Matrix, sx: f32, out: &mut Tensor) {
        #[cfg(target_arch = "x86_64")]
        if runtime_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { x86::gemv4_avx2(xq, &w.q4, sx, out) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if runtime_available() {
            // SAFETY: NEON support was just verified at runtime.
            unsafe { arm::gemv4_neon(xq, &w.q4, sx, out) };
            return;
        }
        scalar::gemv4_core(xq, &w.q4, sx, out);
    }

    fn qgemm4_gates_rows_into(
        &self,
        xq: &[i8],
        m: usize,
        w: &PreparedQ4Matrix,
        sx: &[f32],
        out: &mut Tensor,
    ) {
        assert_eq!(m, sx.len(), "qgemm4_gates_rows needs one scale per row");
        let Some(gp) = &w.gates else {
            // no gate panels on this weight: plain stacked sweep
            farm4_dispatch(xq, m, w, RowScales::PerRow(sx, 1.0), out);
            return;
        };
        let scales = RowScales::PerRow(sx, 1.0);
        #[cfg(target_arch = "x86_64")]
        if runtime_available() {
            // SAFETY: AVX2 support was just verified at runtime.
            unsafe { x86::gates4_avx2(xq, m, gp, scales, out) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if runtime_available() {
            // SAFETY: NEON support was just verified at runtime.
            unsafe { arm::gates4_neon(xq, m, gp, scales, out) };
            return;
        }
        blocked::qgemm4_gates_core(xq, m, gp, scales, out);
    }
}

fn farm4_dispatch(
    xq: &[i8],
    m: usize,
    w: &PreparedQ4Matrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    #[cfg(target_arch = "x86_64")]
    if runtime_available() {
        // SAFETY: AVX2 support was just verified at runtime.
        unsafe { x86::farm4_avx2(xq, m, &w.q4, scales, out) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if runtime_available() {
        // SAFETY: NEON support was just verified at runtime.
        unsafe { arm::farm4_neon(xq, m, &w.q4, scales, out) };
        return;
    }
    scalar::farm4_core(xq, m, &w.q4, scales, out);
}

fn farm_dispatch(
    xq: &[i8],
    m: usize,
    w: &PreparedQMatrix,
    scales: RowScales<'_>,
    out: &mut Tensor,
) {
    #[cfg(target_arch = "x86_64")]
    if runtime_available() {
        // SAFETY: AVX2 support was just verified at runtime.
        unsafe { x86::farm_avx2(xq, m, &w.q, scales, out) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if runtime_available() {
        // SAFETY: NEON support was just verified at runtime.
        unsafe { arm::farm_neon(xq, m, &w.q, scales, out) };
        return;
    }
    scalar::farm_core(xq, m, &w.q, scales, out);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    use crate::kernels::pack::{PackedGatePanels, PackedQ4GatePanels, KC};
    use crate::kernels::{scalar, RowScales};
    use crate::quant::Q4Matrix;
    use crate::tensor::{Tensor, TensorI8};

    /// Exact int8 dot: widen i8→i16, `madd` pairs into i32 lanes, sum.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 16;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let av = _mm_loadu_si128(a.as_ptr().add(c * 16).cast());
            let bv = _mm_loadu_si128(b.as_ptr().add(c * 16).cast());
            let prod = _mm256_madd_epi16(_mm256_cvtepi8_epi16(av), _mm256_cvtepi8_epi16(bv));
            acc = _mm256_add_epi32(acc, prod);
        }
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256::<1>(acc);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s)); // swap 64-bit halves
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s)); // swap 32-bit pairs
        let mut sum = _mm_cvtsi128_si32(s);
        for i in chunks * 16..n {
            sum += a[i] as i32 * b[i] as i32;
        }
        sum
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(c * 8));
            let bv = _mm256_loadu_ps(b.as_ptr().add(c * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let lo = _mm256_castps256_ps128(acc);
        let hi = _mm256_extractf128_ps::<1>(acc);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0x1>(s, s));
        let mut sum = _mm_cvtss_f32(s);
        for i in chunks * 8..n {
            sum += a[i] * b[i];
        }
        sum
    }

    /// The farm schedule with AVX2 dots (same 4-row weight tiles as the
    /// scalar core; int8 results are bit-identical).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn farm_avx2(
        xq: &[i8],
        m: usize,
        wq: &TensorI8,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (n, k) = (wq.rows(), wq.cols());
        assert_eq!(xq.len(), m * k, "simd activation panel mismatch");
        out.reset(&[m, n]);
        let mut j = 0;
        while j + 4 <= n {
            let w0 = wq.row(j);
            let w1 = wq.row(j + 1);
            let w2 = wq.row(j + 2);
            let w3 = wq.row(j + 3);
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let scale = scales.get(i);
                let (a0, a1, a2, a3) = (
                    dot_i8_avx2(xi, w0),
                    dot_i8_avx2(xi, w1),
                    dot_i8_avx2(xi, w2),
                    dot_i8_avx2(xi, w3),
                );
                let orow = out.row_mut(i);
                orow[j] = a0 as f32 * scale;
                orow[j + 1] = a1 as f32 * scale;
                orow[j + 2] = a2 as f32 * scale;
                orow[j + 3] = a3 as f32 * scale;
            }
            j += 4;
        }
        while j < n {
            let wj = wq.row(j);
            for i in 0..m {
                out.row_mut(i)[j] =
                    dot_i8_avx2(&xq[i * k..(i + 1) * k], wj) as f32 * scales.get(i);
            }
            j += 1;
        }
    }

    /// m = 1 GEMV with AVX2 dots over the row-major reference copy (same
    /// 4-row tiling as `scalar::gemv_core`; int8 results bit-identical).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemv_avx2(xq: &[i8], wq: &TensorI8, scale: f32, out: &mut Tensor) {
        let (n, k) = (wq.rows(), wq.cols());
        assert_eq!(xq.len(), k, "gemv takes exactly one activation row");
        out.reset(&[1, n]);
        let orow = out.row_mut(0);
        let mut j = 0;
        while j + 4 <= n {
            orow[j] = dot_i8_avx2(xq, wq.row(j)) as f32 * scale;
            orow[j + 1] = dot_i8_avx2(xq, wq.row(j + 1)) as f32 * scale;
            orow[j + 2] = dot_i8_avx2(xq, wq.row(j + 2)) as f32 * scale;
            orow[j + 3] = dot_i8_avx2(xq, wq.row(j + 3)) as f32 * scale;
            j += 4;
        }
        while j < n {
            orow[j] = dot_i8_avx2(xq, wq.row(j)) as f32 * scale;
            j += 1;
        }
    }

    /// Fused GRU-gate sweep over gate-interleaved panels with AVX2 dots
    /// (same schedule as `blocked::qgemm_gates_core`; bit-identical).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gates_avx2(
        xq: &[i8],
        m: usize,
        gp: &PackedGatePanels,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (h, k) = (gp.h(), gp.k());
        assert_eq!(xq.len(), m * k, "fused-gate activation panel mismatch");
        out.reset(&[m, 3 * h]);
        let nstrips = gp.nstrips();
        for j in 0..h {
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let (mut az, mut ar, mut ac) = (0i32, 0, 0);
                for s in 0..nstrips {
                    let k0 = s * KC;
                    let kc = gp.strip_cols(s);
                    let block = gp.block(s, j);
                    let xs = &xi[k0..k0 + kc];
                    az += dot_i8_avx2(xs, &block[..kc]);
                    ar += dot_i8_avx2(xs, &block[kc..2 * kc]);
                    ac += dot_i8_avx2(xs, &block[2 * kc..]);
                }
                let scale = scales.get(i);
                let orow = out.row_mut(i);
                orow[j] = az as f32 * scale;
                orow[h + j] = ar as f32 * scale;
                orow[2 * h + j] = ac as f32 * scale;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_f32_avx2(
        x: &Tensor,
        w: &Tensor,
        bias: Option<&[f32]>,
        out: &mut Tensor,
    ) {
        let (m, k) = (x.rows(), x.cols());
        let (n, k2) = (w.rows(), w.cols());
        assert_eq!(k, k2, "gemm_f32 contraction mismatch");
        out.reset(&[m, n]);
        for i in 0..m {
            let xi = x.row(i);
            let orow = out.row_mut(i);
            for j in 0..n {
                orow[j] = dot_f32_avx2(xi, w.row(j));
            }
            if let Some(b) = bias {
                for j in 0..n {
                    orow[j] += b[j];
                }
            }
        }
    }

    // -- int4 unpack-and-widen dots -----------------------------------------

    /// Exact i32 dot of one full 32-column scale group: 16 nibble-packed
    /// weight bytes against 32 activation bytes.  Unpack: mask the low
    /// nibbles, shift-mask the high nibbles, sign-extend 4-bit
    /// two's-complement via the xor-sub trick `(v ^ 8) - 8`, then
    /// interleave lo/hi back into natural column order with
    /// `unpacklo/unpackhi` before the same widen-madd accumulation as
    /// [`dot_i8_avx2`].  Per-lane products fit i16 (|x|·|w| ≤ 127·7·2),
    /// so the accumulation is exact.
    ///
    /// SAFETY: caller guarantees 32 readable i8 at `x` and 16 readable
    /// bytes at `w`.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_q4_block32_avx2(x: *const i8, w: *const u8) -> i32 {
        let v = _mm_loadu_si128(w.cast());
        let mask = _mm_set1_epi8(0x0f);
        let eight = _mm_set1_epi8(8);
        let lo = _mm_and_si128(v, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), mask);
        let lo = _mm_sub_epi8(_mm_xor_si128(lo, eight), eight);
        let hi = _mm_sub_epi8(_mm_xor_si128(hi, eight), eight);
        // byte t of `lo`/`hi` holds columns 2t / 2t+1: interleaving
        // restores natural order (w01 = cols 0..15, w23 = cols 16..31)
        let w01 = _mm_unpacklo_epi8(lo, hi);
        let w23 = _mm_unpackhi_epi8(lo, hi);
        let x01 = _mm_loadu_si128(x.cast());
        let x23 = _mm_loadu_si128(x.add(16).cast());
        let p0 = _mm256_madd_epi16(_mm256_cvtepi8_epi16(x01), _mm256_cvtepi8_epi16(w01));
        let p1 = _mm256_madd_epi16(_mm256_cvtepi8_epi16(x23), _mm256_cvtepi8_epi16(w23));
        let acc = _mm256_add_epi32(p0, p1);
        let lo128 = _mm256_castsi256_si128(acc);
        let hi128 = _mm256_extracti128_si256::<1>(acc);
        let s = _mm_add_epi32(lo128, hi128);
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s));
        _mm_cvtsi128_si32(s)
    }

    /// Exact i32 sub-dot of one scale group, columns `[c0, cend)`
    /// (strip- or row-relative): full 32-column groups take the vector
    /// block, ragged tails fall back to the scalar nibble walk — both
    /// exact, so the choice cannot change bits.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_q4_group_avx2(xs: &[i8], wbytes: &[u8], c0: usize, cend: usize) -> i32 {
        if cend - c0 == 32 {
            dot_q4_block32_avx2(xs.as_ptr().add(c0), wbytes.as_ptr().add(c0 / 2))
        } else {
            scalar::dot_q4_group(xs, wbytes, c0, cend)
        }
    }

    /// One int4 row dot under the fixed accumulation contract (exact i32
    /// per group → f32 × group scale → f32 sum ascending): bit-identical
    /// to `scalar::dot_q4_row`.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_q4_row_avx2(
        xq: &[i8],
        wbytes: &[u8],
        scales: &[f32],
        k: usize,
        group: usize,
    ) -> f32 {
        let mut acc = 0.0f32;
        for (g, &s) in scales.iter().enumerate() {
            let c0 = g * group;
            let cend = (c0 + group).min(k);
            acc += dot_q4_group_avx2(xq, wbytes, c0, cend) as f32 * s;
        }
        acc
    }

    /// The int4 farm schedule with AVX2 nibble dots over the row-major
    /// reference layout (bit-identical to `scalar::farm4_core`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn farm4_avx2(
        xq: &[i8],
        m: usize,
        w: &Q4Matrix,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(xq.len(), m * k, "simd int4 activation panel mismatch");
        out.reset(&[m, n]);
        let group = w.group();
        for j in 0..n {
            let wb = w.row_data(j);
            let ws = w.row_scales(j);
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                out.row_mut(i)[j] = dot_q4_row_avx2(xi, wb, ws, k, group) * scales.get(i);
            }
        }
    }

    /// m = 1 int4 GEMV with AVX2 nibble dots (bit-identical to
    /// `scalar::gemv4_core`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemv4_avx2(xq: &[i8], w: &Q4Matrix, sx: f32, out: &mut Tensor) {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(xq.len(), k, "gemv4 takes exactly one activation row");
        out.reset(&[1, n]);
        let group = w.group();
        let orow = out.row_mut(0);
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot_q4_row_avx2(xq, w.row_data(j), w.row_scales(j), k, group) * sx;
        }
    }

    /// Fused int4 GRU-gate sweep over gate-interleaved nibble panels
    /// (same schedule as `blocked::qgemm4_gates_core`; bit-identical).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gates4_avx2(
        xq: &[i8],
        m: usize,
        gp: &PackedQ4GatePanels,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (h, k, group) = (gp.h(), gp.k(), gp.group());
        assert_eq!(xq.len(), m * k, "fused-gate int4 activation panel mismatch");
        out.reset(&[m, 3 * h]);
        let nstrips = gp.nstrips();
        for j in 0..h {
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let (mut az, mut ar, mut ac) = (0f32, 0f32, 0f32);
                for s in 0..nstrips {
                    let k0 = s * KC;
                    let kcs = gp.strip_cols(s);
                    let pairs = kcs.div_ceil(2);
                    let gs = kcs.div_ceil(group);
                    let block = gp.block(s, j);
                    let bscales = gp.block_scales(s, j);
                    let xs = &xi[k0..k0 + kcs];
                    let (zb, rb, cb) =
                        (&block[..pairs], &block[pairs..2 * pairs], &block[2 * pairs..]);
                    for g in 0..gs {
                        let c0 = g * group;
                        let cend = (c0 + group).min(kcs);
                        az += dot_q4_group_avx2(xs, zb, c0, cend) as f32 * bscales[g];
                        ar += dot_q4_group_avx2(xs, rb, c0, cend) as f32 * bscales[gs + g];
                        ac += dot_q4_group_avx2(xs, cb, c0, cend) as f32 * bscales[2 * gs + g];
                    }
                }
                let scale = scales.get(i);
                let orow = out.row_mut(i);
                orow[j] = az * scale;
                orow[h + j] = ar * scale;
                orow[2 * h + j] = ac * scale;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    use crate::kernels::pack::{PackedGatePanels, PackedQ4GatePanels, KC};
    use crate::kernels::{scalar, RowScales};
    use crate::quant::Q4Matrix;
    use crate::tensor::{Tensor, TensorI8};

    /// Exact int8 dot: widening `vmull_s8` into i16, pairwise-accumulate
    /// into i32 lanes, horizontal sum.
    #[target_feature(enable = "neon")]
    unsafe fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 16;
        let mut acc = vdupq_n_s32(0);
        for c in 0..chunks {
            let av = vld1q_s8(a.as_ptr().add(c * 16));
            let bv = vld1q_s8(b.as_ptr().add(c * 16));
            acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(av), vget_low_s8(bv)));
            acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(av), vget_high_s8(bv)));
        }
        let mut sum = vaddvq_s32(acc);
        for i in chunks * 16..n {
            sum += a[i] as i32 * b[i] as i32;
        }
        sum
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_f32_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let av = vld1q_f32(a.as_ptr().add(c * 4));
            let bv = vld1q_f32(b.as_ptr().add(c * 4));
            acc = vfmaq_f32(acc, av, bv);
        }
        let mut sum = vaddvq_f32(acc);
        for i in chunks * 4..n {
            sum += a[i] * b[i];
        }
        sum
    }

    /// The farm schedule with NEON dots (int8 bit-identical to scalar).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn farm_neon(
        xq: &[i8],
        m: usize,
        wq: &TensorI8,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (n, k) = (wq.rows(), wq.cols());
        assert_eq!(xq.len(), m * k, "simd activation panel mismatch");
        out.reset(&[m, n]);
        let mut j = 0;
        while j + 4 <= n {
            let w0 = wq.row(j);
            let w1 = wq.row(j + 1);
            let w2 = wq.row(j + 2);
            let w3 = wq.row(j + 3);
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let scale = scales.get(i);
                let (a0, a1, a2, a3) = (
                    dot_i8_neon(xi, w0),
                    dot_i8_neon(xi, w1),
                    dot_i8_neon(xi, w2),
                    dot_i8_neon(xi, w3),
                );
                let orow = out.row_mut(i);
                orow[j] = a0 as f32 * scale;
                orow[j + 1] = a1 as f32 * scale;
                orow[j + 2] = a2 as f32 * scale;
                orow[j + 3] = a3 as f32 * scale;
            }
            j += 4;
        }
        while j < n {
            let wj = wq.row(j);
            for i in 0..m {
                out.row_mut(i)[j] =
                    dot_i8_neon(&xq[i * k..(i + 1) * k], wj) as f32 * scales.get(i);
            }
            j += 1;
        }
    }

    /// m = 1 GEMV with NEON dots over the row-major reference copy (same
    /// 4-row tiling as `scalar::gemv_core`; int8 results bit-identical).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemv_neon(xq: &[i8], wq: &TensorI8, scale: f32, out: &mut Tensor) {
        let (n, k) = (wq.rows(), wq.cols());
        assert_eq!(xq.len(), k, "gemv takes exactly one activation row");
        out.reset(&[1, n]);
        let orow = out.row_mut(0);
        let mut j = 0;
        while j + 4 <= n {
            orow[j] = dot_i8_neon(xq, wq.row(j)) as f32 * scale;
            orow[j + 1] = dot_i8_neon(xq, wq.row(j + 1)) as f32 * scale;
            orow[j + 2] = dot_i8_neon(xq, wq.row(j + 2)) as f32 * scale;
            orow[j + 3] = dot_i8_neon(xq, wq.row(j + 3)) as f32 * scale;
            j += 4;
        }
        while j < n {
            orow[j] = dot_i8_neon(xq, wq.row(j)) as f32 * scale;
            j += 1;
        }
    }

    /// Fused GRU-gate sweep over gate-interleaved panels with NEON dots
    /// (same schedule as `blocked::qgemm_gates_core`; bit-identical).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gates_neon(
        xq: &[i8],
        m: usize,
        gp: &PackedGatePanels,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (h, k) = (gp.h(), gp.k());
        assert_eq!(xq.len(), m * k, "fused-gate activation panel mismatch");
        out.reset(&[m, 3 * h]);
        let nstrips = gp.nstrips();
        for j in 0..h {
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let (mut az, mut ar, mut ac) = (0i32, 0, 0);
                for s in 0..nstrips {
                    let k0 = s * KC;
                    let kc = gp.strip_cols(s);
                    let block = gp.block(s, j);
                    let xs = &xi[k0..k0 + kc];
                    az += dot_i8_neon(xs, &block[..kc]);
                    ar += dot_i8_neon(xs, &block[kc..2 * kc]);
                    ac += dot_i8_neon(xs, &block[2 * kc..]);
                }
                let scale = scales.get(i);
                let orow = out.row_mut(i);
                orow[j] = az as f32 * scale;
                orow[h + j] = ar as f32 * scale;
                orow[2 * h + j] = ac as f32 * scale;
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_f32_neon(
        x: &Tensor,
        w: &Tensor,
        bias: Option<&[f32]>,
        out: &mut Tensor,
    ) {
        let (m, k) = (x.rows(), x.cols());
        let (n, k2) = (w.rows(), w.cols());
        assert_eq!(k, k2, "gemm_f32 contraction mismatch");
        out.reset(&[m, n]);
        for i in 0..m {
            let xi = x.row(i);
            let orow = out.row_mut(i);
            for j in 0..n {
                orow[j] = dot_f32_neon(xi, w.row(j));
            }
            if let Some(b) = bias {
                for j in 0..n {
                    orow[j] += b[j];
                }
            }
        }
    }

    // -- int4 unpack-and-widen dots -----------------------------------------

    /// Exact i32 dot of one full 32-column scale group: 16 nibble-packed
    /// weight bytes against 32 activation bytes.  Unpack: mask the low
    /// nibbles, logical-shift the high nibbles down, sign-extend 4-bit
    /// two's-complement via `(v ^ 8) - 8`, then `vzip1q/vzip2q`
    /// interleave lo/hi back into natural column order before the same
    /// widening `vmull_s8` + `vpadalq_s16` accumulation as
    /// [`dot_i8_neon`] — exact, so lane order cannot change bits.
    ///
    /// SAFETY: caller guarantees 32 readable i8 at `x` and 16 readable
    /// bytes at `w`.
    #[target_feature(enable = "neon")]
    unsafe fn dot_q4_block32_neon(x: *const i8, w: *const u8) -> i32 {
        let v = vld1q_u8(w);
        let lo = vreinterpretq_s8_u8(vandq_u8(v, vdupq_n_u8(0x0f)));
        let hi = vreinterpretq_s8_u8(vshrq_n_u8::<4>(v));
        let eight = vdupq_n_s8(8);
        let lo = vsubq_s8(veorq_s8(lo, eight), eight);
        let hi = vsubq_s8(veorq_s8(hi, eight), eight);
        // byte t of `lo`/`hi` holds columns 2t / 2t+1: zipping restores
        // natural order (w01 = cols 0..15, w23 = cols 16..31)
        let w01 = vzip1q_s8(lo, hi);
        let w23 = vzip2q_s8(lo, hi);
        let x01 = vld1q_s8(x);
        let x23 = vld1q_s8(x.add(16));
        let mut acc = vdupq_n_s32(0);
        acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(x01), vget_low_s8(w01)));
        acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(x01), vget_high_s8(w01)));
        acc = vpadalq_s16(acc, vmull_s8(vget_low_s8(x23), vget_low_s8(w23)));
        acc = vpadalq_s16(acc, vmull_s8(vget_high_s8(x23), vget_high_s8(w23)));
        vaddvq_s32(acc)
    }

    /// Exact i32 sub-dot of one scale group, columns `[c0, cend)`: full
    /// 32-column groups take the vector block, ragged tails fall back to
    /// the scalar nibble walk — both exact.
    #[target_feature(enable = "neon")]
    unsafe fn dot_q4_group_neon(xs: &[i8], wbytes: &[u8], c0: usize, cend: usize) -> i32 {
        if cend - c0 == 32 {
            dot_q4_block32_neon(xs.as_ptr().add(c0), wbytes.as_ptr().add(c0 / 2))
        } else {
            scalar::dot_q4_group(xs, wbytes, c0, cend)
        }
    }

    /// One int4 row dot under the fixed accumulation contract —
    /// bit-identical to `scalar::dot_q4_row`.
    #[target_feature(enable = "neon")]
    unsafe fn dot_q4_row_neon(
        xq: &[i8],
        wbytes: &[u8],
        scales: &[f32],
        k: usize,
        group: usize,
    ) -> f32 {
        let mut acc = 0.0f32;
        for (g, &s) in scales.iter().enumerate() {
            let c0 = g * group;
            let cend = (c0 + group).min(k);
            acc += dot_q4_group_neon(xq, wbytes, c0, cend) as f32 * s;
        }
        acc
    }

    /// The int4 farm schedule with NEON nibble dots over the row-major
    /// reference layout (bit-identical to `scalar::farm4_core`).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn farm4_neon(
        xq: &[i8],
        m: usize,
        w: &Q4Matrix,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(xq.len(), m * k, "simd int4 activation panel mismatch");
        out.reset(&[m, n]);
        let group = w.group();
        for j in 0..n {
            let wb = w.row_data(j);
            let ws = w.row_scales(j);
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                out.row_mut(i)[j] = dot_q4_row_neon(xi, wb, ws, k, group) * scales.get(i);
            }
        }
    }

    /// m = 1 int4 GEMV with NEON nibble dots (bit-identical to
    /// `scalar::gemv4_core`).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemv4_neon(xq: &[i8], w: &Q4Matrix, sx: f32, out: &mut Tensor) {
        let (n, k) = (w.rows(), w.cols());
        assert_eq!(xq.len(), k, "gemv4 takes exactly one activation row");
        out.reset(&[1, n]);
        let group = w.group();
        let orow = out.row_mut(0);
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot_q4_row_neon(xq, w.row_data(j), w.row_scales(j), k, group) * sx;
        }
    }

    /// Fused int4 GRU-gate sweep over gate-interleaved nibble panels
    /// (same schedule as `blocked::qgemm4_gates_core`; bit-identical).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gates4_neon(
        xq: &[i8],
        m: usize,
        gp: &PackedQ4GatePanels,
        scales: RowScales<'_>,
        out: &mut Tensor,
    ) {
        let (h, k, group) = (gp.h(), gp.k(), gp.group());
        assert_eq!(xq.len(), m * k, "fused-gate int4 activation panel mismatch");
        out.reset(&[m, 3 * h]);
        let nstrips = gp.nstrips();
        for j in 0..h {
            for i in 0..m {
                let xi = &xq[i * k..(i + 1) * k];
                let (mut az, mut ar, mut ac) = (0f32, 0f32, 0f32);
                for s in 0..nstrips {
                    let k0 = s * KC;
                    let kcs = gp.strip_cols(s);
                    let pairs = kcs.div_ceil(2);
                    let gs = kcs.div_ceil(group);
                    let block = gp.block(s, j);
                    let bscales = gp.block_scales(s, j);
                    let xs = &xi[k0..k0 + kcs];
                    let (zb, rb, cb) =
                        (&block[..pairs], &block[pairs..2 * pairs], &block[2 * pairs..]);
                    for g in 0..gs {
                        let c0 = g * group;
                        let cend = (c0 + group).min(kcs);
                        az += dot_q4_group_neon(xs, zb, c0, cend) as f32 * bscales[g];
                        ar += dot_q4_group_neon(xs, rb, c0, cend) as f32 * bscales[gs + g];
                        ac += dot_q4_group_neon(xs, cb, c0, cend) as f32 * bscales[2 * gs + g];
                    }
                }
                let scale = scales.get(i);
                let orow = out.row_mut(i);
                orow[j] = az * scale;
                orow[h + j] = ar * scale;
                orow[2 * h + j] = ac * scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{qgemm_farm_rows, qgemm_ref};
    use crate::prng::Pcg64;
    use crate::quant::QMatrix;
    use crate::tensor::TensorI8;

    fn rand_i8(r: usize, c: usize, rng: &mut Pcg64) -> TensorI8 {
        TensorI8::new(&[r, c], (0..r * c).map(|_| (rng.below(255) as i32 - 127) as i8).collect())
            .unwrap()
    }

    #[test]
    fn simd_bit_identical_to_reference_incl_unroll_tails() {
        // k values straddle the 16-lane vector width; whatever path the
        // host CPU takes (vector or scalar fallback), results are exact
        let mut rng = Pcg64::seeded(0);
        let be = SimdBackend;
        let shapes = [(1usize, 3usize, 1usize), (2, 7, 15), (3, 9, 16), (4, 33, 17), (8, 66, 320)];
        for &(m, n, k) in &shapes {
            let x = rand_i8(m, k, &mut rng);
            let wq = rand_i8(n, k, &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.021 });
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_into(x.data(), m, &w, 0.013, &mut out);
            assert_eq!(out, qgemm_ref(&x, &wq, 0.013, 0.021), "({m},{n},{k})");

            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.002 * i as f32).collect();
            let mut rows = Tensor::zeros(&[0, 0]);
            be.qgemm_farm_rows_into(x.data(), m, &w, &sx, &mut rows);
            assert_eq!(rows, qgemm_farm_rows(&x, &wq, &sx, 0.021), "rows ({m},{n},{k})");
        }
    }

    #[test]
    fn simd_gemv_and_fused_gates_bit_identical() {
        // whatever path the host takes (vector or fallback), the m = 1
        // GEMV and the fused gate sweep stay exact
        let mut rng = Pcg64::seeded(3);
        let be = SimdBackend;
        for &(n, k) in &[(1usize, 1usize), (5, 7), (33, 17), (66, 320)] {
            let x = rand_i8(1, k, &mut rng);
            let wq = rand_i8(n, k, &mut rng);
            let w = PreparedQMatrix::new(QMatrix { q: wq.clone(), scale: 0.021 });
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemv_into(x.data(), &w, 0.013, &mut out);
            assert_eq!(out, qgemm_ref(&x, &wq, 0.013, 0.021), "gemv ({n},{k})");
        }
        for &(m, h, k) in &[(1usize, 1usize, 1usize), (2, 5, 7), (3, 32, 257)] {
            let x = rand_i8(m, k, &mut rng);
            let wq = rand_i8(3 * h, k, &mut rng);
            let w = PreparedQMatrix::new_with_gates(QMatrix { q: wq.clone(), scale: 0.021 });
            assert!(w.gates.is_some(), "3h-row weight must carry gate panels");
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.002 * i as f32).collect();
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm_gates_rows_into(x.data(), m, &w, &sx, &mut out);
            assert_eq!(out, qgemm_farm_rows(&x, &wq, &sx, 0.021), "gates ({m},{h},{k})");
        }
    }

    fn rand_q4(n: usize, k: usize, rng: &mut Pcg64) -> crate::quant::Q4Matrix {
        crate::quant::quantize4(&Tensor::randn(&[n, k], 0.5, rng))
    }

    #[test]
    fn simd_int4_bit_identical_to_scalar_reference() {
        // k values straddle the 32-column group width (vector block vs
        // ragged scalar tail); whatever path the host takes, exact
        let mut rng = Pcg64::seeded(5);
        let be = SimdBackend;
        for &(m, n, k) in
            &[(1usize, 3usize, 1usize), (2, 7, 31), (3, 9, 32), (4, 33, 33), (8, 66, 320)]
        {
            let x = rand_i8(m, k, &mut rng);
            let w4 = rand_q4(n, k, &mut rng);
            let w = crate::kernels::PreparedQ4Matrix::new(w4.clone());
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm4_farm_into(x.data(), m, &w, 0.013, &mut out);
            assert_eq!(out, crate::kernels::qgemm4_ref(&x, &w4, 0.013), "({m},{n},{k})");

            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.002 * i as f32).collect();
            let mut rows = Tensor::zeros(&[0, 0]);
            be.qgemm4_farm_rows_into(x.data(), m, &w, &sx, &mut rows);
            assert_eq!(rows, crate::kernels::qgemm4_farm_rows(&x, &w4, &sx), "rows ({m},{n},{k})");
        }
        for &(n, k) in &[(1usize, 1usize), (5, 31), (33, 64), (66, 320)] {
            let x = rand_i8(1, k, &mut rng);
            let w4 = rand_q4(n, k, &mut rng);
            let w = crate::kernels::PreparedQ4Matrix::new(w4.clone());
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemv4_into(x.data(), &w, 0.013, &mut out);
            assert_eq!(out, crate::kernels::qgemm4_ref(&x, &w4, 0.013), "gemv4 ({n},{k})");
        }
        for &(m, h, k) in &[(1usize, 1usize, 1usize), (2, 5, 31), (3, 32, 257)] {
            let x = rand_i8(m, k, &mut rng);
            let w4 = rand_q4(3 * h, k, &mut rng);
            let w = crate::kernels::PreparedQ4Matrix::new_with_gates(w4.clone());
            assert!(w.gates.is_some(), "3h-row int4 weight must carry gate panels");
            let sx: Vec<f32> = (0..m).map(|i| 0.004 + 0.002 * i as f32).collect();
            let mut out = Tensor::zeros(&[0, 0]);
            be.qgemm4_gates_rows_into(x.data(), m, &w, &sx, &mut out);
            assert_eq!(
                out,
                crate::kernels::qgemm4_farm_rows(&x, &w4, &sx),
                "gates4 ({m},{h},{k})"
            );
        }
    }
}

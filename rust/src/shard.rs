//! Sharded multi-threaded serving runtime (DESIGN.md §9).
//!
//! PR 1–4 built a serving stack whose every GEMM, stream and fidelity
//! decision ran on one thread — one core of "as fast as the hardware
//! allows".  This module recovers the other cores the paper's embedded
//! targets actually have: a [`run_sharded`] serve owns **N worker
//! shards**, each a dedicated OS thread running its own per-tier
//! [`StreamPool`]s against a *shared* `Arc<Engine>` plan (the weights —
//! including the pre-packed int8 layouts — exist once in memory no
//! matter the shard count; `infer.rs`/`kernels` carry compile-time
//! `Send + Sync` proofs of that sharing), behind a single front-end
//! **admission router** that places each arriving session on the
//! least-occupied shard with free capacity, spilling to the next shard
//! (and, under `--ladder`, down the fidelity ladder inside the chosen
//! shard) under backpressure.
//!
//! Execution is round-based: each round the router hands every busy or
//! newly-fed shard one [`Admission`] batch over a bounded channel, all
//! shards run one lock-stepped tick **concurrently** (chunk delivery →
//! pool pump → session close), and each replies with a [`TickReport`].
//! The simulated clock advances by the *maximum* shard tick time — the
//! wall-clock of the parallel round — so throughput genuinely scales
//! with shards while latency accounting stays honest.  The control
//! plane (arrival schedule, placement, latency histograms, fidelity
//! controllers) lives entirely on the router thread, which is what
//! makes `--shards 1` replay the pre-shard serving loop decision for
//! decision: same admission order, same controller call sequence, same
//! metrics — bit-identical deterministic output.
//!
//! Determinism contract: per-stream transcripts never depend on
//! placement (pooled decoding is bit-identical to sequential decoding,
//! `rust/tests/stream_pool.rs`), so **any** shard count yields identical
//! transcripts and CER for a fixed seed — only placement and timing
//! differ (`rust/tests/shard.rs`).  The same router-only control plane
//! is what makes the flight-recorder event journal deterministic: with
//! `--obs on`, every admission/placement/spill/shift/backpressure/drain
//! event is produced on the router thread ([`crate::obs::journal`]),
//! never inside a worker, so the per-session lifecycle record is a
//! fixed multiset at any shard count.
//!
//! Drain protocol: when arrivals end, the router keeps ticking busy
//! shards until every session completes (graceful drain of the ramp),
//! then hangs up the command channels; workers exit on the disconnect,
//! and a worker stopped with sessions still live (router abort mid-
//! serve) flushes them through [`StreamPool::drain`] rather than
//! dropping hidden state mid-utterance.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use crate::data::Utterance;
use crate::error::{Error, Result};
use crate::infer::{Breakdown, Engine};
use crate::obs::{self, trace::BlockSpan};
use crate::prng::Pcg64;
use crate::stream::{BlockTrace, PoolStats, StreamId, StreamPool};

// ---------------------------------------------------------------------------
// Router <-> worker protocol.
// ---------------------------------------------------------------------------

/// One admission the router hands a shard: which utterance to open a
/// session for, and which fidelity tier's pool should hold it (always
/// tier 0 on the plain stream path).
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    pub utt: usize,
    pub tier: usize,
}

/// A session that completed during a shard tick.
#[derive(Clone, Debug)]
pub struct FinishedSession {
    pub utt: usize,
    pub tier: usize,
    pub transcript: String,
}

/// What a shard reports back after one lock-stepped round.
#[derive(Clone, Debug)]
pub struct TickReport {
    pub shard: usize,
    /// per-tier live sessions after this round's admissions, before the
    /// work phase — the occupancy snapshot the serving report records
    pub occ_before: Vec<usize>,
    /// per-tier live sessions after finished sessions closed — the
    /// router's authoritative placement state for the next round
    pub occ_after: Vec<usize>,
    pub finished: Vec<FinishedSession>,
    /// measured wall-clock of the work phase (chunk delivery + pump +
    /// close; admissions excluded, exactly like the unsharded loop)
    pub secs: f64,
    /// cumulative engine component timing for this shard (not a delta)
    pub breakdown: Breakdown,
    /// cumulative pool counters summed over this shard's tier pools
    pub stats: PoolStats,
    /// per-`pump_block` trace records from this tick, utterance-mapped
    /// but not yet clock-stamped (the router does that).  Always empty
    /// with obs off — `Vec::new()` never allocates.
    pub blocks: Vec<BlockSpan>,
    /// cascade escalations this tick as `(utt, tier)` pairs, in pump
    /// order — the router stamps the clock and journals them
    /// (`cascade_escalate`), keeping the journal single-threaded.
    /// Always empty with obs off.
    pub escalations: Vec<(usize, usize)>,
}

enum ToShard {
    /// One round's admissions, plus an optional cascade escalation
    /// threshold override the controller decided this tick (None = keep
    /// the pools' current threshold).
    Tick(Vec<Admission>, Option<f64>),
}

enum FromShard {
    Done(TickReport),
    Fatal(Error),
}

// ---------------------------------------------------------------------------
// The worker shard.
// ---------------------------------------------------------------------------

struct InFlight {
    id: StreamId,
    utt: usize,
    off: usize,
    tier: usize,
}

/// One worker shard: per-tier stream pools plus the in-flight session
/// table, owned by a dedicated OS thread for the lifetime of the serve.
struct ShardWorker<'a> {
    shard: usize,
    pools: Vec<StreamPool>,
    active: Vec<InFlight>,
    utts: &'a [Utterance],
    chunk_frames: usize,
    feat: usize,
    bd: Breakdown,
}

impl ShardWorker<'_> {
    fn run(mut self, rx: Receiver<ToShard>, tx: SyncSender<FromShard>) {
        while let Ok(ToShard::Tick(admissions, threshold)) = rx.recv() {
            match self.tick(admissions, threshold) {
                Ok(report) => {
                    if tx.send(FromShard::Done(report)).is_err() {
                        break; // router gone
                    }
                }
                Err(e) => {
                    let _ = tx.send(FromShard::Fatal(e));
                    break;
                }
            }
        }
        // router hung up: graceful drain of anything still live (only
        // non-empty on an abort — a normal serve drains via rounds)
        let mut bd = Breakdown::default();
        for pool in self.pools.iter_mut() {
            let _ = pool.drain(&mut bd);
        }
    }

    /// One lock-stepped round: admit, deliver one client chunk per live
    /// session, pump every busy pool, close finished sessions.  Mirrors
    /// one iteration of the pre-shard serving loop exactly.
    fn tick(&mut self, admissions: Vec<Admission>, threshold: Option<f64>) -> Result<TickReport> {
        if let Some(t) = threshold {
            for pool in self.pools.iter_mut() {
                if pool.cascade().is_some() {
                    pool.set_escalation_threshold(t)?;
                }
            }
        }
        for adm in &admissions {
            let id = self.pools[adm.tier].open()?;
            self.active.push(InFlight { id, utt: adm.utt, off: 0, tier: adm.tier });
        }
        let occ_before: Vec<usize> = self.pools.iter().map(|p| p.active()).collect();

        let t0 = std::time::Instant::now();
        for a in &mut self.active {
            let data = self.utts[a.utt].feats.data();
            let end = (a.off + self.chunk_frames * self.feat).min(data.len());
            if a.off < end {
                self.pools[a.tier].push_frames(a.id, &data[a.off..end])?;
                a.off = end;
            }
        }
        // With obs on, pump through the traced path and map each block's
        // session ids to utterance numbers (the in-flight table still
        // holds every advancing session — closes happen below).  The
        // records ship back unstamped; the router owns the clock.
        let obs_on = obs::enabled();
        let mut blocks: Vec<BlockSpan> = Vec::new();
        let mut traces: Vec<BlockTrace> = Vec::new();
        for tier in 0..self.pools.len() {
            if self.pools[tier].active() == 0 {
                continue;
            }
            if obs_on {
                self.pools[tier].pump_traced(&mut self.bd, &mut traces)?;
                for tr in traces.drain(..) {
                    let utts = tr
                        .ids
                        .iter()
                        .map(|id| {
                            self.active
                                .iter()
                                .find(|a| a.id == *id)
                                .expect("pumped session missing from in-flight table")
                                .utt
                        })
                        .collect();
                    blocks.push(BlockSpan {
                        clock: 0.0,
                        secs: tr.secs,
                        shard: self.shard,
                        tier,
                        utts,
                        steps: tr.steps,
                        spans: tr.spans,
                    });
                }
            } else {
                self.pools[tier].pump(&mut self.bd)?;
            }
        }
        // id -> utt snapshot before closes remove sessions from the
        // in-flight table: close-side cascade escalations still need the
        // mapping (slot ids are not reused until next tick's admissions)
        let idmap: Vec<(StreamId, usize)> = if obs_on {
            self.active.iter().map(|a| (a.id, a.utt)).collect()
        } else {
            Vec::new()
        };
        let mut finished = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].off >= self.utts[self.active[i].utt].feats.data().len() {
                let a = self.active.swap_remove(i);
                let closed = self.pools[a.tier].close(a.id, &mut self.bd)?;
                finished.push(FinishedSession {
                    utt: a.utt,
                    tier: a.tier,
                    transcript: closed.transcript,
                });
            } else {
                i += 1;
            }
        }
        let secs = t0.elapsed().as_secs_f64();

        let mut escalations: Vec<(usize, usize)> = Vec::new();
        for (tier, pool) in self.pools.iter_mut().enumerate() {
            if obs_on {
                for id in pool.escalations() {
                    let utt = idmap
                        .iter()
                        .find(|(i, _)| i == id)
                        .expect("escalated session missing from in-flight snapshot")
                        .1;
                    escalations.push((utt, tier));
                }
            }
            // the per-tick escalation list must not grow across rounds,
            // obs on or off
            pool.clear_escalations();
        }

        let occ_after: Vec<usize> = self.pools.iter().map(|p| p.active()).collect();
        let mut stats = PoolStats::default();
        for p in &self.pools {
            stats.absorb(&p.stats);
        }
        Ok(TickReport {
            shard: self.shard,
            occ_before,
            occ_after,
            finished,
            secs,
            breakdown: self.bd,
            stats,
            blocks,
            escalations,
        })
    }
}

// ---------------------------------------------------------------------------
// The router-facing handle.
// ---------------------------------------------------------------------------

/// The router's view of the worker fleet: bounded command/report
/// channels plus the per-shard, per-tier occupancy cache that placement
/// reads.  The cache is authoritative between rounds (reset from each
/// [`TickReport::occ_after`]) and is advanced in place by [`ShardedServer::stage`]
/// as the router assigns arrivals within a round.
pub struct ShardedServer {
    txs: Vec<SyncSender<ToShard>>,
    rxs: Vec<Receiver<FromShard>>,
    occ: Vec<Vec<usize>>,
    tiers: usize,
    capacity: usize,
}

impl ShardedServer {
    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    pub fn tiers(&self) -> usize {
        self.tiers
    }

    /// Session slots per tier per shard.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Cached live sessions of `shard` across its tiers.
    pub fn total_active(&self, shard: usize) -> usize {
        self.occ[shard].iter().sum()
    }

    /// Any live session anywhere in the fleet?
    pub fn any_active(&self) -> bool {
        (0..self.shards()).any(|s| self.total_active(s) > 0)
    }

    /// Cached per-tier occupancy of one shard.
    pub fn occupancy(&self, shard: usize, tier: usize) -> usize {
        self.occ[shard][tier]
    }

    /// Least-occupancy placement with spill: among shards that still
    /// have a free slot at some tier in `want(shard)..tiers` (the
    /// within-shard spill walks *down* the ladder, never up), pick the
    /// shard with the lowest total occupancy fraction; ties go to the
    /// lowest shard id.  `None` = every shard is full at every eligible
    /// tier — the router queues the arrival (backpressure).
    pub fn place(&self, want: impl Fn(usize) -> usize) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, f64)> = None;
        for shard in 0..self.shards() {
            let w = want(shard);
            let Some(tier) = (w..self.tiers).find(|&t| self.occ[shard][t] < self.capacity) else {
                continue;
            };
            let frac = self.total_active(shard) as f64 / (self.tiers * self.capacity) as f64;
            if best.map_or(true, |(_, _, bf)| frac < bf) {
                best = Some((shard, tier, frac));
            }
        }
        best.map(|(s, t, _)| (s, t))
    }

    /// Record a staged admission in the occupancy cache, so later
    /// placements within the same round see the slot as taken.
    pub fn stage(&mut self, shard: usize, tier: usize) {
        debug_assert!(self.occ[shard][tier] < self.capacity);
        self.occ[shard][tier] += 1;
    }

    /// Run one parallel round: every shard that is busy or has staged
    /// admissions gets a tick; all ticked shards work concurrently; the
    /// reports come back indexed by shard (`None` = shard sat the round
    /// out, i.e. it was idle with nothing admitted).
    pub fn round(
        &mut self,
        admissions: Vec<Vec<Admission>>,
    ) -> Result<Vec<Option<TickReport>>> {
        let none = vec![None; admissions.len()];
        self.round_with_thresholds(admissions, &none)
    }

    /// [`ShardedServer::round`] with a per-shard cascade escalation
    /// threshold override: `thresholds[shard] = Some(t)` tells that
    /// shard's cascade pools to gate at `t` from this tick on (the
    /// controller's threshold governor under SLO pressure).  `None`
    /// entries leave the shard's threshold alone, so plain `round` is
    /// unchanged behavior.
    pub fn round_with_thresholds(
        &mut self,
        mut admissions: Vec<Vec<Admission>>,
        thresholds: &[Option<f64>],
    ) -> Result<Vec<Option<TickReport>>> {
        assert_eq!(admissions.len(), self.shards());
        assert_eq!(thresholds.len(), self.shards());
        let mut ticked = vec![false; self.shards()];
        for shard in 0..self.shards() {
            let adm = std::mem::take(&mut admissions[shard]);
            if adm.is_empty() && self.total_active(shard) == 0 {
                continue;
            }
            self.txs[shard]
                .send(ToShard::Tick(adm, thresholds[shard]))
                .map_err(|_| Error::other(format!("shard {shard} worker hung up")))?;
            ticked[shard] = true;
        }
        let mut reports: Vec<Option<TickReport>> = (0..self.shards()).map(|_| None).collect();
        for shard in 0..self.shards() {
            if !ticked[shard] {
                continue;
            }
            match self.rxs[shard].recv() {
                Ok(FromShard::Done(r)) => {
                    self.occ[shard].copy_from_slice(&r.occ_after);
                    reports[shard] = Some(r);
                }
                Ok(FromShard::Fatal(e)) => return Err(e),
                Err(_) => return Err(Error::other(format!("shard {shard} worker died"))),
            }
        }
        Ok(reports)
    }
}

/// Spawn `shards` worker threads — each with one [`StreamPool`] of
/// `pool_size` slots per engine in `engines` (one engine per fidelity
/// tier; a plain stream serve passes exactly one) — and run `router`
/// against the fleet.  Workers exit when the router returns (the
/// command channels disconnect) and are joined before this returns, so
/// no thread outlives the serve.
pub fn run_sharded<R>(
    engines: &[Arc<Engine>],
    shards: usize,
    pool_size: usize,
    chunk_frames: usize,
    utts: &[Utterance],
    router: impl FnOnce(&mut ShardedServer) -> Result<R>,
) -> Result<R> {
    run_sharded_with(
        engines,
        shards,
        pool_size,
        chunk_frames,
        utts,
        |_, e| Ok(StreamPool::new(e, pool_size)),
        router,
    )
}

/// [`run_sharded`] with a pool factory: `make_pool(tier, engine)` builds
/// each worker's per-tier pool, so a cascade serve can attach a
/// [`crate::stream::CascadeCfg`] to the rung pools it gates while every
/// existing caller keeps plain pools.  The factory runs on the router
/// thread; a factory error aborts the serve (already-spawned workers
/// exit on the dropped command channels and are joined by the scope).
pub fn run_sharded_with<R>(
    engines: &[Arc<Engine>],
    shards: usize,
    pool_size: usize,
    chunk_frames: usize,
    utts: &[Utterance],
    make_pool: impl Fn(usize, Arc<Engine>) -> Result<StreamPool>,
    router: impl FnOnce(&mut ShardedServer) -> Result<R>,
) -> Result<R> {
    if shards == 0 {
        return Err(Error::Config("shards must be >= 1".into()));
    }
    if engines.is_empty() {
        return Err(Error::Config("run_sharded needs at least one engine tier".into()));
    }
    let tiers = engines.len();
    std::thread::scope(|scope| {
        let mut txs = Vec::with_capacity(shards);
        let mut rxs = Vec::with_capacity(shards);
        for shard in 0..shards {
            let (tx_cmd, rx_cmd) = sync_channel::<ToShard>(1);
            let (tx_rep, rx_rep) = sync_channel::<FromShard>(1);
            let pools = engines
                .iter()
                .enumerate()
                .map(|(t, e)| make_pool(t, e.clone()))
                .collect::<Result<Vec<_>>>()?;
            let worker = ShardWorker {
                shard,
                pools,
                active: Vec::new(),
                utts,
                chunk_frames,
                feat: engines[0].feat_dim(),
                bd: Breakdown::default(),
            };
            scope.spawn(move || worker.run(rx_cmd, tx_rep));
            txs.push(tx_cmd);
            rxs.push(rx_rep);
        }
        let mut links = ShardedServer {
            txs,
            rxs,
            occ: vec![vec![0; tiers]; shards],
            tiers,
            capacity: pool_size,
        };
        let out = router(&mut links);
        drop(links); // hang up -> workers drain and exit; scope joins them
        out
    })
}

// ---------------------------------------------------------------------------
// Sharded arrival schedule.
// ---------------------------------------------------------------------------

/// The offered load of a sharded serve: the superposition of `shards`
/// independent Poisson processes, each at `rate / shards` from its own
/// child generator ([`Pcg64::shard_seeded`]).  The union of independent
/// Poisson processes is again Poisson at the summed rate, so the
/// offered load is statistically identical at every shard count while
/// the per-shard sub-processes stay reproducible and mutually
/// uncorrelated.  With one shard the schedule is **bit-identical** to
/// the historical root-seeded process (shard 0's child *is* the root
/// stream), which anchors the `--shards 1` compatibility guarantee.
///
/// Returns `n` arrival times, ascending; session `i` streams `utts[i]`.
pub fn sharded_arrivals(n: usize, shards: usize, rate: f64, seed: u64) -> Vec<f64> {
    assert!(shards >= 1 && rate > 0.0);
    let sub_rate = rate / shards as f64;
    let mut gens: Vec<Pcg64> = (0..shards).map(|s| Pcg64::shard_seeded(seed, s as u64)).collect();
    let mut next: Vec<f64> =
        gens.iter_mut().map(|g| -g.uniform().max(1e-12).ln() / sub_rate).collect();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let s = next
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.total_cmp(b))
            .map(|(i, _)| i)
            .unwrap();
        out.push(next[s]);
        next[s] += -gens[s].uniform().max(1e-12).ln() / sub_rate;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Precision;
    use crate::stream::{demo_dims, synthetic_params};

    #[test]
    fn single_shard_arrivals_match_the_historical_process() {
        // the exact loop stream_serve ran before sharding existed
        let (n, rate, seed) = (64usize, 8.0, 17u64);
        let mut rng = Pcg64::seeded(seed);
        let mut want = Vec::new();
        let mut t = 0.0;
        for _ in 0..n {
            t += -rng.uniform().max(1e-12).ln() / rate;
            want.push(t);
        }
        assert_eq!(sharded_arrivals(n, 1, rate, seed), want);
    }

    #[test]
    fn sharded_arrivals_are_sorted_and_reproducible() {
        let a = sharded_arrivals(100, 4, 16.0, 3);
        let b = sharded_arrivals(100, 4, 16.0, 3);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // different shard counts give different (but valid) schedules
        let c = sharded_arrivals(100, 2, 16.0, 3);
        assert_ne!(a, c);
        // mean inter-arrival stays ~1/rate regardless of shard count
        let mean = a.last().unwrap() / 100.0;
        assert!((mean - 1.0 / 16.0).abs() < 0.02, "mean gap {mean}");
    }

    #[test]
    fn placement_prefers_least_occupied_and_spills() {
        // shard 0: tier 0 full, tier 1 empty (2 spill slots, total 2)
        // shard 1: tier 0 has 1, tier 1 empty    (3 free,       total 1)
        // shard 2: completely full               (0 free,       total 4)
        let mut links = ShardedServer {
            txs: Vec::new(),
            rxs: Vec::new(),
            occ: vec![vec![2, 0], vec![1, 0], vec![2, 2]],
            tiers: 2,
            capacity: 2,
        };
        // shards() counts command channels; placement never sends on
        // them, so dangling dummy ends are fine here
        let mut keep = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = sync_channel::<ToShard>(1);
            let (tx2, rx2) = sync_channel::<FromShard>(1);
            links.txs.push(tx);
            links.rxs.push(rx2);
            keep.push((rx, tx2));
        }
        // wanting tier 0 everywhere: shard 1 is least occupied and has
        // tier-0 room -> wins at its routed tier
        assert_eq!(links.place(|_| 0), Some((1, 0)));
        links.stage(1, 0);
        // now shards 0 and 1 tie on total occupancy; the tie breaks to
        // shard 0, which is full at tier 0 and spills DOWN to tier 1
        assert_eq!(links.place(|_| 0), Some((0, 1)));
        links.stage(0, 1);
        // keep placing: exactly the 3 remaining free slots, then total
        // backpressure (shard 2 never gets a session — it is full)
        for _ in 0..3 {
            let (shard, tier) = links.place(|_| 0).expect("free slots remain");
            assert_ne!(shard, 2, "a full shard must never be picked");
            links.stage(shard, tier);
        }
        assert_eq!(links.place(|_| 0), None, "fleet full -> router queues");
    }

    #[test]
    fn round_trip_through_a_real_worker_fleet() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.5, 7);
        let engine = Arc::new(
            Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap(),
        );
        let data = crate::data::Dataset::generate(crate::data::CorpusSpec::standard(5), 0, 0, 4);
        let utts = &data.test;
        let done = run_sharded(&[engine], 2, 2, 16, utts, |links| {
            assert_eq!(links.shards(), 2);
            assert_eq!(links.tiers(), 1);
            // admit two sessions to each shard, then drive to completion
            let mut admissions = vec![Vec::new(), Vec::new()];
            for utt in 0..4 {
                let (shard, tier) = links.place(|_| 0).unwrap();
                links.stage(shard, tier);
                admissions[shard].push(Admission { utt, tier });
            }
            assert_eq!(admissions[0].len(), 2, "least-occupancy must balance 2/2");
            let mut finished = 0;
            let mut rounds = 0;
            let mut adm = admissions;
            while links.any_active() || rounds == 0 {
                let reports = links.round(std::mem::take(&mut adm))?;
                adm = vec![Vec::new(), Vec::new()];
                for r in reports.into_iter().flatten() {
                    assert!(r.secs >= 0.0);
                    finished += r.finished.len();
                }
                rounds += 1;
                assert!(rounds < 10_000, "fleet failed to drain");
            }
            Ok(finished)
        })
        .unwrap();
        assert_eq!(done, 4, "every session must complete and report");
    }
}

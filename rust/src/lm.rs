//! Character n-gram language model with interpolated backoff.
//!
//! The paper's Table 2 pairs each device tier with a different LM size
//! (13.7 GB server / 56 MB / 32 MB / 14 MB).  The size knob here is
//! (order, count-pruning threshold): higher order + no pruning = the
//! "server" LM, low order + aggressive pruning = the embedded ones.  The
//! decoder fuses LM scores during beam search ([`crate::decoder`]).

use std::collections::BTreeMap;

use crate::data::{char_to_index, index_to_char};

/// Interpolated add-smoothing char n-gram model over label indices
/// (1 = space, 2 = ', 3.. = letters; blank never appears in text).
#[derive(Clone, Debug)]
pub struct CharLm {
    pub order: usize,
    /// context (len < order) -> next-char counts
    counts: BTreeMap<Vec<i32>, BTreeMap<i32, u32>>,
    /// interpolation weight toward lower orders
    lambda: f64,
    vocab: usize,
}

impl CharLm {
    /// Train from transcripts. `prune_min` drops n-gram contexts whose
    /// total count is below the threshold (the size knob).
    pub fn train(texts: &[&str], order: usize, prune_min: u32) -> CharLm {
        assert!(order >= 1);
        let mut counts: BTreeMap<Vec<i32>, BTreeMap<i32, u32>> = BTreeMap::new();
        for text in texts {
            let labels: Vec<i32> = text.chars().filter_map(char_to_index).collect();
            for i in 0..labels.len() {
                // all context lengths 0..order-1
                for ctx_len in 0..order.min(i + 1) {
                    let ctx: Vec<i32> = labels[i - ctx_len..i].to_vec();
                    *counts.entry(ctx).or_default().entry(labels[i]).or_insert(0) += 1;
                }
            }
        }
        if prune_min > 1 {
            counts.retain(|ctx, m| {
                // never prune the unigram table
                ctx.is_empty() || m.values().sum::<u32>() >= prune_min
            });
        }
        CharLm { order, counts, lambda: 0.4, vocab: 28 }
    }

    /// log P(next | history) with interpolated backoff across orders.
    pub fn logp(&self, history: &[i32], next: i32) -> f64 {
        let mut p = 1.0 / self.vocab as f64; // uniform floor
        // interpolate from unigram up to the longest available context
        for ctx_len in 0..self.order {
            if ctx_len > history.len() {
                break;
            }
            let ctx = &history[history.len() - ctx_len..];
            if let Some(m) = self.counts.get(ctx) {
                let total: u32 = m.values().sum();
                if total > 0 {
                    let c = m.get(&next).copied().unwrap_or(0);
                    let p_here = (c as f64 + 0.1) / (total as f64 + 0.1 * self.vocab as f64);
                    p = (1.0 - self.lambda) * p + self.lambda * p_here;
                }
            }
        }
        p.max(1e-12).ln()
    }

    /// Sequence log probability.
    pub fn score(&self, labels: &[i32]) -> f64 {
        let mut lp = 0.0;
        for i in 0..labels.len() {
            lp += self.logp(&labels[..i], labels[i]);
        }
        lp
    }

    /// Number of stored n-gram entries.
    pub fn entries(&self) -> usize {
        self.counts.values().map(|m| m.len()).sum()
    }

    /// Approximate serialized size (the Table-2 "language model size"):
    /// each entry ≈ context bytes + 1 char + 4-byte count.
    pub fn size_bytes(&self) -> usize {
        self.counts
            .iter()
            .map(|(ctx, m)| m.len() * (ctx.len() + 5))
            .sum()
    }

    /// Perplexity over held-out texts.
    pub fn perplexity(&self, texts: &[&str]) -> f64 {
        let (mut lp, mut n) = (0.0, 0usize);
        for t in texts {
            let labels: Vec<i32> = t.chars().filter_map(char_to_index).collect();
            lp += self.score(&labels);
            n += labels.len();
        }
        (-lp / n.max(1) as f64).exp()
    }
}

/// Pretty-print a label sequence (debugging aid).
pub fn labels_string(labels: &[i32]) -> String {
    labels.iter().filter_map(|&l| index_to_char(l)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TRAIN: &[&str] = &["the cat", "the dog", "the cat ran", "a cat sat"];

    #[test]
    fn predicts_seen_continuations() {
        let lm = CharLm::train(TRAIN, 3, 0);
        // after "th", 'e' is far more likely than 'q'
        let hist: Vec<i32> = "th".chars().map(|c| char_to_index(c).unwrap()).collect();
        let e = lm.logp(&hist, char_to_index('e').unwrap());
        let q = lm.logp(&hist, char_to_index('q').unwrap());
        assert!(e > q + 1.0, "e={e} q={q}");
    }

    #[test]
    fn score_prefers_training_like_text() {
        let lm = CharLm::train(TRAIN, 3, 0);
        let good = lm.score(&"the cat".chars().filter_map(char_to_index).collect::<Vec<_>>());
        let bad = lm.score(&"zxq vvk".chars().filter_map(char_to_index).collect::<Vec<_>>());
        assert!(good > bad);
    }

    #[test]
    fn pruning_shrinks_model() {
        let texts: Vec<&str> = TRAIN.iter().copied().cycle().take(40).collect();
        let full = CharLm::train(&texts, 4, 0);
        let pruned = CharLm::train(&texts, 2, 50);
        assert!(pruned.size_bytes() < full.size_bytes());
        assert!(pruned.entries() > 0);
    }

    #[test]
    fn perplexity_lower_on_in_domain() {
        let lm = CharLm::train(TRAIN, 3, 0);
        let in_d = lm.perplexity(&["the cat"]);
        let out_d = lm.perplexity(&["qzx jvw"]);
        assert!(in_d < out_d);
        assert!(in_d > 1.0);
    }

    #[test]
    fn logp_is_normalized_enough() {
        // sum over vocab of exp(logp) should be ~1 (smoothed distribution)
        let lm = CharLm::train(TRAIN, 3, 0);
        let hist: Vec<i32> = "ca".chars().map(|c| char_to_index(c).unwrap()).collect();
        let mut total = 0.0;
        for next in 1..=28 {
            total += lm.logp(&hist, next).exp();
        }
        assert!((total - 1.0).abs() < 0.15, "total {total}");
    }
}

//! Embedded inference engine — pure Rust, no XLA on the "device".
//!
//! This is the paper's §4 deployment path: the acoustic model runs on
//! custom GEMM kernels ([`crate::kernels`]), int8-quantized after
//! training, streaming with low latency.  Structure mirrors the paper's
//! runtime exactly:
//!
//! * the **recurrent** GEMM runs at the stream batch (1 for a single
//!   session; m for a lock-stepped [`crate::stream::StreamPool`]),
//!   strictly sequential in time — routed through the fused GRU-gate
//!   kernel over gate-interleaved panels by default
//!   ([`Engine::set_fused_gates`]), and through the dedicated m = 1
//!   GEMV path when the batch is a single stream (both bit-identical
//!   to the plain farm sweep);
//! * the **non-recurrent** GEMM batches across time, up to
//!   [`Engine::time_batch`] output steps (the paper found > ~4 hurts
//!   latency — §4);
//! * activations are quantized dynamically per GEMM — per *row* on the
//!   recurrent path, so pooled and single-stream decoding are
//!   bit-identical; weights once at load; biases and gate math stay f32.
//!
//! The execution model is a **plan/executor split** (DESIGN.md §4):
//!
//! * The **plan** is the [`Engine`] — immutable shared weights prepared
//!   for every GEMM backend at construction ([`PreparedQMatrix`]: the
//!   row-major reference layout plus the NR-panel pre-packed layout,
//!   built once, never per call) plus the selected
//!   [`GemmBackend`](crate::kernels::GemmBackend).
//! * The **executor state** is per stream: [`StreamState`] carries the
//!   GRU hidden vectors, the raw-frame buffer, and a [`Scratch`] arena of
//!   reusable activation/quantization buffers.  Every GEMM runs through
//!   the backend's `*_into` entry points into scratch-owned tensors, the
//!   hidden state is updated in place, and log-softmax runs in place —
//!   so the steady-state block loop ([`Engine::pump_block`]) performs
//!   **zero heap allocations** (enforced by a counting global allocator
//!   in `rust/tests/alloc_free.rs`).
//!
//! The block computation is decomposed into staged primitives
//! (`frontend` → per-layer `nonrec_block` + stepwise `rec_gates`/
//! `gru_cell` → `head`) that the stream pool re-drives at batch m.
//!
//! Per-component timing feeds Table 2's "% time spent in acoustic model"
//! and the latency experiments.

use std::collections::{BTreeMap, BTreeSet};

use crate::checkpoint::Entry;
use crate::data::labels_to_text;
use crate::decoder;
use crate::error::{Error, Result};
use crate::kernels::{self, BackendSel, GemmBackend, PreparedQ4Matrix, PreparedQMatrix};
use crate::model::ParamSet;
use crate::obs::{self, OpKind, SpanSet, Stage};
use crate::quant::{quantize, quantize4, quantize_into};
use crate::runtime::ModelDims;
use crate::tensor::Tensor;

/// Inference numeric mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    F32,
    Int8,
    /// Sub-byte weights: int4 nibbles with per-group scales (`--bits 4`).
    Int4,
}

impl Precision {
    /// Lower-case label used in reports and logs (`stream-serve --json`).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }
}

/// A dense operator `y = x Wᵀ`, f32, int8- or int4-quantized.  Quantized
/// weights are prepared for every backend layout at construction (plan
/// time).
#[derive(Clone, Debug)]
enum QDense {
    F32(Tensor),
    I8(PreparedQMatrix),
    I4(PreparedQ4Matrix),
}

/// Run one backend kernel call under the obs kernel counters: op kind,
/// m-bucket, MACs/bytes from [`kernels::farm_counts`], and the kernel's
/// wall nanos.  With obs off this is the single relaxed load and the
/// call itself — nothing else (DESIGN.md §10 overhead budget).
#[inline]
fn kernel_obs<R>(
    be: &dyn GemmBackend,
    kind: OpKind,
    m: usize,
    n: usize,
    k: usize,
    f: impl FnOnce() -> R,
) -> R {
    if !obs::enabled() {
        return f();
    }
    let t0 = std::time::Instant::now();
    let r = f();
    let c = kernels::farm_counts(m, n, k);
    obs::counters::record(
        be.name(),
        kind,
        m,
        c.macs,
        c.bytes_read + c.bytes_written,
        t0.elapsed().as_nanos() as u64,
    );
    r
}

/// [`kernel_obs`] for the int4 ops: bytes come from
/// [`kernels::farm4_counts`] (nibble stream + per-group scales), so the
/// GOP/s-per-byte reporting stays honest at `--bits 4`.
#[inline]
fn kernel_obs4<R>(
    be: &dyn GemmBackend,
    kind: OpKind,
    m: usize,
    n: usize,
    k: usize,
    f: impl FnOnce() -> R,
) -> R {
    if !obs::enabled() {
        return f();
    }
    let t0 = std::time::Instant::now();
    let r = f();
    let c = kernels::farm4_counts(m, n, k);
    obs::counters::record(
        be.name(),
        kind,
        m,
        c.macs,
        c.bytes_read + c.bytes_written,
        t0.elapsed().as_nanos() as u64,
    );
    r
}

/// Time activation quantization into the thread-local pending cell the
/// enclosing stage drains ([`obs::spans::take_pending_quantize`]), so
/// quantize self-time is attributed exactly once.
#[inline]
fn quant_obs<R>(f: impl FnOnce() -> R) -> R {
    if !obs::enabled() {
        return f();
    }
    let t0 = std::time::Instant::now();
    let r = f();
    obs::spans::add_pending_quantize(t0.elapsed().as_secs_f64());
    r
}

impl QDense {
    fn from(w: &Tensor, p: Precision) -> QDense {
        match p {
            Precision::F32 => QDense::F32(w.clone()),
            Precision::Int8 => QDense::I8(PreparedQMatrix::new(quantize(w))),
            Precision::Int4 => QDense::I4(PreparedQ4Matrix::new(quantize4(w))),
        }
    }

    /// Like [`QDense::from`], additionally building the gate-interleaved
    /// [`PackedGatePanels`](crate::kernels::PackedGatePanels) layout when
    /// the weight is a stacked `[z | r | h̃]` gate matrix (rows divisible
    /// by 3) — used for recurrent GRU weights so the fused gate kernel
    /// has its layout ready at plan time.
    fn from_gated(w: &Tensor, p: Precision) -> QDense {
        match p {
            Precision::F32 => QDense::F32(w.clone()),
            Precision::Int8 => QDense::I8(PreparedQMatrix::new_with_gates(quantize(w))),
            Precision::Int4 => QDense::I4(PreparedQ4Matrix::new_with_gates(quantize4(w))),
        }
    }

    /// From a typed ladder-artifact entry: int8 entries install their
    /// stored `QMatrix` verbatim (scale included), f32 entries stay f32.
    fn from_entry(e: &Entry) -> QDense {
        match e {
            Entry::F32(t) => QDense::F32(t.clone()),
            Entry::I8(q) => QDense::I8(PreparedQMatrix::new(q.clone())),
            Entry::I4(q) => QDense::I4(PreparedQ4Matrix::new(q.clone())),
        }
    }

    /// [`QDense::from_entry`] with gate panels (see [`QDense::from_gated`]).
    fn from_entry_gated(e: &Entry) -> QDense {
        match e {
            Entry::F32(t) => QDense::F32(t.clone()),
            Entry::I8(q) => QDense::I8(PreparedQMatrix::new_with_gates(q.clone())),
            Entry::I4(q) => QDense::I4(PreparedQ4Matrix::new_with_gates(q.clone())),
        }
    }

    fn out_dim(&self) -> usize {
        match self {
            QDense::F32(w) => w.rows(),
            QDense::I8(q) => q.n(),
            QDense::I4(q) => q.n(),
        }
    }

    fn in_dim(&self) -> usize {
        match self {
            QDense::F32(w) => w.cols(),
            QDense::I8(q) => q.k(),
            QDense::I4(q) => q.k(),
        }
    }

    /// Apply to (m, k) activations, writing into `out` (per-call
    /// activation scale — the time-batched non-recurrent path).
    fn apply_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        out: &mut Tensor,
    ) {
        match self {
            QDense::F32(w) => {
                let (m, k) = (x.rows(), x.cols());
                kernel_obs(be, OpKind::F32, m, w.rows(), k, || be.gemm_f32_into(x, w, None, out))
            }
            QDense::I8(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                // per-row dynamic quantization would be more accurate; the
                // paper (and farm) use per-call scales — do the same.
                let sx = quant_obs(|| quantize_into(x.data(), &mut qs.xq[..m * k]));
                if m == 1 {
                    // steady-state decode shape: the GEMV path (per-call
                    // and per-row scales coincide at m = 1, so this is
                    // bit-identical to the batch call)
                    kernel_obs(be, OpKind::Gemv, 1, qw.n(), k, || {
                        be.qgemv_into(&qs.xq[..k], qw, sx, out)
                    });
                } else {
                    kernel_obs(be, OpKind::Gemm, m, qw.n(), k, || {
                        be.qgemm_farm_into(&qs.xq[..m * k], m, qw, sx, out)
                    });
                }
            }
            QDense::I4(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                let sx = quant_obs(|| quantize_into(x.data(), &mut qs.xq[..m * k]));
                if m == 1 {
                    kernel_obs4(be, OpKind::Gemv4, 1, qw.n(), k, || {
                        be.qgemv4_into(&qs.xq[..k], qw, sx, out)
                    });
                } else {
                    kernel_obs4(be, OpKind::Gemm4, m, qw.n(), k, || {
                        be.qgemm4_farm_into(&qs.xq[..m * k], m, qw, sx, out)
                    });
                }
            }
        }
    }

    /// Apply to (m, k) activations where each row belongs to an
    /// *independent stream*: dynamic quantization runs per row, so the
    /// result is bit-identical to m separate batch-1
    /// [`QDense::apply_into`] calls while the weight matrix streams
    /// through cache once.
    fn apply_rows_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        out: &mut Tensor,
    ) {
        match self {
            QDense::F32(w) => {
                let (m, k) = (x.rows(), x.cols());
                kernel_obs(be, OpKind::F32, m, w.rows(), k, || be.gemm_f32_into(x, w, None, out))
            }
            QDense::I8(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                qs.sx.resize(m, 0.0);
                quant_obs(|| {
                    for i in 0..m {
                        qs.sx[i] = quantize_into(x.row(i), &mut qs.xq[i * k..(i + 1) * k]);
                    }
                });
                if m == 1 {
                    // single stream: `sx[0] · w.scale` is the exact same
                    // f32 product the per-row path computes → bit-identical
                    kernel_obs(be, OpKind::Gemv, 1, qw.n(), k, || {
                        be.qgemv_into(&qs.xq[..k], qw, qs.sx[0], out)
                    });
                } else {
                    kernel_obs(be, OpKind::Gemm, m, qw.n(), k, || {
                        be.qgemm_farm_rows_into(&qs.xq[..m * k], m, qw, &qs.sx[..m], out)
                    });
                }
            }
            QDense::I4(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                qs.sx.resize(m, 0.0);
                quant_obs(|| {
                    for i in 0..m {
                        qs.sx[i] = quantize_into(x.row(i), &mut qs.xq[i * k..(i + 1) * k]);
                    }
                });
                if m == 1 {
                    kernel_obs4(be, OpKind::Gemv4, 1, qw.n(), k, || {
                        be.qgemv4_into(&qs.xq[..k], qw, qs.sx[0], out)
                    });
                } else {
                    kernel_obs4(be, OpKind::Gemm4, m, qw.n(), k, || {
                        be.qgemm4_farm_rows_into(&qs.xq[..m * k], m, qw, &qs.sx[..m], out)
                    });
                }
            }
        }
    }

    /// [`QDense::apply_rows_into`] routed through the backend's fused
    /// GRU-gate entry point: when the prepared weight carries gate
    /// panels, all three gate products per hidden unit are computed in
    /// one sweep (bit-identical either way — exact i32 accumulation).
    fn apply_gates_rows_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        out: &mut Tensor,
    ) {
        match self {
            QDense::F32(w) => {
                let (m, k) = (x.rows(), x.cols());
                kernel_obs(be, OpKind::F32, m, w.rows(), k, || be.gemm_f32_into(x, w, None, out))
            }
            QDense::I8(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                qs.sx.resize(m, 0.0);
                quant_obs(|| {
                    for i in 0..m {
                        qs.sx[i] = quantize_into(x.row(i), &mut qs.xq[i * k..(i + 1) * k]);
                    }
                });
                kernel_obs(be, OpKind::FusedGates, m, qw.n(), k, || {
                    be.qgemm_gates_rows_into(&qs.xq[..m * k], m, qw, &qs.sx[..m], out)
                });
            }
            QDense::I4(qw) => {
                let (m, k) = (x.rows(), x.cols());
                qs.xq.resize(m * k, 0);
                qs.sx.resize(m, 0.0);
                quant_obs(|| {
                    for i in 0..m {
                        qs.sx[i] = quantize_into(x.row(i), &mut qs.xq[i * k..(i + 1) * k]);
                    }
                });
                kernel_obs4(be, OpKind::FusedGates4, m, qw.n(), k, || {
                    be.qgemm4_gates_rows_into(&qs.xq[..m * k], m, qw, &qs.sx[..m], out)
                });
            }
        }
    }

    /// Weight bytes on "device" (the packed plan-time copy is derived
    /// data and not counted — it never ships in an artifact).
    fn bytes(&self) -> usize {
        match self {
            QDense::F32(w) => w.len() * 4,
            QDense::I8(q) => q.q.data().len() + 4,
            QDense::I4(q) => q.bytes(),
        }
    }
}

/// A possibly-factored dense operator.
#[derive(Clone, Debug)]
enum Op {
    Dense(QDense),
    /// y = (x Vᵀ) Uᵀ
    LowRank { u: QDense, v: QDense },
}

impl Op {
    fn from_params(params: &ParamSet, base: &str, p: Precision) -> Result<Op> {
        if params.contains(&format!("{base}_u")) {
            Ok(Op::LowRank {
                u: QDense::from(params.get(&format!("{base}_u"))?, p),
                v: QDense::from(params.get(&format!("{base}_v"))?, p),
            })
        } else {
            Ok(Op::Dense(QDense::from(params.get(&format!("{base}_w"))?, p)))
        }
    }

    /// [`Op::from_params`] for recurrent gate weights: the op producing
    /// the stacked `[z | r | h̃]` gate rows gets gate panels (for a
    /// factored op that is `u`, the `(3H, r)` factor; `v` produces the
    /// rank-`r` intermediate and stays plain).
    fn from_params_gated(params: &ParamSet, base: &str, p: Precision) -> Result<Op> {
        if params.contains(&format!("{base}_u")) {
            Ok(Op::LowRank {
                u: QDense::from_gated(params.get(&format!("{base}_u"))?, p),
                v: QDense::from(params.get(&format!("{base}_v"))?, p),
            })
        } else {
            Ok(Op::Dense(QDense::from_gated(params.get(&format!("{base}_w"))?, p)))
        }
    }

    fn from_entries(entries: &BTreeMap<String, Entry>, base: &str) -> Result<Op> {
        if entries.contains_key(&format!("{base}_u")) {
            Ok(Op::LowRank {
                u: QDense::from_entry(entry(entries, &format!("{base}_u"))?),
                v: QDense::from_entry(entry(entries, &format!("{base}_v"))?),
            })
        } else {
            Ok(Op::Dense(QDense::from_entry(entry(entries, &format!("{base}_w"))?)))
        }
    }

    /// [`Op::from_entries`] with gate panels on the gate-producing factor
    /// (see [`Op::from_params_gated`]).
    fn from_entries_gated(entries: &BTreeMap<String, Entry>, base: &str) -> Result<Op> {
        if entries.contains_key(&format!("{base}_u")) {
            Ok(Op::LowRank {
                u: QDense::from_entry_gated(entry(entries, &format!("{base}_u"))?),
                v: QDense::from_entry(entry(entries, &format!("{base}_v"))?),
            })
        } else {
            Ok(Op::Dense(QDense::from_entry_gated(entry(entries, &format!("{base}_w"))?)))
        }
    }

    /// Per-call-quantized apply into `out` (`mid` holds the factored
    /// intermediate; untouched for dense ops).
    fn apply_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        out: &mut Tensor,
    ) {
        match self {
            Op::Dense(w) => w.apply_into(be, x, qs, out),
            Op::LowRank { u, v } => {
                v.apply_into(be, x, qs, mid);
                u.apply_into(be, mid, qs, out);
            }
        }
    }

    /// Per-row-quantized apply (the pooled recurrent path); see
    /// [`QDense::apply_rows_into`].
    fn apply_rows_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        out: &mut Tensor,
    ) {
        match self {
            Op::Dense(w) => w.apply_rows_into(be, x, qs, out),
            Op::LowRank { u, v } => {
                v.apply_rows_into(be, x, qs, mid);
                u.apply_rows_into(be, mid, qs, out);
            }
        }
    }

    /// [`Op::apply_rows_into`] with the gate-producing GEMM routed
    /// through the fused gate entry point (the `(3H, ·)` op; for a
    /// factored op only `u` produces gate rows).
    fn apply_gates_rows_into(
        &self,
        be: &dyn GemmBackend,
        x: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        out: &mut Tensor,
    ) {
        match self {
            Op::Dense(w) => w.apply_gates_rows_into(be, x, qs, out),
            Op::LowRank { u, v } => {
                v.apply_rows_into(be, x, qs, mid);
                u.apply_gates_rows_into(be, mid, qs, out);
            }
        }
    }

    fn out_dim(&self) -> usize {
        match self {
            Op::Dense(w) => w.out_dim(),
            Op::LowRank { u, .. } => u.out_dim(),
        }
    }

    fn in_dim(&self) -> usize {
        match self {
            Op::Dense(w) => w.in_dim(),
            Op::LowRank { v, .. } => v.in_dim(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            Op::Dense(w) => w.bytes(),
            Op::LowRank { u, v } => u.bytes() + v.bytes(),
        }
    }

    /// MACs for an (m, k) input.
    fn macs(&self, m: usize) -> u64 {
        match self {
            Op::Dense(w) => (m * w.out_dim() * w.in_dim()) as u64,
            Op::LowRank { u, v } => {
                (m * v.out_dim() * v.in_dim() + m * u.out_dim() * u.in_dim()) as u64
            }
        }
    }
}

fn entry<'a>(entries: &'a BTreeMap<String, Entry>, name: &str) -> Result<&'a Entry> {
    entries
        .get(name)
        .ok_or_else(|| Error::Checkpoint(format!("ladder artifact missing entry '{name}'")))
}

fn bias_entry(entries: &BTreeMap<String, Entry>, name: &str) -> Result<Vec<f32>> {
    match entry(entries, name)? {
        Entry::F32(t) => Ok(t.data().to_vec()),
        Entry::I8(_) | Entry::I4(_) => Err(Error::Checkpoint(format!(
            "bias '{name}' must be stored f32 (biases and gate math stay f32 on the embedded path)"
        ))),
    }
}

/// Does `op` map an `inp`-dim input to an `out`-dim output (with
/// consistent inner rank if factored)?  Shape gate for untrusted
/// artifact entries.
fn op_matches(op: &Op, out: usize, inp: usize) -> bool {
    let inner_ok = match op {
        Op::Dense(_) => true,
        Op::LowRank { u, v } => u.in_dim() == v.out_dim(),
    };
    inner_ok && op.out_dim() == out && op.in_dim() == inp
}

struct ConvLayer {
    context: usize,
    op: Op,
    bias: Vec<f32>,
}

struct GruLayer {
    hidden: usize,
    rec: Op,
    nonrec: Op,
    bias: Vec<f32>,
}

/// Cumulative per-component time (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub frontend: f64,
    pub nonrec: f64,
    pub rec: f64,
    pub gates: f64,
    pub fc_out: f64,
    /// frames of audio processed (raw, pre-frontend)
    pub frames: u64,
    pub macs: u64,
    /// Observability self-time spans (DESIGN.md §10).  Empty unless
    /// `--obs on`: the legacy component fields above always accumulate
    /// (they are load-bearing for reports and the controller), while
    /// the spans add the finer self-time taxonomy — quantize time is
    /// *subtracted* from its enclosing stage here so the span sum
    /// equals wall time without double counting.
    pub spans: SpanSet,
}

impl Breakdown {
    pub fn acoustic_total(&self) -> f64 {
        self.frontend + self.nonrec + self.rec + self.gates + self.fc_out
    }

    /// Fold another breakdown into this one — the cross-shard
    /// aggregation of the sharded serving report (DESIGN.md §9).
    pub fn absorb(&mut self, o: &Breakdown) {
        self.frontend += o.frontend;
        self.nonrec += o.nonrec;
        self.rec += o.rec;
        self.gates += o.gates;
        self.fc_out += o.fc_out;
        self.frames += o.frames;
        self.macs += o.macs;
        self.spans.absorb(&o.spans);
    }

    /// Real-time factor given a frame hop (seconds of audio per frame).
    pub fn speedup_over_realtime(&self, frame_hop_secs: f64) -> f64 {
        let audio = self.frames as f64 * frame_hop_secs;
        audio / self.acoustic_total().max(1e-12)
    }
}

// ---------------------------------------------------------------------------
// Scratch arena: every buffer the block executor reuses.
// ---------------------------------------------------------------------------

/// Reusable activation-quantization buffers, threaded through every GEMM
/// call so dynamic quantization never allocates in steady state.
#[derive(Default)]
pub(crate) struct QuantScratch {
    /// quantized activation panel (row-major, sized m·k per call)
    pub(crate) xq: Vec<i8>,
    /// per-row dynamic scales (the pooled recurrent path)
    pub(crate) sx: Vec<f32>,
}

impl QuantScratch {
    pub(crate) fn footprint_bytes(&self) -> usize {
        self.xq.capacity() + self.sx.capacity() * 4
    }
}

/// The per-stream scratch arena: every tensor the block executor writes,
/// allocated lazily on the first (warmup) block and reused verbatim from
/// then on.  [`Scratch::grow_events`] counts post-warmup growth — the
/// steady-state contract is that it stays at zero
/// (`rust/tests/alloc_free.rs` additionally asserts zero allocator hits
/// with a counting global allocator).
#[derive(Default)]
pub struct Scratch {
    /// staging copy of one raw block (drained from the stream buffer)
    pub(crate) chunk: Vec<f32>,
    pub(crate) qs: QuantScratch,
    /// factored-op intermediate (`x Vᵀ`)
    pub(crate) mid: Tensor,
    /// layer ping-pong: `a` holds the current activations
    pub(crate) a: Tensor,
    pub(crate) b: Tensor,
    /// non-recurrent gate pre-activations of the current layer
    pub(crate) gx: Tensor,
    /// recurrent gate pre-activations of the current step
    pub(crate) gh: Tensor,
    /// head intermediates
    pub(crate) fc_y: Tensor,
    /// log-prob rows of the most recent block (log-softmax in place)
    pub(crate) logp: Tensor,
    /// block-boundary hidden-state checkpoint (one tensor per GRU layer),
    /// filled by [`StreamState::snap_checkpoint`] — the cascade decoder's
    /// rewind target, so escalating a block is a memcpy, not a re-decode
    pub(crate) ckpt: Vec<Tensor>,
    high_water: usize,
    grow_events: u64,
}

impl Scratch {
    pub(crate) fn new() -> Scratch {
        Scratch::default()
    }

    /// Log-prob rows of the most recent block processed into this arena.
    pub fn logp(&self) -> &Tensor {
        &self.logp
    }

    /// Total bytes currently reserved by the arena's buffers.
    pub fn footprint_bytes(&self) -> usize {
        self.chunk.capacity() * 4
            + self.qs.footprint_bytes()
            + 4 * (self.mid.capacity()
                + self.a.capacity()
                + self.b.capacity()
                + self.gx.capacity()
                + self.gh.capacity()
                + self.fc_y.capacity()
                + self.logp.capacity()
                + self.ckpt.iter().map(|t| t.capacity()).sum::<usize>())
    }

    /// Times the arena grew **after** its warmup block — zero in steady
    /// state (the debug-friendly allocation counter of DESIGN.md §4).
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    /// Fold the current footprint into the growth counter (called once
    /// per block by the executor).
    pub(crate) fn settle(&mut self) {
        let fp = self.footprint_bytes();
        if fp > self.high_water {
            if self.high_water > 0 {
                self.grow_events += 1;
            }
            self.high_water = fp;
        }
    }
}

/// The streaming embedded engine.
pub struct Engine {
    pub precision: Precision,
    pub time_batch: usize,
    backend: &'static dyn GemmBackend,
    backend_sel: BackendSel,
    fused_gates: bool,
    conv: Vec<ConvLayer>,
    grus: Vec<GruLayer>,
    fc: Op,
    fc_bias: Vec<f32>,
    out: Op,
    out_bias: Vec<f32>,
    vocab: usize,
    feat_dim: usize,
    total_stride: usize,
    split_scheme: bool,
}

/// Per-stream session state, split from the shared [`Engine`] weights:
/// carried GRU hidden vectors, the raw-frame ring buffer, and the
/// [`Scratch`] arena of the block executor.  One of these exists per
/// live utterance; the stream pool lock-steps many of them against a
/// single engine.
pub struct StreamState {
    pub(crate) h: Vec<Tensor>,
    pub(crate) buf: Vec<f32>,
    pub(crate) scratch: Scratch,
}

impl StreamState {
    /// Raw feature values currently buffered (not yet processed).
    pub fn buffered_len(&self) -> usize {
        self.buf.len()
    }

    /// Log-prob rows of the most recent block processed by
    /// [`Engine::pump_block`] (borrowed from the scratch arena).
    pub fn block_logp(&self) -> &Tensor {
        self.scratch.logp()
    }

    /// Bytes reserved by this stream's scratch arena.
    pub fn scratch_footprint(&self) -> usize {
        self.scratch.footprint_bytes()
    }

    /// Post-warmup scratch growth events (zero in steady state).
    pub fn scratch_grow_events(&self) -> u64 {
        self.scratch.grow_events()
    }

    /// Snapshot the carried hidden state into the scratch arena's
    /// checkpoint buffers (the cascade decoder calls this at every block
    /// boundary).  The buffers are allocated on the first call and reused
    /// verbatim from then on, so steady-state snapping is a memcpy.
    pub fn snap_checkpoint(&mut self) {
        if self.scratch.ckpt.len() != self.h.len() {
            self.scratch.ckpt = self.h.iter().map(|t| Tensor::zeros(t.shape())).collect();
        }
        for (c, h) in self.scratch.ckpt.iter_mut().zip(&self.h) {
            c.data_mut().copy_from_slice(h.data());
        }
    }

    /// Restore the hidden state from the last [`Self::snap_checkpoint`]
    /// — the cascade rewind: a memcpy per layer, never a re-decode.
    /// Panics if no checkpoint was ever snapped (programming error).
    pub fn rewind_to_checkpoint(&mut self) {
        assert_eq!(
            self.scratch.ckpt.len(),
            self.h.len(),
            "rewind_to_checkpoint without a prior snap_checkpoint"
        );
        for (h, c) in self.h.iter_mut().zip(&self.scratch.ckpt) {
            h.data_mut().copy_from_slice(c.data());
        }
    }
}

impl Engine {
    /// Build from trained parameters. `scheme` is the artifact scheme
    /// string ("unfactored" | "partial" | "split" | "joint" — joint is not
    /// supported on the embedded path, matching the paper's choice of
    /// partial factorization for deployment).  The GEMM backend defaults
    /// to [`BackendSel::Auto`]; see [`Engine::with_backend`].
    pub fn from_params(
        dims: &ModelDims,
        scheme: &str,
        params: &ParamSet,
        precision: Precision,
        time_batch: usize,
    ) -> Result<Engine> {
        if scheme == "joint" {
            return Err(Error::other("joint scheme unsupported on the embedded path"));
        }
        let split = scheme == "split";
        let mut conv = Vec::new();
        for (i, c) in dims.conv.iter().enumerate() {
            conv.push(ConvLayer {
                context: c.context,
                op: Op::Dense(QDense::from(params.get(&format!("conv{i}_w"))?, precision)),
                bias: params.get(&format!("conv{i}_b"))?.data().to_vec(),
            });
        }
        let mut grus = Vec::new();
        for (i, &h) in dims.gru_dims.iter().enumerate() {
            let (rec, nonrec) = if split {
                // concatenate the three per-gate factored ops by applying
                // them separately; represented as three ops via a wrapper
                // below — for simplicity materialize a partially-joint pair
                // of dense matrices from the per-gate factors.
                (
                    Op::Dense(QDense::from_gated(
                        &concat_gates(params, &format!("rec{i}"))?,
                        precision,
                    )),
                    Op::Dense(QDense::from(
                        &concat_gates(params, &format!("nonrec{i}"))?,
                        precision,
                    )),
                )
            } else {
                (
                    Op::from_params_gated(params, &format!("rec{i}"), precision)?,
                    Op::from_params(params, &format!("nonrec{i}"), precision)?,
                )
            };
            grus.push(GruLayer {
                hidden: h,
                rec,
                nonrec,
                bias: params.get(&format!("gru{i}_b"))?.data().to_vec(),
            });
        }
        Ok(Engine {
            precision,
            time_batch: time_batch.max(1),
            backend: kernels::resolve(BackendSel::Auto)?,
            backend_sel: BackendSel::Auto,
            fused_gates: true,
            conv,
            grus,
            fc: Op::from_params(params, "fc", precision)?,
            fc_bias: params.get("fc_b")?.data().to_vec(),
            out: Op::Dense(QDense::from(params.get("out_w")?, precision)),
            out_bias: params.get("out_b")?.data().to_vec(),
            vocab: dims.vocab,
            feat_dim: dims.feat_dim,
            total_stride: dims.total_stride,
            split_scheme: split,
        })
    }

    /// Build directly from a ladder artifact's typed entries
    /// ([`crate::registry`], DESIGN.md §8): int8 weight entries install
    /// their stored quantized matrices verbatim — **no SVD and no
    /// re-quantization at load** — while biases stay f32.  Backend
    /// layouts ([`PreparedQMatrix`]) are packed here, once, at load.
    ///
    /// Decoding is bit-identical to an engine built by
    /// [`Engine::from_params`] at [`Precision::Int8`] from the same
    /// factored f32 weights, because `ladder-build` quantized those exact
    /// tensors with the same [`crate::quant::quantize`] call that
    /// `from_params` uses, and the artifact round-trips the int8 data and
    /// f32 scales exactly (`rust/tests/ladder.rs`).
    pub fn from_entries(
        dims: &ModelDims,
        entries: &BTreeMap<String, Entry>,
        time_batch: usize,
    ) -> Result<Engine> {
        // every artifact entry must be consumed by the dims-derived layer
        // map — an entry `dims` doesn't name means the checkpoint holds
        // more network than these dims describe, and building anyway
        // would silently drop layers and decode garbage
        let mut expected: BTreeSet<String> = BTreeSet::new();
        {
            // rec/nonrec/fc may be factored (u, v) or dense (w); conv and
            // the output projection are always dense (paper §3.2)
            let mut expect_op = |base: &str| {
                if entries.contains_key(&format!("{base}_u")) {
                    expected.insert(format!("{base}_u"));
                    expected.insert(format!("{base}_v"));
                } else {
                    expected.insert(format!("{base}_w"));
                }
            };
            for i in 0..dims.gru_dims.len() {
                expect_op(&format!("rec{i}"));
                expect_op(&format!("nonrec{i}"));
            }
            expect_op("fc");
        }
        for i in 0..dims.conv.len() {
            expected.insert(format!("conv{i}_w"));
            expected.insert(format!("conv{i}_b"));
        }
        for i in 0..dims.gru_dims.len() {
            expected.insert(format!("gru{i}_b"));
        }
        expected.insert("fc_b".into());
        expected.insert("out_w".into());
        expected.insert("out_b".into());
        if let Some(extra) = entries.keys().find(|k| !expected.contains(*k)) {
            return Err(Error::Checkpoint(format!(
                "artifact entry '{extra}' is not named by the given model dims \
                 (layer-count mismatch between checkpoint and dims?)"
            )));
        }

        let any_i8 = entries.values().any(|e| matches!(e, Entry::I8(_)));
        let any_i4 = entries.values().any(|e| matches!(e, Entry::I4(_)));
        let mut conv = Vec::new();
        for (i, c) in dims.conv.iter().enumerate() {
            conv.push(ConvLayer {
                context: c.context,
                op: Op::Dense(QDense::from_entry(entry(entries, &format!("conv{i}_w"))?)),
                bias: bias_entry(entries, &format!("conv{i}_b"))?,
            });
        }
        let mut grus = Vec::new();
        for (i, &h) in dims.gru_dims.iter().enumerate() {
            grus.push(GruLayer {
                hidden: h,
                rec: Op::from_entries_gated(entries, &format!("rec{i}"))?,
                nonrec: Op::from_entries(entries, &format!("nonrec{i}"))?,
                bias: bias_entry(entries, &format!("gru{i}_b"))?,
            });
        }
        let fc = Op::from_entries(entries, "fc")?;
        let fc_bias = bias_entry(entries, "fc_b")?;
        let out = Op::Dense(QDense::from_entry(entry(entries, "out_w")?));
        let out_bias = bias_entry(entries, "out_b")?;

        // shape validation: artifacts are untrusted input — a
        // mis-dimensioned entry must fail here with a clean error, not
        // panic inside a GEMM contraction mid-serve
        let shape_err = |what: &str| {
            Err(Error::Checkpoint(format!(
                "artifact entry shapes for {what} do not match the given model dims"
            )))
        };
        let mut prev = dims.feat_dim;
        for (i, (c, layer)) in dims.conv.iter().zip(&conv).enumerate() {
            if !op_matches(&layer.op, c.dim, c.context * prev) || layer.bias.len() != c.dim {
                return shape_err(&format!("conv{i}"));
            }
            prev = c.dim;
        }
        for (i, (&h, g)) in dims.gru_dims.iter().zip(&grus).enumerate() {
            if !op_matches(&g.rec, 3 * h, h)
                || !op_matches(&g.nonrec, 3 * h, prev)
                || g.bias.len() != 3 * h
            {
                return shape_err(&format!("gru layer {i}"));
            }
            prev = h;
        }
        if !op_matches(&fc, dims.fc_dim, prev) || fc_bias.len() != dims.fc_dim {
            return shape_err("fc");
        }
        if !op_matches(&out, dims.vocab, dims.fc_dim) || out_bias.len() != dims.vocab {
            return shape_err("the output projection");
        }

        Ok(Engine {
            precision: if any_i4 {
                Precision::Int4
            } else if any_i8 {
                Precision::Int8
            } else {
                Precision::F32
            },
            time_batch: time_batch.max(1),
            backend: kernels::resolve(BackendSel::Auto)?,
            backend_sel: BackendSel::Auto,
            fused_gates: true,
            conv,
            grus,
            fc,
            fc_bias,
            out,
            out_bias,
            vocab: dims.vocab,
            feat_dim: dims.feat_dim,
            total_stride: dims.total_stride,
            split_scheme: false,
        })
    }

    /// Select the GEMM backend (`--backend` on the CLI; DESIGN.md §4
    /// dispatch rules).  Int8 decoding is bit-identical across backends;
    /// `simd` may differ from scalar at rounding level on f32 paths.
    pub fn set_backend(&mut self, sel: BackendSel) -> Result<()> {
        self.backend = kernels::resolve(sel)?;
        self.backend_sel = sel;
        Ok(())
    }

    /// Builder form of [`Engine::set_backend`].
    pub fn with_backend(mut self, sel: BackendSel) -> Result<Engine> {
        self.set_backend(sel)?;
        Ok(self)
    }

    /// Name of the backend actually executing (after `auto` resolution).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The selector this engine was configured with.
    pub fn backend_sel(&self) -> BackendSel {
        self.backend_sel
    }

    /// Route the recurrent GEMM through the fused GRU-gate kernel
    /// (`--fused-gates` on the CLI; on by default).  Off pins the plain
    /// stacked sweep; decoding is **bit-identical** either way (exact i32
    /// accumulation — the parity suite asserts it), so this is a
    /// performance/debugging switch, not an accuracy knob.
    pub fn set_fused_gates(&mut self, on: bool) {
        self.fused_gates = on;
    }

    /// Builder form of [`Engine::set_fused_gates`].
    pub fn with_fused_gates(mut self, on: bool) -> Engine {
        self.set_fused_gates(on);
        self
    }

    /// Whether the recurrent GEMM routes through the fused gate kernel.
    pub fn fused_gates(&self) -> bool {
        self.fused_gates
    }

    pub fn new_state(&self) -> StreamState {
        StreamState {
            h: self.grus.iter().map(|g| Tensor::zeros(&[1, g.hidden])).collect(),
            buf: Vec::new(),
            scratch: Scratch::new(),
        }
    }

    /// Model weight footprint in bytes (the Table-2 acoustic model size;
    /// plan-time packed copies are derived data and excluded).
    pub fn model_bytes(&self) -> usize {
        let conv: usize = self.conv.iter().map(|c| c.op.bytes() + c.bias.len() * 4).sum();
        let gru: usize = self
            .grus
            .iter()
            .map(|g| g.rec.bytes() + g.nonrec.bytes() + g.bias.len() * 4)
            .sum();
        conv + gru
            + self.fc.bytes()
            + self.fc_bias.len() * 4
            + self.out.bytes()
            + self.out_bias.len() * 4
    }

    /// MACs per output timestep (batch-1 streaming).
    pub fn macs_per_step(&self) -> u64 {
        let mut macs = 0u64;
        let mut t = self.total_stride as u64; // raw frames per output step
        for c in &self.conv {
            t /= c.context as u64;
            macs += c.op.macs(1) * t;
        }
        for g in &self.grus {
            macs += g.rec.macs(1) + g.nonrec.macs(1);
        }
        macs + self.fc.macs(1) + self.out.macs(1)
    }

    /// MACs per output step spent in the conv frontend alone.  The
    /// frontend is never factored (§3.2), so when a cascade rung pair
    /// shares it the escalated re-run skips exactly this many MACs —
    /// the effective-FLOPs accounting in `serve.rs` subtracts it.
    pub fn frontend_macs_per_step(&self) -> u64 {
        let mut macs = 0u64;
        let mut t = self.total_stride as u64;
        for c in &self.conv {
            t /= c.context as u64;
            macs += c.op.macs(1) * t;
        }
        macs
    }

    /// Buffer raw feature frames for a stream without processing them
    /// (pairs with [`Engine::pump_block`]; [`Engine::stream`] is the
    /// convenience wrapper over both).
    pub fn buffer_frames(&self, state: &mut StreamState, frames: &[f32], bd: &mut Breakdown) {
        assert!(frames.len() % self.feat_dim == 0);
        state.buf.extend_from_slice(frames);
        bd.frames += (frames.len() / self.feat_dim) as u64;
    }

    /// Process one full time-batched block from the stream's buffer, if
    /// one is available; returns whether a block ran.  The block's
    /// log-prob rows are left in the scratch arena
    /// ([`StreamState::block_logp`]) — they are valid until the next
    /// block.  In steady state (shapes warmed up) this path performs
    /// **zero heap allocations** (`rust/tests/alloc_free.rs`).
    pub fn pump_block(&self, state: &mut StreamState, bd: &mut Breakdown) -> Result<bool> {
        let block_raw = self.block_raw_len();
        if state.buf.len() < block_raw {
            return Ok(false);
        }
        let StreamState { h, buf, scratch } = state;
        scratch.chunk.resize(block_raw, 0.0);
        scratch.chunk.copy_from_slice(&buf[..block_raw]);
        buf.drain(..block_raw);
        self.run_chunk(h, scratch, bd)
    }

    /// Stream raw feature frames; returns log-prob rows for each completed
    /// output step.  Feed arbitrary-size chunks; leftovers are buffered.
    pub fn stream(
        &self,
        state: &mut StreamState,
        frames: &[f32],
        bd: &mut Breakdown,
    ) -> Result<Vec<Vec<f32>>> {
        self.buffer_frames(state, frames, bd);
        let mut outputs = Vec::new();
        while self.pump_block(state, bd)? {
            let logp = state.scratch.logp();
            for r in 0..logp.rows() {
                outputs.push(logp.row(r).to_vec());
            }
        }
        Ok(outputs)
    }

    /// Flush any buffered frames shorter than a full block (end of
    /// utterance), padding with zeros to a stride boundary.
    pub fn flush(&self, state: &mut StreamState, bd: &mut Breakdown) -> Result<Vec<Vec<f32>>> {
        if state.buf.is_empty() {
            return Ok(Vec::new());
        }
        let raw_per_step = self.total_stride * self.feat_dim;
        let steps = state.buf.len().div_ceil(raw_per_step);
        let StreamState { h, buf, scratch } = state;
        scratch.chunk.resize(buf.len(), 0.0);
        scratch.chunk.copy_from_slice(buf);
        scratch.chunk.resize(steps * raw_per_step, 0.0);
        buf.clear();
        self.run_chunk(h, scratch, bd)?;
        let logp = state.scratch.logp();
        Ok((0..logp.rows()).map(|r| logp.row(r).to_vec()).collect())
    }

    // -- staged primitives -------------------------------------------------
    //
    // `run_chunk` (single stream) and `StreamPool::pump` (m streams,
    // lock-stepped) are both built from these, which is what makes pooled
    // decoding bit-identical to sequential decoding by construction.
    // Every primitive writes into caller-owned scratch tensors.

    /// Frontend: stack-and-project conv layers over one raw chunk
    /// (time-batched by nature).  Ping-pongs `a`/`b`; the `(T, d)` result
    /// is left in `a`.
    pub(crate) fn frontend_into(
        &self,
        chunk: &[f32],
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        a: &mut Tensor,
        b: &mut Tensor,
        bd: &mut Breakdown,
    ) {
        let t_raw = chunk.len() / self.feat_dim;
        a.reset(&[t_raw, self.feat_dim]);
        a.data_mut().copy_from_slice(chunk);
        let t0 = std::time::Instant::now();
        for c in &self.conv {
            let (t, f) = (a.rows(), a.cols());
            let t2 = t / c.context;
            // stack: reinterpret the prefix as (t2, context·f) in place
            a.reset(&[t2, c.context * f]);
            c.op.apply_into(self.backend, a, qs, mid, b);
            bd.macs += c.op.macs(t2);
            for row in 0..t2 {
                let r = b.row_mut(row);
                for (v, bias) in r.iter_mut().zip(&c.bias) {
                    *v = (*v + bias).max(0.0); // bias + ReLU
                }
            }
            std::mem::swap(a, b);
        }
        let dt = t0.elapsed().as_secs_f64();
        bd.frontend += dt;
        if obs::enabled() {
            let q = obs::spans::take_pending_quantize();
            bd.spans.add(Stage::Quantize, q);
            bd.spans.add(Stage::Frontend, (dt - q).max(0.0));
        }
    }

    /// Non-recurrent GEMM + bias for GRU layer `li`, batched across the
    /// whole block (§4), into `gx`.
    pub(crate) fn nonrec_block_into(
        &self,
        li: usize,
        x: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        gx: &mut Tensor,
        bd: &mut Breakdown,
    ) {
        let g = &self.grus[li];
        let t = x.rows();
        let t0 = std::time::Instant::now();
        g.nonrec.apply_into(self.backend, x, qs, mid, gx);
        bd.macs += g.nonrec.macs(t);
        for row in 0..t {
            let r = gx.row_mut(row);
            for (v, b) in r.iter_mut().zip(&g.bias) {
                *v += b;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        bd.nonrec += dt;
        if obs::enabled() {
            let q = obs::spans::take_pending_quantize();
            bd.spans.add(Stage::Quantize, q);
            bd.spans.add(Stage::Nonrec, (dt - q).max(0.0));
        }
    }

    /// One recurrent GEMM for layer `li` over `h` = (m, H) — the m rows
    /// are independent streams' hidden states, lock-stepped into a single
    /// batch-m farm call with per-row activation scales — into `gh`.
    pub(crate) fn rec_gates_into(
        &self,
        li: usize,
        h: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        gh: &mut Tensor,
        bd: &mut Breakdown,
    ) {
        let g = &self.grus[li];
        let t1 = std::time::Instant::now();
        if self.fused_gates {
            g.rec.apply_gates_rows_into(self.backend, h, qs, mid, gh);
        } else {
            g.rec.apply_rows_into(self.backend, h, qs, mid, gh);
        }
        bd.macs += g.rec.macs(h.rows());
        let dt = t1.elapsed().as_secs_f64();
        bd.rec += dt;
        if obs::enabled() {
            let q = obs::spans::take_pending_quantize();
            bd.spans.add(Stage::Quantize, q);
            bd.spans.add(Stage::RecGates, (dt - q).max(0.0));
        }
    }

    /// FC + output projection + in-place log-softmax over the block's GRU
    /// outputs; log-prob rows land in `logp`.
    pub(crate) fn head_into(
        &self,
        x: &Tensor,
        qs: &mut QuantScratch,
        mid: &mut Tensor,
        fc_y: &mut Tensor,
        logp: &mut Tensor,
        bd: &mut Breakdown,
    ) {
        let t3 = std::time::Instant::now();
        let t = x.rows();
        self.fc.apply_into(self.backend, x, qs, mid, fc_y);
        bd.macs += self.fc.macs(t);
        for row in 0..t {
            let r = fc_y.row_mut(row);
            for (v, b) in r.iter_mut().zip(&self.fc_bias) {
                *v = (*v + b).max(0.0);
            }
        }
        self.out.apply_into(self.backend, fc_y, qs, mid, logp);
        bd.macs += self.out.macs(t);
        for row in 0..t {
            let r = logp.row_mut(row);
            for (v, b) in r.iter_mut().zip(&self.out_bias) {
                *v += b;
            }
            log_softmax_in_place(r);
        }
        let dt = t3.elapsed().as_secs_f64();
        bd.fc_out += dt;
        if obs::enabled() {
            let q = obs::spans::take_pending_quantize();
            bd.spans.add(Stage::Quantize, q);
            bd.spans.add(Stage::Head, (dt - q).max(0.0));
        }
    }

    /// The block executor: run the staged primitives over the chunk
    /// staged in `scratch.chunk`, leaving log-prob rows in
    /// `scratch.logp`.  Allocation-free once the arena is warm.
    /// `pub(crate)` so the cascade decoder ([`crate::stream`]) can re-run
    /// the chunk still staged in the arena through a higher rung after a
    /// checkpoint rewind.
    pub(crate) fn run_chunk(
        &self,
        h: &mut [Tensor],
        scratch: &mut Scratch,
        bd: &mut Breakdown,
    ) -> Result<bool> {
        let Scratch { chunk, qs, mid, a, b, gx, gh, fc_y, logp, .. } = scratch;
        self.frontend_into(chunk, qs, mid, a, b, bd);

        // GRU stack: time-batched nonrec, then sequential recurrent steps
        // at stream-batch 1
        for (li, g) in self.grus.iter().enumerate() {
            self.nonrec_block_into(li, a, qs, mid, gx, bd);
            let t = gx.rows();
            b.reset(&[t, g.hidden]);
            for step in 0..t {
                self.rec_gates_into(li, &h[li], qs, mid, gh, bd);
                let t2 = std::time::Instant::now();
                gru_cell(gx.row(step), gh.row(0), h[li].data(), b.row_mut(step));
                // in-place hidden update — no per-step Tensor allocation
                h[li].data_mut().copy_from_slice(b.row(step));
                let dt = t2.elapsed().as_secs_f64();
                bd.gates += dt;
                if obs::enabled() {
                    bd.spans.add(Stage::GruCell, dt);
                }
            }
            std::mem::swap(a, b);
        }

        self.head_into(a, qs, mid, fc_y, logp, bd);
        scratch.settle();
        Ok(true)
    }

    /// Transcribe a whole utterance (streaming internally); returns
    /// (greedy text, logprob rows).
    pub fn transcribe(
        &self,
        feats: &Tensor,
        bd: &mut Breakdown,
    ) -> Result<(String, Vec<Vec<f32>>)> {
        let mut state = self.new_state();
        let mut rows = self.stream(&mut state, feats.data(), bd)?;
        rows.extend(self.flush(&mut state, bd)?);
        let t = rows.len();
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let logp = Tensor::new(&[t, self.vocab], flat)?;
        let labels = decoder::greedy_decode(&logp, t);
        Ok((labels_to_text(&labels), rows))
    }

    pub fn is_split(&self) -> bool {
        self.split_scheme
    }

    // -- shared-dimension accessors (used by the stream pool and CLI) ------

    /// Number of stacked GRU layers.
    pub fn num_gru_layers(&self) -> usize {
        self.grus.len()
    }

    /// Hidden width of GRU layer `li`.
    pub fn gru_hidden(&self, li: usize) -> usize {
        self.grus[li].hidden
    }

    /// Feature dimension of raw input frames.
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// Output vocabulary size (CTC symbols incl. blank).
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Raw frames consumed per output step.
    pub fn total_stride(&self) -> usize {
        self.total_stride
    }

    /// Raw f32 values per output step (`total_stride × feat_dim`).
    pub fn step_raw_len(&self) -> usize {
        self.total_stride * self.feat_dim
    }

    /// Raw f32 values per full time-batched block.
    pub fn block_raw_len(&self) -> usize {
        self.time_batch * self.step_raw_len()
    }

    /// Whether a [`StreamState`] produced by this engine can be driven by
    /// `other` mid-stream — the cascade pairing contract: identical layer
    /// map (hidden widths, conv stack shape, head dims) and identical
    /// time batch, so a block-boundary hidden checkpoint means the same
    /// thing on both rungs.  Weight precision and rank may differ; that
    /// is the whole point of the cascade.
    pub fn state_compatible(&self, other: &Engine) -> bool {
        self.time_batch == other.time_batch
            && self.feat_dim == other.feat_dim
            && self.vocab == other.vocab
            && self.total_stride == other.total_stride
            && self.conv.len() == other.conv.len()
            && self
                .conv
                .iter()
                .zip(&other.conv)
                .all(|(a, b)| a.context == b.context && a.bias.len() == b.bias.len())
            && self.grus.len() == other.grus.len()
            && self.grus.iter().zip(&other.grus).all(|(a, b)| a.hidden == b.hidden)
    }
}

// Compile-time Send+Sync audit (DESIGN.md §9): the sharded runtime
// shares one `Arc<Engine>` plan across N worker threads and moves
// per-stream state between them, so these bounds are load-bearing — a
// future non-Sync field (say, a `Cell` cache inside a weight op) must
// fail the build here, not corrupt a serve.
const _: () = crate::assert_send_sync::<Engine>();
const _: () = crate::assert_send_sync::<StreamState>();

/// One GRU cell update (elementwise gate math), writing the new hidden
/// state into `out`.  `gx`/`gh` are the non-recurrent/recurrent gate
/// pre-activations laid out `[z | r | h̃]`; identical op order on every
/// path (single-stream and pooled), which the bit-identity tests rely on.
#[inline]
pub(crate) fn gru_cell(gx: &[f32], gh: &[f32], h_prev: &[f32], out: &mut [f32]) {
    let h_dim = out.len();
    for j in 0..h_dim {
        let z = sigmoid(gx[j] + gh[j]);
        let r = sigmoid(gx[h_dim + j] + gh[h_dim + j]);
        let cand = (gx[2 * h_dim + j] + r * gh[2 * h_dim + j]).tanh();
        out[j] = (1.0 - z) * h_prev[j] + z * cand;
    }
}

/// Materialize a per-gate split group (`{base}_z/_r/_h` factored pairs)
/// into the concatenated (3H, k) dense matrix.
fn concat_gates(params: &ParamSet, base: &str) -> Result<Tensor> {
    let mut parts = Vec::new();
    for gate in ["z", "r", "h"] {
        let u = params.get(&format!("{base}_{gate}_u"))?;
        let v = params.get(&format!("{base}_{gate}_v"))?;
        parts.push(u.matmul(v)?);
    }
    let refs: Vec<&Tensor> = parts.iter().collect();
    Tensor::concat_rows(&refs)
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Per-frame decode confidence from one already-materialized log-softmax
/// row — no extra softmax pass, just a scan: the top-2 log-prob margin
/// scaled by one minus the normalized posterior entropy,
/// `(lp₁ - lp₂) · (1 - H/ln V)`.  Both factors are non-negative, so the
/// score is ≥ 0 with equality only at a uniform posterior; a strict
/// `< threshold` comparison therefore never escalates at threshold 0 and
/// always escalates at threshold ∞ — the cascade's bit-identity
/// endpoints (DESIGN.md §11).
pub fn frame_confidence(row: &[f32]) -> f64 {
    if row.len() < 2 {
        return f64::INFINITY;
    }
    let (mut lp1, mut lp2) = (f64::NEG_INFINITY, f64::NEG_INFINITY);
    let mut entropy = 0.0f64;
    for &v in row {
        let lp = v as f64;
        if lp > lp1 {
            lp2 = lp1;
            lp1 = lp;
        } else if lp > lp2 {
            lp2 = lp;
        }
        // -p·ln p with p = exp(lp); exp(-inf) rows contribute 0
        let p = lp.exp();
        if p > 0.0 {
            entropy -= p * lp;
        }
    }
    let norm = (entropy / (row.len() as f64).ln()).clamp(0.0, 1.0);
    (lp1 - lp2) * (1.0 - norm)
}

/// Worst-frame confidence over a block of log-prob rows — the cascade's
/// escalation signal: a block re-runs on the high rung iff this value is
/// strictly below the escalation threshold.
pub fn block_confidence(logp: &Tensor) -> f64 {
    (0..logp.rows()).map(|r| frame_confidence(logp.row(r))).fold(f64::INFINITY, f64::min)
}

/// In-place log-softmax over one logits row (same arithmetic as the
/// previous allocating version, so outputs are bit-identical).
#[inline]
fn log_softmax_in_place(row: &mut [f32]) {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
    for v in row {
        *v -= lse;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::runtime::{ConvDims, ModelDims};

    fn tiny_dims() -> ModelDims {
        ModelDims {
            feat_dim: 8,
            conv: vec![ConvDims { context: 2, dim: 12 }],
            gru_dims: vec![10, 12],
            fc_dim: 14,
            vocab: 29,
            total_stride: 2,
        }
    }

    fn tiny_params(dims: &ModelDims, factored: bool, seed: u64) -> ParamSet {
        let mut rng = Pcg64::seeded(seed);
        let mut p = ParamSet::new();
        let mut prev = dims.feat_dim;
        for (i, c) in dims.conv.iter().enumerate() {
            p.set(format!("conv{i}_w"), Tensor::glorot(c.dim, c.context * prev, &mut rng));
            p.set(format!("conv{i}_b"), Tensor::zeros(&[c.dim]));
            prev = c.dim;
        }
        for (i, &h) in dims.gru_dims.iter().enumerate() {
            let din = if i == 0 { dims.conv.last().unwrap().dim } else { dims.gru_dims[i - 1] };
            if factored {
                let r = h.min(din);
                p.set(format!("rec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
                p.set(format!("rec{i}_v"), Tensor::glorot(r, h, &mut rng));
                p.set(format!("nonrec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
                p.set(format!("nonrec{i}_v"), Tensor::glorot(r, din, &mut rng));
            } else {
                p.set(format!("rec{i}_w"), Tensor::glorot(3 * h, h, &mut rng));
                p.set(format!("nonrec{i}_w"), Tensor::glorot(3 * h, din, &mut rng));
            }
            p.set(format!("gru{i}_b"), Tensor::zeros(&[3 * h]));
        }
        let last = *dims.gru_dims.last().unwrap();
        if factored {
            let r = dims.fc_dim.min(last);
            p.set("fc_u", Tensor::glorot(dims.fc_dim, r, &mut rng));
            p.set("fc_v", Tensor::glorot(r, last, &mut rng));
        } else {
            p.set("fc_w", Tensor::glorot(dims.fc_dim, last, &mut rng));
        }
        p.set("fc_b", Tensor::zeros(&[dims.fc_dim]));
        p.set("out_w", Tensor::glorot(dims.vocab, dims.fc_dim, &mut rng));
        p.set("out_b", Tensor::zeros(&[dims.vocab]));
        p
    }

    #[test]
    fn stream_output_counts_and_normalization() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 0);
        let eng = Engine::from_params(&dims, "partial", &p, Precision::F32, 4).unwrap();
        let mut state = eng.new_state();
        let mut bd = Breakdown::default();
        let mut rng = Pcg64::seeded(1);
        let feats = Tensor::randn(&[16, 8], 1.0, &mut rng);
        let rows = eng.stream(&mut state, feats.data(), &mut bd).unwrap();
        assert_eq!(rows.len(), 8); // 16 raw frames / stride 2
        for r in &rows {
            let total: f32 = r.iter().map(|v| v.exp()).sum();
            assert!((total - 1.0).abs() < 1e-3);
        }
        assert!(bd.acoustic_total() > 0.0);
        assert_eq!(bd.frames, 16);
    }

    #[test]
    fn chunked_streaming_equals_one_shot() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 2);
        let eng = Engine::from_params(&dims, "partial", &p, Precision::F32, 2).unwrap();
        let mut rng = Pcg64::seeded(3);
        let feats = Tensor::randn(&[24, 8], 0.7, &mut rng);

        let mut bd = Breakdown::default();
        let (text_a, rows_a) = eng.transcribe(&feats, &mut bd).unwrap();

        // feed in ragged chunks
        let mut state = eng.new_state();
        let mut bd2 = Breakdown::default();
        let mut rows_b = Vec::new();
        let d = feats.data();
        for chunk in [&d[..40], &d[40..56], &d[56..]] {
            rows_b.extend(eng.stream(&mut state, chunk, &mut bd2).unwrap());
        }
        rows_b.extend(eng.flush(&mut state, &mut bd2).unwrap());
        assert_eq!(rows_a.len(), rows_b.len());
        for (a, b) in rows_a.iter().zip(&rows_b) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5, "{x} vs {y}");
            }
        }
        let _ = text_a;
    }

    #[test]
    fn int8_engine_tracks_f32() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 4);
        let f32_eng = Engine::from_params(&dims, "partial", &p, Precision::F32, 4).unwrap();
        let i8_eng = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap();
        let mut rng = Pcg64::seeded(5);
        let feats = Tensor::randn(&[32, 8], 0.7, &mut rng);
        let mut bda = Breakdown::default();
        let mut bdb = Breakdown::default();
        let (_, ra) = f32_eng.transcribe(&feats, &mut bda).unwrap();
        let (_, rb) = i8_eng.transcribe(&feats, &mut bdb).unwrap();
        let mut diff = 0.0f32;
        let mut n = 0usize;
        for (a, b) in ra.iter().zip(&rb) {
            for (x, y) in a.iter().zip(b) {
                diff += (x - y).abs();
                n += 1;
            }
        }
        let mean = diff / n as f32;
        assert!(mean < 0.25, "mean logprob diff {mean}");
        // int8 model is ~4x smaller
        let ratio = f32_eng.model_bytes() as f64 / i8_eng.model_bytes() as f64;
        assert!(ratio > 3.0, "size ratio {ratio}");
    }

    #[test]
    fn factored_engine_matches_dense_materialization() {
        let dims = tiny_dims();
        let pf = tiny_params(&dims, true, 6);
        // materialize dense params from the factors
        let mut pd = ParamSet::new();
        for (k, v) in pf.iter() {
            if k.ends_with("_u") {
                let base = k.trim_end_matches("_u");
                let w = pf
                    .get(&format!("{base}_u"))
                    .unwrap()
                    .matmul(pf.get(&format!("{base}_v")).unwrap())
                    .unwrap();
                pd.set(format!("{base}_w"), w);
            } else if !k.ends_with("_v") {
                pd.set(k.clone(), v.clone());
            }
        }
        let ef = Engine::from_params(&dims, "partial", &pf, Precision::F32, 4).unwrap();
        let ed = Engine::from_params(&dims, "unfactored", &pd, Precision::F32, 4).unwrap();
        let mut rng = Pcg64::seeded(7);
        let feats = Tensor::randn(&[16, 8], 0.5, &mut rng);
        let mut b1 = Breakdown::default();
        let mut b2 = Breakdown::default();
        let (_, ra) = ef.transcribe(&feats, &mut b1).unwrap();
        let (_, rb) = ed.transcribe(&feats, &mut b2).unwrap();
        for (a, b) in ra.iter().zip(&rb) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-3);
            }
        }
        // factored model does fewer MACs per step iff rank < min(m,n)/2;
        // here rank = min => more MACs, but bytes reflect the factors
        assert!(ef.macs_per_step() > 0 && ed.macs_per_step() > 0);
    }

    #[test]
    fn engine_from_entries_bit_identical_to_from_params() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 12);
        // artifact-style entries: weights quantized once at build, biases f32
        let mut entries = BTreeMap::new();
        for (name, t) in p.iter() {
            if name.ends_with("_b") {
                entries.insert(name.clone(), Entry::F32(t.clone()));
            } else {
                entries.insert(name.clone(), Entry::I8(quantize(t)));
            }
        }
        let ea = Engine::from_entries(&dims, &entries, 4).unwrap();
        let ep = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap();
        assert_eq!(ea.precision, Precision::Int8);
        assert_eq!(ea.model_bytes(), ep.model_bytes());
        assert_eq!(ea.macs_per_step(), ep.macs_per_step());
        let mut rng = Pcg64::seeded(13);
        let feats = Tensor::randn(&[24, 8], 0.7, &mut rng);
        let mut b1 = Breakdown::default();
        let mut b2 = Breakdown::default();
        let (ta, ra) = ea.transcribe(&feats, &mut b1).unwrap();
        let (tb, rb) = ep.transcribe(&feats, &mut b2).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(ra, rb, "entry-built engine must decode bit-identically");
    }

    #[test]
    fn from_entries_rejects_missing_and_i8_bias() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 14);
        let mut entries = BTreeMap::new();
        for (name, t) in p.iter() {
            entries.insert(name.clone(), Entry::F32(t.clone()));
        }
        entries.remove("fc_b");
        assert!(Engine::from_entries(&dims, &entries, 4).is_err());
        entries.insert("fc_b".into(), Entry::I8(quantize(&Tensor::zeros(&[dims.fc_dim]))));
        assert!(Engine::from_entries(&dims, &entries, 4).is_err());
    }

    #[test]
    fn from_entries_rejects_mis_dimensioned_entries() {
        // same layer *counts* but different widths than dims: must be a
        // clean Error::Checkpoint at construction, not a GEMM panic later
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 17);
        let mut wide = tiny_dims();
        wide.fc_dim = dims.fc_dim + 2;
        let mut entries = BTreeMap::new();
        for (name, t) in p.iter() {
            entries.insert(name.clone(), Entry::F32(t.clone()));
        }
        let e = Engine::from_entries(&wide, &entries, 4).unwrap_err();
        assert!(matches!(e, Error::Checkpoint(_)), "expected checkpoint error, got {e:?}");
    }

    #[test]
    fn from_entries_rejects_layers_beyond_dims() {
        // a checkpoint with one more GRU layer than `dims` describes must
        // fail loudly instead of silently dropping the extra layer
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 15);
        let mut entries = BTreeMap::new();
        for (name, t) in p.iter() {
            entries.insert(name.clone(), Entry::F32(t.clone()));
        }
        let mut rng = Pcg64::seeded(16);
        entries.insert("rec2_w".into(), Entry::F32(Tensor::glorot(9, 3, &mut rng)));
        let e = Engine::from_entries(&dims, &entries, 4).unwrap_err();
        assert!(e.to_string().contains("rec2_w"), "should name the orphan entry: {e}");
    }

    #[test]
    fn joint_scheme_rejected() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 8);
        assert!(Engine::from_params(&dims, "joint", &p, Precision::F32, 4).is_err());
    }

    #[test]
    fn backend_switch_is_bit_identical_on_int8() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 20);
        let mut rng = Pcg64::seeded(21);
        let feats = Tensor::randn(&[24, 8], 0.7, &mut rng);
        let base = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4)
            .unwrap()
            .with_backend(BackendSel::Scalar)
            .unwrap();
        let mut b0 = Breakdown::default();
        let (t0, r0) = base.transcribe(&feats, &mut b0).unwrap();
        for sel in [BackendSel::Blocked, BackendSel::Auto] {
            let eng = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4)
                .unwrap()
                .with_backend(sel)
                .unwrap();
            let mut bd = Breakdown::default();
            let (t, r) = eng.transcribe(&feats, &mut bd).unwrap();
            assert_eq!(t, t0, "{sel} transcript");
            assert_eq!(r, r0, "{sel} must be bit-identical to scalar on int8");
        }
    }

    #[test]
    fn int4_engine_tracks_f32_and_halves_int8_bytes() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 30);
        let f32_eng = Engine::from_params(&dims, "partial", &p, Precision::F32, 4).unwrap();
        let i8_eng = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap();
        let i4_eng = Engine::from_params(&dims, "partial", &p, Precision::Int4, 4).unwrap();
        let mut rng = Pcg64::seeded(31);
        let feats = Tensor::randn(&[32, 8], 0.7, &mut rng);
        let mut bda = Breakdown::default();
        let mut bdb = Breakdown::default();
        let (_, ra) = f32_eng.transcribe(&feats, &mut bda).unwrap();
        let (_, rb) = i4_eng.transcribe(&feats, &mut bdb).unwrap();
        let mut diff = 0.0f32;
        let mut n = 0usize;
        for (a, b) in ra.iter().zip(&rb) {
            for (x, y) in a.iter().zip(b) {
                diff += (x - y).abs();
                n += 1;
            }
        }
        let mean = diff / n as f32;
        // 4-bit per-group quantization is coarser than int8 but must stay
        // in the same ballpark on a tiny random net
        assert!(mean < 0.6, "mean logprob diff {mean}");
        // weight payload: nibbles + per-group scales land under int8 even
        // on these tiny matrices, where every row is shorter than one
        // scale group so the scale overhead is at its worst case (real
        // layer widths k ≥ 256 approach the asymptotic ~1.8×)
        let ratio = i8_eng.model_bytes() as f64 / i4_eng.model_bytes() as f64;
        assert!(ratio > 1.1, "int8/int4 size ratio {ratio}");
        assert!(i4_eng.model_bytes() < f32_eng.model_bytes() / 3);
    }

    #[test]
    fn backend_switch_is_bit_identical_on_int4() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 32);
        let mut rng = Pcg64::seeded(33);
        let feats = Tensor::randn(&[24, 8], 0.7, &mut rng);
        let base = Engine::from_params(&dims, "partial", &p, Precision::Int4, 4)
            .unwrap()
            .with_backend(BackendSel::Scalar)
            .unwrap();
        let mut b0 = Breakdown::default();
        let (t0, r0) = base.transcribe(&feats, &mut b0).unwrap();
        for sel in [BackendSel::Blocked, BackendSel::Auto] {
            for fused in [true, false] {
                let eng = Engine::from_params(&dims, "partial", &p, Precision::Int4, 4)
                    .unwrap()
                    .with_backend(sel)
                    .unwrap()
                    .with_fused_gates(fused);
                let mut bd = Breakdown::default();
                let (t, r) = eng.transcribe(&feats, &mut bd).unwrap();
                assert_eq!(t, t0, "{sel} fused={fused} transcript");
                assert_eq!(r, r0, "{sel} fused={fused} must be bit-identical on int4");
            }
        }
    }

    #[test]
    fn engine_from_int4_entries_bit_identical_to_from_params() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 34);
        let mut entries = BTreeMap::new();
        for (name, t) in p.iter() {
            if name.ends_with("_b") {
                entries.insert(name.clone(), Entry::F32(t.clone()));
            } else {
                entries.insert(name.clone(), Entry::I4(quantize4(t)));
            }
        }
        let ea = Engine::from_entries(&dims, &entries, 4).unwrap();
        let ep = Engine::from_params(&dims, "partial", &p, Precision::Int4, 4).unwrap();
        assert_eq!(ea.precision, Precision::Int4);
        assert_eq!(ea.model_bytes(), ep.model_bytes());
        let mut rng = Pcg64::seeded(35);
        let feats = Tensor::randn(&[24, 8], 0.7, &mut rng);
        let mut b1 = Breakdown::default();
        let mut b2 = Breakdown::default();
        let (ta, ra) = ea.transcribe(&feats, &mut b1).unwrap();
        let (tb, rb) = ep.transcribe(&feats, &mut b2).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(ra, rb, "int4 entry-built engine must decode bit-identically");
    }

    #[test]
    fn scratch_stops_growing_after_warmup() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 22);
        let eng = Engine::from_params(&dims, "partial", &p, Precision::Int8, 2).unwrap();
        let mut state = eng.new_state();
        let mut bd = Breakdown::default();
        let mut rng = Pcg64::seeded(23);
        let block = eng.block_raw_len();
        let feats = Tensor::randn(&[4 * block / 8, 8], 0.7, &mut rng);
        eng.stream(&mut state, feats.data(), &mut bd).unwrap(); // warm
        let fp = state.scratch_footprint();
        assert!(fp > 0);
        for _ in 0..5 {
            eng.buffer_frames(&mut state, &feats.data()[..block], &mut bd);
            assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        }
        assert_eq!(state.scratch_footprint(), fp, "steady state must not grow the arena");
        assert_eq!(state.scratch_grow_events(), 0);
    }

    #[test]
    fn frame_confidence_orders_posteriors() {
        // a near-one-hot log-softmax row is maximally confident
        let mut peaked = vec![-20.0f32; 10];
        peaked[3] = -1e-6;
        // uniform posterior: zero margin and maximal entropy
        let uniform = vec![-(10f32.ln()); 10];
        let hi = frame_confidence(&peaked);
        let lo = frame_confidence(&uniform);
        assert!(hi > lo, "peaked ({hi}) must beat uniform ({lo})");
        assert!(lo.abs() < 1e-6, "uniform confidence is ~0, got {lo}");
        assert!(hi > 1.0, "near-one-hot margin dominates, got {hi}");
        // degenerate single-symbol rows never escalate
        assert_eq!(frame_confidence(&[0.0]), f64::INFINITY);
    }

    #[test]
    fn block_confidence_is_worst_frame() {
        let mut peaked = vec![-20.0f32; 5];
        peaked[0] = -1e-6;
        let uniform = vec![-(5f32.ln()); 5];
        let t = Tensor::new(&[2, 5], [peaked.clone(), uniform.clone()].concat()).unwrap();
        let worst = block_confidence(&t);
        assert!((worst - frame_confidence(&uniform)).abs() < 1e-12);
        assert!(worst < frame_confidence(&peaked));
    }

    #[test]
    fn checkpoint_rewind_restores_hidden_state() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 31);
        let eng = Engine::from_params(&dims, "partial", &p, Precision::Int8, 2).unwrap();
        let mut state = eng.new_state();
        let mut bd = Breakdown::default();
        let mut rng = Pcg64::seeded(32);
        let block = eng.block_raw_len();
        let feats = Tensor::randn(&[2 * block / 8, 8], 0.7, &mut rng);
        // advance one block so h is non-trivial, then snap
        eng.buffer_frames(&mut state, feats.data(), &mut bd);
        assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        state.snap_checkpoint();
        let snapped: Vec<Vec<f32>> = state.h.iter().map(|t| t.data().to_vec()).collect();
        // advance again (mutates h), rewind, and the snap must be back
        assert!(eng.pump_block(&mut state, &mut bd).unwrap());
        assert!(state.h.iter().zip(&snapped).any(|(h, s)| h.data() != s.as_slice()));
        state.rewind_to_checkpoint();
        for (h, s) in state.h.iter().zip(&snapped) {
            assert_eq!(h.data(), s.as_slice(), "rewind must be bit-exact");
        }
    }

    #[test]
    fn state_compatible_matches_layer_maps() {
        let dims = tiny_dims();
        let p = tiny_params(&dims, true, 33);
        let a = Engine::from_params(&dims, "partial", &p, Precision::Int8, 2).unwrap();
        let b = Engine::from_params(&dims, "partial", &p, Precision::F32, 2).unwrap();
        assert!(a.state_compatible(&b), "precision may differ across rungs");
        let c = Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap();
        assert!(!a.state_compatible(&c), "time batch must agree");
        let mut dims2 = tiny_dims();
        dims2.gru_dims[0] += 2;
        let p2 = tiny_params(&dims2, true, 33);
        let d = Engine::from_params(&dims2, "partial", &p2, Precision::Int8, 2).unwrap();
        assert!(!a.state_compatible(&d), "hidden widths must agree");
    }
}

//! Server-path serving simulator (the Table-2 "GPU server" row and the
//! batching-vs-latency trade-off of §4).
//!
//! A discrete-event simulation driven by *measured* execution times: batch
//! arrivals follow a seeded Poisson process, a dynamic batcher groups up
//! to `max_batch` queued requests (or whatever arrived within the batching
//! window), and each batch is actually executed through the PJRT eval
//! artifact — so service times are real, only the arrival clock is
//! simulated.  This mirrors how the paper's server deployment batches
//! independent user streams, in contrast to the single-user embedded path
//! ([`crate::infer`]).

use crate::data::Utterance;
use crate::error::{Error, Result};
use crate::metricsx::Histogram;
use crate::model::ParamSet;
use crate::runtime::Runtime;
use crate::train::Evaluator;
use crate::prng::Pcg64;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// mean request arrival rate (utterances / second)
    pub arrival_rate: f64,
    /// maximum dynamic batch size (the eval artifact's batch is the cap)
    pub max_batch: usize,
    /// batching window: wait at most this long to fill a batch (seconds)
    pub window: f64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { arrival_rate: 20.0, max_batch: 8, window: 0.05, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub throughput: f64,
    pub mean_batch: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_service: f64,
    /// wall-clock seconds actually spent executing batches
    pub busy_secs: f64,
    /// simulated span from first arrival to last completion
    pub span_secs: f64,
}

/// Run the serving simulation over `utts` (one request per utterance).
pub fn simulate(
    rt: &Runtime,
    eval_artifact: &str,
    params: &ParamSet,
    utts: &[Utterance],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no requests"));
    }
    let eval = Evaluator::new(rt, eval_artifact)?;
    let mut rng = Pcg64::seeded(cfg.seed);

    // Poisson arrivals: exponential inter-arrival gaps.
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for _ in 0..utts.len() {
        t += -rng.uniform().max(1e-12).ln() / cfg.arrival_rate;
        arrivals.push(t);
    }

    let mut lat = Histogram::new();
    let mut clock = 0.0f64; // simulated time
    let mut busy = 0.0f64;
    let mut served = 0usize;
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut i = 0usize;

    while i < utts.len() {
        // server idle: jump to next arrival if queue empty
        if clock < arrivals[i] {
            clock = arrivals[i];
        }
        // collect the batch: everything that has arrived, plus anything
        // arriving within the window, up to max_batch
        let deadline = clock + cfg.window;
        let mut j = i;
        while j < utts.len() && j - i < cfg.max_batch && arrivals[j] <= deadline {
            j += 1;
        }
        // if we waited for the window, the clock advances to the last
        // arrival we accepted (or the full window if the batch is full)
        let batch: Vec<&Utterance> = utts[i..j].iter().collect();
        if j - i == cfg.max_batch {
            clock = clock.max(arrivals[j - 1]);
        } else if j < utts.len() {
            clock = deadline;
        } else {
            clock = clock.max(arrivals[j - 1]);
        }

        // execute for real
        let owned: Vec<Utterance> = batch.iter().map(|u| (*u).clone()).collect();
        let t0 = std::time::Instant::now();
        let _ = eval.logprobs(params, &owned)?;
        let service = t0.elapsed().as_secs_f64();
        busy += service;
        clock += service;
        for k in i..j {
            lat.record(clock - arrivals[k]);
        }
        batch_sizes.push(j - i);
        served += j - i;
        i = j;
    }

    let span = clock - arrivals[0];
    Ok(ServeReport {
        requests: served,
        throughput: served as f64 / span.max(1e-9),
        mean_batch: batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len().max(1) as f64,
        p50_latency: lat.percentile(0.5),
        p95_latency: lat.percentile(0.95),
        p99_latency: lat.percentile(0.99),
        mean_service: busy / batch_sizes.len().max(1) as f64,
        busy_secs: busy,
        span_secs: span,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.arrival_rate > 0.0 && c.max_batch >= 1 && c.window >= 0.0);
    }

    // end-to-end serving tests live in rust/tests/integration.rs (they
    // need compiled artifacts).
}

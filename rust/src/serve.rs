//! Serving: concurrent utterance streams over the embedded engine, plus
//! the PJRT whole-utterance batcher for the Table-2 "GPU server" row.
//!
//! The primary path is [`stream_serve`]: a Poisson arrival process opens
//! **real concurrent decode sessions** on a [`StreamPool`] and streams
//! each utterance in client-sized chunks, so the pool's lock-stepped
//! recurrent GEMMs run at the batch the load actually produces (m = 1–4
//! is the paper's §4 sweet spot).  Arrival clocks are simulated; every
//! service interval is measured wall-clock on the real kernels, and the
//! report carries per-stream latency percentiles and a time-weighted
//! pool-occupancy histogram (DESIGN.md §6).
//!
//! [`ladder_serve`] is the adaptive-fidelity path (DESIGN.md §8): one
//! [`StreamPool`] per rank-ladder tier from a [`Registry`], with a
//! [`FidelityController`] routing *new* sessions down the ladder when the
//! routed tier's p99 breaches its target or its pool saturates, and back
//! up once the load drains.
//!
//! [`simulate`] keeps the earlier discrete-event *whole-utterance*
//! batcher: requests are padded into a static PJRT eval batch (the
//! server-side deployment of Prabhavalkar et al.), the contrast case to
//! per-frame stream pooling.  It needs the `xla` feature + artifacts.

use std::sync::Arc;

use crate::controller::{ControllerConfig, FidelityController, ShiftEvent};
use crate::data::Utterance;
use crate::error::{Error, Result};
use crate::infer::{Breakdown, Engine};
use crate::metricsx::{Histogram, LatencySummary, OccupancyTracker};
use crate::model::ParamSet;
use crate::prng::Pcg64;
use crate::registry::Registry;
use crate::runtime::Runtime;
use crate::stream::StreamPool;
use crate::train::Evaluator;

// ---------------------------------------------------------------------------
// Stream-pool serving (embedded path, pure Rust).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct StreamServeConfig {
    /// mean session arrival rate (utterances / second)
    pub arrival_rate: f64,
    /// concurrent session slots (the lock-step batch ceiling)
    pub pool_size: usize,
    /// raw feature frames a client delivers per engine tick
    pub chunk_frames: usize,
    pub seed: u64,
}

impl Default for StreamServeConfig {
    fn default() -> Self {
        StreamServeConfig { arrival_rate: 8.0, pool_size: 4, chunk_frames: 16, seed: 0 }
    }
}

/// Report from a [`stream_serve`] run.
#[derive(Clone, Debug)]
pub struct StreamServeReport {
    pub sessions: usize,
    pub pool_size: usize,
    /// GEMM backend the engine executed on (after `auto` resolution)
    pub backend: &'static str,
    /// completed sessions per simulated second
    pub throughput: f64,
    /// arrival → final-transcript latency across sessions
    pub session_latency: LatencySummary,
    /// time-weighted pool occupancy over the run
    pub occupancy: OccupancyTracker,
    /// mean stream-batch the pooled recurrent GEMMs actually ran at
    pub mean_rec_batch: f64,
    /// wall-clock actually spent in the engine
    pub busy_secs: f64,
    /// simulated span from first arrival to last completion
    pub span_secs: f64,
    /// accumulated engine component timing
    pub breakdown: Breakdown,
    /// (reference, hypothesis) per completed session, arrival order
    pub transcripts: Vec<(String, String)>,
}

/// One in-flight session: which utterance it is streaming and how far the
/// "client" has gotten.
struct InFlight {
    id: crate::stream::StreamId,
    utt: usize,
    off: usize,
    arrived: f64,
}

/// Serve `utts` as concurrent streaming sessions over a [`StreamPool`].
///
/// Arrivals follow a seeded Poisson process.  Each engine tick, every
/// live session receives its next `chunk_frames` frames, the pool pumps
/// (one lock-stepped batch-m advance over all runnable streams), and
/// sessions whose audio is exhausted are closed (tail flush + transcript).
/// The simulated clock advances by the *measured* tick time, so latency
/// and occupancy numbers reflect the real kernels under the offered load.
pub fn stream_serve(
    engine: Arc<Engine>,
    utts: &[Utterance],
    cfg: &StreamServeConfig,
) -> Result<StreamServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no sessions"));
    }
    if cfg.pool_size == 0 || cfg.chunk_frames == 0 {
        return Err(Error::Config("pool_size and chunk_frames must be >= 1".into()));
    }
    let feat = engine.feat_dim();
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for _ in 0..utts.len() {
        t += -rng.uniform().max(1e-12).ln() / cfg.arrival_rate;
        arrivals.push(t);
    }

    let mut pool = StreamPool::new(engine, cfg.pool_size);
    let mut active: Vec<InFlight> = Vec::new();
    let mut next = 0usize;
    let mut clock = 0.0f64;
    let mut busy = 0.0f64;
    let mut bd = Breakdown::default();
    let mut lat = Histogram::new();
    let mut occupancy = OccupancyTracker::new();
    let mut transcripts: Vec<(usize, String, String)> = Vec::new();

    while next < utts.len() || !active.is_empty() {
        // admit queued arrivals while slots are free
        while next < utts.len() && arrivals[next] <= clock && !pool.is_full() {
            let id = pool.open()?;
            active.push(InFlight { id, utt: next, off: 0, arrived: arrivals[next] });
            next += 1;
        }
        if active.is_empty() {
            // idle server: record the empty-pool gap, jump to the arrival
            let target = clock.max(arrivals[next]);
            if target > clock {
                occupancy.record(0, target - clock);
            }
            clock = target;
            continue;
        }

        // one engine tick: clients deliver a chunk each, the pool pumps,
        // finished sessions close — all measured as one service interval
        let occ_now = active.len();
        let t0 = std::time::Instant::now();
        for a in &mut active {
            let data = utts[a.utt].feats.data();
            let end = (a.off + cfg.chunk_frames * feat).min(data.len());
            if a.off < end {
                pool.push_frames(a.id, &data[a.off..end])?;
                a.off = end;
            }
        }
        pool.pump(&mut bd)?;
        let mut finished: Vec<(InFlight, String)> = Vec::new();
        let mut i = 0;
        while i < active.len() {
            if active[i].off >= utts[active[i].utt].feats.data().len() {
                let a = active.swap_remove(i);
                let closed = pool.close(a.id, &mut bd)?;
                finished.push((a, closed.transcript));
            } else {
                i += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        busy += dt;
        clock += dt;
        occupancy.record(occ_now, dt);
        for (a, hyp) in finished {
            lat.record(clock - a.arrived);
            transcripts.push((a.utt, utts[a.utt].text.clone(), hyp));
        }
    }

    // sessions complete out of order under churn; report in arrival order
    transcripts.sort_by_key(|(utt, _, _)| *utt);
    let transcripts: Vec<(String, String)> =
        transcripts.into_iter().map(|(_, reference, hyp)| (reference, hyp)).collect();

    let span = clock - arrivals[0];
    Ok(StreamServeReport {
        sessions: utts.len(),
        pool_size: cfg.pool_size,
        backend: pool.engine().backend_name(),
        throughput: utts.len() as f64 / span.max(1e-9),
        session_latency: lat.summary(),
        occupancy,
        mean_rec_batch: pool.stats.mean_rec_batch(),
        busy_secs: busy,
        span_secs: span,
        breakdown: bd,
        transcripts,
    })
}

// ---------------------------------------------------------------------------
// Adaptive-fidelity ladder serving (registry + controller, DESIGN.md §8).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct LadderServeConfig {
    /// steady-state session arrival rate (utterances / second)
    pub base_rate: f64,
    /// arrival rate inside the ramp window
    pub ramp_rate: f64,
    /// session indices `[start, end)` arriving at `ramp_rate` — the
    /// synthetic load ramp the controller must absorb
    pub ramp_range: (usize, usize),
    /// session slots per fidelity tier
    pub pool_size: usize,
    /// raw feature frames a client delivers per engine tick
    pub chunk_frames: usize,
    pub seed: u64,
    pub controller: ControllerConfig,
}

impl Default for LadderServeConfig {
    fn default() -> Self {
        LadderServeConfig {
            base_rate: 4.0,
            ramp_rate: 1e5,
            ramp_range: (0, 0),
            pool_size: 4,
            chunk_frames: 16,
            seed: 0,
            controller: ControllerConfig::default(),
        }
    }
}

/// Per-tier slice of a [`LadderServeReport`].
#[derive(Clone, Debug)]
pub struct TierReport {
    pub tier: usize,
    pub tag: String,
    pub rank_frac: f64,
    /// scalar parameter count of the tier's variant
    pub params: usize,
    /// sessions admitted at this tier
    pub sessions: usize,
    /// arrival → final-transcript latency of those sessions
    pub latency: LatencySummary,
    /// time-weighted occupancy of this tier's pool
    pub occupancy: OccupancyTracker,
}

/// Report from a [`ladder_serve`] run.
#[derive(Clone, Debug)]
pub struct LadderServeReport {
    pub sessions: usize,
    pub pool_size: usize,
    /// GEMM backend every tier's engine executed on
    pub backend: &'static str,
    pub tiers: Vec<TierReport>,
    pub downshifts: u64,
    pub upshifts: u64,
    /// fidelity shifts in order (simulated clock, new tier)
    pub shifts: Vec<ShiftEvent>,
    /// admission tier per session, indexed by arrival order
    pub tier_of_session: Vec<usize>,
    pub throughput: f64,
    pub busy_secs: f64,
    pub span_secs: f64,
    pub breakdown: Breakdown,
}

/// One in-flight ladder session: which utterance, how far the client has
/// streamed it, and which tier admitted it.
struct InFlightTiered {
    id: crate::stream::StreamId,
    utt: usize,
    off: usize,
    arrived: f64,
    tier: usize,
}

/// Serve `utts` as concurrent streaming sessions across a rank ladder,
/// one [`StreamPool`] per tier, with the [`FidelityController`] routing
/// each *new* session to a tier (spilling further down the ladder when
/// the routed pool is full).  Arrival clocks are simulated with a
/// piecewise Poisson rate (the ramp); every service interval is measured
/// wall-clock on the real kernels, exactly like [`stream_serve`].
pub fn ladder_serve(
    registry: &Registry,
    utts: &[Utterance],
    cfg: &LadderServeConfig,
) -> Result<LadderServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no sessions"));
    }
    if cfg.pool_size == 0 || cfg.chunk_frames == 0 {
        return Err(Error::Config("pool_size and chunk_frames must be >= 1".into()));
    }
    if cfg.base_rate <= 0.0 || cfg.ramp_rate <= 0.0 {
        return Err(Error::Config("arrival rates must be positive".into()));
    }
    let tiers = registry.num_tiers();
    let feat = registry.dims.feat_dim;
    let mut ctl = FidelityController::new(tiers, cfg.controller.clone())?;

    let mut rng = Pcg64::seeded(cfg.seed);
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for i in 0..utts.len() {
        let rate = if i >= cfg.ramp_range.0 && i < cfg.ramp_range.1 {
            cfg.ramp_rate
        } else {
            cfg.base_rate
        };
        t += -rng.uniform().max(1e-12).ln() / rate;
        arrivals.push(t);
    }

    let mut pools: Vec<StreamPool> = registry
        .variants()
        .iter()
        .map(|v| StreamPool::new(v.engine.clone(), cfg.pool_size))
        .collect();
    let mut lat: Vec<Histogram> = (0..tiers).map(|_| Histogram::new()).collect();
    let mut occ: Vec<OccupancyTracker> = (0..tiers).map(|_| OccupancyTracker::new()).collect();
    let mut sessions_at: Vec<usize> = vec![0; tiers];
    let mut tier_of_session: Vec<usize> = vec![0; utts.len()];

    let mut active: Vec<InFlightTiered> = Vec::new();
    let mut next = 0usize;
    let mut clock = 0.0f64;
    let mut busy = 0.0f64;
    let mut bd = Breakdown::default();

    while next < utts.len() || !active.is_empty() {
        // admit queued arrivals: route to the controller's tier, spilling
        // down the ladder when that pool is full (never up — an overload
        // must not push extra load onto the expensive tiers)
        while next < utts.len() && arrivals[next] <= clock {
            let want = ctl.tier();
            let Some(tier) = (want..tiers).find(|&t| !pools[t].is_full()) else {
                break;
            };
            let id = pools[tier].open()?;
            active.push(InFlightTiered { id, utt: next, off: 0, arrived: arrivals[next], tier });
            tier_of_session[next] = tier;
            sessions_at[tier] += 1;
            next += 1;
        }
        if active.is_empty() {
            // idle server: the controller sees a drained system, the
            // occupancy trackers record the empty gap, the clock jumps
            ctl.observe(clock, 0.0);
            let target = clock.max(arrivals[next]);
            if target > clock {
                for o in occ.iter_mut() {
                    o.record(0, target - clock);
                }
            }
            clock = target;
            continue;
        }

        // one engine tick across every tier: clients deliver a chunk
        // each, busy pools pump, finished sessions close
        let occ_now: Vec<usize> = pools.iter().map(|p| p.active()).collect();
        let t0 = std::time::Instant::now();
        for a in &mut active {
            let data = utts[a.utt].feats.data();
            let end = (a.off + cfg.chunk_frames * feat).min(data.len());
            if a.off < end {
                pools[a.tier].push_frames(a.id, &data[a.off..end])?;
                a.off = end;
            }
        }
        for pool in pools.iter_mut() {
            if pool.active() > 0 {
                pool.pump(&mut bd)?;
            }
        }
        let mut finished: Vec<InFlightTiered> = Vec::new();
        let mut i = 0;
        while i < active.len() {
            if active[i].off >= utts[active[i].utt].feats.data().len() {
                let a = active.swap_remove(i);
                pools[a.tier].close(a.id, &mut bd)?;
                finished.push(a);
            } else {
                i += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        busy += dt;
        clock += dt;
        for (t, o) in occ.iter_mut().enumerate() {
            o.record(occ_now[t], dt);
        }
        for a in finished {
            let l = clock - a.arrived;
            lat[a.tier].record(l);
            ctl.record_latency(a.tier, l);
        }
        // control tick: the routed tier's pool is the admission signal
        ctl.observe(clock, pools[ctl.tier()].occupancy_frac());
    }

    let span = clock - arrivals[0];
    let tiers_report: Vec<TierReport> = (0..tiers)
        .map(|t| {
            let v = registry.tier(t);
            TierReport {
                tier: t,
                tag: v.info.tag.clone(),
                rank_frac: v.info.rank_frac,
                params: v.info.params,
                sessions: sessions_at[t],
                latency: lat[t].summary(),
                occupancy: occ[t].clone(),
            }
        })
        .collect();
    Ok(LadderServeReport {
        sessions: utts.len(),
        pool_size: cfg.pool_size,
        backend: registry.tier(0).engine.backend_name(),
        tiers: tiers_report,
        downshifts: ctl.downshifts,
        upshifts: ctl.upshifts,
        shifts: ctl.shifts().to_vec(),
        tier_of_session,
        throughput: utts.len() as f64 / span.max(1e-9),
        busy_secs: busy,
        span_secs: span,
        breakdown: bd,
    })
}

// ---------------------------------------------------------------------------
// Whole-utterance PJRT batcher (the server-row baseline; `xla` feature).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// mean request arrival rate (utterances / second)
    pub arrival_rate: f64,
    /// maximum dynamic batch size (the eval artifact's batch is the cap)
    pub max_batch: usize,
    /// batching window: wait at most this long to fill a batch (seconds)
    pub window: f64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { arrival_rate: 20.0, max_batch: 8, window: 0.05, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub throughput: f64,
    pub mean_batch: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_service: f64,
    /// wall-clock seconds actually spent executing batches
    pub busy_secs: f64,
    /// simulated span from first arrival to last completion
    pub span_secs: f64,
}

/// Run the whole-utterance serving simulation over `utts` (one request
/// per utterance) — batch arrivals are simulated, service times are real
/// PJRT executions.
pub fn simulate(
    rt: &Runtime,
    eval_artifact: &str,
    params: &ParamSet,
    utts: &[Utterance],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no requests"));
    }
    let eval = Evaluator::new(rt, eval_artifact)?;
    let mut rng = Pcg64::seeded(cfg.seed);

    // Poisson arrivals: exponential inter-arrival gaps.
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for _ in 0..utts.len() {
        t += -rng.uniform().max(1e-12).ln() / cfg.arrival_rate;
        arrivals.push(t);
    }

    let mut lat = Histogram::new();
    let mut clock = 0.0f64; // simulated time
    let mut busy = 0.0f64;
    let mut served = 0usize;
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut i = 0usize;

    while i < utts.len() {
        // server idle: jump to next arrival if queue empty
        if clock < arrivals[i] {
            clock = arrivals[i];
        }
        // collect the batch: everything that has arrived, plus anything
        // arriving within the window, up to max_batch
        let deadline = clock + cfg.window;
        let mut j = i;
        while j < utts.len() && j - i < cfg.max_batch && arrivals[j] <= deadline {
            j += 1;
        }
        // if we waited for the window, the clock advances to the last
        // arrival we accepted (or the full window if the batch is full)
        let batch: Vec<&Utterance> = utts[i..j].iter().collect();
        if j - i == cfg.max_batch {
            clock = clock.max(arrivals[j - 1]);
        } else if j < utts.len() {
            clock = deadline;
        } else {
            clock = clock.max(arrivals[j - 1]);
        }

        // execute for real
        let owned: Vec<Utterance> = batch.iter().map(|u| (*u).clone()).collect();
        let t0 = std::time::Instant::now();
        let _ = eval.logprobs(params, &owned)?;
        let service = t0.elapsed().as_secs_f64();
        busy += service;
        clock += service;
        for k in i..j {
            lat.record(clock - arrivals[k]);
        }
        batch_sizes.push(j - i);
        served += j - i;
        i = j;
    }

    let span = clock - arrivals[0];
    Ok(ServeReport {
        requests: served,
        throughput: served as f64 / span.max(1e-9),
        mean_batch: batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len().max(1) as f64,
        p50_latency: lat.percentile(0.5),
        p95_latency: lat.percentile(0.95),
        p99_latency: lat.percentile(0.99),
        mean_service: busy / batch_sizes.len().max(1) as f64,
        busy_secs: busy,
        span_secs: span,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusSpec, Dataset};
    use crate::infer::Precision;
    use crate::stream::{demo_dims, synthetic_params};

    #[test]
    fn default_configs_sane() {
        let c = ServeConfig::default();
        assert!(c.arrival_rate > 0.0 && c.max_batch >= 1 && c.window >= 0.0);
        let s = StreamServeConfig::default();
        assert!(s.arrival_rate > 0.0 && s.pool_size >= 1 && s.chunk_frames >= 1);
        let l = LadderServeConfig::default();
        assert!(l.base_rate > 0.0 && l.ramp_rate > 0.0 && l.pool_size >= 1);
        assert!(l.controller.low_water < l.controller.high_water);
    }

    #[test]
    fn stream_serve_reports_concurrent_sessions() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.25, 3);
        let engine =
            Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
        let data = Dataset::generate(CorpusSpec::standard(21), 0, 0, 6);
        let cfg = StreamServeConfig {
            arrival_rate: 1e6, // everyone arrives at once -> pool saturates
            pool_size: 3,
            chunk_frames: 16,
            seed: 1,
        };
        let r = stream_serve(engine, &data.test, &cfg).unwrap();
        assert_eq!(r.sessions, 6);
        assert_eq!(r.transcripts.len(), 6);
        assert!(!r.backend.is_empty(), "report must name the GEMM backend");
        assert!(r.throughput > 0.0);
        assert!(r.session_latency.p50 <= r.session_latency.p95);
        assert!(r.session_latency.p95 <= r.session_latency.p99);
        // at instant arrivals the pool must actually fill
        assert!(r.occupancy.max_occupancy() == 3, "max occ {}", r.occupancy.max_occupancy());
        assert!(r.mean_rec_batch > 1.5, "mean rec batch {}", r.mean_rec_batch);
        assert!(r.breakdown.frames > 0);
    }

    #[test]
    fn stream_serve_low_rate_stays_mostly_solo() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.25, 4);
        let engine =
            Arc::new(Engine::from_params(&dims, "partial", &p, Precision::F32, 4).unwrap());
        let data = Dataset::generate(CorpusSpec::standard(22), 0, 0, 4);
        // arrivals far apart relative to service time: occupancy ~1
        let cfg = StreamServeConfig {
            arrival_rate: 0.001,
            pool_size: 4,
            chunk_frames: 32,
            seed: 2,
        };
        let r = stream_serve(engine, &data.test, &cfg).unwrap();
        assert_eq!(r.sessions, 4);
        assert!(r.mean_rec_batch <= 1.0 + 1e-9);
        assert!(r.occupancy.mean() <= 1.0 + 1e-9);
    }

    // end-to-end PJRT serving tests live in rust/tests/integration.rs
    // (they need compiled artifacts + the `xla` feature).
}

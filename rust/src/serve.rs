//! Serving: concurrent utterance streams over the embedded engine — now
//! a sharded, multi-threaded runtime (DESIGN.md §9) — plus the PJRT
//! whole-utterance batcher for the Table-2 "GPU server" row.
//!
//! The primary path is [`stream_serve`]: a Poisson arrival process opens
//! **real concurrent decode sessions** across `--shards N` worker
//! threads (each owning its own [`StreamPool`](crate::stream::StreamPool) over the shared
//! `Arc<Engine>` plan), behind the admission router of
//! [`crate::shard`]: least-occupancy placement with per-shard
//! backpressure and spill, fed over bounded channels, with graceful
//! drain when the arrivals end.  Arrival clocks are simulated; every
//! round's service interval is measured wall-clock on the real kernels
//! running concurrently, and the report carries per-stream latency
//! percentiles and time-weighted occupancy both per shard and merged
//! cross-shard ([`Histogram::merge`]/[`OccupancyTracker::merge`]).
//!
//! Compatibility contract: with a fixed seed, `--shards 1` replays the
//! pre-shard serving loop decision for decision (same arrival schedule,
//! same admission order, same metrics recording), and **any** shard
//! count yields identical per-stream transcripts — placement never
//! changes decoding, because pooled decoding is bit-identical to
//! sequential decoding (`rust/tests/shard.rs`).
//!
//! [`ladder_serve`] is the adaptive-fidelity path (DESIGN.md §8): each
//! shard runs one [`StreamPool`](crate::stream::StreamPool) per rank-ladder tier from a
//! [`Registry`] plus its **own** [`FidelityController`] (per-shard
//! hysteresis), and the report merges every shard's shift log into one
//! clock-ordered, shard-tagged log.
//!
//! [`simulate`] keeps the earlier discrete-event *whole-utterance*
//! batcher: requests are padded into a static PJRT eval batch (the
//! server-side deployment of Prabhavalkar et al.), the contrast case to
//! per-frame stream pooling.  It needs the `xla` feature + artifacts.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::controller::{merge_shift_logs, ControllerConfig, FidelityController, ShiftEvent};
use crate::data::Utterance;
use crate::error::{Error, Result};
use crate::infer::{Breakdown, Engine};
use crate::jsonx::Json;
use crate::metricsx::{Histogram, LatencySummary, OccupancyTracker};
use crate::model::ParamSet;
use crate::obs::export::EXPORT_EVERY_ROUNDS;
use crate::obs::{
    self, trace, Event, EventKind, Journal, MetricsExporter, ObsReport, SloConfig, SloEngine,
    SloSummary, SpanSet, TraceBuilder, NO_SHARD,
};
use crate::prng::Pcg64;
use crate::registry::Registry;
use crate::runtime::Runtime;
use crate::shard::{run_sharded, run_sharded_with, sharded_arrivals, Admission};
use crate::stream::{CascadeCfg, PoolStats, StreamPool};
use crate::train::Evaluator;

// ---------------------------------------------------------------------------
// Stream-pool serving (embedded path, pure Rust, sharded).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct StreamServeConfig {
    /// mean session arrival rate (utterances / second, summed over the
    /// per-shard sub-processes)
    pub arrival_rate: f64,
    /// concurrent session slots per shard (the lock-step batch ceiling)
    pub pool_size: usize,
    /// raw feature frames a client delivers per engine tick
    pub chunk_frames: usize,
    /// worker shards (OS threads); 1 replays the unsharded loop exactly
    pub shards: usize,
    pub seed: u64,
    /// JSONL metrics snapshot file (`--metrics-out FILE`); None disables
    /// the exporter
    pub metrics_out: Option<String>,
    /// Chrome-trace / Perfetto JSON output file (`--trace-out FILE`);
    /// needs `--obs on` (the trace is assembled from the event journal)
    pub trace_out: Option<String>,
    /// latency/availability objective evaluated over completed sessions
    /// (`--slo-target MS`); None disables the burn-rate engine
    pub slo: Option<SloConfig>,
    /// whether an SLO breach steers the runtime (`--slo-actions on`):
    /// this path sheds admissions while breaching.  Off by default —
    /// observe and journal only, so determinism contracts are untouched
    pub slo_actions: bool,
    /// fixed simulated tick in seconds (`--fixed-tick-ms F`): the clock
    /// advances by exactly this every round instead of the measured wall
    /// time, making clocks — and the exported trace — deterministic
    pub tick_secs: Option<f64>,
}

impl Default for StreamServeConfig {
    fn default() -> Self {
        StreamServeConfig {
            arrival_rate: 8.0,
            pool_size: 4,
            chunk_frames: 16,
            shards: 1,
            seed: 0,
            metrics_out: None,
            trace_out: None,
            slo: None,
            slo_actions: false,
            tick_secs: None,
        }
    }
}

/// Cascade wiring for a ladder serve (`--cascade LOW:HIGH` resolved
/// against the registry by [`crate::registry::Registry::cascade_pair`]):
/// sessions admitted at `low_tier` decode through the confidence-gated
/// cascade, escalating breached blocks to `high_tier`'s rung.
#[derive(Clone, Copy, Debug)]
pub struct CascadePlan {
    /// tier every cascade block decodes on first (cheaper rung — the
    /// *higher* tier index)
    pub low_tier: usize,
    /// escalation target tier (the higher-fidelity rung)
    pub high_tier: usize,
    /// worst-frame confidence below which a block escalates
    pub threshold: f64,
}

/// Cascade outcome of a serve: the gate counters plus the analytic
/// effective-FLOPs accounting the text and `--json` reports print.
#[derive(Clone, Debug)]
pub struct CascadeSummary {
    /// configured escalation threshold (the controller may have steered
    /// the live value below this under SLO pressure)
    pub threshold: f64,
    /// blocks that went through the confidence gate
    pub stream_blocks: u64,
    /// the subset that escalated to the high rung
    pub escalated_blocks: u64,
    /// `escalated_blocks / stream_blocks` (0 when no blocks ran)
    pub escalation_rate: f64,
    /// GFLOP per raw frame of pure low-rung decoding
    pub gflops_low: f64,
    /// GFLOP per raw frame of pure high-rung decoding
    pub gflops_high: f64,
    /// effective GFLOP per raw frame at the observed escalation rate:
    /// low + rate × (high − shared frontend), the cascade's actual
    /// compute draw
    pub gflops_effective: f64,
    /// escalation-threshold halvings the controller took under pressure
    pub threshold_cuts: u64,
    /// threshold doublings the controller took on drain
    pub threshold_restores: u64,
}

impl CascadeSummary {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("threshold", Json::num(self.threshold)),
            ("stream_blocks", Json::num(self.stream_blocks as f64)),
            ("escalated_blocks", Json::num(self.escalated_blocks as f64)),
            ("escalation_rate", Json::num(self.escalation_rate)),
            ("gflops_low", Json::num(self.gflops_low)),
            ("gflops_high", Json::num(self.gflops_high)),
            ("gflops_effective", Json::num(self.gflops_effective)),
            ("threshold_cuts", Json::num(self.threshold_cuts as f64)),
            ("threshold_restores", Json::num(self.threshold_restores as f64)),
        ])
    }
}

/// Analytic effective-FLOPs accounting for a finished cascade serve:
/// every gated block pays the low rung; escalated blocks additionally
/// pay the high rung, minus the conv frontend when the pair shares it
/// (the pooled path reuses the low rung's frontend activations).
fn cascade_summary(
    low: &Engine,
    cc: &CascadeCfg,
    stats: &PoolStats,
    threshold_cuts: u64,
    threshold_restores: u64,
) -> CascadeSummary {
    let stride = low.total_stride() as f64;
    let gflops = |macs: u64| 2.0 * macs as f64 / stride / 1e9;
    let esc_macs = if cc.shared_frontend {
        cc.high.macs_per_step() - cc.high.frontend_macs_per_step()
    } else {
        cc.high.macs_per_step()
    };
    let rate = stats.escalation_rate();
    CascadeSummary {
        threshold: cc.threshold,
        stream_blocks: stats.stream_blocks,
        escalated_blocks: stats.escalated_blocks,
        escalation_rate: rate,
        gflops_low: gflops(low.macs_per_step()),
        gflops_high: gflops(cc.high.macs_per_step()),
        gflops_effective: gflops(low.macs_per_step()) + rate * gflops(esc_macs),
        threshold_cuts,
        threshold_restores,
    }
}

/// Shared validation for the trace/SLO/fixed-tick extras both serve
/// paths accept.
fn validate_obs_extras(
    trace_out: &Option<String>,
    slo: &Option<SloConfig>,
    slo_actions: bool,
    tick_secs: Option<f64>,
) -> Result<()> {
    if trace_out.is_some() && !obs::enabled() {
        return Err(Error::Config(
            "--trace-out needs --obs on (the trace is assembled from the event journal)".into(),
        ));
    }
    if slo_actions && slo.is_none() {
        return Err(Error::Config("--slo-actions on needs --slo-target".into()));
    }
    if let Some(t) = tick_secs {
        if !(t > 0.0) {
            return Err(Error::Config("--fixed-tick-ms must be > 0".into()));
        }
    }
    Ok(())
}

/// First JSONL row of a serve with an exporter attached: the topology
/// and SLO the run was held to, so `obs-report` can analyze the file
/// without the command line that produced it.
fn write_config_row(
    ex: &mut MetricsExporter,
    serve: &str,
    shards: usize,
    pool_size: usize,
    chunk_frames: usize,
    slo: &Option<SloConfig>,
    slo_actions: bool,
) -> Result<()> {
    let mut body = vec![
        ("serve", Json::str(serve)),
        ("shards", Json::num(shards as f64)),
        ("pool_size", Json::num(pool_size as f64)),
        ("chunk_frames", Json::num(chunk_frames as f64)),
        ("slo_actions", Json::Bool(slo_actions)),
    ];
    if let Some(s) = slo {
        body.push(("slo_target", Json::num(s.target_p99)));
        body.push(("slo_deadline", Json::num(s.deadline)));
        body.push(("slo_budget", Json::num(s.budget)));
    }
    ex.write_snapshot("serve-config", 0.0, body)
}

/// Per-shard slice of a serving report.
#[derive(Clone, Debug)]
pub struct ShardSlice {
    pub shard: usize,
    /// sessions this shard served
    pub sessions: usize,
    /// arrival → final-transcript latency of those sessions
    pub latency: LatencySummary,
    /// time-weighted occupancy of this shard (summed over its tiers)
    pub occupancy: OccupancyTracker,
}

impl ShardSlice {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::num(self.shard as f64)),
            ("sessions", Json::num(self.sessions as f64)),
            ("latency", self.latency.to_json()),
            ("occupancy", self.occupancy.to_json()),
        ])
    }
}

/// Report from a [`stream_serve`] run.
#[derive(Clone, Debug)]
pub struct StreamServeReport {
    pub sessions: usize,
    pub pool_size: usize,
    /// worker shards the serve ran on
    pub shards: usize,
    /// GEMM backend the engine executed on (after `auto` resolution)
    pub backend: &'static str,
    /// numeric mode the engine served at ("f32", "int8" or "int4")
    pub precision: &'static str,
    /// whether the recurrent GEMM routed through the fused gate kernel
    pub fused_gates: bool,
    /// completed sessions per simulated second
    pub throughput: f64,
    /// arrival → final-transcript latency across all sessions
    /// (per-shard histograms merged at the sample level)
    pub session_latency: LatencySummary,
    /// time-weighted occupancy merged across shards
    pub occupancy: OccupancyTracker,
    /// per-shard latency/occupancy slices
    pub per_shard: Vec<ShardSlice>,
    /// shard that served each session, indexed by arrival order
    pub shard_of_session: Vec<usize>,
    /// mean stream-batch the pooled recurrent GEMMs actually ran at
    pub mean_rec_batch: f64,
    /// aggregate wall-clock spent in the engine across all shard
    /// threads (CPU-seconds; can exceed `span_secs` when shards > 1)
    pub busy_secs: f64,
    /// simulated span from first arrival to last completion
    pub span_secs: f64,
    /// accumulated engine component timing, summed across shards
    pub breakdown: Breakdown,
    /// (reference, hypothesis) per completed session, arrival order
    pub transcripts: Vec<(String, String)>,
    /// flight-recorder data (spans, kernel counters, event journal) —
    /// Some only when the serve ran with `--obs on`
    pub obs: Option<ObsReport>,
    /// SLO attainment / burn-rate summary — Some only when the serve ran
    /// with `--slo-target`
    pub slo: Option<SloSummary>,
    /// cascade gate counters and effective-FLOPs accounting — Some only
    /// when the serve ran with `--cascade`
    pub cascade: Option<CascadeSummary>,
}

impl StreamServeReport {
    /// Machine-readable report (`stream-serve --json`): everything CI
    /// and the bench harness parse instead of grepping text.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema_version", Json::num(obs::SCHEMA_VERSION as f64)),
            ("kind", Json::str("stream-serve")),
            ("sessions", Json::num(self.sessions as f64)),
            ("pool_size", Json::num(self.pool_size as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("backend", Json::str(self.backend)),
            ("precision", Json::str(self.precision)),
            ("fused_gates", Json::Bool(self.fused_gates)),
            ("throughput", Json::num(self.throughput)),
            ("busy_secs", Json::num(self.busy_secs)),
            ("span_secs", Json::num(self.span_secs)),
            ("mean_rec_batch", Json::num(self.mean_rec_batch)),
            ("latency", self.session_latency.to_json()),
            ("occupancy", self.occupancy.to_json()),
            ("per_shard", Json::Arr(self.per_shard.iter().map(|s| s.to_json()).collect())),
            (
                "shard_of_session",
                Json::Arr(
                    self.shard_of_session.iter().map(|&s| Json::num(s as f64)).collect(),
                ),
            ),
        ]);
        if let Some(c) = &self.cascade {
            fields.push(("cascade", c.to_json()));
        }
        if let Some(s) = &self.slo {
            fields.push(("slo", s.to_json()));
        }
        if let Some(o) = &self.obs {
            fields.push(("obs", o.to_json()));
        }
        Json::obj(fields)
    }
}

/// Serve `utts` as concurrent streaming sessions across `cfg.shards`
/// worker threads, each running a [`StreamPool`](crate::stream::StreamPool) over the shared engine.
///
/// Arrivals are the superposition of per-shard seeded Poisson processes
/// ([`sharded_arrivals`]; with one shard this is the historical
/// root-seeded schedule, bit for bit).  Each round the router admits
/// queued arrivals to the least-occupied shard with a free slot
/// (spilling to the next shard under backpressure), every busy shard
/// runs one lock-stepped tick concurrently, and the simulated clock
/// advances by the measured wall-clock of the parallel round — so
/// latency and occupancy numbers reflect the real kernels, on all
/// cores, under the offered load.
pub fn stream_serve(
    engine: Arc<Engine>,
    utts: &[Utterance],
    cfg: &StreamServeConfig,
) -> Result<StreamServeReport> {
    stream_serve_cascade(engine, None, utts, cfg)
}

/// [`stream_serve`] with an optional confidence-gated cascade
/// (`--cascade LOW:HIGH --escalate-threshold T`): every pool decodes on
/// `engine` (the low rung) and re-runs breached blocks on
/// `cascade.high`.  `None` is exactly `stream_serve`.
pub fn stream_serve_cascade(
    engine: Arc<Engine>,
    cascade: Option<CascadeCfg>,
    utts: &[Utterance],
    cfg: &StreamServeConfig,
) -> Result<StreamServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no sessions"));
    }
    if cfg.pool_size == 0 || cfg.chunk_frames == 0 {
        return Err(Error::Config("pool_size and chunk_frames must be >= 1".into()));
    }
    if cfg.shards == 0 {
        return Err(Error::Config("shards must be >= 1".into()));
    }
    if cfg.arrival_rate <= 0.0 {
        return Err(Error::Config("arrival rate must be positive".into()));
    }
    validate_obs_extras(&cfg.trace_out, &cfg.slo, cfg.slo_actions, cfg.tick_secs)?;
    let shards = cfg.shards;
    let backend = engine.backend_name();
    let precision = engine.precision.name();
    let fused_gates = engine.fused_gates();
    let arrivals = sharded_arrivals(utts.len(), shards, cfg.arrival_rate, cfg.seed);
    let engines = [engine];

    let make_pool = |_tier: usize, e: Arc<Engine>| match &cascade {
        Some(cc) => StreamPool::new(e, cfg.pool_size).with_cascade(cc.clone()),
        None => Ok(StreamPool::new(e, cfg.pool_size)),
    };
    run_sharded_with(&engines, shards, cfg.pool_size, cfg.chunk_frames, utts, make_pool, |links| {
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut next = 0usize;
        let mut clock = 0.0f64;
        let mut busy = 0.0f64;
        let mut lat: Vec<Histogram> = (0..shards).map(|_| Histogram::new()).collect();
        let mut occ: Vec<OccupancyTracker> = (0..shards).map(|_| OccupancyTracker::new()).collect();
        let mut sessions_at: Vec<usize> = vec![0; shards];
        let mut shard_of_session: Vec<usize> = vec![0; utts.len()];
        let mut breakdowns: Vec<Breakdown> = vec![Breakdown::default(); shards];
        let mut stats: Vec<PoolStats> = vec![PoolStats::default(); shards];
        let mut transcripts: Vec<(usize, String, String)> = Vec::new();

        // flight recorder: per-shard event rings plus one router ring
        // (index `shards`) for pre-placement events, sized once up front
        // so the serve loop never grows them (DESIGN.md §10).  A cascade
        // serve journals one event per escalated block, so its rings get
        // block-scale headroom.
        let obs_on = obs::enabled();
        let per_utt = if cascade.is_some() { 32 } else { 4 };
        let jcap = if obs_on { per_utt * utts.len() + 64 } else { 1 };
        let mut journals: Vec<Journal> =
            (0..shards + 1).map(|_| Journal::with_capacity(jcap)).collect();
        let mut exporter = match &cfg.metrics_out {
            Some(path) => Some(MetricsExporter::create(path)?),
            None => None,
        };
        if let Some(ex) = exporter.as_mut() {
            write_config_row(
                ex,
                "stream-serve",
                shards,
                cfg.pool_size,
                cfg.chunk_frames,
                &cfg.slo,
                cfg.slo_actions,
            )?;
        }
        let mut tracer = TraceBuilder::new();
        let mut slo = match &cfg.slo {
            Some(c) => Some(SloEngine::new(c.clone())?),
            None => None,
        };
        let mut rounds = 0usize;

        while next < utts.len() || !queue.is_empty() || links.any_active() {
            // arrivals land in the admission queue as the clock passes them
            while next < utts.len() && arrivals[next] <= clock {
                if obs_on {
                    journals[shards].push(Event {
                        clock: arrivals[next],
                        shard: NO_SHARD,
                        session: next,
                        tier: 0,
                        kind: EventKind::Admission,
                    });
                }
                queue.push_back(next);
                next += 1;
            }
            // least-occupancy placement; a full fleet leaves the rest
            // queued (backpressure) for a later round — and under
            // `--slo-actions on` a burn-rate breach sheds the whole
            // round's admissions (never into an idle fleet: shedding with
            // nothing running could not clear the breach)
            let shedding = cfg.slo_actions
                && slo.as_ref().map_or(false, |e| e.breaching())
                && links.any_active();
            let mut admissions: Vec<Vec<Admission>> = vec![Vec::new(); shards];
            while !shedding {
                let Some(&utt) = queue.front() else { break };
                let Some((shard, tier)) = links.place(|_| 0) else { break };
                queue.pop_front();
                links.stage(shard, tier);
                admissions[shard].push(Admission { utt, tier });
                shard_of_session[utt] = shard;
                sessions_at[shard] += 1;
                if obs_on {
                    journals[shard].push(Event {
                        clock,
                        shard,
                        session: utt,
                        tier,
                        kind: EventKind::Placement,
                    });
                }
            }
            if obs_on && !queue.is_empty() {
                journals[shards].push(Event {
                    clock,
                    shard: NO_SHARD,
                    session: queue.len(),
                    tier: 0,
                    kind: EventKind::Backpressure,
                });
            }
            if !links.any_active() {
                // idle fleet (staged admissions count as active): record
                // the empty gap on every shard and jump to the arrival
                let target = clock.max(arrivals[next]);
                if target > clock {
                    for o in occ.iter_mut() {
                        o.record(0, target - clock);
                    }
                }
                clock = target;
                continue;
            }

            // one parallel round across the fleet; the clock advances by
            // the slowest shard's measured tick (the round's wall-clock),
            // or by exactly `--fixed-tick-ms` when one is set
            let reports = links.round(admissions)?;
            let measured = reports.iter().flatten().map(|r| r.secs).fold(0.0, f64::max);
            busy += reports.iter().flatten().map(|r| r.secs).sum::<f64>();
            let dt = cfg.tick_secs.unwrap_or(measured);
            let clock_before = clock;
            clock += dt;
            for (shard, rep) in reports.into_iter().enumerate() {
                match rep {
                    Some(mut r) => {
                        tracer.stamp_tick(clock_before, dt, &mut r.blocks, cfg.tick_secs.is_some());
                        // cascade escalations journal on the router with
                        // the round's clock, like every worker outcome
                        for &(utt, tier) in &r.escalations {
                            journals[shard].push(Event {
                                clock,
                                shard,
                                session: utt,
                                tier,
                                kind: EventKind::CascadeEscalate,
                            });
                        }
                        occ[shard].record(r.occ_before.iter().sum(), dt);
                        breakdowns[shard] = r.breakdown;
                        stats[shard] = r.stats;
                        for f in r.finished {
                            let l = clock - arrivals[f.utt];
                            lat[shard].record(l);
                            if let Some(eng) = slo.as_mut() {
                                if let Some(misses) = eng.record(l) {
                                    if obs_on {
                                        journals[shards].push(Event {
                                            clock,
                                            shard: NO_SHARD,
                                            session: misses as usize,
                                            tier: 0,
                                            kind: EventKind::SloAlert,
                                        });
                                    }
                                }
                            }
                            if obs_on {
                                journals[shard].push(Event {
                                    clock,
                                    shard,
                                    session: f.utt,
                                    tier: f.tier,
                                    kind: EventKind::Drain,
                                });
                            }
                            transcripts.push((f.utt, utts[f.utt].text.clone(), f.transcript));
                        }
                    }
                    None => occ[shard].record(0, dt),
                }
            }
            rounds += 1;
            if let Some(ex) = exporter.as_mut() {
                if rounds % EXPORT_EVERY_ROUNDS == 0 {
                    let mut sp = SpanSet::default();
                    for b in &breakdowns {
                        sp.absorb(&b.spans);
                    }
                    ex.write_serve_snapshot("stream-serve", clock, &sp, &journals, tracer.delta())?;
                }
            }
        }

        // sessions complete out of order under churn; report in arrival order
        transcripts.sort_by_key(|(utt, _, _)| *utt);
        let transcripts: Vec<(String, String)> =
            transcripts.into_iter().map(|(_, reference, hyp)| (reference, hyp)).collect();

        let span = clock - arrivals[0];
        let mut all_lat = Histogram::new();
        let mut all_occ = OccupancyTracker::new();
        let mut bd = Breakdown::default();
        let mut st = PoolStats::default();
        let mut per_shard = Vec::with_capacity(shards);
        for s in 0..shards {
            all_lat.merge(&lat[s]);
            all_occ.merge(&occ[s]);
            bd.absorb(&breakdowns[s]);
            st.absorb(&stats[s]);
            per_shard.push(ShardSlice {
                shard: s,
                sessions: sessions_at[s],
                latency: lat[s].summary(),
                occupancy: occ[s].clone(),
            });
        }
        if let Some(ex) = exporter.as_mut() {
            ex.write_serve_snapshot("stream-serve", clock, &bd.spans, &journals, tracer.delta())?;
        }
        let merged_journal = obs::journal::merge(&journals);
        if let Some(path) = &cfg.trace_out {
            trace::write_chrome_trace(path, &merged_journal, tracer.blocks())?;
        }
        let obs_report = obs_on.then(|| ObsReport {
            spans: bd.spans,
            plan_spans: obs::spans::global_snapshot(),
            counters: obs::counters::snapshot(),
            journal: merged_journal,
            journal_dropped: obs::journal::total_dropped(&journals),
        });
        Ok(StreamServeReport {
            sessions: utts.len(),
            pool_size: cfg.pool_size,
            shards,
            backend,
            precision,
            fused_gates,
            throughput: utts.len() as f64 / span.max(1e-9),
            session_latency: all_lat.summary(),
            occupancy: all_occ,
            per_shard,
            shard_of_session,
            mean_rec_batch: st.mean_rec_batch(),
            busy_secs: busy,
            span_secs: span,
            breakdown: bd,
            transcripts,
            obs: obs_report,
            slo: slo.as_ref().map(|e| e.summary()),
            cascade: cascade.as_ref().map(|cc| cascade_summary(&engines[0], cc, &st, 0, 0)),
        })
    })
}

// ---------------------------------------------------------------------------
// Adaptive-fidelity ladder serving (registry + controller, DESIGN.md §8),
// sharded: per-shard tier pools + per-shard hysteresis.
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct LadderServeConfig {
    /// steady-state session arrival rate (utterances / second)
    pub base_rate: f64,
    /// arrival rate inside the ramp window
    pub ramp_rate: f64,
    /// session indices `[start, end)` arriving at `ramp_rate` — the
    /// synthetic load ramp the controllers must absorb
    pub ramp_range: (usize, usize),
    /// session slots per fidelity tier per shard
    pub pool_size: usize,
    /// raw feature frames a client delivers per engine tick
    pub chunk_frames: usize,
    /// worker shards (OS threads), each with its own tier pools and
    /// fidelity controller; 1 replays the unsharded loop exactly
    pub shards: usize,
    pub seed: u64,
    pub controller: ControllerConfig,
    /// JSONL metrics snapshot file (`--metrics-out FILE`); None disables
    /// the exporter
    pub metrics_out: Option<String>,
    /// Chrome-trace / Perfetto JSON output file (`--trace-out FILE`);
    /// needs `--obs on` (the trace is assembled from the event journal)
    pub trace_out: Option<String>,
    /// latency/availability objective evaluated over completed sessions
    /// (`--slo-target MS`); None disables the burn-rate engine
    pub slo: Option<SloConfig>,
    /// whether an SLO breach steers the runtime (`--slo-actions on`):
    /// this path feeds the breach into every fidelity controller as
    /// extra downshift pressure.  Off by default
    pub slo_actions: bool,
    /// fixed simulated tick in seconds (`--fixed-tick-ms F`): the clock
    /// advances by exactly this every round instead of the measured wall
    /// time, making clocks — and the exported trace — deterministic
    pub tick_secs: Option<f64>,
    /// confidence-gated cascade over one rung pair (`--cascade LOW:HIGH
    /// --escalate-threshold T`); None serves every tier plain
    pub cascade: Option<CascadePlan>,
}

impl Default for LadderServeConfig {
    fn default() -> Self {
        LadderServeConfig {
            base_rate: 4.0,
            ramp_rate: 1e5,
            ramp_range: (0, 0),
            pool_size: 4,
            chunk_frames: 16,
            shards: 1,
            seed: 0,
            controller: ControllerConfig::default(),
            metrics_out: None,
            trace_out: None,
            slo: None,
            slo_actions: false,
            tick_secs: None,
            cascade: None,
        }
    }
}

/// Per-tier slice of a [`LadderServeReport`] (merged across shards).
#[derive(Clone, Debug)]
pub struct TierReport {
    pub tier: usize,
    pub tag: String,
    pub rank_frac: f64,
    /// quantized-weight width of the tier's artifact (8 or 4)
    pub bits: u32,
    /// scalar parameter count of the tier's variant
    pub params: usize,
    /// effective decode cost of the tier's rung, GFLOP per raw frame
    /// (derived from the artifact's factor dims at registry load)
    pub gflops_per_frame: f64,
    /// sessions admitted at this tier (all shards)
    pub sessions: usize,
    /// arrival → final-transcript latency of those sessions
    pub latency: LatencySummary,
    /// time-weighted occupancy of this tier's pools, merged cross-shard
    pub occupancy: OccupancyTracker,
}

impl TierReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tier", Json::num(self.tier as f64)),
            ("tag", Json::str(self.tag.clone())),
            ("rank_frac", Json::num(self.rank_frac)),
            ("bits", Json::num(self.bits as f64)),
            ("params", Json::num(self.params as f64)),
            ("gflops_per_frame", Json::num(self.gflops_per_frame)),
            ("sessions", Json::num(self.sessions as f64)),
            ("latency", self.latency.to_json()),
            ("occupancy", self.occupancy.to_json()),
        ])
    }
}

/// Report from a [`ladder_serve`] run.
#[derive(Clone, Debug)]
pub struct LadderServeReport {
    pub sessions: usize,
    pub pool_size: usize,
    /// worker shards the serve ran on
    pub shards: usize,
    /// GEMM backend every tier's engine executed on
    pub backend: &'static str,
    /// whether tier engines routed the recurrent GEMM through the fused
    /// gate kernel
    pub fused_gates: bool,
    pub tiers: Vec<TierReport>,
    /// per-shard latency/occupancy slices (across that shard's tiers)
    pub per_shard: Vec<ShardSlice>,
    pub downshifts: u64,
    pub upshifts: u64,
    /// every shard's fidelity shifts, merged in clock order (each event
    /// carries the shard whose controller shifted)
    pub shifts: Vec<ShiftEvent>,
    /// admission tier per session, indexed by arrival order
    pub tier_of_session: Vec<usize>,
    /// shard that served each session, indexed by arrival order
    pub shard_of_session: Vec<usize>,
    pub throughput: f64,
    /// aggregate engine wall-clock across shard threads (CPU-seconds)
    pub busy_secs: f64,
    pub span_secs: f64,
    pub breakdown: Breakdown,
    /// flight-recorder data (spans, kernel counters, event journal) —
    /// Some only when the serve ran with `--obs on`
    pub obs: Option<ObsReport>,
    /// SLO attainment / burn-rate summary — Some only when the serve ran
    /// with `--slo-target`
    pub slo: Option<SloSummary>,
    /// cascade gate counters and effective-FLOPs accounting — Some only
    /// when the serve ran with `--cascade`
    pub cascade: Option<CascadeSummary>,
}

impl LadderServeReport {
    /// Machine-readable report (`stream-serve --ladder --json`).
    pub fn to_json(&self) -> Json {
        let shifts: Vec<Json> = self
            .shifts
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("clock", Json::num(s.clock)),
                    ("tier", Json::num(s.tier as f64)),
                    ("shard", Json::num(s.shard as f64)),
                    ("down", Json::Bool(s.down)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("schema_version", Json::num(obs::SCHEMA_VERSION as f64)),
            ("kind", Json::str("ladder-serve")),
            ("sessions", Json::num(self.sessions as f64)),
            ("pool_size", Json::num(self.pool_size as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("backend", Json::str(self.backend)),
            ("fused_gates", Json::Bool(self.fused_gates)),
            ("throughput", Json::num(self.throughput)),
            ("busy_secs", Json::num(self.busy_secs)),
            ("span_secs", Json::num(self.span_secs)),
            ("downshifts", Json::num(self.downshifts as f64)),
            ("upshifts", Json::num(self.upshifts as f64)),
            ("tiers", Json::Arr(self.tiers.iter().map(|t| t.to_json()).collect())),
            ("per_shard", Json::Arr(self.per_shard.iter().map(|s| s.to_json()).collect())),
            ("shifts", Json::Arr(shifts)),
            (
                "tier_of_session",
                Json::Arr(
                    self.tier_of_session.iter().map(|&t| Json::num(t as f64)).collect(),
                ),
            ),
            (
                "shard_of_session",
                Json::Arr(
                    self.shard_of_session.iter().map(|&s| Json::num(s as f64)).collect(),
                ),
            ),
        ];
        if let Some(c) = &self.cascade {
            fields.push(("cascade", c.to_json()));
        }
        if let Some(s) = &self.slo {
            fields.push(("slo", s.to_json()));
        }
        if let Some(o) = &self.obs {
            fields.push(("obs", o.to_json()));
        }
        Json::obj(fields)
    }
}

/// A controller shift as a journal event: the same clock and tier the
/// ad-hoc shift log records, shard-tagged, so the merged journal subsumes
/// `merge_shift_logs` while the legacy `shifts` report field stays.
fn shift_event(sh: &ShiftEvent, shard: usize) -> Event {
    Event {
        clock: sh.clock,
        shard,
        session: 0,
        tier: sh.tier,
        kind: if sh.down { EventKind::DownShift } else { EventKind::UpShift },
    }
}

/// Serve `utts` as concurrent streaming sessions across a rank ladder
/// sharded over `cfg.shards` worker threads: every shard owns one
/// [`StreamPool`](crate::stream::StreamPool) per tier (all sharing the registry's engines) plus its
/// own [`FidelityController`].  The router places each *new* session on
/// the least-occupied shard that has room at (or below — spill, never
/// up) that shard's routed tier.  Arrival clocks follow the piecewise
/// Poisson ramp **globally** from the root seed: the ramp is a
/// coordinated load event, so it is not thinned per shard — per-shard
/// sub-seeding applies to the steady-state [`stream_serve`] path.
pub fn ladder_serve(
    registry: &Registry,
    utts: &[Utterance],
    cfg: &LadderServeConfig,
) -> Result<LadderServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no sessions"));
    }
    if cfg.pool_size == 0 || cfg.chunk_frames == 0 {
        return Err(Error::Config("pool_size and chunk_frames must be >= 1".into()));
    }
    if cfg.shards == 0 {
        return Err(Error::Config("shards must be >= 1".into()));
    }
    if cfg.base_rate <= 0.0 || cfg.ramp_rate <= 0.0 {
        return Err(Error::Config("arrival rates must be positive".into()));
    }
    validate_obs_extras(&cfg.trace_out, &cfg.slo, cfg.slo_actions, cfg.tick_secs)?;
    let tiers = registry.num_tiers();
    let shards = cfg.shards;
    // resolve the cascade plan against the ladder before any thread
    // spawns: build the CascadeCfg the low tier's pools will carry
    let cascade: Option<CascadeCfg> = match &cfg.cascade {
        Some(plan) => {
            if plan.low_tier >= tiers || plan.high_tier >= tiers {
                return Err(Error::Config(format!(
                    "cascade tiers {}:{} out of range (ladder has {tiers} tiers)",
                    plan.low_tier, plan.high_tier
                )));
            }
            if plan.low_tier <= plan.high_tier {
                return Err(Error::Config(
                    "cascade LOW must be a cheaper rung (higher tier index) than HIGH".into(),
                ));
            }
            Some(CascadeCfg {
                high: registry.tier(plan.high_tier).engine.clone(),
                threshold: plan.threshold,
                shared_frontend: registry.shared_frontend(plan.low_tier, plan.high_tier),
            })
        }
        None => None,
    };
    let mut ctls: Vec<FidelityController> = (0..shards)
        .map(|s| FidelityController::for_shard(tiers, cfg.controller.clone(), s))
        .collect::<Result<_>>()?;
    if let Some(plan) = &cfg.cascade {
        // the escalation threshold becomes each controller's first
        // pressure actuator (cut before downshift, restore before
        // upshift); the live value is propagated to the worker pools
        // every round
        for ctl in ctls.iter_mut() {
            ctl.set_cascade_knob(plan.threshold);
        }
    }

    let mut rng = Pcg64::seeded(cfg.seed);
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for i in 0..utts.len() {
        let rate = if i >= cfg.ramp_range.0 && i < cfg.ramp_range.1 {
            cfg.ramp_rate
        } else {
            cfg.base_rate
        };
        t += -rng.uniform().max(1e-12).ln() / rate;
        arrivals.push(t);
    }

    let engines = registry.engines();
    let backend = registry.tier(0).engine.backend_name();
    let fused_gates = registry.tier(0).engine.fused_gates();

    let make_pool = |tier: usize, e: Arc<Engine>| match (&cascade, &cfg.cascade) {
        (Some(cc), Some(plan)) if tier == plan.low_tier => {
            StreamPool::new(e, cfg.pool_size).with_cascade(cc.clone())
        }
        _ => Ok(StreamPool::new(e, cfg.pool_size)),
    };
    run_sharded_with(&engines, shards, cfg.pool_size, cfg.chunk_frames, utts, make_pool, |links| {
        let mut queue: VecDeque<usize> = VecDeque::new();
        let mut next = 0usize;
        let mut clock = 0.0f64;
        let mut busy = 0.0f64;
        let mut lat: Vec<Vec<Histogram>> = (0..shards)
            .map(|_| (0..tiers).map(|_| Histogram::new()).collect())
            .collect();
        let mut occ: Vec<Vec<OccupancyTracker>> = (0..shards)
            .map(|_| (0..tiers).map(|_| OccupancyTracker::new()).collect())
            .collect();
        let mut sessions_at: Vec<usize> = vec![0; tiers];
        let mut tier_of_session: Vec<usize> = vec![0; utts.len()];
        let mut shard_of_session: Vec<usize> = vec![0; utts.len()];
        let mut shard_sessions: Vec<usize> = vec![0; shards];
        let mut breakdowns: Vec<Breakdown> = vec![Breakdown::default(); shards];
        let mut stats: Vec<PoolStats> = vec![PoolStats::default(); shards];

        // flight recorder (see stream_serve): per-shard rings + router
        // ring, with block-scale headroom for cascade escalation events
        let obs_on = obs::enabled();
        let per_utt = if cascade.is_some() { 32 } else { 4 };
        let jcap = if obs_on { per_utt * utts.len() + 64 } else { 1 };
        let mut journals: Vec<Journal> =
            (0..shards + 1).map(|_| Journal::with_capacity(jcap)).collect();
        let mut exporter = match &cfg.metrics_out {
            Some(path) => Some(MetricsExporter::create(path)?),
            None => None,
        };
        if let Some(ex) = exporter.as_mut() {
            write_config_row(
                ex,
                "ladder-serve",
                shards,
                cfg.pool_size,
                cfg.chunk_frames,
                &cfg.slo,
                cfg.slo_actions,
            )?;
        }
        let mut tracer = TraceBuilder::new();
        let mut slo = match &cfg.slo {
            Some(c) => Some(SloEngine::new(c.clone())?),
            None => None,
        };
        let mut rounds = 0usize;

        while next < utts.len() || !queue.is_empty() || links.any_active() {
            while next < utts.len() && arrivals[next] <= clock {
                if obs_on {
                    journals[shards].push(Event {
                        clock: arrivals[next],
                        shard: NO_SHARD,
                        session: next,
                        tier: 0,
                        kind: EventKind::Admission,
                    });
                }
                queue.push_back(next);
                next += 1;
            }
            // route each arrival: least-occupied shard that has room at
            // (or below) its controller's tier — an overload must never
            // push extra load onto the expensive tiers
            let mut admissions: Vec<Vec<Admission>> = vec![Vec::new(); shards];
            while let Some(&utt) = queue.front() {
                let Some((shard, tier)) = links.place(|s| ctls[s].tier()) else { break };
                queue.pop_front();
                links.stage(shard, tier);
                admissions[shard].push(Admission { utt, tier });
                tier_of_session[utt] = tier;
                shard_of_session[utt] = shard;
                sessions_at[tier] += 1;
                shard_sessions[shard] += 1;
                if obs_on {
                    journals[shard].push(Event {
                        clock,
                        shard,
                        session: utt,
                        tier,
                        kind: EventKind::Placement,
                    });
                    if tier != ctls[shard].tier() {
                        journals[shard].push(Event {
                            clock,
                            shard,
                            session: utt,
                            tier,
                            kind: EventKind::TierSpill,
                        });
                    }
                }
            }
            if obs_on && !queue.is_empty() {
                journals[shards].push(Event {
                    clock,
                    shard: NO_SHARD,
                    session: queue.len(),
                    tier: 0,
                    kind: EventKind::Backpressure,
                });
            }
            if !links.any_active() {
                // idle fleet: every controller sees a drained system and
                // the occupancy trackers record the empty gap
                for (s, ctl) in ctls.iter_mut().enumerate() {
                    if let Some(sh) = ctl.observe(clock, 0.0) {
                        if obs_on {
                            journals[s].push(shift_event(&sh, s));
                        }
                    }
                }
                let target = clock.max(arrivals[next]);
                if target > clock {
                    for shard_occ in occ.iter_mut() {
                        for o in shard_occ.iter_mut() {
                            o.record(0, target - clock);
                        }
                    }
                }
                clock = target;
                continue;
            }

            // propagate each controller's live escalation threshold to
            // its shard's cascade pools (None when no cascade is armed,
            // which makes this exactly the plain round)
            let thresholds: Vec<Option<f64>> =
                ctls.iter().map(|c| c.escalation_threshold()).collect();
            let reports = links.round_with_thresholds(admissions, &thresholds)?;
            let measured = reports.iter().flatten().map(|r| r.secs).fold(0.0, f64::max);
            busy += reports.iter().flatten().map(|r| r.secs).sum::<f64>();
            let dt = cfg.tick_secs.unwrap_or(measured);
            let clock_before = clock;
            clock += dt;
            for (shard, rep) in reports.into_iter().enumerate() {
                match rep {
                    Some(mut r) => {
                        tracer.stamp_tick(clock_before, dt, &mut r.blocks, cfg.tick_secs.is_some());
                        for &(utt, tier) in &r.escalations {
                            journals[shard].push(Event {
                                clock,
                                shard,
                                session: utt,
                                tier,
                                kind: EventKind::CascadeEscalate,
                            });
                        }
                        for (o, &k) in occ[shard].iter_mut().zip(&r.occ_before) {
                            o.record(k, dt);
                        }
                        breakdowns[shard] = r.breakdown;
                        stats[shard] = r.stats;
                        for f in r.finished {
                            let l = clock - arrivals[f.utt];
                            lat[shard][f.tier].record(l);
                            ctls[shard].record_latency(f.tier, l);
                            if let Some(eng) = slo.as_mut() {
                                if let Some(misses) = eng.record(l) {
                                    if obs_on {
                                        journals[shards].push(Event {
                                            clock,
                                            shard: NO_SHARD,
                                            session: misses as usize,
                                            tier: 0,
                                            kind: EventKind::SloAlert,
                                        });
                                    }
                                }
                            }
                            if obs_on {
                                journals[shard].push(Event {
                                    clock,
                                    shard,
                                    session: f.utt,
                                    tier: f.tier,
                                    kind: EventKind::Drain,
                                });
                            }
                        }
                        // control tick: the shard's routed tier's pool is
                        // its admission signal; under `--slo-actions on`
                        // a burn-rate breach is extra downshift pressure
                        let slo_pressure =
                            cfg.slo_actions && slo.as_ref().map_or(false, |e| e.breaching());
                        let routed = ctls[shard].tier();
                        let frac = r.occ_after[routed] as f64 / cfg.pool_size as f64;
                        if let Some(sh) = ctls[shard].observe_with_pressure(clock, frac, slo_pressure)
                        {
                            if obs_on {
                                journals[shard].push(shift_event(&sh, shard));
                            }
                        }
                    }
                    None => {
                        for o in occ[shard].iter_mut() {
                            o.record(0, dt);
                        }
                        let slo_pressure =
                            cfg.slo_actions && slo.as_ref().map_or(false, |e| e.breaching());
                        if let Some(sh) = ctls[shard].observe_with_pressure(clock, 0.0, slo_pressure)
                        {
                            if obs_on {
                                journals[shard].push(shift_event(&sh, shard));
                            }
                        }
                    }
                }
            }
            rounds += 1;
            if let Some(ex) = exporter.as_mut() {
                if rounds % EXPORT_EVERY_ROUNDS == 0 {
                    let mut sp = SpanSet::default();
                    for b in &breakdowns {
                        sp.absorb(&b.spans);
                    }
                    ex.write_serve_snapshot("ladder-serve", clock, &sp, &journals, tracer.delta())?;
                }
            }
        }

        let span = clock - arrivals[0];
        let tiers_report: Vec<TierReport> = (0..tiers)
            .map(|tier| {
                let v = registry.tier(tier);
                let mut h = Histogram::new();
                let mut o = OccupancyTracker::new();
                for s in 0..shards {
                    h.merge(&lat[s][tier]);
                    o.merge(&occ[s][tier]);
                }
                TierReport {
                    tier,
                    tag: v.info.tag.clone(),
                    rank_frac: v.info.rank_frac,
                    bits: v.info.bits,
                    params: v.info.params,
                    gflops_per_frame: v.info.gflops_per_frame,
                    sessions: sessions_at[tier],
                    latency: h.summary(),
                    occupancy: o,
                }
            })
            .collect();
        let mut per_shard = Vec::with_capacity(shards);
        let mut bd = Breakdown::default();
        for s in 0..shards {
            let mut h = Histogram::new();
            let mut o = OccupancyTracker::new();
            for tier in 0..tiers {
                h.merge(&lat[s][tier]);
                o.merge(&occ[s][tier]);
            }
            bd.absorb(&breakdowns[s]);
            per_shard.push(ShardSlice {
                shard: s,
                sessions: shard_sessions[s],
                latency: h.summary(),
                occupancy: o,
            });
        }
        if let Some(ex) = exporter.as_mut() {
            ex.write_serve_snapshot("ladder-serve", clock, &bd.spans, &journals, tracer.delta())?;
        }
        let merged_journal = obs::journal::merge(&journals);
        if let Some(path) = &cfg.trace_out {
            trace::write_chrome_trace(path, &merged_journal, tracer.blocks())?;
        }
        let obs_report = obs_on.then(|| ObsReport {
            spans: bd.spans,
            plan_spans: obs::spans::global_snapshot(),
            counters: obs::counters::snapshot(),
            journal: merged_journal,
            journal_dropped: obs::journal::total_dropped(&journals),
        });
        let shift_logs: Vec<&[ShiftEvent]> = ctls.iter().map(|c| c.shifts()).collect();
        let cascade_report = match (&cascade, &cfg.cascade) {
            (Some(cc), Some(plan)) => {
                let mut st = PoolStats::default();
                for s in &stats {
                    st.absorb(s);
                }
                // exactly one rung pair per serve: the folded counters
                // are the low tier's counters (no other pool cascades)
                Some(cascade_summary(
                    &registry.tier(plan.low_tier).engine,
                    cc,
                    &st,
                    ctls.iter().map(|c| c.threshold_cuts).sum(),
                    ctls.iter().map(|c| c.threshold_restores).sum(),
                ))
            }
            _ => None,
        };
        Ok(LadderServeReport {
            sessions: utts.len(),
            pool_size: cfg.pool_size,
            shards,
            backend,
            fused_gates,
            tiers: tiers_report,
            per_shard,
            downshifts: ctls.iter().map(|c| c.downshifts).sum(),
            upshifts: ctls.iter().map(|c| c.upshifts).sum(),
            shifts: merge_shift_logs(&shift_logs),
            tier_of_session,
            shard_of_session,
            throughput: utts.len() as f64 / span.max(1e-9),
            busy_secs: busy,
            span_secs: span,
            breakdown: bd,
            obs: obs_report,
            slo: slo.as_ref().map(|e| e.summary()),
            cascade: cascade_report,
        })
    })
}

// ---------------------------------------------------------------------------
// Whole-utterance PJRT batcher (the server-row baseline; `xla` feature).
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// mean request arrival rate (utterances / second)
    pub arrival_rate: f64,
    /// maximum dynamic batch size (the eval artifact's batch is the cap)
    pub max_batch: usize,
    /// batching window: wait at most this long to fill a batch (seconds)
    pub window: f64,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { arrival_rate: 20.0, max_batch: 8, window: 0.05, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub throughput: f64,
    pub mean_batch: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub mean_service: f64,
    /// wall-clock seconds actually spent executing batches
    pub busy_secs: f64,
    /// simulated span from first arrival to last completion
    pub span_secs: f64,
}

/// Run the whole-utterance serving simulation over `utts` (one request
/// per utterance) — batch arrivals are simulated, service times are real
/// PJRT executions.
pub fn simulate(
    rt: &Runtime,
    eval_artifact: &str,
    params: &ParamSet,
    utts: &[Utterance],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    if utts.is_empty() {
        return Err(Error::other("no requests"));
    }
    let eval = Evaluator::new(rt, eval_artifact)?;
    let mut rng = Pcg64::seeded(cfg.seed);

    // Poisson arrivals: exponential inter-arrival gaps.
    let mut arrivals: Vec<f64> = Vec::with_capacity(utts.len());
    let mut t = 0.0;
    for _ in 0..utts.len() {
        t += -rng.uniform().max(1e-12).ln() / cfg.arrival_rate;
        arrivals.push(t);
    }

    let mut lat = Histogram::new();
    let mut clock = 0.0f64; // simulated time
    let mut busy = 0.0f64;
    let mut served = 0usize;
    let mut batch_sizes: Vec<usize> = Vec::new();
    let mut i = 0usize;

    while i < utts.len() {
        // server idle: jump to next arrival if queue empty
        if clock < arrivals[i] {
            clock = arrivals[i];
        }
        // collect the batch: everything that has arrived, plus anything
        // arriving within the window, up to max_batch
        let deadline = clock + cfg.window;
        let mut j = i;
        while j < utts.len() && j - i < cfg.max_batch && arrivals[j] <= deadline {
            j += 1;
        }
        // if we waited for the window, the clock advances to the last
        // arrival we accepted (or the full window if the batch is full)
        let batch: Vec<&Utterance> = utts[i..j].iter().collect();
        if j - i == cfg.max_batch {
            clock = clock.max(arrivals[j - 1]);
        } else if j < utts.len() {
            clock = deadline;
        } else {
            clock = clock.max(arrivals[j - 1]);
        }

        // execute for real
        let owned: Vec<Utterance> = batch.iter().map(|u| (*u).clone()).collect();
        let t0 = std::time::Instant::now();
        let _ = eval.logprobs(params, &owned)?;
        let service = t0.elapsed().as_secs_f64();
        busy += service;
        clock += service;
        for k in i..j {
            lat.record(clock - arrivals[k]);
        }
        batch_sizes.push(j - i);
        served += j - i;
        i = j;
    }

    let span = clock - arrivals[0];
    Ok(ServeReport {
        requests: served,
        throughput: served as f64 / span.max(1e-9),
        mean_batch: batch_sizes.iter().sum::<usize>() as f64 / batch_sizes.len().max(1) as f64,
        p50_latency: lat.percentile(0.5),
        p95_latency: lat.percentile(0.95),
        p99_latency: lat.percentile(0.99),
        mean_service: busy / batch_sizes.len().max(1) as f64,
        busy_secs: busy,
        span_secs: span,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusSpec, Dataset};
    use crate::infer::Precision;
    use crate::stream::{demo_dims, synthetic_params};

    #[test]
    fn default_configs_sane() {
        let c = ServeConfig::default();
        assert!(c.arrival_rate > 0.0 && c.max_batch >= 1 && c.window >= 0.0);
        let s = StreamServeConfig::default();
        assert!(s.arrival_rate > 0.0 && s.pool_size >= 1 && s.chunk_frames >= 1);
        assert_eq!(s.shards, 1, "unsharded serving is the default");
        assert!(s.trace_out.is_none() && s.slo.is_none() && s.tick_secs.is_none());
        assert!(!s.slo_actions, "SLO breaches must not steer by default");
        let l = LadderServeConfig::default();
        assert!(l.base_rate > 0.0 && l.ramp_rate > 0.0 && l.pool_size >= 1);
        assert_eq!(l.shards, 1);
        assert!(l.controller.low_water < l.controller.high_water);
        assert!(l.trace_out.is_none() && l.slo.is_none() && !l.slo_actions);
        assert!(l.cascade.is_none(), "plain ladder serving is the default");
    }

    #[test]
    fn obs_extras_validate_their_preconditions() {
        let was = obs::enabled();
        obs::set_enabled(false);
        assert!(
            validate_obs_extras(&Some("t.json".into()), &None, false, None).is_err(),
            "--trace-out without --obs on must be rejected"
        );
        obs::set_enabled(true);
        assert!(validate_obs_extras(&Some("t.json".into()), &None, false, None).is_ok());
        obs::set_enabled(was);
        assert!(
            validate_obs_extras(&None, &None, true, None).is_err(),
            "--slo-actions on without an SLO must be rejected"
        );
        assert!(validate_obs_extras(&None, &None, false, Some(0.0)).is_err());
        assert!(validate_obs_extras(&None, &None, false, Some(0.004)).is_ok());
        let slo = Some(SloConfig::for_target(0.25, 0.01));
        assert!(validate_obs_extras(&None, &slo, true, None).is_ok());
    }

    #[test]
    fn stream_serve_reports_concurrent_sessions() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.25, 3);
        let engine =
            Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
        let data = Dataset::generate(CorpusSpec::standard(21), 0, 0, 6);
        let cfg = StreamServeConfig {
            arrival_rate: 1e6, // everyone arrives at once -> pool saturates
            pool_size: 3,
            chunk_frames: 16,
            shards: 1,
            seed: 1,
            ..Default::default()
        };
        let r = stream_serve(engine, &data.test, &cfg).unwrap();
        assert_eq!(r.sessions, 6);
        assert_eq!(r.shards, 1);
        assert_eq!(r.transcripts.len(), 6);
        assert!(!r.backend.is_empty(), "report must name the GEMM backend");
        assert!(r.throughput > 0.0);
        assert!(r.session_latency.p50 <= r.session_latency.p95);
        assert!(r.session_latency.p95 <= r.session_latency.p99);
        // at instant arrivals the pool must actually fill
        assert!(r.occupancy.max_occupancy() == 3, "max occ {}", r.occupancy.max_occupancy());
        assert!(r.mean_rec_batch > 1.5, "mean rec batch {}", r.mean_rec_batch);
        assert!(r.breakdown.frames > 0);
        assert_eq!(r.per_shard.len(), 1);
        assert_eq!(r.per_shard[0].sessions, 6);
        assert!(r.shard_of_session.iter().all(|&s| s == 0));
    }

    #[test]
    fn stream_serve_low_rate_stays_mostly_solo() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.25, 4);
        let engine =
            Arc::new(Engine::from_params(&dims, "partial", &p, Precision::F32, 4).unwrap());
        let data = Dataset::generate(CorpusSpec::standard(22), 0, 0, 4);
        // arrivals far apart relative to service time: occupancy ~1
        let cfg = StreamServeConfig {
            arrival_rate: 0.001,
            pool_size: 4,
            chunk_frames: 32,
            shards: 1,
            seed: 2,
            ..Default::default()
        };
        let r = stream_serve(engine, &data.test, &cfg).unwrap();
        assert_eq!(r.sessions, 4);
        assert!(r.mean_rec_batch <= 1.0 + 1e-9);
        assert!(r.occupancy.mean() <= 1.0 + 1e-9);
    }

    #[test]
    fn sharded_serve_balances_sessions_and_serializes() {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.25, 3);
        let engine =
            Arc::new(Engine::from_params(&dims, "partial", &p, Precision::Int8, 4).unwrap());
        let data = Dataset::generate(CorpusSpec::standard(23), 0, 0, 8);
        let cfg = StreamServeConfig {
            arrival_rate: 1e6, // burst -> both shards must take load
            pool_size: 2,
            chunk_frames: 16,
            shards: 2,
            seed: 1,
            ..Default::default()
        };
        let r = stream_serve(engine, &data.test, &cfg).unwrap();
        assert_eq!(r.shards, 2);
        assert_eq!(r.per_shard.len(), 2);
        assert_eq!(r.per_shard.iter().map(|s| s.sessions).sum::<usize>(), 8);
        assert!(
            r.per_shard.iter().all(|s| s.sessions > 0),
            "least-occupancy placement must spread a burst: {:?}",
            r.per_shard.iter().map(|s| s.sessions).collect::<Vec<_>>()
        );
        assert_eq!(r.shard_of_session.len(), 8);
        assert_eq!(r.transcripts.len(), 8);
        // the merged latency summary counts every session exactly once
        assert_eq!(r.session_latency.count, 8);
        // machine-readable form round-trips through the JSON parser
        let j = Json::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("schema_version").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("per_shard").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("latency").unwrap().get("p99").unwrap().as_f64().is_some());
    }

    // end-to-end PJRT serving tests live in rust/tests/integration.rs
    // (they need compiled artifacts + the `xla` feature).
}

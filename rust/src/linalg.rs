//! Dense linear algebra: one-sided Jacobi SVD, truncated SVD, matrix
//! norms, and the paper's nondimensional trace norm coefficient ν(W).
//!
//! The SVD is the heart of the paper's stage-1 → stage-2 transition
//! (truncated-SVD warmstart, §3) and of the Figure 2/3 diagnostics.  A
//! one-sided Jacobi iteration is used: it is simple, numerically robust
//! (singular values to near machine precision), and fast enough for the
//! weight matrices involved (≤ ~1.5k × 1.5k).

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Full singular value decomposition `W = U diag(s) Vt`.
#[derive(Clone, Debug)]
pub struct Svd {
    /// (m, r) left singular vectors, r = min(m, n).
    pub u: Tensor,
    /// r singular values, descending.
    pub s: Vec<f32>,
    /// (r, n) right singular vectors (transposed).
    pub vt: Tensor,
}

/// One-sided Jacobi SVD of an (m, n) matrix.
///
/// Works on A (or Aᵀ if m < n) by orthogonalizing column pairs with Jacobi
/// rotations until convergence; singular values are the resulting column
/// norms. Complexity O(min(m,n)² · max(m,n) · sweeps) with typically
/// < 20 sweeps.
pub fn svd(w: &Tensor) -> Result<Svd> {
    let (m, n) = (w.rows(), w.cols());
    if m == 0 || n == 0 {
        return Err(Error::Linalg("svd of empty matrix".into()));
    }
    // Jacobi operates column-wise on the tall orientation.
    let transposed = m < n;
    let a = if transposed { w.transpose() } else { w.clone() };
    let (rows, cols) = (a.rows(), a.cols()); // rows >= cols

    // Column-major copy for cache-friendly column ops.
    let mut colmaj = vec![0.0f64; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            colmaj[j * rows + i] = a.at2(i, j) as f64;
        }
    }
    // V accumulates the right rotations (cols x cols), column-major.
    let mut v = vec![0.0f64; cols * cols];
    for j in 0..cols {
        v[j * cols + j] = 1.0;
    }

    let eps = 1e-14_f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                let (cp, cq) = (p * rows, q * rows);
                for i in 0..rows {
                    let x = colmaj[cp + i];
                    let y = colmaj[cq + i];
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation zeroing the (p, q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..rows {
                    let x = colmaj[cp + i];
                    let y = colmaj[cq + i];
                    colmaj[cp + i] = c * x - s * y;
                    colmaj[cq + i] = s * x + c * y;
                }
                for i in 0..cols {
                    let x = v[p * cols + i];
                    let y = v[q * cols + i];
                    v[p * cols + i] = c * x - s * y;
                    v[q * cols + i] = s * x + c * y;
                }
            }
        }
        if off < 1e-12 {
            break;
        }
    }

    // Singular values = column norms; U = normalized columns.
    let mut sv: Vec<(f64, usize)> = (0..cols)
        .map(|j| {
            let norm = (0..rows)
                .map(|i| colmaj[j * rows + i] * colmaj[j * rows + i])
                .sum::<f64>()
                .sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let r = cols;
    let mut u = Tensor::zeros(&[rows, r]);
    let mut vt = Tensor::zeros(&[r, cols]);
    let mut s = Vec::with_capacity(r);
    for (k, (norm, j)) in sv.iter().enumerate() {
        s.push(*norm as f32);
        if *norm > 1e-30 {
            for i in 0..rows {
                u.set2(i, k, (colmaj[j * rows + i] / norm) as f32);
            }
        } else {
            // Null direction: leave U column zero (not used downstream —
            // truncation drops it, and reconstruction multiplies by s=0).
        }
        for i in 0..cols {
            vt.set2(k, i, v[j * cols + i] as f32);
        }
    }

    if transposed {
        // W = (A)ᵀ = (U S Vᵀ)ᵀ = V S Uᵀ: swap roles.
        Ok(Svd { u: vt.transpose(), s, vt: u.transpose() })
    } else {
        Ok(Svd { u, s, vt })
    }
}

impl Svd {
    /// Reconstruct `U[:, :r] diag(s[:r]) Vt[:r, :]`.
    pub fn reconstruct(&self, r: usize) -> Tensor {
        let m = self.u.rows();
        let n = self.vt.cols();
        let r = r.min(self.s.len());
        let mut out = Tensor::zeros(&[m, n]);
        for k in 0..r {
            let sk = self.s[k];
            if sk == 0.0 {
                continue;
            }
            for i in 0..m {
                let uik = self.u.at2(i, k) * sk;
                if uik == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                let vrow = self.vt.row(k);
                for j in 0..n {
                    orow[j] += uik * vrow[j];
                }
            }
        }
        out
    }

    /// Balanced factor split at rank r: `U_bal = U √Σ`, `V_bal = √Σ Vt`
    /// — the split for which Lemma 1 attains equality, used to warmstart
    /// stage-2 factors from a stage-1 matrix.
    pub fn balanced_factors(&self, r: usize) -> (Tensor, Tensor) {
        let m = self.u.rows();
        let n = self.vt.cols();
        let r = r.min(self.s.len());
        let mut uf = Tensor::zeros(&[m, r]);
        let mut vf = Tensor::zeros(&[r, n]);
        for k in 0..r {
            let sq = self.s[k].max(0.0).sqrt();
            for i in 0..m {
                uf.set2(i, k, self.u.at2(i, k) * sq);
            }
            for j in 0..n {
                vf.set2(k, j, self.vt.at2(k, j) * sq);
            }
        }
        (uf, vf)
    }

    /// Smallest rank whose leading singular values explain `threshold`
    /// (e.g. 0.9) of the squared-singular-value mass — the paper's
    /// "percentage of variance explained" truncation rule (§3, Fig. 3).
    pub fn rank_for_variance(&self, threshold: f64) -> usize {
        let total: f64 = self.s.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if total <= 0.0 {
            return 1;
        }
        let mut acc = 0.0;
        for (k, &x) in self.s.iter().enumerate() {
            acc += (x as f64) * (x as f64);
            if acc >= threshold * total {
                return k + 1;
            }
        }
        self.s.len()
    }
}

/// Trace norm (nuclear norm): sum of singular values.
pub fn trace_norm(w: &Tensor) -> Result<f32> {
    Ok(svd(w)?.s.iter().sum())
}

/// Lemma 1's variational surrogate at a factor pair:
/// `½(‖U‖²_F + ‖V‖²_F) ≥ ‖U·V‖_*`, with equality at the balanced split
/// ([`Svd::balanced_factors`]).  This is the quantity stage-1 training
/// penalizes in place of the trace norm ([`crate::autograd::optim`]).
pub fn surrogate_norm(u: &Tensor, v: &Tensor) -> f32 {
    let su: f64 = u.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    let sv: f64 = v.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
    (0.5 * (su + sv)) as f32
}

/// The paper's Definition 1: nondimensional trace norm coefficient
/// ν(W) = (‖σ‖₁/‖σ‖₂ − 1) / (√d − 1), d = min(m, n) ≥ 2.
///
/// Scale-invariant; 0 iff rank 1, 1 iff maximal rank with equal singular
/// values (Proposition 1 / Appendix A).
pub fn nu_coefficient(w: &Tensor) -> Result<f32> {
    let d = w.rows().min(w.cols());
    if d < 2 {
        return Err(Error::Linalg("nu needs min(m,n) >= 2".into()));
    }
    let s = svd(w)?.s;
    nu_from_singular_values(&s)
}

/// ν computed directly from a singular value vector.
pub fn nu_from_singular_values(s: &[f32]) -> Result<f32> {
    let d = s.len();
    if d < 2 {
        return Err(Error::Linalg("nu needs d >= 2".into()));
    }
    let l1: f64 = s.iter().map(|&x| x as f64).sum();
    let l2: f64 = s.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if l2 == 0.0 {
        return Err(Error::Linalg("nu of zero matrix".into()));
    }
    Ok(((l1 / l2 - 1.0) / ((d as f64).sqrt() - 1.0)) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    fn reconstruction_error(w: &Tensor) -> f32 {
        let s = svd(w).unwrap();
        let rec = s.reconstruct(s.s.len());
        w.max_abs_diff(&rec)
    }

    #[test]
    fn svd_diagonal() {
        let w = Tensor::new(&[3, 3], vec![3., 0., 0., 0., 1., 0., 0., 0., 2.]).unwrap();
        let s = svd(&w).unwrap();
        assert_close(s.s[0], 3.0, 1e-5);
        assert_close(s.s[1], 2.0, 1e-5);
        assert_close(s.s[2], 1.0, 1e-5);
        assert!(reconstruction_error(&w) < 1e-4);
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Pcg64::seeded(5);
        for &(m, n) in &[(10, 10), (17, 5), (5, 17), (33, 8), (1, 7), (7, 1)] {
            let w = Tensor::randn(&[m, n], 1.0, &mut rng);
            let err = reconstruction_error(&w);
            assert!(err < 1e-3, "({m},{n}) err {err}");
        }
    }

    #[test]
    fn svd_orthonormal_u() {
        let mut rng = Pcg64::seeded(6);
        let w = Tensor::randn(&[12, 6], 1.0, &mut rng);
        let s = svd(&w).unwrap();
        // Uᵀ U = I
        let gram = s.u.transpose().matmul(&s.u).unwrap();
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert_close(gram.at2(i, j), want, 1e-4);
            }
        }
    }

    #[test]
    fn svd_low_rank_detects_rank() {
        // rank-2 matrix: outer products
        let mut rng = Pcg64::seeded(7);
        let a = Tensor::randn(&[9, 2], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 7], 1.0, &mut rng);
        let w = a.matmul(&b).unwrap();
        let s = svd(&w).unwrap();
        assert!(s.s[1] > 1e-3);
        assert!(s.s[2] < 1e-4, "s2 = {}", s.s[2]);
        assert_eq!(s.rank_for_variance(0.999), 2);
    }

    #[test]
    fn truncated_svd_is_best_approx() {
        let mut rng = Pcg64::seeded(8);
        let w = Tensor::randn(&[10, 8], 1.0, &mut rng);
        let s = svd(&w).unwrap();
        // Eckart-Young: residual Frobenius² = sum of dropped s².
        for r in 1..8 {
            let rec = s.reconstruct(r);
            let mut diff = w.clone();
            for (d, v) in diff.data_mut().iter_mut().zip(rec.data()) {
                *d -= v;
            }
            let resid = diff.frob_norm();
            let expect: f32 = s.s[r..].iter().map(|x| x * x).sum::<f32>().sqrt();
            assert_close(resid, expect, 1e-3);
        }
    }

    #[test]
    fn balanced_factors_multiply_back() {
        let mut rng = Pcg64::seeded(9);
        let w = Tensor::randn(&[8, 6], 1.0, &mut rng);
        let s = svd(&w).unwrap();
        let (u, v) = s.balanced_factors(6);
        let rec = u.matmul(&v).unwrap();
        assert!(w.max_abs_diff(&rec) < 1e-3);
        // Lemma 1 equality: ½(‖U‖² + ‖V‖²) == trace norm at the balanced split
        let surrogate = surrogate_norm(&u, &v);
        let tn: f32 = s.s.iter().sum();
        assert_close(surrogate, tn, 1e-3 * tn.max(1.0));
    }

    #[test]
    fn nu_properties() {
        // rank 1 => 0
        let mut w = Tensor::zeros(&[4, 4]);
        for j in 0..4 {
            w.set2(0, j, 2.0);
        }
        assert_close(nu_coefficient(&w).unwrap(), 0.0, 1e-5);
        // identity => 1
        let mut id = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            id.set2(i, i, 3.0);
        }
        assert_close(nu_coefficient(&id).unwrap(), 1.0, 1e-5);
        // scale invariance
        let mut rng = Pcg64::seeded(10);
        let w = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let n1 = nu_coefficient(&w).unwrap();
        let mut w2 = w.clone();
        w2.scale(17.0);
        let n2 = nu_coefficient(&w2).unwrap();
        assert_close(n1, n2, 1e-4);
        assert!(n1 > 0.0 && n1 < 1.0);
    }

    #[test]
    fn rank_for_variance_monotone_in_threshold() {
        let mut rng = Pcg64::seeded(11);
        let w = Tensor::randn(&[12, 12], 1.0, &mut rng);
        let s = svd(&w).unwrap();
        let mut prev = 0;
        for t in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let r = s.rank_for_variance(t);
            assert!(r >= prev);
            prev = r;
        }
    }
}

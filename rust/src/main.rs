//! `repro` — launcher for the trace-norm reproduction.
//!
//! See `cli::USAGE` (or run with no args) for subcommands.  The heavy
//! lifting lives in the library crate; this binary wires config + CLI into
//! the experiment harness, trainers and the embedded engine.

use std::path::Path;
use std::sync::Arc;

use tracenorm::autograd::NativeOpts;
use tracenorm::checkpoint::{self, TrainMeta, TrainState};
use tracenorm::cli::{self, Cli, USAGE};
use tracenorm::controller::ControllerConfig;
use tracenorm::data::{Batcher, CorpusSpec, Dataset};
use tracenorm::error::Result;
use tracenorm::experiments;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::jsonx::Json;
use tracenorm::kernels::BackendSel;
use tracenorm::model::ParamSet;
use tracenorm::obs::trace::Replay;
use tracenorm::obs::{spans, MetricsExporter, SloConfig, SloEngine};
use tracenorm::registry::{ladder_build_with_bits, Registry};
use tracenorm::runtime::{BatchGeom, ModelDims, Runtime};
use tracenorm::serve::{
    ladder_serve, stream_serve_cascade, CascadePlan, LadderServeConfig, StreamServeConfig,
};
use tracenorm::stream::{demo_dims, synthetic_params, CascadeCfg};
use tracenorm::train::{
    eval_name, native_mini_dims, two_stage, two_stage_native, EpochLog, Evaluator,
    NativeEvaluator, NativeTrainer, Stage2Lr, TrainOpts, Trainer, NATIVE_RANK_LADDER,
};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            std::process::exit(1);
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = cli::parse(args)?;
    match cli.subcommand.as_str() {
        "info" => info(&cli),
        "experiment" => {
            let id = cli
                .positional
                .first()
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            experiments::run(&id, cli.cfg.clone())
        }
        "train" => train_cmd(&cli),
        "two-stage" => two_stage_cmd(&cli),
        "transcribe" => transcribe_cmd(&cli),
        "bench-gemm" => {
            let mut ctx = experiments::Ctx::new(cli.cfg.clone())?;
            experiments::kernelsx::fig6(&mut ctx)
        }
        "stream-serve" => stream_serve_cmd(&cli),
        "ladder-build" => ladder_build_cmd(&cli),
        "obs-report" => obs_report_cmd(&cli),
        other => Err(tracenorm::Error::Config(format!("unknown subcommand '{other}'"))),
    }
}

fn open_runtime(cli: &Cli) -> Result<Runtime> {
    Runtime::open(cli.flag_str("artifacts", "artifacts"))
}

/// The `--backend {scalar,blocked,simd,auto}` flag (DESIGN.md §4).
fn backend_flag(cli: &Cli) -> Result<BackendSel> {
    cli.flag_str("backend", "auto").parse()
}

/// The `--bits {8,4}` flag: quantized-weight width for ladder rungs,
/// the serving engines and QAT fine-tuning (DESIGN.md §4).
fn bits_flag(cli: &Cli) -> Result<u32> {
    match cli.flag_usize("bits", 8) {
        8 => Ok(8),
        4 => Ok(4),
        other => Err(tracenorm::Error::Config(format!("--bits must be 8 or 4 (got '{other}')"))),
    }
}

/// Resolve `--precision {int8,f32}` × `--bits {8,4}` to an engine
/// precision.  `--precision f32` serves unquantized (and rejects an
/// explicit `--bits 4`, which would silently mean something else);
/// otherwise `--bits` picks the int8 or packed-int4 weight path.
fn precision_flag(cli: &Cli) -> Result<Precision> {
    let bits = bits_flag(cli)?;
    match cli.flag_str("precision", "int8").as_str() {
        "f32" => {
            if bits != 8 {
                return Err(tracenorm::Error::Config(
                    "--bits 4 contradicts --precision f32 (drop one)".into(),
                ));
            }
            Ok(Precision::F32)
        }
        _ => Ok(if bits == 4 { Precision::Int4 } else { Precision::Int8 }),
    }
}

/// An `--x {on,off}` switch flag.
fn on_off_flag(cli: &Cli, name: &str, default: bool) -> Result<bool> {
    match cli.flag_str(name, if default { "on" } else { "off" }).as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => Err(tracenorm::Error::Config(format!(
            "--{name} must be 'on' or 'off' (got '{other}')"
        ))),
    }
}

/// `--autotune {on,off}` (default on): construction-time NR/KC tile
/// probing for the blocked packed layout.  Must run before any engine or
/// registry is built — packing happens at construction (DESIGN.md §4).
fn apply_autotune_flag(cli: &Cli) -> Result<()> {
    tracenorm::kernels::autotune::set_enabled(on_off_flag(cli, "autotune", true)?);
    Ok(())
}

/// `--fused-gates {on,off}` (default on): route the recurrent GEMM
/// through the fused GRU-gate kernel.  Bit-identical either way.
fn fused_gates_flag(cli: &Cli) -> Result<bool> {
    on_off_flag(cli, "fused-gates", true)
}

/// `--obs {on,off}` (default off): the flight-recorder observability
/// layer (DESIGN.md §10).  Like `--autotune`, must run before engines
/// are built so plan-time spans (pack, autotune, quantize) are captured.
fn apply_obs_flag(cli: &Cli) -> Result<()> {
    tracenorm::obs::set_enabled(on_off_flag(cli, "obs", false)?);
    Ok(())
}

/// `--metrics-out FILE`: JSONL snapshot destination for the serve loops
/// and native training (None when the flag is absent).
fn metrics_out_flag(cli: &Cli) -> Option<String> {
    let path = cli.flag_str("metrics-out", "");
    if path.is_empty() {
        None
    } else {
        Some(path)
    }
}

/// `--trace-out FILE`: Chrome-trace / Perfetto JSON destination for the
/// serve loops (None when the flag is absent).  Needs `--obs on`.
fn trace_out_flag(cli: &Cli) -> Option<String> {
    let path = cli.flag_str("trace-out", "");
    if path.is_empty() {
        None
    } else {
        Some(path)
    }
}

/// `--slo-target MS` + `--slo-budget FRAC` + `--slo-actions {on,off}`:
/// the declarative serving SLO and whether a burn-rate breach may steer
/// the runtime (DESIGN.md §10).  Actions without a target are rejected
/// in serve-config validation.
fn slo_flags(cli: &Cli) -> Result<(Option<SloConfig>, bool)> {
    let actions = on_off_flag(cli, "slo-actions", false)?;
    let slo = match cli.cfg.raw("slo-target") {
        Some(_) => Some(SloConfig::for_target(
            cli.flag_f64("slo-target", 250.0) / 1e3,
            cli.flag_f64("slo-budget", 0.01),
        )),
        None => None,
    };
    Ok((slo, actions))
}

/// `--fixed-tick-ms F`: advance the simulated clock by exactly F ms per
/// round instead of the measured wall time, making serve clocks — and
/// the exported trace — deterministic (None = wall-clock ticks).
fn fixed_tick_flag(cli: &Cli) -> Option<f64> {
    cli.cfg.raw("fixed-tick-ms").map(|_| cli.flag_f64("fixed-tick-ms", 4.0) / 1e3)
}

fn info(cli: &Cli) -> Result<()> {
    let rt = open_runtime(cli)?;
    let m = rt.manifest();
    println!("alphabet: {} symbols", m.alphabet.len());
    println!("rank ladder: {:?}", m.rank_ladder);
    println!("\nconfigs:");
    for (name, d) in &m.configs {
        println!(
            "  {name}: feat {} conv {:?} gru {:?} fc {} vocab {} stride {}",
            d.feat_dim,
            d.conv.iter().map(|c| c.dim).collect::<Vec<_>>(),
            d.gru_dims,
            d.fc_dim,
            d.vocab,
            d.total_stride
        );
    }
    println!("\nartifacts ({}):", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<36} kind={:<12} scheme={:<10} rank_frac={:?}",
            a.kind, a.scheme, a.rank_frac
        );
    }
    Ok(())
}

fn default_ctx(cli: &Cli) -> Result<experiments::Ctx> {
    experiments::Ctx::new(cli.cfg.clone())
}

/// A `--load`-ed checkpoint: either a resumable native train-state or a
/// bare parameter set (v1, or any f32 v2 artifact).
enum LoadedCkpt {
    State(TrainState),
    Params(ParamSet),
}

fn load_ckpt(path: &str) -> Result<LoadedCkpt> {
    let art = checkpoint::load_artifact(path)?;
    if checkpoint::is_train_state(&art) {
        Ok(LoadedCkpt::State(checkpoint::train_state_from_artifact(&art)?))
    } else {
        Ok(LoadedCkpt::Params(checkpoint::params_from_artifact(&art)?))
    }
}

/// Params + (when the checkpoint is a train-state) the model dims it was
/// trained with — so `ladder-build`/`stream-serve --load` serve native
/// checkpoints without out-of-band layer-map knowledge.
fn load_ckpt_params(path: &str) -> Result<(ParamSet, Option<ModelDims>)> {
    match load_ckpt(path)? {
        LoadedCkpt::State(st) => Ok((st.params, Some(st.meta.dims))),
        LoadedCkpt::Params(p) => Ok((p, None)),
    }
}

fn train_cmd(cli: &Cli) -> Result<()> {
    if cli.cfg.bool_or("native", false) {
        return native_train_cmd(cli);
    }
    let ctx = default_ctx(cli)?;
    let artifact = cli.flag_str("artifact", "train_mini_partial_full");
    let opts = TrainOpts {
        seed: cli.flag_usize("seed", 17) as u64,
        lr: cli.flag_f64("lr", 3e-3) as f32,
        lr_decay: cli.flag_f64("lr-decay", 0.92) as f32,
        epochs: cli.flag_usize("epochs", 5),
        lam_rec: cli.flag_f64("lam-rec", 0.0) as f32,
        lam_nonrec: cli.flag_f64("lam-nonrec", 0.0) as f32,
        quiet: false,
    };
    let spec = ctx.rt.manifest().artifact(&artifact)?.clone();
    let mut batcher = Batcher::new(
        &ctx.data.train,
        spec.batch
            .ok_or_else(|| tracenorm::Error::Config("not a train artifact".into()))?,
        ctx.data.spec.feat_dim,
        opts.seed,
    );
    let eval = Evaluator::new(&ctx.rt, &eval_name(&artifact))?;
    println!("training {artifact} for {} epochs", opts.epochs);
    let mut t = match cli.cfg.raw("load") {
        Some(path) => {
            println!("warmstarting from checkpoint {path}");
            Trainer::with_params(&ctx.rt, &artifact, tracenorm::checkpoint::load(path)?, opts)?
        }
        None => Trainer::new(&ctx.rt, &artifact, opts)?,
    };
    t.run(&mut batcher, Some(&eval), Some(&ctx.data.dev))?;
    let stats = eval.greedy_cer(&t.params, &ctx.data.test)?;
    println!(
        "final: params {}  test CER {:.3}  WER {:.3}",
        t.params.num_scalars(),
        stats.cer(),
        stats.wer()
    );
    if let Some(path) = cli.cfg.raw("save") {
        tracenorm::checkpoint::save(&t.params, path)?;
        println!("saved checkpoint to {path}");
    }
    Ok(())
}

fn loss_trajectory(history: &[EpochLog]) -> String {
    history.iter().map(|l| format!("{:.4}", l.mean_loss)).collect::<Vec<_>>().join(" -> ")
}

fn loss_decreased(history: &[EpochLog]) -> bool {
    history.len() >= 2 && history.windows(2).all(|w| w[1].mean_loss < w[0].mean_loss)
}

/// `train --native`: the paper's two-stage scheme on the pure-Rust
/// autograd backend — runs in the default offline build, no artifacts,
/// no manifest, no XLA (DESIGN.md §2.5).  `--stage two` (default) runs
/// stage-1 + SVD transition + stage-2 end to end; `--stage 1`/`--stage 2`
/// run a single stage, with `--load` resuming a saved train-state
/// (momentum + LR schedule restored from the TNCK-v2 meta block) or
/// warmstarting stage 2 from stage-1 parameters.
fn native_train_cmd(cli: &Cli) -> Result<()> {
    let seed = cli.flag_usize("seed", 17) as u64;
    let stage = cli.flag_str("stage", "two");
    let epochs = cli.flag_usize("epochs", 6);
    let transition = cli.flag_usize("transition", epochs.div_ceil(2)).min(epochs);
    let threshold = cli.flag_f64("threshold", 0.9);
    let n_train = cli.flag_usize("utts", 48);
    let n_dev = cli.flag_usize("dev-utts", 8);
    let batch = cli.flag_usize("batch", 4);
    if n_train < batch {
        return Err(tracenorm::Error::Config(format!(
            "--utts {n_train} is smaller than --batch {batch}: every epoch would drop its \
             only (partial) batch and train nothing"
        )));
    }
    // `--bits 4|8` turns on quantization-aware fine-tuning: the forward
    // pass trains through the serving quantizer (STE).  Only the stage
    // being trained here sees it — the two-stage driver keeps stage 1 in
    // plain f32 regardless.
    let qat_bits = match cli.cfg.raw("bits") {
        Some(_) => Some(bits_flag(cli)?),
        None => None,
    };
    let mut nopts = NativeOpts {
        momentum: cli.flag_f64("momentum", 0.9) as f32,
        clip: cli.flag_f64("clip", 2.0) as f32,
        qat_bits,
    };
    let mut opts = TrainOpts {
        seed,
        lr: cli.flag_f64("lr", 5e-3) as f32,
        lr_decay: cli.flag_f64("lr-decay", 0.92) as f32,
        epochs,
        lam_rec: cli.flag_f64("lam-rec", 1e-3) as f32,
        lam_nonrec: cli.flag_f64("lam-nonrec", 1e-3) as f32,
        quiet: false,
    };

    let loaded = match cli.cfg.raw("load") {
        Some(path) => {
            println!("loading checkpoint {path}");
            Some(load_ckpt(path)?)
        }
        None => None,
    };
    // resume/warmstart on the same synthetic corpus the checkpoint was
    // trained on unless --seed explicitly overrides
    let seed = match &loaded {
        Some(LoadedCkpt::State(st)) if cli.cfg.raw("seed").is_none() => st.meta.seed,
        _ => seed,
    };
    opts.seed = seed;
    let dims = match &loaded {
        Some(LoadedCkpt::State(st)) => st.meta.dims.clone(),
        _ => native_mini_dims(),
    };
    let corpus = CorpusSpec::standard(seed);
    if dims.feat_dim != corpus.feat_dim {
        return Err(tracenorm::Error::Config(format!(
            "checkpoint feat_dim {} does not match the synthetic corpus ({})",
            dims.feat_dim, corpus.feat_dim
        )));
    }
    let geom =
        BatchGeom { batch, max_frames: corpus.max_frames, max_label: corpus.max_label };
    let data = Dataset::generate(corpus, n_train, n_dev, n_dev.max(4));
    let mut batcher = Batcher::new(&data.train, geom, data.spec.feat_dim, seed);
    let eval = NativeEvaluator::new(&dims);
    println!(
        "native training: stage {stage}, {} train / {} dev utts, batch {batch}, {epochs} epochs{}",
        data.train.len(),
        data.dev.len(),
        match qat_bits {
            Some(b) => format!(", QAT int{b}"),
            None => String::new(),
        }
    );

    // epochs completed in earlier sessions (restored from a resumed
    // train-state, so the saved `epoch` stays cumulative)
    let mut prior_epochs = 0usize;
    // restore the saved schedule on resume unless the flag was given
    // explicitly on this command line
    let restore_schedule = |opts: &mut TrainOpts, nopts: &mut NativeOpts, st: &TrainMeta| {
        if cli.cfg.raw("lr").is_none() {
            opts.lr = st.lr;
        }
        if cli.cfg.raw("lr-decay").is_none() {
            opts.lr_decay = st.lr_decay;
        }
        if cli.cfg.raw("momentum").is_none() {
            nopts.momentum = st.momentum;
        }
        if cli.cfg.raw("clip").is_none() {
            nopts.clip = st.clip;
        }
    };

    let (mut trainer, final_stage) = match stage.as_str() {
        "two" => {
            if loaded.is_some() {
                return Err(tracenorm::Error::Config(
                    "--load applies to --stage 1|2 (resume/warmstart); --stage two always \
                     starts stage 1 fresh"
                        .into(),
                ));
            }
            let r = two_stage_native(
                &dims,
                &mut batcher,
                Some(&data.dev),
                threshold,
                NATIVE_RANK_LADDER,
                transition,
                epochs,
                opts,
                nopts,
                Stage2Lr::Continuation,
            )?;
            println!("stage1 loss trajectory: {}", loss_trajectory(&r.stage1_history));
            println!("stage1 loss decreased: {}", loss_decreased(&r.stage1_history));
            println!(
                "picked rank_frac {:.3}  stage-1 params {}  stage-2 params {}",
                r.rank_frac,
                r.stage1_params.num_scalars(),
                r.stage2.params.num_scalars()
            );
            (r.stage2, 2u32)
        }
        "1" => {
            let mut t = match loaded {
                Some(LoadedCkpt::State(st)) if st.meta.stage == 1 => {
                    println!("resuming stage-1 train-state (epoch {}, lr {})", st.meta.epoch, st.meta.lr);
                    restore_schedule(&mut opts, &mut nopts, &st.meta);
                    if cli.cfg.raw("lam-rec").is_none() {
                        opts.lam_rec = st.meta.lam_rec;
                    }
                    if cli.cfg.raw("lam-nonrec").is_none() {
                        opts.lam_nonrec = st.meta.lam_nonrec;
                    }
                    prior_epochs = st.meta.epoch;
                    let mut t =
                        NativeTrainer::resume(&dims, st.params, st.momentum, opts.lr, opts, nopts)?;
                    t.epoch_offset = prior_epochs;
                    t
                }
                Some(LoadedCkpt::State(st)) => {
                    return Err(tracenorm::Error::Config(format!(
                        "--stage 1 cannot resume a stage-{} train-state (re-running the \
                         surrogate stage on truncated factors corrupts the two-stage \
                         provenance); use --stage 2 to continue it",
                        st.meta.stage
                    )));
                }
                Some(LoadedCkpt::Params(p)) => NativeTrainer::with_params(&dims, p, opts, nopts)?,
                None => NativeTrainer::new_factored(&dims, opts, nopts),
            };
            t.run(&mut batcher, Some(&eval), Some(&data.dev))?;
            println!("stage1 loss trajectory: {}", loss_trajectory(&t.history));
            println!("stage1 loss decreased: {}", loss_decreased(&t.history));
            (t, 1u32)
        }
        "2" => {
            opts.lam_rec = 0.0;
            opts.lam_nonrec = 0.0;
            let mut t = match loaded {
                Some(LoadedCkpt::State(st)) if st.meta.stage == 2 => {
                    println!(
                        "resuming stage-2 train-state (epoch {}, lr {} — schedule carried)",
                        st.meta.epoch, st.meta.lr
                    );
                    restore_schedule(&mut opts, &mut nopts, &st.meta);
                    prior_epochs = st.meta.epoch;
                    let mut t =
                        NativeTrainer::resume(&dims, st.params, st.momentum, opts.lr, opts, nopts)?;
                    t.epoch_offset = prior_epochs;
                    t
                }
                Some(LoadedCkpt::State(st)) => {
                    // §3.2.3 continuation: stage 2 picks up the stage-1
                    // schedule position, matching two_stage_native
                    restore_schedule(&mut opts, &mut nopts, &st.meta);
                    let p2 = truncate_for_stage2(cli, st.params, threshold)?;
                    NativeTrainer::with_params(&dims, p2, opts, nopts)?
                }
                Some(LoadedCkpt::Params(p)) => {
                    let p2 = truncate_for_stage2(cli, p, threshold)?;
                    NativeTrainer::with_params(&dims, p2, opts, nopts)?
                }
                None => {
                    return Err(tracenorm::Error::Config(
                        "--stage 2 needs --load (a stage-1 checkpoint or a stage-2 train-state)"
                            .into(),
                    ))
                }
            };
            t.run(&mut batcher, Some(&eval), Some(&data.dev))?;
            println!("stage2 loss trajectory: {}", loss_trajectory(&t.history));
            println!("stage2 loss decreased: {}", loss_decreased(&t.history));
            (t, 2u32)
        }
        other => {
            return Err(tracenorm::Error::Config(format!(
                "--stage must be 1, 2 or two (got '{other}')"
            )))
        }
    };

    // `--metrics-out FILE`: one versioned JSONL snapshot per final-stage
    // epoch (same envelope as the serve exporters, kind "train-epoch")
    if let Some(path) = metrics_out_flag(cli) {
        let mut ex = MetricsExporter::create(&path)?;
        for e in &trainer.history {
            ex.write_snapshot(
                "train-epoch",
                e.epoch as f64,
                vec![
                    ("epoch", Json::num(e.epoch as f64)),
                    ("mean_loss", Json::num(e.mean_loss)),
                    ("mean_ctc", Json::num(e.mean_ctc)),
                    ("lr", Json::num(e.lr as f64)),
                    (
                        "dev_cer",
                        match e.dev_cer {
                            Some(c) => Json::num(c),
                            None => Json::Null,
                        },
                    ),
                ],
            )?;
        }
        println!("wrote {} epoch snapshots to {path}", trainer.history.len());
    }

    let stats = eval.greedy_cer(&trainer.params, &data.test)?;
    println!(
        "final: params {}  test CER {:.3}  WER {:.3}",
        trainer.params.num_scalars(),
        stats.cer(),
        stats.wer()
    );
    if let Some(path) = cli.cfg.raw("save") {
        let meta = TrainMeta {
            dims: dims.clone(),
            stage: final_stage,
            epoch: prior_epochs + trainer.history.len(),
            lr: trainer.lr,
            lr_decay: trainer.opts.lr_decay,
            momentum: trainer.nopts.momentum,
            clip: trainer.nopts.clip,
            lam_rec: trainer.opts.lam_rec,
            lam_nonrec: trainer.opts.lam_nonrec,
            seed,
        };
        let state = TrainState {
            params: std::mem::take(&mut trainer.params),
            momentum: std::mem::take(&mut trainer.velocity),
            meta,
        };
        checkpoint::save_train_state(&state, path)?;
        println!("saved train-state checkpoint to {path} (servable via ladder-build/stream-serve --load)");
    }
    Ok(())
}

/// Stage-2 warmstart from stage-1 parameters: truncate every group at
/// `--rank-frac`, or pick the fraction by explained variance
/// (`--threshold`) against the native ladder.
fn truncate_for_stage2(cli: &Cli, stage1: ParamSet, threshold: f64) -> Result<ParamSet> {
    let frac = match cli.cfg.raw("rank-frac") {
        Some(_) => cli.flag_f64("rank-frac", 0.5),
        None => tracenorm::model::pick_rank_frac(&stage1, threshold, NATIVE_RANK_LADDER)?,
    };
    println!("stage-2 warmstart: truncating groups at rank_frac {frac:.3}");
    tracenorm::model::truncate_groups(&stage1, frac)
}

fn two_stage_cmd(cli: &Cli) -> Result<()> {
    let ctx = default_ctx(cli)?;
    let stage1 = cli.flag_str("stage1", "train_mini_partial_full");
    let family = cli.flag_str("family", "train_mini_partial");
    let threshold = cli.flag_f64("threshold", 0.9);
    let transition = cli.flag_usize("transition", 3);
    let total = cli.flag_usize("total", 8);
    let opts = TrainOpts {
        seed: cli.flag_usize("seed", 17) as u64,
        lr: cli.flag_f64("lr", 3e-3) as f32,
        lr_decay: cli.flag_f64("lr-decay", 0.92) as f32,
        epochs: transition,
        lam_rec: cli.flag_f64("lam-rec", 1e-3) as f32,
        lam_nonrec: cli.flag_f64("lam-nonrec", 1e-3) as f32,
        quiet: false,
    };
    let spec = ctx.rt.manifest().artifact(&stage1)?.clone();
    let mut batcher = Batcher::new(
        &ctx.data.train,
        spec.batch.unwrap(),
        ctx.data.spec.feat_dim,
        opts.seed,
    );
    println!(
        "two-stage: {stage1} -> {family}_r*, threshold {threshold}, transition {transition}/{total}"
    );
    let result = two_stage(
        &ctx.rt,
        &mut batcher,
        &ctx.data.dev,
        &stage1,
        &family,
        threshold,
        transition,
        total,
        opts,
        Stage2Lr::Continuation,
    )?;
    let eval = Evaluator::new(
        &ctx.rt,
        &eval_name(&format!("{family}_{}", tracenorm::train::frac_tag(result.rank_frac))),
    )?;
    let stats = eval.greedy_cer(&result.stage2.params, &ctx.data.test)?;
    println!(
        "picked rank_frac {}  stage-2 params {}  test CER {:.3}",
        result.rank_frac,
        result.stage2.params.num_scalars(),
        stats.cer()
    );
    Ok(())
}

fn transcribe_cmd(cli: &Cli) -> Result<()> {
    let ctx = default_ctx(cli)?;
    let precision = precision_flag(cli)?;
    let n = cli.flag_usize("utts", 5);
    // quick train so the transcription is meaningful
    let artifact = "train_mini_partial_full";
    let opts = TrainOpts {
        seed: cli.flag_usize("seed", 17) as u64,
        lr: cli.flag_f64("lr", 3e-3) as f32,
        lr_decay: 0.92,
        epochs: cli.flag_usize("epochs", 4),
        lam_rec: 1e-4,
        lam_nonrec: 1e-4,
        quiet: false,
    };
    let spec = ctx.rt.manifest().artifact(artifact)?.clone();
    let mut batcher =
        Batcher::new(&ctx.data.train, spec.batch.unwrap(), ctx.data.spec.feat_dim, 1);
    println!("training a quick model ({} epochs)...", opts.epochs);
    let mut t = Trainer::new(&ctx.rt, artifact, opts)?;
    t.run(&mut batcher, None, None)?;

    let dims = ctx.rt.manifest().dims("wsj_mini")?.clone();
    apply_autotune_flag(cli)?;
    let engine = Engine::from_params(&dims, "partial", &t.params, precision, 4)?
        .with_backend(backend_flag(cli)?)?
        .with_fused_gates(fused_gates_flag(cli)?);
    println!(
        "\nembedded engine: {:?}, backend {}, fused gates {}, model {} KB, {} MACs/step",
        precision,
        engine.backend_name(),
        if engine.fused_gates() { "on" } else { "off" },
        engine.model_bytes() / 1024,
        engine.macs_per_step()
    );
    let mut bd = Breakdown::default();
    for u in ctx.data.test.iter().take(n) {
        let (hyp, _) = engine.transcribe(&u.feats, &mut bd)?;
        println!("  ref: {:<16} hyp: {}", u.text, hyp);
    }
    println!(
        "\nacoustic time {:.1} ms for {:.2} s audio -> {:.1}x realtime (host)",
        bd.acoustic_total() * 1e3,
        bd.frames as f64 * 0.01,
        bd.speedup_over_realtime(0.01)
    );
    Ok(())
}

/// `ladder-build`: the offline rank-ladder pass — per-group truncated
/// SVD at each rank fraction, int8 (or, with `--bits 4`, packed int4)
/// quantization, one self-describing TNCK-v2 artifact per rung plus
/// `ladder.json` (DESIGN.md §8).  Runs fully offline: weights come from
/// `--load` or, for demos and CI smoke, a synthetic full-rank model on
/// the `wsj_mini` demo dims.
fn ladder_build_cmd(cli: &Cli) -> Result<()> {
    let out = cli.flag_str("out", "ladder");
    let seed = cli.flag_usize("seed", 17) as u64;
    let bits = bits_flag(cli)?;
    let fracs_flag = cli.flag_str("fracs", "0.75,0.5,0.25");
    let fracs = fracs_flag
        .split(',')
        .map(|s| {
            s.trim().parse::<f64>().map_err(|_| {
                tracenorm::Error::Config(format!("bad --fracs entry '{s}' (want e.g. 0.5,0.25)"))
            })
        })
        .collect::<Result<Vec<f64>>>()?;
    let (params, dims) = match cli.cfg.raw("load") {
        Some(path) => {
            let (params, ckpt_dims) = load_ckpt_params(path)?;
            match ckpt_dims {
                Some(d) => {
                    println!("loading trained weights from train-state {path} (dims from its meta block)");
                    (params, d)
                }
                None => {
                    println!("loading trained weights from checkpoint {path} (wsj_mini dims assumed)");
                    (params, demo_dims())
                }
            }
        }
        None => {
            println!("using synthetic full-rank weights — structure is real, accuracy is not");
            let dims = demo_dims();
            (synthetic_params(&dims, 1.0, seed), dims)
        }
    };
    let rungs = ladder_build_with_bits(&params, &dims, &fracs, bits, Path::new(&out))?;
    println!("ladder written to {out}/ ({} rungs, int{bits} weights):", rungs.len());
    for (tier, r) in rungs.iter().enumerate() {
        println!(
            "  tier {tier}  {}  rank_frac {:.3}  bits {}  params {}  weights {} KB  {:.3} GFLOP/frame",
            r.tag,
            r.rank_frac,
            r.bits,
            r.params,
            r.bytes / 1024,
            r.gflops_per_frame
        );
        for (base, nu) in &r.nu {
            println!("      nu({base}) = {nu:.3}");
        }
    }
    println!("serve it with: repro stream-serve --ladder {out}");
    Ok(())
}

/// `stream-serve --ladder DIR`: adaptive-fidelity serving over a built
/// rank ladder, sharded across `--shards` worker threads (per-shard
/// fidelity controllers).  A synthetic load ramp (the first
/// `--ramp-utts` sessions arrive at `--ramp-rate`) drives the
/// controllers down the ladder and back up; the report is per-tier,
/// with per-shard slices and a merged shift log.
fn ladder_serve_cmd(cli: &Cli, dir: &str) -> Result<()> {
    // precision, weights and scheme are baked into the ladder artifacts;
    // silently ignoring these flags would serve something other than
    // what the command line claims
    for flag in ["precision", "bits", "load", "rank-frac", "scheme"] {
        if cli.cfg.raw(flag).is_some() {
            return Err(tracenorm::Error::Config(format!(
                "--{flag} does not apply with --ladder (the ladder artifacts fix it); \
                 rebuild the ladder instead"
            )));
        }
    }
    let json = cli.cfg.bool_or("json", false);
    let seed = cli.flag_usize("seed", 17) as u64;
    let n = cli.flag_usize("utts", 32);
    let shards = cli.flag_usize("shards", 1);
    let ramp_utts = cli.flag_usize("ramp-utts", n / 2).min(n);
    apply_autotune_flag(cli)?;
    apply_obs_flag(cli)?;
    let reg = Registry::load_with_options(
        Path::new(dir),
        cli.flag_usize("time-batch", 4),
        backend_flag(cli)?,
        fused_gates_flag(cli)?,
    )?;
    if !json {
        println!(
            "registry {dir}: {} tiers, {} shard(s), backend {}",
            reg.num_tiers(),
            shards,
            reg.tier(0).engine.backend_name()
        );
        for v in reg.variants() {
            println!(
                "  {}  rank_frac {:.3}  bits {}  params {}  weights {} KB  {:.3} GFLOP/frame",
                v.info.tag,
                v.info.rank_frac,
                v.info.bits,
                v.info.params,
                v.info.bytes / 1024,
                v.info.gflops_per_frame
            );
        }
    }
    let cascade = match cli.cfg.raw("cascade") {
        Some(spec) => {
            let (low_tier, high_tier) = reg.cascade_pair(spec)?;
            Some(CascadePlan {
                low_tier,
                high_tier,
                threshold: cli.flag_f64("escalate-threshold", 1.0),
            })
        }
        None => {
            if cli.cfg.raw("escalate-threshold").is_some() {
                return Err(tracenorm::Error::Config(
                    "--escalate-threshold needs --cascade LOW:HIGH".into(),
                ));
            }
            None
        }
    };
    let (slo, slo_actions) = slo_flags(cli)?;
    let cfg = LadderServeConfig {
        base_rate: cli.flag_f64("rate", 4.0),
        ramp_rate: cli.flag_f64("ramp-rate", 1e5),
        ramp_range: (0, ramp_utts),
        pool_size: cli.flag_usize("pool", 4),
        chunk_frames: cli.flag_usize("chunk", 16),
        shards,
        seed,
        controller: ControllerConfig {
            target_p99: cli.flag_f64("target-p99-ms", 250.0) / 1e3,
            ..ControllerConfig::default()
        },
        metrics_out: metrics_out_flag(cli),
        trace_out: trace_out_flag(cli),
        slo,
        slo_actions,
        tick_secs: fixed_tick_flag(cli),
        cascade,
    };
    let data = Dataset::generate(CorpusSpec::standard(seed), 0, 0, n);
    let r = ladder_serve(&reg, &data.test, &cfg)?;

    if json {
        println!("{}", r.to_json().to_string_pretty());
        return Ok(());
    }
    println!(
        "\n{} sessions ({} ramped) in {:.2} s simulated span ({:.2} s engine-busy) -> {:.1} sessions/s",
        r.sessions, ramp_utts, r.span_secs, r.busy_secs, r.throughput
    );
    println!("per-tier report:");
    for t in &r.tiers {
        println!(
            "  tier {}  {}  rank {:.3}  bits {}  {:.3} GF/frame  sessions {:>3}  p50 {:>7.1} ms  p95 {:>7.1} ms  p99 {:>7.1} ms  occ mean {:.2}",
            t.tier,
            t.tag,
            t.rank_frac,
            t.bits,
            t.gflops_per_frame,
            t.sessions,
            t.latency.p50 * 1e3,
            t.latency.p95 * 1e3,
            t.latency.p99 * 1e3,
            t.occupancy.mean()
        );
    }
    if r.shards > 1 {
        println!("per-shard report:");
        for s in &r.per_shard {
            println!(
                "  shard {}  sessions {:>3}  p50 {:>7.1} ms  p99 {:>7.1} ms  occ mean {:.2}",
                s.shard,
                s.sessions,
                s.latency.p50 * 1e3,
                s.latency.p99 * 1e3,
                s.occupancy.mean()
            );
        }
    }
    if let Some(c) = &r.cascade {
        println!(
            "cascade: escalation-rate {:.1}% ({} of {} blocks)  threshold {:.4}",
            c.escalation_rate * 100.0,
            c.escalated_blocks,
            c.stream_blocks,
            c.threshold
        );
        println!(
            "  effective {:.3} GFLOP/frame  (low {:.3}, high {:.3}, {:.2}x below pure high rung)",
            c.gflops_effective,
            c.gflops_low,
            c.gflops_high,
            c.gflops_high / c.gflops_effective
        );
        println!(
            "  threshold governor: {} cuts, {} restores",
            c.threshold_cuts, c.threshold_restores
        );
    }
    println!("fidelity shifts: {} down, {} up", r.downshifts, r.upshifts);
    for s in &r.shifts {
        if r.shards > 1 {
            println!(
                "  t={:8.3} s  shard {}  -> tier {} ({})",
                s.clock,
                s.shard,
                s.tier,
                if s.down { "downshift" } else { "upshift" }
            );
        } else {
            println!(
                "  t={:8.3} s  -> tier {} ({})",
                s.clock,
                s.tier,
                if s.down { "downshift" } else { "upshift" }
            );
        }
    }
    if let Some(s) = &r.slo {
        print!("{}", s.line());
    }
    if let Some(o) = &r.obs {
        println!("\n{}", o.self_time_table());
    }
    Ok(())
}

/// `stream-serve`: the multi-stream serving demo, sharded across
/// `--shards` worker threads — runs fully offline (synthetic corpus +
/// synthetic or checkpointed weights).  With `--ladder DIR` it becomes
/// the adaptive-fidelity path instead; with `--json` the report is a
/// single machine-readable document.
fn stream_serve_cmd(cli: &Cli) -> Result<()> {
    if let Some(dir) = cli.cfg.raw("ladder") {
        let dir = dir.to_string();
        return ladder_serve_cmd(cli, &dir);
    }
    let json = cli.cfg.bool_or("json", false);
    let precision = precision_flag(cli)?;
    let pool = cli.flag_usize("pool", 4);
    let n = cli.flag_usize("utts", 32);
    let rate = cli.flag_f64("rate", 8.0);
    let chunk = cli.flag_usize("chunk", 16);
    let shards = cli.flag_usize("shards", 1);
    let seed = cli.flag_usize("seed", 17) as u64;
    let time_batch = cli.flag_usize("time-batch", 4);
    let scheme = cli.flag_str("scheme", "partial");

    // `--cascade LOWFRAC:HIGHFRAC` pairs two synthetic rank fractions
    // built from the same seed, so the unfactored conv frontend is
    // byte-identical across the pair and escalated blocks reuse it.
    // Trained weights carry one factorization — cascade those through a
    // built ladder (`--ladder DIR --cascade LOW:HIGH`) instead.
    let cascade_fracs = match cli.cfg.raw("cascade") {
        Some(spec) => {
            if cli.cfg.raw("load").is_some() {
                return Err(tracenorm::Error::Config(
                    "--cascade with trained weights needs a built ladder: \
                     ladder-build --out DIR, then stream-serve --ladder DIR --cascade LOW:HIGH"
                        .into(),
                ));
            }
            if cli.cfg.raw("rank-frac").is_some() {
                return Err(tracenorm::Error::Config(
                    "--rank-frac conflicts with --cascade LOWFRAC:HIGHFRAC (the pair fixes both rungs)"
                        .into(),
                ));
            }
            let (ls, hs) = spec.split_once(':').ok_or_else(|| {
                tracenorm::Error::Config(format!(
                    "--cascade wants LOWFRAC:HIGHFRAC rank fractions (e.g. 0.25:0.75), got '{spec}'"
                ))
            })?;
            let frac = |s: &str| -> Result<f64> {
                let f = s.trim().parse::<f64>().map_err(|_| {
                    tracenorm::Error::Config(format!("bad --cascade rank fraction '{s}'"))
                })?;
                if !(f > 0.0 && f <= 1.0) {
                    return Err(tracenorm::Error::Config(format!(
                        "--cascade rank fraction {f} out of range (0, 1]"
                    )));
                }
                Ok(f)
            };
            let (lf, hf) = (frac(ls)?, frac(hs)?);
            if lf >= hf {
                return Err(tracenorm::Error::Config(format!(
                    "--cascade LOW fraction must be below HIGH ({lf} >= {hf}); \
                     the low rung is the cheap one"
                )));
            }
            Some((lf, hf))
        }
        None => {
            if cli.cfg.raw("escalate-threshold").is_some() {
                return Err(tracenorm::Error::Config(
                    "--escalate-threshold needs --cascade LOW:HIGH".into(),
                ));
            }
            None
        }
    };

    let (params, dims) = match cli.cfg.raw("load") {
        Some(path) => {
            if !json {
                println!("loading weights from checkpoint {path}");
            }
            let (params, ckpt_dims) = load_ckpt_params(path)?;
            // train-states carry their own layer map; bare v1 checkpoints
            // are assumed to match the demo dims, as before
            (params, ckpt_dims.unwrap_or_else(demo_dims))
        }
        None => {
            if scheme != "partial" {
                return Err(tracenorm::Error::Config(
                    "--scheme other than 'partial' requires --load (synthetic weights are partial-factored)".into(),
                ));
            }
            if !json {
                println!(
                    "using synthetic (untrained) weights — timing is real, transcripts are not"
                );
            }
            let dims = demo_dims();
            let frac = cascade_fracs
                .map(|(lf, _)| lf)
                .unwrap_or_else(|| cli.flag_f64("rank-frac", 0.25));
            let p = synthetic_params(&dims, frac, seed);
            (p, dims)
        }
    };
    apply_autotune_flag(cli)?;
    apply_obs_flag(cli)?;
    let engine = Arc::new(
        Engine::from_params(&dims, &scheme, &params, precision, time_batch)?
            .with_backend(backend_flag(cli)?)?
            .with_fused_gates(fused_gates_flag(cli)?),
    );
    let cascade = match cascade_fracs {
        Some((_, hf)) => {
            let hp = synthetic_params(&dims, hf, seed);
            let high = Arc::new(
                Engine::from_params(&dims, &scheme, &hp, precision, time_batch)?
                    .with_backend(backend_flag(cli)?)?
                    .with_fused_gates(fused_gates_flag(cli)?),
            );
            Some(CascadeCfg {
                high,
                threshold: cli.flag_f64("escalate-threshold", 1.0),
                shared_frontend: true,
            })
        }
        None => None,
    };
    if !json {
        println!(
            "engine: {:?}, backend {}, fused gates {}, model {} KB, {shards} shard(s) x pool {pool}, arrival rate {rate}/s, chunk {chunk} frames",
            precision,
            engine.backend_name(),
            if engine.fused_gates() { "on" } else { "off" },
            engine.model_bytes() / 1024
        );
    }

    let data = Dataset::generate(CorpusSpec::standard(seed), 0, 0, n);
    let (slo, slo_actions) = slo_flags(cli)?;
    let cfg = StreamServeConfig {
        arrival_rate: rate,
        pool_size: pool,
        chunk_frames: chunk,
        shards,
        seed,
        metrics_out: metrics_out_flag(cli),
        trace_out: trace_out_flag(cli),
        slo,
        slo_actions,
        tick_secs: fixed_tick_flag(cli),
    };
    let r = stream_serve_cascade(engine, cascade, &data.test, &cfg)?;

    if json {
        println!("{}", r.to_json().to_string_pretty());
        return Ok(());
    }
    println!(
        "\n{} sessions in {:.2} s simulated span ({:.2} s engine-busy) -> {:.1} sessions/s",
        r.sessions, r.span_secs, r.busy_secs, r.throughput
    );
    let l = r.session_latency;
    println!(
        "session latency  p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms  max {:.1} ms",
        l.p50 * 1e3,
        l.p95 * 1e3,
        l.p99 * 1e3,
        l.max * 1e3
    );
    println!(
        "pool occupancy   mean {:.2} (max {})  |  pooled recurrent GEMM batch mean {:.2}",
        r.occupancy.mean(),
        r.occupancy.max_occupancy(),
        r.mean_rec_batch
    );
    for (k, frac) in r.occupancy.buckets() {
        println!("  occ {k}: {:5.1}% of time", frac * 100.0);
    }
    if let Some(c) = &r.cascade {
        println!(
            "cascade: escalation-rate {:.1}% ({} of {} blocks)  threshold {:.4}",
            c.escalation_rate * 100.0,
            c.escalated_blocks,
            c.stream_blocks,
            c.threshold
        );
        println!(
            "  effective {:.3} GFLOP/frame  (low {:.3}, high {:.3}, {:.2}x below pure high rung)",
            c.gflops_effective,
            c.gflops_low,
            c.gflops_high,
            c.gflops_high / c.gflops_effective
        );
    }
    if r.shards > 1 {
        println!("per-shard report:");
        for s in &r.per_shard {
            println!(
                "  shard {}  sessions {:>3}  p50 {:>7.1} ms  p99 {:>7.1} ms  occ mean {:.2}",
                s.shard,
                s.sessions,
                s.latency.p50 * 1e3,
                s.latency.p99 * 1e3,
                s.occupancy.mean()
            );
        }
    }
    println!(
        "audio {:.2} s -> {:.1}x realtime aggregate",
        r.breakdown.frames as f64 * 0.01,
        r.breakdown.speedup_over_realtime(0.01)
    );
    if let Some(s) = &r.slo {
        print!("{}", s.line());
    }
    if let Some(o) = &r.obs {
        println!("\n{}", o.self_time_table());
    }
    println!("\nsample transcripts (hyp vs ref):");
    for (reference, hyp) in r.transcripts.iter().take(5) {
        println!("  ref: {reference:<20} hyp: {hyp}");
    }
    Ok(())
}

/// Nearest-rank percentile over an ascending-sorted sample (the same
/// discipline the SLO engine and fidelity controller use).
fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let r = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[r - 1]
}

/// `obs-report FILE.jsonl`: the offline analyzer over a `--metrics-out`
/// capture.  Validates the versioned envelope (schema version, gapless
/// `seq`), replays the journal and block-trace deltas into per-session
/// timelines, prints the self-time trend and per-tier SLO attainment /
/// burn tables, and with `--trace-out` re-emits the Perfetto trace from
/// the JSONL alone — byte-identical to what the live serve wrote.
fn obs_report_cmd(cli: &Cli) -> Result<()> {
    let path = cli.positional.first().ok_or_else(|| {
        tracenorm::Error::Config("obs-report needs a --metrics-out JSONL path".into())
    })?;
    let text = std::fs::read_to_string(path)?;
    let r = Replay::from_jsonl(&text)?;

    let kind = if r.kind.is_empty() { "serve" } else { r.kind.as_str() };
    println!(
        "{path}: {} lines, {} {kind} snapshots, last clock {:.3} s",
        r.lines, r.snapshots, r.last_clock
    );
    if let Some(c) = &r.config {
        println!(
            "serve-config: {} on {} shard(s), pool {}, chunk {} frames, slo-actions {}",
            c.serve,
            c.shards,
            c.pool_size,
            c.chunk_frames,
            if c.slo_actions { "on" } else { "off" }
        );
    }
    if r.other_kinds > 0 {
        println!("  ({} lines of other kinds tolerated)", r.other_kinds);
    }
    if r.gap_missed > 0 {
        println!(
            "WARNING: journal-gap rows declare {} lost events — the timelines below are incomplete",
            r.gap_missed
        );
    }

    // self-time trend across snapshots, then the final breakdown table
    if r.trend.len() > 1 {
        println!("\nself-time trend (cumulative decode seconds per snapshot):");
        for (clock, sp) in &r.trend {
            println!("  t={clock:8.3} s  decode {:.4} s", sp.total_secs());
        }
    }
    println!("\nself-time breakdown (replayed):");
    print!("{}", spans::table(&r.last_spans, "decode"));
    if r.last_plan_spans.total_secs() > 0.0 {
        print!("{}", spans::table(&r.last_plan_spans, "plan"));
    }

    // per-session lifecycle reconstruction
    let timelines = r.timelines();
    let completed: Vec<_> = timelines.iter().filter(|t| t.latency().is_some()).collect();
    let blocks_total: usize = timelines.iter().map(|t| t.blocks).sum();
    println!(
        "\nsessions: {} seen, {} completed, {} pump blocks replayed",
        timelines.len(),
        completed.len(),
        blocks_total
    );

    // SLO objective: the serve-config row wins; `--slo-target` is the
    // fallback for captures that predate it
    let slo_cfg = match &r.config {
        Some(c) => c.slo_target.map(|t| {
            let mut s = SloConfig::for_target(t, c.slo_budget.unwrap_or(0.01));
            if let Some(d) = c.slo_deadline {
                s.deadline = d;
            }
            s
        }),
        None => None,
    }
    .unwrap_or_else(|| {
        SloConfig::for_target(
            cli.flag_f64("slo-target", 250.0) / 1e3,
            cli.flag_f64("slo-budget", 0.01),
        )
    });

    // group completions by tier, in drain order (the order the live SLO
    // engine saw them), and replay the burn-rate engine over the stream
    let mut drains: Vec<(f64, usize, f64)> = completed
        .iter()
        .map(|t| (t.drain.unwrap(), t.tier.unwrap_or(0), t.latency().unwrap()))
        .collect();
    drains.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut engine = SloEngine::new(slo_cfg.clone())?;
    let mut by_tier: std::collections::BTreeMap<usize, Vec<f64>> = Default::default();
    for &(_, tier, l) in &drains {
        engine.record(l);
        by_tier.entry(tier).or_default().push(l);
    }
    println!(
        "\nSLO attainment by tier (deadline {:.0} ms, budget {:.2}%):",
        slo_cfg.deadline * 1e3,
        slo_cfg.budget * 100.0
    );
    println!("  tier  sessions   p50 ms   p99 ms  attainment");
    for (tier, lats) in &mut by_tier {
        let n = lats.len();
        let good = lats.iter().filter(|&&l| l <= slo_cfg.deadline).count();
        lats.sort_by(f64::total_cmp);
        println!(
            "  {tier:>4}  {n:>8}  {:>7.1}  {:>7.1}  {:>9.1}%",
            nearest_rank(lats, 0.5) * 1e3,
            nearest_rank(lats, 0.99) * 1e3,
            good as f64 / n.max(1) as f64 * 100.0
        );
    }
    print!("{}", engine.summary().line());
    let alerts_journaled =
        r.journal.iter().filter(|e| e.kind == tracenorm::obs::EventKind::SloAlert).count();
    if alerts_journaled > 0 {
        println!("journaled slo_alert events: {alerts_journaled}");
    }

    // trace re-emission: pure function of the replayed journal + blocks,
    // so with a gapless capture this matches the live --trace-out bytes
    if let Some(out) = trace_out_flag(cli) {
        tracenorm::obs::trace::write_chrome_trace(&out, &r.journal, &r.blocks)?;
        println!("\ntrace re-emitted to {out}");
    }
    Ok(())
}

//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the coordinator's hot path.
//!
//! Flow (see /opt/xla-example/load_hlo/ for the reference wiring):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::cpu().compile` → `execute`.  HLO **text** is the
//! interchange format — serialized jax≥0.5 protos are rejected by
//! xla_extension 0.5.1 (64-bit instruction ids).
//!
//! The manifest is the L2↔L3 contract: input/output ordering, shapes and
//! dtypes per artifact.  [`LoadedArtifact::run`] validates every call
//! against it, so marshalling bugs surface as errors instead of garbage
//! numerics.  Compiled executables are cached per artifact name.
//!
//! Everything that touches PJRT sits behind the `xla` cargo feature: the
//! default (offline) build still parses manifests and serves [`ModelDims`]
//! to the embedded engine and [`crate::stream`] pool, but
//! [`Runtime::load`] reports that execution needs the feature.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::jsonx::Json;
use crate::tensor::{Tensor, TensorI8};

// ---------------------------------------------------------------------------
// Manifest model.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
    S8,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" => Ok(Dtype::S32),
            "s8" => Ok(Dtype::S8),
            other => Err(Error::Manifest(format!("unknown dtype {other}"))),
        }
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug)]
pub struct BatchGeom {
    pub batch: usize,
    pub max_frames: usize,
    pub max_label: usize,
}

#[derive(Clone, Debug)]
pub struct ConvDims {
    pub context: usize,
    pub dim: usize,
}

/// Static dimensions of a model config (mirrors python configs.ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelDims {
    pub feat_dim: usize,
    pub conv: Vec<ConvDims>,
    pub gru_dims: Vec<usize>,
    pub fc_dim: usize,
    pub vocab: usize,
    pub total_stride: usize,
}

impl ModelDims {
    /// Self-describing JSON form, embedded in rank-ladder rung metadata
    /// ([`crate::registry`]) and native train-state checkpoints
    /// ([`crate::checkpoint`]) so artifacts carry their own layer map.
    pub fn to_json(&self) -> Json {
        let conv: Vec<Json> = self
            .conv
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("context", Json::num(c.context as f64)),
                    ("dim", Json::num(c.dim as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("feat_dim", Json::num(self.feat_dim as f64)),
            ("conv", Json::Arr(conv)),
            (
                "gru_dims",
                Json::arr_num(&self.gru_dims.iter().map(|&g| g as f64).collect::<Vec<_>>()),
            ),
            ("fc_dim", Json::num(self.fc_dim as f64)),
            ("vocab", Json::num(self.vocab as f64)),
            ("total_stride", Json::num(self.total_stride as f64)),
        ])
    }

    /// Parse the [`ModelDims::to_json`] form back.
    pub fn from_json(j: &Json) -> Result<ModelDims> {
        let req_usize = |j: &Json, key: &str| -> Result<usize> {
            j.req(key)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("dims '{key}' must be a number")))
        };
        let conv = j
            .req("conv")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("dims 'conv' must be an array".into()))?
            .iter()
            .map(|c| {
                Ok(ConvDims { context: req_usize(c, "context")?, dim: req_usize(c, "dim")? })
            })
            .collect::<Result<Vec<_>>>()?;
        let gru_dims = j
            .req("gru_dims")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("dims 'gru_dims' must be an array".into()))?
            .iter()
            .map(|g| g.as_usize().ok_or_else(|| Error::Manifest("non-numeric gru dim".into())))
            .collect::<Result<Vec<_>>>()?;
        Ok(ModelDims {
            feat_dim: req_usize(j, "feat_dim")?,
            conv,
            gru_dims,
            fc_dim: req_usize(j, "fc_dim")?,
            vocab: req_usize(j, "vocab")?,
            total_stride: req_usize(j, "total_stride")?,
        })
    }

    /// Structural equality (layer map + widths).
    pub fn same_as(&self, other: &ModelDims) -> bool {
        self.feat_dim == other.feat_dim
            && self.gru_dims == other.gru_dims
            && self.fc_dim == other.fc_dim
            && self.vocab == other.vocab
            && self.total_stride == other.total_stride
            && self.conv.len() == other.conv.len()
            && self
                .conv
                .iter()
                .zip(&other.conv)
                .all(|(x, y)| x.context == y.context && x.dim == y.dim)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// "train" | "eval" | "stream" | "stream_int8"
    pub kind: String,
    pub config: String,
    pub scheme: String,
    pub rank_frac: Option<f64>,
    pub use_masks: bool,
    pub param_names: Vec<String>,
    pub mask_names: Vec<String>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub batch: Option<BatchGeom>,
    pub chunk: Option<usize>,
}

impl ArtifactSpec {
    /// Shape of a named input (parameters are inputs).
    pub fn input_shape(&self, name: &str) -> Result<&[usize]> {
        self.inputs
            .iter()
            .find(|io| io.name == name)
            .map(|io| io.shape.as_slice())
            .ok_or_else(|| Error::Manifest(format!("{}: no input '{name}'", self.name)))
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub alphabet: Vec<String>,
    pub configs: BTreeMap<String, ModelDims>,
    pub rank_ladder: Vec<f64>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_specs(v: &Json) -> Result<Vec<IoSpec>> {
    v.as_arr()
        .ok_or_else(|| Error::Manifest("inputs/outputs not an array".into()))?
        .iter()
        .map(|io| {
            Ok(IoSpec {
                name: io.req("name")?.as_str().unwrap_or_default().to_string(),
                shape: io
                    .req("shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: Dtype::parse(io.req("dtype")?.as_str().unwrap_or(""))?,
            })
        })
        .collect()
}

fn str_list(v: Option<&Json>) -> Vec<String> {
    v.and_then(|a| a.as_arr())
        .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text)?;
        let alphabet = str_list(root.get("alphabet"));
        let mut configs = BTreeMap::new();
        if let Some(cfgs) = root.get("configs").and_then(|c| c.as_obj()) {
            for (name, c) in cfgs {
                let conv = c
                    .req("conv")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|s| ConvDims {
                        context: s.get("context").and_then(|v| v.as_usize()).unwrap_or(2),
                        dim: s.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
                    })
                    .collect();
                configs.insert(
                    name.clone(),
                    ModelDims {
                        feat_dim: c.req("feat_dim")?.as_usize().unwrap_or(0),
                        conv,
                        gru_dims: c
                            .req("gru_dims")?
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect(),
                        fc_dim: c.req("fc_dim")?.as_usize().unwrap_or(0),
                        vocab: c.req("vocab")?.as_usize().unwrap_or(0),
                        total_stride: c.req("total_stride")?.as_usize().unwrap_or(1),
                    },
                );
            }
        }
        let rank_ladder = root
            .get("rank_ladder")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();

        let mut artifacts = BTreeMap::new();
        for a in root.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let batch = a.get("batch").and_then(|b| {
                Some(BatchGeom {
                    batch: b.get("batch")?.as_usize()?,
                    max_frames: b.get("max_frames")?.as_usize()?,
                    max_label: b.get("max_label")?.as_usize()?,
                })
            });
            let spec = ArtifactSpec {
                name: a.req("name")?.as_str().unwrap_or_default().to_string(),
                file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                kind: a.req("kind")?.as_str().unwrap_or_default().to_string(),
                config: a.req("config")?.as_str().unwrap_or_default().to_string(),
                scheme: a.req("scheme")?.as_str().unwrap_or_default().to_string(),
                rank_frac: a.get("rank_frac").and_then(|v| v.as_f64()),
                use_masks: a.get("use_masks").and_then(|v| v.as_bool()).unwrap_or(false),
                param_names: str_list(a.get("param_names")),
                mask_names: str_list(a.get("mask_names")),
                inputs: io_specs(a.req("inputs")?)?,
                outputs: io_specs(a.req("outputs")?)?,
                batch,
                chunk: a.get("chunk").and_then(|v| v.as_usize()),
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { alphabet, configs, rank_ladder, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact '{name}'")))
    }

    pub fn dims(&self, config: &str) -> Result<&ModelDims> {
        self.configs
            .get(config)
            .ok_or_else(|| Error::Manifest(format!("no config '{config}'")))
    }
}

// ---------------------------------------------------------------------------
// Values crossing the boundary.
// ---------------------------------------------------------------------------

/// A host value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(Vec<i32>, Vec<usize>),
    I8(TensorI8),
}

impl Value {
    pub fn scalar(v: f32) -> Value {
        Value::F32(Tensor::scalar(v))
    }

    pub fn shape(&self) -> Vec<usize> {
        match self {
            Value::F32(t) => t.shape().to_vec(),
            Value::I32(_, s) => s.clone(),
            Value::I8(t) => t.shape().to_vec(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(_) => Dtype::F32,
            Value::I32(..) => Dtype::S32,
            Value::I8(_) => Dtype::S8,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::other("value is not f32")),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => Err(Error::other("value is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Value::I32(v, _) => Ok(v),
            _ => Err(Error::other("value is not i32")),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            Value::F32(t) if t.len() == 1 => Ok(t.data()[0]),
            _ => Err(Error::other("value is not a scalar")),
        }
    }

    #[cfg(feature = "xla")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => xla::Literal::vec1(t.data()).reshape(&dims)?,
            Value::I32(v, _) => xla::Literal::vec1(v).reshape(&dims)?,
            Value::I8(t) => {
                // i8 lacks the crate's NativeType constructor path; build
                // the literal from raw bytes instead.
                let bytes: &[u8] =
                    unsafe { std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len()) };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    &self.shape(),
                    bytes,
                )?
            }
        };
        Ok(lit)
    }

    #[cfg(feature = "xla")]
    fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Value> {
        match spec.dtype {
            Dtype::F32 => {
                let data = lit.to_vec::<f32>()?;
                Ok(Value::F32(Tensor::new(&spec.shape, data)?))
            }
            Dtype::S32 => {
                let data = lit.to_vec::<i32>()?;
                Ok(Value::I32(data, spec.shape.clone()))
            }
            Dtype::S8 => {
                let data = lit.to_vec::<i8>()?;
                Ok(Value::I8(TensorI8::new(&spec.shape, data)?))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Runtime.
// ---------------------------------------------------------------------------

pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with host values; validates shapes/dtypes against the spec.
    pub fn run(&self, inputs: &[Value]) -> Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Manifest(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        for (v, spec) in inputs.iter().zip(&self.spec.inputs) {
            if v.shape() != spec.shape || v.dtype() != spec.dtype {
                return Err(Error::Manifest(format!(
                    "{}: input '{}' expects {:?}/{:?}, got {:?}/{:?}",
                    self.spec.name,
                    spec.name,
                    spec.shape,
                    spec.dtype,
                    v.shape(),
                    v.dtype()
                )));
            }
        }
        #[cfg(feature = "xla")]
        {
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            if tuple.len() != self.spec.outputs.len() {
                return Err(Error::Manifest(format!(
                    "{}: expected {} outputs, got {}",
                    self.spec.name,
                    self.spec.outputs.len(),
                    tuple.len()
                )));
            }
            tuple
                .iter()
                .zip(&self.spec.outputs)
                .map(|(lit, spec)| Value::from_literal(lit, spec))
                .collect()
        }
        #[cfg(not(feature = "xla"))]
        Err(Error::other(format!(
            "{}: executing artifacts requires the `xla` feature",
            self.spec.name
        )))
    }
}

pub struct Runtime {
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<BTreeMap<String, Arc<LoadedArtifact>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain manifest.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        #[cfg(feature = "xla")]
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            #[cfg(feature = "xla")]
            client,
            manifest,
            dir,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Default artifact dir: $REPRO_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Runtime> {
        let dir =
            std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load (compile) an artifact; cached per name.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
        if let Some(hit) = self.cache.lock().unwrap().get(name) {
            return Ok(hit.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        #[cfg(feature = "xla")]
        {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::other("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let loaded = Arc::new(LoadedArtifact { spec, exe });
            self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
            Ok(loaded)
        }
        #[cfg(not(feature = "xla"))]
        Err(Error::other(format!(
            "cannot load artifact '{}' ({}): built without the `xla` feature",
            name,
            path.display()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
      "alphabet": ["<b>", " ", "a"],
      "configs": {"c": {"feat_dim": 4, "conv": [{"context": 2, "dim": 8}],
                         "gru_dims": [8], "fc_dim": 8, "vocab": 3,
                         "total_stride": 2}},
      "rank_ladder": [0.25, 0.5],
      "artifacts": [{
        "name": "a", "file": "a.hlo.txt", "kind": "eval", "config": "c",
        "scheme": "partial", "rank_frac": 0.25, "use_masks": false,
        "param_names": ["w"],
        "inputs": [{"name": "w", "shape": [2, 3], "dtype": "f32"}],
        "outputs": [{"name": "y", "shape": [2], "dtype": "s32"}],
        "batch": {"batch": 1, "max_frames": 8, "max_label": 2}
      }]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        assert_eq!(m.alphabet.len(), 3);
        assert_eq!(m.rank_ladder, vec![0.25, 0.5]);
        let a = m.artifact("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.outputs[0].dtype, Dtype::S32);
        assert_eq!(a.batch.unwrap().max_frames, 8);
        assert_eq!(m.dims("c").unwrap().gru_dims, vec![8]);
        assert!(m.artifact("nope").is_err());
        assert_eq!(a.input_shape("w").unwrap(), &[2, 3]);
        assert!(a.input_shape("nope").is_err());
    }

    #[test]
    fn value_shapes_and_dtypes() {
        let v = Value::F32(Tensor::zeros(&[2, 3]));
        assert_eq!(v.shape(), vec![2, 3]);
        assert_eq!(v.dtype(), Dtype::F32);
        let s = Value::scalar(1.5);
        assert_eq!(s.scalar_f32().unwrap(), 1.5);
        assert!(s.as_i32().is_err());
    }
}

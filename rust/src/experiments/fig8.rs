//! Figure 8: CER vs parameters — low-rank factorization vs learned
//! (magnitude) sparsity vs width-scaled dense baselines.
//!
//! * low-rank points: stage-2 models from the best trace-norm stage-1 run
//!   at several SVD thresholds (partially-joint scheme, growing dims);
//! * sparse points: dense warmup → magnitude pruning (masks) → finetune,
//!   plotted at *effective* (surviving) parameter counts — the Narang et
//!   al. baseline;
//! * dense points: the same architecture with GRU widths scaled to 1.0 /
//!   0.75 / 0.5.

use crate::data::Batcher;
use crate::error::Result;
use crate::model::{
    effective_params, magnitude_masks, pick_rank_frac, warmstart, ParamSet,
};
use crate::train::{eval_name, frac_tag, Evaluator, TrainOpts, Trainer};

use super::stage1::{self, TRACE};
use super::{f, Csv, Ctx};

pub fn fig8(ctx: &mut Ctx) -> Result<()> {
    stage1::sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap().clone();
    let best_trace = stage1::best_run(&runs, TRACE).unwrap().clone();
    let epochs = ctx.epochs2();

    let mut csv = Csv::create(&ctx.out, "fig8", &["technique", "params", "cer"])?;
    println!("\nFig 8 — CER vs parameters by reduction technique");
    println!("{:>12} {:>12} {:>8}", "technique", "params", "CER");
    let mut emit = |csv: &mut Csv, tech: &str, params: usize, cer: f64| -> Result<()> {
        println!("{tech:>12} {params:>12} {cer:>8.3}");
        csv.row(&[tech.into(), params.to_string(), f(cer)])
    };

    // ---- low-rank series (reuses the fig4 machinery)
    for th in [0.5, 0.7, 0.9] {
        let frac = pick_rank_frac(&best_trace.params, th, &ctx.rt.manifest().rank_ladder)?;
        let artifact = format!("train_mini_partial_{}", frac_tag(frac));
        let spec = ctx.rt.manifest().artifact(&artifact)?.clone();
        let p0 = warmstart(&best_trace.params, &spec, ctx.seed() + 8)?;
        let opts = TrainOpts {
            seed: ctx.seed(),
            lr: (best_trace.final_lr * 3.0).min(ctx.lr()),
            lr_decay: 0.92,
            epochs,
            quiet: true,
            ..Default::default()
        };
        let mut batcher = Batcher::new(
            &ctx.data.train,
            spec.batch.unwrap(),
            ctx.data.spec.feat_dim,
            ctx.seed() ^ 0x81,
        );
        let mut t = Trainer::with_params(&ctx.rt, &artifact, p0, opts)?;
        t.run(&mut batcher, None, None)?;
        let cer = Evaluator::new(&ctx.rt, &eval_name(&artifact))?
            .greedy_cer(&t.params, &ctx.data.dev)?
            .cer();
        emit(&mut csv, "low-rank", t.params.num_scalars(), cer)?;
    }

    // ---- sparsity series: dense warmup -> magnitude prune -> finetune
    {
        let artifact = "train_mini_unfact_masked";
        let spec = ctx.rt.manifest().artifact(artifact)?.clone();
        for sparsity in [0.6, 0.8, 0.9] {
            let warm_opts = TrainOpts {
                seed: ctx.seed(),
                lr: ctx.lr(),
                lr_decay: 0.92,
                epochs: (ctx.epochs1() / 2).max(1),
                quiet: true,
                ..Default::default()
            };
            let mut batcher = Batcher::new(
                &ctx.data.train,
                spec.batch.unwrap(),
                ctx.data.spec.feat_dim,
                ctx.seed() ^ 0x82,
            );
            let mut t = Trainer::new(&ctx.rt, artifact, warm_opts)?;
            // warmup with all-ones masks
            let ones = all_ones_masks(&spec, &t.params)?;
            t.set_masks(ones)?;
            t.run(&mut batcher, None, None)?;
            // prune + finetune
            let masks = magnitude_masks(&t.params, sparsity)?;
            t.set_masks(masks.clone())?;
            t.opts.epochs = epochs;
            t.run(&mut batcher, None, None)?;
            let cer = Evaluator::new(&ctx.rt, "eval_mini_unfact")?
                .greedy_cer(&t.params, &ctx.data.dev)?
                .cer();
            emit(&mut csv, "sparse", effective_params(&t.params, &masks), cer)?;
        }
    }

    // ---- width-scaled dense baselines
    for (tech, artifact) in [
        ("dense-1.0x", "train_mini_unfact"),
        ("dense-0.75x", "train_s75_unfact"),
        ("dense-0.5x", "train_s50_unfact"),
    ] {
        let spec = ctx.rt.manifest().artifact(artifact)?.clone();
        let opts = TrainOpts {
            seed: ctx.seed(),
            lr: ctx.lr(),
            lr_decay: 0.92,
            epochs: ctx.epochs1() + epochs,
            quiet: true,
            ..Default::default()
        };
        let mut batcher = Batcher::new(
            &ctx.data.train,
            spec.batch.unwrap(),
            ctx.data.spec.feat_dim,
            ctx.seed() ^ 0x83,
        );
        let mut t = Trainer::new(&ctx.rt, artifact, opts)?;
        t.run(&mut batcher, None, None)?;
        let cer = Evaluator::new(&ctx.rt, &eval_name(artifact))?
            .greedy_cer(&t.params, &ctx.data.dev)?
            .cer();
        emit(&mut csv, tech, t.params.num_scalars(), cer)?;
    }

    csv.done();
    Ok(())
}

/// All-ones masks matching an artifact's mask inputs.
fn all_ones_masks(
    spec: &crate::runtime::ArtifactSpec,
    _params: &ParamSet,
) -> Result<ParamSet> {
    let mut masks = ParamSet::new();
    for mn in &spec.mask_names {
        let shape = spec.input_shape(mn)?;
        masks.set(mn.clone(), crate::tensor::Tensor::full(shape, 1.0));
    }
    Ok(masks)
}

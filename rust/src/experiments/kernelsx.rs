//! Figure 6 (farm vs gemmlowp GEMM benchmark) and Figure 7 (ν geometry).

use crate::devicesim::{self, Device};
use crate::error::Result;
use crate::kernels::{farm_counts, lowp_counts, qgemm_farm, qgemm_lowp};
use crate::linalg::nu_from_singular_values;
use crate::prng::Pcg64;
use crate::tensor::TensorI8;

use super::{f, Csv, Ctx};

/// The paper's Figure-6 benchmark shape: A is 6144 × 320, batch 1..16.
pub const FIG6_N: usize = 6144;
pub const FIG6_K: usize = 320;
pub const FIG6_BATCHES: [usize; 5] = [1, 2, 4, 8, 16];

fn rand_i8(shape: &[usize], rng: &mut Pcg64) -> TensorI8 {
    let n: usize = shape.iter().product();
    let data: Vec<i8> = (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    TensorI8::new(shape, data).unwrap()
}

/// Measure a kernel's wall-clock (seconds/call, best of `reps`).
pub fn time_kernel(
    kernel: impl Fn(&TensorI8, &TensorI8) -> crate::tensor::Tensor,
    m: usize,
    reps: usize,
) -> f64 {
    let mut rng = Pcg64::seeded(42 + m as u64);
    let x = rand_i8(&[m, FIG6_K], &mut rng);
    let w = rand_i8(&[FIG6_N, FIG6_K], &mut rng);
    let _ = kernel(&x, &w); // warm
    let mut best = f64::MAX;
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        let out = kernel(&x, &w);
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&out);
        best = best.min(dt);
    }
    best
}

/// Fig 6: farm vs gemmlowp-style GEMM across batch sizes; host-measured,
/// then roofline-projected onto the paper's three devices.
pub fn fig6(ctx: &mut Ctx) -> Result<()> {
    let reps = ctx.cfg.usize_or("exp.fig6_reps", 5);
    let host = devicesim::host_device(50.0, 10.0);
    let devices: [&Device; 3] =
        [&devicesim::IPHONE7, &devicesim::IPHONE6, &devicesim::RPI3];

    let mut csv = Csv::create(
        &ctx.out,
        "fig6",
        &["batch", "kernel", "host_secs", "host_gops", "iphone7_gops", "iphone6_gops", "rpi3_gops", "speedup_farm_over_lowp"],
    )?;
    println!("\nFig 6 — farm vs gemmlowp, A = {FIG6_N}x{FIG6_K} int8");
    println!(
        "{:>6} {:>8} {:>10} {:>9} | {:>8} {:>8} {:>8}",
        "batch", "kernel", "host(ms)", "GOP/s", "iPh7", "iPh6", "RPi3"
    );

    for &m in &FIG6_BATCHES {
        let tf = time_kernel(|x, w| qgemm_farm(x, w, 0.01, 0.01), m, reps);
        let tl = time_kernel(|x, w| qgemm_lowp(x, w, 0.01, 0.01), m, reps);
        let speedup = tl / tf;
        // GOP/s is *useful* ops (m·n·k MACs) regardless of internal
        // tile padding — the paper plots effective GEMM throughput.
        let useful = farm_counts(m, FIG6_N, FIG6_K).ops();
        for (name, secs, counts) in [
            ("farm", tf, farm_counts(m, FIG6_N, FIG6_K)),
            ("lowp", tl, lowp_counts(m, FIG6_N, FIG6_K)),
        ] {
            let gops = useful as f64 / secs / 1e9;
            let dev_gops: Vec<f64> = devices
                .iter()
                .map(|d| {
                    let t = d.project_from_host(&counts, &host, secs);
                    useful as f64 / t / 1e9
                })
                .collect();
            println!(
                "{:>6} {:>8} {:>10.3} {:>9.2} | {:>8.2} {:>8.2} {:>8.2}",
                m,
                name,
                secs * 1e3,
                gops,
                dev_gops[0],
                dev_gops[1],
                dev_gops[2]
            );
            csv.row(&[
                m.to_string(),
                name.into(),
                f(secs),
                f(gops),
                f(dev_gops[0]),
                f(dev_gops[1]),
                f(dev_gops[2]),
                f(speedup),
            ])?;
        }
        println!("{:>6} {:>8} farm/lowp speedup: {:.2}x", m, "", speedup);
    }
    csv.done();
    Ok(())
}

/// Fig 7: the ℓ¹/ℓ² geometry of ν in 2-D — sweep the angle of a fixed-ℓ²
/// singular-value vector and report ‖σ‖₁ and ν.
pub fn fig7(ctx: &mut Ctx) -> Result<()> {
    let mut csv = Csv::create(&ctx.out, "fig7", &["theta", "sigma1", "sigma2", "l1", "nu"])?;
    println!("\nFig 7 — contours of the nondimensional trace norm (2-D)");
    println!("{:>8} {:>8} {:>8} {:>8} {:>8}", "theta", "s1", "s2", "l1", "nu");
    let steps = 9;
    for i in 0..=steps {
        let theta = std::f64::consts::FRAC_PI_2 * i as f64 / steps as f64;
        let (s1, s2) = (theta.cos() as f32, theta.sin() as f32);
        // fold into descending order (singular values are sorted)
        let (a, b) = if s1 >= s2 { (s1, s2) } else { (s2, s1) };
        let l1 = a + b;
        let nu = nu_from_singular_values(&[a.max(1e-9), b.max(0.0)])?;
        println!("{:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}", theta, a, b, l1, nu);
        csv.row(&[f(theta), f(a as f64), f(b as f64), f(l1 as f64), f(nu as f64)])?;
    }
    println!("  (l1 ranges from 1 at rank-1 to sqrt(2) at equal singular values; nu from 0 to 1)");
    csv.done();
    Ok(())
}

//! Figures 1–3: the stage-1 regularization sweep.
//!
//! One λ grid is trained per regularization type (trace-norm surrogate on
//! the factored model vs ℓ² on the dense model, plus the λ=0 baselines);
//! the three figures are views over the same runs:
//!
//! * **Fig 1** — final dev CER as a function of (λ_rec, λ_nonrec);
//! * **Fig 2** — ν(W) of the 3rd GRU's nonrec weight vs λ_nonrec (λ_rec=0)
//!   and of its rec weight vs λ_rec (λ_nonrec=0);
//! * **Fig 3** — rank needed for 90 % variance vs CER, per run.

use crate::data::Batcher;
use crate::error::Result;
use crate::model::{diagnose_groups, ParamSet};
use crate::train::{eval_name, Evaluator, TrainOpts, Trainer};

use super::{f, Csv, Ctx};

/// Stage-1 regularization kind.
pub const TRACE: &str = "trace_norm";
pub const L2: &str = "l2";

#[derive(Clone, Debug)]
pub struct GroupDiagLite {
    pub base: String,
    pub nu: f32,
    pub rank90: usize,
    pub full: usize,
}

#[derive(Clone, Debug)]
pub struct SweepRun {
    pub reg: &'static str,
    pub lam_rec: f32,
    pub lam_nonrec: f32,
    pub cer: f64,
    pub diags: Vec<GroupDiagLite>,
    pub params: ParamSet,
    pub final_lr: f32,
}

pub fn artifact_for(reg: &str) -> &'static str {
    match reg {
        TRACE => "train_mini_partial_full",
        _ => "train_mini_unfact",
    }
}

/// Train one stage-1 model and collect diagnostics.
pub fn train_one(
    ctx: &Ctx,
    reg: &'static str,
    lam_rec: f32,
    lam_nonrec: f32,
    epochs: usize,
) -> Result<SweepRun> {
    let artifact = artifact_for(reg);
    let opts = TrainOpts {
        seed: ctx.seed(),
        lr: ctx.lr(),
        lr_decay: 0.92,
        epochs,
        lam_rec,
        lam_nonrec,
        quiet: true,
    };
    let mut batcher = Batcher::new(
        &ctx.data.train,
        ctx.rt.manifest().artifact(artifact)?.batch.unwrap(),
        ctx.data.spec.feat_dim,
        ctx.seed() ^ 0xb,
    );
    let eval = Evaluator::new(&ctx.rt, &eval_name(artifact))?;
    let mut t = Trainer::new(&ctx.rt, artifact, opts)?;
    t.run(&mut batcher, None, None)?;
    let cer = eval.greedy_cer(&t.params, &ctx.data.dev)?.cer();
    let diags = diagnose_groups(&t.params)?
        .into_iter()
        .map(|d| GroupDiagLite { base: d.base, nu: d.nu, rank90: d.rank90, full: d.full_rank })
        .collect();
    Ok(SweepRun {
        reg,
        lam_rec,
        lam_nonrec,
        cer,
        diags,
        params: t.params,
        final_lr: t.lr,
    })
}

/// The shared λ sweep (cached on the context).
pub fn sweep(ctx: &mut Ctx) -> Result<()> {
    if ctx.stage1_sweep.is_some() {
        return Ok(());
    }
    let lams: Vec<f32> = ctx
        .cfg
        .f64_list("exp.lambdas")
        .unwrap_or_else(|| vec![3e-4, 3e-3])
        .into_iter()
        .map(|v| v as f32)
        .collect();
    let mults: [f32; 3] = [0.0, 1.0, 3.0];
    let epochs = ctx.epochs1();

    let mut grid: Vec<(f32, f32)> = vec![(0.0, 0.0)];
    for &ln in &lams {
        for &m in &mults {
            grid.push((m * ln, ln)); // (λ_rec, λ_nonrec)
        }
        grid.push((ln, 0.0)); // λ_nonrec = 0 column (Fig 2 right panel)
    }

    let mut runs = Vec::new();
    for reg in [TRACE, L2] {
        for &(lr_, ln) in &grid {
            let t0 = std::time::Instant::now();
            let run = train_one(ctx, reg, lr_, ln, epochs)?;
            println!(
                "  [{reg:>10}] lam_rec={lr_:<8.0e} lam_nonrec={ln:<8.0e} CER {:.3}  ({:.0}s)",
                run.cer,
                t0.elapsed().as_secs_f64()
            );
            runs.push(run);
        }
    }
    ctx.stage1_sweep = Some(runs);
    Ok(())
}

/// Fig 1: CER vs (λ_rec, λ_nonrec) per regularization type.
pub fn fig1(ctx: &mut Ctx) -> Result<()> {
    sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap();
    let mut csv = Csv::create(&ctx.out, "fig1", &["reg", "lam_rec", "lam_nonrec", "cer"])?;
    println!("\nFig 1 — CER by regularization strength");
    println!("{:>12} {:>10} {:>10} {:>8}", "reg", "lam_rec", "lam_nonrec", "CER");
    for r in runs.iter() {
        println!(
            "{:>12} {:>10.1e} {:>10.1e} {:>8.3}",
            r.reg, r.lam_rec, r.lam_nonrec, r.cer
        );
        csv.row(&[
            r.reg.to_string(),
            format!("{:e}", r.lam_rec),
            format!("{:e}", r.lam_nonrec),
            f(r.cer),
        ])?;
    }
    csv.done();
    Ok(())
}

/// Fig 2: ν of the 3rd GRU's weights vs regularization strength.
pub fn fig2(ctx: &mut Ctx) -> Result<()> {
    sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap();
    let mut csv = Csv::create(
        &ctx.out,
        "fig2",
        &["panel", "reg", "lambda", "nu"],
    )?;
    println!("\nFig 2 — nondimensional trace norm coefficient nu(W), GRU-3");
    println!("  left panel: nonrec2 weight, lam_rec = 0, sweep lam_nonrec");
    for r in runs.iter().filter(|r| r.lam_rec == 0.0) {
        if let Some(d) = r.diags.iter().find(|d| d.base == "nonrec2") {
            println!("   [{:>10}] lambda={:<9.1e} nu={:.3}", r.reg, r.lam_nonrec, d.nu);
            csv.row(&[
                "nonrec".into(),
                r.reg.to_string(),
                format!("{:e}", r.lam_nonrec),
                f(d.nu as f64),
            ])?;
        }
    }
    println!("  right panel: rec2 weight, lam_nonrec = 0, sweep lam_rec");
    for r in runs.iter().filter(|r| r.lam_nonrec == 0.0) {
        if let Some(d) = r.diags.iter().find(|d| d.base == "rec2") {
            println!("   [{:>10}] lambda={:<9.1e} nu={:.3}", r.reg, r.lam_rec, d.nu);
            csv.row(&[
                "rec".into(),
                r.reg.to_string(),
                format!("{:e}", r.lam_rec),
                f(d.nu as f64),
            ])?;
        }
    }
    csv.done();
    Ok(())
}

/// Fig 3: rank@90 % variance vs CER (3rd GRU weights), colored by reg.
pub fn fig3(ctx: &mut Ctx) -> Result<()> {
    sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap();
    let mut csv = Csv::create(
        &ctx.out,
        "fig3",
        &["weight", "reg", "lam_rec", "lam_nonrec", "cer", "rank90", "full_rank"],
    )?;
    println!("\nFig 3 — SVD rank for 90% variance vs CER (GRU-3)");
    println!(
        "{:>8} {:>12} {:>8} {:>8} {:>6}",
        "weight", "reg", "CER", "rank90", "full"
    );
    for r in runs.iter() {
        for base in ["nonrec2", "rec2"] {
            if let Some(d) = r.diags.iter().find(|d| d.base == base) {
                let reg_label = if r.lam_rec == 0.0 && r.lam_nonrec == 0.0 {
                    "unregularized"
                } else {
                    r.reg
                };
                println!(
                    "{:>8} {:>12} {:>8.3} {:>8} {:>6}",
                    base, reg_label, r.cer, d.rank90, d.full
                );
                csv.row(&[
                    base.into(),
                    reg_label.into(),
                    format!("{:e}", r.lam_rec),
                    format!("{:e}", r.lam_nonrec),
                    f(r.cer),
                    d.rank90.to_string(),
                    d.full.to_string(),
                ])?;
            }
        }
    }
    csv.done();
    Ok(())
}

/// Best run of a given reg type (lowest CER among regularized runs).
pub fn best_run<'a>(runs: &'a [SweepRun], reg: &str) -> Option<&'a SweepRun> {
    runs.iter()
        .filter(|r| r.reg == reg && (r.lam_rec != 0.0 || r.lam_nonrec != 0.0))
        .min_by(|a, b| a.cer.partial_cmp(&b.cer).unwrap())
}

/// The unregularized baseline of a given reg family.
pub fn unreg_run<'a>(runs: &'a [SweepRun], reg: &str) -> Option<&'a SweepRun> {
    runs.iter()
        .find(|r| r.reg == reg && r.lam_rec == 0.0 && r.lam_nonrec == 0.0)
}

//! Experiment harness: one module per paper table/figure.
//!
//! Every experiment prints rows mirroring the paper's table/series and
//! writes `results/<id>.csv`.  The mapping from paper artifact to module
//! is in DESIGN.md §5; EXPERIMENTS.md records paper-vs-measured.

pub mod extras;
pub mod fig8;
pub mod kernelsx;
pub mod stage1;
pub mod stage2;
pub mod tables;

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::configx::Config;
use crate::data::{CorpusSpec, Dataset};
use crate::error::{Error, Result};
use crate::runtime::Runtime;

/// Shared experiment context.
pub struct Ctx {
    pub rt: Runtime,
    pub data: Dataset,
    pub out: PathBuf,
    pub cfg: Config,
    /// stage-1 λ-sweep cache shared by figs 1–4 (populated on first use)
    pub stage1_sweep: Option<Vec<stage1::SweepRun>>,
    /// trained deployment tiers shared by Tables 1–2
    pub tiers: Option<Vec<tables::Tier>>,
}

impl Ctx {
    pub fn new(cfg: Config) -> Result<Ctx> {
        let artifacts = cfg.str_or("artifacts", "artifacts");
        let rt = Runtime::open(&artifacts)?;
        let seed = cfg.usize_or("seed", 17) as u64;
        let n_train = cfg.usize_or("exp.n_train", 192);
        let n_dev = cfg.usize_or("exp.n_dev", 48);
        let n_test = cfg.usize_or("exp.n_test", 48);
        let data = Dataset::generate(CorpusSpec::standard(seed), n_train, n_dev, n_test);
        let out = PathBuf::from(cfg.str_or("results", "results"));
        std::fs::create_dir_all(&out)?;
        Ok(Ctx { rt, data, out, cfg, stage1_sweep: None, tiers: None })
    }

    /// Default stage-1 training epochs.
    pub fn epochs1(&self) -> usize {
        self.cfg.usize_or("exp.epochs1", 4)
    }

    /// Default stage-2 training epochs.
    pub fn epochs2(&self) -> usize {
        self.cfg.usize_or("exp.epochs2", 4)
    }

    pub fn lr(&self) -> f32 {
        self.cfg.f64_or("exp.lr", 2e-3) as f32
    }

    pub fn seed(&self) -> u64 {
        self.cfg.usize_or("seed", 17) as u64
    }
}

/// Tiny CSV writer.
pub struct Csv {
    path: PathBuf,
    file: std::fs::File,
}

impl Csv {
    pub fn create(dir: &Path, name: &str, header: &[&str]) -> Result<Csv> {
        let path = dir.join(format!("{name}.csv"));
        let mut file = std::fs::File::create(&path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(Csv { path, file })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn done(self) -> PathBuf {
        println!("  -> wrote {}", self.path.display());
        self.path
    }
}

/// Format helper for CSV fields.
pub fn f(v: f64) -> String {
    format!("{v:.6}")
}

/// Experiment ids in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "table1", "table2", "fig6", "fig7",
    "fig8", "table3",
];

/// Extension experiments beyond the paper's numbered artifacts.
pub const EXTRAS: &[&str] = &["ablation-schemes", "latency", "paper-dims"];

/// Dispatch experiments by id: "all", "extras", a single id, or a
/// comma-separated list (which shares one sweep/tier cache).
pub fn run(id: &str, cfg: Config) -> Result<()> {
    let mut ctx = Ctx::new(cfg)?;
    let ids: Vec<&str> = match id {
        "all" => ALL.to_vec(),
        "extras" => EXTRAS.to_vec(),
        other => other.split(',').map(|s| s.trim()).collect(),
    };
    for x in &ids {
        if ids.len() > 1 {
            println!("\n=== experiment {x} ===");
        }
        run_in(&mut ctx, x)?;
    }
    Ok(())
}

fn run_in(ctx: &mut Ctx, id: &str) -> Result<()> {
    match id {
        // figs 1-3 share the stage-1 sweep; each re-renders its view
        "fig1" => stage1::fig1(ctx),
        "fig2" => stage1::fig2(ctx),
        "fig3" => stage1::fig3(ctx),
        "fig4" => stage2::fig4(ctx),
        "fig5" => stage2::fig5(ctx),
        "table1" => tables::table1(ctx),
        "table2" => tables::table2(ctx),
        "table3" => tables::table3(ctx),
        "fig6" => kernelsx::fig6(ctx),
        "fig7" => kernelsx::fig7(ctx),
        "fig8" => fig8::fig8(ctx),
        "ablation-schemes" => extras::ablation_schemes(ctx),
        "latency" => extras::latency(ctx),
        "paper-dims" => extras::paper_dims(ctx),
        other => Err(Error::other(format!(
            "unknown experiment '{other}' (known: {}, {})",
            ALL.join(", "),
            EXTRAS.join(", ")
        ))),
    }
}

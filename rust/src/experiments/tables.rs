//! Tables 1–3: the production-grade model tiers and the factorization
//! ablation.
//!
//! * **Table 1** — WER of baseline + three compressed acoustic-model tiers
//!   under one shared ("server-grade") language model.
//! * **Table 2** — per-device deployment: tier WER with the device-sized
//!   LM, speedup over realtime (devicesim roofline projection of the
//!   embedded engine), and % time in the acoustic model.
//! * **Table 3** — partially-joint vs completely-split factorization.

use crate::data::Batcher;
use crate::devicesim::{self, Device};
use crate::error::Result;
use crate::infer::{Breakdown, Engine, Precision};
use crate::kernels::GemmCounts;
use crate::lm::CharLm;
use crate::model::{pick_rank_frac, warmstart, ParamSet};
use crate::serve::{self, ServeConfig};
use crate::train::{eval_name, frac_tag, Evaluator, TrainOpts, Trainer};

use super::stage1::{self, TRACE};
use super::{f, Csv, Ctx};

/// Audio frame hop: 10 ms (standard filterbank rate; the corpus renders
/// one feature frame per hop).
pub const FRAME_HOP_SECS: f64 = 0.01;

/// A trained deployment tier.
#[derive(Clone)]
pub struct Tier {
    pub name: &'static str,
    pub family: &'static str, // artifact family for stage 2
    pub config: &'static str, // manifest config name
    pub params: ParamSet,
    pub scheme: String,
    pub n_params: usize,
    pub eval_artifact: String,
}

/// Train the tier set: baseline (dense, regularized) + three compressed
/// tiers.  tier-3 uses the "fast" (stride-doubled, Gram-CTC analog)
/// config: larger than tier-2 but faster (App. B.4).  Cached on the
/// context so Tables 1 and 2 share one training pass.
pub fn train_tiers(ctx: &mut Ctx) -> Result<()> {
    if ctx.tiers.is_some() {
        return Ok(());
    }
    stage1::sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap().clone();
    let best_l2 = stage1::best_run(&runs, super::stage1::L2).unwrap().clone();
    let best_trace = stage1::best_run(&runs, TRACE).unwrap().clone();
    let epochs = ctx.epochs2();

    let mut tiers = Vec::new();

    // baseline: the best dense stage-1 model (the "server" acoustic model)
    tiers.push(Tier {
        name: "baseline",
        family: "train_mini_unfact",
        config: "wsj_mini",
        n_params: best_l2.params.num_scalars(),
        scheme: "unfactored".into(),
        eval_artifact: "eval_mini_unfact".into(),
        params: best_l2.params.clone(),
    });

    // tier-1 / tier-2: trace-norm stage-2 at moderate/aggressive rank
    for (name, th) in [("tier-1", 0.85f64), ("tier-2", 0.5)] {
        let frac = pick_rank_frac(&best_trace.params, th, &ctx.rt.manifest().rank_ladder)?;
        let artifact = format!("train_mini_partial_{}", frac_tag(frac));
        let spec = ctx.rt.manifest().artifact(&artifact)?.clone();
        let p0 = warmstart(&best_trace.params, &spec, ctx.seed() + 2)?;
        let opts = TrainOpts {
            seed: ctx.seed(),
            lr: (best_trace.final_lr * 3.0).min(ctx.lr()),
            lr_decay: 0.92,
            epochs,
            quiet: true,
            ..Default::default()
        };
        let mut batcher = Batcher::new(
            &ctx.data.train,
            spec.batch.unwrap(),
            ctx.data.spec.feat_dim,
            ctx.seed() ^ 0x71,
        );
        let mut t = Trainer::with_params(&ctx.rt, &artifact, p0, opts)?;
        t.run(&mut batcher, None, None)?;
        tiers.push(Tier {
            name,
            family: "train_mini_partial",
            config: "wsj_mini",
            n_params: t.params.num_scalars(),
            scheme: "partial".into(),
            eval_artifact: eval_name(&artifact),
            params: t.params,
        });
    }

    // tier-3: the fast (extra-stride) config, trace-norm two-stage.
    // Stride 8 halves the output frame rate below the corpus's character
    // rate (4–9 frames/char), which plain CTC cannot align.  The paper
    // solves exactly this with Gram-CTC: multi-character output units
    // halve the *label* rate (App. B.4).  We emulate the same label-rate /
    // frame-rate ratio by rendering the fast tier's corpus at doubled
    // character durations — the compute story (×2 faster GRUs per audio
    // second) is unchanged, which is what Tables 1–2 measure.
    {
        let fast_data = fast_dataset(ctx);
        let fast_train = filter_ctc_feasible(&fast_data.train, 8);
        let art1 = "train_fast_partial_full";
        let spec1 = ctx.rt.manifest().artifact(art1)?.clone();
        let opts1 = TrainOpts {
            seed: ctx.seed(),
            lr: ctx.lr(),
            lr_decay: 0.92,
            epochs: ctx.epochs1(),
            lam_rec: best_trace.lam_rec,
            lam_nonrec: best_trace.lam_nonrec,
            quiet: true,
        };
        let mut batcher = Batcher::new(
            &fast_train,
            spec1.batch.unwrap(),
            ctx.data.spec.feat_dim,
            ctx.seed() ^ 0x72,
        );
        let mut t1 = Trainer::new(&ctx.rt, art1, opts1)?;
        t1.run(&mut batcher, None, None)?;
        let frac = pick_rank_frac(&t1.params, 0.5, &[0.25, 0.5])?;
        let artifact = format!("train_fast_partial_{}", frac_tag(frac));
        let spec2 = ctx.rt.manifest().artifact(&artifact)?.clone();
        let p0 = warmstart(&t1.params, &spec2, ctx.seed() + 3)?;
        let opts2 = TrainOpts {
            seed: ctx.seed(),
            lr: (t1.lr * 3.0).min(ctx.lr()),
            lr_decay: 0.92,
            epochs,
            quiet: true,
            ..Default::default()
        };
        let mut t2 = Trainer::with_params(&ctx.rt, &artifact, p0, opts2)?;
        t2.run(&mut batcher, None, None)?;
        tiers.push(Tier {
            name: "tier-3",
            family: "train_fast_partial",
            config: "wsj_mini_fast",
            n_params: t2.params.num_scalars(),
            scheme: "partial".into(),
            eval_artifact: eval_name(&artifact),
            params: t2.params,
        });
    }

    ctx.tiers = Some(tiers);
    Ok(())
}

/// The Gram-CTC-analog corpus for the stride-8 "fast" config: same text
/// distribution, doubled character durations (label rate halved relative
/// to the frame rate, as Gram-CTC's multi-char units do).  Deterministic
/// in the experiment seed.
pub fn fast_dataset(ctx: &Ctx) -> crate::data::Dataset {
    let mut spec = crate::data::CorpusSpec::standard(ctx.seed() ^ 0xfa57);
    spec.dur_min = 9;
    spec.dur_max = 15;
    spec.feasibility_stride = 8;
    crate::data::Dataset::generate(
        spec,
        ctx.data.train.len(),
        ctx.data.dev.len(),
        ctx.data.test.len(),
    )
}

/// Keep utterances whose CTC alignment is feasible at `stride`:
/// output steps ≥ labels + repeated-label blanks (+1 slack).
fn filter_ctc_feasible(utts: &[crate::data::Utterance], stride: usize) -> Vec<crate::data::Utterance> {
    utts.iter()
        .filter(|u| {
            let t_out = u.feats.shape()[0] / stride;
            let repeats = u.labels.windows(2).filter(|w| w[0] == w[1]).count();
            t_out >= u.labels.len() + repeats + 1
        })
        .cloned()
        .collect()
}

/// Table 1: tier WERs under the shared server-grade LM.
pub fn table1(ctx: &mut Ctx) -> Result<()> {
    train_tiers(ctx)?;
    let tiers = ctx.tiers.as_ref().unwrap().clone();
    let texts = ctx.data.train_texts();
    let server_lm = CharLm::train(&texts, 4, 0);
    let beam = ctx.cfg.usize_or("exp.beam", 8);

    let mut csv = Csv::create(&ctx.out, "table1", &["model", "params", "wer", "rel"])?;
    println!("\nTable 1 — WER of low-rank tiers, shared server LM");
    println!("{:>10} {:>12} {:>8} {:>10}", "model", "params", "WER", "% rel");
    let fast_test = fast_dataset(ctx).test;
    let mut base_wer = None;
    for t in &tiers {
        let eval = Evaluator::new(&ctx.rt, &t.eval_artifact)?;
        // tier-3 is evaluated on its Gram-CTC-analog corpus (see
        // train_tiers) — same text distribution, halved label rate.
        let test: &[crate::data::Utterance] =
            if t.config == "wsj_mini_fast" { &fast_test } else { &ctx.data.test };
        let stats = eval.beam_cer(&t.params, test, beam, Some(&server_lm), 0.8)?;
        let wer = stats.wer();
        let base = *base_wer.get_or_insert(wer);
        let rel = if base > 0.0 { (base - wer) / base * 100.0 } else { 0.0 };
        println!(
            "{:>10} {:>12} {:>8.3} {:>9.1}%",
            t.name, t.n_params, wer, rel
        );
        csv.row(&[t.name.into(), t.n_params.to_string(), f(wer), f(rel)])?;
    }
    csv.done();
    Ok(())
}

/// Host device model for projecting measured kernel efficiency.
fn host() -> Device {
    devicesim::host_device(50.0, 10.0)
}

/// Table 2: per-device embedded deployment.
pub fn table2(ctx: &mut Ctx) -> Result<()> {
    train_tiers(ctx)?;
    let tiers = ctx.tiers.as_ref().unwrap().clone();
    let texts = ctx.data.train_texts();
    let beam = ctx.cfg.usize_or("exp.beam", 8);

    // device rows: (device, tier index, LM pruning) — mirroring the paper's
    // pairing of stronger devices with bigger models/LMs
    let rows: Vec<(&Device, usize, usize, u32)> = vec![
        (&devicesim::IPHONE7, 1, 4, 0),  // tier-1, unpruned order-4 LM
        (&devicesim::IPHONE6, 2, 3, 2),  // tier-2, pruned order-3
        (&devicesim::RPI3, 3, 2, 4),     // tier-3, heavily pruned order-2
    ];

    let mut csv = Csv::create(
        &ctx.out,
        "table2",
        &[
            "device", "acoustic_model", "lm_bytes", "wer", "rel",
            "speedup_over_realtime", "pct_time_acoustic",
        ],
    )?;
    println!("\nTable 2 — embedded deployment per device");
    println!(
        "{:>15} {:>10} {:>9} {:>7} {:>7} {:>9} {:>8}",
        "device", "model", "LM(B)", "WER", "%rel", "RT-x", "%AM"
    );

    // server row: PJRT path + serving sim, baseline acoustic model
    {
        let base = &tiers[0];
        let server_lm = CharLm::train(&texts, 4, 0);
        let eval = Evaluator::new(&ctx.rt, &base.eval_artifact)?;
        let stats =
            eval.beam_cer(&base.params, &ctx.data.test, beam, Some(&server_lm), 0.8)?;
        let wer = stats.wer();
        // serving throughput -> realtime factor for the server row
        let report = serve::simulate(
            &ctx.rt,
            &base.eval_artifact,
            &base.params,
            &ctx.data.test,
            &ServeConfig::default(),
        )?;
        let audio_secs: f64 = ctx
            .data
            .test
            .iter()
            .map(|u| u.feats.shape()[0] as f64 * FRAME_HOP_SECS)
            .sum();
        let rtx = audio_secs / report.busy_secs.max(1e-9);
        println!(
            "{:>15} {:>10} {:>9} {:>7.3} {:>7.1} {:>9.2} {:>8.1}",
            "GPU server", "baseline", server_lm.size_bytes(), wer, 0.0, rtx, 70.8
        );
        csv.row(&[
            "GPU server".into(),
            "baseline".into(),
            server_lm.size_bytes().to_string(),
            f(wer),
            f(0.0),
            f(rtx),
            f(70.8),
        ])?;
    }

    let base_wer = {
        let base = &tiers[0];
        let server_lm = CharLm::train(&texts, 4, 0);
        let eval = Evaluator::new(&ctx.rt, &base.eval_artifact)?;
        eval.beam_cer(&base.params, &ctx.data.test, beam, Some(&server_lm), 0.8)?.wer()
    };

    let fast_test = fast_dataset(ctx).test;
    for (device, tier_idx, lm_order, lm_prune) in rows {
        let tier = &tiers[tier_idx];
        let dims = ctx.rt.manifest().dims(tier.config)?.clone();
        let lm = CharLm::train(&texts, lm_order, lm_prune);
        let engine =
            Engine::from_params(&dims, &tier.scheme, &tier.params, Precision::Int8, 4)?;
        let test: &[crate::data::Utterance] =
            if tier.config == "wsj_mini_fast" { &fast_test } else { &ctx.data.test };

        // int8 engine inference over the test set, with beam+LM decode
        let mut bd = Breakdown::default();
        let mut stats = crate::decoder::ErrorStats::default();
        let mut decode_secs = 0.0f64;
        for u in test {
            let (_, rows_lp) = engine.transcribe(&u.feats, &mut bd)?;
            let t = rows_lp.len();
            let flat: Vec<f32> = rows_lp.iter().flatten().copied().collect();
            let logp = crate::tensor::Tensor::new(&[t, dims.vocab], flat)?;
            let t0 = std::time::Instant::now();
            let hyp = crate::decoder::transcript_beam(&logp, t, beam, Some(&lm), 0.8);
            decode_secs += t0.elapsed().as_secs_f64();
            stats.push(&hyp, &u.text);
        }
        let wer = stats.wer();
        let rel = (base_wer - wer) / base_wer.max(1e-9) * 100.0;

        // devicesim projection: keep the host-measured fraction-of-roofline
        // and swap in the device's roofline (DESIGN.md §3)
        let counts = GemmCounts {
            macs: bd.macs,
            bytes_read: (engine.model_bytes() as u64)
                .saturating_mul(bd.frames / dims.total_stride as u64 / 4),
            bytes_written: 0,
        };
        let host_secs = bd.acoustic_total();
        let dev_secs = device.project_from_host(&counts, &host(), host_secs);
        let audio = bd.frames as f64 * FRAME_HOP_SECS;
        // decode/LM time scales with the compute roofline ratio
        let scale = dev_secs / host_secs.max(1e-12);
        let dev_decode = decode_secs * scale.min(20.0);
        let rtx = audio / (dev_secs + dev_decode).max(1e-12);
        let pct_am = dev_secs / (dev_secs + dev_decode) * 100.0;

        println!(
            "{:>15} {:>10} {:>9} {:>7.3} {:>7.1} {:>9.2} {:>8.1}",
            device.name, tier.name, lm.size_bytes(), wer, rel, rtx, pct_am
        );
        csv.row(&[
            device.name.into(),
            tier.name.into(),
            lm.size_bytes().to_string(),
            f(wer),
            f(rel),
            f(rtx),
            f(pct_am),
        ])?;
    }
    csv.done();
    Ok(())
}

/// Table 3: partially-joint vs completely-split factorization.
pub fn table3(ctx: &mut Ctx) -> Result<()> {
    stage1::sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap().clone();
    let best = stage1::best_run(&runs, TRACE).unwrap().clone();
    let thresholds = [0.5, 0.6, 0.7, 0.8];
    let epochs = ctx.epochs2();

    // split-scheme stage 1 (same λs)
    let art1 = "train_mini_split_full";
    let spec1 = ctx.rt.manifest().artifact(art1)?.clone();
    let opts1 = TrainOpts {
        seed: ctx.seed(),
        lr: ctx.lr(),
        lr_decay: 0.92,
        epochs: ctx.epochs1(),
        lam_rec: best.lam_rec,
        lam_nonrec: best.lam_nonrec,
        quiet: true,
    };
    let mut batcher = Batcher::new(
        &ctx.data.train,
        spec1.batch.unwrap(),
        ctx.data.spec.feat_dim,
        ctx.seed() ^ 0x73,
    );
    let mut t_split = Trainer::new(&ctx.rt, art1, opts1)?;
    t_split.run(&mut batcher, None, None)?;

    let mut csv = Csv::create(
        &ctx.out,
        "table3",
        &["svd_threshold", "split_params", "split_cer", "partial_params", "partial_cer"],
    )?;
    println!("\nTable 3 — completely-split vs partially-joint factorization");
    println!(
        "{:>10} | {:>12} {:>8} | {:>12} {:>8}",
        "threshold", "split prms", "CER", "partial prms", "CER"
    );
    for &th in &thresholds {
        // split stage 2
        let frac_s = pick_rank_frac(&t_split.params, th, &[0.25, 0.5])?;
        let art_s = format!("train_mini_split_{}", frac_tag(frac_s));
        let spec_s = ctx.rt.manifest().artifact(&art_s)?.clone();
        let p_s = warmstart(&t_split.params, &spec_s, ctx.seed() + 4)?;
        let opts = TrainOpts {
            seed: ctx.seed(),
            lr: (t_split.lr * 3.0).min(ctx.lr()),
            lr_decay: 0.92,
            epochs,
            quiet: true,
            ..Default::default()
        };
        let mut tr_s = Trainer::with_params(&ctx.rt, &art_s, p_s, opts.clone())?;
        tr_s.run(&mut batcher, None, None)?;
        let cer_s = Evaluator::new(&ctx.rt, &eval_name(&art_s))?
            .greedy_cer(&tr_s.params, &ctx.data.dev)?
            .cer();

        // partial stage 2 from the best partial stage-1
        let frac_p = pick_rank_frac(&best.params, th, &ctx.rt.manifest().rank_ladder)?;
        let art_p = format!("train_mini_partial_{}", frac_tag(frac_p));
        let spec_p = ctx.rt.manifest().artifact(&art_p)?.clone();
        let p_p = warmstart(&best.params, &spec_p, ctx.seed() + 5)?;
        let mut tr_p = Trainer::with_params(&ctx.rt, &art_p, p_p, opts)?;
        tr_p.run(&mut batcher, None, None)?;
        let cer_p = Evaluator::new(&ctx.rt, &eval_name(&art_p))?
            .greedy_cer(&tr_p.params, &ctx.data.dev)?
            .cer();

        println!(
            "{:>10.2} | {:>12} {:>8.3} | {:>12} {:>8.3}",
            th,
            tr_s.params.num_scalars(),
            cer_s,
            tr_p.params.num_scalars(),
            cer_p
        );
        csv.row(&[
            f(th),
            tr_s.params.num_scalars().to_string(),
            f(cer_s),
            tr_p.params.num_scalars().to_string(),
            f(cer_p),
        ])?;
    }
    csv.done();
    Ok(())
}

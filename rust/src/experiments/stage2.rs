//! Figures 4–5: stage-2 (low-rank) experiments.
//!
//! * **Fig 4** — params vs CER of stage-2 models warmstarted from the best
//!   trace-norm / ℓ² / unregularized stage-1 models at several SVD
//!   explained-variance thresholds.
//! * **Fig 5** — fixed parameter target and fixed total epoch budget;
//!   sweep the stage-1→2 transition epoch (left panel) and record the CER
//!   trajectory across the transition (right panel).

use crate::data::Batcher;
use crate::error::Result;
use crate::model::{pick_rank_frac, warmstart};
use crate::train::{eval_name, frac_tag, Evaluator, Stage2Lr, TrainOpts, Trainer};

use super::{f, Csv, Ctx};
use super::stage1::{self, SweepRun, L2, TRACE};

/// Train a stage-2 model warmstarted from `run` at `threshold`; returns
/// (params count, dev CER, rank_frac).
fn stage2_from(
    ctx: &Ctx,
    run: &SweepRun,
    threshold: f64,
    epochs: usize,
) -> Result<(usize, f64, f64)> {
    let frac = pick_rank_frac(&run.params, threshold, &ctx.rt.manifest().rank_ladder)?;
    let artifact = format!("train_mini_partial_{}", frac_tag(frac));
    let spec = ctx.rt.manifest().artifact(&artifact)?.clone();
    let params = warmstart(&run.params, &spec, ctx.seed() + 1)?;
    let opts = TrainOpts {
        seed: ctx.seed(),
        // §3.2.2: stage-2 initial LR = 3x the final stage-1 LR
        lr: (run.final_lr * 3.0).min(ctx.lr()),
        lr_decay: 0.92,
        epochs,
        lam_rec: 0.0,
        lam_nonrec: 0.0,
        quiet: true,
    };
    let mut batcher = Batcher::new(
        &ctx.data.train,
        spec.batch.unwrap(),
        ctx.data.spec.feat_dim,
        ctx.seed() ^ 0x52,
    );
    let eval = Evaluator::new(&ctx.rt, &eval_name(&artifact))?;
    let mut t = Trainer::with_params(&ctx.rt, &artifact, params, opts)?;
    t.run(&mut batcher, None, None)?;
    let cer = eval.greedy_cer(&t.params, &ctx.data.dev)?.cer();
    Ok((t.params.num_scalars(), cer, frac))
}

/// Fig 4: number of parameters vs CER by stage-1 regularization type.
pub fn fig4(ctx: &mut Ctx) -> Result<()> {
    stage1::sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap().clone();
    let thresholds = [0.5, 0.7, 0.85, 0.95];
    let epochs = ctx.epochs2();

    let mut csv = Csv::create(
        &ctx.out,
        "fig4",
        &["stage1_reg", "threshold", "rank_frac", "params", "cer"],
    )?;
    println!("\nFig 4 — stage-2 params vs CER by stage-1 regularization");
    println!(
        "{:>14} {:>10} {:>10} {:>10} {:>8}",
        "stage1", "threshold", "rank_frac", "params", "CER"
    );
    let sources: Vec<(&str, &SweepRun)> = [
        stage1::best_run(&runs, TRACE).map(|r| (TRACE, r)),
        stage1::best_run(&runs, L2).map(|r| (L2, r)),
        stage1::unreg_run(&runs, L2).map(|r| ("unregularized", r)),
    ]
    .into_iter()
    .flatten()
    .collect();

    for (label, run) in sources {
        for &th in &thresholds {
            let (params, cer, frac) = stage2_from(ctx, run, th, epochs)?;
            println!(
                "{label:>14} {th:>10.2} {frac:>10.3} {params:>10} {cer:>8.3}"
            );
            csv.row(&[
                label.into(),
                f(th),
                f(frac),
                params.to_string(),
                f(cer),
            ])?;
        }
    }
    csv.done();
    Ok(())
}

/// Fig 5: transition-epoch sweep under a fixed total budget, plus the
/// convergence trace across the transition.
pub fn fig5(ctx: &mut Ctx) -> Result<()> {
    stage1::sweep(ctx)?;
    let runs = ctx.stage1_sweep.as_ref().unwrap().clone();
    let total = ctx.cfg.usize_or("exp.fig5_total", ctx.epochs1() + ctx.epochs2());
    let transitions: Vec<usize> = (1..total).step_by(2.max(total / 4)).collect();
    let target_frac = 0.25; // the fixed "3M-parameter" analog

    let mut csv = Csv::create(
        &ctx.out,
        "fig5",
        &["reg", "transition_epoch", "final_cer"],
    )?;
    let mut curve_csv = Csv::create(
        &ctx.out,
        "fig5_curve",
        &["reg", "epoch", "stage", "dev_cer"],
    )?;

    println!("\nFig 5 (left) — final CER vs transition epoch (budget {total} epochs)");
    for reg in [TRACE, L2] {
        let best = stage1::best_run(&runs, reg).expect("sweep has regularized runs");
        let (lam_rec, lam_nonrec) = (best.lam_rec, best.lam_nonrec);
        for &te in &transitions {
            let (final_cer, curve) =
                transition_run(ctx, reg, lam_rec, lam_nonrec, te, total, target_frac)?;
            println!("  [{reg:>10}] transition {te:>2}  final CER {final_cer:.3}");
            csv.row(&[reg.into(), te.to_string(), f(final_cer)])?;
            // record the curve for the middle transition (right panel)
            if te == transitions[transitions.len() / 2] {
                for (epoch, stage, cer) in curve {
                    curve_csv.row(&[reg.into(), epoch.to_string(), stage, f(cer)])?;
                }
            }
        }
    }
    csv.done();
    curve_csv.done();
    Ok(())
}

/// One fixed-budget run with transition at `te`; returns final CER and the
/// per-epoch (epoch, stage, dev CER) curve.
fn transition_run(
    ctx: &Ctx,
    reg: &'static str,
    lam_rec: f32,
    lam_nonrec: f32,
    te: usize,
    total: usize,
    target_frac: f64,
) -> Result<(f64, Vec<(usize, String, f64)>)> {
    let stage1_art = stage1::artifact_for(reg);
    let spec1 = ctx.rt.manifest().artifact(stage1_art)?.clone();
    let mut batcher = Batcher::new(
        &ctx.data.train,
        spec1.batch.unwrap(),
        ctx.data.spec.feat_dim,
        ctx.seed() ^ 0x55,
    );
    let eval1 = Evaluator::new(&ctx.rt, &eval_name(stage1_art))?;
    let opts1 = TrainOpts {
        seed: ctx.seed(),
        lr: ctx.lr(),
        lr_decay: 0.92,
        epochs: te,
        lam_rec,
        lam_nonrec,
        quiet: true,
    };
    let mut t1 = Trainer::new(&ctx.rt, stage1_art, opts1)?;
    let mut curve = Vec::new();
    for e in 0..te {
        t1.run_one_epoch(&mut batcher, None, None)?;
        let cer = eval1.greedy_cer(&t1.params, &ctx.data.dev)?.cer();
        curve.push((e, "stage1".to_string(), cer));
    }

    // transition at the fixed target rank (Fig 5 keeps the size fixed)
    let artifact2 = format!("train_mini_partial_{}", frac_tag(target_frac));
    let spec2 = ctx.rt.manifest().artifact(&artifact2)?.clone();
    let params2 = warmstart(&t1.params, &spec2, ctx.seed() + 1)?;
    let eval2 = Evaluator::new(&ctx.rt, &eval_name(&artifact2))?;
    let opts2 = TrainOpts {
        seed: ctx.seed(),
        // §3.2.3: LR continues the stage-1 schedule
        lr: t1.lr,
        lr_decay: 0.92,
        epochs: total - te,
        lam_rec: 0.0,
        lam_nonrec: 0.0,
        quiet: true,
    };
    let mut t2 = Trainer::with_params(&ctx.rt, &artifact2, params2, opts2)?;
    let mut final_cer = f64::NAN;
    for e in te..total {
        t2.run_one_epoch(&mut batcher, None, None)?;
        let cer = eval2.greedy_cer(&t2.params, &ctx.data.dev)?.cer();
        curve.push((e, "stage2".to_string(), cer));
        final_cer = cer;
    }
    let _ = Stage2Lr::Continuation; // documented choice above
    Ok((final_cer, curve))
}

//! Extension experiments beyond the paper's numbered figures:
//!
//! * `ablation-schemes` — App. B.2 discussion as data: stage-1 training
//!   under all four factorization schemes at matched λ.
//! * `latency` — the §4 time-batching trade-off measured on the *server*
//!   (PJRT stream artifacts, chunk 4/8/16) and the embedded engine.
//! * `paper-dims` — analytic §Perf companion: MACs/bytes of the published
//!   model dimensions projected onto the paper's devices (no training).

use crate::data::Batcher;
use crate::devicesim::{self};
use crate::error::Result;
use crate::infer::{Breakdown, Engine, Precision};
use crate::kernels::GemmCounts;
use crate::model::ParamSet;
use crate::runtime::Value;
use crate::tensor::Tensor;
use crate::train::{eval_name, Evaluator, TrainOpts, Trainer};

use super::{f, Csv, Ctx};

/// Stage-1 CER under each factorization scheme at matched λ (App. B.2).
pub fn ablation_schemes(ctx: &mut Ctx) -> Result<()> {
    let mut csv = Csv::create(
        &ctx.out,
        "ablation_schemes",
        &["scheme", "params", "cer", "mean_loss"],
    )?;
    println!("\nAblation — factorization schemes (stage 1, matched lambda)");
    println!("{:>12} {:>10} {:>8} {:>10}", "scheme", "params", "CER", "loss");
    for (scheme, artifact) in [
        ("unfactored", "train_mini_unfact"),
        ("partial", "train_mini_partial_full"),
        ("split", "train_mini_split_full"),
        ("joint", "train_mini_joint_full"),
    ] {
        let spec = ctx.rt.manifest().artifact(artifact)?.clone();
        let opts = TrainOpts {
            seed: ctx.seed(),
            lr: ctx.lr(),
            lr_decay: 0.92,
            epochs: ctx.epochs1(),
            lam_rec: 3e-4,
            lam_nonrec: 3e-4,
            quiet: true,
        };
        let mut batcher = Batcher::new(
            &ctx.data.train,
            spec.batch.unwrap(),
            ctx.data.spec.feat_dim,
            ctx.seed() ^ 0x91,
        );
        let mut t = Trainer::new(&ctx.rt, artifact, opts)?;
        t.run(&mut batcher, None, None)?;
        let cer = Evaluator::new(&ctx.rt, &eval_name(artifact))?
            .greedy_cer(&t.params, &ctx.data.dev)?
            .cer();
        let loss = t.history.last().map(|l| l.mean_loss).unwrap_or(f64::NAN);
        println!("{:>12} {:>10} {:>8.3} {:>10.4}", scheme, t.params.num_scalars(), cer, loss);
        csv.row(&[scheme.into(), t.params.num_scalars().to_string(), f(cer), f(loss)])?;
    }
    csv.done();
    Ok(())
}

/// Chunk-size (time-batching) latency on the PJRT stream artifacts.
pub fn latency(ctx: &mut Ctx) -> Result<()> {
    let mut csv = Csv::create(
        &ctx.out,
        "latency",
        &["path", "chunk_frames", "ms_per_chunk", "ms_per_frame", "first_output_ms"],
    )?;
    println!("\nLatency — time-batching on the server (PJRT) and embedded paths");
    println!(
        "{:>10} {:>8} {:>12} {:>12} {:>14}",
        "path", "chunk", "ms/chunk", "ms/frame", "1st-output ms"
    );
    for chunk in [4usize, 8, 16] {
        let name = format!("stream_mini_partial_r250_c{chunk}");
        let loaded = ctx.rt.load(&name)?;
        let dims = ctx.rt.manifest().dims("wsj_mini")?.clone();
        let params = ParamSet::init(&loaded.spec, 1)?;
        let mut inputs = params.values_in_order(&loaded.spec.param_names)?;
        for &h in &dims.gru_dims {
            inputs.push(Value::F32(Tensor::zeros(&[1, h])));
        }
        let mut rng = crate::prng::Pcg64::seeded(2);
        inputs.push(Value::F32(Tensor::randn(&[1, chunk, dims.feat_dim], 0.5, &mut rng)));
        loaded.run(&inputs)?; // warm
        let reps = 30;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            std::hint::black_box(loaded.run(&inputs)?);
        }
        let per_chunk = t0.elapsed().as_secs_f64() / reps as f64;
        // first output needs one full chunk of audio + one chunk compute
        let first = chunk as f64 * 10.0 + per_chunk * 1e3;
        println!(
            "{:>10} {:>8} {:>12.3} {:>12.3} {:>14.1}",
            "pjrt", chunk, per_chunk * 1e3, per_chunk * 1e3 / chunk as f64, first
        );
        csv.row(&[
            "pjrt".into(),
            chunk.to_string(),
            f(per_chunk * 1e3),
            f(per_chunk * 1e3 / chunk as f64),
            f(first),
        ])?;
    }

    // embedded engine, same sweep
    let dims = ctx.rt.manifest().dims("wsj_mini")?.clone();
    let spec = ctx.rt.manifest().artifact("train_mini_partial_r250")?.clone();
    let params = ParamSet::init(&spec, 1)?;
    for tb in [1usize, 2, 4] {
        let chunk = tb * dims.total_stride;
        let engine = Engine::from_params(&dims, "partial", &params, Precision::Int8, tb)?;
        let mut rng = crate::prng::Pcg64::seeded(3);
        let frames = Tensor::randn(&[chunk, dims.feat_dim], 0.5, &mut rng);
        let mut bd = Breakdown::default();
        let mut state = engine.new_state();
        engine.stream(&mut state, frames.data(), &mut bd)?; // warm
        let reps = 200;
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            let mut st = engine.new_state();
            std::hint::black_box(engine.stream(&mut st, frames.data(), &mut bd)?);
        }
        let per_chunk = t0.elapsed().as_secs_f64() / reps as f64;
        let first = chunk as f64 * 10.0 + per_chunk * 1e3;
        println!(
            "{:>10} {:>8} {:>12.3} {:>12.3} {:>14.1}",
            "embedded", chunk, per_chunk * 1e3, per_chunk * 1e3 / chunk as f64, first
        );
        csv.row(&[
            "embedded".into(),
            chunk.to_string(),
            f(per_chunk * 1e3),
            f(per_chunk * 1e3 / chunk as f64),
            f(first),
        ])?;
    }
    println!("  (larger chunks amortize the non-recurrent GEMM but delay the first output —\n   the paper's reason for capping time-batching near 4)");
    csv.done();
    Ok(())
}

/// Analytic device projection for the *published* model dimensions.
pub fn paper_dims(ctx: &mut Ctx) -> Result<()> {
    let dims = ctx.rt.manifest().dims("paper")?.clone();
    let mut csv = Csv::create(
        &ctx.out,
        "paper_dims",
        &["rank_frac", "macs_per_step", "weight_mb_int8", "device", "est_rt_x"],
    )?;
    println!("\nPaper-dims estimate — published model (GRU 768/1024/1280, FC 1536), int8");
    println!(
        "{:>10} {:>14} {:>12} {:>16} {:>9}",
        "rank_frac", "MACs/step", "weights MB", "device", "est RT-x"
    );
    for frac in [1.0f64, 0.25] {
        // per-step MACs: conv (amortized per output step) + GRUs + FC + out
        let mut macs: f64 = 0.0;
        let mut bytes: f64 = 0.0; // int8 weight bytes
        let mut prev = dims.feat_dim;
        let mut steps_per_out = dims.total_stride;
        for c in &dims.conv {
            steps_per_out /= c.context;
            let m = (c.dim * c.context * prev) as f64;
            macs += m * (steps_per_out.max(1)) as f64;
            bytes += m;
            prev = c.dim;
        }
        let mut din = prev;
        for &h in &dims.gru_dims {
            for (rows, cols) in [(3 * h, h), (3 * h, din)] {
                let full = rows.min(cols) as f64;
                let r = (full * frac).round();
                let (m, b) = if frac >= 1.0 {
                    ((rows * cols) as f64, (rows * cols) as f64)
                } else {
                    (
                        r * (rows + cols) as f64,
                        r * (rows + cols) as f64,
                    )
                };
                macs += m;
                bytes += b;
            }
            din = h;
        }
        let fc = (dims.fc_dim * din) as f64;
        let out = (dims.vocab * dims.fc_dim) as f64;
        macs += fc * frac.min(1.0) * if frac < 1.0 { 2.0 } else { 1.0 } + out;
        bytes += fc + out;

        for dev in devicesim::ALL_EMBEDDED {
            // 100 steps/s of output (10 ms frames, stride amortized inside)
            let steps_per_sec = 100.0 / dims.total_stride as f64;
            let counts = GemmCounts {
                macs: (macs * steps_per_sec) as u64,
                bytes_read: (bytes * steps_per_sec) as u64,
                bytes_written: 0,
            };
            let secs = dev.roofline_secs(&counts);
            let rtx = 1.0 / secs;
            println!(
                "{:>10.2} {:>14.0} {:>12.1} {:>16} {:>9.2}",
                frac,
                macs,
                bytes / 1e6,
                dev.name,
                rtx
            );
            csv.row(&[
                f(frac),
                format!("{macs:.0}"),
                f(bytes / 1e6),
                dev.name.into(),
                f(rtx),
            ])?;
        }
    }
    println!("  (shape check: full-rank int8 barely reaches realtime on RPi-3-class devices;\n   rank-0.25 factorization recovers the paper's >1x margins)");
    csv.done();
    Ok(())
}

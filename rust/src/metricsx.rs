//! Timers, counters, latency histograms and pool-occupancy tracking for
//! the coordinator and the serving/inference paths.  The stream-pool
//! serving report ([`crate::serve::stream_serve`]) is built from
//! [`LatencySummary`] (per-stream p50/p95/p99) and [`OccupancyTracker`]
//! (time-weighted pool occupancy).
//!
//! The sharded runtime (DESIGN.md §9) aggregates per-shard metrics with
//! [`Histogram::merge`] / [`OccupancyTracker::merge`]: merging happens
//! at the *sample* level, so a merged histogram's [`LatencySummary`] is
//! exactly the summary of the union of samples — never an approximation
//! stitched from per-shard percentiles.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::jsonx::Json;

/// Monotonic named counters, shareable across threads.
#[derive(Default, Debug)]
pub struct Counters {
    inner: std::sync::Mutex<BTreeMap<String, u64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.inner.lock().unwrap().clone()
    }
}

/// Lock-free accumulating timer: total nanoseconds + call count.
#[derive(Default, Debug)]
pub struct TimerCell {
    nanos: AtomicU64,
    calls: AtomicU64,
}

impl TimerCell {
    pub fn record(&self, dt: std::time::Duration) {
        self.nanos.fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn total_secs(&self) -> f64 {
        self.nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.calls();
        if c == 0 {
            0.0
        } else {
            self.total_secs() / c as f64
        }
    }

    pub fn reset(&self) {
        self.nanos.store(0, Ordering::Relaxed);
        self.calls.store(0, Ordering::Relaxed);
    }
}

/// Latency histogram with exact percentiles (stores samples; fine for the
/// request volumes of the serving sim).
#[derive(Default, Debug, Clone)]
pub struct Histogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// q in [0, 1]; nearest-rank percentile.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let idx = ((self.samples.len() as f64 - 1.0) * q).round() as usize;
        self.samples[idx.min(self.samples.len() - 1)]
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }

    /// Fold another histogram's samples into this one (cross-shard
    /// aggregation).  Exact: the merged summary equals the summary of a
    /// single histogram fed every sample.
    pub fn merge(&mut self, other: &Histogram) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }

    /// One-shot percentile summary (the serving-report shape).
    pub fn summary(&mut self) -> LatencySummary {
        LatencySummary {
            count: self.len(),
            mean: self.mean(),
            p50: self.percentile(0.5),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
            max: self.max(),
        }
    }
}

/// Percentile snapshot of a latency [`Histogram`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Machine-readable form for the `--json` serving reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean)),
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
        ])
    }
}

/// Time-weighted occupancy histogram for a fixed-capacity pool: how much
/// wall-clock the pool spent with exactly k live sessions.  Mean
/// occupancy is the effective stream-batch the pooled recurrent GEMMs
/// ran at, which is what links serving load to kernel efficiency
/// (DESIGN.md §6).
#[derive(Clone, Debug, Default)]
pub struct OccupancyTracker {
    /// secs_at[k] = seconds spent with occupancy exactly k
    secs_at: Vec<f64>,
}

impl OccupancyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `secs` spent at `occupancy` live sessions.
    pub fn record(&mut self, occupancy: usize, secs: f64) {
        if self.secs_at.len() <= occupancy {
            self.secs_at.resize(occupancy + 1, 0.0);
        }
        self.secs_at[occupancy] += secs;
    }

    pub fn total_secs(&self) -> f64 {
        self.secs_at.iter().sum()
    }

    /// Time-weighted mean occupancy.
    pub fn mean(&self) -> f64 {
        let total = self.total_secs();
        if total <= 0.0 {
            return 0.0;
        }
        self.secs_at
            .iter()
            .enumerate()
            .map(|(k, &s)| k as f64 * s)
            .sum::<f64>()
            / total
    }

    /// Fraction of tracked time spent at exactly `k` sessions.
    pub fn frac_at(&self, k: usize) -> f64 {
        let total = self.total_secs();
        if total <= 0.0 {
            return 0.0;
        }
        self.secs_at.get(k).copied().unwrap_or(0.0) / total
    }

    /// Highest occupancy ever recorded with nonzero time.
    pub fn max_occupancy(&self) -> usize {
        self.secs_at
            .iter()
            .rposition(|&s| s > 0.0)
            .unwrap_or(0)
    }

    /// `(k, fraction)` rows for report printing, skipping empty buckets.
    pub fn buckets(&self) -> Vec<(usize, f64)> {
        let total = self.total_secs();
        self.secs_at
            .iter()
            .enumerate()
            .filter(|(_, &s)| s > 0.0)
            .map(|(k, &s)| (k, s / total))
            .collect()
    }

    /// Fold another tracker's time-at-occupancy buckets into this one
    /// (cross-shard aggregation).  Exact: bucket seconds add, so the
    /// merged mean is the time-weighted mean over every shard's samples.
    pub fn merge(&mut self, other: &OccupancyTracker) {
        if self.secs_at.len() < other.secs_at.len() {
            self.secs_at.resize(other.secs_at.len(), 0.0);
        }
        for (k, &s) in other.secs_at.iter().enumerate() {
            self.secs_at[k] += s;
        }
    }

    /// Machine-readable form for the `--json` serving reports.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets()
            .into_iter()
            .map(|(k, frac)| Json::arr_num(&[k as f64, frac]))
            .collect();
        Json::obj(vec![
            ("mean", Json::num(self.mean())),
            ("max", Json::num(self.max_occupancy() as f64)),
            ("total_secs", Json::num(self.total_secs())),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Simple stopwatch for phase reporting.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        c.add("x", 2);
        c.add("x", 3);
        c.add("y", 1);
        assert_eq!(c.get("x"), 5);
        assert_eq!(c.get("y"), 1);
        assert_eq!(c.get("z"), 0);
    }

    #[test]
    fn timer_counts_calls() {
        let t = TimerCell::default();
        let v = t.time(|| 42);
        assert_eq!(v, 42);
        t.time(|| ());
        assert_eq!(t.calls(), 2);
        assert!(t.total_secs() >= 0.0);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(1.0), 100.0);
        assert!((h.percentile(0.5) - 50.0).abs() <= 1.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn percentile_cache_invalidates_on_record_and_merge() {
        // the sort is cached behind the `sorted` flag; recording or
        // merging after a percentile query must invalidate it so later
        // queries see the new samples
        let mut h = Histogram::new();
        h.record(10.0);
        h.record(20.0);
        assert_eq!(h.percentile(1.0), 20.0); // sorts, sets the flag
        h.record(5.0);
        assert_eq!(h.percentile(0.0), 5.0); // stale cache would say 10.0
        assert_eq!(h.percentile(1.0), 20.0);
        let mut other = Histogram::new();
        other.record(100.0);
        h.merge(&other);
        assert_eq!(h.percentile(1.0), 100.0); // stale cache would say 20.0
    }

    #[test]
    fn merged_histogram_summary_equals_single_shard_summary() {
        // the cross-shard aggregation contract: splitting the same
        // samples across k shards and merging is indistinguishable from
        // one shard seeing everything
        let samples: Vec<f64> = (0..97).map(|i| ((i * 37) % 101) as f64 * 0.013).collect();
        let mut single = Histogram::new();
        for &s in &samples {
            single.record(s);
        }
        let mut shards: Vec<Histogram> = (0..3).map(|_| Histogram::new()).collect();
        for (i, &s) in samples.iter().enumerate() {
            shards[i % 3].record(s);
        }
        let mut merged = Histogram::new();
        for h in &shards {
            merged.merge(h);
        }
        let (a, b) = (merged.summary(), single.summary());
        assert_eq!(a.count, b.count);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p50, b.p50);
        assert_eq!(a.p95, b.p95);
        assert_eq!(a.p99, b.p99);
        assert_eq!(a.max, b.max);
    }

    #[test]
    fn occupancy_merge_adds_buckets() {
        let mut a = OccupancyTracker::new();
        a.record(1, 2.0);
        a.record(3, 1.0);
        let mut b = OccupancyTracker::new();
        b.record(3, 1.0);
        b.record(5, 4.0);
        a.merge(&b);
        assert!((a.total_secs() - 8.0).abs() < 1e-12);
        assert!((a.frac_at(3) - 0.25).abs() < 1e-12);
        assert_eq!(a.max_occupancy(), 5);
        // merging an empty tracker is a no-op
        let before = a.total_secs();
        a.merge(&OccupancyTracker::new());
        assert_eq!(a.total_secs(), before);
    }

    #[test]
    fn summary_and_tracker_serialize() {
        let mut h = Histogram::new();
        h.record(0.5);
        let j = h.summary().to_json();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(1));
        let mut o = OccupancyTracker::new();
        o.record(2, 1.0);
        let j = o.to_json();
        assert_eq!(j.get("max").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("buckets").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn occupancy_tracker_weights_by_time() {
        let mut o = OccupancyTracker::new();
        o.record(0, 1.0);
        o.record(2, 1.0);
        o.record(4, 2.0);
        assert!((o.total_secs() - 4.0).abs() < 1e-12);
        assert!((o.mean() - (0.0 + 2.0 + 8.0) / 4.0).abs() < 1e-12);
        assert!((o.frac_at(4) - 0.5).abs() < 1e-12);
        assert_eq!(o.frac_at(1), 0.0);
        assert_eq!(o.max_occupancy(), 4);
        assert_eq!(o.buckets().len(), 3);
    }

    #[test]
    fn empty_occupancy_is_zero() {
        let o = OccupancyTracker::new();
        assert_eq!(o.mean(), 0.0);
        assert_eq!(o.max_occupancy(), 0);
        assert!(o.buckets().is_empty());
    }
}

//! Training orchestrator — the L3 coordination layer for the paper's §3.
//!
//! The Rust side owns the loop: it feeds the AOT `train_*` executable the
//! full optimizer state every step (params + momentum + batch + the
//! runtime hyperparameters λ_rec, λ_nonrec, lr), reads the updated state
//! back, applies pruning masks, runs dev evaluation through the matching
//! `eval_*` executable, and implements the paper's **two-stage scheme**:
//!
//! 1. *Stage 1*: full-rank factored training with the trace-norm
//!    surrogate (or dense training with ℓ², or unregularized).
//! 2. *Transition*: per-group SVD of the stage-1 weights, rank chosen by
//!    explained variance against the AOT rank ladder, balanced-factor
//!    warmstart ([`crate::model::warmstart`]).
//! 3. *Stage 2*: low-rank training, no regularization, LR carried over
//!    per the §3.2.3 schedule (continuation or 3× final stage-1 LR).
//!
//! Training runs behind the [`TrainBackend`]/[`EvalBackend`] traits with
//! two implementations sharing one epoch loop: the XLA-AOT path above
//! ([`Trainer`]/[`Evaluator`] — needs the `xla` feature at runtime), and
//! the pure-Rust [`NativeTrainer`]/[`NativeEvaluator`] built on
//! [`crate::autograd`] (reverse-mode tape + CTC + the surrogate
//! penalty), which runs the full two-stage scheme — [`two_stage_native`]
//! — in the default offline build (DESIGN.md §2.5).

use std::sync::Arc;

use crate::autograd::{self, NativeOpts};
use crate::data::{Batch, Batcher, Utterance, make_batch};
use crate::decoder::{self, ErrorStats};
use crate::error::{Error, Result};
use crate::infer::{Breakdown, Engine, Precision};
use crate::model::{self, ParamSet};
use crate::runtime::{LoadedArtifact, ModelDims, Runtime, Value};
use crate::tensor::Tensor;

/// Scalar metrics returned by one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub ctc: f32,
    pub penalty: f32,
    pub grad_norm: f32,
}

/// Options for one training stage.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub seed: u64,
    pub lr: f32,
    /// multiplicative LR decay applied after each epoch
    pub lr_decay: f32,
    pub epochs: usize,
    pub lam_rec: f32,
    pub lam_nonrec: f32,
    pub quiet: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            seed: 0,
            lr: 2e-3,
            lr_decay: 0.95,
            epochs: 10,
            lam_rec: 0.0,
            lam_nonrec: 0.0,
            quiet: true,
        }
    }
}

/// Per-epoch log entry.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub mean_loss: f64,
    pub mean_ctc: f64,
    pub lr: f32,
    pub dev_cer: Option<f64>,
}

// ---------------------------------------------------------------------------
// Backend traits: the XLA-AOT and native paths behind one interface.
// ---------------------------------------------------------------------------

/// One training backend: something that owns a parameter set, applies
/// one optimizer step per batch, and follows the §3.2.3 LR schedule.
/// Two implementations exist — the XLA-AOT [`Trainer`] (executes the
/// lowered `train_*` artifacts; needs the `xla` feature at runtime) and
/// the pure-Rust [`NativeTrainer`] (reverse-mode autograd + CTC,
/// [`crate::autograd`]; works in the default offline build).  The epoch
/// loop is shared: [`run_one_epoch_on`] / [`run_epochs_on`].
pub trait TrainBackend {
    /// Human-readable identity for logs and error messages.
    fn backend_name(&self) -> &str;
    /// One optimizer step on a batch.
    fn step(&mut self, batch: &Batch) -> Result<StepMetrics>;
    fn params(&self) -> &ParamSet;
    fn lr(&self) -> f32;
    fn set_lr(&mut self, lr: f32);
    fn opts(&self) -> &TrainOpts;
    fn history(&self) -> &[EpochLog];
    fn history_mut(&mut self) -> &mut Vec<EpochLog>;
    /// Epochs completed before this backend instance existed (a resumed
    /// native run), so logged epoch numbers stay cumulative.
    fn epoch_offset(&self) -> usize {
        0
    }
}

/// Dev/test evaluation behind the same split: the XLA-AOT [`Evaluator`]
/// (batched `eval_*` artifacts) or the [`NativeEvaluator`] (the embedded
/// f32 engine itself — eval exactly what will be served).
pub trait EvalBackend {
    fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats>;
}

/// One epoch (all batches once) on any backend; appends to its history
/// and applies the per-epoch LR decay.
pub fn run_one_epoch_on(
    t: &mut dyn TrainBackend,
    batcher: &mut Batcher,
    eval: Option<&dyn EvalBackend>,
    dev: Option<&[Utterance]>,
) -> Result<()> {
    let epoch = t.epoch_offset() + t.history().len();
    let mut sum_loss = 0.0f64;
    let mut sum_ctc = 0.0f64;
    let batches = batcher.epoch();
    let n = batches.len().max(1);
    for b in &batches {
        let m = t.step(b)?;
        if !m.loss.is_finite() {
            return Err(Error::Train(format!(
                "non-finite loss at epoch {epoch} ({})",
                t.backend_name()
            )));
        }
        sum_loss += m.loss as f64;
        sum_ctc += m.ctc as f64;
    }
    let dev_cer = match (eval, dev) {
        (Some(e), Some(d)) => Some(e.greedy_cer(t.params(), d)?.cer()),
        _ => None,
    };
    let log = EpochLog {
        epoch,
        mean_loss: sum_loss / n as f64,
        mean_ctc: sum_ctc / n as f64,
        lr: t.lr(),
        dev_cer,
    };
    if !t.opts().quiet {
        match dev_cer {
            Some(c) => println!(
                "  epoch {epoch:>3}  loss {:.4}  ctc {:.4}  lr {:.5}  dev CER {:.3}",
                log.mean_loss, log.mean_ctc, log.lr, c
            ),
            None => println!(
                "  epoch {epoch:>3}  loss {:.4}  ctc {:.4}  lr {:.5}",
                log.mean_loss, log.mean_ctc, log.lr
            ),
        }
    }
    t.history_mut().push(log);
    let decay = t.opts().lr_decay;
    let lr = t.lr() * decay;
    t.set_lr(lr);
    Ok(())
}

/// `opts.epochs` epochs over the batcher on any backend.
pub fn run_epochs_on(
    t: &mut dyn TrainBackend,
    batcher: &mut Batcher,
    eval: Option<&dyn EvalBackend>,
    dev: Option<&[Utterance]>,
) -> Result<()> {
    for _ in 0..t.opts().epochs {
        run_one_epoch_on(t, batcher, eval, dev)?;
    }
    Ok(())
}

/// Single-stage trainer bound to one train artifact.
pub struct Trainer {
    artifact: Arc<LoadedArtifact>,
    pub params: ParamSet,
    pub momentum: ParamSet,
    pub masks: Option<ParamSet>,
    pub lr: f32,
    pub opts: TrainOpts,
    pub history: Vec<EpochLog>,
}

impl Trainer {
    /// Fresh-initialized trainer for a named train artifact.
    pub fn new(rt: &Runtime, artifact: &str, opts: TrainOpts) -> Result<Trainer> {
        let loaded = rt.load(artifact)?;
        let params = ParamSet::init(&loaded.spec, opts.seed)?;
        let momentum = ParamSet::zeros_like(&params);
        Ok(Trainer {
            artifact: loaded,
            params,
            momentum,
            masks: None,
            lr: opts.lr,
            opts,
            history: Vec::new(),
        })
    }

    /// Warmstarted trainer (stage 2): params given, momentum zeroed.
    pub fn with_params(
        rt: &Runtime,
        artifact: &str,
        params: ParamSet,
        opts: TrainOpts,
    ) -> Result<Trainer> {
        let loaded = rt.load(artifact)?;
        for n in &loaded.spec.param_names {
            if params.get(n)?.shape() != loaded.spec.input_shape(n)? {
                return Err(Error::Train(format!("param '{n}' shape mismatch vs {artifact}")));
            }
        }
        let momentum = ParamSet::zeros_like(&params);
        Ok(Trainer {
            artifact: loaded,
            params,
            momentum,
            masks: None,
            lr: opts.lr,
            opts,
            history: Vec::new(),
        })
    }

    pub fn spec_name(&self) -> &str {
        &self.artifact.spec.name
    }

    /// Install pruning masks (the artifact must have been lowered with
    /// `use_masks`); weights are re-projected after every step.
    pub fn set_masks(&mut self, masks: ParamSet) -> Result<()> {
        if !self.artifact.spec.use_masks {
            return Err(Error::Train(format!(
                "{} was not lowered with mask inputs",
                self.artifact.spec.name
            )));
        }
        self.params.apply_masks(&masks)?;
        self.masks = Some(masks);
        Ok(())
    }

    /// One optimizer step on a batch.
    pub fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        let spec = &self.artifact.spec;
        let names = &spec.param_names;
        let mut inputs = self.params.values_in_order(names)?;
        inputs.extend(self.momentum.values_in_order(names)?);
        if spec.use_masks {
            let masks = self
                .masks
                .as_ref()
                .ok_or_else(|| Error::Train("masked artifact without masks set".into()))?;
            for mn in &spec.mask_names {
                inputs.push(Value::F32(masks.get(mn)?.clone()));
            }
        }
        inputs.push(batch.feats.clone());
        inputs.push(batch.frame_lens.clone());
        inputs.push(batch.labels.clone());
        inputs.push(batch.label_lens.clone());
        inputs.push(Value::scalar(self.lr));
        inputs.push(Value::scalar(self.opts.lam_rec));
        inputs.push(Value::scalar(self.opts.lam_nonrec));

        let outputs = self.artifact.run(&inputs)?;
        let np = names.len();
        self.params = ParamSet::from_values(names, &outputs[..np])?;
        self.momentum = ParamSet::from_values(names, &outputs[np..2 * np])?;
        if let Some(masks) = &self.masks {
            self.params.apply_masks(masks)?;
        }
        let scalar = |i: usize| -> Result<f32> { outputs[2 * np + i].scalar_f32() };
        Ok(StepMetrics {
            loss: scalar(0)?,
            ctc: scalar(1)?,
            penalty: scalar(2)?,
            grad_norm: scalar(3)?,
        })
    }

    /// Train for `opts.epochs` epochs over the batcher, decaying LR per
    /// epoch and logging dev CER through `eval` when provided.
    pub fn run(&mut self, batcher: &mut Batcher, eval: Option<&Evaluator>, dev: Option<&[Utterance]>) -> Result<()> {
        run_epochs_on(self, batcher, eval.map(|e| e as &dyn EvalBackend), dev)
    }

    /// One epoch (all batches once); appends to history.
    pub fn run_one_epoch(
        &mut self,
        batcher: &mut Batcher,
        eval: Option<&Evaluator>,
        dev: Option<&[Utterance]>,
    ) -> Result<()> {
        run_one_epoch_on(self, batcher, eval.map(|e| e as &dyn EvalBackend), dev)
    }
}

impl TrainBackend for Trainer {
    fn backend_name(&self) -> &str {
        &self.artifact.spec.name
    }

    fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        Trainer::step(self, batch)
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn opts(&self) -> &TrainOpts {
        &self.opts
    }

    fn history(&self) -> &[EpochLog] {
        &self.history
    }

    fn history_mut(&mut self) -> &mut Vec<EpochLog> {
        &mut self.history
    }
}

// ---------------------------------------------------------------------------
// Evaluation through the eval_* artifacts.
// ---------------------------------------------------------------------------

/// Evaluator bound to one eval artifact.
pub struct Evaluator {
    artifact: Arc<LoadedArtifact>,
    feat_dim: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, artifact: &str) -> Result<Evaluator> {
        let loaded = rt.load(artifact)?;
        let dims = rt.manifest().dims(&loaded.spec.config)?;
        Ok(Evaluator { artifact: loaded, feat_dim: dims.feat_dim })
    }

    /// Run the model over utterances, returning per-utterance (logprobs,
    /// out_len, reference text).
    pub fn logprobs(
        &self,
        params: &ParamSet,
        utts: &[Utterance],
    ) -> Result<Vec<(Tensor, usize, String)>> {
        let spec = &self.artifact.spec;
        let geom = spec
            .batch
            .ok_or_else(|| Error::Manifest(format!("{}: eval without batch geom", spec.name)))?;
        let pvals = params.values_in_order(&spec.param_names)?;
        let mut out = Vec::with_capacity(utts.len());
        for chunk in utts.chunks(geom.batch) {
            let refs: Vec<&Utterance> = chunk.iter().collect();
            let batch = make_batch(&refs, &geom, self.feat_dim);
            let mut inputs = pvals.clone();
            inputs.push(batch.feats.clone());
            inputs.push(batch.frame_lens.clone());
            let res = self.artifact.run(&inputs)?;
            let logp = res[0].as_f32()?;
            let lens = res[1].as_i32()?;
            let (b, t, v) = (logp.shape()[0], logp.shape()[1], logp.shape()[2]);
            debug_assert_eq!(b, geom.batch);
            for (i, u) in chunk.iter().enumerate() {
                let rows =
                    Tensor::new(&[t, v], logp.data()[i * t * v..(i + 1) * t * v].to_vec())?;
                out.push((rows, lens[i] as usize, u.text.clone()));
            }
        }
        Ok(out)
    }

    /// Greedy-decoded corpus error rates.
    pub fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats> {
        let mut stats = ErrorStats::default();
        for (logp, len, reference) in self.logprobs(params, utts)? {
            let hyp = decoder::transcript_greedy(&logp, len);
            stats.push(&hyp, &reference);
        }
        Ok(stats)
    }

    /// Beam-decoded error rates with optional LM fusion.
    pub fn beam_cer(
        &self,
        params: &ParamSet,
        utts: &[Utterance],
        beam: usize,
        lm: Option<&crate::lm::CharLm>,
        lm_weight: f64,
    ) -> Result<ErrorStats> {
        let mut stats = ErrorStats::default();
        for (logp, len, reference) in self.logprobs(params, utts)? {
            let hyp = decoder::transcript_beam(&logp, len, beam, lm, lm_weight);
            stats.push(&hyp, &reference);
        }
        Ok(stats)
    }
}

impl EvalBackend for Evaluator {
    fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats> {
        Evaluator::greedy_cer(self, params, utts)
    }
}

// ---------------------------------------------------------------------------
// Native trainer: pure-Rust autograd + CTC (crate::autograd), no XLA.
// ---------------------------------------------------------------------------

/// Native evaluator: greedy CER through the embedded f32
/// [`Engine`] itself — the dev metric is computed on exactly the code
/// path the checkpoint will be served by.
pub struct NativeEvaluator {
    dims: ModelDims,
    time_batch: usize,
}

impl NativeEvaluator {
    pub fn new(dims: &ModelDims) -> NativeEvaluator {
        NativeEvaluator { dims: dims.clone(), time_batch: 4 }
    }

    pub fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats> {
        // "partial" dispatches per group on the params themselves
        // (factored where `{base}_u` exists, dense otherwise)
        let eng = Engine::from_params(&self.dims, "partial", params, Precision::F32, self.time_batch)?;
        let mut stats = ErrorStats::default();
        let mut bd = Breakdown::default();
        for u in utts {
            let (hyp, _) = eng.transcribe(&u.feats, &mut bd)?;
            stats.push(&hyp, &u.text);
        }
        Ok(stats)
    }
}

impl EvalBackend for NativeEvaluator {
    fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats> {
        NativeEvaluator::greedy_cer(self, params, utts)
    }
}

/// Pure-Rust single-stage trainer: reverse-mode autograd through the
/// factored GRU stack + CTC ([`crate::autograd`]), the §3 trace-norm
/// surrogate penalty, and SGD with momentum.  Runs in the default
/// offline build — no artifacts, no manifest, no XLA.
pub struct NativeTrainer {
    pub dims: ModelDims,
    pub params: ParamSet,
    /// momentum buffers (one per parameter)
    pub velocity: ParamSet,
    pub lr: f32,
    pub opts: TrainOpts,
    pub nopts: NativeOpts,
    pub history: Vec<EpochLog>,
    /// epochs completed by earlier sessions (set on resume); logged and
    /// saved epoch numbers are offset by this so they stay cumulative
    pub epoch_offset: usize,
}

impl NativeTrainer {
    /// Fresh stage-1 trainer: full-rank factored init
    /// ([`model::init_factored_full`]).
    pub fn new_factored(dims: &ModelDims, opts: TrainOpts, nopts: NativeOpts) -> NativeTrainer {
        let params = model::init_factored_full(dims, opts.seed);
        NativeTrainer::assemble(dims, params, opts, nopts)
    }

    /// Fresh dense trainer (the ℓ² baseline scheme).
    pub fn new_dense(dims: &ModelDims, opts: TrainOpts, nopts: NativeOpts) -> NativeTrainer {
        let params = model::init_dense(dims, opts.seed);
        NativeTrainer::assemble(dims, params, opts, nopts)
    }

    /// Warmstarted trainer (stage 2): params given, momentum zeroed.
    /// Validates the parameter set against `dims` so a mismatched
    /// checkpoint fails here with a clean error instead of panicking in
    /// a GEMM contraction mid-epoch.
    pub fn with_params(
        dims: &ModelDims,
        params: ParamSet,
        opts: TrainOpts,
        nopts: NativeOpts,
    ) -> Result<NativeTrainer> {
        model::check_params_match_dims(&params, dims)?;
        Ok(NativeTrainer::assemble(dims, params, opts, nopts))
    }

    fn assemble(
        dims: &ModelDims,
        params: ParamSet,
        opts: TrainOpts,
        nopts: NativeOpts,
    ) -> NativeTrainer {
        let velocity = ParamSet::zeros_like(&params);
        let lr = opts.lr;
        NativeTrainer {
            dims: dims.clone(),
            params,
            velocity,
            lr,
            opts,
            nopts,
            history: Vec::new(),
            epoch_offset: 0,
        }
    }

    /// Resumed trainer: params **and** momentum buffers restored from a
    /// saved train state ([`crate::checkpoint::load_train_state`]), with
    /// the LR schedule position carried in `lr` — the fix for the
    /// save-path metadata loss (ISSUE 4 satellite).
    pub fn resume(
        dims: &ModelDims,
        params: ParamSet,
        velocity: ParamSet,
        lr: f32,
        opts: TrainOpts,
        nopts: NativeOpts,
    ) -> Result<NativeTrainer> {
        for (name, v) in velocity.iter() {
            if params.get(name)?.shape() != v.shape() {
                return Err(Error::Train(format!(
                    "resume: momentum '{name}' shape {:?} does not match params",
                    v.shape()
                )));
            }
        }
        if velocity.len() != params.len() {
            return Err(Error::Train("resume: momentum/param name sets differ".into()));
        }
        let mut t = NativeTrainer::with_params(dims, params, opts, nopts)?;
        t.velocity = velocity;
        t.lr = lr;
        Ok(t)
    }

    /// One optimizer step: mean CTC loss + gradients over the batch rows,
    /// surrogate penalty added, global-norm clip, momentum update.  With
    /// `nopts.qat_bits` set, the forward pass runs through the
    /// straight-through `fake_quant` wrapper so the loss is measured on
    /// the weights inference will actually serve.
    pub fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        let utts = batch.utterances()?;
        let (ctc, mut grads) =
            autograd::batch_ctc_grads_qat(&self.params, &self.dims, &utts, self.nopts.qat_bits)?;
        let (penalty, pgrads) =
            autograd::surrogate_penalty(&self.params, self.opts.lam_rec, self.opts.lam_nonrec)?;
        for (name, g) in pgrads.iter() {
            grads.get_mut(name)?.add_assign(g)?;
        }
        let grad_norm = autograd::clip_grads(&mut grads, self.nopts.clip);
        autograd::sgd_momentum_step(
            &mut self.params,
            &mut self.velocity,
            &grads,
            self.lr,
            self.nopts.momentum,
        )?;
        Ok(StepMetrics { loss: ctc + penalty, ctc, penalty, grad_norm })
    }

    /// Train for `opts.epochs` epochs (shared epoch loop).
    pub fn run(
        &mut self,
        batcher: &mut Batcher,
        eval: Option<&dyn EvalBackend>,
        dev: Option<&[Utterance]>,
    ) -> Result<()> {
        run_epochs_on(self, batcher, eval, dev)
    }
}

impl TrainBackend for NativeTrainer {
    fn backend_name(&self) -> &str {
        "native"
    }

    fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        NativeTrainer::step(self, batch)
    }

    fn params(&self) -> &ParamSet {
        &self.params
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn opts(&self) -> &TrainOpts {
        &self.opts
    }

    fn history(&self) -> &[EpochLog] {
        &self.history
    }

    fn history_mut(&mut self) -> &mut Vec<EpochLog> {
        &mut self.history
    }

    fn epoch_offset(&self) -> usize {
        self.epoch_offset
    }
}

// ---------------------------------------------------------------------------
// Two-stage pipeline (§3 + §3.2.3).
// ---------------------------------------------------------------------------

/// How stage 2 sets its initial LR.
#[derive(Clone, Copy, Debug)]
pub enum Stage2Lr {
    /// 3× the final stage-1 LR (§3.2.2 protocol)
    TripleFinal,
    /// continue the stage-1 schedule as if one model trained throughout
    /// (§3.2.3 protocol)
    Continuation,
}

/// Result of a full two-stage run.
pub struct TwoStageResult {
    pub stage1_params: ParamSet,
    pub stage2: Trainer,
    pub rank_frac: f64,
    pub stage1_history: Vec<EpochLog>,
}

/// Derive the eval-artifact name for a train artifact.
pub fn eval_name(train_artifact: &str) -> String {
    train_artifact.replacen("train_", "eval_", 1)
}

/// Name tag for a rank fraction, matching aot.py's `frac_tag`.
pub fn frac_tag(frac: f64) -> String {
    format!("r{:03}", (frac * 1000.0).round() as usize)
}

/// Run the two-stage scheme.
///
/// * `stage1_artifact` — e.g. "train_mini_partial_full" (trace norm) or
///   "train_mini_unfact" (ℓ²/unregularized).
/// * `stage2_family` — e.g. "train_mini_partial": the rank tag is appended.
/// * `svd_threshold` — explained-variance threshold for rank selection.
/// * `transition_epoch` — epochs spent in stage 1; the remaining budget
///   (`total_epochs - transition_epoch`) goes to stage 2.
#[allow(clippy::too_many_arguments)]
pub fn two_stage(
    rt: &Runtime,
    batcher: &mut Batcher,
    dev: &[Utterance],
    stage1_artifact: &str,
    stage2_family: &str,
    svd_threshold: f64,
    transition_epoch: usize,
    total_epochs: usize,
    stage1_opts: TrainOpts,
    stage2_lr: Stage2Lr,
) -> Result<TwoStageResult> {
    // ---- stage 1
    let mut opts1 = stage1_opts.clone();
    opts1.epochs = transition_epoch;
    let eval1 = Evaluator::new(rt, &eval_name(stage1_artifact))?;
    let mut t1 = Trainer::new(rt, stage1_artifact, opts1)?;
    t1.run(batcher, Some(&eval1), Some(dev))?;

    // ---- transition: rank selection + warmstart
    let ladder = rt.manifest().rank_ladder.clone();
    let frac = model::pick_rank_frac(&t1.params, svd_threshold, &ladder)?;
    let stage2_artifact = format!("{stage2_family}_{}", frac_tag(frac));
    let spec2 = rt.manifest().artifact(&stage2_artifact)?.clone();
    let params2 = model::warmstart(&t1.params, &spec2, stage1_opts.seed + 1)?;

    // ---- stage 2 (no regularization; §3.2.2/§3.2.3 LR rules)
    let mut opts2 = stage1_opts.clone();
    opts2.lam_rec = 0.0;
    opts2.lam_nonrec = 0.0;
    opts2.epochs = total_epochs.saturating_sub(transition_epoch);
    opts2.lr = match stage2_lr {
        Stage2Lr::TripleFinal => t1.lr * 3.0,
        Stage2Lr::Continuation => t1.lr,
    };
    let eval2 = Evaluator::new(rt, &eval_name(&stage2_artifact))?;
    let mut t2 = Trainer::with_params(rt, &stage2_artifact, params2, opts2)?;
    t2.run(batcher, Some(&eval2), Some(dev))?;

    Ok(TwoStageResult {
        stage1_params: t1.params,
        stage2: t2,
        rank_frac: frac,
        stage1_history: t1.history,
    })
}

/// Default rank ladder for the manifest-free native path (the AOT
/// manifest carries its own; this mirrors the same spread).
pub const NATIVE_RANK_LADDER: &[f64] = &[0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 1.0];

/// Built-in model config for manifest-free native training (`train
/// --native`): feature width matches the synthetic corpus
/// ([`crate::data::CorpusSpec::standard`]), sized so a CI smoke run
/// trains in seconds while still exercising conv, a two-layer GRU stack,
/// factored fc and the full CTC head.  Bigger serving-scale dims live in
/// [`crate::stream::demo_dims`].
pub fn native_mini_dims() -> ModelDims {
    ModelDims {
        feat_dim: 40,
        conv: vec![crate::runtime::ConvDims { context: 2, dim: 32 }],
        gru_dims: vec![32, 32],
        fc_dim: 48,
        vocab: 29,
        total_stride: 2,
    }
}

/// Result of a native two-stage run.
pub struct NativeTwoStageResult {
    pub stage1_params: ParamSet,
    pub stage2: NativeTrainer,
    pub rank_frac: f64,
    pub stage1_history: Vec<EpochLog>,
}

/// The full §3 two-stage scheme on the native backend, end to end in the
/// default offline build:
///
/// 1. **Stage 1** — full-rank factored training under the
///    `λ/2·(‖U‖²+‖V‖²)` surrogate for `transition_epoch` epochs.
/// 2. **Transition** — per-group explained-variance rank selection
///    against `ladder` ([`model::pick_rank_frac`]), then truncated-SVD
///    balanced-factor warmstart ([`model::truncate_groups`] — the same
///    transform `ladder-build` applies per rung).
/// 3. **Stage 2** — low-rank training, no regularization, LR per the
///    §3.2.2/§3.2.3 rule (`stage2_lr`), for the remaining budget.  With
///    `nopts.qat_bits` set, stage 2 fine-tunes through the
///    straight-through `fake_quant` wrapper (quantization-aware
///    fine-tuning for the int8/int4 serving path); stage 1 always
///    trains in plain f32 regardless.
///
/// The stage-2 parameter set is directly servable: `Engine::from_params`,
/// `ladder-build`, and `stream-serve --load` all consume it unchanged.
#[allow(clippy::too_many_arguments)]
pub fn two_stage_native(
    dims: &ModelDims,
    batcher: &mut Batcher,
    dev: Option<&[Utterance]>,
    svd_threshold: f64,
    ladder: &[f64],
    transition_epoch: usize,
    total_epochs: usize,
    stage1_opts: TrainOpts,
    nopts: NativeOpts,
    stage2_lr: Stage2Lr,
) -> Result<NativeTwoStageResult> {
    let eval = NativeEvaluator::new(dims);
    let eval_ref = dev.map(|_| &eval as &dyn EvalBackend);

    // ---- stage 1: full-rank factored + surrogate (never quantized —
    // QAT only makes sense once the served topology is fixed, §3.2.2)
    let mut opts1 = stage1_opts.clone();
    opts1.epochs = transition_epoch;
    let mut nopts1 = nopts;
    nopts1.qat_bits = None;
    let mut t1 = NativeTrainer::new_factored(dims, opts1, nopts1);
    t1.run(batcher, eval_ref, dev)?;

    // ---- transition: rank selection + balanced-factor truncation
    let frac = model::pick_rank_frac(&t1.params, svd_threshold, ladder)?;
    let params2 = model::truncate_groups(&t1.params, frac)?;

    // ---- stage 2: low-rank, no regularization
    let mut opts2 = stage1_opts.clone();
    opts2.lam_rec = 0.0;
    opts2.lam_nonrec = 0.0;
    opts2.epochs = total_epochs.saturating_sub(transition_epoch);
    opts2.lr = match stage2_lr {
        Stage2Lr::TripleFinal => t1.lr * 3.0,
        Stage2Lr::Continuation => t1.lr,
    };
    let mut t2 = NativeTrainer::with_params(dims, params2, opts2, nopts)?;
    t2.run(batcher, eval_ref, dev)?;

    Ok(NativeTwoStageResult {
        stage1_params: t1.params,
        stage2: t2,
        rank_frac: frac,
        stage1_history: t1.history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_name_mapping() {
        assert_eq!(eval_name("train_mini_partial_full"), "eval_mini_partial_full");
        assert_eq!(eval_name("train_mini_unfact"), "eval_mini_unfact");
    }

    #[test]
    fn frac_tags_match_aot() {
        assert_eq!(frac_tag(0.125), "r125");
        assert_eq!(frac_tag(0.25), "r250");
        assert_eq!(frac_tag(0.375), "r375");
        assert_eq!(frac_tag(0.5), "r500");
        assert_eq!(frac_tag(0.75), "r750");
    }

    #[test]
    fn default_opts_sane() {
        let o = TrainOpts::default();
        assert!(o.lr > 0.0 && o.lr_decay <= 1.0 && o.epochs > 0);
    }

    // -- native backend ----------------------------------------------------

    use crate::data::{CorpusSpec, Dataset};
    use crate::runtime::{BatchGeom, ConvDims};

    fn tiny_native_dims() -> ModelDims {
        ModelDims {
            feat_dim: 8,
            conv: vec![ConvDims { context: 2, dim: 10 }],
            gru_dims: vec![8, 8],
            fc_dim: 12,
            vocab: 29,
            total_stride: 2,
        }
    }

    fn tiny_corpus(seed: u64, n_train: usize, n_dev: usize) -> Dataset {
        let spec = CorpusSpec {
            seed,
            feat_dim: 8,
            max_frames: 64,
            max_label: 6,
            dur_min: 3,
            dur_max: 6,
            noise: 0.3,
            bands: 2,
            feasibility_stride: 2,
        };
        Dataset::generate(spec, n_train, n_dev, n_dev)
    }

    fn tiny_geom(batch: usize) -> BatchGeom {
        BatchGeom { batch, max_frames: 64, max_label: 6 }
    }

    #[test]
    fn native_step_updates_params_and_reports_finite_metrics() {
        let dims = tiny_native_dims();
        let data = tiny_corpus(11, 6, 2);
        let mut batcher = Batcher::new(&data.train, tiny_geom(3), 8, 0);
        let opts = TrainOpts { lam_rec: 1e-3, lam_nonrec: 1e-3, ..TrainOpts::default() };
        let mut t = NativeTrainer::new_factored(&dims, opts, NativeOpts::default());
        let before = t.params.get("rec0_u").unwrap().clone();
        let batches = batcher.epoch();
        let m = t.step(&batches[0]).unwrap();
        assert!(m.loss.is_finite() && m.ctc > 0.0, "loss {} ctc {}", m.loss, m.ctc);
        assert!(m.penalty > 0.0, "surrogate penalty must be active in stage 1");
        assert!(m.grad_norm > 0.0);
        assert!(t.params.get("rec0_u").unwrap().max_abs_diff(&before) > 0.0);
    }

    #[test]
    fn native_epoch_runner_logs_lr_decay_and_dev_cer() {
        let dims = tiny_native_dims();
        let data = tiny_corpus(12, 6, 2);
        let mut batcher = Batcher::new(&data.train, tiny_geom(3), 8, 1);
        let opts = TrainOpts { epochs: 2, lr: 1e-3, lr_decay: 0.5, ..TrainOpts::default() };
        let mut t = NativeTrainer::new_factored(&dims, opts, NativeOpts::default());
        let eval = NativeEvaluator::new(&dims);
        t.run(&mut batcher, Some(&eval), Some(&data.dev)).unwrap();
        assert_eq!(t.history.len(), 2);
        assert!((t.history[0].lr - 1e-3).abs() < 1e-9);
        assert!((t.history[1].lr - 5e-4).abs() < 1e-9);
        assert!((t.lr - 2.5e-4).abs() < 1e-9);
        assert!(t.history.iter().all(|l| l.dev_cer.is_some()));
    }

    #[test]
    fn native_two_stage_transitions_to_low_rank() {
        let dims = tiny_native_dims();
        let data = tiny_corpus(13, 6, 0);
        let mut batcher = Batcher::new(&data.train, tiny_geom(3), 8, 2);
        let opts = TrainOpts { lr: 2e-3, lam_rec: 1e-3, lam_nonrec: 1e-3, ..TrainOpts::default() };
        let r = two_stage_native(
            &dims,
            &mut batcher,
            None,
            0.9,
            NATIVE_RANK_LADDER,
            1,
            2,
            opts,
            NativeOpts::default(),
            Stage2Lr::Continuation,
        )
        .unwrap();
        assert!(NATIVE_RANK_LADDER.contains(&r.rank_frac));
        assert_eq!(r.stage1_history.len(), 1);
        assert_eq!(r.stage2.history.len(), 1);
        // stage 2 dropped the regularizer per §3.2.2
        assert_eq!(r.stage2.opts.lam_rec, 0.0);
        assert!(r.stage2.history[0].mean_loss.is_finite());
        // the stage-2 params stay servable by the embedded engine
        assert!(Engine::from_params(&dims, "partial", &r.stage2.params, Precision::F32, 4).is_ok());
        if r.rank_frac < 1.0 {
            assert!(r.stage2.params.num_scalars() < r.stage1_params.num_scalars());
        }
    }

    #[test]
    fn native_qat_step_trains_and_two_stage_confines_qat_to_stage2() {
        let dims = tiny_native_dims();
        let data = tiny_corpus(14, 6, 0);
        let mut batcher = Batcher::new(&data.train, tiny_geom(3), 8, 3);
        let nopts = NativeOpts { qat_bits: Some(4), ..NativeOpts::default() };

        // a QAT step updates params with finite metrics, same as f32
        let mut t = NativeTrainer::new_factored(&dims, TrainOpts::default(), nopts);
        let before = t.params.get("rec0_u").unwrap().clone();
        let batches = batcher.epoch();
        let m = t.step(&batches[0]).unwrap();
        assert!(m.loss.is_finite() && m.ctc > 0.0, "loss {} ctc {}", m.loss, m.ctc);
        assert!(t.params.get("rec0_u").unwrap().max_abs_diff(&before) > 0.0);

        // the two-stage driver keeps QAT out of stage 1, in for stage 2
        let opts = TrainOpts { lr: 2e-3, lam_rec: 1e-3, lam_nonrec: 1e-3, ..TrainOpts::default() };
        let r = two_stage_native(
            &dims,
            &mut batcher,
            None,
            0.9,
            NATIVE_RANK_LADDER,
            1,
            2,
            opts,
            nopts,
            Stage2Lr::Continuation,
        )
        .unwrap();
        assert_eq!(r.stage2.nopts.qat_bits, Some(4));
        assert!(r.stage2.history[0].mean_loss.is_finite());
        // the fine-tuned params stay servable on the quantized path
        assert!(
            Engine::from_params(&dims, "partial", &r.stage2.params, Precision::Int4, 4).is_ok()
        );
    }

    #[test]
    fn native_resume_validates_momentum_shapes() {
        let dims = tiny_native_dims();
        let params = model::init_factored_full(&dims, 3);
        let good = ParamSet::zeros_like(&params);
        assert!(NativeTrainer::resume(
            &dims,
            params.clone(),
            good,
            1e-3,
            TrainOpts::default(),
            NativeOpts::default()
        )
        .is_ok());
        let mut bad = ParamSet::zeros_like(&params);
        bad.set("rec0_u", Tensor::zeros(&[2, 2]));
        assert!(NativeTrainer::resume(
            &dims,
            params,
            bad,
            1e-3,
            TrainOpts::default(),
            NativeOpts::default()
        )
        .is_err());
    }
}

//! Training orchestrator — the L3 coordination layer for the paper's §3.
//!
//! The Rust side owns the loop: it feeds the AOT `train_*` executable the
//! full optimizer state every step (params + momentum + batch + the
//! runtime hyperparameters λ_rec, λ_nonrec, lr), reads the updated state
//! back, applies pruning masks, runs dev evaluation through the matching
//! `eval_*` executable, and implements the paper's **two-stage scheme**:
//!
//! 1. *Stage 1*: full-rank factored training with the trace-norm
//!    surrogate (or dense training with ℓ², or unregularized).
//! 2. *Transition*: per-group SVD of the stage-1 weights, rank chosen by
//!    explained variance against the AOT rank ladder, balanced-factor
//!    warmstart ([`crate::model::warmstart`]).
//! 3. *Stage 2*: low-rank training, no regularization, LR carried over
//!    per the §3.2.3 schedule (continuation or 3× final stage-1 LR).

use std::sync::Arc;

use crate::data::{Batch, Batcher, Utterance, make_batch};
use crate::decoder::{self, ErrorStats};
use crate::error::{Error, Result};
use crate::model::{self, ParamSet};
use crate::runtime::{LoadedArtifact, Runtime, Value};
use crate::tensor::Tensor;

/// Scalar metrics returned by one train step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub ctc: f32,
    pub penalty: f32,
    pub grad_norm: f32,
}

/// Options for one training stage.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub seed: u64,
    pub lr: f32,
    /// multiplicative LR decay applied after each epoch
    pub lr_decay: f32,
    pub epochs: usize,
    pub lam_rec: f32,
    pub lam_nonrec: f32,
    pub quiet: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            seed: 0,
            lr: 2e-3,
            lr_decay: 0.95,
            epochs: 10,
            lam_rec: 0.0,
            lam_nonrec: 0.0,
            quiet: true,
        }
    }
}

/// Per-epoch log entry.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub mean_loss: f64,
    pub mean_ctc: f64,
    pub lr: f32,
    pub dev_cer: Option<f64>,
}

/// Single-stage trainer bound to one train artifact.
pub struct Trainer {
    artifact: Arc<LoadedArtifact>,
    pub params: ParamSet,
    pub momentum: ParamSet,
    pub masks: Option<ParamSet>,
    pub lr: f32,
    pub opts: TrainOpts,
    pub history: Vec<EpochLog>,
}

impl Trainer {
    /// Fresh-initialized trainer for a named train artifact.
    pub fn new(rt: &Runtime, artifact: &str, opts: TrainOpts) -> Result<Trainer> {
        let loaded = rt.load(artifact)?;
        let params = ParamSet::init(&loaded.spec, opts.seed)?;
        let momentum = ParamSet::zeros_like(&params);
        Ok(Trainer {
            artifact: loaded,
            params,
            momentum,
            masks: None,
            lr: opts.lr,
            opts,
            history: Vec::new(),
        })
    }

    /// Warmstarted trainer (stage 2): params given, momentum zeroed.
    pub fn with_params(
        rt: &Runtime,
        artifact: &str,
        params: ParamSet,
        opts: TrainOpts,
    ) -> Result<Trainer> {
        let loaded = rt.load(artifact)?;
        for n in &loaded.spec.param_names {
            if params.get(n)?.shape() != loaded.spec.input_shape(n)? {
                return Err(Error::Train(format!("param '{n}' shape mismatch vs {artifact}")));
            }
        }
        let momentum = ParamSet::zeros_like(&params);
        Ok(Trainer {
            artifact: loaded,
            params,
            momentum,
            masks: None,
            lr: opts.lr,
            opts,
            history: Vec::new(),
        })
    }

    pub fn spec_name(&self) -> &str {
        &self.artifact.spec.name
    }

    /// Install pruning masks (the artifact must have been lowered with
    /// `use_masks`); weights are re-projected after every step.
    pub fn set_masks(&mut self, masks: ParamSet) -> Result<()> {
        if !self.artifact.spec.use_masks {
            return Err(Error::Train(format!(
                "{} was not lowered with mask inputs",
                self.artifact.spec.name
            )));
        }
        self.params.apply_masks(&masks)?;
        self.masks = Some(masks);
        Ok(())
    }

    /// One optimizer step on a batch.
    pub fn step(&mut self, batch: &Batch) -> Result<StepMetrics> {
        let spec = &self.artifact.spec;
        let names = &spec.param_names;
        let mut inputs = self.params.values_in_order(names)?;
        inputs.extend(self.momentum.values_in_order(names)?);
        if spec.use_masks {
            let masks = self
                .masks
                .as_ref()
                .ok_or_else(|| Error::Train("masked artifact without masks set".into()))?;
            for mn in &spec.mask_names {
                inputs.push(Value::F32(masks.get(mn)?.clone()));
            }
        }
        inputs.push(batch.feats.clone());
        inputs.push(batch.frame_lens.clone());
        inputs.push(batch.labels.clone());
        inputs.push(batch.label_lens.clone());
        inputs.push(Value::scalar(self.lr));
        inputs.push(Value::scalar(self.opts.lam_rec));
        inputs.push(Value::scalar(self.opts.lam_nonrec));

        let outputs = self.artifact.run(&inputs)?;
        let np = names.len();
        self.params = ParamSet::from_values(names, &outputs[..np])?;
        self.momentum = ParamSet::from_values(names, &outputs[np..2 * np])?;
        if let Some(masks) = &self.masks {
            self.params.apply_masks(masks)?;
        }
        let scalar = |i: usize| -> Result<f32> { outputs[2 * np + i].scalar_f32() };
        Ok(StepMetrics {
            loss: scalar(0)?,
            ctc: scalar(1)?,
            penalty: scalar(2)?,
            grad_norm: scalar(3)?,
        })
    }

    /// Train for `opts.epochs` epochs over the batcher, decaying LR per
    /// epoch and logging dev CER through `eval` when provided.
    pub fn run(&mut self, batcher: &mut Batcher, eval: Option<&Evaluator>, dev: Option<&[Utterance]>) -> Result<()> {
        let epochs = self.opts.epochs;
        for _ in 0..epochs {
            self.run_one_epoch(batcher, eval, dev)?;
        }
        Ok(())
    }

    /// One epoch (all batches once); appends to history.
    pub fn run_one_epoch(
        &mut self,
        batcher: &mut Batcher,
        eval: Option<&Evaluator>,
        dev: Option<&[Utterance]>,
    ) -> Result<()> {
        let epoch = self.history.len();
        let mut sum_loss = 0.0f64;
        let mut sum_ctc = 0.0f64;
        let batches = batcher.epoch();
        let n = batches.len().max(1);
        for b in &batches {
            let m = self.step(b)?;
            if !m.loss.is_finite() {
                return Err(Error::Train(format!(
                    "non-finite loss at epoch {epoch} ({})",
                    self.artifact.spec.name
                )));
            }
            sum_loss += m.loss as f64;
            sum_ctc += m.ctc as f64;
        }
        let dev_cer = match (eval, dev) {
            (Some(e), Some(d)) => Some(e.greedy_cer(&self.params, d)?.cer()),
            _ => None,
        };
        let log = EpochLog {
            epoch,
            mean_loss: sum_loss / n as f64,
            mean_ctc: sum_ctc / n as f64,
            lr: self.lr,
            dev_cer,
        };
        if !self.opts.quiet {
            match dev_cer {
                Some(c) => println!(
                    "  epoch {epoch:>3}  loss {:.4}  ctc {:.4}  lr {:.5}  dev CER {:.3}",
                    log.mean_loss, log.mean_ctc, log.lr, c
                ),
                None => println!(
                    "  epoch {epoch:>3}  loss {:.4}  ctc {:.4}  lr {:.5}",
                    log.mean_loss, log.mean_ctc, log.lr
                ),
            }
        }
        self.history.push(log);
        self.lr *= self.opts.lr_decay;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Evaluation through the eval_* artifacts.
// ---------------------------------------------------------------------------

/// Evaluator bound to one eval artifact.
pub struct Evaluator {
    artifact: Arc<LoadedArtifact>,
    feat_dim: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, artifact: &str) -> Result<Evaluator> {
        let loaded = rt.load(artifact)?;
        let dims = rt.manifest().dims(&loaded.spec.config)?;
        Ok(Evaluator { artifact: loaded, feat_dim: dims.feat_dim })
    }

    /// Run the model over utterances, returning per-utterance (logprobs,
    /// out_len, reference text).
    pub fn logprobs(
        &self,
        params: &ParamSet,
        utts: &[Utterance],
    ) -> Result<Vec<(Tensor, usize, String)>> {
        let spec = &self.artifact.spec;
        let geom = spec
            .batch
            .ok_or_else(|| Error::Manifest(format!("{}: eval without batch geom", spec.name)))?;
        let pvals = params.values_in_order(&spec.param_names)?;
        let mut out = Vec::with_capacity(utts.len());
        for chunk in utts.chunks(geom.batch) {
            let refs: Vec<&Utterance> = chunk.iter().collect();
            let batch = make_batch(&refs, &geom, self.feat_dim);
            let mut inputs = pvals.clone();
            inputs.push(batch.feats.clone());
            inputs.push(batch.frame_lens.clone());
            let res = self.artifact.run(&inputs)?;
            let logp = res[0].as_f32()?;
            let lens = res[1].as_i32()?;
            let (b, t, v) = (logp.shape()[0], logp.shape()[1], logp.shape()[2]);
            debug_assert_eq!(b, geom.batch);
            for (i, u) in chunk.iter().enumerate() {
                let rows =
                    Tensor::new(&[t, v], logp.data()[i * t * v..(i + 1) * t * v].to_vec())?;
                out.push((rows, lens[i] as usize, u.text.clone()));
            }
        }
        Ok(out)
    }

    /// Greedy-decoded corpus error rates.
    pub fn greedy_cer(&self, params: &ParamSet, utts: &[Utterance]) -> Result<ErrorStats> {
        let mut stats = ErrorStats::default();
        for (logp, len, reference) in self.logprobs(params, utts)? {
            let hyp = decoder::transcript_greedy(&logp, len);
            stats.push(&hyp, &reference);
        }
        Ok(stats)
    }

    /// Beam-decoded error rates with optional LM fusion.
    pub fn beam_cer(
        &self,
        params: &ParamSet,
        utts: &[Utterance],
        beam: usize,
        lm: Option<&crate::lm::CharLm>,
        lm_weight: f64,
    ) -> Result<ErrorStats> {
        let mut stats = ErrorStats::default();
        for (logp, len, reference) in self.logprobs(params, utts)? {
            let hyp = decoder::transcript_beam(&logp, len, beam, lm, lm_weight);
            stats.push(&hyp, &reference);
        }
        Ok(stats)
    }
}

// ---------------------------------------------------------------------------
// Two-stage pipeline (§3 + §3.2.3).
// ---------------------------------------------------------------------------

/// How stage 2 sets its initial LR.
#[derive(Clone, Copy, Debug)]
pub enum Stage2Lr {
    /// 3× the final stage-1 LR (§3.2.2 protocol)
    TripleFinal,
    /// continue the stage-1 schedule as if one model trained throughout
    /// (§3.2.3 protocol)
    Continuation,
}

/// Result of a full two-stage run.
pub struct TwoStageResult {
    pub stage1_params: ParamSet,
    pub stage2: Trainer,
    pub rank_frac: f64,
    pub stage1_history: Vec<EpochLog>,
}

/// Derive the eval-artifact name for a train artifact.
pub fn eval_name(train_artifact: &str) -> String {
    train_artifact.replacen("train_", "eval_", 1)
}

/// Name tag for a rank fraction, matching aot.py's `frac_tag`.
pub fn frac_tag(frac: f64) -> String {
    format!("r{:03}", (frac * 1000.0).round() as usize)
}

/// Run the two-stage scheme.
///
/// * `stage1_artifact` — e.g. "train_mini_partial_full" (trace norm) or
///   "train_mini_unfact" (ℓ²/unregularized).
/// * `stage2_family` — e.g. "train_mini_partial": the rank tag is appended.
/// * `svd_threshold` — explained-variance threshold for rank selection.
/// * `transition_epoch` — epochs spent in stage 1; the remaining budget
///   (`total_epochs - transition_epoch`) goes to stage 2.
#[allow(clippy::too_many_arguments)]
pub fn two_stage(
    rt: &Runtime,
    batcher: &mut Batcher,
    dev: &[Utterance],
    stage1_artifact: &str,
    stage2_family: &str,
    svd_threshold: f64,
    transition_epoch: usize,
    total_epochs: usize,
    stage1_opts: TrainOpts,
    stage2_lr: Stage2Lr,
) -> Result<TwoStageResult> {
    // ---- stage 1
    let mut opts1 = stage1_opts.clone();
    opts1.epochs = transition_epoch;
    let eval1 = Evaluator::new(rt, &eval_name(stage1_artifact))?;
    let mut t1 = Trainer::new(rt, stage1_artifact, opts1)?;
    t1.run(batcher, Some(&eval1), Some(dev))?;

    // ---- transition: rank selection + warmstart
    let ladder = rt.manifest().rank_ladder.clone();
    let frac = model::pick_rank_frac(&t1.params, svd_threshold, &ladder)?;
    let stage2_artifact = format!("{stage2_family}_{}", frac_tag(frac));
    let spec2 = rt.manifest().artifact(&stage2_artifact)?.clone();
    let params2 = model::warmstart(&t1.params, &spec2, stage1_opts.seed + 1)?;

    // ---- stage 2 (no regularization; §3.2.2/§3.2.3 LR rules)
    let mut opts2 = stage1_opts.clone();
    opts2.lam_rec = 0.0;
    opts2.lam_nonrec = 0.0;
    opts2.epochs = total_epochs.saturating_sub(transition_epoch);
    opts2.lr = match stage2_lr {
        Stage2Lr::TripleFinal => t1.lr * 3.0,
        Stage2Lr::Continuation => t1.lr,
    };
    let eval2 = Evaluator::new(rt, &eval_name(&stage2_artifact))?;
    let mut t2 = Trainer::with_params(rt, &stage2_artifact, params2, opts2)?;
    t2.run(batcher, Some(&eval2), Some(dev))?;

    Ok(TwoStageResult {
        stage1_params: t1.params,
        stage2: t2,
        rank_frac: frac,
        stage1_history: t1.history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_name_mapping() {
        assert_eq!(eval_name("train_mini_partial_full"), "eval_mini_partial_full");
        assert_eq!(eval_name("train_mini_unfact"), "eval_mini_unfact");
    }

    #[test]
    fn frac_tags_match_aot() {
        assert_eq!(frac_tag(0.125), "r125");
        assert_eq!(frac_tag(0.25), "r250");
        assert_eq!(frac_tag(0.375), "r375");
        assert_eq!(frac_tag(0.5), "r500");
        assert_eq!(frac_tag(0.75), "r750");
    }

    #[test]
    fn default_opts_sane() {
        let o = TrainOpts::default();
        assert!(o.lr > 0.0 && o.lr_decay <= 1.0 && o.epochs > 0);
    }
}

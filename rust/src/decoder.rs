//! CTC decoding (greedy + beam search with LM fusion) and error rates.
//!
//! Greedy decoding is the fast path the embedded engine uses; beam search
//! with character-LM fusion is the server/table path (Tables 1–2 report
//! WER under an external LM).  CER/WER are Levenshtein distances over
//! characters/words, matching the paper's metrics (§3.2: CER for WSJ
//! experiments, WER for the production tables).

use std::collections::BTreeMap;

use crate::data::{index_to_char, labels_to_text};
use crate::lm::CharLm;
use crate::tensor::Tensor;

pub const BLANK: i32 = 0;

/// One greedy (best-path) step: argmax of a log-prob row (strict `>`, so
/// ties go to the lowest index).  Shared by [`greedy_decode`] and the
/// incremental decoder of [`crate::stream`], which must collapse
/// identically.
#[inline]
pub fn greedy_step(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (j, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = j;
        }
    }
    best as i32
}

/// Greedy (best-path) decode of one utterance.
/// `logprobs`: (T, V) log-softmax rows; `len`: valid frames.
pub fn greedy_decode(logprobs: &Tensor, len: usize) -> Vec<i32> {
    let mut out = Vec::new();
    let mut prev = -1i32;
    for t in 0..len.min(logprobs.rows()) {
        let c = greedy_step(logprobs.row(t));
        if c != prev && c != BLANK {
            out.push(c);
        }
        prev = c;
    }
    out
}

/// Prefix beam search with optional character-LM shallow fusion.
///
/// Standard CTC prefix beam search (Hannun et al.): beams are label
/// prefixes carrying (log p_blank, log p_nonblank); extending by character
/// `c` adds `lm_weight · logP_lm(c | prefix)`.
pub fn beam_decode(
    logprobs: &Tensor,
    len: usize,
    beam_width: usize,
    lm: Option<&CharLm>,
    lm_weight: f64,
) -> Vec<i32> {
    let v = logprobs.cols();
    // prefix -> (p_b, p_nb) in log space
    let mut beams: BTreeMap<Vec<i32>, (f64, f64)> = BTreeMap::new();
    beams.insert(vec![], (0.0, f64::NEG_INFINITY));

    for t in 0..len.min(logprobs.rows()) {
        let row = logprobs.row(t);
        let mut next: BTreeMap<Vec<i32>, (f64, f64)> = BTreeMap::new();
        for (prefix, &(pb, pnb)) in &beams {
            let p_total = logaddexp(pb, pnb);
            // extend with blank: prefix unchanged
            {
                let e = next.entry(prefix.clone()).or_insert((f64::NEG_INFINITY, f64::NEG_INFINITY));
                e.0 = logaddexp(e.0, p_total + row[BLANK as usize] as f64);
            }
            // repeat last char: stays same prefix (non-blank path)
            if let Some(&last) = prefix.last() {
                let e = next.entry(prefix.clone()).or_insert((f64::NEG_INFINITY, f64::NEG_INFINITY));
                e.1 = logaddexp(e.1, pnb + row[last as usize] as f64);
            }
            // extend with a new character
            for c in 1..v as i32 {
                let p_c = row[c as usize] as f64;
                if p_c < -14.0 {
                    continue; // prune improbable symbols
                }
                let mut ext = prefix.clone();
                ext.push(c);
                // repeated char requires the blank path; different char any
                let base = if Some(&c) == prefix.last() { pb } else { p_total };
                if base == f64::NEG_INFINITY {
                    continue;
                }
                let lm_bonus = match lm {
                    Some(model) => lm_weight * model.logp(prefix, c),
                    None => 0.0,
                };
                let e = next.entry(ext).or_insert((f64::NEG_INFINITY, f64::NEG_INFINITY));
                e.1 = logaddexp(e.1, base + p_c + lm_bonus);
            }
        }
        // keep top beams
        let mut scored: Vec<(Vec<i32>, (f64, f64))> = next.into_iter().collect();
        scored.sort_by(|a, b| {
            logaddexp(b.1 .0, b.1 .1)
                .partial_cmp(&logaddexp(a.1 .0, a.1 .1))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        scored.truncate(beam_width);
        beams = scored.into_iter().collect();
    }

    beams
        .into_iter()
        .max_by(|a, b| {
            logaddexp(a.1 .0, a.1 .1)
                .partial_cmp(&logaddexp(b.1 .0, b.1 .1))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(prefix, _)| prefix)
        .unwrap_or_default()
}

/// `log(eᵃ + eᵇ)` without overflow; −∞-safe.  Shared by the beam
/// decoder's prefix merging and the CTC alpha/beta recursions of the
/// native trainer ([`crate::autograd::ctc`]).
pub fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

// ---------------------------------------------------------------------------
// Error rates.
// ---------------------------------------------------------------------------

/// Levenshtein edit distance between two sequences.
pub fn levenshtein<T: PartialEq>(a: &[T], b: &[T]) -> usize {
    let (n, m) = (a.len(), b.len());
    if n == 0 {
        return m;
    }
    let mut prev: Vec<usize> = (0..=m).collect();
    let mut cur = vec![0usize; m + 1];
    for i in 1..=n {
        cur[0] = i;
        for j in 1..=m {
            let cost = if a[i - 1] == b[j - 1] { 0 } else { 1 };
            cur[j] = (prev[j] + 1).min(cur[j - 1] + 1).min(prev[j - 1] + cost);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// Character error rate of hypothesis vs reference text.
pub fn cer(hyp: &str, reference: &str) -> f64 {
    let h: Vec<char> = hyp.chars().collect();
    let r: Vec<char> = reference.chars().collect();
    if r.is_empty() {
        return if h.is_empty() { 0.0 } else { 1.0 };
    }
    levenshtein(&h, &r) as f64 / r.len() as f64
}

/// Word error rate.
pub fn wer(hyp: &str, reference: &str) -> f64 {
    let h: Vec<&str> = hyp.split_whitespace().collect();
    let r: Vec<&str> = reference.split_whitespace().collect();
    if r.is_empty() {
        return if h.is_empty() { 0.0 } else { 1.0 };
    }
    levenshtein(&h, &r) as f64 / r.len() as f64
}

/// Aggregate error rates over a corpus (edit-distance-weighted, the
/// standard corpus-level definition).
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorStats {
    pub char_edits: usize,
    pub char_total: usize,
    pub word_edits: usize,
    pub word_total: usize,
    pub utterances: usize,
}

impl ErrorStats {
    pub fn push(&mut self, hyp: &str, reference: &str) {
        let h: Vec<char> = hyp.chars().collect();
        let r: Vec<char> = reference.chars().collect();
        self.char_edits += levenshtein(&h, &r);
        self.char_total += r.len();
        let hw: Vec<&str> = hyp.split_whitespace().collect();
        let rw: Vec<&str> = reference.split_whitespace().collect();
        self.word_edits += levenshtein(&hw, &rw);
        self.word_total += rw.len();
        self.utterances += 1;
    }

    pub fn cer(&self) -> f64 {
        if self.char_total == 0 {
            0.0
        } else {
            self.char_edits as f64 / self.char_total as f64
        }
    }

    pub fn wer(&self) -> f64 {
        if self.word_total == 0 {
            0.0
        } else {
            self.word_edits as f64 / self.word_total as f64
        }
    }
}

/// Decode a batch of logprob tensors to text via greedy decoding.
pub fn transcript_greedy(logprobs: &Tensor, len: usize) -> String {
    labels_to_text(&greedy_decode(logprobs, len))
}

/// Decode to text via beam search.
pub fn transcript_beam(
    logprobs: &Tensor,
    len: usize,
    beam: usize,
    lm: Option<&CharLm>,
    lm_weight: f64,
) -> String {
    beam_decode(logprobs, len, beam, lm, lm_weight)
        .iter()
        .filter_map(|&l| index_to_char(l))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proplite;

    /// Build (T, V) logprobs that put mass `p` on the path and spread the
    /// rest.
    fn path_logprobs(path: &[i32], v: usize, p: f32) -> Tensor {
        let t = path.len();
        let rest = ((1.0 - p) / (v as f32 - 1.0)).ln();
        let mut m = Tensor::full(&[t, v], rest);
        for (ti, &c) in path.iter().enumerate() {
            m.set2(ti, c as usize, p.ln());
        }
        m
    }

    #[test]
    fn greedy_collapses_repeats_and_blanks() {
        // path: a a <b> a b b  => "aab" in label space
        let a = 3i32;
        let b = 4i32;
        let lp = path_logprobs(&[a, a, BLANK, a, b, b], 6, 0.9);
        assert_eq!(greedy_decode(&lp, 6), vec![a, a, b]);
    }

    #[test]
    fn greedy_respects_length() {
        let a = 3i32;
        let lp = path_logprobs(&[a, BLANK, a, a], 6, 0.9);
        assert_eq!(greedy_decode(&lp, 1), vec![a]);
    }

    #[test]
    fn beam_equals_greedy_on_peaky_distributions() {
        let path = [5i32, 5, BLANK, 7, BLANK, 9, 9];
        let lp = path_logprobs(&path, 12, 0.98);
        let g = greedy_decode(&lp, path.len());
        let b = beam_decode(&lp, path.len(), 8, None, 0.0);
        assert_eq!(g, b);
    }

    #[test]
    fn beam_sums_paths_greedy_misses() {
        // classic case: two frames, p(a)=0.4, p(blank)=0.6 each frame.
        // greedy gives blank path => ""; beam sums a-paths:
        // P("a") = 0.4*0.4 + 0.4*0.6 + 0.6*0.4 = 0.64 > P("") = 0.36.
        let v = 4;
        let mut lp = Tensor::full(&[2, v], (0.001f32 / 2.0).ln());
        for t in 0..2 {
            lp.set2(t, 0, 0.599f32.ln());
            lp.set2(t, 3, 0.4f32.ln());
        }
        assert_eq!(greedy_decode(&lp, 2), Vec::<i32>::new());
        assert_eq!(beam_decode(&lp, 2, 8, None, 0.0), vec![3]);
    }

    #[test]
    fn lm_fusion_steers_ties() {
        let lm = CharLm::train(&["aa aa aa"], 2, 0);
        // ambiguous frame: 'a' vs 'b' nearly equal
        let a = crate::data::char_to_index('a').unwrap();
        let b = crate::data::char_to_index('b').unwrap();
        let v = 29;
        let mut lp = Tensor::full(&[1, v], (0.02f32 / 26.0).ln());
        lp.set2(0, a as usize, 0.49f32.ln());
        lp.set2(0, b as usize, 0.494f32.ln());
        // without LM: 'b' wins; with LM trained on 'a's: 'a' wins
        assert_eq!(beam_decode(&lp, 1, 4, None, 0.0), vec![b]);
        assert_eq!(beam_decode(&lp, 1, 4, Some(&lm), 1.0), vec![a]);
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein(b"kitten", b"sitting"), 3);
        assert_eq!(levenshtein(b"", b"abc"), 3);
        assert_eq!(levenshtein(b"abc", b"abc"), 0);
    }

    #[test]
    fn levenshtein_properties() {
        proplite::check(
            "levenshtein-triangle",
            60,
            |rng, size| {
                let mk = |rng: &mut crate::prng::Pcg64| -> Vec<u8> {
                    (0..rng.below(size + 2)).map(|_| rng.below(3) as u8).collect()
                };
                (mk(rng), mk(rng), mk(rng))
            },
            |(a, b, c)| {
                let ab = levenshtein(a, b);
                let bc = levenshtein(b, c);
                let ac = levenshtein(a, c);
                // symmetry, identity, triangle inequality
                ab == levenshtein(b, a)
                    && levenshtein(a, a) == 0
                    && ac <= ab + bc
                    && ab <= a.len().max(b.len())
            },
        );
    }

    #[test]
    fn error_stats_aggregate() {
        let mut s = ErrorStats::default();
        s.push("the cat", "the cat");
        s.push("the bat", "the cat");
        assert_eq!(s.utterances, 2);
        assert!(s.cer() > 0.0 && s.cer() < 0.2);
        assert!((s.wer() - 1.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn cer_wer_edge_cases() {
        assert_eq!(cer("", ""), 0.0);
        assert_eq!(cer("a", ""), 1.0);
        assert_eq!(wer("", "a b"), 1.0);
        assert_eq!(wer("a b", "a b"), 0.0);
    }
}

//! Rank-ladder model registry (DESIGN.md §8): the offline `ladder-build`
//! pass and the serve-time variant registry.
//!
//! The paper's central artifact is a *family* of models along the
//! accuracy-vs-parameters curve — trace-norm-trained, SVD-truncated at a
//! ladder of ranks, then int8-quantized (§3–§4).  [`ladder_build`] makes
//! that family a deployable unit: for each requested rank fraction it
//! runs the per-group truncated SVD ([`crate::model::truncate_groups`],
//! the same balanced-factor rule as the stage-2 warmstart), quantizes
//! every weight to int8 ([`crate::quant::quantize`]) — or int4 with
//! per-group scales ([`crate::quant::quantize4`], `ladder-build --bits 4`)
//! for half-size rungs — and writes one self-describing TNCK-v2 artifact
//! per rung plus a `ladder.json` manifest:
//!
//! ```text
//! <dir>/ladder.json        rung index: tag, file, rank_frac, params, bytes
//! <dir>/rung_r0500.tnck    v2 artifact: int8 factors + f32 biases + meta
//! <dir>/rung_r0250.tnck    (meta: scheme, rank_frac, model dims, ν(W) per group)
//! ...
//! ```
//!
//! [`Registry::load`] re-reads the ladder, verifies every artifact's
//! checksum, rebuilds an [`Engine`] per rung **directly from the stored
//! int8 factors** ([`Engine::from_entries`] — no SVD, no re-quantization
//! at load), and exposes the variants as fidelity tiers: tier 0 is the
//! highest-rank rung, deeper tiers are progressively cheaper.  The
//! admission controller ([`crate::controller`]) walks those tiers at
//! serve time.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::checkpoint::{self, Artifact, Entry};
use crate::error::{Error, Result};
use crate::infer::Engine;
use crate::jsonx::Json;
use crate::kernels::BackendSel;
use crate::model::{self, ParamSet};
use crate::quant::{quantize, quantize4};
use crate::runtime::ModelDims;

/// File name of the rung index inside a ladder directory.
pub const LADDER_MANIFEST: &str = "ladder.json";

/// Stable rung tag for a rank fraction: `r1000`, `r0500`, `r0250`, ...
pub fn rung_tag(rank_frac: f64) -> String {
    format!("r{:04}", (rank_frac * 1000.0).round() as u32)
}

/// Build-time facts about one rung, persisted in `ladder.json` and in
/// each artifact's metadata.
#[derive(Clone, Debug)]
pub struct RungInfo {
    pub tag: String,
    pub rank_frac: f64,
    /// artifact file name, relative to the ladder directory
    pub file: String,
    /// scalar parameter count of the factored model (the Fig-4 x-axis)
    pub params: usize,
    /// on-device weight bytes of the quantized artifact
    pub bytes: usize,
    /// weight precision of the rung (8 = int8, 4 = int4); artifacts
    /// written before the int4 path default to 8
    pub bits: u32,
    /// per-group nondimensional trace norm ν(W) after truncation
    pub nu: Vec<(String, f32)>,
    /// effective decode cost of the rung in GFLOP per raw input frame
    /// (2 × MACs/step ÷ stride).  Derived from the stored factor dims at
    /// build/load time — never persisted, so it can't drift from the
    /// artifact — and the number cascade rung-pair choice reads instead
    /// of recomputing it in `serve.rs`.
    pub gflops_per_frame: f64,
}

/// GFLOP per raw input frame for an engine: 2 ops per MAC, spread over
/// the frames one output step consumes.
fn engine_gflops_per_frame(engine: &Engine) -> f64 {
    2.0 * engine.macs_per_step() as f64 / engine.total_stride() as f64 / 1e9
}

/// Build a rank ladder from trained parameters: one int8 TNCK-v2
/// artifact per rank fraction, plus the `ladder.json` index.  Fractions
/// are deduplicated and sorted descending so rung order matches tier
/// order.  Returns the rung index in tier order.
pub fn ladder_build(
    params: &ParamSet,
    dims: &ModelDims,
    rank_fracs: &[f64],
    dir: &Path,
) -> Result<Vec<RungInfo>> {
    ladder_build_with_bits(params, dims, rank_fracs, 8, dir)
}

/// [`ladder_build`] with an explicit weight precision: 8 stores int8
/// per-tensor-scale entries, 4 stores int4 per-group-scale entries at
/// roughly half the bytes per rung (`ladder-build --bits 4`).  Biases
/// stay f32 either way.
pub fn ladder_build_with_bits(
    params: &ParamSet,
    dims: &ModelDims,
    rank_fracs: &[f64],
    bits: u32,
    dir: &Path,
) -> Result<Vec<RungInfo>> {
    if rank_fracs.is_empty() {
        return Err(Error::Config("ladder_build needs at least one rank fraction".into()));
    }
    if bits != 8 && bits != 4 {
        return Err(Error::Config(format!("ladder_build bits must be 8 or 4, got {bits}")));
    }
    let mut fracs: Vec<f64> = rank_fracs.to_vec();
    fracs.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
    fracs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
    std::fs::create_dir_all(dir)?;

    let mut rungs = Vec::with_capacity(fracs.len());
    for frac in fracs {
        let (factored, nu) = model::truncate_groups_diag(params, frac)?;
        let tag = rung_tag(frac);
        if let Some(clash) = rungs.iter().find(|r: &&RungInfo| r.tag == tag) {
            return Err(Error::Config(format!(
                "rank fractions {} and {frac} both map to rung tag '{tag}' \
                 (tags resolve 3 decimals); pick more distinct fractions",
                clash.rank_frac
            )));
        }
        let scalars = factored.num_scalars();

        let mut art = Artifact::new(rung_meta(dims, frac, &tag, scalars, bits, &nu));
        let t0 = std::time::Instant::now();
        for (name, t) in factored.iter() {
            if name.ends_with("_b") {
                art.set(name.clone(), Entry::F32(t.clone()));
            } else if bits == 4 {
                art.set(name.clone(), Entry::I4(quantize4(t)));
            } else {
                art.set(name.clone(), Entry::I8(quantize(t)));
            }
        }
        if crate::obs::enabled() {
            // build-time weight quantization is plan-time work: it lands
            // in the global spans, not any stream's decode breakdown
            crate::obs::spans::record_global(
                crate::obs::Stage::Quantize,
                t0.elapsed().as_secs_f64(),
            );
        }
        // fail the offline build, not the later serve, if the source
        // checkpoint and `dims` disagree (extra/missing layers) — every
        // rung must construct a servable engine
        let probe = Engine::from_entries(dims, &art.entries, 1)?;
        let file = format!("rung_{tag}.tnck");
        checkpoint::save_artifact(&art, dir.join(&file))?;
        rungs.push(RungInfo {
            tag,
            rank_frac: frac,
            file,
            params: scalars,
            bytes: art.payload_bytes(),
            bits,
            nu,
            gflops_per_frame: engine_gflops_per_frame(&probe),
        });
    }
    write_manifest(&rungs, dir)?;
    Ok(rungs)
}

/// One loaded ladder variant: its build-time facts plus a ready engine.
pub struct Variant {
    pub info: RungInfo,
    pub engine: Arc<Engine>,
}

/// The serve-time registry: every ladder variant loaded, verified and
/// wrapped in an engine, ordered fidelity-descending (tier 0 first).
pub struct Registry {
    pub dims: ModelDims,
    pub dir: PathBuf,
    variants: Vec<Variant>,
}

impl Registry {
    /// Load a ladder directory written by [`ladder_build`] with the
    /// default ([`BackendSel::Auto`]) GEMM backend.
    pub fn load(dir: &Path, time_batch: usize) -> Result<Registry> {
        Registry::load_with_backend(dir, time_batch, BackendSel::Auto)
    }

    /// Load a ladder directory written by [`ladder_build`].  Every
    /// artifact's checksum is verified on read, its metadata is checked
    /// against the manifest row, and all rungs must agree on model dims.
    /// Each rung's engine executes on `backend` (`--backend` on the CLI);
    /// weight packing for the blocked layout happens here, once per rung,
    /// never at serve time.
    pub fn load_with_backend(
        dir: &Path,
        time_batch: usize,
        backend: BackendSel,
    ) -> Result<Registry> {
        Registry::load_with_options(dir, time_batch, backend, true)
    }

    /// [`Registry::load_with_backend`] plus the fused GRU-gate switch
    /// (`--fused-gates` on the CLI): every rung's engine routes its
    /// recurrent GEMM through the gate-interleaved fused kernel when
    /// `fused` is set (decoding is bit-identical either way).  Gate
    /// panels are built here at load alongside the blocked packing.
    pub fn load_with_options(
        dir: &Path,
        time_batch: usize,
        backend: BackendSel,
        fused: bool,
    ) -> Result<Registry> {
        let manifest_path = dir.join(LADDER_MANIFEST);
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Checkpoint(format!("cannot read {}: {e}", manifest_path.display()))
        })?;
        let j = Json::parse(&text)?;
        let rows = j
            .req("rungs")?
            .as_arr()
            .ok_or_else(|| Error::Checkpoint("ladder.json 'rungs' must be an array".into()))?;
        if rows.is_empty() {
            return Err(Error::Checkpoint("ladder.json lists no rungs".into()));
        }

        let mut dims: Option<ModelDims> = None;
        let mut variants = Vec::with_capacity(rows.len());
        for row in rows {
            let file = json_str(row, "file")?;
            let art = checkpoint::load_artifact(dir.join(&file))?;
            let mut info = rung_info_from_meta(&art.meta, &file)?;
            info.bytes = art.payload_bytes();
            let want_frac = json_f64(row, "rank_frac")?;
            if (info.rank_frac - want_frac).abs() > 1e-9 {
                return Err(Error::Checkpoint(format!(
                    "rung {file}: manifest rank_frac {want_frac} != artifact {}",
                    info.rank_frac
                )));
            }
            let d = ModelDims::from_json(art.meta.req("dims")?)?;
            match &dims {
                None => dims = Some(d),
                Some(have) if have.same_as(&d) => {}
                Some(_) => {
                    return Err(Error::Checkpoint(format!(
                        "rung {file}: model dims disagree with earlier rungs"
                    )))
                }
            }
            let mut engine =
                Engine::from_entries(dims.as_ref().unwrap(), &art.entries, time_batch)?;
            engine.set_backend(backend)?;
            engine.set_fused_gates(fused);
            info.gflops_per_frame = engine_gflops_per_frame(&engine);
            variants.push(Variant { info, engine: Arc::new(engine) });
        }
        variants.sort_by(|a, b| {
            b.info
                .rank_frac
                .partial_cmp(&a.info.rank_frac)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        Ok(Registry { dims: dims.unwrap(), dir: dir.to_path_buf(), variants })
    }

    pub fn num_tiers(&self) -> usize {
        self.variants.len()
    }

    /// Variant at fidelity tier `t` (0 = highest rank).
    pub fn tier(&self, t: usize) -> &Variant {
        &self.variants[t]
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// The tier engines in fidelity order — the shared plan handed to
    /// every worker shard of a sharded ladder serve (DESIGN.md §9).
    /// Cloning the `Arc`s is free; the prepared weights exist once no
    /// matter how many shards serve them.
    pub fn engines(&self) -> Vec<Arc<Engine>> {
        self.variants.iter().map(|v| v.engine.clone()).collect()
    }

    /// Resolve one side of a `--cascade LOW:HIGH` spec to a tier index:
    /// either a rung tag (`r0250`) or a bare tier index (`1`).
    fn resolve_rung(&self, part: &str) -> Result<usize> {
        if let Some(t) = self.variants.iter().position(|v| v.info.tag == part) {
            return Ok(t);
        }
        if let Ok(t) = part.parse::<usize>() {
            if t < self.variants.len() {
                return Ok(t);
            }
            return Err(Error::Config(format!(
                "cascade rung '{part}': tier index out of range (ladder has {} tiers)",
                self.variants.len()
            )));
        }
        Err(Error::Config(format!(
            "cascade rung '{part}': no rung with that tag or tier index (tags: {})",
            self.variants.iter().map(|v| v.info.tag.as_str()).collect::<Vec<_>>().join(", ")
        )))
    }

    /// Parse a `--cascade LOW:HIGH` rung-pair spec against this ladder.
    /// Each side is a rung tag (`r0250`) or tier index; LOW is the rung
    /// every block decodes on first (cheaper, *higher* tier index), HIGH
    /// the escalation target.  Returns `(low_tier, high_tier)`.
    pub fn cascade_pair(&self, spec: &str) -> Result<(usize, usize)> {
        let (low_s, high_s) = spec.split_once(':').ok_or_else(|| {
            Error::Config(format!("cascade spec '{spec}' must be LOW:HIGH (rung tags or tiers)"))
        })?;
        let low = self.resolve_rung(low_s.trim())?;
        let high = self.resolve_rung(high_s.trim())?;
        if low == high {
            return Err(Error::Config(format!(
                "cascade spec '{spec}': LOW and HIGH resolve to the same rung"
            )));
        }
        // tier 0 is the highest-fidelity rung: the cheap decode rung must
        // sit *deeper* in the ladder than its escalation target
        if low < high {
            return Err(Error::Config(format!(
                "cascade spec '{spec}': LOW ({}, {:.1} GFLOP/frame) is costlier than \
                 HIGH ({}, {:.1} GFLOP/frame) — swap the pair",
                self.variants[low].info.tag,
                self.variants[low].info.gflops_per_frame,
                self.variants[high].info.tag,
                self.variants[high].info.gflops_per_frame,
            )));
        }
        Ok((low, high))
    }

    /// Whether two rungs share a byte-identical conv frontend.  The
    /// frontend is never factored (§3.2) and build-time quantization is
    /// deterministic, so rungs built from the same checkpoint at the
    /// same weight precision carry identical frontend entries — the
    /// cascade then reuses the low rung's frontend output on escalation
    /// instead of recomputing it.
    pub fn shared_frontend(&self, a: usize, b: usize) -> bool {
        self.variants[a].info.bits == self.variants[b].info.bits
    }
}

// Compile-time Send+Sync audit (DESIGN.md §9): a loaded registry is
// read-only shared state for the whole shard fleet.
const _: () = crate::assert_send_sync::<Registry>();
const _: () = crate::assert_send_sync::<Variant>();

// ---------------------------------------------------------------------------
// JSON plumbing (manifest + per-artifact metadata).
// ---------------------------------------------------------------------------

fn write_manifest(rungs: &[RungInfo], dir: &Path) -> Result<()> {
    let rows: Vec<Json> = rungs
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("tag", Json::str(r.tag.clone())),
                ("file", Json::str(r.file.clone())),
                ("rank_frac", Json::num(r.rank_frac)),
                ("params", Json::num(r.params as f64)),
                ("bytes", Json::num(r.bytes as f64)),
                ("bits", Json::num(r.bits as f64)),
            ])
        })
        .collect();
    let j = Json::obj(vec![("kind", Json::str("ladder")), ("rungs", Json::Arr(rows))]);
    std::fs::write(dir.join(LADDER_MANIFEST), j.to_string_pretty())?;
    Ok(())
}

fn rung_meta(
    dims: &ModelDims,
    frac: f64,
    tag: &str,
    params: usize,
    bits: u32,
    nu: &[(String, f32)],
) -> Json {
    let nu_obj = Json::Obj(
        nu.iter().map(|(base, v)| (base.clone(), Json::Num(*v as f64))).collect(),
    );
    Json::obj(vec![
        ("kind", Json::str("ladder-rung")),
        ("scheme", Json::str("partial")),
        ("tag", Json::str(tag)),
        ("rank_frac", Json::num(frac)),
        ("params", Json::num(params as f64)),
        ("bits", Json::num(bits as f64)),
        ("dims", dims.to_json()),
        ("nu", nu_obj),
    ])
}

fn rung_info_from_meta(meta: &Json, file: &str) -> Result<RungInfo> {
    if json_str(meta, "kind")? != "ladder-rung" {
        return Err(Error::Checkpoint(format!("{file}: not a ladder-rung artifact")));
    }
    let nu = meta
        .req("nu")?
        .as_obj()
        .ok_or_else(|| Error::Checkpoint(format!("{file}: 'nu' must be an object")))?
        .iter()
        .map(|(k, v)| {
            v.as_f64()
                .map(|f| (k.clone(), f as f32))
                .ok_or_else(|| Error::Checkpoint(format!("{file}: non-numeric nu entry")))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(RungInfo {
        tag: json_str(meta, "tag")?,
        rank_frac: json_f64(meta, "rank_frac")?,
        file: file.to_string(),
        params: json_f64(meta, "params")? as usize,
        bytes: 0, // caller fills this from the loaded entries
        // pre-int4 artifacts carry no 'bits' key: they are int8
        bits: meta.get("bits").and_then(|b| b.as_f64()).map(|b| b as u32).unwrap_or(8),
        nu,
        gflops_per_frame: 0.0, // caller derives this from the built engine
    })
}

fn json_str(j: &Json, key: &str) -> Result<String> {
    j.req(key)?
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Checkpoint(format!("'{key}' must be a string")))
}

fn json_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .ok_or_else(|| Error::Checkpoint(format!("'{key}' must be a number")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ConvDims;

    #[test]
    fn rung_tags_are_stable() {
        assert_eq!(rung_tag(1.0), "r1000");
        assert_eq!(rung_tag(0.5), "r0500");
        assert_eq!(rung_tag(0.25), "r0250");
        assert_eq!(rung_tag(0.125), "r0125");
    }

    #[test]
    fn dims_json_roundtrip() {
        let d = ModelDims {
            feat_dim: 8,
            conv: vec![ConvDims { context: 2, dim: 12 }],
            gru_dims: vec![10, 12],
            fc_dim: 14,
            vocab: 29,
            total_stride: 2,
        };
        let j = d.to_json();
        let back = ModelDims::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert!(d.same_as(&back));
    }

    #[test]
    fn empty_ladder_rejected() {
        let dir = std::env::temp_dir().join(format!("tnladder-empty-{}", std::process::id()));
        assert!(ladder_build(&ParamSet::new(), &demo_dims_tiny(), &[], &dir).is_err());
    }

    fn demo_dims_tiny() -> ModelDims {
        ModelDims {
            feat_dim: 8,
            conv: vec![ConvDims { context: 2, dim: 12 }],
            gru_dims: vec![10],
            fc_dim: 14,
            vocab: 29,
            total_stride: 2,
        }
    }

    // end-to-end build -> load -> bit-identical serve lives in
    // rust/tests/ladder.rs
}

//! Int8 quantization (paper §4): symmetric per-tensor scheme.
//!
//! The paper quantizes weights and GEMM inputs to unsigned 8-bit after
//! training ("2% to 4% relative increase in WER").  We use the symmetric
//! signed-int8 variant (zero-point 0), which composes directly with the
//! widening multiply-accumulate in [`crate::kernels`]: the asymmetric
//! row/column-offset corrections gemmlowp needs are exactly the
//! bookkeeping the farm-style kernel avoids at small batch.

use crate::tensor::{Tensor, TensorI8};

/// Quantized matrix: `w ≈ scale * q`.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub q: TensorI8,
    pub scale: f32,
}

/// Symmetric per-tensor quantization: scale = max|w| / 127.
pub fn quantize(w: &Tensor) -> QMatrix {
    let amax = w.abs_max().max(1e-12);
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    let data: Vec<i8> = w
        .data()
        .iter()
        .map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8)
        .collect();
    QMatrix { q: TensorI8::new(w.shape(), data).unwrap(), scale }
}

/// Quantize a row-slice of activations into a caller-provided buffer,
/// returning the scale (dynamic activation quantization, one scale per
/// GEMM call, as the embedded runtime does).
pub fn quantize_into(x: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), out.len());
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Analytic worst-case absolute error of an int8 GEMM output element
/// against the f32 reference, for a `k`-length contraction with
/// activation scale `sx` and weight scale `sw`.
///
/// With symmetric round-to-nearest quantization each operand carries at
/// most half a step of error (`|eₓ| ≤ sx/2`, `|e_w| ≤ sw/2`) and the
/// quantized magnitudes are bounded by 127, so per product term
/// `|x·w − sx·sw·x_q·w_q| ≤ sx·127·(sw/2) + sw·127·(sx/2) + (sx/2)(sw/2)`,
/// giving `k · sx · sw · 127.25` over the contraction.  A small slack
/// covers f32 accumulation rounding on both sides (negligible next to
/// the quantization term for the k used here).  `tests/properties.rs`
/// asserts every qgemm kernel stays inside this bound.
pub fn qgemm_abs_error_bound(k: usize, sx: f32, sw: f32) -> f32 {
    let quant = k as f32 * sx * sw * 127.25;
    quant * 1.01 + 1e-6
}

pub fn dequantize(q: &QMatrix) -> Tensor {
    let data: Vec<f32> = q.q.data().iter().map(|&v| v as f32 * q.scale).collect();
    Tensor::new(q.q.shape(), data).unwrap()
}

/// Quantization error statistics (for EXPERIMENTS.md and tests).
#[derive(Clone, Copy, Debug)]
pub struct QuantError {
    pub max_abs: f32,
    pub rms: f32,
    /// error relative to the RMS of the original tensor
    pub rel_rms: f32,
}

pub fn quant_error(w: &Tensor) -> QuantError {
    let deq = dequantize(&quantize(w));
    let n = w.len().max(1);
    let mut max_abs = 0.0f32;
    let mut sum_sq = 0.0f64;
    let mut ref_sq = 0.0f64;
    for (a, b) in w.data().iter().zip(deq.data()) {
        let e = (a - b).abs();
        max_abs = max_abs.max(e);
        sum_sq += (e as f64) * (e as f64);
        ref_sq += (*a as f64) * (*a as f64);
    }
    let rms = (sum_sq / n as f64).sqrt() as f32;
    let ref_rms = (ref_sq / n as f64).sqrt().max(1e-12) as f32;
    QuantError { max_abs, rms, rel_rms: rms / ref_rms }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seeded(0);
        let w = Tensor::randn(&[37, 53], 0.3, &mut rng);
        let q = quantize(&w);
        let deq = dequantize(&q);
        let half_step = q.scale * 0.5 + 1e-7;
        assert!(w.max_abs_diff(&deq) <= half_step);
    }

    #[test]
    fn scale_covers_max() {
        let w = Tensor::new(&[1, 4], vec![0.1, -2.0, 0.5, 1.9]).unwrap();
        let q = quantize(&w);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-7);
        // extreme value maps to ±127
        assert_eq!(q.q.data()[1], -127);
    }

    #[test]
    fn quantize_into_matches_quantize() {
        let mut rng = Pcg64::seeded(1);
        let w = Tensor::randn(&[1, 64], 1.0, &mut rng);
        let q = quantize(&w);
        let mut buf = vec![0i8; 64];
        let scale = quantize_into(w.data(), &mut buf);
        assert!((scale - q.scale).abs() < 1e-9);
        assert_eq!(&buf, q.q.data());
    }

    #[test]
    fn relative_error_small_for_gaussian() {
        let mut rng = Pcg64::seeded(2);
        let w = Tensor::randn(&[128, 128], 1.0, &mut rng);
        let e = quant_error(&w);
        // int8 SNR for a Gaussian clipped at ~4.3 sigma: rel err well under 2%
        assert!(e.rel_rms < 0.02, "rel_rms {}", e.rel_rms);
    }

    #[test]
    fn zero_tensor_quantizes_to_zero() {
        let w = Tensor::zeros(&[3, 3]);
        let q = quantize(&w);
        assert!(q.q.data().iter().all(|&v| v == 0));
    }
}

//! Int8 and int4 quantization (paper §4): symmetric schemes.
//!
//! The paper quantizes weights and GEMM inputs to unsigned 8-bit after
//! training ("2% to 4% relative increase in WER").  We use the symmetric
//! signed-int8 variant (zero-point 0), which composes directly with the
//! widening multiply-accumulate in [`crate::kernels`]: the asymmetric
//! row/column-offset corrections gemmlowp needs are exactly the
//! bookkeeping the farm-style kernel avoids at small batch.
//!
//! The int4 path ([`Q4Matrix`]) halves bytes-per-weight again, which is
//! the dominant lever at batch 1 where the GEMM is bound by streaming
//! weight bytes.  A single per-tensor scale is too coarse at 4 bits, so
//! weights quantize symmetrically per **group** of [`Q4_GROUP`]
//! consecutive columns with one f32 scale each (scale = group max / 7;
//! values in [-7, 7], stored as two's-complement nibbles, two per byte).
//! Activations stay int8 — the kernels widen nibbles to i16/i32 and the
//! per-group scale multiplies an exact i32 sub-accumulation, which is
//! what makes the int4 path bit-identical across backends.

use crate::tensor::{Tensor, TensorI8};

/// Quantized matrix: `w ≈ scale * q`.
#[derive(Clone, Debug)]
pub struct QMatrix {
    pub q: TensorI8,
    pub scale: f32,
}

/// Symmetric per-tensor quantization: scale = max|w| / 127.
pub fn quantize(w: &Tensor) -> QMatrix {
    let amax = w.abs_max().max(1e-12);
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    let data: Vec<i8> = w
        .data()
        .iter()
        .map(|&v| (v * inv).round().clamp(-127.0, 127.0) as i8)
        .collect();
    QMatrix { q: TensorI8::new(w.shape(), data).unwrap(), scale }
}

/// Quantize a row-slice of activations into a caller-provided buffer,
/// returning the scale (dynamic activation quantization, one scale per
/// GEMM call, as the embedded runtime does).
pub fn quantize_into(x: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), out.len());
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
    let scale = amax / 127.0;
    let inv = 1.0 / scale;
    for (o, &v) in out.iter_mut().zip(x) {
        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Analytic worst-case absolute error of an int8 GEMM output element
/// against the f32 reference, for a `k`-length contraction with
/// activation scale `sx` and weight scale `sw`.
///
/// With symmetric round-to-nearest quantization each operand carries at
/// most half a step of error (`|eₓ| ≤ sx/2`, `|e_w| ≤ sw/2`) and the
/// quantized magnitudes are bounded by 127, so per product term
/// `|x·w − sx·sw·x_q·w_q| ≤ sx·127·(sw/2) + sw·127·(sx/2) + (sx/2)(sw/2)`,
/// giving `k · sx · sw · 127.25` over the contraction.  A small slack
/// covers f32 accumulation rounding on both sides (negligible next to
/// the quantization term for the k used here).  `tests/properties.rs`
/// asserts every qgemm kernel stays inside this bound.
pub fn qgemm_abs_error_bound(k: usize, sx: f32, sw: f32) -> f32 {
    let quant = k as f32 * sx * sw * 127.25;
    quant * 1.01 + 1e-6
}

pub fn dequantize(q: &QMatrix) -> Tensor {
    let data: Vec<f32> = q.q.data().iter().map(|&v| v as f32 * q.scale).collect();
    Tensor::new(q.q.shape(), data).unwrap()
}

// ---------------------------------------------------------------------------
// Int4: per-group symmetric quantization, two nibbles per byte.
// ---------------------------------------------------------------------------

/// Columns per int4 scale group.  Chosen to divide every blocked-backend
/// strip width ([`crate::kernels::autotune::CANDIDATES`] uses kc ∈
/// {128, 256, 512}), so a KC strip always covers whole groups and the
/// packed cores never split a group's i32 sub-accumulation across strips.
pub const Q4_GROUP: usize = 32;

/// Sign-extend the low nibble of a packed byte.
#[inline(always)]
pub fn nibble_lo(b: u8) -> i8 {
    (((b & 0x0f) << 4) as i8) >> 4
}

/// Sign-extend the high nibble of a packed byte.
#[inline(always)]
pub fn nibble_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Pack two int4 values (each in [-8, 7]) into one byte: `lo` in the low
/// nibble, `hi` in the high nibble (two's complement).
#[inline(always)]
pub fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    ((lo as u8) & 0x0f) | ((hi as u8) << 4)
}

/// Int4-quantized matrix: `w[r, c] ≈ scales[r·ngroups + c/group] · q[r, c]`,
/// with `q` stored as two's-complement nibbles, two per byte.
///
/// Row-major layout: each row is `ceil(k/2)` bytes; byte `j` of a row
/// holds column `2j` in its low nibble and column `2j+1` in its high
/// nibble (the high nibble of the last byte is zero when `k` is odd).
/// Scales are row-major `(n, ngroups)` with `ngroups = ceil(k/group)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Q4Matrix {
    shape: [usize; 2], // (n, k)
    group: usize,
    data: Vec<u8>,
    scales: Vec<f32>,
}

impl Q4Matrix {
    /// Rebuild from stored parts (the checkpoint loader); validates the
    /// byte/scale counts against the logical shape.
    pub fn from_parts(
        n: usize,
        k: usize,
        group: usize,
        data: Vec<u8>,
        scales: Vec<f32>,
    ) -> Option<Q4Matrix> {
        if group == 0 || data.len() != n * k.div_ceil(2) || scales.len() != n * k.div_ceil(group)
        {
            return None;
        }
        Some(Q4Matrix { shape: [n, k], group, data, scales })
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        self.shape[1]
    }

    /// `(n, k)` as a shape slice (checkpoint entries expose it).
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Columns per scale group.
    pub fn group(&self) -> usize {
        self.group
    }

    /// Scale groups per row.
    pub fn ngroups(&self) -> usize {
        self.cols().div_ceil(self.group)
    }

    /// Packed bytes per row.
    pub fn row_bytes(&self) -> usize {
        self.cols().div_ceil(2)
    }

    /// All packed nibble bytes, row-major.
    pub fn data(&self) -> &[u8] {
        &self.data
    }

    /// All per-group scales, row-major `(n, ngroups)`.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Packed bytes of row `r`.
    pub fn row_data(&self, r: usize) -> &[u8] {
        let rb = self.row_bytes();
        &self.data[r * rb..(r + 1) * rb]
    }

    /// Group scales of row `r`.
    pub fn row_scales(&self, r: usize) -> &[f32] {
        let g = self.ngroups();
        &self.scales[r * g..(r + 1) * g]
    }

    /// Decode one element (sign-extended int4 value).
    pub fn get(&self, r: usize, c: usize) -> i8 {
        debug_assert!(r < self.rows() && c < self.cols());
        let b = self.data[r * self.row_bytes() + c / 2];
        if c % 2 == 0 {
            nibble_lo(b)
        } else {
            nibble_hi(b)
        }
    }

    /// Largest group scale (the `sw` of [`qgemm4_abs_error_bound`]).
    pub fn max_scale(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(s))
    }

    /// On-device payload bytes: packed nibbles plus the f32 scales.
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Per-group symmetric int4 quantization with the default [`Q4_GROUP`]
/// group width.  `w` must be rank 2 (weight matrices only — biases stay
/// f32 on the embedded path).
pub fn quantize4(w: &Tensor) -> Q4Matrix {
    quantize4_grouped(w, Q4_GROUP)
}

/// [`quantize4`] with an explicit group width (tests exercise ragged
/// tails; production uses [`Q4_GROUP`]).
pub fn quantize4_grouped(w: &Tensor, group: usize) -> Q4Matrix {
    assert!(group > 0, "group width must be positive");
    assert_eq!(w.rank(), 2, "int4 quantization is for rank-2 weights");
    let (n, k) = (w.rows(), w.cols());
    let ngroups = k.div_ceil(group);
    let row_bytes = k.div_ceil(2);
    let mut data = vec![0u8; n * row_bytes];
    let mut scales = vec![0.0f32; n * ngroups];
    let mut qrow = vec![0i8; k];
    for r in 0..n {
        let row = w.row(r);
        for g in 0..ngroups {
            let c0 = g * group;
            let c1 = (c0 + group).min(k);
            let amax = row[c0..c1].iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            let scale = amax / 7.0;
            let inv = 1.0 / scale;
            scales[r * ngroups + g] = scale;
            for c in c0..c1 {
                qrow[c] = (row[c] * inv).round().clamp(-7.0, 7.0) as i8;
            }
        }
        for j in 0..row_bytes {
            let lo = qrow[2 * j];
            let hi = if 2 * j + 1 < k { qrow[2 * j + 1] } else { 0 };
            data[r * row_bytes + j] = pack_nibbles(lo, hi);
        }
    }
    Q4Matrix { shape: [n, k], group, data, scales }
}

/// Reconstruct the f32 matrix a [`Q4Matrix`] represents.
pub fn dequantize4(q: &Q4Matrix) -> Tensor {
    let (n, k, group) = (q.rows(), q.cols(), q.group());
    let ngroups = q.ngroups();
    let mut data = vec![0.0f32; n * k];
    for r in 0..n {
        for c in 0..k {
            let s = q.scales()[r * ngroups + c / group];
            data[r * k + c] = q.get(r, c) as f32 * s;
        }
    }
    Tensor::new(&[n, k], data).unwrap()
}

/// Quantize-dequantize through the exact serving int4 quantizer — the
/// forward of the straight-through-estimator `fake_quant` op
/// ([`crate::autograd`]), so quantization-aware fine-tuning optimizes
/// against precisely the rounding the inference engine will apply.
pub fn fake_quantize4(w: &Tensor) -> Tensor {
    dequantize4(&quantize4(w))
}

/// [`fake_quantize4`]'s int8 sibling (per-tensor, the serving int8
/// quantizer verbatim).
pub fn fake_quantize8(w: &Tensor) -> Tensor {
    dequantize(&quantize(w))
}

/// Analytic worst-case absolute error of an int4-weight GEMM output
/// element against the f32 reference, for a `k`-length contraction with
/// int8 activation scale `sx` and **largest** group scale `sw`
/// ([`Q4Matrix::max_scale`]).
///
/// Same derivation as [`qgemm_abs_error_bound`] with the weight magnitude
/// bound dropping from 127 to 7: per product term
/// `|x·w − sx·s_g·x_q·w_q| ≤ sx·127·(s_g/2) + s_g·7·(sx/2) + (sx/2)(s_g/2)`,
/// i.e. `sx·s_g·67.25`, and `s_g ≤ sw` for every group, giving
/// `k · sx · sw · 67.25` over the contraction plus f32 rounding slack.
pub fn qgemm4_abs_error_bound(k: usize, sx: f32, sw: f32) -> f32 {
    let quant = k as f32 * sx * sw * 67.25;
    quant * 1.01 + 1e-6
}

/// Quantization error statistics (for EXPERIMENTS.md and tests).
#[derive(Clone, Copy, Debug)]
pub struct QuantError {
    pub max_abs: f32,
    pub rms: f32,
    /// error relative to the RMS of the original tensor
    pub rel_rms: f32,
}

pub fn quant_error(w: &Tensor) -> QuantError {
    let deq = dequantize(&quantize(w));
    let n = w.len().max(1);
    let mut max_abs = 0.0f32;
    let mut sum_sq = 0.0f64;
    let mut ref_sq = 0.0f64;
    for (a, b) in w.data().iter().zip(deq.data()) {
        let e = (a - b).abs();
        max_abs = max_abs.max(e);
        sum_sq += (e as f64) * (e as f64);
        ref_sq += (*a as f64) * (*a as f64);
    }
    let rms = (sum_sq / n as f64).sqrt() as f32;
    let ref_rms = (ref_sq / n as f64).sqrt().max(1e-12) as f32;
    QuantError { max_abs, rms, rel_rms: rms / ref_rms }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let mut rng = Pcg64::seeded(0);
        let w = Tensor::randn(&[37, 53], 0.3, &mut rng);
        let q = quantize(&w);
        let deq = dequantize(&q);
        let half_step = q.scale * 0.5 + 1e-7;
        assert!(w.max_abs_diff(&deq) <= half_step);
    }

    #[test]
    fn scale_covers_max() {
        let w = Tensor::new(&[1, 4], vec![0.1, -2.0, 0.5, 1.9]).unwrap();
        let q = quantize(&w);
        assert!((q.scale - 2.0 / 127.0).abs() < 1e-7);
        // extreme value maps to ±127
        assert_eq!(q.q.data()[1], -127);
    }

    #[test]
    fn quantize_into_matches_quantize() {
        let mut rng = Pcg64::seeded(1);
        let w = Tensor::randn(&[1, 64], 1.0, &mut rng);
        let q = quantize(&w);
        let mut buf = vec![0i8; 64];
        let scale = quantize_into(w.data(), &mut buf);
        assert!((scale - q.scale).abs() < 1e-9);
        assert_eq!(&buf, q.q.data());
    }

    #[test]
    fn relative_error_small_for_gaussian() {
        let mut rng = Pcg64::seeded(2);
        let w = Tensor::randn(&[128, 128], 1.0, &mut rng);
        let e = quant_error(&w);
        // int8 SNR for a Gaussian clipped at ~4.3 sigma: rel err well under 2%
        assert!(e.rel_rms < 0.02, "rel_rms {}", e.rel_rms);
    }

    #[test]
    fn zero_tensor_quantizes_to_zero() {
        let w = Tensor::zeros(&[3, 3]);
        let q = quantize(&w);
        assert!(q.q.data().iter().all(|&v| v == 0));
    }

    #[test]
    fn nibble_pack_roundtrips_full_range() {
        for lo in -8i8..=7 {
            for hi in -8i8..=7 {
                let b = pack_nibbles(lo, hi);
                assert_eq!(nibble_lo(b), lo);
                assert_eq!(nibble_hi(b), hi);
            }
        }
    }

    #[test]
    fn q4_roundtrip_error_bounded_by_half_group_step() {
        let mut rng = Pcg64::seeded(4);
        // ragged k (odd, non-multiple of the group) exercises both tails
        let w = Tensor::randn(&[9, 77], 0.3, &mut rng);
        let q = quantize4(&w);
        assert_eq!(q.ngroups(), 77usize.div_ceil(Q4_GROUP));
        assert_eq!(q.row_bytes(), 39);
        let deq = dequantize4(&q);
        for r in 0..w.rows() {
            for c in 0..w.cols() {
                let s = q.row_scales(r)[c / Q4_GROUP];
                let e = (w.row(r)[c] - deq.row(r)[c]).abs();
                assert!(e <= 0.5 * s + 1e-7, "({r},{c}): err {e} > half step {}", 0.5 * s);
            }
        }
    }

    #[test]
    fn q4_scale_covers_group_max_and_extreme_maps_to_7() {
        // two groups of 2 with very different ranges: per-group scales
        // must adapt where a per-tensor scale would crush the small group
        let w = Tensor::new(&[1, 4], vec![0.01, -0.02, 7.0, -3.5]).unwrap();
        let q = quantize4_grouped(&w, 2);
        assert!((q.row_scales(0)[0] - 0.02 / 7.0).abs() < 1e-9);
        assert!((q.row_scales(0)[1] - 1.0).abs() < 1e-9);
        assert_eq!(q.get(0, 1), -7);
        assert_eq!(q.get(0, 2), 7);
        assert_eq!(q.max_scale(), 1.0);
    }

    #[test]
    fn q4_from_parts_validates_lengths() {
        let w = Tensor::zeros(&[3, 5]);
        let q = quantize4_grouped(&w, 4);
        let rebuilt = Q4Matrix::from_parts(
            3,
            5,
            4,
            q.data().to_vec(),
            q.scales().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt.row_bytes(), q.row_bytes());
        assert!(Q4Matrix::from_parts(3, 5, 4, vec![0u8; 2], q.scales().to_vec()).is_none());
        assert!(Q4Matrix::from_parts(3, 5, 0, q.data().to_vec(), q.scales().to_vec()).is_none());
    }

    #[test]
    fn q4_payload_is_half_byte_per_weight_plus_scales() {
        let mut rng = Pcg64::seeded(5);
        let w = Tensor::randn(&[64, 256], 0.5, &mut rng);
        let q = quantize4(&w);
        let weights = 64 * 256;
        let scale_bytes = 64 * (256 / Q4_GROUP) * 4;
        assert_eq!(q.payload_bytes(), weights / 2 + scale_bytes);
        // ~0.5 bytes/weight once scales amortize over 32-wide groups
        let bpw = q.payload_bytes() as f64 / weights as f64;
        assert!(bpw < 0.7, "bytes/weight {bpw}");
    }

    #[test]
    fn fake_quantize_matches_serving_quantizers() {
        let mut rng = Pcg64::seeded(6);
        let w = Tensor::randn(&[7, 33], 0.4, &mut rng);
        assert_eq!(fake_quantize4(&w), dequantize4(&quantize4(&w)));
        assert_eq!(fake_quantize8(&w), dequantize(&quantize(&w)));
        // idempotent: re-quantizing a fake-quantized tensor is a no-op
        let fq = fake_quantize4(&w);
        assert!(fq.max_abs_diff(&fake_quantize4(&fq)) < 1e-6);
    }
}

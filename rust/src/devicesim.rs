//! Device roofline models for the paper's embedded targets.
//!
//! The paper reports (Fig. 6 caption) peak single-core throughputs of
//! 56.16 / 22.4 / 9.6 GOP/s for iPhone 7, iPhone 6 and Raspberry Pi 3, and
//! notes the kernels are "mostly limited by memory bandwidth".  We model
//! each device as `time = max(ops / (eff_c · peak_ops), bytes / (eff_b ·
//! bandwidth))` — the classic roofline — with efficiency factors calibrated
//! so the farm/gemmlowp contrast measured on the host (which is an
//! *algorithmic* property: packing traffic vs streaming, see
//! [`crate::kernels`]) projects onto each device's absolute scale.

use crate::kernels::GemmCounts;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// peak single-core ops/s (1 MAC = 2 ops), from the paper
    pub peak_gops: f64,
    /// sustained memory bandwidth, GB/s (public STREAM-class numbers)
    pub mem_bw_gbs: f64,
    /// fraction of peak compute a tuned int8 kernel sustains
    pub compute_eff: f64,
    /// fraction of peak bandwidth sustained on streaming reads
    pub bw_eff: f64,
}

/// iPhone 7 (A10 Fusion, 1 big core).
pub const IPHONE7: Device = Device {
    name: "iPhone 7",
    peak_gops: 56.16,
    mem_bw_gbs: 12.8,
    compute_eff: 0.75,
    bw_eff: 0.65,
};

/// iPhone 6 (A8).
pub const IPHONE6: Device = Device {
    name: "iPhone 6",
    peak_gops: 22.4,
    mem_bw_gbs: 6.4,
    compute_eff: 0.75,
    bw_eff: 0.65,
};

/// Raspberry Pi 3 Model B (Cortex-A53 @ 1.2 GHz).
pub const RPI3: Device = Device {
    name: "Raspberry Pi 3",
    peak_gops: 9.6,
    mem_bw_gbs: 2.8,
    compute_eff: 0.70,
    bw_eff: 0.55,
};

/// A generous "GPU server" stand-in for the Table-2 baseline row.
pub const GPU_SERVER: Device = Device {
    name: "GPU server",
    peak_gops: 10_000.0,
    mem_bw_gbs: 700.0,
    compute_eff: 0.6,
    bw_eff: 0.7,
};

pub const ALL_EMBEDDED: [Device; 3] = [IPHONE7, IPHONE6, RPI3];

impl Device {
    /// Roofline execution time (seconds) for an op/byte profile.
    pub fn roofline_secs(&self, c: &GemmCounts) -> f64 {
        let compute = c.ops() as f64 / (self.peak_gops * 1e9 * self.compute_eff);
        let bytes = (c.bytes_read + c.bytes_written) as f64;
        let memory = bytes / (self.mem_bw_gbs * 1e9 * self.bw_eff);
        compute.max(memory)
    }

    /// Achieved GOP/s for the profile under the roofline.
    pub fn achieved_gops(&self, c: &GemmCounts) -> f64 {
        c.ops() as f64 / self.roofline_secs(c) / 1e9
    }

    /// Is this profile memory-bound on this device?
    pub fn memory_bound(&self, c: &GemmCounts) -> bool {
        let compute = c.ops() as f64 / (self.peak_gops * 1e9 * self.compute_eff);
        self.roofline_secs(c) > compute + f64::EPSILON
    }

    /// Project a host-measured time onto this device: host measurements
    /// capture the *algorithmic* efficiency (fraction of the host roofline
    /// achieved); the projection keeps that fraction and swaps rooflines.
    pub fn project_from_host(&self, c: &GemmCounts, host: &Device, host_secs: f64) -> f64 {
        let host_ideal = host.roofline_secs(c);
        let algo_eff = (host_ideal / host_secs).min(1.0); // ≤ 1: fraction of roofline achieved
        self.roofline_secs(c) / algo_eff.max(1e-3)
    }
}

/// The host this suite actually runs on (calibrated crudely; absolute host
/// numbers are never reported — only device projections and ratios).
pub fn host_device(peak_gops: f64, mem_bw_gbs: f64) -> Device {
    Device {
        name: "host",
        peak_gops,
        mem_bw_gbs,
        compute_eff: 1.0,
        bw_eff: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{farm_counts, lowp_counts};

    #[test]
    fn paper_gemm_is_memory_bound_at_batch_1() {
        // Figure 6 benchmark shape: A 6144x320, batch 1
        let c = farm_counts(1, 6144, 320);
        for d in ALL_EMBEDDED {
            assert!(d.memory_bound(&c), "{} should be bw-bound", d.name);
        }
    }

    #[test]
    fn roofline_monotone_in_batch() {
        let mut prev = 0.0;
        for b in [1usize, 2, 4, 8, 16] {
            let t = IPHONE7.roofline_secs(&farm_counts(b, 6144, 320));
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn farm_beats_lowp_on_roofline_at_small_batch() {
        for b in [1usize, 2, 4] {
            let tf = RPI3.roofline_secs(&farm_counts(b, 6144, 320));
            let tl = RPI3.roofline_secs(&lowp_counts(b, 6144, 320));
            assert!(tl / tf > 1.5, "batch {b}: ratio {}", tl / tf);
        }
    }

    #[test]
    fn achieved_gops_below_peak() {
        let c = farm_counts(4, 6144, 320);
        for d in ALL_EMBEDDED {
            let g = d.achieved_gops(&c);
            assert!(g > 0.0 && g <= d.peak_gops);
        }
    }

    #[test]
    fn projection_preserves_algorithmic_efficiency() {
        let c = farm_counts(1, 6144, 320);
        let host = host_device(100.0, 20.0);
        let ideal = host.roofline_secs(&c);
        // a kernel at 50% of host roofline lands at 50% of device roofline
        let dev_t = IPHONE7.project_from_host(&c, &host, ideal * 2.0);
        let dev_ideal = IPHONE7.roofline_secs(&c);
        assert!((dev_t / dev_ideal - 2.0).abs() < 1e-9);
    }
}

//! Minimal JSON parser + writer (no serde in the offline environment).
//!
//! Parses the `artifacts/manifest.json` contract emitted by
//! `python/compile/aot.py` and serializes experiment results under
//! `results/`. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not produced by either side).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Default for Json {
    fn default() -> Self {
        Json::Null
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key '{key}'")))
    }

    /// Typed `req` conveniences: fetch a key and coerce, with the key
    /// name in the error (the obs-report replay parses untrusted JSONL,
    /// so "which key was wrong" matters).
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Manifest(format!("key '{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Manifest(format!("key '{key}' is not a number")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("key '{key}' is not a string")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Manifest(format!("key '{key}' is not an array")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Serialize (pretty with 1-space indent, stable key order).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Serialize on a single line (no whitespace, stable key order) — the
    /// JSONL form used by the `--metrics-out` exporter, where one document
    /// per line is the contract.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write_compact(&mut s);
        s
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(
                                char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert!(arr[2].get("b").unwrap().is_null());
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr": [1, 2.5, "s"], "b": true, "n": null, "o": {"k": -3}}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string_pretty();
        let v2 = Json::parse(&text).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn compact_is_one_line_and_roundtrips() {
        let src = r#"{"arr": [1, 2.5, "s"], "b": true, "n": null, "o": {"k": -3}, "e": {}, "ea": []}"#;
        let v = Json::parse(src).unwrap();
        let line = v.to_string_compact();
        assert!(!line.contains('\n'), "compact form must stay on one line: {line}");
        assert!(!line.contains(": "), "compact form carries no separator spaces");
        assert_eq!(Json::parse(&line).unwrap(), v);
        assert_eq!(Json::obj(vec![]).to_string_compact(), "{}");
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let round = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}

//! Config system: a TOML-subset parser + typed access.
//!
//! Supports the subset the launcher needs: `[section]` headers, `key =
//! value` with string/number/bool/array values, `#` comments.  CLI
//! `--key value` flags overlay file values, so every experiment knob is
//! settable from either place (see `repro --help`).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Flat "section.key" -> raw value string map with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: bad section", lineno + 1)))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            cfg.values.insert(full_key, unquote(value.trim()));
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Overlay (e.g. CLI flags over file): other wins.
    pub fn overlay(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    pub fn raw(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.raw(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.raw(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.raw(key)
            .and_then(|s| match s {
                "true" | "1" | "yes" => Some(true),
                "false" | "0" | "no" => Some(false),
                _ => None,
            })
            .unwrap_or(default)
    }

    /// Comma- or TOML-array-valued key as f64 list.
    pub fn f64_list(&self, key: &str) -> Option<Vec<f64>> {
        let raw = self.raw(key)?;
        let inner = raw.trim().trim_start_matches('[').trim_end_matches(']');
        let vals: Option<Vec<f64>> = inner
            .split(',')
            .map(|p| p.trim())
            .filter(|p| !p.is_empty())
            .map(|p| p.parse().ok())
            .collect();
        vals
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // honor '#' outside quotes
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    let v = v.trim();
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# launcher config
seed = 7

[train]
lr = 0.002            # base LR
epochs = 40
scheme = "partial"
lambdas = [0.0001, 0.0003, 0.001]
verbose = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("seed", 0), 7);
        assert_eq!(c.f64_or("train.lr", 0.0), 0.002);
        assert_eq!(c.usize_or("train.epochs", 0), 40);
        assert_eq!(c.str_or("train.scheme", ""), "partial");
        assert!(c.bool_or("train.verbose", false));
        assert_eq!(c.f64_list("train.lambdas").unwrap(), vec![1e-4, 3e-4, 1e-3]);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.f64_or("nope", 1.5), 1.5);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn overlay_wins() {
        let mut base = Config::parse("a = 1\nb = 2").unwrap();
        let over = Config::parse("b = 3").unwrap();
        base.overlay(&over);
        assert_eq!(base.usize_or("a", 0), 1);
        assert_eq!(base.usize_or("b", 0), 3);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("novalue").is_err());
    }

    #[test]
    fn comments_inside_strings_survive() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a#b");
    }
}

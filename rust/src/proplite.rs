//! Property-based testing helper (proptest is unavailable offline).
//!
//! A deliberately small harness: generate `n` random cases from a seeded
//! [`Pcg64`], run the property, and on failure re-run a crude shrinking
//! pass (halving sizes) to report a smaller counterexample.  Used by the
//! invariant tests across the coordinator, decoder and linalg modules.

use crate::prng::Pcg64;

/// Run `prop` over `n` random cases drawn by `gen`.
///
/// `gen` receives a seeded RNG and a "size" hint that grows with the case
/// index, so early cases are small. On failure, retries with progressively
/// smaller size hints to find a smaller witness, then panics with both.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, n: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Pcg64, usize) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Pcg64::seeded(fnv1a(name));
    for case in 0..n {
        let size = 1 + case * 4 / n.max(1) * 8 + case % 8; // ragged growth
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // shrink: try smaller sizes with fresh draws
            let mut witness = format!("{input:?}");
            for s in (0..size).rev() {
                for _ in 0..20 {
                    let cand = gen(&mut rng, s);
                    if !prop(&cand) {
                        witness = format!("{cand:?}");
                        break;
                    }
                }
            }
            panic!("property '{name}' failed (case {case}, size {size}).\nwitness: {witness}");
        }
    }
}

/// Stable 64-bit hash of the property name for seeding (FNV-1a).
fn fnv1a(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("commutative-add", 200, |rng, _| (rng.below(1000) as i64, rng.below(1000) as i64), |(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_witness() {
        check("always-false", 10, |rng, s| rng.below(s + 1), |_| false);
    }
}

//! Declarative SLOs and multi-window burn-rate alerts.
//!
//! An [`SloConfig`] states the objective — a p99 latency target and an
//! availability error budget (a session is *good* iff its arrival-to-
//! transcript latency is within `deadline`; at most `budget` of sessions
//! may miss).  The [`SloEngine`] evaluates the objective over the same
//! per-session latency stream the `metricsx` histograms record, on the
//! router thread, using the SRE multi-window burn-rate rule:
//!
//! * **burn rate** = (bad fraction in a window) / budget — 1.0 means the
//!   budget is being spent exactly at the sustainable rate;
//! * alert when the **fast** window (last `fast_window` sessions) burns
//!   at ≥ `fast_burn` *and* the **slow** window (last `slow_window`)
//!   burns at ≥ `slow_burn`.  The fast window makes the alert prompt,
//!   the slow window keeps one bad session from paging.
//!
//! Rising edges emit a journal [`SloAlert`](super::EventKind::SloAlert)
//! event.  With `--slo-actions on`, a breach also becomes a control
//! input: the fidelity controllers see it as extra downshift pressure
//! (`FidelityController::observe_with_pressure`) and the plain router
//! sheds admissions while it lasts.  The default is `--slo-actions off`:
//! the engine observes and journals but steers nothing, so every
//! existing bit-identity and determinism test carries over unchanged.

use crate::error::{Error, Result};
use crate::jsonx::Json;

/// A declarative serving SLO.  Construct via [`SloConfig::for_target`]
/// and override fields as needed.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// p99 latency objective in seconds (reported against the windowed
    /// p99; also the default `deadline`).
    pub target_p99: f64,
    /// Deadline for the availability objective: a session is good iff
    /// `latency <= deadline`.
    pub deadline: f64,
    /// Error budget: allowed fraction of sessions missing the deadline.
    pub budget: f64,
    /// Fast window length in sessions (the 1-window of the alert rule).
    pub fast_window: usize,
    /// Slow window length in sessions (the N-window; must be >= fast).
    pub slow_window: usize,
    /// Burn-rate threshold for the fast window.
    pub fast_burn: f64,
    /// Burn-rate threshold for the slow window.
    pub slow_burn: f64,
}

impl SloConfig {
    /// The default objective shape for a target: deadline = target, 1%
    /// error budget unless overridden, 8/32-session windows, alert at
    /// 2x/1x burn.
    pub fn for_target(target_p99: f64, budget: f64) -> SloConfig {
        SloConfig {
            target_p99,
            deadline: target_p99,
            budget,
            fast_window: 8,
            slow_window: 32,
            fast_burn: 2.0,
            slow_burn: 1.0,
        }
    }

    fn validate(&self) -> Result<()> {
        if !(self.target_p99 > 0.0) || !(self.deadline > 0.0) {
            return Err(Error::Config("slo: target/deadline must be > 0".into()));
        }
        if !(self.budget > 0.0 && self.budget <= 1.0) {
            return Err(Error::Config("slo: budget must be in (0, 1]".into()));
        }
        if self.fast_window == 0 || self.slow_window < self.fast_window {
            return Err(Error::Config(
                "slo: need fast_window >= 1 and slow_window >= fast_window".into(),
            ));
        }
        if !(self.fast_burn > 0.0) || !(self.slow_burn > 0.0) {
            return Err(Error::Config("slo: burn thresholds must be > 0".into()));
        }
        Ok(())
    }
}

/// Burn-rate evaluator over the per-session latency stream.  One ring of
/// the last `slow_window` latencies, sized at construction — recording a
/// sample never allocates.
pub struct SloEngine {
    cfg: SloConfig,
    ring: Vec<f64>,
    next: usize,
    filled: usize,
    /// Sessions observed / deadline misses, cumulative.
    pub total: u64,
    pub misses: u64,
    alerting: bool,
    /// Rising-edge alerts fired.
    pub alerts: u64,
}

impl SloEngine {
    pub fn new(cfg: SloConfig) -> Result<SloEngine> {
        cfg.validate()?;
        let ring = vec![0.0; cfg.slow_window];
        Ok(SloEngine { cfg, ring, next: 0, filled: 0, total: 0, misses: 0, alerting: false, alerts: 0 })
    }

    pub fn cfg(&self) -> &SloConfig {
        &self.cfg
    }

    /// Record one completed session.  Returns `Some(misses_so_far)` on
    /// the rising edge of a breach — the caller journals it as an
    /// [`SloAlert`](super::EventKind::SloAlert) event.
    pub fn record(&mut self, latency: f64) -> Option<u64> {
        self.ring[self.next] = latency;
        self.next = (self.next + 1) % self.ring.len();
        self.filled = (self.filled + 1).min(self.ring.len());
        self.total += 1;
        if latency > self.cfg.deadline {
            self.misses += 1;
        }
        let breaching = self.breaching();
        let rising = breaching && !self.alerting;
        self.alerting = breaching;
        if rising {
            self.alerts += 1;
            Some(self.misses)
        } else {
            None
        }
    }

    /// Bad fraction over the last `window` samples (fewer if the stream
    /// is shorter), divided by the budget: the burn rate.
    pub fn burn(&self, window: usize) -> f64 {
        let n = window.min(self.filled);
        if n == 0 {
            return 0.0;
        }
        let len = self.ring.len();
        let mut bad = 0usize;
        for k in 1..=n {
            // walk backwards from the most recent sample
            let i = (self.next + len - k) % len;
            if self.ring[i] > self.cfg.deadline {
                bad += 1;
            }
        }
        (bad as f64 / n as f64) / self.cfg.budget
    }

    pub fn fast_burn(&self) -> f64 {
        self.burn(self.cfg.fast_window)
    }

    pub fn slow_burn(&self) -> f64 {
        self.burn(self.cfg.slow_window)
    }

    /// The multi-window alert condition.  Requires at least a full fast
    /// window of evidence so a first bad session cannot page on its own.
    pub fn breaching(&self) -> bool {
        self.filled >= self.cfg.fast_window
            && self.fast_burn() >= self.cfg.fast_burn
            && self.slow_burn() >= self.cfg.slow_burn
    }

    /// Fraction of sessions that met the deadline, cumulative.
    pub fn attainment(&self) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        1.0 - self.misses as f64 / self.total as f64
    }

    /// p99 over the slow window (nearest-rank, same discipline as the
    /// fidelity controller's windowed p99).
    pub fn windowed_p99(&self) -> f64 {
        if self.filled == 0 {
            return 0.0;
        }
        let mut w: Vec<f64> = self.ring[..self.filled].to_vec();
        w.sort_by(f64::total_cmp);
        let rank = ((0.99 * w.len() as f64).ceil() as usize).clamp(1, w.len());
        w[rank - 1]
    }

    pub fn summary(&self) -> SloSummary {
        SloSummary {
            target_p99: self.cfg.target_p99,
            deadline: self.cfg.deadline,
            budget: self.cfg.budget,
            total: self.total,
            misses: self.misses,
            attainment: self.attainment(),
            windowed_p99: self.windowed_p99(),
            fast_burn: self.fast_burn(),
            slow_burn: self.slow_burn(),
            alerts: self.alerts,
            breaching: self.alerting,
        }
    }
}

/// Snapshot of the engine for the serve report (`--json` and text).
#[derive(Clone, Debug)]
pub struct SloSummary {
    pub target_p99: f64,
    pub deadline: f64,
    pub budget: f64,
    pub total: u64,
    pub misses: u64,
    pub attainment: f64,
    pub windowed_p99: f64,
    pub fast_burn: f64,
    pub slow_burn: f64,
    pub alerts: u64,
    pub breaching: bool,
}

impl SloSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("target_p99", Json::num(self.target_p99)),
            ("deadline", Json::num(self.deadline)),
            ("budget", Json::num(self.budget)),
            ("total", Json::num(self.total as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("attainment", Json::num(self.attainment)),
            ("windowed_p99", Json::num(self.windowed_p99)),
            ("fast_burn", Json::num(self.fast_burn)),
            ("slow_burn", Json::num(self.slow_burn)),
            ("alerts", Json::num(self.alerts as f64)),
            ("breaching", Json::Bool(self.breaching)),
        ])
    }

    /// One-line rendering for the plain-text serve report.
    pub fn line(&self) -> String {
        format!(
            "SLO: p99 target {:.0} ms, deadline {:.0} ms, budget {:.2}% | attainment {:.1}% ({} of {} missed) | burn fast {:.2} slow {:.2} | alerts {}\n",
            self.target_p99 * 1e3,
            self.deadline * 1e3,
            self.budget * 100.0,
            self.attainment * 100.0,
            self.misses,
            self.total,
            self.fast_burn,
            self.slow_burn,
            self.alerts,
        )
    }
}

const _: () = crate::assert_send_sync::<SloEngine>();

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SloConfig {
        SloConfig { fast_window: 4, slow_window: 8, ..SloConfig::for_target(0.1, 0.25) }
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(SloConfig { target_p99: 0.0, ..cfg() }.validate().is_err());
        assert!(SloConfig { budget: 0.0, ..cfg() }.validate().is_err());
        assert!(SloConfig { budget: 1.5, ..cfg() }.validate().is_err());
        assert!(SloConfig { slow_window: 2, ..cfg() }.validate().is_err());
        assert!(SloConfig { fast_burn: 0.0, ..cfg() }.validate().is_err());
        assert!(cfg().validate().is_ok());
    }

    #[test]
    fn good_sessions_never_burn_or_alert() {
        let mut e = SloEngine::new(cfg()).unwrap();
        for _ in 0..32 {
            assert_eq!(e.record(0.05), None);
        }
        assert_eq!(e.fast_burn(), 0.0);
        assert_eq!(e.slow_burn(), 0.0);
        assert_eq!(e.attainment(), 1.0);
        assert_eq!(e.alerts, 0);
        assert!(!e.breaching());
    }

    #[test]
    fn sustained_misses_alert_once_on_the_rising_edge() {
        let mut e = SloEngine::new(cfg()).unwrap();
        let mut fired = Vec::new();
        for i in 0..8 {
            if let Some(m) = e.record(0.5) {
                fired.push((i, m));
            }
        }
        // 100% bad / 25% budget = burn 4.0 in both windows; the alert
        // needs a full fast window (4 samples), then fires exactly once.
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].0, 3);
        assert_eq!(e.alerts, 1);
        assert!(e.breaching());
        assert!((e.fast_burn() - 4.0).abs() < 1e-12);
        assert!((e.slow_burn() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn recovery_clears_the_alert_and_rearms_it() {
        let mut e = SloEngine::new(cfg()).unwrap();
        for _ in 0..4 {
            e.record(0.5);
        }
        assert!(e.breaching());
        // a clean fast window clears the fast burn and with it the alert
        for _ in 0..4 {
            e.record(0.05);
        }
        assert!(!e.breaching());
        assert!(e.fast_burn() < cfg().fast_burn);
        // a second sustained breach fires a second alert
        let mut again = 0;
        for _ in 0..8 {
            if e.record(0.5).is_some() {
                again += 1;
            }
        }
        assert_eq!(again, 1);
        assert_eq!(e.alerts, 2);
    }

    #[test]
    fn fast_window_spikes_need_the_slow_window_to_confirm() {
        // budget 0.5, slow window 8: one bad sample in 8 = slow burn
        // 0.25 < 1.0, so a short spike does not page even though the
        // fast window briefly burns hot.
        let mut e = SloEngine::new(SloConfig {
            fast_window: 2,
            slow_window: 8,
            fast_burn: 1.0,
            ..SloConfig::for_target(0.1, 0.5)
        })
        .unwrap();
        for _ in 0..7 {
            assert_eq!(e.record(0.05), None);
        }
        assert_eq!(e.record(0.5), None, "fast burn hits 1.0 but slow burn 0.25 < 1.0");
        assert!(e.fast_burn() >= 1.0);
        assert!(e.slow_burn() < 1.0);
        assert!(!e.breaching());
    }

    #[test]
    fn summary_carries_the_burn_state_and_serializes() {
        let mut e = SloEngine::new(cfg()).unwrap();
        e.record(0.05);
        e.record(0.5);
        let s = e.summary();
        assert_eq!(s.total, 2);
        assert_eq!(s.misses, 1);
        assert!((s.attainment - 0.5).abs() < 1e-12);
        assert!(s.windowed_p99 >= 0.5);
        let j = s.to_json();
        assert_eq!(j.get("misses").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("breaching").unwrap().as_bool(), Some(false));
        assert!(s.line().contains("attainment 50.0%"));
    }
}

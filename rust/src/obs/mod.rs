//! Flight-recorder observability (DESIGN.md §10).
//!
//! A dependency-free instrumentation layer threaded through every tier of
//! the runtime:
//!
//! * [`spans`] — a fixed [`Stage`] taxonomy with zero-alloc per-stream
//!   accumulators ([`SpanSet`], embedded in `infer::Breakdown`), so a
//!   decode produces an exact self-time breakdown that sums to wall time,
//!   plus process-global atomic spans for plan-time work (pack, autotune
//!   probes, build-time quantization).
//! * [`counters`] — per-(backend, op-kind, m-bucket) atomic kernel
//!   counters (calls, MACs, bytes, nanos) recorded at the `GemmBackend`
//!   dispatch sites, giving live GOP/s per backend and shape class.
//! * [`journal`] — pre-sized per-shard ring buffers of typed router
//!   events (admission, placement, tier spill, shift, backpressure,
//!   drain), merged clock-ordered on the router thread.
//! * [`export`] — the `--metrics-out FILE` JSONL exporter: periodic
//!   versioned snapshots (spans, counters, journal deltas, block-trace
//!   deltas) during `stream-serve` / `ladder-serve` / `train --native`,
//!   plus explicit `journal-gap` rows when a ring lapped a cursor.
//! * [`trace`] — per-session causal traces: per-`pump_block` records
//!   stamped onto the simulated clock by the router, a Chrome-trace /
//!   Perfetto exporter (`--trace-out`), and the offline `obs-report`
//!   replay over a `--metrics-out` JSONL.
//! * [`slo`] — declarative latency/availability objectives with
//!   multi-window burn-rate alerts (`--slo-target`), journaled as
//!   [`EventKind::SloAlert`] events and optionally wired into the
//!   fidelity controller and admission shedding (`--slo-actions on`).
//!
//! The whole layer is **off by default** behind one process-global
//! relaxed atomic ([`enabled`], `--obs on|off`): with obs off, every hot
//! path pays exactly one `Ordering::Relaxed` load and records nothing, so
//! transcripts and timing are bit-identical either way.  With obs on the
//! steady-state zero-allocation invariant still holds — span sets are
//! fixed arrays inside existing per-stream state, counters are static
//! atomics, and journal rings are sized at serve construction
//! (`rust/tests/alloc_free.rs` pins both switch positions).

pub mod counters;
pub mod export;
pub mod journal;
pub mod slo;
pub mod spans;
pub mod trace;

pub use counters::OpKind;
pub use export::MetricsExporter;
pub use journal::{Event, EventKind, Journal, NO_SHARD};
pub use slo::{SloConfig, SloEngine, SloSummary};
pub use spans::{SpanSet, Stage};
pub use trace::{BlockSpan, TraceBuilder};

use std::sync::atomic::{AtomicBool, Ordering};

use crate::jsonx::Json;

/// Version stamp carried by every `--json` serve report and every
/// `--metrics-out` JSONL snapshot (DESIGN.md §10).  Bump it whenever a
/// field is renamed, removed, or changes meaning — additive fields keep
/// the version.
pub const SCHEMA_VERSION: u64 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the observability layer on or off process-wide (`--obs on|off`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether instrumentation is live.  This single relaxed load is the
/// entire hot-path cost of the layer when off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clear every process-global accumulator (plan-time spans and kernel
/// counters).  The serve loops deliberately do *not* call this — engine
/// construction (packing, autotune) happens before a serve starts, and
/// resetting there would erase those plan-time spans; a CLI invocation is
/// a fresh process anyway.  Tests call it for isolation (the suite runs
/// with `RUST_TEST_THREADS=1`, so reset/read races are not a concern).
pub fn reset_process_metrics() {
    spans::reset_global();
    counters::reset();
}

/// Everything the obs layer contributes to a serve report: the decode
/// self-time breakdown, plan-time spans, kernel counters, and the merged
/// shard event journal.
#[derive(Clone, Debug, Default)]
pub struct ObsReport {
    /// Decode-path self-time spans aggregated across shards
    /// (`Breakdown::spans` merged at the sample level).
    pub spans: SpanSet,
    /// Plan-time spans (pack, autotune, build-time quantize) — global
    /// snapshot, disjoint from the decode spans by construction.
    pub plan_spans: SpanSet,
    /// Kernel-counter snapshot (see [`counters::snapshot`]).
    pub counters: Json,
    /// Clock-ordered merge of every shard's event journal.
    pub journal: Vec<Event>,
    /// Ring-buffer overwrites across all shards (0 unless a serve
    /// outlives its journal capacity).
    pub journal_dropped: u64,
}

impl ObsReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("spans", self.spans.to_json()),
            ("plan_spans", self.plan_spans.to_json()),
            ("counters", self.counters.clone()),
            ("journal", journal::events_to_json(&self.journal)),
            ("journal_dropped", Json::num(self.journal_dropped as f64)),
        ])
    }

    /// The flamegraph-style self-time table printed by the non-`--json`
    /// serve reports: stages sorted by self time, with share-of-total
    /// bars, decode spans first and plan-time spans below.
    pub fn self_time_table(&self) -> String {
        let mut out = String::new();
        out.push_str("self-time breakdown (obs):\n");
        out.push_str(&spans::table(&self.spans, "decode"));
        if self.plan_spans.total_secs() > 0.0 {
            out.push_str(&spans::table(&self.plan_spans, "plan"));
        }
        out
    }
}

const _: () = crate::assert_send_sync::<ObsReport>();

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn switch_toggles_and_restores() {
        let was = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(was);
    }

    #[test]
    fn empty_report_serializes() {
        let r = ObsReport { counters: Json::Arr(vec![]), ..ObsReport::default() };
        let j = r.to_json();
        assert!(j.get("spans").is_some());
        assert!(j.get("journal").unwrap().as_arr().unwrap().is_empty());
        assert!(r.self_time_table().contains("self-time breakdown"));
    }
}

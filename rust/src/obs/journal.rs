//! Shard event journal: pre-sized ring buffers of typed router events.
//!
//! Every serve-level decision the admission router makes — a session
//! arriving, being placed on a shard/tier, spilling down the ladder,
//! a controller shift, backpressure, a session draining — is recorded as
//! a fixed-size [`Event`] in a per-shard [`Journal`] ring.  All events
//! are produced **on the router thread** (the control plane is
//! single-threaded by design, DESIGN.md §9), so with a fixed seed the
//! journal is fully deterministic: same config, same event sequence,
//! at any shard count the same multiset of per-session lifecycle events.
//!
//! Rings are sized once at serve construction and overwrite their oldest
//! entry when full (tracking the drop count), preserving the no-steady-
//! state-allocation rule.  The merged, clock-ordered view the report and
//! the JSONL exporter use subsumes the ad-hoc
//! `controller::merge_shift_logs` path: shift events appear in the
//! journal with the same clocks, shard-tagged, interleaved with the
//! admission/placement/drain record around them.

use crate::jsonx::Json;

/// Shard tag for events that belong to the router itself rather than a
/// worker shard (arrival-queue admissions, backpressure).  Serialized as
/// `-1`.
pub const NO_SHARD: usize = usize::MAX;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A session's arrival time passed: it entered the router's
    /// admission queue.  `shard` is [`NO_SHARD`]; `tier` is 0.
    Admission,
    /// The session was placed onto `shard`/`tier`.
    Placement,
    /// The placement landed below the tier the controller wanted
    /// (within-shard downward spill); `tier` is the tier actually used.
    TierSpill,
    /// A fidelity controller shifted down to `tier`.
    DownShift,
    /// A fidelity controller shifted up to `tier`.
    UpShift,
    /// No shard had a free slot this round; `session` carries the queue
    /// depth left waiting.  `shard` is [`NO_SHARD`].
    Backpressure,
    /// The session finished and its pool slot drained.
    Drain,
    /// The SLO burn-rate engine crossed its fast+slow thresholds (rising
    /// edge only).  `shard` is [`NO_SHARD`] (the engine runs on the
    /// router over the merged latency stream); `session` carries the
    /// total deadline misses observed so far.
    SloAlert,
    /// A cascade block breached the confidence threshold and re-ran on
    /// the high rung.  `tier` is the tier the session decodes on (the
    /// low rung of the pair); journaled by the router from worker tick
    /// reports so the control plane stays single-threaded.
    CascadeEscalate,
}

impl EventKind {
    pub const fn name(self) -> &'static str {
        match self {
            EventKind::Admission => "admission",
            EventKind::Placement => "placement",
            EventKind::TierSpill => "tier_spill",
            EventKind::DownShift => "downshift",
            EventKind::UpShift => "upshift",
            EventKind::Backpressure => "backpressure",
            EventKind::Drain => "drain",
            EventKind::SloAlert => "slo_alert",
            EventKind::CascadeEscalate => "cascade_escalate",
        }
    }

    /// Inverse of [`EventKind::name`], for the `obs-report` JSONL replay.
    pub fn parse(name: &str) -> Option<EventKind> {
        Some(match name {
            "admission" => EventKind::Admission,
            "placement" => EventKind::Placement,
            "tier_spill" => EventKind::TierSpill,
            "downshift" => EventKind::DownShift,
            "upshift" => EventKind::UpShift,
            "backpressure" => EventKind::Backpressure,
            "drain" => EventKind::Drain,
            "slo_alert" => EventKind::SloAlert,
            "cascade_escalate" => EventKind::CascadeEscalate,
            _ => return None,
        })
    }
}

/// One journal entry.  Fixed-size and `Copy` so ring writes are a store,
/// not an allocation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Simulated clock (seconds) when the router made the decision.
    pub clock: f64,
    /// Worker shard the event concerns, or [`NO_SHARD`] for router-level
    /// events.
    pub shard: usize,
    /// Session (utterance) id, or the kind-specific payload documented
    /// on [`EventKind`].
    pub session: usize,
    /// Ladder tier (always 0 for the single-tier `stream-serve` path).
    pub tier: usize,
    pub kind: EventKind,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let shard = if self.shard == NO_SHARD { -1.0 } else { self.shard as f64 };
        Json::obj(vec![
            ("clock", Json::num(self.clock)),
            ("shard", Json::num(shard)),
            ("session", Json::num(self.session as f64)),
            ("tier", Json::num(self.tier as f64)),
            ("kind", Json::str(self.kind.name())),
        ])
    }
}

impl Event {
    /// Inverse of [`Event::to_json`], for the `obs-report` JSONL replay.
    /// `shard: -1` maps back to [`NO_SHARD`].
    pub fn from_json(j: &Json) -> crate::error::Result<Event> {
        let bad = |what: &str| crate::error::Error::Config(format!("journal event: bad {what}"));
        let shard_raw = j.get("shard").and_then(Json::as_f64).ok_or_else(|| bad("shard"))?;
        let shard = if shard_raw < 0.0 { NO_SHARD } else { shard_raw as usize };
        let kind_name = j.get("kind").and_then(Json::as_str).ok_or_else(|| bad("kind"))?;
        let kind = EventKind::parse(kind_name).ok_or_else(|| bad("kind"))?;
        Ok(Event {
            clock: j.get("clock").and_then(Json::as_f64).ok_or_else(|| bad("clock"))?,
            shard,
            session: j.get("session").and_then(Json::as_usize).ok_or_else(|| bad("session"))?,
            tier: j.get("tier").and_then(Json::as_usize).ok_or_else(|| bad("tier"))?,
            kind,
        })
    }
}

pub fn events_to_json(events: &[Event]) -> Json {
    Json::Arr(events.iter().map(Event::to_json).collect())
}

/// A pre-sized overwrite-oldest ring of [`Event`]s with a monotone
/// sequence counter, so the exporter can ship deltas
/// ([`Journal::events_since`]) without re-sending history.
#[derive(Clone, Debug)]
pub struct Journal {
    buf: Vec<Event>,
    cap: usize,
    /// Total events ever pushed; the oldest retained event has sequence
    /// number `total - len`.
    total: u64,
}

impl Journal {
    /// Ring sized once, up front.  `cap` is clamped to at least 1.
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        Journal { buf: Vec::with_capacity(cap), cap, total: 0 }
    }

    /// Append an event, overwriting the oldest once the ring is full.
    /// Never allocates after construction.
    pub fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[(self.total as usize) % self.cap] = ev;
        }
        self.total += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever pushed (retained + overwritten).
    pub fn total_pushed(&self) -> u64 {
        self.total
    }

    /// Events overwritten by ring wraparound.
    pub fn dropped(&self) -> u64 {
        self.total - self.buf.len() as u64
    }

    /// Retained events in push order (oldest first).
    pub fn iter(&self) -> impl Iterator<Item = &Event> {
        let split = if self.buf.len() < self.cap {
            0
        } else {
            (self.total as usize) % self.cap
        };
        self.buf[split..].iter().chain(self.buf[..split].iter())
    }

    /// Events with sequence number >= `since`, plus how many in that
    /// range were already overwritten — the exporter's delta view.
    pub fn events_since(&self, since: u64) -> (Vec<Event>, u64) {
        let oldest = self.total - self.buf.len() as u64;
        let missed = oldest.saturating_sub(since);
        let skip = since.saturating_sub(oldest) as usize;
        (self.iter().skip(skip).copied().collect(), missed)
    }
}

/// Canonical total order over events: clock, then every remaining field.
/// Ordering by *content* rather than by arrival makes the merged journal
/// a pure function of the event multiset — the offline `obs-report`
/// replay reassembles the same multiset from snapshot deltas (a
/// different partition of the same events) and must sort to the same
/// sequence, byte for byte, even when a fixed tick puts many events on
/// identical clocks.
pub fn canonical_cmp(a: &Event, b: &Event) -> std::cmp::Ordering {
    a.clock
        .total_cmp(&b.clock)
        .then(a.shard.cmp(&b.shard))
        .then(a.session.cmp(&b.session))
        .then((a.kind as u8).cmp(&(b.kind as u8)))
        .then(a.tier.cmp(&b.tier))
}

/// Merge per-shard journals into one clock-ordered event list, in the
/// [`canonical_cmp`] order — the same discipline as
/// `controller::merge_shift_logs`, generalized to the full event
/// vocabulary and made partition-independent for the offline replay.
pub fn merge(journals: &[Journal]) -> Vec<Event> {
    let mut all: Vec<Event> = journals.iter().flat_map(|j| j.iter().copied()).collect();
    all.sort_by(canonical_cmp);
    all
}

/// Total overwrites across a set of journals.
pub fn total_dropped(journals: &[Journal]) -> u64 {
    journals.iter().map(|j| j.dropped()).sum()
}

const _: () = crate::assert_send_sync::<Event>();
const _: () = crate::assert_send_sync::<Journal>();

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(clock: f64, session: usize) -> Event {
        Event { clock, shard: 0, session, tier: 0, kind: EventKind::Placement }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut j = Journal::with_capacity(3);
        for i in 0..5 {
            j.push(ev(i as f64, i));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.total_pushed(), 5);
        assert_eq!(j.dropped(), 2);
        let kept: Vec<usize> = j.iter().map(|e| e.session).collect();
        assert_eq!(kept, vec![2, 3, 4], "oldest entries were overwritten in order");
    }

    #[test]
    fn push_never_allocates_after_construction() {
        let mut j = Journal::with_capacity(4);
        let cap_before = j.buf.capacity();
        for i in 0..64 {
            j.push(ev(i as f64, i));
        }
        assert_eq!(j.buf.capacity(), cap_before, "ring must not grow past construction");
    }

    #[test]
    fn events_since_yields_deltas_and_missed_counts() {
        let mut j = Journal::with_capacity(3);
        for i in 0..3 {
            j.push(ev(i as f64, i));
        }
        let (d, missed) = j.events_since(1);
        assert_eq!(missed, 0);
        assert_eq!(d.iter().map(|e| e.session).collect::<Vec<_>>(), vec![1, 2]);
        // wrap: seqs 0..=4, ring keeps 2..=4
        j.push(ev(3.0, 3));
        j.push(ev(4.0, 4));
        let (d, missed) = j.events_since(1);
        assert_eq!(missed, 1, "seq 1 was overwritten");
        assert_eq!(d.iter().map(|e| e.session).collect::<Vec<_>>(), vec![2, 3, 4]);
        let (d, missed) = j.events_since(5);
        assert!(d.is_empty());
        assert_eq!(missed, 0);
    }

    #[test]
    fn merge_orders_by_clock_stably() {
        let mut a = Journal::with_capacity(8);
        let mut b = Journal::with_capacity(8);
        a.push(Event { clock: 1.0, shard: 0, session: 0, tier: 0, kind: EventKind::Admission });
        a.push(Event { clock: 3.0, shard: 0, session: 0, tier: 0, kind: EventKind::Drain });
        b.push(Event { clock: 1.0, shard: 1, session: 1, tier: 0, kind: EventKind::Admission });
        b.push(Event { clock: 2.0, shard: 1, session: 1, tier: 1, kind: EventKind::TierSpill });
        let m = merge(&[a, b]);
        assert_eq!(m.len(), 4);
        assert!(m.windows(2).all(|w| w[0].clock <= w[1].clock));
        // stable: journal order preserved at the tied clock
        assert_eq!(m[0].shard, 0);
        assert_eq!(m[1].shard, 1);
    }

    #[test]
    fn event_json_round_trips_including_no_shard_and_slo_alert() {
        let cases = [
            Event { clock: 0.25, shard: 2, session: 9, tier: 1, kind: EventKind::TierSpill },
            Event { clock: 1.5, shard: NO_SHARD, session: 3, tier: 0, kind: EventKind::SloAlert },
            Event { clock: 2.0, shard: NO_SHARD, session: 4, tier: 0, kind: EventKind::Backpressure },
        ];
        for e in cases {
            assert_eq!(Event::from_json(&e.to_json()).unwrap(), e);
        }
        assert!(Event::from_json(&Json::obj(vec![("clock", Json::num(0.0))])).is_err());
        for k in [
            EventKind::Admission,
            EventKind::Placement,
            EventKind::TierSpill,
            EventKind::DownShift,
            EventKind::UpShift,
            EventKind::Backpressure,
            EventKind::Drain,
            EventKind::SloAlert,
            EventKind::CascadeEscalate,
        ] {
            assert_eq!(EventKind::parse(k.name()), Some(k), "name/parse must stay inverse");
        }
        assert_eq!(EventKind::parse("bogus"), None);
    }

    #[test]
    fn router_events_serialize_shard_as_minus_one() {
        let e = Event {
            clock: 0.5,
            shard: NO_SHARD,
            session: 7,
            tier: 0,
            kind: EventKind::Backpressure,
        };
        let j = e.to_json();
        assert_eq!(j.get("shard").unwrap().as_f64(), Some(-1.0));
        assert_eq!(j.get("kind").unwrap().as_str(), Some("backpressure"));
    }
}

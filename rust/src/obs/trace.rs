//! Per-session causal traces (`--trace-out FILE`, `obs-report`).
//!
//! The journal (DESIGN.md §10) records *decisions*; this module adds the
//! *work*: one [`BlockSpan`] per `pump_block` call, carrying which
//! sessions advanced, how many output steps each produced, the block's
//! wall time and its [`SpanSet`] delta.  Workers collect the records
//! inside their tick (guarded by the same single `obs::enabled()` load as
//! every other site) and ship them back in the `TickReport`; the **router
//! thread** stamps them onto the simulated clock with [`TraceBuilder`],
//! so trace assembly stays on the single-threaded control plane and the
//! record *content* (sessions, steps, tiers) is deterministic at any
//! `--shards` count.  Wall-measured durations are only deterministic
//! under `--fixed-tick-ms`, where the router replaces them with equal
//! shares of the fixed tick (and drops the measured span deltas), making
//! the exported trace byte-identical run to run.
//!
//! Two consumers:
//!
//! * [`chrome_trace`] — a Chrome-trace-event / Perfetto JSON document:
//!   `pid` = shard (−1 = router), `tid` = session, `ts` = simulated clock
//!   in microseconds.  Journal events become instants on the session's
//!   track; every block becomes one `"X"` (complete) slice per
//!   participating session.
//! * [`Replay`] — the offline `obs-report` analyzer: parses a
//!   `--metrics-out` JSONL, validates the versioned envelope, replays the
//!   journal/block deltas and reconstructs per-session timelines
//!   ([`timelines`]).  Because the trace is a pure function of journal +
//!   block records, [`Replay::chrome_trace`] re-emits the exact trace the
//!   live serve wrote.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::jsonx::Json;

use super::journal::{Event, EventKind, NO_SHARD};
use super::spans::SpanSet;

/// One `pump_block` call, as seen by one shard worker and stamped onto
/// the simulated clock by the router.
#[derive(Clone, Debug, Default)]
pub struct BlockSpan {
    /// Simulated clock (seconds) at which the block starts.  Workers
    /// leave this 0; [`TraceBuilder::stamp_tick`] fills it in.
    pub clock: f64,
    /// Block duration in seconds (wall-measured, or `dt/n` under a fixed
    /// tick).
    pub secs: f64,
    pub shard: usize,
    pub tier: usize,
    /// Sessions (utterance ids) that advanced in this block, slot order.
    pub utts: Vec<usize>,
    /// Output steps each advancing session produced (the engine's time
    /// batch).
    pub steps: usize,
    /// Self-time delta attributed to this block (empty under a fixed
    /// tick).
    pub spans: SpanSet,
}

impl BlockSpan {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("clock", Json::num(self.clock)),
            ("secs", Json::num(self.secs)),
            ("shard", Json::num(self.shard as f64)),
            ("tier", Json::num(self.tier as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("utts", Json::arr_num(&self.utts.iter().map(|&u| u as f64).collect::<Vec<_>>())),
        ];
        if !self.spans.is_empty() {
            pairs.push(("spans", self.spans.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<BlockSpan> {
        let mut utts = Vec::new();
        for u in j.req_arr("utts")? {
            utts.push(
                u.as_usize().ok_or_else(|| Error::Config("block utts: not a number".into()))?,
            );
        }
        let spans = match j.get("spans") {
            Some(s) => SpanSet::from_json(s)?,
            None => SpanSet::default(),
        };
        Ok(BlockSpan {
            clock: j.req_f64("clock")?,
            secs: j.req_f64("secs")?,
            shard: j.req_usize("shard")?,
            tier: j.req_usize("tier")?,
            steps: j.req_usize("steps")?,
            utts,
            spans,
        })
    }
}

/// Router-side accumulator: stamps worker block records onto the
/// simulated clock and keeps a cursor so the JSONL exporter can ship
/// deltas ([`TraceBuilder::delta`]) without re-sending history.
#[derive(Default)]
pub struct TraceBuilder {
    blocks: Vec<BlockSpan>,
    cursor: usize,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// Absorb one shard tick's block records.  Blocks within a tick ran
    /// sequentially, so each starts where the previous ended, offset from
    /// `clock_before` (the simulated clock when the round began).  Under
    /// a fixed tick (`fixed`), measured durations are replaced by equal
    /// shares of `dt` and the span deltas dropped, so the stamped records
    /// — and everything derived from them — are deterministic.
    pub fn stamp_tick(
        &mut self,
        clock_before: f64,
        dt: f64,
        records: &mut Vec<BlockSpan>,
        fixed: bool,
    ) {
        let n = records.len();
        let mut off = 0.0;
        for (k, mut b) in records.drain(..).enumerate() {
            if fixed {
                b.clock = clock_before + dt * k as f64 / n as f64;
                b.secs = dt / n as f64;
                b.spans = SpanSet::default();
            } else {
                b.clock = clock_before + off;
                off += b.secs;
            }
            self.blocks.push(b);
        }
    }

    /// Blocks stamped since the last `delta` call (the exporter's view).
    pub fn delta(&mut self) -> &[BlockSpan] {
        let from = self.cursor;
        self.cursor = self.blocks.len();
        &self.blocks[from..]
    }

    /// Every block stamped so far, in router order.
    pub fn blocks(&self) -> &[BlockSpan] {
        &self.blocks
    }
}

pub fn blocks_to_json(blocks: &[BlockSpan]) -> Json {
    Json::Arr(blocks.iter().map(BlockSpan::to_json).collect())
}

/// Simulated seconds → whole trace microseconds.  Rounding keeps the
/// serialized timestamps integral, which both Perfetto and the byte-
/// identity contract prefer.
fn us(secs: f64) -> Json {
    Json::num((secs * 1e6).round())
}

fn pid_json(shard: usize) -> Json {
    Json::num(if shard == NO_SHARD { -1.0 } else { shard as f64 })
}

/// Assemble a Chrome-trace-event JSON document from a clock-ordered
/// journal plus stamped block records.  Pure function of its inputs —
/// the live `--trace-out` path and the offline `obs-report` re-emission
/// call this with the same data and get the same bytes.
pub fn chrome_trace(journal: &[Event], blocks: &[BlockSpan]) -> Json {
    // Process metadata first: one named row per shard seen, router = -1.
    let mut pids: Vec<i64> = Vec::new();
    let mut see = |shard: usize| {
        let pid = if shard == NO_SHARD { -1 } else { shard as i64 };
        if !pids.contains(&pid) {
            pids.push(pid);
        }
    };
    journal.iter().for_each(|e| see(e.shard));
    blocks.iter().for_each(|b| see(b.shard));
    pids.sort_unstable();
    let mut events: Vec<Json> = pids
        .iter()
        .map(|&pid| {
            let name =
                if pid < 0 { "router".to_string() } else { format!("shard {pid}") };
            Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ])
        })
        .collect();

    // Journal instants and block slices, merged by timestamp (stable, so
    // ties keep journal-before-block, router order).
    let mut rows: Vec<(f64, Json)> = Vec::with_capacity(journal.len() + blocks.len());
    for e in journal {
        rows.push((
            e.clock,
            Json::obj(vec![
                ("name", Json::str(e.kind.name())),
                ("ph", Json::str("i")),
                ("s", Json::str("t")),
                ("ts", us(e.clock)),
                ("pid", pid_json(e.shard)),
                ("tid", Json::num(e.session as f64)),
                ("args", Json::obj(vec![("tier", Json::num(e.tier as f64))])),
            ]),
        ));
    }
    for b in blocks {
        let mut args = vec![
            ("m", Json::num(b.utts.len() as f64)),
            ("steps", Json::num(b.steps as f64)),
            ("tier", Json::num(b.tier as f64)),
        ];
        if !b.spans.is_empty() {
            args.push(("spans", b.spans.to_json()));
        }
        let args = Json::obj(args);
        for &utt in &b.utts {
            rows.push((
                b.clock,
                Json::obj(vec![
                    ("name", Json::str("block")),
                    ("ph", Json::str("X")),
                    ("ts", us(b.clock)),
                    ("dur", us(b.secs)),
                    ("pid", pid_json(b.shard)),
                    ("tid", Json::num(utt as f64)),
                    ("args", args.clone()),
                ]),
            ));
        }
    }
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    events.extend(rows.into_iter().map(|(_, j)| j));

    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Write the Chrome-trace document to `path` (single compact line plus a
/// trailing newline).
pub fn write_chrome_trace(path: &str, journal: &[Event], blocks: &[BlockSpan]) -> Result<()> {
    let doc = chrome_trace(journal, blocks);
    std::fs::write(path, format!("{}\n", doc.to_string_compact()))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Offline replay (`obs-report`)
// ---------------------------------------------------------------------------

/// The `serve-config` row a serve writes as its first JSONL line when an
/// exporter is attached, so the offline analyzer knows the topology and
/// the SLO the run was held to.
#[derive(Clone, Debug)]
pub struct ServeConfigRow {
    pub serve: String,
    pub shards: usize,
    pub pool_size: usize,
    pub chunk_frames: usize,
    pub slo_target: Option<f64>,
    pub slo_deadline: Option<f64>,
    pub slo_budget: Option<f64>,
    pub slo_actions: bool,
}

impl ServeConfigRow {
    fn from_json(j: &Json) -> Result<ServeConfigRow> {
        let opt = |key: &str| j.get(key).and_then(Json::as_f64);
        Ok(ServeConfigRow {
            serve: j.req_str("serve")?.to_string(),
            shards: j.req_usize("shards")?,
            pool_size: j.req_usize("pool_size")?,
            chunk_frames: j.req_usize("chunk_frames")?,
            slo_target: opt("slo_target"),
            slo_deadline: opt("slo_deadline"),
            slo_budget: opt("slo_budget"),
            slo_actions: j.get("slo_actions").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// Everything `obs-report` reconstructs from a `--metrics-out` JSONL:
/// the envelope-validated snapshot stream, the replayed journal and
/// block records, the self-time trend, and any explicit journal-gap
/// rows.
#[derive(Default)]
pub struct Replay {
    /// Snapshot kind seen ("stream-serve" / "ladder-serve").
    pub kind: String,
    /// Total JSONL lines parsed.
    pub lines: usize,
    /// Serve snapshot lines among them.
    pub snapshots: usize,
    /// Clock-ordered journal, reassembled from the per-snapshot deltas.
    pub journal: Vec<Event>,
    /// Stamped block records, reassembled from the per-snapshot deltas.
    pub blocks: Vec<BlockSpan>,
    /// Events the exporter declared lost via `journal-gap` rows.
    pub gap_missed: u64,
    /// Clock of the last serve snapshot.
    pub last_clock: f64,
    /// Cumulative decode spans at the last snapshot.
    pub last_spans: SpanSet,
    /// Plan-time spans at the last snapshot.
    pub last_plan_spans: SpanSet,
    /// (clock, cumulative decode spans) per snapshot — the trend the
    /// analyzer prints.
    pub trend: Vec<(f64, SpanSet)>,
    pub config: Option<ServeConfigRow>,
    /// Lines with an unknown (but validly enveloped) kind, tolerated for
    /// forward compatibility.
    pub other_kinds: usize,
}

impl Replay {
    /// Parse and validate a `--metrics-out` JSONL: every line must carry
    /// the versioned envelope, `seq` must be gapless from 0, and every
    /// journal/block delta must parse.
    pub fn from_jsonl(text: &str) -> Result<Replay> {
        let mut r = Replay::default();
        for line in text.lines() {
            let v = Json::parse(line)
                .map_err(|e| Error::Config(format!("line {}: {e}", r.lines + 1)))?;
            let ver = v.req_usize("schema_version")?;
            if ver != super::SCHEMA_VERSION as usize {
                return Err(Error::Config(format!(
                    "line {}: schema_version {ver} (analyzer speaks {})",
                    r.lines + 1,
                    super::SCHEMA_VERSION
                )));
            }
            let seq = v.req_usize("seq")?;
            if seq != r.lines {
                return Err(Error::Config(format!(
                    "line {}: seq {seq} breaks the gapless envelope (expected {})",
                    r.lines + 1,
                    r.lines
                )));
            }
            r.lines += 1;
            match v.req_str("kind")? {
                "serve-config" => r.config = Some(ServeConfigRow::from_json(&v)?),
                "journal-gap" => r.gap_missed += v.req_f64("missed")? as u64,
                kind @ ("stream-serve" | "ladder-serve") => {
                    r.kind = kind.to_string();
                    r.snapshots += 1;
                    r.last_clock = v.req_f64("clock")?;
                    r.last_spans = SpanSet::from_json(v.req("spans")?)?;
                    r.last_plan_spans = SpanSet::from_json(v.req("plan_spans")?)?;
                    r.trend.push((r.last_clock, r.last_spans));
                    for e in v.req_arr("journal")? {
                        r.journal.push(Event::from_json(e)?);
                    }
                    if let Some(bs) = v.get("blocks") {
                        for b in bs
                            .as_arr()
                            .ok_or_else(|| Error::Config("blocks: not an array".into()))?
                        {
                            r.blocks.push(BlockSpan::from_json(b)?);
                        }
                    }
                }
                _ => r.other_kinds += 1,
            }
        }
        // Same canonical order as `journal::merge`: sorting by content
        // makes the replayed journal independent of how the exporter
        // partitioned it into deltas, so it matches the in-process merge
        // exactly — even with a fixed tick putting many events on equal
        // clocks.
        r.journal.sort_by(super::journal::canonical_cmp);
        Ok(r)
    }

    /// Re-emit the Perfetto trace from the replayed data alone.  With a
    /// gapless JSONL this is byte-identical to the `--trace-out` file the
    /// live serve wrote.
    pub fn chrome_trace(&self) -> Json {
        chrome_trace(&self.journal, &self.blocks)
    }

    pub fn timelines(&self) -> Vec<SessionTimeline> {
        timelines(&self.journal, &self.blocks)
    }
}

/// One session's reconstructed lifecycle.
#[derive(Clone, Debug, Default)]
pub struct SessionTimeline {
    pub session: usize,
    /// Arrival clock (admission event).
    pub admission: Option<f64>,
    /// Placement clock and shard.
    pub placement: Option<f64>,
    pub shard: Option<usize>,
    /// Tier the session last ran on (spills and the drain record win
    /// over the original placement).
    pub tier: Option<usize>,
    /// Drain clock.
    pub drain: Option<f64>,
    /// `pump_block` slices the session participated in.
    pub blocks: usize,
    /// Lifecycle kinds in clock order (admission/placement/spill/drain).
    pub kinds: Vec<EventKind>,
}

impl SessionTimeline {
    /// Arrival-to-final-transcript latency — exactly what the live serve
    /// recorded into its histogram, recovered from the journal.
    pub fn latency(&self) -> Option<f64> {
        Some(self.drain? - self.admission?)
    }
}

/// Group a clock-ordered journal (plus block records) into per-session
/// timelines.  Only lifecycle kinds carry a session id in `session`;
/// backpressure/alert payloads are skipped.
pub fn timelines(journal: &[Event], blocks: &[BlockSpan]) -> Vec<SessionTimeline> {
    let mut by: BTreeMap<usize, SessionTimeline> = BTreeMap::new();
    fn entry(by: &mut BTreeMap<usize, SessionTimeline>, s: usize) -> &mut SessionTimeline {
        by.entry(s).or_insert_with(|| SessionTimeline { session: s, ..Default::default() })
    }
    for e in journal {
        match e.kind {
            EventKind::Admission => {
                let t = entry(&mut by, e.session);
                t.admission = Some(e.clock);
                t.kinds.push(e.kind);
            }
            EventKind::Placement => {
                let t = entry(&mut by, e.session);
                t.placement = Some(e.clock);
                t.shard = Some(e.shard);
                t.tier = Some(e.tier);
                t.kinds.push(e.kind);
            }
            EventKind::TierSpill => {
                let t = entry(&mut by, e.session);
                t.tier = Some(e.tier);
                t.kinds.push(e.kind);
            }
            EventKind::Drain => {
                let t = entry(&mut by, e.session);
                t.drain = Some(e.clock);
                t.tier = Some(e.tier);
                t.kinds.push(e.kind);
            }
            // Shift events are per-shard, backpressure/SLO payloads are
            // not session ids: none of them belong to a timeline.
            EventKind::DownShift
            | EventKind::UpShift
            | EventKind::Backpressure
            | EventKind::SloAlert => {}
        }
    }
    for b in blocks {
        for &utt in &b.utts {
            entry(&mut by, utt).blocks += 1;
        }
    }
    by.into_values().collect()
}

const _: () = crate::assert_send_sync::<BlockSpan>();
const _: () = crate::assert_send_sync::<TraceBuilder>();

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::spans::Stage;

    fn block(shard: usize, utts: Vec<usize>) -> BlockSpan {
        let mut spans = SpanSet::default();
        spans.add(Stage::RecGates, 0.002);
        BlockSpan { clock: 0.0, secs: 0.004, shard, tier: 0, utts, steps: 2, spans }
    }

    #[test]
    fn block_span_json_round_trips() {
        let mut b = block(1, vec![3, 5]);
        b.clock = 0.25;
        let j = b.to_json();
        let back = BlockSpan::from_json(&j).unwrap();
        assert_eq!(back.utts, vec![3, 5]);
        assert_eq!(back.steps, 2);
        assert_eq!(back.shard, 1);
        assert_eq!(back.clock, 0.25);
        assert_eq!(back.spans.calls[Stage::RecGates.index()], 1);
        // span-free blocks drop the key entirely and parse back empty
        let bare = BlockSpan { spans: SpanSet::default(), ..b };
        let j = bare.to_json();
        assert!(j.get("spans").is_none());
        assert!(BlockSpan::from_json(&j).unwrap().spans.is_empty());
    }

    #[test]
    fn stamp_tick_offsets_blocks_and_fixed_mode_is_deterministic() {
        let mut tb = TraceBuilder::new();
        let mut recs = vec![block(0, vec![1]), block(0, vec![1, 2])];
        tb.stamp_tick(1.0, 0.01, &mut recs, false);
        assert!(recs.is_empty());
        assert_eq!(tb.blocks()[0].clock, 1.0);
        assert!((tb.blocks()[1].clock - 1.004).abs() < 1e-12, "second block starts after first");
        // fixed tick: equal shares of dt, spans dropped
        let mut tb = TraceBuilder::new();
        let mut recs = vec![block(0, vec![1]), block(0, vec![1])];
        tb.stamp_tick(2.0, 0.01, &mut recs, true);
        assert_eq!(tb.blocks()[0].secs, 0.005);
        assert_eq!(tb.blocks()[1].clock, 2.005);
        assert!(tb.blocks()[0].spans.is_empty());
    }

    #[test]
    fn delta_ships_each_block_exactly_once() {
        let mut tb = TraceBuilder::new();
        let mut recs = vec![block(0, vec![1])];
        tb.stamp_tick(0.0, 0.01, &mut recs, false);
        assert_eq!(tb.delta().len(), 1);
        assert_eq!(tb.delta().len(), 0, "no new blocks, empty delta");
        let mut recs = vec![block(0, vec![2]), block(0, vec![2])];
        tb.stamp_tick(0.01, 0.01, &mut recs, false);
        assert_eq!(tb.delta().len(), 2);
        assert_eq!(tb.blocks().len(), 3);
    }

    #[test]
    fn chrome_trace_is_deterministic_and_per_session() {
        let journal = vec![
            Event { clock: 0.0, shard: NO_SHARD, session: 7, tier: 0, kind: EventKind::Admission },
            Event { clock: 0.0, shard: 0, session: 7, tier: 0, kind: EventKind::Placement },
            Event { clock: 0.02, shard: 0, session: 7, tier: 0, kind: EventKind::Drain },
        ];
        let mut b = block(0, vec![7, 9]);
        b.clock = 0.01;
        let doc = chrome_trace(&journal, &[b]);
        let text = doc.to_string_compact();
        let again = chrome_trace(
            &journal,
            &[BlockSpan { clock: 0.01, ..block(0, vec![7, 9]) }],
        )
        .to_string_compact();
        assert_eq!(text, again, "pure function of its inputs");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata rows lead: router (-1) then shard 0
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        assert_eq!(events[0].get("pid").unwrap().as_f64(), Some(-1.0));
        assert_eq!(events[1].get("pid").unwrap().as_f64(), Some(0.0));
        // one "X" slice per participating session
        let slices: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).collect();
        assert_eq!(slices.len(), 2);
        assert_eq!(slices[0].get("tid").unwrap().as_usize(), Some(7));
        assert_eq!(slices[1].get("tid").unwrap().as_usize(), Some(9));
        assert_eq!(slices[0].get("ts").unwrap().as_f64(), Some(10_000.0), "µs timestamps");
        // instants ride the session's track too
        let drains: Vec<&Json> =
            events.iter().filter(|e| e.get("name").unwrap().as_str() == Some("drain")).collect();
        assert_eq!(drains[0].get("tid").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn replay_validates_the_envelope_and_rebuilds_timelines() {
        let lines = [
            r#"{"schema_version":1,"kind":"serve-config","seq":0,"clock":0,"serve":"stream-serve","shards":1,"pool_size":2,"chunk_frames":8,"slo_target":0.25,"slo_deadline":0.25,"slo_budget":0.01,"slo_actions":false}"#,
            r#"{"schema_version":1,"kind":"stream-serve","seq":1,"clock":0.5,"spans":{"rec_gates":{"calls":4,"secs":0.004},"total_secs":0.004},"plan_spans":{"total_secs":0},"counters":[],"journal":[{"clock":0.1,"kind":"admission","session":0,"shard":-1,"tier":0},{"clock":0.1,"kind":"placement","session":0,"shard":0,"tier":0}],"blocks":[{"clock":0.2,"secs":0.004,"shard":0,"steps":2,"tier":0,"utts":[0]}],"journal_missed":0}"#,
            r#"{"schema_version":1,"kind":"journal-gap","seq":2,"clock":0.6,"missed":3}"#,
            r#"{"schema_version":1,"kind":"stream-serve","seq":3,"clock":1.0,"spans":{"rec_gates":{"calls":8,"secs":0.008},"total_secs":0.008},"plan_spans":{"total_secs":0},"counters":[],"journal":[{"clock":0.9,"kind":"drain","session":0,"shard":0,"tier":0}],"blocks":[],"journal_missed":0}"#,
        ];
        let r = Replay::from_jsonl(&lines.join("\n")).unwrap();
        assert_eq!(r.lines, 4);
        assert_eq!(r.snapshots, 2);
        assert_eq!(r.gap_missed, 3);
        assert_eq!(r.kind, "stream-serve");
        assert_eq!(r.journal.len(), 3);
        assert_eq!(r.blocks.len(), 1);
        assert_eq!(r.trend.len(), 2);
        assert_eq!(r.config.as_ref().unwrap().slo_target, Some(0.25));
        let tl = r.timelines();
        assert_eq!(tl.len(), 1);
        assert_eq!(tl[0].session, 0);
        assert_eq!(tl[0].blocks, 1);
        assert!((tl[0].latency().unwrap() - 0.8).abs() < 1e-12);
        assert_eq!(
            tl[0].kinds,
            vec![EventKind::Admission, EventKind::Placement, EventKind::Drain]
        );
    }

    #[test]
    fn replay_rejects_broken_envelopes() {
        let bad_seq = [
            r#"{"schema_version":1,"kind":"journal-gap","seq":0,"clock":0,"missed":1}"#,
            r#"{"schema_version":1,"kind":"journal-gap","seq":2,"clock":0,"missed":1}"#,
        ]
        .join("\n");
        assert!(Replay::from_jsonl(&bad_seq).is_err(), "seq gap must fail validation");
        let bad_ver = r#"{"schema_version":9,"kind":"journal-gap","seq":0,"clock":0,"missed":1}"#;
        assert!(Replay::from_jsonl(bad_ver).is_err(), "wrong schema_version must fail");
        assert!(Replay::from_jsonl("not json").is_err());
    }
}

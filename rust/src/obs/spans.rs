//! Stage spans: the fixed self-time taxonomy and its accumulators.
//!
//! [`Stage`] names every place the runtime spends time; [`SpanSet`] is a
//! pair of fixed arrays (seconds + call counts) embedded in
//! `infer::Breakdown`, so per-stream accumulation is plain field
//! arithmetic — no allocation, no locks, merged across shards with
//! [`SpanSet::absorb`] exactly like the rest of the breakdown.
//!
//! Self-time discipline: every second of a decode is attributed to
//! **exactly one** stage.  The engine's staged primitives already time
//! themselves for the legacy `Breakdown` fields; the span layer reuses
//! those measurements and *subtracts* nested quantization time (collected
//! in a thread-local pending cell by `QDense`) from the enclosing stage,
//! so `frontend + nonrec + rec_gates + gru_cell + head + quantize +
//! decode` sums to the measured wall time of the block loop instead of
//! double-counting.
//!
//! Plan-time work (weight packing, autotune probes, build-time
//! quantization) happens outside any stream, possibly on several threads
//! at once, so it accumulates into process-global atomic nanosecond
//! cells ([`record_global`] / [`global_snapshot`]) reported separately
//! from the decode spans.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::jsonx::Json;

/// Every stage the runtime attributes time to.  The order is the wire
/// order of the JSON arrays; append only (the schema version covers
/// renames/removals).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Strided conv frontend GEMMs.
    Frontend,
    /// Non-recurrent (input-side) GRU GEMMs over the whole block.
    Nonrec,
    /// Recurrent gate pre-activation GEMMs (plain or fused).
    RecGates,
    /// The element-wise GRU cell update.
    GruCell,
    /// FC + output head GEMMs and the log-softmax.
    Head,
    /// int8 activation quantization (nested inside the GEMM stages;
    /// subtracted from them so the sum stays exact).
    Quantize,
    /// Plan-time weight packing (`PreparedQMatrix` construction).
    Pack,
    /// Greedy CTC decode + transcript collapse.
    Decode,
    /// Construction-time NR/KC tile probing.
    Autotune,
}

/// Number of stages (array sizes below).
pub const NUM_STAGES: usize = 9;

/// All stages in wire order.
pub const ALL: [Stage; NUM_STAGES] = [
    Stage::Frontend,
    Stage::Nonrec,
    Stage::RecGates,
    Stage::GruCell,
    Stage::Head,
    Stage::Quantize,
    Stage::Pack,
    Stage::Decode,
    Stage::Autotune,
];

impl Stage {
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    pub const fn name(self) -> &'static str {
        match self {
            Stage::Frontend => "frontend",
            Stage::Nonrec => "nonrec",
            Stage::RecGates => "rec_gates",
            Stage::GruCell => "gru_cell",
            Stage::Head => "head",
            Stage::Quantize => "quantize",
            Stage::Pack => "pack",
            Stage::Decode => "decode",
            Stage::Autotune => "autotune",
        }
    }

    /// Inverse of [`Stage::name`], for the `obs-report` JSONL replay.
    pub fn parse(name: &str) -> Option<Stage> {
        ALL.iter().copied().find(|s| s.name() == name)
    }
}

/// A fixed-size span accumulator: seconds and call counts per stage.
/// `Copy` + `Default` so it rides inside `Breakdown` without changing
/// that type's contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanSet {
    pub secs: [f64; NUM_STAGES],
    pub calls: [u64; NUM_STAGES],
}

impl SpanSet {
    /// Attribute `secs` of self time (one call) to `stage`.
    #[inline]
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.index()] += secs;
        self.calls[stage.index()] += 1;
    }

    /// Merge another span set in (cross-shard / cross-stream absorption,
    /// mirroring `Breakdown::absorb`).
    pub fn absorb(&mut self, o: &SpanSet) {
        for i in 0..NUM_STAGES {
            self.secs[i] += o.secs[i];
            self.calls[i] += o.calls[i];
        }
    }

    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage.index()]
    }

    /// Total attributed self time across every stage.
    pub fn total_secs(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.calls.iter().all(|&c| c == 0)
    }

    /// The span activity accumulated since `earlier` was snapshotted
    /// (per-stage subtraction).  `SpanSet` is `Copy`, so the traced pump
    /// loop snapshots the breakdown before each block and diffs after —
    /// that difference is the block's own SpanSet.
    pub fn delta_from(&self, earlier: &SpanSet) -> SpanSet {
        let mut d = SpanSet::default();
        for i in 0..NUM_STAGES {
            d.secs[i] = self.secs[i] - earlier.secs[i];
            d.calls[i] = self.calls[i] - earlier.calls[i];
        }
        d
    }

    /// Inverse of [`SpanSet::to_json`] (the `total_secs` scalar is
    /// derived, so it is ignored on the way back in).  Unknown keys are
    /// tolerated for forward compatibility within a schema version.
    pub fn from_json(j: &Json) -> crate::error::Result<SpanSet> {
        let bad = |m: String| crate::error::Error::Config(m);
        let mut out = SpanSet::default();
        let obj = j.as_obj().ok_or_else(|| bad("spans: not an object".into()))?;
        for (k, v) in obj {
            if k == "total_secs" {
                continue;
            }
            let Some(s) = Stage::parse(k) else { continue };
            out.secs[s.index()] = v
                .get("secs")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad(format!("spans.{k}: missing secs")))?;
            out.calls[s.index()] = v
                .get("calls")
                .and_then(Json::as_f64)
                .ok_or_else(|| bad(format!("spans.{k}: missing calls")))?
                as u64;
        }
        Ok(out)
    }

    /// `{"frontend": {"secs": .., "calls": ..}, ...}` — only stages that
    /// were hit, plus a `total_secs` scalar for the 5%-of-wall check.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        for s in ALL {
            if self.calls[s.index()] > 0 {
                pairs.push((
                    s.name(),
                    Json::obj(vec![
                        ("secs", Json::num(self.secs[s.index()])),
                        ("calls", Json::num(self.calls[s.index()] as f64)),
                    ]),
                ));
            }
        }
        pairs.push(("total_secs", Json::num(self.total_secs())));
        Json::obj(pairs)
    }
}

/// Render a span set as an aligned text table, stages sorted by self
/// time descending with a share bar — the flamegraph-style view of the
/// plain-text serve report.
pub fn table(spans: &SpanSet, label: &str) -> String {
    let total = spans.total_secs();
    if total <= 0.0 {
        return format!("  ({label}: no samples)\n");
    }
    let mut rows: Vec<Stage> = ALL.iter().copied().filter(|s| spans.calls[s.index()] > 0).collect();
    rows.sort_by(|a, b| spans.get(*b).total_cmp(&spans.get(*a)));
    let mut out = String::new();
    for s in rows {
        let secs = spans.get(s);
        let frac = secs / total;
        let bar = "#".repeat((frac * 30.0).round() as usize);
        out.push_str(&format!(
            "  {label:>6}  {:<10} {:>9.3} ms  {:>5.1}%  {:>8} calls  {bar}\n",
            s.name(),
            secs * 1e3,
            frac * 100.0,
            spans.calls[s.index()],
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Plan-time global spans (pack / autotune / build-time quantize)
// ---------------------------------------------------------------------------

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static GLOBAL_NANOS: [AtomicU64; NUM_STAGES] = [ZERO; NUM_STAGES];
static GLOBAL_CALLS: [AtomicU64; NUM_STAGES] = [ZERO; NUM_STAGES];

/// Attribute plan-time work to a stage, process-globally (relaxed
/// atomics; plan work is rare and coarse).
pub fn record_global(stage: Stage, secs: f64) {
    GLOBAL_NANOS[stage.index()].fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
    GLOBAL_CALLS[stage.index()].fetch_add(1, Ordering::Relaxed);
}

/// Snapshot the plan-time spans into an ordinary [`SpanSet`].
pub fn global_snapshot() -> SpanSet {
    let mut s = SpanSet::default();
    for i in 0..NUM_STAGES {
        s.secs[i] = GLOBAL_NANOS[i].load(Ordering::Relaxed) as f64 / 1e9;
        s.calls[i] = GLOBAL_CALLS[i].load(Ordering::Relaxed);
    }
    s
}

/// Zero the plan-time spans (serve entry / test isolation).
pub fn reset_global() {
    for i in 0..NUM_STAGES {
        GLOBAL_NANOS[i].store(0, Ordering::Relaxed);
        GLOBAL_CALLS[i].store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Nested-quantize pending cell
// ---------------------------------------------------------------------------

thread_local! {
    /// Seconds of activation quantization accumulated inside the current
    /// enclosing stage.  `Cell<f64>` has no destructor, so the slot costs
    /// no allocation or TLS teardown registration.
    static PENDING_QUANT: Cell<f64> = const { Cell::new(0.0) };
}

/// Record nested quantization time (called by `QDense` with obs on).
#[inline]
pub fn add_pending_quantize(secs: f64) {
    PENDING_QUANT.with(|c| c.set(c.get() + secs));
}

/// Drain the pending quantization time at a stage boundary: the caller
/// attributes the drained seconds to [`Stage::Quantize`] and the
/// remainder of its own elapsed time to itself.
#[inline]
pub fn take_pending_quantize() -> f64 {
    PENDING_QUANT.with(|c| c.replace(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_absorb_total() {
        let mut a = SpanSet::default();
        a.add(Stage::Frontend, 0.5);
        a.add(Stage::Quantize, 0.25);
        let mut b = SpanSet::default();
        b.add(Stage::Frontend, 1.0);
        a.absorb(&b);
        assert_eq!(a.get(Stage::Frontend), 1.5);
        assert_eq!(a.calls[Stage::Frontend.index()], 2);
        assert!((a.total_secs() - 1.75).abs() < 1e-12);
        assert!(!a.is_empty());
        assert!(SpanSet::default().is_empty());
    }

    #[test]
    fn json_skips_cold_stages_and_carries_total() {
        let mut s = SpanSet::default();
        s.add(Stage::Head, 2.0);
        let j = s.to_json();
        assert!(j.get("head").is_some());
        assert!(j.get("frontend").is_none(), "untouched stages stay out of the report");
        assert_eq!(j.get("total_secs").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn stage_indices_match_wire_order() {
        for (i, s) in ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
    }

    #[test]
    fn pending_quantize_drains_to_zero() {
        add_pending_quantize(0.125);
        add_pending_quantize(0.125);
        assert_eq!(take_pending_quantize(), 0.25);
        assert_eq!(take_pending_quantize(), 0.0);
    }

    #[test]
    fn global_spans_round_trip() {
        reset_global();
        record_global(Stage::Pack, 0.001);
        record_global(Stage::Autotune, 0.002);
        let s = global_snapshot();
        assert!(s.get(Stage::Pack) > 0.0);
        assert_eq!(s.calls[Stage::Autotune.index()], 1);
        reset_global();
        assert!(global_snapshot().is_empty());
    }

    #[test]
    fn table_sorts_by_self_time() {
        let mut s = SpanSet::default();
        s.add(Stage::Frontend, 0.1);
        s.add(Stage::RecGates, 0.7);
        let t = table(&s, "decode");
        let rec = t.find("rec_gates").unwrap();
        let fr = t.find("frontend").unwrap();
        assert!(rec < fr, "hotter stage prints first:\n{t}");
    }
}

//! JSONL metrics exporter (`--metrics-out FILE`).
//!
//! One JSON document per line, each stamped with the obs
//! [`SCHEMA_VERSION`](crate::obs::SCHEMA_VERSION), a monotone `seq`, a
//! snapshot `kind` and the simulated clock.  Serve loops write a
//! snapshot every few rounds plus a final one; `train --native` writes
//! one per epoch.  Lines are flushed as written so a killed run still
//! leaves a valid prefix — every line must parse on its own
//! (`python3 -m json.tool` per line in CI).

use std::fs::File;
use std::io::{BufWriter, Write};

use crate::error::Result;
use crate::jsonx::Json;

use super::journal::Journal;
use super::trace::BlockSpan;
use super::{counters, journal, spans, trace, SpanSet};

/// Rounds between periodic serve snapshots (plus one final snapshot at
/// drain).  Coarse on purpose: the exporter is for trend lines, not
/// per-round tracing — the journal carries the per-event record.
pub const EXPORT_EVERY_ROUNDS: usize = 32;

pub struct MetricsExporter {
    w: BufWriter<File>,
    seq: u64,
    /// Per-shard journal cursors (sequence numbers already exported).
    cursors: Vec<u64>,
}

impl MetricsExporter {
    pub fn create(path: &str) -> Result<Self> {
        Ok(MetricsExporter { w: BufWriter::new(File::create(path)?), seq: 0, cursors: Vec::new() })
    }

    /// Write one snapshot line: the standard envelope
    /// (`schema_version`, `kind`, `seq`, `clock`) plus `body` fields.
    pub fn write_snapshot(
        &mut self,
        kind: &str,
        clock: f64,
        body: Vec<(&str, Json)>,
    ) -> Result<()> {
        let mut pairs = vec![
            ("schema_version", Json::num(super::SCHEMA_VERSION as f64)),
            ("kind", Json::str(kind)),
            ("seq", Json::num(self.seq as f64)),
            ("clock", Json::num(clock)),
        ];
        pairs.extend(body);
        self.seq += 1;
        writeln!(self.w, "{}", Json::obj(pairs).to_string_compact())?;
        self.w.flush()?;
        Ok(())
    }

    /// The serve-loop snapshot: decode spans so far, plan spans, kernel
    /// counters, the journal events new since the last snapshot, and the
    /// block-trace records stamped since then.
    ///
    /// If any ring lapped its cursor since the last snapshot, the lost
    /// events cannot be recovered — rather than pretending the delta is
    /// complete, an explicit `{"kind":"journal-gap","missed":N}` row is
    /// written first (its own JSONL line, in the same seq stream), so an
    /// offline replay knows exactly how many events it is missing.
    pub fn write_serve_snapshot(
        &mut self,
        kind: &str,
        clock: f64,
        decode_spans: &SpanSet,
        journals: &[Journal],
        blocks: &[BlockSpan],
    ) -> Result<()> {
        if self.cursors.len() < journals.len() {
            self.cursors.resize(journals.len(), 0);
        }
        let mut delta = Vec::new();
        let mut missed = 0u64;
        for (i, j) in journals.iter().enumerate() {
            let (evs, m) = j.events_since(self.cursors[i]);
            self.cursors[i] = j.total_pushed();
            delta.extend(evs);
            missed += m;
        }
        delta.sort_by(journal::canonical_cmp);
        if missed > 0 {
            self.write_snapshot("journal-gap", clock, vec![("missed", Json::num(missed as f64))])?;
        }
        self.write_snapshot(
            kind,
            clock,
            vec![
                ("spans", decode_spans.to_json()),
                ("plan_spans", spans::global_snapshot().to_json()),
                ("counters", counters::snapshot()),
                ("journal", journal::events_to_json(&delta)),
                ("journal_missed", Json::num(missed as f64)),
                ("blocks", trace::blocks_to_json(blocks)),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::{Event, EventKind};
    use crate::obs::Stage;

    fn temp_path(tag: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("tracenorm_obs_export_{tag}_{}.jsonl", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn lines_parse_individually_and_carry_the_envelope() {
        let path = temp_path("env");
        let mut ex = MetricsExporter::create(&path).unwrap();
        ex.write_snapshot("train-epoch", 0.0, vec![("mean_loss", Json::num(1.5))]).unwrap();
        ex.write_snapshot("train-epoch", 1.0, vec![("mean_loss", Json::num(1.25))]).unwrap();
        drop(ex);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.get("schema_version").unwrap().as_usize(), Some(1));
            assert_eq!(v.get("kind").unwrap().as_str(), Some("train-epoch"));
            assert_eq!(v.get("seq").unwrap().as_usize(), Some(i));
            assert!(v.get("mean_loss").is_some());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serve_snapshots_ship_journal_deltas_once() {
        let path = temp_path("delta");
        let mut ex = MetricsExporter::create(&path).unwrap();
        let mut spans = SpanSet::default();
        spans.add(Stage::RecGates, 0.25);
        let mut j = Journal::with_capacity(8);
        j.push(Event { clock: 0.1, shard: 0, session: 0, tier: 0, kind: EventKind::Placement });
        ex.write_serve_snapshot("stream-serve", 0.2, &spans, std::slice::from_ref(&j), &[])
            .unwrap();
        j.push(Event { clock: 0.3, shard: 0, session: 0, tier: 0, kind: EventKind::Drain });
        let block = BlockSpan {
            clock: 0.25,
            secs: 0.01,
            shard: 0,
            tier: 0,
            utts: vec![0],
            steps: 2,
            spans: SpanSet::default(),
        };
        ex.write_serve_snapshot(
            "stream-serve",
            0.4,
            &spans,
            std::slice::from_ref(&j),
            std::slice::from_ref(&block),
        )
        .unwrap();
        drop(ex);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].get("journal").unwrap().as_arr().unwrap().len(), 1);
        assert!(lines[0].get("blocks").unwrap().as_arr().unwrap().is_empty());
        let second = lines[1].get("journal").unwrap().as_arr().unwrap();
        assert_eq!(second.len(), 1, "second snapshot ships only the new event");
        assert_eq!(second[0].get("kind").unwrap().as_str(), Some("drain"));
        assert!(lines[1].get("spans").unwrap().get("rec_gates").is_some());
        let blocks = lines[1].get("blocks").unwrap().as_arr().unwrap();
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].get("utts").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ring_lap_emits_an_explicit_gap_row_and_drops_nothing_silently() {
        let path = temp_path("gap");
        let mut ex = MetricsExporter::create(&path).unwrap();
        let spans = SpanSet::default();
        let mut j = Journal::with_capacity(2);
        let ev = |clock: f64, session: usize| Event {
            clock,
            shard: 0,
            session,
            tier: 0,
            kind: EventKind::Placement,
        };
        j.push(ev(0.1, 0));
        ex.write_serve_snapshot("stream-serve", 0.2, &spans, std::slice::from_ref(&j), &[])
            .unwrap();
        // push 3 more into a 2-ring: seq 1 survives only until seq 3
        // lands, so the exporter's cursor (1) gets lapped by one event.
        j.push(ev(0.3, 1));
        j.push(ev(0.4, 2));
        j.push(ev(0.5, 3));
        ex.write_serve_snapshot("stream-serve", 0.6, &spans, std::slice::from_ref(&j), &[])
            .unwrap();
        ex.write_serve_snapshot("stream-serve", 0.7, &spans, std::slice::from_ref(&j), &[])
            .unwrap();
        drop(ex);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 4, "snapshot, gap row + snapshot, snapshot");
        assert_eq!(lines[1].get("kind").unwrap().as_str(), Some("journal-gap"));
        assert_eq!(lines[1].get("missed").unwrap().as_usize(), Some(1));
        // seq stream stays gapless across the extra row
        for (i, l) in lines.iter().enumerate() {
            assert_eq!(l.get("seq").unwrap().as_usize(), Some(i));
        }
        // shipped events + declared gap account for every push exactly once
        let shipped: Vec<usize> = lines
            .iter()
            .filter_map(|l| l.get("journal"))
            .flat_map(|a| a.as_arr().unwrap().iter())
            .map(|e| e.get("session").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(shipped, vec![0, 2, 3], "session 1 was lapped, nothing duplicated");
        let missed: usize =
            lines.iter().filter_map(|l| l.get("missed")).map(|m| m.as_usize().unwrap()).sum();
        assert_eq!(shipped.len() + missed, j.total_pushed() as usize);
        std::fs::remove_file(&path).ok();
    }
}

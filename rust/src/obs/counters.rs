//! Kernel counters: per-(backend, op kind, m-bucket) atomic tallies.
//!
//! Every `GemmBackend` dispatch site (`QDense::apply_*` in `infer.rs`)
//! reports the op it ran — kind, activation batch m, MACs, bytes moved,
//! and kernel nanoseconds — into a fixed grid of static atomic cells.
//! The grid is allocated at compile time, so recording is lock-free and
//! allocation-free on the steady-state decode path; with obs off the
//! sites skip the record entirely (one relaxed load).
//!
//! The m-bucket axis mirrors the paper's small-batch sweep (Fig. 6):
//! m = 1 (the GEMV path), 2–4, 5–8, and >8 — live GOP/s per backend and
//! shape class next to the `BENCH_gemm.json` numbers.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::jsonx::Json;

/// What kind of kernel call ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// int8 farm GEMM (`qgemm_farm_into` / `qgemm_farm_rows_into`).
    Gemm,
    /// m = 1 int8 GEMV fast path.
    Gemv,
    /// Fused GRU-gate sweep (`qgemm_gates_rows_into`).
    FusedGates,
    /// f32 reference GEMM.
    F32,
    /// int4 farm GEMM (`qgemm4_farm_into` / `qgemm4_farm_rows_into`).
    Gemm4,
    /// m = 1 int4 GEMV fast path.
    Gemv4,
    /// Fused int4 GRU-gate sweep (`qgemm4_gates_rows_into`).
    FusedGates4,
}

pub const NUM_KINDS: usize = 7;
pub const ALL_KINDS: [OpKind; NUM_KINDS] = [
    OpKind::Gemm,
    OpKind::Gemv,
    OpKind::FusedGates,
    OpKind::F32,
    OpKind::Gemm4,
    OpKind::Gemv4,
    OpKind::FusedGates4,
];

impl OpKind {
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    pub const fn name(self) -> &'static str {
        match self {
            OpKind::Gemm => "gemm",
            OpKind::Gemv => "gemv",
            OpKind::FusedGates => "fused_gates",
            OpKind::F32 => "f32",
            OpKind::Gemm4 => "qgemm4",
            OpKind::Gemv4 => "qgemv4",
            OpKind::FusedGates4 => "qgemm4_gates",
        }
    }
}

/// Activation-batch buckets: m = 1, 2–4, 5–8, >8.
pub const NUM_BUCKETS: usize = 4;
pub const BUCKET_NAMES: [&str; NUM_BUCKETS] = ["m1", "m2_4", "m5_8", "m_gt8"];

#[inline]
pub const fn m_bucket(m: usize) -> usize {
    match m {
        0 | 1 => 0,
        2..=4 => 1,
        5..=8 => 2,
        _ => 3,
    }
}

/// Backend axis: the known `GemmBackend::name()` values, plus a spill
/// slot so an out-of-tree backend still counts somewhere.
pub const NUM_BACKENDS: usize = 4;
pub const BACKEND_NAMES: [&str; NUM_BACKENDS] = ["scalar", "blocked", "simd", "other"];

#[inline]
fn backend_index(name: &str) -> usize {
    match name {
        "scalar" => 0,
        "blocked" => 1,
        "simd" => 2,
        _ => 3,
    }
}

struct Cell {
    calls: AtomicU64,
    macs: AtomicU64,
    bytes: AtomicU64,
    nanos: AtomicU64,
}

impl Cell {
    const fn new() -> Self {
        Cell {
            calls: AtomicU64::new(0),
            macs: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            nanos: AtomicU64::new(0),
        }
    }
}

const NUM_CELLS: usize = NUM_BACKENDS * NUM_KINDS * NUM_BUCKETS;
#[allow(clippy::declare_interior_mutable_const)]
const EMPTY: Cell = Cell::new();
static CELLS: [Cell; NUM_CELLS] = [EMPTY; NUM_CELLS];

#[inline]
fn cell(backend: usize, kind: OpKind, bucket: usize) -> &'static Cell {
    &CELLS[(backend * NUM_KINDS + kind.index()) * NUM_BUCKETS + bucket]
}

/// Record one kernel call.  `bytes` counts operand reads + result
/// writes (`kernels::farm_counts`), `nanos` the kernel wall time.
#[inline]
pub fn record(backend: &str, kind: OpKind, m: usize, macs: u64, bytes: u64, nanos: u64) {
    let c = cell(backend_index(backend), kind, m_bucket(m));
    c.calls.fetch_add(1, Ordering::Relaxed);
    c.macs.fetch_add(macs, Ordering::Relaxed);
    c.bytes.fetch_add(bytes, Ordering::Relaxed);
    c.nanos.fetch_add(nanos, Ordering::Relaxed);
}

/// Total kernel calls recorded so far (all cells) — the freeze probe for
/// the `--obs off` tests.
pub fn total_calls() -> u64 {
    CELLS.iter().map(|c| c.calls.load(Ordering::Relaxed)).sum()
}

/// Cascade tallies: blocks that went through the confidence gate and
/// the subset that escalated to the high rung.  Separate from the
/// kernel-cell grid because the unit is a decode block, not a kernel
/// dispatch.
static CASCADE_BLOCKS: AtomicU64 = AtomicU64::new(0);
static CASCADE_ESCALATED: AtomicU64 = AtomicU64::new(0);

/// Record cascade gate outcomes: `blocks` low-rung blocks scored, of
/// which `escalated` breached the threshold and re-ran on the high rung.
#[inline]
pub fn record_cascade(blocks: u64, escalated: u64) {
    CASCADE_BLOCKS.fetch_add(blocks, Ordering::Relaxed);
    CASCADE_ESCALATED.fetch_add(escalated, Ordering::Relaxed);
}

/// `(blocks_scored, blocks_escalated)` since the last `reset`.
pub fn cascade_totals() -> (u64, u64) {
    (
        CASCADE_BLOCKS.load(Ordering::Relaxed),
        CASCADE_ESCALATED.load(Ordering::Relaxed),
    )
}

/// Zero every cell (serve entry / test isolation).
pub fn reset() {
    for c in &CELLS {
        c.calls.store(0, Ordering::Relaxed);
        c.macs.store(0, Ordering::Relaxed);
        c.bytes.store(0, Ordering::Relaxed);
        c.nanos.store(0, Ordering::Relaxed);
    }
    CASCADE_BLOCKS.store(0, Ordering::Relaxed);
    CASCADE_ESCALATED.store(0, Ordering::Relaxed);
}

/// Snapshot the non-empty cells as a JSON array of rows:
/// `{"backend", "op", "m_bucket", "calls", "macs", "bytes", "secs",
/// "gops"}` — `gops` is MACs*2 / secs / 1e9 (0 when untimed).
pub fn snapshot() -> Json {
    let mut rows = Vec::new();
    for (bi, bname) in BACKEND_NAMES.iter().enumerate() {
        for kind in ALL_KINDS {
            for (mi, mname) in BUCKET_NAMES.iter().enumerate() {
                let c = cell(bi, kind, mi);
                let calls = c.calls.load(Ordering::Relaxed);
                if calls == 0 {
                    continue;
                }
                let macs = c.macs.load(Ordering::Relaxed);
                let secs = c.nanos.load(Ordering::Relaxed) as f64 / 1e9;
                let gops = if secs > 0.0 { macs as f64 * 2.0 / secs / 1e9 } else { 0.0 };
                rows.push(Json::obj(vec![
                    ("backend", Json::str(*bname)),
                    ("op", Json::str(kind.name())),
                    ("m_bucket", Json::str(*mname)),
                    ("calls", Json::num(calls as f64)),
                    ("macs", Json::num(macs as f64)),
                    ("bytes", Json::num(c.bytes.load(Ordering::Relaxed) as f64)),
                    ("secs", Json::num(secs)),
                    ("gops", Json::num(gops)),
                ]));
            }
        }
    }
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_kind_indices_stay_dense() {
        // the cell grid indexes by `self as usize`: every kind must map
        // into [0, NUM_KINDS) with no gaps
        for (i, kind) in ALL_KINDS.iter().enumerate() {
            assert_eq!(kind.index(), i, "{}", kind.name());
        }
        assert_eq!(ALL_KINDS.len(), NUM_KINDS);
    }

    #[test]
    fn buckets_cover_the_small_batch_sweep() {
        assert_eq!(m_bucket(1), 0);
        assert_eq!(m_bucket(2), 1);
        assert_eq!(m_bucket(4), 1);
        assert_eq!(m_bucket(5), 2);
        assert_eq!(m_bucket(8), 2);
        assert_eq!(m_bucket(9), 3);
        assert_eq!(m_bucket(128), 3);
    }

    #[test]
    fn record_snapshot_reset() {
        reset();
        record("blocked", OpKind::Gemv, 1, 1000, 2000, 500);
        record("blocked", OpKind::Gemv, 1, 1000, 2000, 500);
        record("nonesuch", OpKind::F32, 16, 10, 20, 0);
        assert_eq!(total_calls(), 3);
        let rows = snapshot();
        let rows = rows.as_arr().unwrap();
        assert_eq!(rows.len(), 2, "one row per hot cell");
        let gemv = rows
            .iter()
            .find(|r| r.get("op").unwrap().as_str() == Some("gemv"))
            .expect("gemv row");
        assert_eq!(gemv.get("backend").unwrap().as_str(), Some("blocked"));
        assert_eq!(gemv.get("m_bucket").unwrap().as_str(), Some("m1"));
        assert_eq!(gemv.get("calls").unwrap().as_f64(), Some(2.0));
        assert_eq!(gemv.get("macs").unwrap().as_f64(), Some(2000.0));
        // 2000 MACs * 2 ops / 1e-6 s / 1e9 = 4 GOP/s
        assert!((gemv.get("gops").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
        let other = rows
            .iter()
            .find(|r| r.get("op").unwrap().as_str() == Some("f32"))
            .expect("f32 row");
        assert_eq!(other.get("backend").unwrap().as_str(), Some("other"));
        assert_eq!(other.get("gops").unwrap().as_f64(), Some(0.0), "untimed row reports 0");
        // cascade tallies live on the same reset cycle as the cell grid
        record_cascade(4, 1);
        record_cascade(1, 0);
        assert_eq!(cascade_totals(), (5, 1));
        reset();
        assert_eq!(total_calls(), 0);
        assert!(snapshot().as_arr().unwrap().is_empty());
        assert_eq!(cascade_totals(), (0, 0));
    }
}

//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`From` impls (no `thiserror` in the offline
//! build); the `Error::Xla` variant only exists when the `xla` feature
//! is enabled, so the default build carries no XLA surface at all.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),

    #[cfg(feature = "xla")]
    Xla(xla::Error),

    Json { pos: usize, msg: String },

    Config(String),

    Manifest(String),

    Checkpoint(String),

    Shape(String),

    Linalg(String),

    Train(String),

    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            #[cfg(feature = "xla")]
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Json { pos, msg } => write!(f, "json parse error at byte {pos}: {msg}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Linalg(m) => write!(f, "linalg error: {m}"),
            Error::Train(m) => write!(f, "train error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            #[cfg(feature = "xla")]
            Error::Xla(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_variants() {
        assert_eq!(Error::other("boom").to_string(), "boom");
        assert_eq!(Error::Config("bad flag".into()).to_string(), "config error: bad flag");
        assert_eq!(
            Error::Checkpoint("poisoned".into()).to_string(),
            "checkpoint error: poisoned"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(io.to_string().starts_with("io error:"));
    }
}

//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),

    #[error("json parse error at byte {pos}: {msg}")]
    Json { pos: usize, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("shape error: {0}")]
    Shape(String),

    #[error("linalg error: {0}")]
    Linalg(String),

    #[error("train error: {0}")]
    Train(String),

    #[error("{0}")]
    Other(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}
